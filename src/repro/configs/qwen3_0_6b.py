"""qwen3-0.6b [dense]: 28L d_model=1024 16H (GQA kv=8) d_ff=3072
vocab=151936 — qk_norm, GQA. [hf:Qwen/Qwen3-0.6B; hf]"""

from repro.configs.base import ModelConfig, SWMConfig

CONFIG = ModelConfig(
    name="qwen3-0.6b",
    family="lm",
    n_layers=28,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    head_dim=128,
    d_ff=3072,
    vocab=151936,
    qk_norm=True,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    swm=SWMConfig(block_size=128, impl="paper"),
    remat="block",
)

SMOKE = ModelConfig(
    name="qwen3-smoke",
    family="lm",
    n_layers=3,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab=256,
    qk_norm=True,
    rope_theta=1_000_000.0,
    swm=SWMConfig(block_size=8, impl="paper"),
    remat="none",
    param_dtype="float32",
    compute_dtype="float32",
)
