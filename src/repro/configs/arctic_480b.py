"""arctic-480b [moe]: 35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000,
MoE 128 experts top-2 + dense residual FFN in parallel.
[hf:Snowflake/snowflake-arctic-base; hf]

Dense-baseline note: 480B params with AdamW-f32 moments does NOT fit 256
v5e chips (476B·10B ≈ 4.8TB > 4.1TB fleet HBM); the config therefore uses
FSDP (params over data×model) + bf16 moments. With the paper's SWM (k=128)
the expert weights shrink 128× and the whole problem fits trivially — this
arch is the strongest demonstration of the paper's storage claim.
"""

from repro.configs.base import ModelConfig, SWMConfig, TrainConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="lm",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=4864,
    vocab=32000,
    n_experts=128,
    n_experts_per_token=2,
    d_ff_expert=4864,
    moe_every=1,
    dense_residual_ffn=True,
    capacity_factor=1.25,
    rope_theta=10_000.0,
    tie_embeddings=False,
    swm=SWMConfig(block_size=128, impl="paper"),
    fsdp=True,
    remat="block",
)

SMOKE = ModelConfig(
    name="arctic-smoke",
    family="lm",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=96,
    vocab=256,
    n_experts=8,
    n_experts_per_token=2,
    d_ff_expert=96,
    dense_residual_ffn=True,
    tie_embeddings=False,
    swm=SWMConfig(block_size=8, impl="paper"),
    remat="none",
    param_dtype="float32",
    compute_dtype="float32",
)
