"""gemma3-27b [dense]: 62L d_model=5376 32H (GQA kv=16) d_ff=21504
vocab=262144 — 5:1 local:global interleave, 128k context.
[hf:google/gemma-3-*; unverified]"""

from repro.configs.base import ModelConfig, SWMConfig

CONFIG = ModelConfig(
    name="gemma3-27b",
    family="lm",
    n_layers=62,
    d_model=5376,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    d_ff=21504,
    vocab=262144,
    sliding_window=1024,
    local_global_pattern=5,           # 5 local : 1 global
    rope_theta=1_000_000.0,
    rope_theta_local=10_000.0,
    tie_embeddings=True,
    swm=SWMConfig(block_size=128, impl="paper"),
    fsdp=False,
    remat="block",
)

SMOKE = ModelConfig(
    name="gemma3-smoke",
    family="lm",
    n_layers=6,                       # one full 5:1 period
    d_model=96,
    n_heads=4,
    n_kv_heads=2,
    head_dim=24,
    d_ff=192,
    vocab=256,
    sliding_window=8,
    local_global_pattern=5,
    rope_theta=1_000_000.0,
    rope_theta_local=10_000.0,
    swm=SWMConfig(block_size=8, impl="paper"),
    remat="none",
    param_dtype="float32",
    compute_dtype="float32",
)
