"""--arch registry: id -> (CONFIG, SMOKE)."""

from __future__ import annotations

import importlib
from typing import Dict, Tuple

from repro.configs.base import ModelConfig

__all__ = ["ARCHS", "get_config", "get_smoke"]

ARCHS: Dict[str, str] = {
    "gemma3-27b": "repro.configs.gemma3_27b",
    "qwen3-0.6b": "repro.configs.qwen3_0_6b",
    "deepseek-7b": "repro.configs.deepseek_7b",
    "internlm2-20b": "repro.configs.internlm2_20b",
    "arctic-480b": "repro.configs.arctic_480b",
    "qwen3-moe-235b-a22b": "repro.configs.qwen3_moe_235b",
    "paligemma-3b": "repro.configs.paligemma_3b",
    "rwkv6-7b": "repro.configs.rwkv6_7b",
    "seamless-m4t-medium": "repro.configs.seamless_m4t_medium",
    "jamba-v0.1-52b": "repro.configs.jamba_52b",
}

# archs with a sub-quadratic / O(1)-state path that run the long_500k cell
LONG_CONTEXT_ARCHS = {"rwkv6-7b", "jamba-v0.1-52b", "gemma3-27b"}


def _mod(arch: str):
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; choices: {sorted(ARCHS)}")
    return importlib.import_module(ARCHS[arch])


def get_config(arch: str) -> ModelConfig:
    return _mod(arch).CONFIG


def get_smoke(arch: str) -> ModelConfig:
    return _mod(arch).SMOKE
