"""rwkv6-7b [ssm]: 32L d_model=4096 (attn-free) d_ff=14336 vocab=65536 —
Finch, data-dependent decay. [arXiv:2404.05892; hf]

O(1) recurrent state → runs the long_500k decode cell natively."""

from repro.configs.base import LayerGroup, LayerSpec, ModelConfig, SWMConfig

_RWKV_GROUPS = (
    LayerGroup(layers=(LayerSpec(mixer="rwkv", ffn="dense"),), repeat=32),
)

CONFIG = ModelConfig(
    name="rwkv6-7b",
    family="lm",
    n_layers=32,
    d_model=4096,
    n_heads=64,               # wkv heads = d_model / rwkv_head_dim
    n_kv_heads=64,
    head_dim=64,
    d_ff=14336,
    vocab=65536,
    rwkv_head_dim=64,
    rwkv_decay_lora=64,
    rwkv_mix_lora=32,
    tie_embeddings=False,
    groups=_RWKV_GROUPS,
    swm=SWMConfig(block_size=128, impl="paper"),
    remat="block",
)

SMOKE = ModelConfig(
    name="rwkv6-smoke",
    family="lm",
    n_layers=3,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab=256,
    rwkv_head_dim=16,
    rwkv_decay_lora=8,
    rwkv_mix_lora=8,
    tie_embeddings=False,
    groups=(LayerGroup(layers=(LayerSpec(mixer="rwkv", ffn="dense"),),
                       repeat=3),),
    swm=SWMConfig(block_size=8, impl="paper"),
    remat="none",
    param_dtype="float32",
    compute_dtype="float32",
)
