"""qwen3-moe-235b-a22b [moe]: 94L d_model=4096 64H (GQA kv=4) expert
d_ff=1536 vocab=151936, MoE 128 experts top-8, qk_norm.
[hf:Qwen/Qwen3-235B-A22B; hf]"""

from repro.configs.base import ModelConfig, SWMConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="lm",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    head_dim=128,
    d_ff=1536,
    vocab=151936,
    qk_norm=True,
    n_experts=128,
    n_experts_per_token=8,
    d_ff_expert=1536,
    moe_every=1,                 # every layer is MoE (no dense FFN)
    capacity_factor=1.25,
    rope_theta=1_000_000.0,
    tie_embeddings=False,
    swm=SWMConfig(block_size=128, impl="paper"),
    fsdp=True,
    remat="block",
)

SMOKE = ModelConfig(
    name="qwen3-moe-smoke",
    family="lm",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=96,
    vocab=256,
    qk_norm=True,
    n_experts=8,
    n_experts_per_token=4,
    d_ff_expert=96,
    tie_embeddings=False,
    swm=SWMConfig(block_size=8, impl="paper"),
    remat="none",
    param_dtype="float32",
    compute_dtype="float32",
)
