"""paligemma-3b [vlm]: 18L d_model=2048 8H (MQA kv=1, head_dim 256)
d_ff=16384 vocab=257216 — SigLIP frontend + gemma decoder.
[arXiv:2407.07726; hf]

The SigLIP vision tower is a STUB per the assignment: ``input_specs``
supplies precomputed patch embeddings (B, 256, d_model); the decoder uses
prefix-LM masking (bidirectional over the image prefix, causal over text).
"""

from repro.configs.base import ModelConfig, SWMConfig

CONFIG = ModelConfig(
    name="paligemma-3b",
    family="vlm",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab=257216,
    n_img_tokens=256,
    rope_theta=10_000.0,
    tie_embeddings=True,
    swm=SWMConfig(block_size=128, impl="paper"),
    remat="block",
)

SMOKE = ModelConfig(
    name="paligemma-smoke",
    family="vlm",
    n_layers=3,
    d_model=64,
    n_heads=4,
    n_kv_heads=1,
    head_dim=16,
    d_ff=128,
    vocab=256,
    n_img_tokens=8,
    swm=SWMConfig(block_size=8, impl="paper"),
    remat="none",
    param_dtype="float32",
    compute_dtype="float32",
)
