"""deepseek-7b [dense]: 30L d_model=4096 32H (GQA kv=32 = MHA) d_ff=11008
vocab=102400 — llama-arch. [arXiv:2401.02954; hf]"""

from repro.configs.base import ModelConfig, SWMConfig

CONFIG = ModelConfig(
    name="deepseek-7b",
    family="lm",
    n_layers=30,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    head_dim=128,
    d_ff=11008,
    vocab=102400,
    rope_theta=10_000.0,
    tie_embeddings=False,
    swm=SWMConfig(block_size=128, impl="paper"),
    remat="block",
)

SMOKE = ModelConfig(
    name="deepseek-smoke",
    family="lm",
    n_layers=3,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    head_dim=16,
    d_ff=172,            # not divisible by 8: exercises valid_block_size
    vocab=256,
    rope_theta=10_000.0,
    tie_embeddings=False,
    swm=SWMConfig(block_size=8, impl="paper"),
    remat="none",
    param_dtype="float32",
    compute_dtype="float32",
)
