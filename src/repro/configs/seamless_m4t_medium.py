"""seamless-m4t-medium [audio]: 12L enc + 12L dec, d_model=1024 16H (kv=16)
d_ff=4096 vocab=256206 — encoder-decoder, multimodal. [arXiv:2308.11596; hf]

The speech frontend (fbank conv feature extractor) is a STUB per the
assignment: ``input_specs`` supplies precomputed frame embeddings
(B, T_enc, d_model). Encoder frames are capped at the model's 4k operating
envelope; decoder token length follows the assigned shape.
"""

from repro.configs.base import ModelConfig, SWMConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="encdec",
    n_layers=12,                 # decoder layers
    n_enc_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab=256206,
    enc_seq=4096,                # frontend envelope cap
    tie_embeddings=True,
    swm=SWMConfig(block_size=128, impl="paper"),
    remat="block",
)

SMOKE = ModelConfig(
    name="seamless-smoke",
    family="encdec",
    n_layers=2,
    n_enc_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab=256,
    enc_seq=16,
    swm=SWMConfig(block_size=8, impl="paper"),
    remat="none",
    param_dtype="float32",
    compute_dtype="float32",
)
