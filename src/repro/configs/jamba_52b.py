"""jamba-v0.1-52b [hybrid]: 32L d_model=4096 32H (GQA kv=8) d_ff=14336,
MoE 16 experts top-2 every 2 layers, Mamba:attention 7:1 interleave
(attention at layer offset 4 of each 8-layer block). [arXiv:2403.19887; hf]

Mamba layers carry O(1) state; only 4/32 layers hold KV caches → the
long_500k decode cell is feasible (DESIGN.md §Arch-applicability).
"""

from repro.configs.base import ModelConfig, SWMConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="lm",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab=65536,
    n_experts=16,
    n_experts_per_token=2,
    d_ff_expert=14336,
    moe_every=2,
    attn_every=8,
    attn_offset=4,
    mamba_expand=2,
    mamba_d_state=16,
    mamba_d_conv=4,
    capacity_factor=1.25,
    rope_theta=10_000.0,          # jamba uses no rope; retained for the bench
    tie_embeddings=False,
    swm=SWMConfig(block_size=128, impl="paper"),
    fsdp=True,
    remat="block",
)

SMOKE = ModelConfig(
    name="jamba-smoke",
    family="lm",
    n_layers=8,                   # one full mamba/attn/moe period
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab=256,
    n_experts=4,
    n_experts_per_token=2,
    d_ff_expert=128,
    moe_every=2,
    attn_every=8,
    attn_offset=4,
    mamba_d_state=8,
    swm=SWMConfig(block_size=8, impl="paper"),
    remat="none",
    param_dtype="float32",
    compute_dtype="float32",
)
