"""internlm2-20b [dense]: 48L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=92544. [arXiv:2403.17297; hf]"""

from repro.configs.base import ModelConfig, SWMConfig

CONFIG = ModelConfig(
    name="internlm2-20b",
    family="lm",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab=92544,
    rope_theta=1_000_000.0,
    tie_embeddings=False,
    swm=SWMConfig(block_size=128, impl="paper"),
    remat="block",
)

SMOKE = ModelConfig(
    name="internlm2-smoke",
    family="lm",
    n_layers=3,
    d_model=96,
    n_heads=6,
    n_kv_heads=2,
    head_dim=16,
    d_ff=256,
    vocab=256,
    rope_theta=1_000_000.0,
    tie_embeddings=False,
    swm=SWMConfig(block_size=8, impl="paper"),
    remat="none",
    param_dtype="float32",
    compute_dtype="float32",
)
