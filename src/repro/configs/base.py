"""Config dataclasses for models, SWM compression, parallelism, and shapes.

Every assigned architecture gets a ``src/repro/configs/<id>.py`` exporting
``CONFIG`` (full size, exact paper/HF numbers) and ``SMOKE`` (reduced same-
family config for CPU tests). ``repro.configs.registry`` resolves ``--arch``.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax.numpy as jnp

# ---------------------------------------------------------------------------
# SWM (the paper's technique)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SWMConfig:
    """Block-circulant compression settings (paper §3/§4).

    block_size: k. 0 or 1 disables (dense baseline).
    impl: 'paper' | 'freq' | 'dft' | 'pallas'  (see core.circulant)
    targets: which projection families are compressed. Components that are
      not plain weight GEMMs (routing, scans, embeddings) are never touched
      — see DESIGN.md §Arch-applicability.
    """

    block_size: int = 0
    impl: str = "freq"
    karatsuba: bool = False
    targets: Tuple[str, ...] = ("attn", "ffn", "expert")

    @property
    def enabled(self) -> bool:
        return self.block_size > 1

    def applies_to(self, family: str) -> bool:
        return self.enabled and family in self.targets


# ---------------------------------------------------------------------------
# Layer pattern descriptors
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One decoder layer's composition within a scan group.

    mixer: 'attn' | 'attn_local' | 'mamba' | 'rwkv'
    ffn:   'dense' | 'moe' | 'dense+moe' (arctic parallel residual) | 'none'
    """

    mixer: str = "attn"
    ffn: str = "dense"


@dataclasses.dataclass(frozen=True)
class LayerGroup:
    """``layers`` repeated ``repeat`` times via lax.scan (params stacked)."""

    layers: Tuple[LayerSpec, ...]
    repeat: int


# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "lm"          # lm | encdec | vlm
    # dims
    n_layers: int = 2
    d_model: int = 128
    n_heads: int = 2
    n_kv_heads: int = 2
    head_dim: int = 64
    d_ff: int = 256
    vocab: int = 256
    # attention
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    rope_theta_local: float = 10_000.0
    sliding_window: int = 0             # >0: width of local attention
    local_global_pattern: int = 0       # gemma3: N local per 1 global
    logit_softcap: float = 0.0
    flash_q_chunk: int = 512
    flash_kv_chunk: int = 1024
    # ffn / moe
    n_experts: int = 0
    n_experts_per_token: int = 0
    d_ff_expert: int = 0
    moe_every: int = 1                  # jamba: MoE on every Nth layer
    dense_residual_ffn: bool = False    # arctic: dense FFN in parallel w/ MoE
    capacity_factor: float = 1.25
    # mamba (hybrid)
    attn_every: int = 0                 # jamba: attention every Nth layer
    attn_offset: int = 0
    mamba_expand: int = 2
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_dt_rank: int = 0              # 0 -> d_model // 16
    # rwkv
    rwkv_head_dim: int = 64
    rwkv_decay_lora: int = 64
    rwkv_mix_lora: int = 32
    # encdec / vlm frontends (stubs provide embeddings directly)
    n_enc_layers: int = 0
    enc_seq: int = 0                    # encoder frames for encdec stubs
    n_img_tokens: int = 0               # vlm prefix length
    tie_embeddings: bool = True
    # numerics
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    norm_dtype: str = "float32"
    # compression
    swm: SWMConfig = dataclasses.field(default_factory=SWMConfig)
    # distribution
    fsdp: bool = False                  # shard params over data axis too
    low_tp: bool = False                # replicate SWM tables (no head/mlp TP)
    remat: str = "block"                # none | block | full
    scan_layers: bool = True
    optimizer: str = "adamw"            # adamw | adafactor
    # architecture pattern override (derived if None)
    groups: Optional[Tuple[LayerGroup, ...]] = None

    # ------------------------------------------------------------------
    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // max(1, self.n_heads))

    @property
    def dtype(self):
        return jnp.dtype(self.compute_dtype)

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def layer_groups(self) -> Tuple[LayerGroup, ...]:
        """Derive the scan-group structure from the pattern fields."""
        if self.groups is not None:
            return self.groups
        specs = []
        for i in range(self.n_layers):
            if self.attn_every > 0:
                mixer = "attn" if i % self.attn_every == self.attn_offset else "mamba"
            elif self.local_global_pattern > 0:
                period = self.local_global_pattern + 1
                mixer = "attn" if (i % period) == self.local_global_pattern else "attn_local"
            elif self.sliding_window > 0:
                mixer = "attn_local"        # no pattern -> all-local
            else:
                mixer = "attn"
            if self.is_moe and (i % self.moe_every == self.moe_every - 1):
                ffn = "dense+moe" if self.dense_residual_ffn else "moe"
            else:
                ffn = "dense"
            specs.append(LayerSpec(mixer=mixer, ffn=ffn))
        return _group_layers(tuple(specs))


def _group_layers(specs: Tuple[LayerSpec, ...]) -> Tuple[LayerGroup, ...]:
    """Factor the per-layer spec list into repeated groups for lax.scan.

    Finds the smallest period P such that the sequence is (prefix of) a
    repetition of its first P entries; trailing partial periods become their
    own group(s).
    """
    n = len(specs)
    for period in range(1, n + 1):
        pattern = specs[:period]
        if all(specs[i] == pattern[i % period] for i in range(n)):
            full, rem = divmod(n, period)
            groups = []
            if full:
                groups.append(LayerGroup(layers=pattern, repeat=full))
            if rem:
                groups.append(LayerGroup(layers=specs[full * period :], repeat=1))
            return tuple(groups)
    return (LayerGroup(layers=specs, repeat=1),)


# ---------------------------------------------------------------------------
# Input shapes (the assignment's 4 shapes)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str               # 'train' | 'prefill' | 'decode'


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    seed: int = 0
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1_000
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    moment_dtype: str = "float32"
    z_loss: float = 1e-4
    moe_aux_loss: float = 1e-2
    microbatch: int = 0                 # 0 = no gradient accumulation
    grad_compression: str = "none"      # none | int8_ef
    checkpoint_every: int = 200
    checkpoint_dir: str = "/tmp/repro_ckpt"
    # quantization-aware training: fake-quantize params through the clipped
    # STE every forward (0 = off). frac_bits -1 derives bits-4, matching the
    # paper's fixed-point split; biases/norm scales are exempt
    # (quant.default_exempt).
    qat_bits: int = 0
    qat_frac_bits: int = -1
