"""Frequency-domain execution plans: precompute once, launch forever.

The paper's §5 inference dataflow computes FFT(w) ONCE and keeps it resident
in BRAM; only activations stream through the FFT→∘→IFFT pipeline. This
module is the TPU analogue. A :class:`BCPlan` precomputes, per weight, at
init / checkpoint-load time:

  * the rfft'd weights ``(wr, wi)`` — padded to the chosen tile grid,
  * the tile sizes ``(pt, qt)`` and padded block counts (plumbed into the
    launch, so the plan's geometry IS the executed geometry),
  * optionally a fused bias and epilogue activation.

(The rDFT basis matrices are k-only constants served by the lru-cached
``dft_bases(k)`` at launch; plans don't duplicate them as pytree leaves.)

``plan.apply(x)`` then contains **no fft primitive and no weight-side work**
in its jaxpr — just the pad of x and one ``pallas_call``
(``jax.make_jaxpr(plan.apply)(x)`` is checked in tests). Plan *geometry*
(tile choice + padded shapes) is cached on ``(p, q, k, dtype)`` so a model
with many same-shaped layers derives it once.

``freeze_params`` walks a (specs, params) pair and attaches ``wr`` / ``wi``
next to every circulant-tagged ``w`` leaf — the serving engine calls it once
after loading a checkpoint, and ``nn.Linear`` picks the frozen path up
automatically. It also *pre-concatenates* the known fused projection groups
(attention Q/K/V; the LSTM's 8 gate tables + gate biases) into one stacked
table per group under the reserved ``"_fused"`` key — exactly the data a
:func:`build_multi_plan` ``BCMultiPlan`` would carry — so the traced
prefill/decode steps launch the fused projection without a single
``jnp.concatenate`` over weight tables in their jaxpr.

Quantized freezing (``quantize="int8"``): the frozen tables are stored int8
with ONE symmetric f32 max-abs scale per (p, q) circulant block, shared
across the K frequency bins and the re/im pair (``quant.symmetric_scales``
— the same scheme ``dist.compress`` uses on gradients), attached as a
sibling ``w_scale`` leaf. Resident table HBM drops ~4× on top of the rfft
freeze's 2×; the Pallas kernel dequantizes on the VMEM tile
(``kernel._bc_kernel``) and the pure-XLA ``dft``/``freq`` fallbacks
dequantize at trace entry, so greedy outputs are bit-identical to running
the fp32 path on host-dequantized tables. Because scales are per-block,
quantization commutes with the fused-group concatenation — scales stack
alongside the tables block for block. Tile geometry is always derived from
the fp32 ``vmem_estimate`` so quantized and fp32 plans share identical
tiles (and therefore identical serve executables/compile budget).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.circulant import concat_biases, split_outputs
from repro.core.quant import (dequantize_symmetric, quantize_symmetric,
                              symmetric_scales)
from repro.kernels.block_circulant.kernel import (choose_blocks,
                                                 choose_blocks_dw,
                                                 vmem_estimate)
from repro.kernels.block_circulant import ops as bc_ops

__all__ = [
    "BCPlan",
    "PlanGeometry",
    "build_plan",
    "build_multi_plan",
    "plan_geometry",
    "dw_geometry",
    "geometry_cache_info",
    "clear_plan_cache",
    "freeze_params",
    "count_frozen_tables",
    "frozen_table_bytes",
    "dequantize_frozen",
    "FUSED_KEY",
    "QUANTIZE_MODES",
]

# Legal ``quantize=`` values for freeze_params/build_plan (and, transitively,
# ServeEngine / launch.serve --quantize).
QUANTIZE_MODES = ("off", "int8")

# Reserved param-tree key for a pre-concatenated multi-projection frozen
# group ({"wr", "wi"[, "bias"]}). Attached by freeze_params; consumed by the
# attention QKV / LSTM gate fused paths via ``w_freq_cat``.
FUSED_KEY = "_fused"

# Default batch hint for tile choice when the runtime batch is unknown at
# plan-build time. Tile sizes (pt, qt) depend on B only when the VMEM budget
# binds; 128 matches the kernel's max bB, so plans and the per-call path
# agree everywhere the budget is slack (bitwise-identical outputs).
_B_HINT = 128


@dataclasses.dataclass(frozen=True)
class PlanGeometry:
    """Static geometry of one (p, q, k) problem: tiles + padded shapes."""

    p: int
    q: int
    k: int
    pt: int
    qt: int
    p_pad: int
    q_pad: int

    @property
    def K(self) -> int:
        return self.k // 2 + 1

    def vmem_bytes(self, bB: int, quantized: bool = False) -> int:
        """VMEM working set; ``quantized`` reports the int8-table variant.
        Tile CHOICE always uses the fp32 estimate (geometry identity)."""
        return vmem_estimate(bB, self.pt, self.qt, self.k,
                             quantized=quantized)


@functools.lru_cache(maxsize=1024)
def plan_geometry(p: int, q: int, k: int, dtype: str = "float32",
                  b_hint: int = _B_HINT) -> PlanGeometry:
    """Cached geometry, keyed on (shape, k, dtype): chosen once per layer
    shape, shared by every plan (and every step) with that signature."""
    _, pt, qt = choose_blocks(b_hint, p, q, k)
    p_pad = p + (-p) % pt
    q_pad = q + (-q) % qt
    return PlanGeometry(p=p, q=q, k=k, pt=pt, qt=qt, p_pad=p_pad, q_pad=q_pad)


@functools.lru_cache(maxsize=1024)
def dw_geometry(p: int, q: int, k: int, dtype: str = "float32",
                b_hint: int = _B_HINT) -> PlanGeometry:
    """Cached BACKWARD geometry: tiles for the transposed-geometry weight
    adjoint (``kernel.bc_dw_pallas``), keyed like :func:`plan_geometry`.

    The dw kernel's (pt, qt) tile the output block grid and its batch tile
    is the contraction axis — chosen once per (p, q, k) signature so every
    train step with the same layer shape reuses both the tile derivation
    AND the jitted dw executable (``bc_dw_pallas`` is keyed on static tile
    sizes). The batch tile itself stays runtime-chosen
    (``kernel.choose_batch_block_dw``), mirroring the forward plan path.
    """
    _, pt, qt = choose_blocks_dw(b_hint, p, q, k)
    return PlanGeometry(p=p, q=q, k=k, pt=pt, qt=qt,
                        p_pad=p + (-p) % pt, q_pad=q + (-q) % qt)


def geometry_cache_info():
    return plan_geometry.cache_info()


def dw_geometry_cache_info():
    return dw_geometry.cache_info()


def clear_plan_cache() -> None:
    plan_geometry.cache_clear()
    dw_geometry.cache_clear()


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=("wr", "wi", "bias", "scale"),
    meta_fields=("k", "p", "q", "pt", "qt", "splits", "activation",
                 "interpret"),
)
@dataclasses.dataclass(frozen=True)
class BCPlan:
    """A frozen frequency-domain execution plan for one projection (or one
    stacked multi-projection). Registered as a pytree: jit/scan/device_put
    treat (wr, wi, bias, scale) as data and the geometry as static. The rDFT
    basis matrices are NOT stored — they are k-only constants that the
    launch path materializes from the lru-cached ``dft_bases(k)``.

    Quantized plans (``build_plan(..., quantize="int8")``) store wr/wi as
    int8 and carry the per-(p, q)-block f32 ``scale``; the kernel
    dequantizes in-tile. Geometry (pt, qt, padding) is identical to the
    fp32 plan of the same (p, q, k)."""

    wr: jax.Array                      # (p_pad, q_pad, K) f32 — int8 if quant
    wi: jax.Array                      # (p_pad, q_pad, K) f32 — int8 if quant
    bias: Optional[jax.Array]          # (1, p·k) f32 or None
    k: int
    p: int                             # true (unpadded) output blocks
    q: int                             # true (unpadded) input blocks
    pt: int
    qt: int
    splits: Tuple[int, ...]            # per-projection p_i (multi-plans)
    activation: str
    interpret: bool
    scale: Optional[jax.Array] = None  # (p_pad, q_pad) f32 when quantized

    # -- derived -------------------------------------------------------
    @property
    def in_dim(self) -> int:
        return self.q * self.k

    @property
    def out_dim(self) -> int:
        return self.p * self.k

    @property
    def n_projections(self) -> int:
        return len(self.splits)

    @property
    def quantized(self) -> bool:
        return self.scale is not None

    def table_bytes(self) -> int:
        """Resident bytes of the frozen tables (+ scales when quantized)."""
        n = self.wr.nbytes + self.wi.nbytes
        if self.scale is not None:
            n += self.scale.nbytes
        return n

    def cache_key(self) -> Tuple:
        """The geometry-cache key this plan was derived from."""
        return (self.p, self.q, self.k, str(self.wr.dtype))

    def dw_tiles(self) -> Tuple[int, int]:
        """(pt, qt) tiles of the plan's weight-adjoint (dw) kernel — served
        by the lru-cached :func:`dw_geometry` over the plan's PADDED table
        shape (the frozen (wr, wi) carry the forward tile padding), so
        repeated train steps reuse the same backward tiles/executable."""
        geo = dw_geometry(int(self.wr.shape[0]), int(self.wr.shape[1]),
                          self.k)
        return (geo.pt, geo.qt)

    # -- apply ---------------------------------------------------------
    def apply(self, x: jax.Array) -> jax.Array:
        """x (..., q·k) -> (..., p·k), fused epilogue included. The traced
        computation contains no fft and no weight-side transform/pad."""
        return bc_ops.block_circulant_matmul(
            x, None, w_freq=(self.wr, self.wi), w_scale=self.scale,
            bias=self.bias, activation=self.activation, k=self.k, q=self.q,
            tiles=(self.pt, self.qt), interpret=self.interpret,
        )[..., : self.out_dim]

    __call__ = apply

    def apply_multi(self, x: jax.Array) -> Tuple[jax.Array, ...]:
        """Stacked multi-projection apply: one launch, N outputs."""
        return tuple(split_outputs(self.apply(x), self.splits, self.k))


def _pad_freq(wr, wi, geo: PlanGeometry):
    pad = ((0, geo.p_pad - wr.shape[0]), (0, geo.q_pad - wr.shape[1]), (0, 0))
    if any(a or b for a, b in pad):
        wr = jnp.pad(wr, pad)
        wi = jnp.pad(wi, pad)
    return wr, wi


def _check_quantize(quantize: str) -> None:
    if quantize not in QUANTIZE_MODES:
        raise ValueError(
            f"quantize={quantize!r}; expected one of {QUANTIZE_MODES}")


def build_plan(
    w: jax.Array,
    *,
    bias: Optional[jax.Array] = None,
    activation: str = "none",
    interpret: Optional[bool] = None,
    b_hint: int = _B_HINT,
    quantize: str = "off",
) -> BCPlan:
    """Precompute a plan from a time-domain block table w (p, q, k).

    Runs rfft(w), tile choice, and padding ONCE — call at init or after
    checkpoint load, never inside the step function. ``quantize="int8"``
    additionally quantizes the padded tables (padding blocks are all-zero,
    so they land on the scale floor and still contribute exact zeros).
    """
    _check_quantize(quantize)
    if interpret is None:
        interpret = not bc_ops._on_tpu()
    p, q, k = w.shape
    geo = plan_geometry(p, q, k, "float32", b_hint)
    wr, wi = bc_ops.freq_weights(w)
    wr, wi = _pad_freq(wr, wi, geo)
    scale = None
    if quantize == "int8":
        scale = symmetric_scales(wr, wi)
        wr = quantize_symmetric(wr, scale)
        wi = quantize_symmetric(wi, scale)
    b2d = bc_ops._as_bias2d(bias)
    return BCPlan(
        wr=wr, wi=wi, bias=b2d,
        k=k, p=p, q=q, pt=geo.pt, qt=geo.qt, splits=(p,),
        activation=activation, interpret=bool(interpret), scale=scale,
    )


def build_multi_plan(
    ws: Sequence[jax.Array],
    *,
    biases: Optional[Sequence[Optional[jax.Array]]] = None,
    activation: str = "none",
    interpret: Optional[bool] = None,
    b_hint: int = _B_HINT,
    quantize: str = "off",
) -> BCPlan:
    """Stack N same-(q, k) projections along p into ONE plan / ONE launch.

    The C-LSTM gate fusion at plan level: 4 gate matrices (or attention
    Q/K/V) that read the same input become a single (Σp_i, q, k) table.
    ``apply_multi`` splits the fused output back per projection.
    (``quantize`` commutes with the stacking — scales are per-block.)
    """
    if interpret is None:
        interpret = not bc_ops._on_tpu()
    q, k = ws[0].shape[1], ws[0].shape[2]
    for w in ws:
        if w.shape[1:] != (q, k):
            raise ValueError(
                f"multi-plan tables must share (q, k); got "
                f"{[tuple(w.shape) for w in ws]}"
            )
    splits = tuple(int(w.shape[0]) for w in ws)
    p = sum(splits)
    w_cat = jnp.concatenate(list(ws), axis=0)
    bias_cat = concat_biases(splits, biases, k)
    plan = build_plan(w_cat, bias=bias_cat, activation=activation,
                      interpret=interpret, b_hint=b_hint, quantize=quantize)
    return dataclasses.replace(plan, splits=splits)


# ---------------------------------------------------------------------------
# Whole-param-tree freezing (serving)
# ---------------------------------------------------------------------------


def _frozen_pair(d) -> bool:
    return isinstance(d, dict) and "wr" in d and "wi" in d


def _attach_fused(out: Dict[str, Any]) -> bool:
    """Attach a pre-concatenated ``FUSED_KEY`` entry when ``out`` is one of
    the known fused projection groups. Concatenation runs EAGERLY here (at
    freeze time), so the traced fused launch reads one resident table —
    no per-trace ``jnp.concatenate`` over weights. Returns True if added.

    Groups recognized:
      * attention Q/K/V — sibling dicts ``q``/``k``/``v`` of frozen tables
        sharing (q, K): stack along the output-block (p) axis;
      * LSTM gates — ``W{g}x``/``W{g}r`` for g in i/f/c/o: each gate's x-
        and recurrent-side tables concatenate along q, the four gates stack
        along p, and the gate biases ``b{g}`` pre-concatenate alongside.

    The per-projection ``wr``/``wi`` entries are KEPT alongside the fused
    copy: cross-attention layers share the q/k/v param structure but
    cannot take the fused launch (their K/V read a different input), and
    freeze-time detection cannot tell self- from cross-attention. The
    extra footprint is the rfft tables of the fused projections only —
    small next to the KV cache, and the time-domain ``w`` is still
    dropped.

    Quantized members fuse too: per-(p, q)-block scales concatenate
    alongside the tables (p axis for the projection/gate stack, q axis for
    the LSTM x/r halves) — quantization commutes with the fusion exactly
    because scales never cross a block boundary.
    """
    if FUSED_KEY in out:
        return False

    def _cat_scales(scales, cat):
        """Fused w_scale from the members' scales: all-or-nothing."""
        if all(s is not None for s in scales):
            return cat(scales)
        if any(s is not None for s in scales):
            raise ValueError(
                "fused projection group mixes quantized and fp32 frozen "
                "tables; freeze with a single quantize mode")
        return None

    qkv = [out.get(n) for n in ("q", "k", "v")]
    if all(_frozen_pair(d) for d in qkv):
        wrs = [d["wr"] for d in qkv]
        shapes = {w.shape[:-3] + w.shape[-2:] for w in wrs}
        if all(w.ndim >= 3 for w in wrs) and len(shapes) == 1:
            fused = {
                "wr": jnp.concatenate(wrs, axis=-3),
                "wi": jnp.concatenate([d["wi"] for d in qkv], axis=-3),
            }
            sc = _cat_scales([d.get("w_scale") for d in qkv],
                             lambda ss: jnp.concatenate(ss, axis=-2))
            if sc is not None:
                fused["w_scale"] = sc
            out[FUSED_KEY] = fused
            return True
        return False
    gates = []
    for g in ("i", "f", "c", "o"):
        px, pr, b = out.get(f"W{g}x"), out.get(f"W{g}r"), out.get(f"b{g}")
        if not (_frozen_pair(px) and _frozen_pair(pr) and b is not None):
            return False
        gates.append((px, pr, b))
    x_shapes = {px["wr"].shape for px, _, _ in gates}
    r_shapes = {pr["wr"].shape for _, pr, _ in gates}
    if len(x_shapes) != 1 or len(r_shapes) != 1:
        return False
    xs, rs = x_shapes.pop(), r_shapes.pop()
    # same output blocks and same K on both sides (same k by construction:
    # the x/r tables of one gate share out_dim, and equal K + equal out_dim
    # pins k); q may differ (d_in vs d_proj)
    if len(xs) != 3 or len(rs) != 3 or xs[0] != rs[0] or xs[-1] != rs[-1]:
        return False
    fused = {
        "wr": jnp.concatenate(
            [jnp.concatenate([px["wr"], pr["wr"]], axis=-2)
             for px, pr, _ in gates], axis=-3),
        "wi": jnp.concatenate(
            [jnp.concatenate([px["wi"], pr["wi"]], axis=-2)
             for px, pr, _ in gates], axis=-3),
        "bias": jnp.concatenate(
            [b.reshape(-1).astype(jnp.float32) for _, _, b in gates]),
    }
    sc = _cat_scales(
        [s for px, pr, _ in gates
         for s in (px.get("w_scale"), pr.get("w_scale"))],
        lambda ss: jnp.concatenate(
            [jnp.concatenate(ss[2 * i: 2 * i + 2], axis=-1)
             for i in range(len(ss) // 2)], axis=-2))
    if sc is not None:
        fused["w_scale"] = sc
    out[FUSED_KEY] = fused
    return True


def freeze_params(specs, params, quantize: str = "off") -> Dict[str, Any]:
    """Replace every circulant table with its frozen frequency weights.

    Walks the ParamSpec tree (which tags circulant leaves — see
    ``nn.Linear.specs``) in lockstep with the param pytree; every tagged
    ``w`` is REPLACED by entries ``wr`` / ``wi`` = rfft(w) along the last
    axis (leading stack/expert dims preserved, so scan-over-layers slices
    them consistently). Dropping the time-domain table matters: keeping it
    would roughly double the circulant weight footprint in device memory
    for the process lifetime of a serving job. ``nn.Linear`` (and the
    fused lstm/attention/ffn paths) detect the frozen entries and take the
    no-fft path without touching ``w``.

    ``quantize="int8"`` stores the frozen tables int8 with a sibling
    ``w_scale`` leaf — one symmetric f32 max-abs scale per (p, q) block,
    shared over the K bins and the re/im pair (``quant.symmetric_scales``).
    Resident table bytes drop ~4×; dequantization happens in-kernel (Pallas
    path) or at trace entry (XLA ``dft``/``freq`` fallback), both bit-
    identical to the fp32 path on dequantized tables. An already-frozen
    fp32 tree re-frozen with ``"int8"`` quantizes in place (no new rfft);
    an already-quantized tree is passed through unchanged under either
    mode (``"off"`` never dequantizes — see :func:`dequantize_frozen`).

    Fused groups (attention Q/K/V, LSTM gates) additionally get a
    pre-concatenated stacked table under :data:`FUSED_KEY` — built here,
    eagerly, from the just-frozen per-projection tables (zero extra rfft
    work), so the fused launch needs no weight concatenation in its trace.
    Idempotent; non-circulant subtrees are returned as-is (same objects,
    no copy).
    """
    from repro.nn.module import ParamSpec

    _check_quantize(quantize)
    if isinstance(specs, ParamSpec) or not isinstance(specs, dict) \
            or not isinstance(params, dict):
        return params
    out = {}
    dropped = set()
    changed = False
    for key, sub_spec in specs.items():
        sub_param = params[key] if key in params else None
        if (isinstance(sub_spec, ParamSpec) and key == "w"
                and "circulant" in getattr(sub_spec, "tags", ())):
            if "wr" in params and "wi" in params:   # already frozen
                wr, wi = params["wr"], params["wi"]
                if (quantize == "int8" and "w_scale" not in params
                        and jnp.issubdtype(wr.dtype, jnp.floating)):
                    # fp32-frozen checkpoint re-frozen quantized: no rfft,
                    # just the int8 encode
                    sc = symmetric_scales(wr, wi)
                    out["w_scale"] = sc
                    wr = quantize_symmetric(wr, sc)
                    wi = quantize_symmetric(wi, sc)
                    changed = True
                out["wr"], out["wi"] = wr, wi
            else:
                wr, wi = bc_ops.freq_weights(sub_param)
                if "conv_taps" in sub_spec.tags:
                    # conv tap tables (r², p, q, k) freeze straight into the
                    # (p, r²·q, K) im2col block-table layout the kernel
                    # consumes, so the traced conv step does no weight-side
                    # transpose/reshape (freeze-once, like the fused groups)
                    t, p, q, K = wr.shape
                    wr = wr.transpose(1, 0, 2, 3).reshape(p, t * q, K)
                    wi = wi.transpose(1, 0, 2, 3).reshape(p, t * q, K)
                if quantize == "int8":
                    # quantize AFTER any layout reshape so the (p, q) scale
                    # grid matches the stored table's block grid
                    sc = symmetric_scales(wr, wi)
                    out["w_scale"] = sc
                    wr = quantize_symmetric(wr, sc)
                    wi = quantize_symmetric(wi, sc)
                out["wr"], out["wi"] = wr, wi
                changed = True
            if "w" in params:
                dropped.add("w")
                changed = True
        else:
            new = freeze_params(sub_spec, sub_param, quantize)
            out[key] = new
            changed = changed or (new is not sub_param)
    # preserve params-only keys (already-frozen trees stay intact)
    for key in params:
        if key in out or key in dropped:
            continue
        if (key == FUSED_KEY and quantize == "int8"
                and isinstance(params[key], dict)
                and "w_scale" not in params[key]):
            # stale fp32 fused group over members just re-quantized above:
            # drop it so _attach_fused rebuilds it from the int8 tables
            changed = True
            continue
        out[key] = params[key]
    changed = _attach_fused(out) or changed
    return out if changed else params


def frozen_table_bytes(params) -> int:
    """Resident bytes of every frozen table in a param tree: all ``wr`` /
    ``wi`` pairs (fused copies included — they are resident too) plus any
    ``w_scale`` leaves. The serve-path quantization acceptance compares
    this between an int8-frozen and an fp32-frozen tree (int8 lands at
    ~0.25× + the per-block scale overhead, comfortably under the 0.55×
    budget)."""
    if not isinstance(params, dict):
        return 0
    n = 0
    for key in ("wr", "wi", "w_scale"):
        if key in params and hasattr(params[key], "nbytes"):
            n += int(params[key].nbytes)
    return n + sum(frozen_table_bytes(v) for v in params.values()
                   if isinstance(v, dict))


def dequantize_frozen(params):
    """int8-frozen tree -> the equivalent fp32-frozen tree (oracle path).

    Wherever a ``(wr, wi, w_scale)`` triple appears, replace the tables
    with ``quant.dequantize_symmetric`` f32 pairs and drop the scale.
    Feeding the result to a ``quantize="off"`` engine reproduces the int8
    engine's outputs BIT-IDENTICALLY: the kernel's in-tile dequant computes
    the same floats this function does, and everything downstream is the
    same executable. Non-dict subtrees pass through untouched.
    """
    if not isinstance(params, dict):
        return params
    out = {}
    for key, val in params.items():
        if key == "w_scale" and "wr" in params:
            continue
        if key in ("wr", "wi") and "w_scale" in params:
            out[key] = dequantize_symmetric(val, params["w_scale"])
        else:
            out[key] = dequantize_frozen(val)
    return out


def count_frozen_tables(params) -> int:
    """Number of frozen frequency tables (``wr``/``wi`` pairs) in a param
    tree — i.e. how many rfft(w) transforms :func:`freeze_params` performed.
    The serving engine's freeze-once invariant is asserted against this
    (``ops.freq_weights_trace_count`` must grow by exactly this much at
    engine construction and not at all afterwards). ``FUSED_KEY`` entries
    are skipped: they are eager concatenations of already-frozen tables,
    not additional transforms."""
    if not isinstance(params, dict):
        return 0
    n = 1 if ("wr" in params and "wi" in params) else 0
    return n + sum(count_frozen_tables(v) for key, v in params.items()
                   if key != FUSED_KEY)
