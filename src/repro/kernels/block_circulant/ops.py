"""Public ops: differentiable block-circulant matmuls backed by the Pallas kernel.

``block_circulant_matmul(x, w)``: x (..., q·k) × blocks w (p, q, k) -> (..., p·k)

* forward  — Pallas kernel (frequency-domain fused; interpret mode on CPU),
  with an optional **fused epilogue** (bias add + activation) executed inside
  the kernel's final-q writeback, and an optional **frozen frequency-weight
  path** (``w_freq=(wr, wi)``) that skips the per-call ``rfft(w)`` entirely —
  the paper's BRAM-resident FFT(w) inference fast path. Execution plans
  (:mod:`.plan`) build on the frozen path.
* backward — closed-form circulant adjoints (no dense expansion), BOTH
  running as Pallas kernel launches:
    dL/dx  = g @ W : **reuses the forward kernel** with the conjugated /
             index-reversed frequency weights (a circulant transpose is the
             index-reversed vector ⇒ conj(ŵ); the block table transposes
             p ↔ q).
    dL/dw[i,j] = Σ_b x_j ⋆ g_i  (circular cross-correlation)
               = irfft( Σ_b conj(x̂_j) ∘ ĝ_i )
             : the **transposed-geometry kernel** ``kernel.bc_dw_pallas`` —
             the same per-bin complex GEMM with the train batch promoted to
             the contraction axis, accumulated in VMEM scratch. The per-bin
             (B, P, f) × (B, Q, f) outer products the einsum fallback
             materialized never touch HBM; ``plan.dw_geometry`` caches the
             backward tiles per (p, q, k) so train steps reuse executables.
             (``_dw_freq_cotangents`` below is kept as the pure-XLA einsum
             ORACLE the gradcheck suite pins the kernel against.)
  Both adjoints are O(n log n) — the paper's training-phase complexity claim
  now holds end to end, in the frozen-frequency `_freq_bwd` path too.
  Residuals carry the forward's (wr, wi) so the backward never re-rffts the
  weight table. Under ``jax.grad`` the forward runs with the activation
  *unfused* (the pre-activation is the residual), keeping
  recompute-under-grad semantics; the primal-only (inference) call is fully
  fused.

``block_circulant_matmul_multi`` stacks several projections that share one
input (LSTM gates, attention QKV) along the p axis and runs them as ONE
kernel launch (C-LSTM's fused gate dataflow).
"""

from __future__ import annotations

import functools
import os
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.circulant import (concat_biases, dft_bases,
                                  dft_bases_adjoint, split_outputs)
from repro.kernels.block_circulant.kernel import (apply_activation,
                                                  bc_dw_pallas,
                                                  bc_matmul_pallas,
                                                  choose_batch_block,
                                                  choose_batch_block_dw,
                                                  choose_blocks)

__all__ = [
    "block_circulant_matmul",
    "block_circulant_matmul_multi",
    "freq_weights",
    "freq_weights_trace_count",
    "outer_dot_shapes",
    "count_pallas_launches",
]


# ---------------------------------------------------------------------------
# Structural jaxpr probes (shared by the test suite and kernel_bench): the
# "no dense (P, Q) einsum in the train step" acceptance checks inspect
# traced programs, not numerics. Both are thin wrappers over the recursive
# walker in ``repro.analysis.walker`` — one traversal, shared with the
# contract auditor, that also descends while/cond/dict-valued sub-jaxprs
# the old per-probe loops missed.
# ---------------------------------------------------------------------------


def outer_dot_shapes(jaxpr) -> List[Tuple[int, ...]]:
    """Output shapes of every ``dot_general`` OUTSIDE pallas_call kernels.

    Recurses through pjit/scan/while/cond/custom-vjp sub-jaxprs but never
    into a ``pallas_call`` body — contractions inside the kernel are tiled
    VMEM work, not the dense XLA fallback. The kernel-backed-adjoint
    regressions assert that none of the returned shapes spans a circulant
    layer's (P, Q) block grid (the signature of the einsum weight adjoint).
    """
    from repro.analysis.walker import iter_eqns

    return [tuple(v.aval.shape)
            for eqn in iter_eqns(jaxpr)
            if eqn.primitive.name == "dot_general"
            for v in eqn.outvars]


def count_pallas_launches(jaxpr) -> int:
    """Number of ``pallas_call`` eqns anywhere in the (closed) jaxpr — one
    kernel launch per execution of the enclosing region."""
    from repro.analysis.walker import iter_eqns

    return sum(1 for eqn in iter_eqns(jaxpr)
               if eqn.primitive.name == "pallas_call")


def _force_interpret() -> bool:
    """``REPRO_INTERPRET=1`` forces Pallas interpret mode even on TPU (the
    CI matrix toggles this); any other value defers to platform detection."""
    return os.environ.get("REPRO_INTERPRET", "") == "1"


def _on_tpu() -> bool:
    if _force_interpret():
        return False
    try:
        return jax.devices()[0].platform == "tpu"
    except (RuntimeError, IndexError):  # pragma: no cover - no backend
        return False


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


# Counts every rfft(w) issued (eagerly or into a trace). Serving freezes
# weights exactly once, so the regression tests assert this counter does not
# move across an entire engine lifetime after freeze_params.
_FREQ_WEIGHT_TRACES = 0


def freq_weights_trace_count() -> int:
    """Process-wide count of ``freq_weights`` invocations (rfft(w) work)."""
    return _FREQ_WEIGHT_TRACES


def freq_weights(w: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Time-domain block table (..., p, q, k) -> (wr, wi) real/imag rfft.

    The frozen-inference precompute (paper: FFT(w) stored in BRAM once).
    Leading stack/expert dims pass through untouched.
    """
    global _FREQ_WEIGHT_TRACES
    _FREQ_WEIGHT_TRACES += 1
    wf = jnp.fft.rfft(w.astype(jnp.float32), axis=-1)
    return jnp.real(wf), jnp.imag(wf)


@functools.lru_cache(maxsize=512)
def _tiles(B: int, p: int, q: int, k: int) -> Tuple[int, int, int]:
    return choose_blocks(B, p, q, k)


def _run_kernel(x2d: jax.Array, wr: jax.Array, wi: jax.Array,
                bias2d: Optional[jax.Array], k: int, activation: str,
                interpret: bool,
                tiles: Optional[Tuple[int, int]] = None,
                w_scale: Optional[jax.Array] = None) -> jax.Array:
    """Pad (rows + block dims) and launch. wr/wi (P, Q, K) may already be
    tile-aligned (plan path) — padding is then a no-op. Returns the FULL
    (B, P_pad·k) output; the caller slices. ``tiles=(pt, qt)`` uses the
    plan's frozen block tiles (only the batch tile stays runtime-chosen).
    ``w_scale`` (P, Q) f32 marks wr/wi as int8 tables dequantized in-kernel
    (padding blocks carry the scale floor and all-zero int8 payloads, so
    they still contribute exact zeros)."""
    P, Q, _ = wr.shape
    B = x2d.shape[0]
    if tiles is not None:
        pt, qt = tiles
        bB = choose_batch_block(B, pt, qt, k)
    else:
        bB, pt, qt = _tiles(B, P, Q, k)
    xp = _pad_to(x2d, 0, bB)
    xp = _pad_to(xp, 1, Q * k)           # x cols up to the weight's Q blocks
    wr = _pad_to(_pad_to(wr, 0, pt), 1, qt)
    wi = _pad_to(_pad_to(wi, 0, pt), 1, qt)
    if w_scale is not None:
        w_scale = _pad_to(_pad_to(w_scale, 0, pt), 1, qt)
    if wr.shape[1] != Q:                 # q padded -> pad x block dim to match
        xp = _pad_to(
            xp.reshape(xp.shape[0], Q, k), 1, qt
        ).reshape(xp.shape[0], -1)
    if bias2d is not None:
        bias2d = _pad_to(bias2d, 1, pt * k)
    c, s, ci, si = dft_bases(k, jnp.float32)
    y = bc_matmul_pallas(
        xp, wr, wi, c, s, ci, si, bias2d, w_scale,
        k=k, block_b=bB, block_p=pt, block_q=qt, interpret=interpret,
        activation=activation,
    )
    return y[:B]


def _transpose_freq(wr: jax.Array, wi: jax.Array):
    """Frequency weights of the transposed block-circulant matrix.

    (W^T)_{ji} = W_ij^T and a circulant transpose is the index-reversed
    vector, i.e. conj(ŵ) in the frequency domain: swap (p, q), negate wi.
    """
    return jnp.transpose(wr, (1, 0, 2)), -jnp.transpose(wi, (1, 0, 2))


def _dx_via_kernel(gz: jax.Array, wr: jax.Array, wi: jax.Array, k: int,
                   q_out: int, interpret: bool) -> jax.Array:
    """dx = gz @ W through the kernel with conj/index-reversed freq weights."""
    P = wr.shape[0]
    gzp = _pad_to(gz, 1, P * k)
    wrT, wiT = _transpose_freq(wr, wi)
    dx = _run_kernel(gzp, wrT, wiT, None, k, "none", interpret)
    return dx[:, : q_out * k]


def _dw_via_kernel(x2d: jax.Array, gz: jax.Array, P: int, Q: int, k: int,
                   interpret: bool, freq_out: bool = False):
    """Weight adjoint through the transposed-geometry Pallas kernel.

    x2d (B, ≤Q·k) and gz (B, ≤P·k) zero-pad up to the (P, Q) block grid and
    its backward tile multiples (``plan.dw_geometry``, cached per shape);
    padded rows/cols contribute exact zeros, so slicing back is lossless.
    Returns time-domain ``dw (P, Q, k)`` f32 when ``freq_out=False`` (the
    `_bwd` path) or the frequency-cotangent pair ``(dwr, dwi)`` each
    (P, Q, K) f32 when ``freq_out=True`` (the `_freq_bwd` path).
    """
    # function-level import: plan.py imports this module at load time
    from repro.kernels.block_circulant.plan import dw_geometry

    geo = dw_geometry(P, Q, k)
    bB = choose_batch_block_dw(x2d.shape[0], geo.pt, geo.qt, k)
    f32 = jnp.float32
    x = _pad_to(x2d.astype(f32), 0, bB)
    g = _pad_to(gz.astype(f32), 0, bB)
    x = jnp.pad(x, ((0, 0), (0, geo.q_pad * k - x.shape[1])))
    g = jnp.pad(g, ((0, 0), (0, geo.p_pad * k - g.shape[1])))
    C, S, CiT, SiT, CT, ST = dft_bases_adjoint(k, f32)
    out = bc_dw_pallas(x, g, C, S, CiT, SiT, CT, ST, k=k, block_b=bB,
                       block_p=geo.pt, block_q=geo.qt, freq_out=freq_out,
                       interpret=interpret)
    if freq_out:
        dwr, dwi = out
        return dwr[:P, :Q], dwi[:P, :Q]
    return out[:P, : Q * k].reshape(P, Q, k)


def _dw_freq_cotangents(x2d, gz, P, Q, k):
    """(dwr, dwi) frequency cotangents of the per-bin complex GEMM — the
    pure-XLA einsum ORACLE for :func:`_dw_via_kernel` (test/gradcheck use
    only; the hot adjoints run the transposed-geometry kernel).

    x2d (B, ≤Q·k) and gz (B, ≤P·k) are zero-padded up to the full (P, Q)
    block grid; padded rows/cols contribute exact zeros.
    """
    C, S, Ci, Si = dft_bases(k, jnp.float32)
    f32 = jnp.float32
    xb = _pad_to(x2d.astype(f32), 1, Q * k).reshape(-1, Q, k)
    xr = xb @ C
    xi = xb @ S
    gb = _pad_to(gz.astype(f32), 1, P * k).reshape(-1, P, k)
    # adjoint of the inverse rDFT (y = yr@Ci + yi@Si)
    gyr = gb @ Ci.T
    gyi = gb @ Si.T
    dwr = jnp.einsum("bpf,bqf->pqf", gyr, xr) + jnp.einsum(
        "bpf,bqf->pqf", gyi, xi)
    dwi = -jnp.einsum("bpf,bqf->pqf", gyr, xi) + jnp.einsum(
        "bpf,bqf->pqf", gyi, xr)
    return dwr, dwi


def _act_bwd(activation: str, z: jax.Array, g: jax.Array) -> jax.Array:
    """gz = g · act'(z), via jax.vjp so every epilogue stays exact."""
    if activation == "none":
        return g
    _, vjp = jax.vjp(lambda t: apply_activation(t, activation), z)
    return vjp(g.astype(z.dtype))[0]


# ---------------------------------------------------------------------------
# Time-domain-parameter op (training path): differentiable in (x, w, bias)
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _bc_matmul2d(interpret: bool, activation: str, x2d: jax.Array,
                 w: jax.Array, bias2d: Optional[jax.Array]) -> jax.Array:
    p, q, k = w.shape
    wr, wi = freq_weights(w)
    y = _run_kernel(x2d, wr, wi, bias2d, k, activation, interpret)
    return y[:, : p * k]


def _fwd(interpret, activation, x2d, w, bias2d):
    p, q, k = w.shape
    wr, wi = freq_weights(w)
    # recompute-under-grad: pre-activation z is the residual; the epilogue
    # activation runs unfused so its input is available to the VJP. The
    # forward's (wr, wi) ride in the residuals so the backward never issues
    # a second rfft of the weight table.
    z = _run_kernel(x2d, wr, wi, bias2d, k, "none", interpret)[:, : p * k]
    return (apply_activation(z, activation).astype(x2d.dtype),
            (x2d, w, bias2d, z, wr, wi))


def _bwd(interpret, activation, res, g):
    x2d, w, bias2d, z, wr, wi = res
    p, q, k = w.shape
    gz = _act_bwd(activation, z, g)
    dx = _dx_via_kernel(gz, wr, wi, k, q, interpret).astype(x2d.dtype)
    # transposed-geometry kernel: dw folded back to the time domain inside
    # the launch (dw = dwr@Cᵀ + dwi@Sᵀ in the final-batch epilogue)
    dw = _dw_via_kernel(x2d, gz, p, q, k, interpret).astype(w.dtype)
    db = None
    if bias2d is not None:
        db = gz.sum(0, keepdims=True).astype(bias2d.dtype)
    return dx, dw, db


_bc_matmul2d.defvjp(_fwd, _bwd)


# ---------------------------------------------------------------------------
# Frozen frequency-weight op (inference / plan path): differentiable in
# (x, wr, wi, bias) — no fft primitive anywhere in its jaxpr
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2, 3, 4))
def _bc_freq2d(interpret: bool, activation: str, k: int, p: int,
               tiles: Optional[Tuple[int, int]],
               x2d: jax.Array, wr: jax.Array, wi: jax.Array,
               bias2d: Optional[jax.Array]) -> jax.Array:
    y = _run_kernel(x2d, wr, wi, bias2d, k, activation, interpret, tiles)
    return y[:, : p * k]


def _freq_fwd(interpret, activation, k, p, tiles, x2d, wr, wi, bias2d):
    z = _run_kernel(x2d, wr, wi, bias2d, k, "none", interpret,
                    tiles)[:, : p * k]
    y = apply_activation(z, activation).astype(x2d.dtype)
    return y, (x2d, wr, wi, bias2d, z)


def _freq_bwd(interpret, activation, k, p, tiles, res, g):
    x2d, wr, wi, bias2d, z = res
    P, Q, _ = wr.shape
    q = x2d.shape[1] // k
    gz = _act_bwd(activation, z, g)
    dx = _dx_via_kernel(gz, wr, wi, k, q, interpret).astype(x2d.dtype)
    dwr, dwi = _dw_via_kernel(x2d, gz, P, Q, k, interpret, freq_out=True)
    db = None
    if bias2d is not None:
        # gz spans the padded P·k columns; the bias only the true p·k
        db = gz[:, : bias2d.shape[1]].sum(0, keepdims=True).astype(
            bias2d.dtype)
    return dx, dwr.astype(wr.dtype), dwi.astype(wi.dtype), db


_bc_freq2d.defvjp(_freq_fwd, _freq_bwd)


def _bc_freq_quant2d(interpret: bool, activation: str, k: int, p: int,
                     tiles: Optional[Tuple[int, int]],
                     x2d: jax.Array, wr: jax.Array, wi: jax.Array,
                     w_scale: jax.Array,
                     bias2d: Optional[jax.Array]) -> jax.Array:
    """Primal-only int8 frozen path: wr/wi int8 + per-block f32 scales,
    dequantized inside the kernel. Serving is inference-only here — QAT
    trains through ``quant.fake_quant_symmetric`` on fp32 tables instead,
    so this path deliberately carries no VJP (grad through int8 storage
    would be a silent zero)."""
    y = _run_kernel(x2d, wr, wi, bias2d, k, activation, interpret, tiles,
                    w_scale=w_scale)
    return y[:, : p * k]


# ---------------------------------------------------------------------------
# Public API
# ---------------------------------------------------------------------------


def _as_bias2d(bias: Optional[jax.Array]) -> Optional[jax.Array]:
    if bias is None:
        return None
    return bias.reshape(1, -1).astype(jnp.float32)


def block_circulant_matmul(
    x: jax.Array,
    w: Optional[jax.Array],
    *,
    bias: Optional[jax.Array] = None,
    activation: str = "none",
    w_freq: Optional[Tuple[jax.Array, jax.Array]] = None,
    w_scale: Optional[jax.Array] = None,
    k: Optional[int] = None,
    q: Optional[int] = None,
    tiles: Optional[Tuple[int, int]] = None,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Differentiable block-circulant matmul; arbitrary leading batch dims.

    ``bias`` (p·k,) and ``activation`` fuse into the kernel epilogue.
    ``w_freq=(wr, wi)`` — precomputed real/imag rfft(w), shape (p, q, K) —
    selects the frozen frequency path (no fft in the traced step); pass
    ``k`` alongside when w is None (K alone is ambiguous for odd k), and
    the true ``q`` plus the frozen ``tiles=(pt, qt)`` when wr/wi are
    tile-padded along the block axes (plans). ``w_scale`` (p, q) f32 marks
    the frozen tables as int8 with per-block symmetric scales, dequantized
    inside the kernel (inference-only: no VJP on the quantized path).
    """
    if interpret is None:
        interpret = not _on_tpu()
    if w_scale is not None and w_freq is None:
        raise ValueError("w_scale only applies to frozen w_freq tables")
    if w_freq is not None:
        wr, wi = w_freq
        p = wr.shape[0]
        if k is None:
            k = 2 * (wr.shape[-1] - 1) if w is None else w.shape[-1]
        if q is None:
            q = wr.shape[1]
    else:
        p, q, k = w.shape
    if x.shape[-1] != q * k:
        # _run_kernel pads x up to padded weights; a caller-side width
        # mismatch against the TRUE q is a miswiring, never padding.
        raise ValueError(
            f"x feature dim {x.shape[-1]} is incompatible with block "
            f"tables (q={q}, k={k}): expected exactly q*k={q * k}"
        )
    lead = x.shape[:-1]
    x2d = x.reshape(-1, x.shape[-1])
    b2d = _as_bias2d(bias)
    if w_freq is not None and w_scale is not None:
        y = _bc_freq_quant2d(bool(interpret), activation, int(k), int(p),
                             tiles, x2d, wr, wi, w_scale, b2d)
    elif w_freq is not None:
        y = _bc_freq2d(bool(interpret), activation, int(k), int(p),
                       tiles, x2d, wr, wi, b2d)
    else:
        y = _bc_matmul2d(bool(interpret), activation, x2d, w, b2d)
    return y.reshape(*lead, p * k)


def block_circulant_matmul_multi(
    x: jax.Array,
    ws: Optional[Sequence[jax.Array]],
    *,
    biases: Optional[Sequence[Optional[jax.Array]]] = None,
    activation: str = "none",
    w_freqs: Optional[Sequence[Tuple[jax.Array, jax.Array]]] = None,
    w_freq_cat: Optional[Tuple[jax.Array, jax.Array]] = None,
    w_scale_cat: Optional[jax.Array] = None,
    splits: Optional[Sequence[int]] = None,
    bias_cat: Optional[jax.Array] = None,
    k: Optional[int] = None,
    interpret: Optional[bool] = None,
) -> List[jax.Array]:
    """N projections sharing one input -> ONE stacked-p kernel launch.

    All tables must share (q, k); outputs are split back per projection.
    This is the C-LSTM gate fusion / attention QKV fusion primitive: instead
    of N grid pipelines each re-streaming the same x tiles, the concatenated
    (Σp_i, q, k) table amortizes the forward DFT of x and the pipeline setup
    across every projection.

    ``w_freq_cat=(wr, wi)`` + ``splits`` + ``k`` (and optionally
    ``bias_cat``) take the table already stacked — the pre-concatenated
    frozen group ``plan.freeze_params`` builds at serve-load time — so the
    traced launch contains no weight-side concatenate at all.
    ``w_scale_cat`` (Σp_i, q) f32 marks the stacked tables as int8
    (quantization commutes with p-axis stacking: scales are per-block).
    """
    if w_scale_cat is not None and w_freq_cat is None:
        raise ValueError("w_scale_cat only applies to w_freq_cat tables")
    if w_freq_cat is not None:
        if splits is None or k is None:
            raise ValueError("w_freq_cat needs explicit splits and k")
        if biases is not None:
            raise ValueError("w_freq_cat takes bias_cat, not per-proj biases")
        ps = [int(p) for p in splits]
        y = block_circulant_matmul(
            x, None, bias=bias_cat, activation=activation,
            w_freq=w_freq_cat, w_scale=w_scale_cat, k=k, interpret=interpret,
        )
        return split_outputs(y, ps, k)
    if w_freqs is not None:
        ps = [wr.shape[0] for wr, _ in w_freqs]
        if k is None:
            if ws is not None:
                k = ws[0].shape[-1]
            else:
                k = 2 * (w_freqs[0][0].shape[-1] - 1)
        w_cat = None
        wf_cat = (jnp.concatenate([wr for wr, _ in w_freqs], axis=0),
                  jnp.concatenate([wi for _, wi in w_freqs], axis=0))
    else:
        ps = [w.shape[0] for w in ws]
        k = ws[0].shape[-1]
        w_cat = jnp.concatenate(list(ws), axis=0)
        wf_cat = None
    bias_cat = concat_biases(ps, biases, k)
    y = block_circulant_matmul(
        x, w_cat, bias=bias_cat, activation=activation, w_freq=wf_cat,
        k=k, interpret=interpret,
    )
    return split_outputs(y, ps, k)
