"""Public op: differentiable block-circulant matmul backed by the Pallas kernel.

``block_circulant_matmul(x, w)``: x (..., q·k) × blocks w (p, q, k) -> (..., p·k)

* forward  — Pallas kernel (frequency-domain fused; interpret mode on CPU).
* backward — closed-form circulant adjoints (no dense expansion):
    dL/dx  = g @ W           : block-circulant matvec with the *transposed*
                               block table (W^T)_{ji} = W_ij^T; a circulant
                               transpose is the index-reversed vector, i.e.
                               conj(ŵ) in the frequency domain.
    dL/dw[i,j] = Σ_b x_j ⋆ g_i  (circular cross-correlation)
               = irfft( Σ_b conj(x̂_j) ∘ ĝ_i )
  Both adjoints are O(n log n) — the paper's training-phase complexity claim.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.circulant import dft_bases
from repro.kernels.block_circulant.kernel import bc_matmul_pallas, choose_blocks

__all__ = ["block_circulant_matmul"]


def _on_tpu() -> bool:
    try:
        return jax.devices()[0].platform == "tpu"
    except Exception:  # pragma: no cover
        return False


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _forward(x2d: jax.Array, w: jax.Array, interpret: bool) -> jax.Array:
    """x2d (B, q·k), w (p, q, k) -> (B, p·k) via the Pallas kernel."""
    p, q, k = w.shape
    B = x2d.shape[0]
    K = k // 2 + 1
    c, s, ci, si = dft_bases(k, jnp.float32)
    wf = jnp.fft.rfft(w.astype(jnp.float32), axis=-1)
    wr, wi = jnp.real(wf), jnp.imag(wf)

    bB, pt, qt = choose_blocks(B, p, q, k)
    xp = _pad_to(x2d, 0, bB)
    wr = _pad_to(_pad_to(wr, 0, pt), 1, qt)
    wi = _pad_to(_pad_to(wi, 0, pt), 1, qt)
    if wr.shape[1] != q:  # q padded -> pad x's block dim to match
        xp = _pad_to(
            xp.reshape(xp.shape[0], q, k), 1, qt
        ).reshape(xp.shape[0], -1)
    y = bc_matmul_pallas(
        xp, wr, wi, c, s, ci, si,
        k=k, block_b=bB, block_p=pt, block_q=qt, interpret=interpret,
    )
    return y[:B, : p * k]


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def _bc_matmul2d(x2d: jax.Array, w: jax.Array, interpret: bool) -> jax.Array:
    return _forward(x2d, w, interpret)


def _fwd(x2d, w, interpret):
    return _forward(x2d, w, interpret), (x2d, w)


def _bwd(interpret, res, g):
    x2d, w = res
    p, q, k = w.shape
    xh = jnp.fft.rfft(
        x2d.astype(jnp.float32).reshape(-1, q, k), axis=-1
    )                                                    # (B, q, K)
    gh = jnp.fft.rfft(
        g.astype(jnp.float32).reshape(-1, p, k), axis=-1
    )                                                    # (B, p, K)
    wh = jnp.fft.rfft(w.astype(jnp.float32), axis=-1)    # (p, q, K)
    # dx̂[b,q,f] = Σ_p ĝ[b,p,f]·conj(ŵ[p,q,f])
    dxh = jnp.einsum("bpf,pqf->bqf", gh, jnp.conj(wh))
    dx = jnp.fft.irfft(dxh, n=k, axis=-1).reshape(x2d.shape).astype(x2d.dtype)
    # dŵ[p,q,f] = Σ_b ĝ[b,p,f]·conj(x̂[b,q,f])
    dwh = jnp.einsum("bpf,bqf->pqf", gh, jnp.conj(xh))
    dw = jnp.fft.irfft(dwh, n=k, axis=-1).astype(w.dtype)
    return dx, dw


_bc_matmul2d.defvjp(_fwd, _bwd)


def block_circulant_matmul(
    x: jax.Array, w: jax.Array, *, interpret: Optional[bool] = None
) -> jax.Array:
    """Differentiable block-circulant matmul; arbitrary leading batch dims."""
    if interpret is None:
        interpret = not _on_tpu()
    p, q, k = w.shape
    lead = x.shape[:-1]
    x2d = x.reshape(-1, q * k)
    y = _bc_matmul2d(x2d, w, bool(interpret))
    return y.reshape(*lead, p * k)
