from repro.kernels.block_circulant.ops import block_circulant_matmul

__all__ = ["block_circulant_matmul"]
