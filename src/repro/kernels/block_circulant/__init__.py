from repro.kernels.block_circulant.ops import (block_circulant_matmul,
                                               block_circulant_matmul_multi,
                                               freq_weights)
from repro.kernels.block_circulant.plan import (BCPlan, build_multi_plan,
                                                build_plan, freeze_params)

__all__ = [
    "block_circulant_matmul",
    "block_circulant_matmul_multi",
    "freq_weights",
    "BCPlan",
    "build_plan",
    "build_multi_plan",
    "freeze_params",
]
