"""Pure-jnp oracle for the block-circulant matmul kernel.

The ground truth is the *dense* expansion: materialize every k×k circulant
block and do an ordinary GEMM. O(B·m·n) — test-only.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["block_circulant_matmul_ref", "blocks_to_dense"]


def blocks_to_dense(w: jax.Array) -> jax.Array:
    """w (p, q, k) -> dense (p·k, q·k); W[i·k+a, j·k+b] = w[i,j,(a-b) mod k]."""
    p, q, k = w.shape
    a = jnp.arange(k)
    idx = (a[:, None] - a[None, :]) % k
    blocks = w[:, :, idx]                                   # (p, q, k, k)
    return jnp.transpose(blocks, (0, 2, 1, 3)).reshape(p * k, q * k)


def block_circulant_matmul_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """x (..., q·k) @ BlockCirculant(w)^T -> (..., p·k), computed densely."""
    W = blocks_to_dense(w.astype(jnp.float32))
    y = x.astype(jnp.float32) @ W.T
    return y.astype(x.dtype)
