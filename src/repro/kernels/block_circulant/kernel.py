"""Pallas TPU kernel: fused block-circulant matmul in the frequency domain.

TPU adaptation of the paper's FPGA/ASIC dataflow (§5):

  FPGA/ASIC                               this kernel
  ---------                               -----------
  FFT butterfly units (depth log k)   →   rDFT as a k×K dense matmul on the
                                          MXU (K = k//2+1); at k=128 the
                                          transform is a single 128-wide
                                          systolic pass.
  BRAM-resident FFT(w) weights        →   frequency-domain weights (wr, wi)
                                          precomputed once outside the kernel
                                          and streamed HBM→VMEM tile by tile.
  ∘-multiply + accumulator            →   per-frequency-bin complex GEMM over
                                          the q (input-block) grid axis,
                                          accumulated in VMEM scratch (f32).
  DDR→BRAM ping-pong buffers          →   Pallas grid pipeline: BlockSpec
                                          double-buffers the next (x, w) tiles
                                          while the MXU consumes the current.
  IFFT + bias/activation peripheral   →   inverse rDFT matmul fused into the
                                          same kernel on the final q step,
                                          followed by the fused epilogue
                                          (bias add + activation) before the
                                          VMEM→HBM writeback.
  One pipeline per gate matrix        →   stacked-p multi-projection: several
                                          projections sharing one input (LSTM
                                          gates, attention QKV) concatenate
                                          their frequency tables along p and
                                          run as ONE kernel launch (see
                                          ops.block_circulant_matmul_multi).

Grid: ``(B/bB, p/pt, q/qt)`` with q innermost, so the frequency-domain
accumulator lives in VMEM scratch across the contraction.

Quantized tables (the paper's 12–16-bit fixed-point results, §4): frozen
(wr, wi) may instead be stored int8 with one symmetric f32 scale per
(p, q) circulant block, shared across the K frequency bins and the re/im
pair (``quant.symmetric_scales``). The int8 tiles stream HBM→VMEM at 1/4
the fp32 bandwidth and are dequantized *inside* the kernel, on the VMEM
tile, right before the per-bin complex GEMM — a single (pt, qt, 1)
broadcast multiply, the same position the MSR bit-truncation decode holds
between BRAM and the multiplier array in the FPGA pipeline. Tile geometry
is chosen with the fp32 ``vmem_estimate`` either way so quantized and
fp32 plans compile to identically-shaped executables.

The per-bin contraction ``y[b,p,f] += Σ_q x[b,q,f]·w[p,q,f]`` is expressed
as a frequency-batched ``dot_general``; Mosaic unrolls the K batch entries
into 2-D MXU dots. (The pure-XLA ``dft``/``freq`` paths in
``repro.core.circulant`` remain the production fallback for toolchains
without batched-dot support.) Correctness is validated in interpret mode
against ``ref.block_circulant_matmul_ref`` over shape/dtype sweeps.

Training adjoints (the paper's training-phase O(n log n) claim):

  * dL/dx — the FORWARD kernel re-launched with the conjugated /
    index-reversed frequency weights (a circulant transpose is the
    index-reversed vector ⇒ conj(ŵ); the block table transposes p ↔ q).
  * dL/dw — :func:`bc_dw_pallas`, the TRANSPOSED-GEOMETRY kernel below:
    ``dŵ[p,q,f] = Σ_b ĝ[b,p,f] · conj(x̂[b,q,f])`` is the same per-bin
    complex GEMM with the train batch promoted to the contraction axis.
    Grid ``(p/pt, q/qt, B/bB)`` with b innermost; the (pt, qt, K)
    frequency cotangent accumulates in VMEM scratch across the batch.
    Both operands transform inside the kernel (g through the adjoint of
    the inverse rDFT ``Ciᵀ/Siᵀ``, x through the analysis bases ``C/S``)
    and the epilogue either folds the cotangent back to the time domain
    (``dw = dwr@Cᵀ + dwi@Sᵀ`` — the `_bwd` path for trainable time-domain
    tables) or writes the (dwr, dwi) pair raw (``freq_out=True`` — the
    `_freq_bwd` path for frozen/plan frequency parameters). No dense
    (B, P, f)×(B, Q, f) outer product is ever materialized in HBM.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["bc_matmul_pallas", "bc_dw_pallas", "choose_blocks",
           "choose_batch_block", "choose_blocks_dw", "choose_batch_block_dw",
           "vmem_estimate", "vmem_estimate_dw", "ACTIVATIONS",
           "apply_activation"]

# Epilogue activations fused into the final-q writeback (the paper's
# IFFT + peripheral stage). Keys are the only legal `activation=` values.
ACTIVATIONS = ("none", "relu", "tanh", "sigmoid", "gelu")


def apply_activation(z: jax.Array, activation: str) -> jax.Array:
    """Elementwise epilogue activation. Pure jnp — legal inside the kernel."""
    if activation == "none":
        return z
    if activation == "relu":
        return jnp.maximum(z, 0.0)
    if activation == "tanh":
        return jnp.tanh(z)
    if activation == "sigmoid":
        return jax.nn.sigmoid(z)
    if activation == "gelu":
        return jax.nn.gelu(z)
    raise ValueError(f"unknown activation {activation!r}; one of {ACTIVATIONS}")


def _cdiv(a: int, b: int) -> int:
    return (a + b - 1) // b


def vmem_estimate(bB: int, pt: int, qt: int, k: int,
                  quantized: bool = False) -> int:
    """Bytes of VMEM working set for one (bB, pt, qt) tile assignment.

    x tile + (wr, wi) tiles double-buffered, f32 accumulator scratch pair,
    y tile, and the four resident DFT basis matrices. The single source of
    truth shared by :func:`choose_blocks` and benchmarks/kernel_bench.py.

    ``quantized=True`` reports the int8-table working set: the streamed
    (wr, wi) tiles shrink 4× (int8 payload) plus a per-(p, q) f32 scale
    tile, and one f32 dequantized copy of the pair is charged (produced by
    the in-kernel dequant, live only within the grid step, so not
    double-buffered). Tile *selection* (:func:`choose_blocks`) always uses
    the fp32 estimate — quantized and fp32 plans must share identical tile
    geometry so the serve paths compile to the same executables.
    """
    K = k // 2 + 1
    x_t = bB * qt * k * 4
    if quantized:
        w_t = 2 * pt * qt * K * 1 + pt * qt * 4   # int8 pair + f32 scales
        deq = 2 * pt * qt * K * 4                  # in-kernel f32 copy
    else:
        w_t = 2 * pt * qt * K * 4
        deq = 0
    acc = 2 * bB * pt * K * 4
    y_t = bB * pt * k * 4
    dft = 2 * k * K * 4 + 2 * K * k * 4
    return 2 * (x_t + w_t) + acc + y_t + dft + deq   # ×2: double buffering


def choose_batch_block(B: int, pt: int, qt: int, k: int,
                       vmem_budget: int = 8 * 1024 * 1024) -> int:
    """Batch tile for FIXED (pt, qt) block tiles — the plan path, where the
    block-axis tiles are frozen into the padded weight layout at build time
    and only the runtime batch varies."""
    bB = min(B, 128)
    while vmem_estimate(bB, pt, qt, k) > vmem_budget and bB > 8:
        bB //= 2
    return bB


def vmem_estimate_dw(bB: int, pt: int, qt: int, k: int) -> int:
    """Bytes of VMEM working set for one (pt, qt, bB) dw-kernel tile.

    x and g tiles double-buffered, the (pt, qt, K) f32 frequency-cotangent
    accumulator pair, the output tile (time-domain dw OR the (dwr, dwi)
    pair — the larger of the two is charged), and the six resident basis
    matrices. Shared by :func:`choose_blocks_dw` and kernel_bench.
    """
    K = k // 2 + 1
    x_t = bB * qt * k * 4
    g_t = bB * pt * k * 4
    acc = 2 * pt * qt * K * 4
    out = max(pt * qt * k, 2 * pt * qt * K) * 4
    dft = 6 * k * K * 4
    return 2 * (x_t + g_t) + acc + out + dft   # ×2: double buffering


def choose_batch_block_dw(B: int, pt: int, qt: int, k: int,
                          vmem_budget: int = 8 * 1024 * 1024) -> int:
    """Batch (contraction) tile for FIXED (pt, qt) dw tiles — the cached
    backward-geometry path, where the block-axis tiles are frozen by
    ``plan.dw_geometry`` and only the runtime batch varies."""
    bB = min(B, 128)
    while vmem_estimate_dw(bB, pt, qt, k) > vmem_budget and bB > 8:
        bB //= 2
    return bB


def choose_blocks_dw(B: int, p: int, q: int, k: int,
                     vmem_budget: int = 8 * 1024 * 1024
                     ) -> Tuple[int, int, int]:
    """Pick (bB, pt, qt) tiles for the transposed-geometry dw kernel.

    Same constraints as :func:`choose_blocks` with the roles permuted:
    (pt, qt) tile the OUTPUT block grid, bB tiles the batch contraction.
    """
    unit = max(1, 128 // k)
    pt = min(p, max(unit, 8 * unit))
    qt = min(q, max(unit, 8 * unit))
    bB = choose_batch_block_dw(B, pt, qt, k, vmem_budget)
    while vmem_estimate_dw(bB, pt, qt, k) > vmem_budget and pt > unit:
        pt = max(unit, pt // 2)
    while vmem_estimate_dw(bB, pt, qt, k) > vmem_budget and qt > unit:
        qt = max(unit, qt // 2)
    return bB, pt, qt


def choose_blocks(B: int, p: int, q: int, k: int,
                  vmem_budget: int = 8 * 1024 * 1024) -> Tuple[int, int, int]:
    """Pick (bB, pt, qt) tile sizes.

    Constraints:
      * lane dim of the x tile (qt·k) and y tile (pt·k) should be a multiple
        of 128 where the problem allows (MXU/VREG alignment);
      * VMEM working set (x tile + w tiles + scratch + y tile) under budget.
    """
    # lane-align the block counts for small k
    unit = max(1, 128 // k)
    qt = min(q, max(unit, 8 * unit))
    pt = min(p, max(unit, 8 * unit))
    bB = choose_batch_block(B, pt, qt, k, vmem_budget)
    while vmem_estimate(bB, pt, qt, k) > vmem_budget and pt > unit:
        pt = max(unit, pt // 2)
    while vmem_estimate(bB, pt, qt, k) > vmem_budget and qt > unit:
        qt = max(unit, qt // 2)
    return bB, pt, qt


def _bc_kernel(x_ref, wr_ref, wi_ref, c_ref, s_ref, ci_ref, si_ref,
               *refs, k: int, nq: int, out_dtype, activation: str = "none",
               has_bias: bool = False, has_scale: bool = False):
    """One (b, i, j) grid step. Shapes (per tile):
      x_ref  : (bB, qt·k)      wr/wi : (pt, qt, K) f32 — or int8 w/ has_scale
      c/s    : (k, K)          ci/si : (K, k)
      sc_ref : (pt, qt)        [only when has_scale — f32 per-block scales]
      b_ref  : (1, pt·k)       [only when has_bias]
      o_ref  : (bB, pt·k)      yr/yi : (bB, pt, K) f32 scratch

    Quantized tables (``has_scale``): wr/wi stream HBM→VMEM as int8 (4× the
    effective weight bandwidth of the fp32 path) and dequantize HERE, on the
    VMEM tile, immediately before the per-bin complex GEMM — one broadcast
    multiply by the (pt, qt, 1) scale tile, the analogue of the MSR
    bit-truncation decode sitting between BRAM and the FPGA multiplier
    array. The scale is shared across the K bins and the re/im pair, so the
    dequant is exactly ``quant.dequantize_symmetric`` and the kernel output
    is bit-identical to running the fp32 kernel on host-dequantized tables.

    The fused epilogue (bias add + activation) runs on the final q step,
    after the inverse rDFT and before the VMEM→HBM writeback — mirroring the
    paper's IFFT + bias/activation peripheral stage.
    """
    refs = list(refs)
    sc_ref = refs.pop(0) if has_scale else None
    b_ref = refs.pop(0) if has_bias else None
    o_ref, yr_acc, yi_acc = refs
    j = pl.program_id(2)
    K = k // 2 + 1
    bB = x_ref.shape[0]
    qt = x_ref.shape[1] // k
    pt = o_ref.shape[1] // k

    @pl.when(j == 0)
    def _zero():
        yr_acc[...] = jnp.zeros_like(yr_acc)
        yi_acc[...] = jnp.zeros_like(yi_acc)

    xb = x_ref[...].astype(jnp.float32).reshape(bB * qt, k)
    # forward rDFT on the MXU: (bB·qt, k) @ (k, K)
    xr = (xb @ c_ref[...]).reshape(bB, qt, K)
    xi = (xb @ s_ref[...]).reshape(bB, qt, K)
    wr = wr_ref[...]
    wi = wi_ref[...]
    if has_scale:
        # in-tile dequant: int8 -> f32 is exact, then one broadcast multiply
        sc = sc_ref[...][..., None]
        wr = wr.astype(jnp.float32) * sc
        wi = wi.astype(jnp.float32) * sc
    # per-bin complex GEMM: contract q, batch f  (bqf,pqf->bpf)
    dn = (((1,), (1,)), ((2,), (2,)))   # contracting q; batching f
    def dot(a, b):
        # a (bB, qt, K), b (pt, qt, K) -> (K, bB, pt) -> (bB, pt, K)
        r = jax.lax.dot_general(a, b, dimension_numbers=dn,
                                preferred_element_type=jnp.float32)
        return jnp.transpose(r, (1, 2, 0))
    yr_acc[...] += dot(xr, wr) - dot(xi, wi)
    yi_acc[...] += dot(xr, wi) + dot(xi, wr)

    @pl.when(j == nq - 1)
    def _finish():
        yr = yr_acc[...].reshape(bB * pt, K)
        yi = yi_acc[...].reshape(bB * pt, K)
        # inverse rDFT on the MXU: (bB·pt, K) @ (K, k)
        y = (yr @ ci_ref[...] + yi @ si_ref[...]).reshape(bB, pt * k)
        if has_bias:
            y = y + b_ref[...].astype(jnp.float32)
        y = apply_activation(y, activation)
        o_ref[...] = y.astype(out_dtype)


@functools.partial(
    jax.jit,
    static_argnames=("k", "block_b", "block_p", "block_q", "interpret",
                     "activation"),
)
def bc_matmul_pallas(
    x: jax.Array,
    wr: jax.Array,
    wi: jax.Array,
    c: jax.Array,
    s: jax.Array,
    ci: jax.Array,
    si: jax.Array,
    bias: Optional[jax.Array] = None,
    w_scale: Optional[jax.Array] = None,
    *,
    k: int,
    block_b: int,
    block_p: int,
    block_q: int,
    interpret: bool = False,
    activation: str = "none",
) -> jax.Array:
    """x (B, q·k) × freq-weights (p, q, K)·2 -> y (B, p·k).

    ``bias`` (1, p·k) and ``activation`` run inside the kernel's final-q
    epilogue (fused, no extra HBM round-trip). With ``w_scale`` (p, q) f32,
    wr/wi are int8 tables dequantized in-kernel on the VMEM tile (see
    ``_bc_kernel``); the scale tile rides the same (i, j) index map as the
    weight tiles. Caller (ops.py / plan.py) guarantees B % block_b == 0,
    p % block_p == 0, q % block_q == 0 (it pads otherwise).
    """
    B = x.shape[0]
    p, q, K = wr.shape
    assert K == k // 2 + 1
    grid = (B // block_b, p // block_p, q // block_q)

    has_bias = bias is not None
    has_scale = w_scale is not None
    kernel = functools.partial(
        _bc_kernel, k=k, nq=grid[2], out_dtype=x.dtype,
        activation=activation, has_bias=has_bias, has_scale=has_scale,
    )
    in_specs = [
        pl.BlockSpec((block_b, block_q * k), lambda b, i, j: (b, j)),
        pl.BlockSpec((block_p, block_q, K), lambda b, i, j: (i, j, 0)),
        pl.BlockSpec((block_p, block_q, K), lambda b, i, j: (i, j, 0)),
        pl.BlockSpec((k, K), lambda b, i, j: (0, 0)),
        pl.BlockSpec((k, K), lambda b, i, j: (0, 0)),
        pl.BlockSpec((K, k), lambda b, i, j: (0, 0)),
        pl.BlockSpec((K, k), lambda b, i, j: (0, 0)),
    ]
    args = [x, wr, wi, c, s, ci, si]
    if has_scale:
        in_specs.append(
            pl.BlockSpec((block_p, block_q), lambda b, i, j: (i, j))
        )
        args.append(w_scale)
    if has_bias:
        in_specs.append(
            pl.BlockSpec((1, block_p * k), lambda b, i, j: (0, i))
        )
        args.append(bias)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_b, block_p * k), lambda b, i, j: (b, i)),
        out_shape=jax.ShapeDtypeStruct((B, p * k), x.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_b, block_p, K), jnp.float32),
            pltpu.VMEM((block_b, block_p, K), jnp.float32),
        ],
        interpret=interpret,
    )(*args)


# ---------------------------------------------------------------------------
# Transposed-geometry weight adjoint: dL/dw as a per-bin complex GEMM with
# the train batch promoted to the contraction axis
# ---------------------------------------------------------------------------


def _bc_dw_kernel(x_ref, g_ref, c_ref, s_ref, cit_ref, sit_ref, ct_ref,
                  st_ref, *refs, k: int, nb: int, freq_out: bool):
    """One (i, j, b) grid step of the dw kernel. Shapes (per tile):
      x_ref   : (bB, qt·k)     g_ref : (bB, pt·k)
      c/s     : (k, K)         cit/sit : (k, K)      ct/st : (K, k)
      o_ref   : (pt, qt·k)             [freq_out=False — time-domain dw]
      dwr/dwi : (pt, qt, K)            [freq_out=True  — frozen-param path]
      r/i acc : (pt, qt, K) f32 scratch

    ``dŵ[p,q,f] = Σ_b ĝ[b,p,f]·conj(x̂[b,q,f])`` — the forward kernel's
    per-bin GEMM with batch as the contraction axis: g transforms through
    the adjoint of the inverse rDFT (Ciᵀ/Siᵀ), x through the analysis
    bases (C/S), conj(x̂) negates the imaginary part. The epilogue on the
    final batch step either folds the cotangent back to the time domain
    (dw = dwr@Cᵀ + dwi@Sᵀ) or writes the (dwr, dwi) pair raw.
    """
    if freq_out:
        dwr_ref, dwi_ref, r_acc, i_acc = refs
    else:
        o_ref, r_acc, i_acc = refs
    b = pl.program_id(2)
    K = k // 2 + 1
    bB = x_ref.shape[0]
    qt = x_ref.shape[1] // k
    pt = g_ref.shape[1] // k

    @pl.when(b == 0)
    def _zero():
        r_acc[...] = jnp.zeros_like(r_acc)
        i_acc[...] = jnp.zeros_like(i_acc)

    xb = x_ref[...].astype(jnp.float32).reshape(bB * qt, k)
    xr = (xb @ c_ref[...]).reshape(bB, qt, K)
    xi = (xb @ s_ref[...]).reshape(bB, qt, K)
    gb = g_ref[...].astype(jnp.float32).reshape(bB * pt, k)
    # adjoint of the inverse rDFT on the MXU: gyr = g @ Ciᵀ, gyi = g @ Siᵀ
    gyr = (gb @ cit_ref[...]).reshape(bB, pt, K)
    gyi = (gb @ sit_ref[...]).reshape(bB, pt, K)
    # per-bin complex GEMM, batch contracted: dŵ[p,q,f] += ĝ[b,p,f]·x̂*[b,q,f]
    dn = (((0,), (0,)), ((2,), (2,)))   # contracting b; batching f

    def dot(a, c):
        # a (bB, pt, K), c (bB, qt, K) -> (K, pt, qt) -> (pt, qt, K)
        r = jax.lax.dot_general(a, c, dimension_numbers=dn,
                                preferred_element_type=jnp.float32)
        return jnp.transpose(r, (1, 2, 0))

    r_acc[...] += dot(gyr, xr) + dot(gyi, xi)
    i_acc[...] += dot(gyi, xr) - dot(gyr, xi)

    @pl.when(b == nb - 1)
    def _finish():
        if freq_out:
            dwr_ref[...] = r_acc[...]
            dwi_ref[...] = i_acc[...]
        else:
            dwr = r_acc[...].reshape(pt * qt, K)
            dwi = i_acc[...].reshape(pt * qt, K)
            # adjoint of the forward rDFT: dw = dwr@Cᵀ + dwi@Sᵀ
            o_ref[...] = (dwr @ ct_ref[...] + dwi @ st_ref[...]).reshape(
                pt, qt * k)


@functools.partial(
    jax.jit,
    static_argnames=("k", "block_b", "block_p", "block_q", "freq_out",
                     "interpret"),
)
def bc_dw_pallas(
    x: jax.Array,
    g: jax.Array,
    c: jax.Array,
    s: jax.Array,
    cit: jax.Array,
    sit: jax.Array,
    ct: jax.Array,
    st: jax.Array,
    *,
    k: int,
    block_b: int,
    block_p: int,
    block_q: int,
    freq_out: bool = False,
    interpret: bool = False,
):
    """x (B, Q·k) and upstream cotangent g (B, P·k) -> weight adjoint.

    ``freq_out=False`` returns the time-domain dw (P, Q·k) f32 (`_bwd`,
    trainable block tables); ``freq_out=True`` returns the frequency
    cotangent pair ``(dwr, dwi)`` each (P, Q, K) f32 (`_freq_bwd`, frozen
    frequency parameters). Basis args come from
    ``circulant.dft_bases_adjoint(k)``. Caller (ops.py) guarantees
    B % block_b == 0, P % block_p == 0, Q % block_q == 0 (it pads
    otherwise; zero-padded rows/cols contribute exact zeros).
    """
    B = x.shape[0]
    Q = x.shape[1] // k
    P = g.shape[1] // k
    K = k // 2 + 1
    grid = (P // block_p, Q // block_q, B // block_b)

    kernel = functools.partial(_bc_dw_kernel, k=k, nb=grid[2],
                               freq_out=freq_out)
    in_specs = [
        pl.BlockSpec((block_b, block_q * k), lambda i, j, b: (b, j)),
        pl.BlockSpec((block_b, block_p * k), lambda i, j, b: (b, i)),
        pl.BlockSpec((k, K), lambda i, j, b: (0, 0)),
        pl.BlockSpec((k, K), lambda i, j, b: (0, 0)),
        pl.BlockSpec((k, K), lambda i, j, b: (0, 0)),
        pl.BlockSpec((k, K), lambda i, j, b: (0, 0)),
        pl.BlockSpec((K, k), lambda i, j, b: (0, 0)),
        pl.BlockSpec((K, k), lambda i, j, b: (0, 0)),
    ]
    if freq_out:
        out_specs = (
            pl.BlockSpec((block_p, block_q, K), lambda i, j, b: (i, j, 0)),
            pl.BlockSpec((block_p, block_q, K), lambda i, j, b: (i, j, 0)),
        )
        out_shape = (
            jax.ShapeDtypeStruct((P, Q, K), jnp.float32),
            jax.ShapeDtypeStruct((P, Q, K), jnp.float32),
        )
    else:
        out_specs = pl.BlockSpec((block_p, block_q * k),
                                 lambda i, j, b: (i, j))
        out_shape = jax.ShapeDtypeStruct((P, Q * k), jnp.float32)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[
            pltpu.VMEM((block_p, block_q, K), jnp.float32),
            pltpu.VMEM((block_p, block_q, K), jnp.float32),
        ],
        interpret=interpret,
    )(x, g, c, s, cit, sit, ct, st)
