"""Deterministic synthetic data pipeline, host-sharded.

No external datasets ship with this container, so the pipeline generates
deterministic synthetic batches — but through the same interface a real
loader would use: each *host process* materializes only its addressable
shard of the global batch and the arrays are assembled per-device
(``make_array_from_callback``), exactly the multi-host pattern. Streams:

  * ``lm``      — zipf-ish token ids (B, S+1); structured so that models can
                  actually learn (next token correlates with current)
  * ``image``   — MNIST-like 28×28 blobs with class-dependent means
  * ``speech``  — TIMIT-like filterbank frames + per-frame phone labels
  * ``vlm`` / ``encdec`` — token stream + stub frontend embeddings
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.dist.sharding import batch_pspec

__all__ = ["SyntheticLM", "synthetic_images", "synthetic_speech",
           "host_sharded_batch"]


def _rng(seed: int, step: int) -> np.random.Generator:
    return np.random.default_rng(np.random.SeedSequence([seed, step]))


@dataclasses.dataclass
class SyntheticLM:
    """Markov-ish synthetic LM stream: learnable but non-trivial."""

    vocab: int
    seq_len: int
    batch: int
    seed: int = 0

    def batch_np(self, step: int) -> Dict[str, np.ndarray]:
        r = _rng(self.seed, step)
        B, S, V = self.batch, self.seq_len + 1, self.vocab
        base = r.integers(0, V, size=(B, 1))
        drift = r.integers(1, 7, size=(B, S)).cumsum(axis=1)
        toks = (base + drift) % V
        noise = r.random((B, S)) < 0.1
        toks = np.where(noise, r.integers(0, V, size=(B, S)), toks)
        return {"tokens": toks.astype(np.int32)}

    def batch_jax(self, step: int):
        return jax.tree.map(jnp.asarray, self.batch_np(step))


def synthetic_images(batch: int, step: int, seed: int = 0,
                     hw: int = 28, n_classes: int = 10):
    """(x (B, hw, hw, 1), y (B,)) — class-dependent gaussians, learnable."""
    r = _rng(seed, step)
    y = r.integers(0, n_classes, size=(batch,))
    grid = np.stack(np.meshgrid(np.linspace(-1, 1, hw), np.linspace(-1, 1, hw)),
                    -1)
    ang = 2 * np.pi * y / n_classes
    centers = np.stack([np.cos(ang), np.sin(ang)], -1) * 0.5
    d = ((grid[None] - centers[:, None, None, :]) ** 2).sum(-1)
    x = np.exp(-d * 8) + 0.3 * r.standard_normal((batch, hw, hw))
    return x[..., None].astype(np.float32), y.astype(np.int32)


def synthetic_speech(batch: int, frames: int, dim: int, step: int,
                     seed: int = 0, n_phones: int = 39):
    """Filterbank-like frames with per-frame phone labels.

    Phone prototypes are drawn from `seed` ONLY (fixed across steps — a
    step-dependent prototype table would make the task unlearnable)."""
    proto = np.random.default_rng(seed).standard_normal((n_phones, dim)) * 0.5
    r = _rng(seed, step)
    y = r.integers(0, n_phones, size=(batch, frames))
    x = proto[y] + 0.3 * r.standard_normal((batch, frames, dim))
    return x.astype(np.float32), y.astype(np.int32)


def host_sharded_batch(mesh: Mesh, batch_np: Dict[str, np.ndarray]):
    """Assemble a global batch from per-host shards (multi-host pattern).

    Each process only touches its addressable slice; on a single process
    this degenerates to a plain device_put with the DP sharding.
    """
    out = {}
    for name, arr in batch_np.items():
        sharding = NamedSharding(mesh, batch_pspec(mesh, arr.ndim))
        out[name] = jax.make_array_from_callback(
            arr.shape, sharding, lambda idx, a=arr: a[idx]
        )
    return out
