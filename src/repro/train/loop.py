"""Train-step builder: loss (chunked CE + z-loss + MoE aux), grad
accumulation (microbatching), global-norm clip, AdamW, metrics.

The returned ``train_step(state, batch)`` is a pure jittable function whose
state is a plain dict pytree ``{"params", "opt": {"m","v"}, "step"}`` —
shardings for every leaf come from dist.sharding (params rules + ZeRO-1 for
moments), so the same function lowers on 1 CPU device or a 512-chip mesh.

Microbatched gradient accumulation runs as a ``lax.scan`` over microbatch
slices; the DP gradient all-reduce of microbatch *i* overlaps with the
compute of *i+1* under XLA's latency-hiding scheduler (collective is rooted
inside the scan body).
"""

from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, TrainConfig
from repro.optim.optimizers import (adafactor_init, adafactor_update,
                                    adamw_init, adamw_update,
                                    clip_by_global_norm, global_norm)
from repro.train.losses import chunked_cross_entropy

__all__ = ["make_loss_fn", "make_train_step", "init_train_state",
           "make_grad_step"]


def make_grad_step(loss_fn: Callable, lr: float = 0.1,
                   audit_args=None, audit_rules=None):
    """Minimal jitted SGD step over a bare ``loss_fn(params, batch)``.

    The train-step harness used by the backward-path structural
    regressions and ``benchmarks/kernel_bench.py``'s train-step mode: no
    optimizer state, no model zoo — just value_and_grad plus an in-dtype
    parameter update, so the cached step's jaxpr exposes exactly the
    forward + adjoint computation (e.g. asserting the block-circulant
    weight adjoint runs as a Pallas launch, never a dense (P, Q) einsum).

    ``audit_args=(params, batch)`` gates construction on the train-step
    structural contract: the full step (value_and_grad + update) is traced
    and audited before anything compiles, raising
    :class:`~repro.analysis.contracts.StructuralContractError` with
    ``file:line`` provenance on any violation. ``audit_rules`` overrides
    the default rule set (``NoFFT`` + ``NoDenseDotGeneral`` — right for
    plan-path losses, where the adjoint must stay kernel-only).
    """

    def raw_step(params, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        new_params = jax.tree.map(
            lambda p, g: p - lr * g.astype(p.dtype), params, grads
        )
        return new_params, loss

    if audit_args is not None:
        _audit_step(raw_step, audit_args, audit_rules, name="grad_step")
    return jax.jit(raw_step)


def _audit_step(step_fn, audit_args, audit_rules, name: str):
    """Trace an unjitted step and run the train-step structural contract."""
    from repro.analysis.contracts import (Contract, StructuralContractError,
                                          run_contract)
    from repro.analysis.rules import NoDenseDotGeneral, NoFFT

    rules = (tuple(audit_rules) if audit_rules is not None
             else (NoFFT(), NoDenseDotGeneral()))
    jp = jax.make_jaxpr(step_fn)(*audit_args)
    violations = run_contract(Contract(name=name, rules=rules), jp)
    if violations:
        raise StructuralContractError(violations)


def make_loss_fn(model, cfg: ModelConfig, tcfg: TrainConfig):
    """batch -> scalar loss. Batch layouts:
       lm:     {"tokens": (B, S+1)}
       vlm:    {"tokens": (B, S+1), "img": (B, P, D)}
       encdec: {"frames": (B, T, D), "tokens": (B, S+1)}

    ``tcfg.qat_bits > 0`` turns on quantization-aware training: every
    forward sees fake-quantized parameters (clipped-STE ``fixed_point``
    through ``quantize_tree`` — complex frozen tables included), while the
    optimizer updates the full-precision master copy. Biases and norm
    scales stay fp32 (``quant.default_exempt``): their dynamic range is
    unrelated to the weight rails. This is the training half of the
    paper's fixed-point results — the serve-time int8 freeze
    (``plan.freeze_params(quantize="int8")``) is the deploy half.
    """
    qat_bits = int(getattr(tcfg, "qat_bits", 0) or 0)
    qat_frac = int(getattr(tcfg, "qat_frac_bits", -1))
    if qat_frac < 0:
        qat_frac = qat_bits - 4

    def loss_fn(params, batch):
        if qat_bits:
            from repro.core.quant import default_exempt, quantize_tree

            params = quantize_tree(params, qat_bits, qat_frac,
                                   exempt=default_exempt)
        tokens = batch["tokens"]
        inp, labels = tokens[:, :-1], tokens[:, 1:]
        kwargs = {}
        if cfg.family == "vlm" and "img" in batch:
            kwargs["img_embeds"] = batch["img"]
        if cfg.family == "encdec":
            kwargs["frames"] = batch["frames"]
        hidden, aux = model.forward_hidden(params, inp, **kwargs)
        if cfg.family == "vlm" and "img" in batch:
            hidden = hidden[:, batch["img"].shape[1]:]   # loss on text only
        table = model.output_table(params)
        ce, metrics = chunked_cross_entropy(
            hidden, table, labels, z_loss=tcfg.z_loss
        )
        loss = ce + tcfg.moe_aux_loss * aux
        return loss, {"ce": ce, "aux": aux, **metrics}

    return loss_fn


def init_train_state(params, tcfg: TrainConfig, optimizer: str = "adamw"):
    init = adafactor_init if optimizer == "adafactor" else adamw_init
    return {
        "params": params,
        "opt": init(params, tcfg),
        "step": jnp.zeros((), jnp.int32),
    }


def make_train_step(model, cfg: ModelConfig, tcfg: TrainConfig, mesh=None,
                    audit_args=None, audit_rules=None):
    """Full production step. ``audit_args=(state, batch)`` audits the traced
    step before first compile — default rules are impl-aware: every SWM
    config gets ``DenseFallbackDot`` (no contraction against a circulant
    layer's dense-equivalent kernel; state-derived operands only, so
    activations pass), and kernel-/DFT-backed impls additionally get total
    ``NoFFT``. The ``paper``/``freq`` impls transform weights per forward
    *by design during training* — freezing happens at serve — so no
    weight-fft rule applies here."""
    loss_fn = make_loss_fn(model, cfg, tcfg)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def compute_grads(params, batch):
        if tcfg.microbatch and tcfg.microbatch > 1:
            n = tcfg.microbatch

            def split(x):
                B = x.shape[0]
                x = x.reshape(n, B // n, *x.shape[1:])
                if mesh is not None:
                    # keep DP on the *inner* batch dim — without this GSPMD
                    # shards the microbatch axis instead (measured: per-chip
                    # batch stayed at the full 16 on gemma3 train_4k)
                    from jax.sharding import NamedSharding, PartitionSpec as P
                    from repro.dist.sharding import data_axes
                    dp = data_axes(mesh)
                    dp = dp if len(dp) > 1 else (dp[0] if dp else None)
                    if (B // n) % max(
                        1, int(__import__("numpy").prod(
                            [mesh.shape[a] for a in data_axes(mesh)]))
                    ) == 0:
                        spec = P(None, dp, *([None] * (x.ndim - 2)))
                        x = jax.lax.with_sharding_constraint(
                            x, NamedSharding(mesh, spec))
                return x

            micro = jax.tree.map(split, batch)

            def body(acc, mb):
                (loss, metrics), grads = grad_fn(params, mb)
                acc_g, acc_l = acc
                acc_g = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32) / n, acc_g, grads
                )
                return (acc_g, acc_l + loss / n), metrics

            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (grads, loss), metrics = jax.lax.scan(
                body, (zero, jnp.zeros(())), micro
            )
            # average over microbatches (the loss already accumulates /n in
            # the scan body): reporting only the LAST microbatch's ce/aux
            # made logged metrics disagree with the loss they feed
            metrics = jax.tree.map(lambda m: m.mean(0), metrics)
            return loss, metrics, grads
        (loss, metrics), grads = grad_fn(params, batch)
        return loss, metrics, grads

    def train_step(state, batch):
        loss, metrics, grads = compute_grads(state["params"], batch)
        grads, gnorm = clip_by_global_norm(grads, tcfg.grad_clip)
        update = (adafactor_update if cfg.optimizer == "adafactor"
                  else adamw_update)
        new_params, new_opt = update(
            state["params"], grads, state["opt"], state["step"], tcfg
        )
        new_state = {
            "params": new_params,
            "opt": new_opt,
            "step": state["step"] + 1,
        }
        metrics = {"loss": loss, "grad_norm": gnorm, **metrics}
        return new_state, metrics

    if audit_args is not None:
        rules = audit_rules
        if rules is None:
            from repro.analysis.contracts import (FFT_FREE_IMPLS,
                                                  dense_equivalent_shapes)
            from repro.analysis.rules import DenseFallbackDot, NoFFT
            rules = []
            if cfg.swm.enabled:
                # state leaves (params + opt moments) lead the flattened
                # invars — all weight-derived for taint purposes
                n_state = len(jax.tree.leaves(audit_args[0]))
                rules.append(DenseFallbackDot(
                    dense_equivalent_shapes(model.specs()),
                    n_param_invars=n_state))
                if cfg.swm.impl in FFT_FREE_IMPLS:
                    rules.append(NoFFT())
        if rules:
            _audit_step(train_step, audit_args, tuple(rules),
                        name="train_step")
    return train_step
