"""Losses. The headline trick is **chunked cross-entropy**: for 262k-vocab
models the (B, S, V) logits tensor would be TB-scale; instead we scan over
sequence chunks, computing logits → logsumexp → nll per chunk and keeping
only scalars, so peak memory is O(B·chunk·V / devices)."""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["chunked_cross_entropy", "softmax_cross_entropy"]


def softmax_cross_entropy(logits, labels, mask=None, z_loss: float = 0.0):
    """logits (..., V) f32, labels (...) int. Returns (mean nll, metrics)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if z_loss > 0:
        nll = nll + z_loss * jnp.square(lse)
    if mask is None:
        mask = jnp.ones_like(nll)
    mask = mask.astype(jnp.float32)
    total = jnp.maximum(mask.sum(), 1.0)
    return (nll * mask).sum() / total, {"tokens": total}


def chunked_cross_entropy(
    hidden: jax.Array,          # (B, S, D) final hidden states
    table: jax.Array,           # (V, D) tied embedding (or head.T)
    labels: jax.Array,          # (B, S) int32
    mask: Optional[jax.Array] = None,   # (B, S) 1=count
    *,
    z_loss: float = 0.0,
    chunk: int = 512,
) -> Tuple[jax.Array, dict]:
    """CE where logits are materialized only one sequence-chunk at a time."""
    B, S, D = hidden.shape
    if mask is None:
        mask = jnp.ones((B, S), jnp.float32)
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    n = hidden.shape[1] // chunk
    # Label logits via ONE row gather of the (sharded) table, outside the
    # chunk scan: ll = <h, table[label]>. take_along_axis over a
    # vocab-sharded (B,c,V) logits tensor would force XLA to all-gather
    # every logits chunk (≈5 GB/device/chunk at 152k vocab) — measured in
    # the first dry-run and eliminated here (EXPERIMENTS.md §Perf).
    rows = table[labels]                                    # (B, S', D)
    hs = jnp.moveaxis(hidden.reshape(B, n, chunk, D), 1, 0)
    rs = jnp.moveaxis(rows.reshape(B, n, chunk, D), 1, 0)
    ms = jnp.moveaxis(mask.reshape(B, n, chunk), 1, 0)
    tf = table.astype(jnp.float32)

    @jax.checkpoint        # recompute chunk logits in backward: the scan
    def body(carry, xs):   # must never stack (n, B, c, V) logits residuals
        tot, cnt = carry
        h, r, m = xs
        h32 = h.astype(jnp.float32)
        logits = jnp.einsum("bcd,vd->bcv", h32, tf)   # stays vocab-sharded
        lse = jax.nn.logsumexp(logits, axis=-1)       # sharded reduce
        ll = jnp.einsum("bcd,bcd->bc", h32, r.astype(jnp.float32))
        nll = lse - ll
        if z_loss > 0:
            nll = nll + z_loss * jnp.square(lse)
        mf = m.astype(jnp.float32)
        return (tot + (nll * mf).sum(), cnt + mf.sum()), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros(()), jnp.zeros(())),
                                 (hs, rs, ms))
    cnt = jnp.maximum(cnt, 1.0)
    return tot / cnt, {"tokens": cnt}
