"""Block-circulant (SWM) linear algebra — the paper's core technique.

A weight matrix ``W ∈ R^{m×n}`` is partitioned into ``p×q`` square blocks of
size ``k`` (``p = m/k``, ``q = n/k``). Each block ``W_ij`` is a circulant
matrix defined by one length-``k`` vector ``w_ij`` (the paper, §3):

    W_ij @ x_j = IFFT( FFT(w_ij) ∘ FFT(x_j) )            (circulant-conv thm)

giving O(n log n) compute and O(n) storage per layer instead of O(n²).

Convention: ``W_ij`` is the circulant matrix whose **first column** is
``w_ij``, i.e. ``W_ij[a, b] = w_ij[(a - b) mod k]`` so ``W_ij @ x`` is the
*circular convolution* ``w ⊛ x`` and the FFT identity above holds exactly.
(The paper's prose says "first row"; with a first-row convention the product
is a circular *correlation*, which is the same family under index reversal —
the trained parameterization is isomorphic. We use the convolution
convention so the stated FFT identity is literally true.)

Four forward implementations, selectable per layer (``impl=``):

  * ``paper``  — faithful to the ASIC dataflow (§5.2):
                 ``y_i = Σ_j IFFT(ŵ_ij ∘ x̂_j)`` — one inverse transform per
                 (i, j) block, accumulated in the **time** domain.
  * ``freq``   — beyond-paper: accumulate in the **frequency** domain, one
                 IFFT per output block: ``y_i = IFFT(Σ_j ŵ_ij ∘ x̂_j)``.
                 q× fewer inverse transforms; bit-identical math (linearity).
  * ``dft``    — TPU-native: the (r)DFT of a length-k block is a small dense
                 matmul against precomputed real cos/sin bases → runs on the
                 MXU. Frequency contraction is a per-bin complex GEMM.
  * ``pallas`` — fused Pallas TPU kernel (see repro.kernels.block_circulant);
                 falls back to interpret mode off-TPU.

All paths share the parameterization: the *time-domain* block table
``w ∈ R^{p×q×k}`` is the trainable parameter (so standard optimizers apply);
inference may precompute ``rfft(w)`` once ("frozen frequency weights" — the
paper stores FFT(w_ij) in BRAM).
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "blocks_to_dense",
    "dense_to_blocks_lstsq",
    "block_circulant_matvec_paper",
    "block_circulant_matvec_freq",
    "block_circulant_matvec_dft",
    "block_circulant_apply",
    "block_circulant_apply_fused",
    "block_circulant_apply_multi",
    "dft_bases",
    "dft_bases_adjoint",
    "valid_block_size",
    "swm_flops",
    "dense_flops",
]


# ---------------------------------------------------------------------------
# Reference / conversion utilities
# ---------------------------------------------------------------------------


def blocks_to_dense(w: jax.Array) -> jax.Array:
    """Expand the block table ``w (p, q, k)`` to the dense ``(p·k, q·k)`` W.

    ``W[i·k + a, j·k + b] = w[i, j, (a - b) mod k]``.
    Oracle only — never used in the hot path.
    """
    p, q, k = w.shape
    a = jnp.arange(k)
    idx = (a[:, None] - a[None, :]) % k            # (k, k): (a-b) mod k
    blocks = w[:, :, idx]                           # (p, q, k, k)
    return jnp.transpose(blocks, (0, 2, 1, 3)).reshape(p * k, q * k)


def dense_to_blocks_lstsq(W: jax.Array, k: int) -> jax.Array:
    """Project a dense matrix to the nearest block-circulant table (Frobenius).

    The least-squares circulant fit of a k×k block B is the mean over its
    circulant diagonals: ``w[d] = mean_a B[a, (a - d) mod k]``. Used to
    initialize SWM layers from dense checkpoints (post-training compression).
    """
    m, n = W.shape
    if m % k or n % k:
        raise ValueError(f"dims ({m},{n}) not divisible by k={k}")
    p, q = m // k, n // k
    blocks = W.reshape(p, k, q, k).transpose(0, 2, 1, 3)  # (p, q, k, k)
    a = jnp.arange(k)
    # For diagonal d, entries B[a, (a-d) mod k].
    cols = (a[None, :] - a[:, None]) % k                   # (d, a) -> col
    gathered = blocks[:, :, a[None, :], cols]              # (p, q, k_d, k_a)
    return gathered.mean(-1)


def valid_block_size(requested: int, *dims: int) -> int:
    """Largest k ≤ requested dividing every dim (the paper requires k | m, n).

    Falls back through divisors; k=1 (dense-equivalent storage layout) is the
    floor. Configs use this so e.g. d_ff=11008 clamps k=128 → 32.
    """
    import math

    g = 0
    for d in dims:
        g = math.gcd(g, int(d))
    k = min(max(1, int(requested)), g)
    while g % k:
        k -= 1
    return k


# ---------------------------------------------------------------------------
# FFT-path forwards
# ---------------------------------------------------------------------------


def _split_blocks(x: jax.Array, k: int) -> jax.Array:
    """(..., n) -> (..., q, k)."""
    *lead, n = x.shape
    assert n % k == 0, (n, k)
    return x.reshape(*lead, n // k, k)


def _sharded_fft(fn, x: jax.Array) -> jax.Array:
    """Run an FFT shard-locally over the DP axes via shard_map.

    GSPMD replicates `fft` ops (all-gathers every sharded operand — §Perf 1);
    but the transform axis is never sharded here, so each shard can FFT its
    slice independently. When a production mesh is registered
    (dist.sharding.set_ambient_mesh) we wrap the op in shard_map over the
    data axes; otherwise this is a plain call. This rescues the
    paper-faithful O(n log n) dataflow for distributed training
    (impl='freq_shmap' / 'paper_shmap').
    """
    from repro.dist.sharding import _AMBIENT_MESH, data_axes
    from jax.sharding import PartitionSpec as P

    mesh = _AMBIENT_MESH[0]
    if mesh is None:
        return fn(x)
    dp = data_axes(mesh)
    if not dp or x.shape[0] % max(
        1, int(np.prod([mesh.shape[a] for a in dp]))
    ):
        return fn(x)
    lead = dp if len(dp) > 1 else dp[0]
    spec = P(lead, *([None] * (x.ndim - 1)))
    return jax.shard_map(fn, mesh=mesh, in_specs=spec, out_specs=spec,
                         check_vma=False)(x)


def block_circulant_matvec_paper(
    x: jax.Array, w: jax.Array, *, precision=None
) -> jax.Array:
    """Paper-faithful §5.2 dataflow: IFFT per (i,j) block, time-domain sum.

    x: (..., n), w: (p, q, k) -> (..., m).  Faithful to the ASIC processing
    system ``y_i = Σ_j IFFT(ŵ_ij ∘ x̂_j)``: the accumulator operates on
    time-domain IFFT outputs, one input block j at a time (the hardware
    iterates blocks through one FFT engine), i.e. O(p·q) inverse transforms.
    Implemented as a lax.scan over j so the (..., p, q, k) tensor is never
    materialized — memory-feasible at LM scale while keeping the exact
    operation count of the paper's dataflow.
    """
    p, q, k = w.shape
    xb = _split_blocks(x, k)                               # (..., q, k)
    xh = jnp.fft.rfft(xb.astype(jnp.float32), axis=-1)     # (..., q, K)
    wh = jnp.fft.rfft(w.astype(jnp.float32), axis=-1)      # (p, q, K)

    def body(acc, xs):
        xh_j, wh_j = xs                                    # (..., K), (p, K)
        prod = xh_j[..., None, :] * wh_j                   # (..., p, K)
        acc = acc + jnp.fft.irfft(prod, n=k, axis=-1)      # time-domain sum
        return acc, None

    acc0 = jnp.zeros((*x.shape[:-1], p, k), jnp.float32)
    acc, _ = jax.lax.scan(
        body, acc0, (jnp.moveaxis(xh, -2, 0), jnp.moveaxis(wh, 1, 0))
    )
    return acc.reshape(*x.shape[:-1], p * k).astype(x.dtype)


def block_circulant_matvec_freq(
    x: jax.Array, w: jax.Array, *, w_freq: Optional[jax.Array] = None,
    k: Optional[int] = None, shmap: bool = False,
) -> jax.Array:
    """Frequency-domain accumulation (beyond-paper): one IFFT per output block.

    ``y_i = IFFT( Σ_j ŵ_ij ∘ x̂_j )``. ``w_freq`` (p, q, K) complex may be
    passed to use frozen precomputed weights (inference; the paper's BRAM) —
    pass ``k`` alongside when w is None (K alone is ambiguous for odd k).
    ``shmap=True`` runs the activation FFTs shard-locally over the DP axes
    (see _sharded_fft) — the faithful O(n log n) dataflow, distributable.
    """
    if w_freq is None:
        p, q, k = w.shape
        w_freq = jnp.fft.rfft(w.astype(jnp.float32), axis=-1)
    else:
        p, q = w_freq.shape[:2]
        if k is None:
            k = (w_freq.shape[-1] - 1) * 2 if w is None else w.shape[-1]
    xb = _split_blocks(x, k).astype(jnp.float32)
    fwd = lambda a: jnp.fft.rfft(a, axis=-1)
    xh = _sharded_fft(fwd, xb) if shmap else fwd(xb)       # (..., q, K)
    yh = jnp.einsum("...qf,pqf->...pf", xh, w_freq)        # (..., p, K)
    inv = lambda a: jnp.fft.irfft(a, n=k, axis=-1)
    yb = _sharded_fft(inv, yh) if shmap else inv(yh)       # (..., p, k)
    return yb.reshape(*x.shape[:-1], p * k).astype(x.dtype)


# ---------------------------------------------------------------------------
# DFT-as-matmul path (MXU-native)
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=64)
def _dft_bases_np(k: int) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Real rDFT analysis/synthesis bases as numpy constants.

    Analysis (x (.., k) real -> X (.., K) complex, K = k//2+1):
        Xr = x @ C,   Xi = x @ S          C[a,f]=cos(2πaf/k), S[a,f]=-sin(2πaf/k)
    Synthesis (X -> y (.., k) real):
        y = Xr @ Ci + Xi @ Si
        Ci[f,a] = g_f·cos(2πaf/k)/k,  Si[f,a] = -g_f·sin(2πaf/k)/k
        g_f = 1 for f ∈ {0, k/2}, else 2   (Hermitian-symmetry fold)
    """
    K = k // 2 + 1
    a = np.arange(k)[:, None]
    f = np.arange(K)[None, :]
    ang = 2.0 * np.pi * a * f / k
    C = np.cos(ang)
    S = -np.sin(ang)
    g = np.full((K,), 2.0)
    g[0] = 1.0
    if k % 2 == 0:
        g[-1] = 1.0
    Ci = (g[:, None] * np.cos(ang).T) / k
    Si = -(g[:, None] * np.sin(ang).T) / k
    return (
        C.astype(np.float32),
        S.astype(np.float32),
        Ci.astype(np.float32),
        Si.astype(np.float32),
    )


def dft_bases(k: int, dtype=jnp.float32):
    C, S, Ci, Si = _dft_bases_np(k)
    return (
        jnp.asarray(C, dtype),
        jnp.asarray(S, dtype),
        jnp.asarray(Ci, dtype),
        jnp.asarray(Si, dtype),
    )


@functools.lru_cache(maxsize=64)
def _dft_bases_adjoint_np(k: int):
    C, S, Ci, Si = _dft_bases_np(k)
    return (C, S, np.ascontiguousarray(Ci.T), np.ascontiguousarray(Si.T),
            np.ascontiguousarray(C.T), np.ascontiguousarray(S.T))


def dft_bases_adjoint(k: int, dtype=jnp.float32):
    """Basis set for the transposed-geometry weight-adjoint (dw) kernel.

    Returns ``(C, S, CiT, SiT, CT, ST)``:

      * ``C, S``     — analysis bases for x̂ (as :func:`dft_bases`),
      * ``CiT, SiT`` — adjoint of the inverse rDFT, applied to the upstream
        cotangent g: ``gyr = g @ Ciᵀ``, ``gyi = g @ Siᵀ`` (the pullback of
        ``y = yr@Ci + yi@Si``),
      * ``CT, ST``   — adjoint of the forward rDFT, folding the frequency
        cotangent back to the time domain: ``dw = dwr@Cᵀ + dwi@Sᵀ``.

    Precomputed as numpy constants (lru-cached) so the dw kernel launch
    carries no per-trace transpose of the basis matrices.
    """
    C, S, CiT, SiT, CT, ST = _dft_bases_adjoint_np(k)
    return tuple(jnp.asarray(a, dtype) for a in (C, S, CiT, SiT, CT, ST))


def _dft_fwd_math(x, w, karatsuba, cdt):
    p, q, k = w.shape
    C, S, Ci, Si = dft_bases(k, cdt)
    f32 = jnp.float32
    xb = _split_blocks(x, k).astype(cdt)                   # (..., q, k)
    wf = w.astype(cdt)
    mm = functools.partial(jnp.matmul, preferred_element_type=f32)
    xr = mm(xb, C).astype(cdt)                             # (..., q, K)
    xi = mm(xb, S).astype(cdt)
    wr = mm(wf, C).astype(cdt)                             # (p, q, K)
    wi = mm(wf, S).astype(cdt)
    ein = functools.partial(jnp.einsum, preferred_element_type=f32)
    if karatsuba:
        # (xr + i·xi)(wr + i·wi): t1 = xr·wr, t2 = xi·wi,
        # yr = t1 - t2, yi = (xr+xi)(wr+wi) - t1 - t2
        t1 = ein("...qf,pqf->...pf", xr, wr)
        t2 = ein("...qf,pqf->...pf", xi, wi)
        t3 = ein("...qf,pqf->...pf", xr + xi, wr + wi)
        yr = (t1 - t2).astype(cdt)
        yi = (t3 - t1 - t2).astype(cdt)
    else:
        yr = (ein("...qf,pqf->...pf", xr, wr)
              - ein("...qf,pqf->...pf", xi, wi)).astype(cdt)
        yi = (ein("...qf,pqf->...pf", xr, wi)
              + ein("...qf,pqf->...pf", xi, wr)).astype(cdt)
    yb = mm(yr, Ci) + mm(yi, Si)                           # (..., p, k) f32
    return yb.reshape(*x.shape[:-1], p * k).astype(x.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def _dft_op(x2d: jax.Array, w: jax.Array, karatsuba: bool) -> jax.Array:
    """2-D core of the DFT path with a hand-written VJP.

    XLA's autodiff of the frequency einsums materializes (K, p, tokens)
    cotangent transposes in f32 (measured 320 GB/dev on gemma3 train_4k).
    The custom VJP computes the circulant adjoints with bf16 operands and
    f32 accumulation, residuals = just (x, w) — frequency tensors are
    recomputed, never stored.
    """
    return _dft_fwd_math(x2d, w, karatsuba, x2d.dtype)


def _dft_fwd(x2d, w, karatsuba):
    return _dft_op(x2d, w, karatsuba), (x2d, w)


def _dft_bwd(karatsuba, res, g):
    x2d, w = res
    p, q, k = w.shape
    cdt = x2d.dtype
    f32 = jnp.float32
    C, S, Ci, Si = dft_bases(k, cdt)
    mm = functools.partial(jnp.matmul, preferred_element_type=f32)
    ein = functools.partial(jnp.einsum, preferred_element_type=f32)
    # recompute frequency operands (cheap small matmuls)
    xb = _split_blocks(x2d, k).astype(cdt)
    xr = mm(xb, C).astype(cdt)
    xi = mm(xb, S).astype(cdt)
    wf = w.astype(cdt)
    wr = mm(wf, C).astype(cdt)
    wi = mm(wf, S).astype(cdt)
    gb = g.reshape(*g.shape[:-1], p, k).astype(cdt)
    # adjoint of the inverse rDFT (y = yr@Ci + yi@Si)
    gyr = mm(gb, Ci.T).astype(cdt)                         # (..., p, K)
    gyi = mm(gb, Si.T).astype(cdt)
    # adjoints of the per-bin complex GEMM
    dxr = (ein("...pf,pqf->...qf", gyr, wr)
           + ein("...pf,pqf->...qf", gyi, wi)).astype(cdt)
    dxi = (-ein("...pf,pqf->...qf", gyr, wi)
           + ein("...pf,pqf->...qf", gyi, wr)).astype(cdt)
    dwr = (ein("...pf,...qf->pqf", gyr, xr)
           + ein("...pf,...qf->pqf", gyi, xi))
    dwi = (-ein("...pf,...qf->pqf", gyr, xi)
           + ein("...pf,...qf->pqf", gyi, xr))
    # adjoint of the forward rDFT (xr = x@C, xi = x@S)
    dx = (mm(dxr, C.T) + mm(dxi, S.T)).reshape(x2d.shape).astype(x2d.dtype)
    dw = (mm(dwr.astype(cdt), C.T)
          + mm(dwi.astype(cdt), S.T)).astype(w.dtype)
    return dx, dw


_dft_op.defvjp(_dft_fwd, _dft_bwd)


def block_circulant_matvec_dft(
    x: jax.Array,
    w: jax.Array,
    *,
    karatsuba: bool = False,
    compute_dtype=None,
) -> jax.Array:
    """MXU path: rDFT via dense matmul, per-bin complex GEMM, inverse matmul.

    Every op is a matmul or einsum → maps onto the systolic array. With
    ``karatsuba=True`` the complex contraction uses 3 real einsums instead
    of 4 (beyond-paper micro-optimization; measured in §Perf).

    Multiplications run in the input dtype (bf16 in production) with f32
    accumulation; the custom VJP keeps backward intermediates in the same
    dtype and saves only (x, w) as residuals (§Perf iterations 2–3).
    """
    if compute_dtype is not None and compute_dtype != x.dtype:
        x = x.astype(compute_dtype)
    lead = x.shape[:-1]
    x2d = x.reshape(-1, x.shape[-1])
    y = _dft_op(x2d, w, bool(karatsuba))
    return y.reshape(*lead, y.shape[-1])


# ---------------------------------------------------------------------------
# Fused pair op: two circulant projections sharing one forward DFT
# (SwiGLU's wi/wu read the same x — the x̂ transform is computed once,
#  saving ~1/3 of the FFN's forward transforms; §Perf "further levers")
# ---------------------------------------------------------------------------


@jax.custom_vjp
def _dft_pair_op(x2d: jax.Array, w1: jax.Array, w2: jax.Array):
    y1, y2, _, _ = _dft_pair_fwd_math(x2d, w1, w2)
    return y1, y2


def _dft_pair_fwd_math(x2d, w1, w2):
    p, q, k = w1.shape
    cdt = x2d.dtype
    C, S, Ci, Si = dft_bases(k, cdt)
    f32 = jnp.float32
    mm = functools.partial(jnp.matmul, preferred_element_type=f32)
    ein = functools.partial(jnp.einsum, preferred_element_type=f32)
    xb = _split_blocks(x2d, k).astype(cdt)
    xr = mm(xb, C).astype(cdt)          # shared forward transform
    xi = mm(xb, S).astype(cdt)

    def one(w):
        wf = w.astype(cdt)
        wr = mm(wf, C).astype(cdt)
        wi = mm(wf, S).astype(cdt)
        yr = (ein("...qf,pqf->...pf", xr, wr)
              - ein("...qf,pqf->...pf", xi, wi)).astype(cdt)
        yi = (ein("...qf,pqf->...pf", xr, wi)
              + ein("...qf,pqf->...pf", xi, wr)).astype(cdt)
        y = mm(yr, Ci) + mm(yi, Si)
        return y.reshape(*x2d.shape[:-1], w.shape[0] * k).astype(x2d.dtype)

    return one(w1), one(w2), xr, xi


def _dft_pair_fwd(x2d, w1, w2):
    y1, y2, _, _ = _dft_pair_fwd_math(x2d, w1, w2)
    return (y1, y2), (x2d, w1, w2)


def _dft_pair_bwd(res, gs):
    x2d, w1, w2 = res
    g1, g2 = gs
    dx1, dw1 = _dft_bwd(False, (x2d, w1), g1)
    dx2, dw2 = _dft_bwd(False, (x2d, w2), g2)
    return dx1 + dx2, dw1, dw2


_dft_pair_op.defvjp(_dft_pair_fwd, _dft_pair_bwd)


def block_circulant_apply_pair(x: jax.Array, w1: jax.Array, w2: jax.Array):
    """(y1, y2) = (BC(w1)·x, BC(w2)·x) with one shared forward DFT."""
    lead = x.shape[:-1]
    x2d = x.reshape(-1, x.shape[-1])
    y1, y2 = _dft_pair_op(x2d, w1, w2)
    return (y1.reshape(*lead, y1.shape[-1]),
            y2.reshape(*lead, y2.shape[-1]))


# ---------------------------------------------------------------------------
# Unified entry point
# ---------------------------------------------------------------------------


def block_circulant_apply(
    x: jax.Array,
    w: jax.Array,
    *,
    impl: str = "freq",
    karatsuba: bool = False,
) -> jax.Array:
    """Dispatch on implementation. x: (..., q·k), w: (p, q, k) -> (..., p·k)."""
    if impl == "paper":
        return block_circulant_matvec_paper(x, w)
    if impl == "freq":
        return block_circulant_matvec_freq(x, w)
    if impl == "freq_shmap":
        lead = x.shape[:-1]
        y = block_circulant_matvec_freq(
            x.reshape(-1, x.shape[-1]), w, shmap=True)
        return y.reshape(*lead, y.shape[-1])
    if impl == "dft":
        return block_circulant_matvec_dft(x, w, karatsuba=karatsuba)
    if impl == "pallas":
        from repro.kernels.block_circulant import ops as bc_ops

        return bc_ops.block_circulant_matmul(x, w)
    raise ValueError(f"unknown impl {impl!r}")


def _epilogue(y: jax.Array, bias: Optional[jax.Array], activation: str
              ) -> jax.Array:
    from repro.kernels.block_circulant.kernel import apply_activation

    if bias is not None:
        y = y + bias.astype(y.dtype)
    return apply_activation(y, activation)


def dequantize_freq_pair(wr: jax.Array, wi: jax.Array,
                         w_scale: Optional[jax.Array]):
    """int8 frozen pair + per-(p, q)-block scale -> f32 pair (no-op when
    ``w_scale`` is None). The XLA ``dft``/``freq`` fallback's analogue of
    the Pallas kernel's in-tile dequant: identical float ops
    (``quant.dequantize_symmetric``), so both paths see the same f32
    tables and greedy outputs stay bit-identical across impls."""
    if w_scale is None:
        return wr, wi
    from repro.core.quant import dequantize_symmetric

    return (dequantize_symmetric(wr, w_scale),
            dequantize_symmetric(wi, w_scale))


def block_circulant_apply_fused(
    x: jax.Array,
    w: Optional[jax.Array],
    *,
    impl: str = "freq",
    bias: Optional[jax.Array] = None,
    activation: str = "none",
    w_freq: Optional[Tuple[jax.Array, jax.Array]] = None,
    w_scale: Optional[jax.Array] = None,
    k: Optional[int] = None,
    karatsuba: bool = False,
) -> jax.Array:
    """One projection with the bias/activation epilogue and (optionally)
    frozen frequency weights ``w_freq=(wr, wi)``.

    * ``impl='pallas'`` — everything fuses into the kernel (epilogue runs in
      VMEM before writeback; frozen weights skip rfft(w) entirely;
      ``w_scale`` marks int8 tables dequantized in-tile).
    * other impls — frozen weights route through the freq path (the paper's
      BRAM-resident FFT(w)); int8 tables dequantize at trace entry
      (:func:`dequantize_freq_pair`); epilogue is a trailing XLA
      elementwise (fused by XLA itself).
    """
    if impl == "pallas":
        from repro.kernels.block_circulant import ops as bc_ops

        return bc_ops.block_circulant_matmul(
            x, w, bias=bias, activation=activation, w_freq=w_freq,
            w_scale=w_scale, k=k
        )
    if w_freq is not None:
        wr, wi = dequantize_freq_pair(*w_freq, w_scale)
        lead = x.shape[:-1]
        y = block_circulant_matvec_freq(
            x.reshape(-1, x.shape[-1]), w,
            w_freq=(wr + 1j * wi).astype(jnp.complex64), k=k,
        )
        y = y.reshape(*lead, y.shape[-1])
    else:
        y = block_circulant_apply(x, w, impl=impl, karatsuba=karatsuba)
    return _epilogue(y, bias, activation)


def concat_biases(splits, biases, k: int) -> Optional[jax.Array]:
    """Stack per-projection biases along the fused p axis (None -> zeros).

    Single source of truth for the stacked-p bias convention, shared by the
    XLA multi path here, ``ops.block_circulant_matmul_multi`` and
    ``plan.build_multi_plan``.
    """
    if biases is None or not any(b is not None for b in biases):
        return None
    parts = [
        (jnp.zeros((p * k,), jnp.float32) if b is None
         else b.reshape(-1).astype(jnp.float32))
        for p, b in zip(splits, biases)
    ]
    return jnp.concatenate(parts)


def split_outputs(y: jax.Array, splits, k: int):
    """Slice a fused (..., Σp_i·k) output back into per-projection outputs."""
    outs = []
    off = 0
    for p in splits:
        outs.append(y[..., off: off + p * k])
        off += p * k
    return outs


def block_circulant_apply_multi(
    x: jax.Array,
    ws,
    *,
    impl: str = "freq",
    biases=None,
    activation: str = "none",
    w_freqs=None,
    w_freq_cat: Optional[Tuple[jax.Array, jax.Array]] = None,
    w_scale_cat: Optional[jax.Array] = None,
    splits: Optional[Tuple[int, ...]] = None,
    bias_cat: Optional[jax.Array] = None,
    k: Optional[int] = None,
    karatsuba: bool = False,
):
    """N projections sharing one input -> one stacked-p launch, any impl.

    Tables concatenate along p (they must share (q, k)), so the shared
    input is transformed once and a single contraction/kernel serves every
    projection — C-LSTM's fused gate dataflow, applied to LSTM gates and
    attention QKV. Returns the per-projection outputs (split back). Pass
    ``k`` when ws is None and the block size is odd (K is ambiguous).

    ``w_freq_cat=(wr, wi)`` takes a PRE-concatenated stacked frozen table
    (``plan.freeze_params`` attaches one per fused group under
    ``plan.FUSED_KEY``) with explicit per-projection ``splits`` (p_i block
    counts) and ``k`` — the zero-concat serve path: no weight-side
    ``jnp.concatenate`` appears in the trace. ``bias_cat`` is the matching
    pre-concatenated (Σp_i·k,) bias (mutually exclusive with ``biases``);
    ``w_scale_cat`` the matching stacked per-block scales when the fused
    tables are int8.
    """
    if w_freq_cat is not None:
        if splits is None or k is None:
            raise ValueError("w_freq_cat needs explicit splits and k")
        if biases is not None:
            raise ValueError("w_freq_cat takes bias_cat, not per-proj biases")
    if impl == "pallas":
        from repro.kernels.block_circulant import ops as bc_ops

        return bc_ops.block_circulant_matmul_multi(
            x, ws, biases=biases, activation=activation, w_freqs=w_freqs,
            w_freq_cat=w_freq_cat, w_scale_cat=w_scale_cat, splits=splits,
            bias_cat=bias_cat, k=k,
        )
    if w_freq_cat is not None:
        wr, wi = dequantize_freq_pair(*w_freq_cat, w_scale_cat)
        ps = list(splits)
        lead = x.shape[:-1]
        y = block_circulant_matvec_freq(
            x.reshape(-1, x.shape[-1]), None,
            w_freq=(wr + 1j * wi).astype(jnp.complex64), k=k,
        ).reshape(*lead, -1)
        if bias_cat is not None:
            y = y + bias_cat.astype(y.dtype)
        return [
            _epilogue(o, None, activation)
            for o in split_outputs(y, ps, k)
        ]
    if w_freqs is not None:
        ps = [wr.shape[0] for wr, _ in w_freqs]
        if k is None:
            k = (ws[0].shape[-1] if ws is not None
                 else 2 * (w_freqs[0][0].shape[-1] - 1))
        wf_cat = jnp.concatenate(
            [(wr + 1j * wi).astype(jnp.complex64) for wr, wi in w_freqs],
            axis=0,
        )
        lead = x.shape[:-1]
        y = block_circulant_matvec_freq(
            x.reshape(-1, x.shape[-1]), None, w_freq=wf_cat, k=k
        ).reshape(*lead, -1)
    else:
        ps = [w.shape[0] for w in ws]
        k = ws[0].shape[-1]
        y = block_circulant_apply(
            x, jnp.concatenate(list(ws), axis=0), impl=impl,
            karatsuba=karatsuba,
        )
    return [
        _epilogue(o, biases[i] if biases is not None else None, activation)
        for i, o in enumerate(split_outputs(y, ps, k))
    ]


# ---------------------------------------------------------------------------
# FLOP accounting (roofline / benchmarks)
# ---------------------------------------------------------------------------


def dense_flops(batch: int, m: int, n: int) -> int:
    return 2 * batch * m * n


def swm_flops(batch: int, m: int, n: int, k: int, impl: str = "freq") -> int:
    """Analytic FLOPs of one SWM layer application (fwd)."""
    p, q, K = m // k, n // k, k // 2 + 1
    fft = 5 * k * int(np.log2(max(k, 2)))   # ~5k·log2 k per length-k rFFT
    contraction = 8 * p * q * K             # complex MAC = 4 mul + 4 add
    if impl == "paper":
        iffts = p * q
    else:
        iffts = p
    return batch * (q * fft + contraction + iffts * fft)
