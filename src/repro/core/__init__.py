# Subpackages import lazily to avoid core <-> nn import cycles
# (nn.linear depends on core.circulant; core.lstm depends on nn.linear).
from repro.core import circulant, quant
