"""SWM-LSTM — the paper's LSTM (§2.2 eq. 1a–1g) with block-circulant weights.

Google-LSTM architecture [35]: gates from x_t and the *projected* recurrent
output y_{t-1}; diagonal peephole connections W_ic/W_fc/W_oc (element-wise,
never circulant — they are already O(n)); projection W_ym to d_proj.

All eight gate matrices and the projection are block-circulant with block
size k (paper §6.1: FFT8 → 0.32% PER loss, FFT16 → 1.23%).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import SWMConfig
from repro.nn.linear import Linear
from repro.nn.module import ParamSpec

__all__ = ["SWMLSTM"]


@dataclasses.dataclass(frozen=True)
class SWMLSTM:
    d_in: int
    d_cell: int
    d_proj: int
    swm: SWMConfig = dataclasses.field(default_factory=SWMConfig)
    dtype: str = "float32"

    def _lin(self, i, o):
        return Linear(in_dim=i, out_dim=o, in_axis=None, out_axis=None,
                      family="lstm", swm=self.swm, dtype=self.dtype)

    def specs(self):
        di, dc, dp = self.d_in, self.d_cell, self.d_proj
        f32 = jnp.float32
        s = {}
        for g in ("i", "f", "c", "o"):
            s[f"W{g}x"] = self._lin(di, dc).specs()
            s[f"W{g}r"] = self._lin(dp, dc).specs()
            s[f"b{g}"] = ParamSpec((dc,), f32, (None,), init="zeros")
        for g in ("i", "f", "o"):     # diagonal peepholes
            s[f"W{g}c"] = ParamSpec((dc,), f32, (None,), init="zeros")
        s["Wym"] = self._lin(dc, dp).specs()
        return s

    def step(self, params, x_t, y_prev, c_prev):
        """One LSTM step (eq. 1a–1g). Shapes: x (B,di), y (B,dp), c (B,dc)."""
        lin_x = lambda g: self._lin(self.d_in, self.d_cell)(params[f"W{g}x"], x_t)
        lin_r = lambda g: self._lin(self.d_proj, self.d_cell)(params[f"W{g}r"], y_prev)
        i = jax.nn.sigmoid(lin_x("i") + lin_r("i") + params["Wic"] * c_prev + params["bi"])
        f = jax.nn.sigmoid(lin_x("f") + lin_r("f") + params["Wfc"] * c_prev + params["bf"])
        g = jax.nn.sigmoid(lin_x("c") + lin_r("c") + params["bc"])
        c = f * c_prev + g * i
        o = jax.nn.sigmoid(lin_x("o") + lin_r("o") + params["Woc"] * c + params["bo"])
        m = o * jnp.tanh(c)
        y = self._lin(self.d_cell, self.d_proj)(params["Wym"], m)
        return y, c

    def __call__(self, params, xs: jax.Array,
                 state: Optional[Tuple[jax.Array, jax.Array]] = None):
        """xs (B, T, di) -> ys (B, T, dp); scan over time."""
        B = xs.shape[0]
        if state is None:
            y0 = jnp.zeros((B, self.d_proj), xs.dtype)
            c0 = jnp.zeros((B, self.d_cell), jnp.float32)
        else:
            y0, c0 = state

        def body(carry, x_t):
            y, c = carry
            y, c = self.step(params, x_t, y, c.astype(jnp.float32))
            return (y, c), y

        (yT, cT), ys = jax.lax.scan(body, (y0, c0), jnp.moveaxis(xs, 1, 0))
        return jnp.moveaxis(ys, 0, 1), (yT, cT)
