"""SWM-LSTM — the paper's LSTM (§2.2 eq. 1a–1g) with block-circulant weights.

Google-LSTM architecture [35]: gates from x_t and the *projected* recurrent
output y_{t-1}; diagonal peephole connections W_ic/W_fc/W_oc (element-wise,
never circulant — they are already O(n)); projection W_ym to d_proj.

All eight gate matrices and the projection are block-circulant with block
size k (paper §6.1: FFT8 → 0.32% PER loss, FFT16 → 1.23%).

Gate fusion (C-LSTM, arXiv:1803.06305): the four gates read the SAME
``[x_t ; y_{t-1}]`` input, so their eight block tables concatenate — per
gate along q (x-source ++ recurrent-source) and across gates along p — into
one (4·dc/k, (di+dp)/k, k) table executed as ONE stacked-p launch per step
(``core.circulant.block_circulant_apply_multi``) with the gate biases fused
into the kernel epilogue. Peepholes and the sigmoids stay outside (they mix
in c, which doesn't exist until after the f/i gates). Falls back to the
8-launch path when the x- and recurrent-side block sizes differ or SWM is
off. Frozen frequency weights (serve path) concatenate the same way.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import SWMConfig
from repro.core import circulant as circ
from repro.nn.linear import Linear
from repro.nn.module import ParamSpec

__all__ = ["SWMLSTM"]


@dataclasses.dataclass(frozen=True)
class SWMLSTM:
    d_in: int
    d_cell: int
    d_proj: int
    swm: SWMConfig = dataclasses.field(default_factory=SWMConfig)
    dtype: str = "float32"

    def _lin(self, i, o):
        return Linear(in_dim=i, out_dim=o, in_axis=None, out_axis=None,
                      family="lstm", swm=self.swm, dtype=self.dtype)

    def specs(self):
        di, dc, dp = self.d_in, self.d_cell, self.d_proj
        f32 = jnp.float32
        s = {}
        for g in ("i", "f", "c", "o"):
            s[f"W{g}x"] = self._lin(di, dc).specs()
            s[f"W{g}r"] = self._lin(dp, dc).specs()
            s[f"b{g}"] = ParamSpec((dc,), f32, (None,), init="zeros")
        for g in ("i", "f", "o"):     # diagonal peepholes
            s[f"W{g}c"] = ParamSpec((dc,), f32, (None,), init="zeros")
        s["Wym"] = self._lin(dc, dp).specs()
        return s

    @property
    def _fused_gate_k(self) -> int:
        """Block size for the fused 8-matrix gate launch; 0 = not fusable."""
        kx = self._lin(self.d_in, self.d_cell).block_size
        kr = self._lin(self.d_proj, self.d_cell).block_size
        return kx if (kx > 1 and kx == kr) else 0

    def _fused_gate_preacts(self, params, x_t, y_prev):
        """[x_t ; y_prev] through ONE stacked (4·dc, di+dp) circulant launch.

        Returns the four gate pre-activations (bias fused, peepholes not).
        Frozen (serve) trees carry the whole 8-table group pre-concatenated
        (``plan.freeze_params`` under ``plan.FUSED_KEY``, gate biases
        included), so the traced step concatenates activations only — never
        weight tables."""
        xy = jnp.concatenate([x_t, y_prev], axis=-1)
        k = self._fused_gate_k
        from repro.kernels.block_circulant.plan import FUSED_KEY

        fused = params.get(FUSED_KEY)
        if fused is not None:
            return circ.block_circulant_apply_multi(
                xy, None, impl=self.swm.impl,
                w_freq_cat=(fused["wr"], fused["wi"]),
                w_scale_cat=fused.get("w_scale"),
                splits=(self.d_cell // k,) * 4, bias_cat=fused["bias"],
                k=k, karatsuba=self.swm.karatsuba,
            )
        gates = ("i", "f", "c", "o")
        pairs = [(params[f"W{g}x"], params[f"W{g}r"]) for g in gates]
        frozen = all("wr" in px and "wi" in px and "wr" in pr and "wi" in pr
                     for px, pr in pairs)
        if frozen:
            # frequency tables only; time-domain concats would be dead code
            # (int8 tables dequantize per side before the q-axis concat —
            # the x/r halves carry separate per-block scales)
            ws = None
            deq = circ.dequantize_freq_pair
            w_freqs = []
            for px, pr in pairs:
                xr, xi = deq(px["wr"], px["wi"], px.get("w_scale"))
                rr, ri = deq(pr["wr"], pr["wi"], pr.get("w_scale"))
                w_freqs.append((jnp.concatenate([xr, rr], axis=1),
                                jnp.concatenate([xi, ri], axis=1)))
        else:
            ws = [jnp.concatenate([px["w"], pr["w"]], axis=1)
                  for px, pr in pairs]
            w_freqs = None
        biases = [params[f"b{g}"] for g in gates]
        return circ.block_circulant_apply_multi(
            xy, ws, biases=biases, impl=self.swm.impl, w_freqs=w_freqs,
            k=k, karatsuba=self.swm.karatsuba,
        )

    def step(self, params, x_t, y_prev, c_prev):
        """One LSTM step (eq. 1a–1g). Shapes: x (B,di), y (B,dp), c (B,dc)."""
        if self._fused_gate_k:
            pre_i, pre_f, pre_c, pre_o = self._fused_gate_preacts(
                params, x_t, y_prev
            )
            i = jax.nn.sigmoid(pre_i + params["Wic"] * c_prev)
            f = jax.nn.sigmoid(pre_f + params["Wfc"] * c_prev)
            g = jax.nn.sigmoid(pre_c)
            c = f * c_prev + g * i
            o = jax.nn.sigmoid(pre_o + params["Woc"] * c)
        else:
            lin_x = lambda g: self._lin(self.d_in, self.d_cell)(params[f"W{g}x"], x_t)
            lin_r = lambda g: self._lin(self.d_proj, self.d_cell)(params[f"W{g}r"], y_prev)
            i = jax.nn.sigmoid(lin_x("i") + lin_r("i") + params["Wic"] * c_prev + params["bi"])
            f = jax.nn.sigmoid(lin_x("f") + lin_r("f") + params["Wfc"] * c_prev + params["bf"])
            g = jax.nn.sigmoid(lin_x("c") + lin_r("c") + params["bc"])
            c = f * c_prev + g * i
            o = jax.nn.sigmoid(lin_x("o") + lin_r("o") + params["Woc"] * c + params["bo"])
        m = o * jnp.tanh(c)
        y = self._lin(self.d_cell, self.d_proj)(params["Wym"], m)
        return y, c

    def __call__(self, params, xs: jax.Array,
                 state: Optional[Tuple[jax.Array, jax.Array]] = None):
        """xs (B, T, di) -> ys (B, T, dp); scan over time."""
        B = xs.shape[0]
        if state is None:
            y0 = jnp.zeros((B, self.d_proj), xs.dtype)
            c0 = jnp.zeros((B, self.d_cell), jnp.float32)
        else:
            y0, c0 = state

        def body(carry, x_t):
            y, c = carry
            y, c = self.step(params, x_t, y, c.astype(jnp.float32))
            return (y, c), y

        (yT, cT), ys = jax.lax.scan(body, (y0, c0), jnp.moveaxis(xs, 1, 0))
        return jnp.moveaxis(ys, 0, 1), (yT, cT)
