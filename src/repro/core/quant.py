"""Fixed-point quantization simulation (paper §4.1).

The paper uses 12-bit (DCNN) / 16-bit (LSTM) fixed point for weights and
activations, verified with a bit-wise C++ simulator. TPUs have no 12-bit
datapath, so we *simulate*: fake-quantize to (bits, frac_bits) fixed point
with a clipped straight-through estimator (gradient passes only through the
representable range — saturated values absorb none) so the accuracy
benchmarks (§4.2 reproduction) can sweep bit widths.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

__all__ = ["fixed_point", "quantize_tree"]


def _rails(bits: int, frac_bits: int):
    """(lo, hi) representable range of signed (bits).(frac_bits) fixed point."""
    scale = float(2**frac_bits)
    return -(2 ** (bits - 1)) / scale, (2 ** (bits - 1) - 1) / scale


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def fixed_point(x: jax.Array, bits: int = 12, frac_bits: int = 8) -> jax.Array:
    """Round to signed (bits).(frac_bits) fixed point; clipped-STE gradient.

    The straight-through estimator passes the cotangent only where the
    forward did NOT saturate at the clip rails [lo, hi]: a weight pinned at
    the rail cannot express a step in the direction that pushed it there,
    so letting gradient through would silently accumulate updates the
    quantized forward never reflects (the classic STE-vs-clipped-STE bug —
    narrower bit widths saturate more weights and absorb more gradient).
    """
    scale = float(2**frac_bits)
    lo, hi = _rails(bits, frac_bits)
    q = jnp.round(x.astype(jnp.float32) * scale) / scale
    return jnp.clip(q, lo, hi).astype(x.dtype)


def _fq_fwd(x, bits, frac_bits):
    return fixed_point(x, bits, frac_bits), x


def _fq_bwd(bits, frac_bits, x, g):
    lo, hi = _rails(bits, frac_bits)
    inside = (x >= lo) & (x <= hi)
    return (jnp.where(inside, g, jnp.zeros_like(g)),)


fixed_point.defvjp(_fq_fwd, _fq_bwd)


def quantize_tree(params, bits: int = 12, frac_bits: int = 8):
    """Fake-quantize every floating leaf of a param tree."""
    def q(x):
        if jnp.issubdtype(x.dtype, jnp.floating):
            return fixed_point(x, bits, frac_bits)
        return x

    return jax.tree.map(q, params)
