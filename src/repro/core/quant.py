"""Fixed-point / int8 quantization (paper §4.1).

The paper uses 12-bit (DCNN) / 16-bit (LSTM) fixed point for weights and
activations, verified with a bit-wise C++ simulator. TPUs have no 12-bit
datapath, so we *simulate*: fake-quantize to (bits, frac_bits) fixed point
with a clipped straight-through estimator (gradient passes only through the
representable range — saturated values absorb none) so the accuracy
benchmarks (§4.2 reproduction) can sweep bit widths.

Two families live here:

* ``fixed_point`` / ``quantize_tree`` — fixed-point fake-quant with a GLOBAL
  (bits, frac_bits) grid, used for activation/weight simulation and QAT.
  ``quantize_tree`` handles complex leaves (frozen ``rfft(w)`` tables stored
  as complex64 fake-quantize through their re/im parts — previously they
  silently escaped the floating-dtype check) and takes an ``exempt``
  predicate for leaves whose dynamic range saturates at weight rails
  (biases, norm scales).
* ``symmetric_scales`` / ``quantize_symmetric`` / ``dequantize_symmetric`` /
  ``fake_quant_symmetric`` — symmetric per-block max-abs int8, the scheme
  ``dist.compress`` uses on gradients, applied to the frozen frequency
  tables: one f32 scale per (p, q) circulant block shared across the K
  frequency bins AND the re/im parts, int8 payload. The Pallas kernel
  dequantizes on the VMEM tile (``kernel._bc_kernel``); ``fake_quant_*`` is
  the bit-exact training-time / oracle counterpart (dequant(quant(x)) with a
  clipped-STE gradient), so in-kernel int8 dequant and the fake-quant dense
  oracle produce identical floats at the same (bits, scales).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

__all__ = [
    "fixed_point", "quantize_tree", "default_exempt",
    "symmetric_scales", "quantize_symmetric", "dequantize_symmetric",
    "fake_quant_symmetric",
]

# Symmetric scales are clamped away from zero so all-zero blocks (e.g. tile
# padding) round-trip to exact zeros instead of 0/0. Matches dist.compress.
_SCALE_FLOOR = 1e-30


def _rails(bits: int, frac_bits: int):
    """(lo, hi) representable range of signed (bits).(frac_bits) fixed point."""
    scale = float(2**frac_bits)
    return -(2 ** (bits - 1)) / scale, (2 ** (bits - 1) - 1) / scale


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def fixed_point(x: jax.Array, bits: int = 12, frac_bits: int = 8) -> jax.Array:
    """Round to signed (bits).(frac_bits) fixed point; clipped-STE gradient.

    The straight-through estimator passes the cotangent only where the
    forward did NOT saturate at the clip rails [lo, hi]: a weight pinned at
    the rail cannot express a step in the direction that pushed it there,
    so letting gradient through would silently accumulate updates the
    quantized forward never reflects (the classic STE-vs-clipped-STE bug —
    narrower bit widths saturate more weights and absorb more gradient).
    """
    scale = float(2**frac_bits)
    lo, hi = _rails(bits, frac_bits)
    q = jnp.round(x.astype(jnp.float32) * scale) / scale
    return jnp.clip(q, lo, hi).astype(x.dtype)


def _fq_fwd(x, bits, frac_bits):
    return fixed_point(x, bits, frac_bits), x


def _fq_bwd(bits, frac_bits, x, g):
    lo, hi = _rails(bits, frac_bits)
    inside = (x >= lo) & (x <= hi)
    return (jnp.where(inside, g, jnp.zeros_like(g)),)


fixed_point.defvjp(_fq_fwd, _fq_bwd)


def _path_names(path) -> tuple:
    """jax key path -> tuple of plain string key names."""
    out = []
    for p in path:
        out.append(str(getattr(p, "key", getattr(p, "name",
                                                 getattr(p, "idx", p)))))
    return tuple(out)


def default_exempt(path_names) -> bool:
    """Default QAT exemption: biases and norm scales.

    Their dynamic range is unrelated to the weight rails — a gemma-style
    RMSNorm scale or an LSTM gate bias saturating at the (bits, frac_bits)
    weight grid absorbs its entire gradient through the clipped STE, so the
    paper's fixed-point sweeps keep them full precision. Matches leaf keys
    named ``bias``/``scale``/``gamma``/``beta``, short b-prefixed bias keys
    (``b``, ``b0``, ``bi``…), and ``*_b``.
    """
    name = path_names[-1] if path_names else ""
    if name in ("bias", "scale", "w_scale", "gamma", "beta"):
        return True
    return (name.startswith("b") and len(name) <= 3) or name.endswith("_b")


def quantize_tree(params, bits: int = 12, frac_bits: int = 8, exempt=None):
    """Fake-quantize every floating AND complex leaf of a param tree.

    Complex leaves (frozen frequency tables stored as complex64) quantize
    through their re/im components — ``jnp.issubdtype(complex64, floating)``
    is False, so the old floating-only check silently skipped them and a
    "quantized" frozen tree was actually full precision. ``exempt`` is a
    predicate over the tuple of key names from the root (see
    :func:`default_exempt`); exempt leaves pass through untouched.
    """
    def q(path, x):
        if exempt is not None and exempt(_path_names(path)):
            return x
        if jnp.issubdtype(x.dtype, jnp.complexfloating):
            re = fixed_point(jnp.real(x), bits, frac_bits)
            im = fixed_point(jnp.imag(x), bits, frac_bits)
            return (re + 1j * im).astype(x.dtype)
        if jnp.issubdtype(x.dtype, jnp.floating):
            return fixed_point(x, bits, frac_bits)
        return x

    return jax.tree_util.tree_map_with_path(q, params)


# ---------------------------------------------------------------------------
# Symmetric per-block int8 (frozen frequency tables)
# ---------------------------------------------------------------------------


def _qmax(bits: int) -> float:
    return float(2 ** (bits - 1) - 1)


def symmetric_scales(wr: jax.Array, wi: jax.Array, bits: int = 8
                     ) -> jax.Array:
    """Per-block symmetric max-abs scale for an (…, p, q, K) re/im pair.

    One f32 scale per (p, q) circulant block, shared across the K frequency
    bins and both the re and im parts: ``s = max(|wr|, |wi|) / qmax``. The
    shared scale is what lets the kernel dequantize a (pt, qt, K) tile with
    a single (pt, qt, 1) broadcast multiply, and what makes fused-group
    concatenation commute with quantization (scales concatenate alongside
    tables block-for-block).
    """
    amax = jnp.maximum(jnp.max(jnp.abs(wr), axis=-1),
                       jnp.max(jnp.abs(wi), axis=-1))
    return jnp.maximum(amax.astype(jnp.float32) / _qmax(bits), _SCALE_FLOOR)


def quantize_symmetric(x: jax.Array, scale: jax.Array, bits: int = 8
                       ) -> jax.Array:
    """(…, p, q, K) f32 table -> int8 with per-(p, q) ``scale``."""
    if bits > 8:
        raise ValueError(f"int8 storage holds at most 8 bits, got {bits}")
    qm = _qmax(bits)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -qm, qm)
    return q.astype(jnp.int8)


def dequantize_symmetric(q: jax.Array, scale: jax.Array) -> jax.Array:
    """Inverse of :func:`quantize_symmetric`: ``q.astype(f32) * scale``.

    Exactly the expression ``kernel._bc_kernel`` evaluates on the VMEM tile
    — int8 -> f32 is exact and the broadcast multiply is the same float op,
    so host-side dequant + fp32 kernel is bit-identical to in-kernel dequant.
    """
    return q.astype(jnp.float32) * scale[..., None]


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _fq_sym(bits: int, x: jax.Array, scale: jax.Array) -> jax.Array:
    qm = _qmax(bits)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -qm, qm)
    return (q * scale[..., None]).astype(x.dtype)


def _fq_sym_fwd(bits, x, scale):
    return _fq_sym(bits, x, scale), (x, scale)


def _fq_sym_bwd(bits, res, g):
    x, scale = res
    lim = _qmax(bits) * scale[..., None]
    inside = (x >= -lim) & (x <= lim)
    return (jnp.where(inside, g, jnp.zeros_like(g)),
            jnp.zeros_like(scale))


_fq_sym.defvjp(_fq_sym_fwd, _fq_sym_bwd)


def fake_quant_symmetric(wr: jax.Array, wi: jax.Array, bits: int = 8):
    """QAT / oracle counterpart of the int8 freeze: ``(wr_fq, wi_fq, scale)``.

    Scales derive from the stop-gradiented pair (quantization grids don't
    backprop); the fake-quantized tables equal
    ``dequantize_symmetric(quantize_symmetric(w, s), s)`` bit for bit, with
    the clipped-STE gradient of :func:`fixed_point` (cotangent zero where
    the forward clipped at ±qmax·s — with max-abs scales nothing clips, so
    this matters only for externally supplied scales).
    """
    scale = symmetric_scales(jax.lax.stop_gradient(wr),
                             jax.lax.stop_gradient(wi), bits)
    return _fq_sym(bits, wr, scale), _fq_sym(bits, wi, scale), scale
