"""Fixed-point quantization simulation (paper §4.1).

The paper uses 12-bit (DCNN) / 16-bit (LSTM) fixed point for weights and
activations, verified with a bit-wise C++ simulator. TPUs have no 12-bit
datapath, so we *simulate*: fake-quantize to (bits, frac_bits) fixed point
with a straight-through estimator so the accuracy benchmarks (§4.2
reproduction) can sweep bit widths.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

__all__ = ["fixed_point", "quantize_tree"]


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def fixed_point(x: jax.Array, bits: int = 12, frac_bits: int = 8) -> jax.Array:
    """Round to signed (bits).(frac_bits) fixed point; STE gradient."""
    scale = float(2**frac_bits)
    lo = -(2 ** (bits - 1)) / scale
    hi = (2 ** (bits - 1) - 1) / scale
    q = jnp.round(x.astype(jnp.float32) * scale) / scale
    return jnp.clip(q, lo, hi).astype(x.dtype)


def _fq_fwd(x, bits, frac_bits):
    return fixed_point(x, bits, frac_bits), None


def _fq_bwd(bits, frac_bits, _, g):
    return (g,)


fixed_point.defvjp(_fq_fwd, _fq_bwd)


def quantize_tree(params, bits: int = 12, frac_bits: int = 8):
    """Fake-quantize every floating leaf of a param tree."""
    def q(x):
        if jnp.issubdtype(x.dtype, jnp.floating):
            return fixed_point(x, bits, frac_bits)
        return x

    return jax.tree.map(q, params)
