"""Block-circulant CONV layer (paper §3, CirCNN [5]).

The CONV tensor F ∈ R^{r×r×C×P} is made block-circulant over the channel
dims: for every spatial tap (i, j), the C×P matrix F(i,j,·,·) is partitioned
into k×k circulant blocks. The layer is computed as an im2col GEMM whose
weight is block-circulant over channels — one fused frequency-domain
contraction across (taps × input-channel blocks):

    ŷ[n, p, f] = Σ_{t, j} ŵ[t, p, j, f] ∘ x̂[n, t, j, f]

Storage: r²·C·P/k instead of r²·C·P. Compute: r²·(C/k)·(P/k)·O(k log k)·HW.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.nn.module import ParamSpec

__all__ = ["CirculantConv2D"]


@dataclasses.dataclass(frozen=True)
class CirculantConv2D:
    in_ch: int
    out_ch: int
    ksize: int = 3
    block_size: int = 1          # k; 1 = dense conv
    dtype: str = "float32"

    @property
    def k(self) -> int:
        from repro.core.circulant import valid_block_size

        if self.block_size <= 1:
            return 1
        return valid_block_size(self.block_size, self.in_ch, self.out_ch)

    def specs(self):
        r, C, P, k = self.ksize, self.in_ch, self.out_ch, self.k
        if k > 1:
            w = ParamSpec((r * r, P // k, C // k, k), jnp.dtype(self.dtype),
                          (None, None, None, None), init="normal",
                          scale=(r * r * C) ** -0.5)
        else:
            w = ParamSpec((r * r, C, P), jnp.dtype(self.dtype),
                          (None, None, None), init="normal",
                          scale=(r * r * C) ** -0.5)
        return {"w": w, "b": ParamSpec((P,), jnp.float32, (None,),
                                       init="zeros")}

    def __call__(self, params, x: jax.Array) -> jax.Array:
        """x (B, H, W, C) -> (B, H-r+1, W-r+1, P), VALID padding."""
        r, C, P, k = self.ksize, self.in_ch, self.out_ch, self.k
        B, H, W, _ = x.shape
        Ho, Wo = H - r + 1, W - r + 1
        # im2col: (B, Ho, Wo, r*r, C)
        patches = jnp.stack(
            [x[:, i : i + Ho, j : j + Wo, :] for i in range(r) for j in range(r)],
            axis=3,
        )
        w = params["w"]
        if k == 1:
            y = jnp.einsum("bhwtc,tcp->bhwp", patches, w.astype(x.dtype))
        else:
            q = C // k
            xb = patches.reshape(B, Ho, Wo, r * r, q, k)
            xh = jnp.fft.rfft(xb.astype(jnp.float32), axis=-1)
            wh = jnp.fft.rfft(w.astype(jnp.float32), axis=-1)  # (t, p, q, K)
            yh = jnp.einsum("bhwtqf,tpqf->bhwpf", xh, wh)
            y = jnp.fft.irfft(yh, n=k, axis=-1).reshape(B, Ho, Wo, P)
            y = y.astype(x.dtype)
        return y + params["b"].astype(y.dtype)
