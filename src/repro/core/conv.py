"""Block-circulant CONV layer (paper §3, CirCNN [5]).

The CONV tensor F ∈ R^{r×r×C×P} is made block-circulant over the channel
dims: for every spatial tap (i, j), the C×P matrix F(i,j,·,·) is partitioned
into k×k circulant blocks. The layer is computed as an im2col GEMM whose
weight is block-circulant over channels — one fused frequency-domain
contraction across (taps × input-channel blocks):

    ŷ[n, p, f] = Σ_{t, j} ŵ[t, p, j, f] ∘ x̂[n, t, j, f]

Storage: r²·C·P/k instead of r²·C·P. Compute: r²·(C/k)·(P/k)·O(k log k)·HW.

Execution shares the block-circulant Linear machinery end to end: the
(t, p, q, k) tap table reshapes to ONE (p, r²·q, k) block table — every
(tap, input-block) pair is a circulant block of the im2col GEMM — and runs
through ``kernels.block_circulant.ops.block_circulant_matmul``: the Pallas
kernel (bias fused into the epilogue), the frozen frequency-weight path
(``plan.freeze_params`` tags the table ``circulant`` and attaches
``wr``/``wi``; serving never re-rffts it), tile choice / ``vmem_estimate``,
and the transposed-geometry training adjoint (kernel-backed dw) all apply
to conv exactly as to Linear. Patch extraction is a single strided gather
(no Python tap loop), differentiable for the dx scatter-back.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.nn.module import ParamSpec

__all__ = ["CirculantConv2D", "extract_patches"]


def extract_patches(x: jax.Array, r: int) -> jax.Array:
    """x (B, H, W, C) -> im2col patches (B, Ho, Wo, r·r, C), VALID padding.

    One strided gather pair (rows then cols) instead of an r² Python loop of
    sliced copies; tap order is (i·r + j) — i (row offset) major — matching
    the layout the tap-table reshape in :class:`CirculantConv2D` assumes.
    Values are pure copies: bit-identical to the loop-of-slices im2col.
    """
    B, H, W, C = x.shape
    if H < r or W < r:
        raise ValueError(
            f"conv input spatial dims ({H}, {W}) are smaller than "
            f"ksize={r}: VALID padding would produce empty output; pad the "
            f"input or reduce ksize"
        )
    Ho, Wo = H - r + 1, W - r + 1
    rows = x[:, jnp.arange(r)[:, None] + jnp.arange(Ho)[None, :]]
    # rows: (B, r, Ho, W, C); gather cols the same way
    patches = rows[:, :, :, jnp.arange(r)[:, None] + jnp.arange(Wo)[None, :]]
    # (B, r, Ho, r, Wo, C) -> (B, Ho, Wo, r, r, C) -> (B, Ho, Wo, r·r, C)
    patches = jnp.transpose(patches, (0, 2, 4, 1, 3, 5))
    return patches.reshape(B, Ho, Wo, r * r, C)


@dataclasses.dataclass(frozen=True)
class CirculantConv2D:
    in_ch: int
    out_ch: int
    ksize: int = 3
    block_size: int = 1          # k; 1 = dense conv
    dtype: str = "float32"

    @property
    def k(self) -> int:
        from repro.core.circulant import valid_block_size

        if self.block_size <= 1:
            return 1
        return valid_block_size(self.block_size, self.in_ch, self.out_ch)

    def specs(self):
        r, C, P, k = self.ksize, self.in_ch, self.out_ch, self.k
        if k > 1:
            # tagged "circulant" so plan.freeze_params swaps the tap table
            # for its frozen rfft (wr, wi) at serve time, like nn.Linear;
            # "conv_taps" makes the freeze store them pre-reshaped in the
            # (p, r²·q, K) im2col block-table layout the kernel consumes
            w = ParamSpec((r * r, P // k, C // k, k), jnp.dtype(self.dtype),
                          (None, None, None, None), init="normal",
                          scale=(r * r * C) ** -0.5,
                          tags=("circulant", "conv_taps"))
        else:
            w = ParamSpec((r * r, C, P), jnp.dtype(self.dtype),
                          (None, None, None), init="normal",
                          scale=(r * r * C) ** -0.5)
        return {"w": w, "b": ParamSpec((P,), jnp.float32, (None,),
                                       init="zeros")}

    def __call__(self, params, x: jax.Array) -> jax.Array:
        """x (B, H, W, C) -> (B, H-r+1, W-r+1, P), VALID padding."""
        r, C, P, k = self.ksize, self.in_ch, self.out_ch, self.k
        B = x.shape[0]
        patches = extract_patches(x, r)            # (B, Ho, Wo, r·r, C)
        Ho, Wo = patches.shape[1], patches.shape[2]
        if k == 1:
            w = params["w"]
            y = jnp.einsum("bhwtc,tcp->bhwp", patches, w.astype(x.dtype))
            return y + params["b"].astype(y.dtype)
        from repro.kernels.block_circulant import ops as bc_ops

        p, q = P // k, C // k
        x2d = patches.reshape(B * Ho * Wo, r * r * C)
        w_bc, w_freq, w_scale = None, None, None
        if "wr" in params and "wi" in params:
            # frozen tables: freeze_params already stored them in the
            # (p, r²·q, K) block-table layout — no weight-side work here
            # (w_scale rides along when the tables are int8)
            w_freq = (params["wr"], params["wi"])
            w_scale = params.get("w_scale")
        else:
            # (t, p, q, k) tap table -> ONE (p, r²·q, k) block table whose
            # block index is t·q + j, matching the patch layout's (t, c)
            w_bc = params["w"].transpose(1, 0, 2, 3).reshape(p, r * r * q, k)
        y = bc_ops.block_circulant_matmul(
            x2d, w_bc, bias=params["b"], w_freq=w_freq, w_scale=w_scale,
            k=k, q=r * r * q,
        )
        return y.reshape(B, Ho, Wo, P)
