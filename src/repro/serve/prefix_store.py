"""Host-memory prefix store: evicted donor rows outlive the engine.

The engine's prefix index (``ServeEngine._prefix_index``) is a map from
block-aligned prompt heads to *resident* slot rows — it dies with the
engine, and a donor evicted to make room (slot reassigned, pad-lane
borrow, prewarm flush) is simply forgotten. For the serving shape the
ROADMAP targets (many tenants sharing system-prompt heads, engines that
die and self-heal, replicas that start cold) that forgetting is the
dominant cold-start cost: every replacement engine re-prefills the same
hot prompt heads from scratch.

:class:`PrefixStore` is the spill target: a host-memory LRU bounded by
``capacity_bytes`` holding, per stored prompt, the full gathered state
rows of the donor slot (host numpy — device buffers are never retained,
so the store survives the engine that filled it). The engine spills into
it at eviction time (``ServeEngine._index_drop_slot``) and a fresh or
restored engine *adopts* the hottest entries back into free slots
(``ServeEngine.adopt_prefixes``), re-registering them in its prefix
index so the next admission round matches against warm rows instead of
cold-prefilling.

Crash safety rides the existing ``ft.checkpoint`` atomics: ``save()``
writes the whole store as one checkpoint step (tmp dir + fsync + rename
+ atomic LATEST pointer), ``load()`` reads the latest — a crash mid-save
never leaves a half-written store visible. The store is engine-agnostic
but geometry-checked: entries carry the fingerprint of the runner/state
geometry that produced them, and adopting against a different geometry
raises instead of silently placing mismatched rows.
"""

from __future__ import annotations

import json
from collections import OrderedDict
from typing import Dict, Iterator, Optional, Tuple

import numpy as np

from repro.ft.checkpoint import (latest_step, restore_checkpoint,
                                 save_checkpoint)

__all__ = ["PrefixStore"]


def _entry_nbytes(prompt: np.ndarray, rows: Dict[str, np.ndarray]) -> int:
    return int(prompt.nbytes) + int(sum(a.nbytes for a in rows.values()))


class PrefixStore:
    """LRU-bounded host store of ``{prompt -> donor state rows}``.

    ``rows`` is the flat leaf dict produced by
    ``guard.flatten_state_tree`` over a single-slot ``gather_state`` —
    one row per leaf, host numpy. Entries are keyed by the full resident
    prompt (the engine re-derives every block-aligned prefix at adoption
    time via ``_index_insert``); recency is bumped on both ``put`` and
    ``hottest`` iteration consumption, so the adoption order is
    most-recently-useful first.

    ``fingerprint`` pins the state geometry (runner class + cache_len +
    leaf shapes); ``put``/``adopt`` against a different geometry raises.
    """

    def __init__(self, capacity_bytes: int = 64 << 20,
                 persist_dir: Optional[str] = None):
        if int(capacity_bytes) < 1:
            raise ValueError(
                f"capacity_bytes must be >= 1, got {capacity_bytes}")
        self.capacity_bytes = int(capacity_bytes)
        self.persist_dir = persist_dir
        self.fingerprint: Optional[str] = None
        self._entries: "OrderedDict[bytes, Tuple[np.ndarray, Dict[str, np.ndarray]]]" = OrderedDict()
        self._nbytes = 0
        self.spills = 0          # accepted puts
        self.evictions = 0       # LRU-evicted entries (capacity pressure)

    # -- core ---------------------------------------------------------------
    def __len__(self) -> int:
        return len(self._entries)

    @property
    def nbytes(self) -> int:
        return self._nbytes

    def _check_fingerprint(self, fingerprint: str, op: str) -> None:
        if self.fingerprint is None:
            self.fingerprint = str(fingerprint)
        elif self.fingerprint != str(fingerprint):
            raise ValueError(
                f"prefix store {op} geometry mismatch: store holds rows "
                f"for {self.fingerprint!r}, engine is "
                f"{str(fingerprint)!r} — a store is only shareable "
                f"between identically-configured engines")

    def put(self, prompt: np.ndarray, rows: Dict[str, np.ndarray],
            fingerprint: str) -> bool:
        """Spill one donor's rows. Returns False (and stores nothing) for
        an entry that alone exceeds the byte budget; otherwise inserts,
        bumps recency, and LRU-evicts colder entries down to capacity."""
        self._check_fingerprint(fingerprint, "put")
        prompt = np.ascontiguousarray(np.asarray(prompt, np.int32)
                                      .reshape(-1))
        rows = {str(k): np.asarray(v) for k, v in rows.items()}
        nb = _entry_nbytes(prompt, rows)
        if nb > self.capacity_bytes:
            return False
        key = prompt.tobytes()
        old = self._entries.pop(key, None)
        if old is not None:
            self._nbytes -= _entry_nbytes(old[0], old[1])
        self._entries[key] = (prompt, rows)
        self._nbytes += nb
        self.spills += 1
        while self._nbytes > self.capacity_bytes:
            _, (p, r) = self._entries.popitem(last=False)
            self._nbytes -= _entry_nbytes(p, r)
            self.evictions += 1
        return True

    def hottest(self) -> Iterator[Tuple[np.ndarray, Dict[str, np.ndarray]]]:
        """Yield ``(prompt, rows)`` most-recently-used first (adoption
        order). Snapshots the order up front so the consumer may ``put``
        or touch entries while iterating."""
        for key in list(reversed(self._entries)):
            e = self._entries.get(key)
            if e is not None:
                yield e

    def touch(self, prompt: np.ndarray) -> bool:
        """Bump an entry's recency (an adopted entry is hot). Returns
        whether the entry exists."""
        key = (np.asarray(prompt, np.int32).reshape(-1)).tobytes()
        if key in self._entries:
            self._entries.move_to_end(key)
            return True
        return False

    # -- persistence (ft.checkpoint atomics) --------------------------------
    def save(self, step: int = 0) -> str:
        """Persist the whole store as one atomic checkpoint step under
        ``persist_dir`` (tmp + rename + LATEST pointer — crash-safe).
        Entries are written coldest-first so ``load`` rebuilds the exact
        LRU order."""
        if self.persist_dir is None:
            raise ValueError("save() needs persist_dir")
        meta = {
            "version": 1,
            "fingerprint": self.fingerprint,
            "capacity_bytes": self.capacity_bytes,
            "prompts": [],
            "row_keys": [],
        }
        state: Dict[str, object] = {}
        for i, (prompt, rows) in enumerate(self._entries.values()):
            meta["prompts"].append(prompt.tolist())
            meta["row_keys"].append(sorted(rows))
            state[f"e{i:05d}"] = dict(rows)
        state["meta"] = np.frombuffer(json.dumps(meta).encode("utf-8"),
                                      np.uint8)
        return save_checkpoint(self.persist_dir, int(step), state)

    @classmethod
    def load(cls, persist_dir: str,
             capacity_bytes: Optional[int] = None) -> "PrefixStore":
        """Rebuild a store from the latest persisted step (empty store if
        none exists yet). ``capacity_bytes`` overrides the persisted
        budget (loading a big store into a smaller budget LRU-evicts the
        coldest entries immediately)."""
        step = latest_step(persist_dir)
        if step is None:
            return cls(capacity_bytes=capacity_bytes or (64 << 20),
                       persist_dir=persist_dir)
        state = restore_checkpoint(persist_dir, int(step))
        meta = json.loads(bytes(np.asarray(state["meta"])).decode("utf-8"))
        if int(meta.get("version", 0)) != 1:
            raise ValueError(
                f"prefix store at {persist_dir} has format version "
                f"{meta.get('version')!r}; this build reads version 1")
        store = cls(capacity_bytes=capacity_bytes
                    or int(meta["capacity_bytes"]),
                    persist_dir=persist_dir)
        store.fingerprint = meta["fingerprint"]
        for i, (prompt, keys) in enumerate(zip(meta["prompts"],
                                               meta["row_keys"])):
            rows = {k: np.asarray(state[f"e{i:05d}"][k]) for k in keys}
            store.put(np.asarray(prompt, np.int32), rows,
                      store.fingerprint)
        store.spills = 0       # loading is not spilling
        return store

    def as_dict(self) -> Dict[str, object]:
        return {
            "entries": len(self._entries),
            "nbytes": self._nbytes,
            "capacity_bytes": self.capacity_bytes,
            "spills": self.spills,
            "evictions": self.evictions,
        }
