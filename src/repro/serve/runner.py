"""ModelRunner — the protocol between :class:`ServeEngine` and a model.

The engine schedules requests, buckets launch shapes, owns the prefix
index, and snapshots host state; everything model-shaped lives behind a
runner. A runner owns the device *state tree* (KV caches, recurrent
state, cross-attention KV — whatever the family persists per slot) and
exposes exactly the operations the engine composes:

* ``init_state(batch)`` — a fresh state tree with one row per slot;
* ``prefill(params, tokens, positions, state, slot_idx, ...)`` — run a
  bucket-shaped prompt group and scatter its rows into the slot state at
  ``slot_idx``; returns ``(last_logits, ok, placed_state)``. The state is
  positional argument 3 so the engine can donate it
  (``donate_argnums=(3,)``);
* ``decode(params, tokens, state, pos, slot_idx)`` — gather the rows
  named by ``slot_idx``, decode one token, scatter back; returns
  ``(logits, ok, placed_state)``. State is positional argument 2
  (``donate_argnums=(2,)``);
* ``gather_state`` / ``place_state`` / ``reset_rows`` — row-level state
  surgery (slot compaction, scrubbing poisoned slots, restore).

**Pad contract.** Prefill buckets are LEFT-padded: real tokens sit
rightmost, pad lanes carry negative positions. A runner must guarantee
pad lanes contribute *exactly nothing* — attention masks ``kv_pos < 0``,
recurrent mixers are handed a ``positions >= 0`` validity mask (segment
mask) so pads never enter token shifts, conv windows, or state updates.
The engine asserts nothing about how; it only relies on bucket-shape
invariance: the same request must produce bit-identical tokens at any
bucket shape, including the unbucketed B=1 loop.

**State-tree shape rules.** The state tree is an arbitrary pytree whose
leaves each carry a slot axis. ``gather_state``/``place_state``/
``reset_rows`` are the only code that knows which axis that is (axis 0
for plain decoder groups, axis 1 for repeat-stacked groups and the
enc-dec layer-stacked leaves). Snapshot/restore never inspects the tree:
it flattens leaves generically (``serve.guard.flatten_state_tree``) and
restores against ``init_state``'s structure and dtypes.

**Capability flags.** ``supports_prefix_cache`` declares whether state
rows are position-sliceable (a donor's rows for positions ``[0, m)`` can
seed another request). Full-length KV caches are; recurrent state is not
(a single state vector encodes the whole prompt — there are no
per-position rows to copy), nor are short local-attention rings (donor
rows past the window are overwritten). ``prefix_cache_unsupported_reason``
carries the actionable message the engine raises. ``min_cache_len``
bounds ``cache_len`` from below; ``requires_extra`` marks families whose
requests carry per-request conditioning (the enc-dec encoder frames).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig

__all__ = ["ModelRunner", "DecoderRunner", "RecurrentRunner",
           "EncDecRunner", "make_runner", "recurrent_mixer_names"]


def recurrent_mixer_names(cfg: ModelConfig) -> Tuple[str, ...]:
    """Sorted unique recurrent mixer kinds ('mamba'/'rwkv') in ``cfg`` —
    empty for pure-attention decoder families."""
    if cfg.family == "encdec":
        return ()
    names = {lspec.mixer for group in cfg.layer_groups()
             for lspec in group.layers if lspec.mixer in ("mamba", "rwkv")}
    return tuple(sorted(names))


class ModelRunner:
    """Base runner: holds the model/config and declares the capability
    flags; subclasses implement the device-side protocol."""

    #: whether state rows are position-sliceable (prefix-cache donors)
    supports_prefix_cache: bool = False
    #: actionable message raised when prefix_cache=True is requested
    prefix_cache_unsupported_reason: str = ""
    #: smallest servable cache_len
    min_cache_len: int = 1
    #: whether requests must carry per-request conditioning (Request.extra)
    requires_extra: bool = False

    def __init__(self, model, cfg: ModelConfig, cache_len: int):
        self.model = model
        self.cfg = cfg
        self.cache_len = int(cache_len)

    def specs(self):
        return self.model.specs()

    # -- device-side protocol (see module docstring) ---------------------
    def init_state(self, batch: int):
        raise NotImplementedError

    def prefill(self, params, tokens, positions, state, slot_idx,
                donor_idx=None, match_len=None, extra=None):
        raise NotImplementedError

    def decode(self, params, tokens, state, pos, slot_idx):
        raise NotImplementedError

    def gather_state(self, state, idx):
        raise NotImplementedError

    def place_state(self, state, sub, idx):
        raise NotImplementedError

    def reset_rows(self, state, idx):
        """Overwrite the rows named by ``idx`` with fresh (blank) rows."""
        blank = self.init_state(int(idx.shape[0]))
        return self.place_state(state, blank, idx)

    # -- host-side hooks -------------------------------------------------
    def prewarm_extra(self, batch: int):
        """Placeholder ``extra`` for prewarm launches (families with
        ``requires_extra``); None otherwise."""
        return None

    def validate_request(self, r) -> None:
        """Family-specific admission checks beyond the engine's shared
        length/budget contract."""
        if getattr(r, "extra", None) is not None:
            raise ValueError(
                f"request carries extra conditioning but "
                f"{type(self).__name__} serves a decoder-only family that "
                f"takes none (drop Request.extra, or serve an enc-dec "
                f"config)")


class DecoderRunner(ModelRunner):
    """Runner over :class:`HybridDecoderLM` — the pre-refactor engine
    device path, verbatim (the refactor's bit-identity oracle).

    The state tree is the model's cache: a list with one dict per layer
    group; leaves carry the slot axis at 0 (plain groups) or 1
    (repeat-stacked groups, leading scan axis). ``moe_no_drop=True`` is
    passed on every forward so MoE configs dispatch without capacity
    drops (batch- and pad-invariant; see :class:`repro.nn.moe.MoE`).
    """

    def __init__(self, model, cfg: ModelConfig, cache_len: int):
        super().__init__(model, cfg, cache_len)
        self._repeat_axes = tuple(
            1 if g.repeat > 1 else 0 for g in cfg.layer_groups()
        )
        self.supports_prefix_cache = True
        from repro.models.decoder import local_attn_cache_len
        for group in cfg.layer_groups():
            for lspec in group.layers:
                if lspec.mixer == "attn_local":
                    ring = local_attn_cache_len(cfg, self.cache_len)
                    if ring < self.cache_len:
                        self.supports_prefix_cache = False
                        self.prefix_cache_unsupported_reason = (
                            f"prefix_cache needs full-length KV caches, but "
                            f"'attn_local' layers keep a ring of {ring} < "
                            f"cache_len={self.cache_len} entries: donor rows "
                            f"past the window are overwritten and the shared "
                            f"head cannot be copied")

    def init_state(self, batch: int):
        return self.model.init_cache(batch, self.cache_len)

    def prefill(self, params, tokens, positions, state, slot_idx,
                donor_idx=None, match_len=None, extra=None):
        """Prefill a bucket-shaped group, then scatter its rows into the
        persistent slot state at ``slot_idx``.

        Without ``donor_idx`` the group starts from fresh (empty) rows.
        With it (the prefix-cache path), row ``j`` starts from a copy of
        slot ``donor_idx[j]``'s rows with every entry at position
        ``>= match_len[j]`` masked out — the shared prompt head is copied,
        not recomputed, and ``tokens``/``positions`` carry only the
        unmatched tail. A missing match passes the row's own slot with
        ``match_len 0`` (fully-masked seed == fresh rows, bit-identical:
        masked entries contribute exactly zero to attention).

        Returns ``(last_logits, ok, placed_state)``: ``ok[j]`` is a
        device-side per-row finiteness flag (all logits finite) — the
        error-isolation guard rides in this executable's epilogue instead
        of costing a separate compile."""
        B = tokens.shape[0]
        if donor_idx is None:
            fresh = self.init_state(B)
        else:
            fresh = self._seed_state(state, donor_idx, match_len)
        logits, filled, _ = self.model.forward(
            params, tokens, positions=positions, cache=fresh,
            logits_mode="last", moe_no_drop=True,
        )
        last = logits[:, -1]
        ok = jnp.isfinite(last).all(axis=-1)
        return last, ok, self.place_state(state, filled, slot_idx)

    def _seed_state(self, state, donor_idx, match_len):
        """Bucket-shaped state seeded from donor slot rows: entries at
        positions ``>= match_len`` (donor tail/decode rows and donor pads)
        get ``pos -> -1`` so only the matched head survives the attention
        mask. k/v values past the match are left in place — masked lanes
        contribute exactly zero, so they never reach the output."""
        sub = self.gather_state(state, donor_idx)
        out = []
        for axis, g in zip(self._repeat_axes, sub):
            m = match_len[:, None] if axis == 0 else match_len[None, :, None]

            def seed(d, m=m):
                return {
                    name: (jnp.where(leaf < m, leaf, -1)
                           if name == "pos" else leaf)
                    for name, leaf in d.items()
                }

            out.append({name: seed(layer) for name, layer in g.items()})
        return out

    def decode(self, params, tokens, state, pos, slot_idx):
        """Gather the slot rows named by ``slot_idx`` into a bucket-shaped
        sub-batch, decode one token there, then scatter the updated rows
        back into the persistent slot state. ``tokens (Bb, 1)``, ``pos
        (Bb,)``, ``slot_idx (Bb,)`` — a pure permutation of rows, so the
        per-slot math is identical to full-slot decode.

        Returns ``(logits, ok, placed_state)`` — ``ok`` is the same
        per-row finiteness flag as ``prefill`` (no extra executable)."""
        sub = self.gather_state(state, slot_idx)
        logits, new_sub = self.model.decode_step(params, tokens, sub, pos,
                                                 moe_no_drop=True)
        ok = jnp.isfinite(logits).all(axis=-1)
        return logits, ok, self.place_state(state, new_sub, slot_idx)

    def gather_state(self, src, idx):
        """Gather slot rows into a sub-batch state (inverse of
        ``place_state``); slot axis 0 plain, 1 repeat-stacked."""
        out = []
        for axis, s_g in zip(self._repeat_axes, src):
            def take(s, axis=axis):
                return s[idx] if axis == 0 else s[:, idx]
            out.append(jax.tree.map(take, s_g))
        return out

    def place_state(self, dst, src, idx):
        """Scatter per-request state rows into slot rows. The slot axis is
        0 for plain groups and 1 for repeat-stacked groups (leading scan
        axis) — mirroring ``model.init_cache``."""
        out = []
        for axis, d_g, s_g in zip(self._repeat_axes, dst, src):
            def put(d, s, axis=axis):
                s = s.astype(d.dtype)
                return (d.at[idx].set(s) if axis == 0
                        else d.at[:, idx].set(s))
            out.append(jax.tree.map(put, d_g, s_g))
        return out


class RecurrentRunner(DecoderRunner):
    """Runner for decoder families with recurrent mixers (rwkv6, mamba,
    jamba hybrids). The device path is :class:`DecoderRunner`'s — pad
    invariance lives in the model: the ``positions >= 0`` validity mask
    computed by ``HybridDecoderLM.forward`` keeps left-pad lanes out of
    token shifts, conv windows, and state updates, so bucketed prefill is
    bit-identical to the unbucketed B=1 loop.

    Recurrent state is NOT position-sliceable: one state vector per slot
    encodes the whole prompt, so there are no per-position rows a prefix
    donor could contribute. The capability flag keeps the engine from
    indexing prompts or seeding from donors."""

    def __init__(self, model, cfg: ModelConfig, cache_len: int):
        super().__init__(model, cfg, cache_len)
        mix = recurrent_mixer_names(cfg)
        self.supports_prefix_cache = False
        self.prefix_cache_unsupported_reason = (
            f"prefix reuse copies per-position donor rows, but "
            f"{'/'.join(mix)} layers hold recurrent state with no "
            f"per-position rows to slice — a donor's state encodes its "
            f"entire prompt (serve this family with prefix_cache=False)")


class EncDecRunner(ModelRunner):
    """Runner over :class:`EncDecLM` (seamless-m4t). Requests carry the
    encoder frames as ``Request.extra`` (shape ``(enc_len, d_model)``);
    the encoder runs inside the prefill executable at admission, and the
    resulting cross-attention KV lives in the state tree alongside the
    decoder self-attention cache — decode steps read it back without ever
    re-running the encoder.

    State tree: ``{"self": ..., "cross": ...}`` with every leaf stacked
    on a leading layer axis, so the slot axis is 1 uniformly."""

    requires_extra = True

    def __init__(self, model, cfg: ModelConfig, cache_len: int):
        super().__init__(model, cfg, cache_len)
        self.enc_len = int(cfg.enc_seq or cache_len)
        self.supports_prefix_cache = False
        self.prefix_cache_unsupported_reason = (
            "enc-dec cross-attention state is computed per request from "
            "its encoder frames; donor rows cannot stand in for another "
            "request's conditioning (serve with prefix_cache=False)")

    def init_state(self, batch: int):
        return self.model.init_cache(batch, self.cache_len)

    def prefill(self, params, tokens, positions, state, slot_idx,
                donor_idx=None, match_len=None, extra=None):
        """``extra (Bb, enc_len, d_model)`` are the stacked encoder frames
        for the admitted chunk; the encoder pass runs here, once per
        request, and its cross-KV is scattered into the slot state with
        the rest of the rows."""
        B = tokens.shape[0]
        fresh = self.init_state(B)
        logits, filled, _ = self.model.forward(
            params, extra, tokens, cache=fresh, logits_mode="last",
            positions=positions,
        )
        last = logits[:, -1]
        ok = jnp.isfinite(last).all(axis=-1)
        return last, ok, self.place_state(state, filled, slot_idx)

    def decode(self, params, tokens, state, pos, slot_idx):
        sub = self.gather_state(state, slot_idx)
        logits, new_sub = self.model.decode_step(params, tokens, sub, pos)
        ok = jnp.isfinite(logits).all(axis=-1)
        return logits, ok, self.place_state(state, new_sub, slot_idx)

    def gather_state(self, state, idx):
        return jax.tree.map(lambda s: s[:, idx], state)

    def place_state(self, dst, src, idx):
        return jax.tree.map(
            lambda d, s: d.at[:, idx].set(s.astype(d.dtype)), dst, src)

    def prewarm_extra(self, batch: int):
        """Zero frames: prewarm launches run the encoder on silence —
        well-defined, finite, and scattered onto rows that the next real
        admission overwrites."""
        return jnp.zeros((batch, self.enc_len, self.cfg.d_model),
                         jnp.float32)

    def validate_request(self, r) -> None:
        extra = getattr(r, "extra", None)
        if extra is None:
            raise ValueError(
                f"enc-dec serving needs encoder frames per request: set "
                f"Request.extra to an ({self.enc_len}, {self.cfg.d_model}) "
                f"array of frame embeddings")
        a = np.asarray(extra)
        if a.shape != (self.enc_len, self.cfg.d_model):
            raise ValueError(
                f"Request.extra has shape {a.shape}, expected "
                f"({self.enc_len}, {self.cfg.d_model}) "
                f"(enc_seq x d_model for this config)")


def make_runner(model, cfg: ModelConfig, cache_len: int) -> ModelRunner:
    """Pick the runner for a config: enc-dec family -> EncDecRunner,
    recurrent mixers present -> RecurrentRunner, else DecoderRunner."""
    if cfg.family == "encdec":
        return EncDecRunner(model, cfg, cache_len)
    if recurrent_mixer_names(cfg):
        return RecurrentRunner(model, cfg, cache_len)
    return DecoderRunner(model, cfg, cache_len)
