"""Serving: prefill / decode step builders and a batched request engine.

``make_prefill_step`` / ``make_decode_step`` produce the jittable functions
that the dry-run lowers for the ``prefill_*`` and ``decode_*`` / ``long_*``
shape cells. ``ServeEngine`` is a minimal continuous-batching driver used by
the serving example: fixed batch slots, greedy sampling, per-slot stop.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig

__all__ = ["make_prefill_step", "make_decode_step", "ServeEngine"]


def make_prefill_step(model, cfg: ModelConfig):
    def prefill_step(params, tokens, cache, extra=None):
        """tokens (B, S) -> (last logits (B, V), filled cache)."""
        kwargs = {}
        if cfg.family == "vlm" and extra is not None:
            kwargs["img_embeds"] = extra
        if cfg.family == "encdec":
            logits, new_cache, _ = model.forward(
                params, extra, tokens, cache=cache, logits_mode="last"
            )
            return logits[:, -1], new_cache
        logits, new_cache, _ = model.forward(
            params, tokens, cache=cache, logits_mode="last", **kwargs
        )
        return logits[:, -1], new_cache

    return prefill_step


def make_decode_step(model, cfg: ModelConfig):
    def decode_step(params, tokens, cache, pos):
        """tokens (B, 1), pos (B,) -> (logits (B, V), cache)."""
        return model.decode_step(params, tokens, cache, pos)

    return decode_step


@dataclasses.dataclass
class Request:
    prompt: np.ndarray
    max_new: int = 16
    out: Optional[List[int]] = None


class ServeEngine:
    """Fixed-slot continuous batching: each slot independently prefills and
    decodes; finished slots accept the next queued request.

    At construction the engine **freezes the frequency-domain weights**:
    every circulant table gets its rfft precomputed once
    (``kernels.block_circulant.plan.freeze_params``) so the jitted prefill /
    decode steps contain no ``rfft(w)`` — the paper's inference dataflow
    (FFT(w) resident in BRAM, only activations stream through transforms).
    """

    def __init__(self, model, cfg: ModelConfig, params, batch: int,
                 cache_len: int):
        if cfg.swm.enabled:
            from repro.kernels.block_circulant.plan import freeze_params

            params = freeze_params(model.specs(), params)
        self.model, self.cfg, self.params = model, cfg, params
        self.batch, self.cache_len = batch, cache_len
        self.prefill = jax.jit(make_prefill_step(model, cfg))
        self.decode = jax.jit(make_decode_step(model, cfg))

    def generate(self, requests: List[Request]) -> List[List[int]]:
        """Greedy-decode a list of requests in batched waves."""
        results = []
        for i in range(0, len(requests), self.batch):
            wave = requests[i : i + self.batch]
            results.extend(self._run_wave(wave))
        return results

    def _run_wave(self, wave: List[Request]) -> List[List[int]]:
        B = self.batch
        plen = max(len(r.prompt) for r in wave)
        toks = np.zeros((B, plen), np.int32)
        for j, r in enumerate(wave):
            toks[j, plen - len(r.prompt):] = r.prompt    # left-pad
        cache = self.model.init_cache(B, self.cache_len)
        logits, cache = self.prefill(self.params, jnp.asarray(toks), cache)
        outs = [[] for _ in wave]
        cur = jnp.argmax(logits, -1).astype(jnp.int32)
        max_new = max(r.max_new for r in wave)
        for t in range(max_new):
            for j, r in enumerate(wave):
                if t < r.max_new:
                    outs[j].append(int(cur[j]))
            pos = jnp.full((B,), plen + t, jnp.int32)
            logits, cache = self.decode(
                self.params, cur[:, None], cache, pos
            )
            cur = jnp.argmax(logits, -1).astype(jnp.int32)
        return outs
