"""Serving: plan-aware continuous batching with bucketed shapes.

``make_prefill_step`` / ``make_decode_step`` produce the jittable functions
that the dry-run lowers for the ``prefill_*`` and ``decode_*`` / ``long_*``
shape cells. ``ServeEngine`` is the production driver; ``WaveEngine`` is the
fixed-wave baseline it replaced (kept for benchmarking and equivalence
tests — see ``benchmarks/serve_bench.py``).

Serving model (the paper's §5 inference dataflow, engine-level)
---------------------------------------------------------------

The paper keeps ``FFT(w)`` resident in BRAM and streams only activations
through the FFT → ∘ → IFFT pipeline. The engine is the TPU/runtime analogue
of that split, applied at three levels:

* **Frozen frequency weights** — at construction the engine runs
  ``kernels.block_circulant.plan.freeze_params`` ONCE: every circulant table
  is replaced by its rfft ``(wr, wi)`` and the time-domain table is dropped.
  This is the engine's shared plan cache: the same frozen tables (the data
  content of a :class:`~repro.kernels.block_circulant.plan.BCPlan`) are
  threaded as ordinary params into *every* bucketed executable, so no
  prefill/decode trace ever contains an ``rfft(w)`` — exactly one frequency
  transform per weight per engine lifetime (test-enforced via
  ``ops.freq_weights_trace_count``). Tile geometry is likewise derived once
  per layer shape through the lru-cached ``plan_geometry``.

* **Bucketed shapes** — jit recompilation is bounded by rounding every
  prefill launch to a bucket grid: batch sizes come from ``batch_buckets``
  (powers of two up to the slot count) and prompt lengths round up to
  ``prompt_buckets``. A full engine lifetime therefore compiles at most
  ``len(batch_buckets) · len(prompt_buckets)`` prefill executables plus ONE
  decode executable (decode always runs at the full slot count). The wave
  baseline instead recompiles for every distinct wave length it happens to
  see — unbounded in the workload.

* **Continuous batching** — requests occupy independent cache *slots*; a
  finished slot admits the next queued request immediately instead of
  stalling the whole wave on the slowest request (the C-LSTM pipeline
  overlap argument, arXiv:1803.06305, applied across sequences). Admission
  order is a :class:`Scheduler` policy (FIFO or shortest-prompt-first), and
  each request carries its own :class:`SamplingParams` and stop tokens.

Padding correctness: bucketed prefill left-pads prompts and numbers the pad
positions *negatively* (real tokens are always positions ``0..L-1``). The
attention mask drops every key with ``kv_pos < 0``, and pad cache writes
land on ring slots with negative ``pos`` (masked until real tokens overwrite
them), so bucket padding is invisible to the math: greedy outputs are
bit-identical across bucket choices, wave sizes, and the B=1 reference loop.
(Recurrent mixers — mamba/rwkv — carry pad tokens through their state and
are not pad-invariant; the engine targets attention-family decoders.)
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Optional, Sequence, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig

__all__ = [
    "make_prefill_step",
    "make_decode_step",
    "SamplingParams",
    "Request",
    "Scheduler",
    "EngineStats",
    "ServeEngine",
    "WaveEngine",
    "pow2_buckets",
    "pick_bucket",
    "batch_split",
]


# ---------------------------------------------------------------------------
# Jittable step builders (also used by launch.dryrun)
# ---------------------------------------------------------------------------


def make_prefill_step(model, cfg: ModelConfig):
    def prefill_step(params, tokens, cache, extra=None, positions=None):
        """tokens (B, S) -> (last logits (B, V), filled cache).

        ``positions`` (B, S) overrides the default ``0..S-1`` numbering. The
        bucketed engines pass left-padded rows whose pad positions are
        *negative*, so padding is masked out of attention (``kv_pos < 0``)
        and out of the cache instead of leaking into the output.
        """
        kwargs = {}
        if cfg.family == "vlm" and extra is not None:
            kwargs["img_embeds"] = extra
        if cfg.family == "encdec":
            logits, new_cache, _ = model.forward(
                params, extra, tokens, cache=cache, logits_mode="last"
            )
            return logits[:, -1], new_cache
        logits, new_cache, _ = model.forward(
            params, tokens, cache=cache, logits_mode="last",
            positions=positions, **kwargs
        )
        return logits[:, -1], new_cache

    return prefill_step


def make_decode_step(model, cfg: ModelConfig):
    def decode_step(params, tokens, cache, pos):
        """tokens (B, 1), pos (B,) -> (logits (B, V), cache)."""
        return model.decode_step(params, tokens, cache, pos)

    return decode_step


# ---------------------------------------------------------------------------
# Shape buckets
# ---------------------------------------------------------------------------


def pow2_buckets(lo: int, hi: int) -> Tuple[int, ...]:
    """Powers of two from ``lo``, always terminated by ``hi`` itself."""
    if hi < 1:
        raise ValueError(f"bucket upper bound must be >= 1, got {hi}")
    out = []
    b = max(1, int(lo))
    while b < hi:
        out.append(b)
        b *= 2
    out.append(int(hi))
    return tuple(sorted(set(out)))


def pick_bucket(n: int, buckets: Sequence[int]) -> int:
    """Smallest bucket >= n."""
    for b in buckets:
        if n <= b:
            return b
    raise ValueError(f"{n} exceeds the largest bucket {max(buckets)}")


def batch_split(m: int, buckets: Sequence[int]) -> List[int]:
    """Greedy decomposition of ``m`` into bucket-sized chunks, largest first.

    ``buckets`` must contain 1 so every m decomposes exactly (the engine's
    batch buckets always do).
    """
    desc = sorted(set(int(b) for b in buckets), reverse=True)
    out: List[int] = []
    rem = int(m)
    while rem > 0:
        b = next(b for b in desc if b <= rem)
        out.append(b)
        rem -= b
    return out


# ---------------------------------------------------------------------------
# Requests, sampling, scheduling
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request sampling. ``temperature <= 0`` means greedy argmax."""

    temperature: float = 0.0
    top_k: int = 0          # 0 = full vocab
    seed: int = 0

    def make_rng(self) -> np.random.Generator:
        return np.random.default_rng(self.seed)


def _sample_token(logits: np.ndarray, sp: SamplingParams,
                  rng: np.random.Generator) -> int:
    if sp.temperature <= 0.0:
        return int(np.argmax(logits))
    z = logits.astype(np.float64) / float(sp.temperature)
    if 0 < sp.top_k < z.shape[-1]:
        kth = np.partition(z, -sp.top_k)[-sp.top_k]
        z = np.where(z >= kth, z, -np.inf)
    z = z - z.max()
    p = np.exp(z)
    p /= p.sum()
    return int(rng.choice(p.shape[-1], p=p))


@dataclasses.dataclass
class Request:
    prompt: np.ndarray
    max_new: int = 16
    stop_tokens: Tuple[int, ...] = ()
    sampling: SamplingParams = SamplingParams()

    @property
    def prompt_len(self) -> int:
        return int(np.asarray(self.prompt).reshape(-1).shape[0])


def _validate_request(r: Request, cache_len: int) -> None:
    """Shared admission contract: no silent truncation, no zero budgets."""
    L = r.prompt_len
    if L == 0:
        raise ValueError("empty prompt")
    if r.max_new < 1:
        raise ValueError(f"max_new must be >= 1, got {r.max_new}")
    if L > cache_len:
        raise ValueError(
            f"prompt length {L} exceeds cache_len={cache_len}: the KV cache "
            f"cannot hold the prompt (raise cache_len or truncate the prompt)"
        )
    # positions written: prompt 0..L-1, then decoded tokens L..L+max_new-2
    # (the final generated token is returned but never fed back)
    if L + r.max_new - 1 > cache_len:
        raise ValueError(
            f"prompt length {L} + max_new={r.max_new} needs "
            f"{L + r.max_new - 1} cache positions but cache_len={cache_len}: "
            f"the ring cache would silently overwrite live context "
            f"(raise cache_len or lower max_new)"
        )


def _reject_recurrent_mixers(cfg: ModelConfig, what: str) -> None:
    """Bucketed/wave prefill left-pads prompts; attention masks the pads via
    negative positions, but recurrent mixers (mamba/rwkv) fold pad tokens
    into their state — outputs would silently depend on padding. Refuse
    rather than serve wrong tokens (pad-aware state resets are roadmapped).
    """
    for group in cfg.layer_groups():
        for lspec in group.layers:
            if lspec.mixer in ("mamba", "rwkv"):
                raise ValueError(
                    f"{what} left-pads prompts, and {lspec.mixer!r} layers "
                    f"carry pad tokens through their recurrent state "
                    f"(not pad-invariant); serving this family needs "
                    f"pad-aware state resets"
                )


class Scheduler:
    """Admission queue: ``fifo`` or ``sjf`` (shortest-prompt-first).

    SJF groups short prompts into the same admission round, which tends to
    land them in one prefill bucket (fewer, fuller launches); FIFO preserves
    arrival order. Per-request outputs are identical under either policy —
    slots are independent — only throughput/latency ordering changes.
    """

    POLICIES = ("fifo", "sjf")

    def __init__(self, policy: str = "fifo"):
        if policy not in self.POLICIES:
            raise ValueError(
                f"unknown scheduler policy {policy!r}; one of {self.POLICIES}"
            )
        self.policy = policy
        self._heap: list = []
        self._seq = 0

    def submit(self, item, prompt_len: int) -> None:
        key = prompt_len if self.policy == "sjf" else 0
        heapq.heappush(self._heap, (key, self._seq, item))
        self._seq += 1

    def take(self, n: int) -> list:
        out = []
        while self._heap and len(out) < n:
            out.append(heapq.heappop(self._heap)[2])
        return out

    def __len__(self) -> int:
        return len(self._heap)


# ---------------------------------------------------------------------------
# Stats
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class EngineStats:
    """Lifetime counters (never reset by ``generate``; compile bounds are
    engine-lifetime properties)."""

    prefill_calls: int = 0
    decode_steps: int = 0
    tokens_generated: int = 0
    requests_completed: int = 0
    padded_prompt_tokens: int = 0          # bucket-padding waste
    slot_steps_active: int = 0             # Σ over decode steps of active slots
    prefill_shapes: Set[Tuple[int, int]] = dataclasses.field(
        default_factory=set)

    @property
    def tokens_per_decode_step(self) -> float:
        """Mean decoded tokens per decode launch — the batching-efficiency
        signal that carries to hardware (wave stalls push it toward 1·)."""
        if self.decode_steps == 0:
            return 0.0
        return self.slot_steps_active / self.decode_steps

    def as_dict(self) -> Dict[str, object]:
        d = dataclasses.asdict(self)
        d["prefill_shapes"] = sorted(self.prefill_shapes)
        d["tokens_per_decode_step"] = self.tokens_per_decode_step
        return d


# ---------------------------------------------------------------------------
# The continuous-batching engine
# ---------------------------------------------------------------------------


class ServeEngine:
    """Continuous batching over ``batch`` cache slots with bucketed shapes.

    * admission is per-slot: a finished slot immediately accepts the next
      queued request (``Scheduler`` policy), instead of the whole batch
      waiting for its slowest member;
    * prefill launches are rounded to ``(batch_bucket, prompt_bucket)``
      shapes so the engine compiles at most ``max_prefill_variants``
      prefill executables — decode always runs at the full slot count
      (exactly one executable);
    * frozen frequency weights are computed exactly once at construction
      (``freeze_params``) and shared by every bucketed executable — the
      paper's BRAM-resident FFT(w), with the jitted steps containing no
      ``rfft(w)``.

    ``generate`` keeps the original API: a list of :class:`Request` in,
    per-request token lists out (request order preserved). Greedy outputs
    are bit-identical to the B=1 one-request-at-a-time loop and to
    :class:`WaveEngine` — bucket padding is attention-masked, never part of
    the math.
    """

    def __init__(self, model, cfg: ModelConfig, params, batch: int,
                 cache_len: int, *,
                 prompt_buckets: Optional[Sequence[int]] = None,
                 policy: str = "fifo"):
        if cfg.family == "encdec":
            raise ValueError(
                "ServeEngine supports decoder-LM families; enc-dec serving "
                "needs an encoder pass per request (use the dryrun cells)"
            )
        _reject_recurrent_mixers(cfg, "bucketed prefill")
        Scheduler(policy)       # fail fast on unknown policies
        if cfg.swm.enabled:
            from repro.kernels.block_circulant.plan import freeze_params

            params = freeze_params(model.specs(), params)
        self.model, self.cfg, self.params = model, cfg, params
        self.batch, self.cache_len = int(batch), int(cache_len)
        self.policy = policy
        if prompt_buckets is None:
            prompt_buckets = pow2_buckets(min(8, self.cache_len),
                                          self.cache_len)
        pb = tuple(sorted(set(int(b) for b in prompt_buckets)))
        if not pb or pb[0] < 1 or pb[-1] > self.cache_len:
            raise ValueError(
                f"prompt_buckets must lie in [1, cache_len={self.cache_len}];"
                f" got {pb}"
            )
        if pb[-1] != self.cache_len:
            pb = pb + (self.cache_len,)     # every admissible prompt fits
        self.prompt_buckets = pb
        self.batch_buckets = pow2_buckets(1, self.batch)
        self.stats = EngineStats()
        self._repeat_axes = tuple(
            1 if g.repeat > 1 else 0 for g in cfg.layer_groups()
        )
        # raw (unjitted) fns kept for jaxpr introspection in tests
        self._prefill_fn = self._prefill_and_place
        self._decode_fn = make_decode_step(model, cfg)
        self._prefill = jax.jit(self._prefill_fn)
        self._decode = jax.jit(self._decode_fn)
        self._reset()

    # -- compile accounting -------------------------------------------------
    @property
    def max_prefill_variants(self) -> int:
        """Upper bound on distinct prefill executables over the lifetime."""
        return len(self.batch_buckets) * len(self.prompt_buckets)

    @property
    def prefill_compiles(self) -> int:
        return int(self._prefill._cache_size())

    @property
    def decode_compiles(self) -> int:
        return int(self._decode._cache_size())

    # -- device-side steps --------------------------------------------------
    def _prefill_and_place(self, params, tokens, positions, cache, slot_idx):
        """Prefill a bucket-shaped group into fresh rows, then scatter those
        rows into the persistent slot cache at ``slot_idx``."""
        B = tokens.shape[0]
        fresh = self.model.init_cache(B, self.cache_len)
        logits, filled, _ = self.model.forward(
            params, tokens, positions=positions, cache=fresh,
            logits_mode="last",
        )
        return logits[:, -1], self._place_cache(cache, filled, slot_idx)

    def _place_cache(self, dst, src, idx):
        """Scatter per-request cache rows into slot rows. The batch axis is
        0 for plain groups and 1 for repeat-stacked groups (leading scan
        axis) — mirroring ``model.init_cache``."""
        out = []
        for axis, d_g, s_g in zip(self._repeat_axes, dst, src):
            def put(d, s, axis=axis):
                s = s.astype(d.dtype)
                return (d.at[idx].set(s) if axis == 0
                        else d.at[:, idx].set(s))
            out.append(jax.tree.map(put, d_g, s_g))
        return out

    # -- host-side slot state ----------------------------------------------
    def _reset(self):
        B = self.batch
        self.cache = self.model.init_cache(B, self.cache_len)
        self._active = np.zeros(B, bool)
        self._slot_req: List[Optional[int]] = [None] * B
        self._slot_rng: List[Optional[np.random.Generator]] = [None] * B
        self._slot_pos = np.zeros(B, np.int32)
        self._slot_last = np.zeros(B, np.int32)
        self._slot_left = np.zeros(B, np.int64)

    def _validate(self, r: Request) -> None:
        _validate_request(r, self.cache_len)

    def _finish(self, slot: int) -> None:
        self._active[slot] = False
        self._slot_req[slot] = None
        self._slot_rng[slot] = None
        self.stats.requests_completed += 1

    def _push_token(self, slot: int, logits_row: np.ndarray, outs, requests
                    ) -> None:
        rid = self._slot_req[slot]
        r = requests[rid]
        tok = _sample_token(logits_row, r.sampling, self._slot_rng[slot])
        if r.stop_tokens and tok in r.stop_tokens:
            self._finish(slot)
            return
        outs[rid].append(tok)
        self.stats.tokens_generated += 1
        self._slot_last[slot] = tok
        self._slot_left[slot] -= 1
        if self._slot_left[slot] <= 0:
            self._finish(slot)

    # -- admission ----------------------------------------------------------
    def _admit(self, sched: Scheduler, outs, requests) -> None:
        free = [i for i in range(self.batch) if not self._active[i]]
        n = min(len(free), len(sched))
        if n == 0:
            return
        by_bucket: Dict[int, List[int]] = {}
        for rid in sched.take(n):
            Sb = pick_bucket(requests[rid].prompt_len, self.prompt_buckets)
            by_bucket.setdefault(Sb, []).append(rid)
        for Sb in sorted(by_bucket):
            rids = by_bucket[Sb]
            for Bb in batch_split(len(rids), self.batch_buckets):
                chunk, rids = rids[:Bb], rids[Bb:]
                slots = [free.pop(0) for _ in chunk]
                toks = np.zeros((Bb, Sb), np.int32)
                pos = np.zeros((Bb, Sb), np.int32)
                for j, rid in enumerate(chunk):
                    p = np.asarray(requests[rid].prompt,
                                   np.int32).reshape(-1)
                    L = p.shape[0]
                    toks[j, Sb - L:] = p
                    # pads get negative positions -> attention-masked
                    pos[j] = np.arange(Sb, dtype=np.int32) - (Sb - L)
                    self.stats.padded_prompt_tokens += Sb - L
                logits, self.cache = self._prefill(
                    self.params, jnp.asarray(toks), jnp.asarray(pos),
                    self.cache, jnp.asarray(np.asarray(slots, np.int32)),
                )
                self.stats.prefill_calls += 1
                self.stats.prefill_shapes.add((Bb, Sb))
                lg = np.asarray(logits)
                for j, (slot, rid) in enumerate(zip(slots, chunk)):
                    r = requests[rid]
                    self._slot_req[slot] = rid
                    self._slot_rng[slot] = r.sampling.make_rng()
                    self._slot_pos[slot] = r.prompt_len
                    self._slot_left[slot] = r.max_new
                    self._active[slot] = True
                    self._push_token(slot, lg[j], outs, requests)

    # -- decode -------------------------------------------------------------
    def _decode_step(self, outs, requests) -> None:
        act = self._active.copy()
        if not act.any():
            return
        logits, self.cache = self._decode(
            self.params, jnp.asarray(self._slot_last[:, None]), self.cache,
            jnp.asarray(self._slot_pos),
        )
        self.stats.decode_steps += 1
        self.stats.slot_steps_active += int(act.sum())
        self._slot_pos[act] += 1
        lg = np.asarray(logits)
        for slot in np.nonzero(act)[0]:
            self._push_token(int(slot), lg[slot], outs, requests)

    def prewarm(self) -> int:
        """Compile every (batch-bucket, prompt-bucket) prefill executable
        plus the decode executable up front, so steady-state serving never
        recompiles. Possible precisely because the bucket grid is finite —
        the wave baseline has no analogue (one executable per distinct wave
        length it happens to see). Returns the number of live executables.
        """
        for Sb in self.prompt_buckets:
            for Bb in self.batch_buckets:
                toks = jnp.zeros((Bb, Sb), jnp.int32)
                # all-pad rows (every position negative): fully masked,
                # mathematically defined, and shape-identical to real traffic
                pos = (jnp.broadcast_to(jnp.arange(Sb, dtype=jnp.int32),
                                        (Bb, Sb)) - Sb)
                slots = jnp.arange(Bb, dtype=jnp.int32)
                self._prefill(self.params, toks, pos, self.cache, slots)
        self._decode(
            self.params, jnp.zeros((self.batch, 1), jnp.int32), self.cache,
            jnp.zeros((self.batch,), jnp.int32),
        )
        return self.prefill_compiles + self.decode_compiles

    # -- public API ---------------------------------------------------------
    def generate(self, requests: List[Request]) -> List[List[int]]:
        """Serve a list of requests; returns per-request tokens, in request
        order. Admission interleaves with decoding: slots refill as soon as
        their request finishes (continuous batching)."""
        reqs = list(requests)
        for r in reqs:
            self._validate(r)
        sched = Scheduler(self.policy)
        for rid, r in enumerate(reqs):
            sched.submit(rid, r.prompt_len)
        outs: List[List[int]] = [[] for _ in reqs]
        self._reset()
        while len(sched) or self._active.any():
            self._admit(sched, outs, reqs)
            self._decode_step(outs, reqs)
        return outs


# ---------------------------------------------------------------------------
# The wave baseline (pre-continuous-batching behavior)
# ---------------------------------------------------------------------------


class WaveEngine:
    """Fixed-wave batching baseline: requests are served in waves of
    ``batch``; every wave re-pads to its longest prompt (one recompile per
    distinct wave length) and every slot stalls until the wave's largest
    ``max_new`` finishes. Greedy only.

    Kept as the comparison point for ``benchmarks/serve_bench.py`` and the
    engine-equivalence tests. Shares the masked-padding convention with
    :class:`ServeEngine` (negative pad positions), so its greedy outputs are
    bit-identical to the continuous engine — the old implementation let pad
    tokens leak into attention, which this fixes.
    """

    def __init__(self, model, cfg: ModelConfig, params, batch: int,
                 cache_len: int):
        if int(batch) > 1:
            # a wave of one never pads; larger waves pad to the wave max
            _reject_recurrent_mixers(cfg, "wave prefill")
        if cfg.swm.enabled:
            from repro.kernels.block_circulant.plan import freeze_params

            params = freeze_params(model.specs(), params)
        self.model, self.cfg, self.params = model, cfg, params
        self.batch, self.cache_len = int(batch), int(cache_len)
        self.stats = EngineStats()
        self._prefill = jax.jit(make_prefill_step(model, cfg))
        self._decode = jax.jit(make_decode_step(model, cfg))

    @property
    def prefill_compiles(self) -> int:
        return int(self._prefill._cache_size())

    @property
    def decode_compiles(self) -> int:
        return int(self._decode._cache_size())

    def generate(self, requests: List[Request]) -> List[List[int]]:
        """Greedy-decode a list of requests in fixed batched waves."""
        for r in requests:
            _validate_request(r, self.cache_len)
            if r.sampling.temperature > 0 or r.stop_tokens:
                raise ValueError(
                    "WaveEngine is a greedy-only baseline: per-request "
                    "sampling and stop tokens need ServeEngine"
                )
        results: List[List[int]] = []
        for i in range(0, len(requests), self.batch):
            results.extend(self._run_wave(requests[i: i + self.batch]))
        return results

    def _run_wave(self, wave: List[Request]) -> List[List[int]]:
        B = self.batch
        plen = max(r.prompt_len for r in wave)
        toks = np.zeros((B, plen), np.int32)
        pos = np.zeros((B, plen), np.int32)
        lens = np.zeros(B, np.int32)
        for j in range(B):
            L = wave[j].prompt_len if j < len(wave) else 0
            lens[j] = L
            if L:
                toks[j, plen - L:] = np.asarray(
                    wave[j].prompt, np.int32).reshape(-1)
            pos[j] = np.arange(plen, dtype=np.int32) - (plen - L)
        cache = self.model.init_cache(B, self.cache_len)
        logits, cache = self._prefill(
            self.params, jnp.asarray(toks), cache, None, jnp.asarray(pos)
        )
        self.stats.prefill_calls += 1
        self.stats.prefill_shapes.add((B, plen))
        outs: List[List[int]] = [[] for _ in wave]
        cur = np.argmax(np.asarray(logits), axis=-1).astype(np.int32)
        for j, r in enumerate(wave):
            outs[j].append(int(cur[j]))
            self.stats.tokens_generated += 1
        max_new = max(r.max_new for r in wave)
        for t in range(max_new - 1):
            logits, cache = self._decode(
                self.params, jnp.asarray(cur[:, None]), cache,
                jnp.asarray(lens + t),
            )
            self.stats.decode_steps += 1
            self.stats.slot_steps_active += sum(
                1 for r in wave if t + 1 < r.max_new)
            cur = np.argmax(np.asarray(logits), axis=-1).astype(np.int32)
            for j, r in enumerate(wave):
                if t + 1 < r.max_new:
                    outs[j].append(int(cur[j]))
                    self.stats.tokens_generated += 1
        for _ in wave:
            self.stats.requests_completed += 1
        return outs
