"""Serving: plan-aware continuous batching with bucketed shapes.

``make_prefill_step`` / ``make_decode_step`` produce the jittable functions
that the dry-run lowers for the ``prefill_*`` and ``decode_*`` / ``long_*``
shape cells. ``ServeEngine`` is the production driver; ``WaveEngine`` is the
fixed-wave baseline it replaced (kept for benchmarking and equivalence
tests — see ``benchmarks/serve_bench.py``).

Serving model (the paper's §5 inference dataflow, engine-level)
---------------------------------------------------------------

The paper keeps ``FFT(w)`` resident in BRAM and streams only activations
through the FFT → ∘ → IFFT pipeline. The engine is the TPU/runtime analogue
of that split, applied at three levels:

* **Frozen frequency weights** — at construction the engine runs
  ``kernels.block_circulant.plan.freeze_params`` ONCE: every circulant table
  is replaced by its rfft ``(wr, wi)`` and the time-domain table is dropped.
  This is the engine's shared plan cache: the same frozen tables (the data
  content of a :class:`~repro.kernels.block_circulant.plan.BCPlan`) are
  threaded as ordinary params into *every* bucketed executable, so no
  prefill/decode trace ever contains an ``rfft(w)`` — exactly one frequency
  transform per weight per engine lifetime (test-enforced via
  ``ops.freq_weights_trace_count``). Tile geometry is likewise derived once
  per layer shape through the lru-cached ``plan_geometry``.

* **Bucketed shapes** — jit recompilation is bounded by rounding every
  launch to a bucket grid: prefill batch sizes come from ``batch_buckets``
  (powers of two up to the slot count), prompt lengths round up to
  ``prompt_buckets``, and *decode* launches compact the active slots into
  the smallest ``decode_buckets`` batch that holds them. A full engine
  lifetime therefore compiles at most
  ``len(batch_buckets) · len(prompt_buckets)`` prefill executables plus
  ``len(decode_buckets)`` decode executables (``prewarm()`` compiles them
  all up front). The wave baseline instead recompiles for every distinct
  wave length it happens to see — unbounded in the workload.

* **Decode-side slot compaction** — the paper's throughput argument (and
  CirCNN's, arXiv:1708.08917) is that no FFT → ∘ → IFFT lane ever carries
  dead data. Before each decode launch the engine gathers the *active*
  slots' cache rows, last tokens, and positions into a bucket-shaped
  sub-batch, decodes there, and scatters logits and cache rows back. In the
  tail of a batch one live request pays for ``pick_bucket(1)`` rows of
  work, not ``batch`` rows (``EngineStats.decode_rows`` /
  ``decode_rows_per_token`` make the saving measurable). Compaction is a
  pure permutation of slot rows — never part of the math — so greedy
  outputs are bit-identical to full-slot decode (``decode_buckets=(batch,)``
  restores the old behavior exactly).

* **Continuous batching, streamed** — requests occupy independent cache
  *slots*; a finished slot admits the next queued request immediately
  instead of stalling the whole wave on the slowest request (the C-LSTM
  pipeline overlap argument, arXiv:1803.06305, applied across sequences).
  Admission order is a :class:`Scheduler` policy (FIFO or
  shortest-prompt-first), and each request carries its own
  :class:`SamplingParams` and stop tokens. The engine serves an open-ended
  stream: ``submit(request)`` returns a request id, ``step()`` advances
  admission + one decode round, ``poll(req_id)`` snapshots progress
  without consuming it, and ``drain()`` runs the loop to idle and claims
  finished outputs. ``generate(list)`` is a thin wrapper over that loop
  (submit all, drain, reorder) — slot state persists across calls instead
  of being reset.

* **Shared-prefix KV reuse** (``prefix_cache=True``) — the CirCNN /
  C-LSTM discipline of touching resident state once, applied across
  requests: prompt heads another request already prefilled are never
  recomputed. Lifecycle of the prefix index:

  1. *match* — admission hashes the new prompt's block-aligned prefixes
     (multiples of ``prefix_block``, longest first) against a host-side
     index of resident slot rows; a hit names a donor slot and a match
     length ``m`` (capped so the tail still produces the first-token
     logits and the tail bucket's pad ring slots stay clear of the copied
     rows: ``m + tail_bucket <= cache_len``);
  2. *copy rows* — the prefill launch gathers the donor's cache rows and
     masks every entry at position ``>= m`` (``pos -> -1``), seeding the
     consumer's rows with exactly the shared head — a device-side row
     copy instead of ``m`` tokens of recomputation
     (``EngineStats.prefill_tokens_saved`` / ``prefix_hits``);
  3. *tail prefill* — only the unmatched tail runs through the model,
     bucket-shaped as usual (reuse composes with prompt buckets), with
     tail positions ``m..L-1`` and pad writes parked on masked ring slots
     past the tail;
  4. *refcount* — a matched donor's rows are pinned (``_slot_refs``)
     until the launch that copies them has run: a pinned free slot is
     never handed to a new request and never borrowed as a decode pad
     lane, so multi-launch admission rounds cannot overwrite rows a
     later launch still reads;
  5. *evict* — eviction is explicit: rows leave the index only when
     their slot is reassigned to a new request, borrowed as a pad lane
     (least-recently-used donors sacrificed first), or the LRU index
     exceeds ``prefix_capacity`` (which forgets entries — rows in slots
     are never freed while referenced).

  Greedy outputs are bit-identical with the prefix cache on or off:
  masked cache entries contribute exactly zero to attention, and the
  copied rows are bit-identical to the rows a full prefill would have
  written (bucket-padding invariance, same params, same positions).

* **Donated decode buffers** (``donate=True``, default) — every
  prefill/decode executable takes the slot cache through
  ``jax.jit(..., donate_argnums)``, so the compaction scatter updates the
  cache in place (XLA input-output aliasing) instead of allocating and
  copying a second full cache per step — the PR-3 gather→decode→scatter
  path's extra HBM round-trip disappears. The engine threads the returned
  cache handle through every call (a donated input buffer is invalid
  after the call), and ``prewarm()`` COMMITS its warm-up results for the
  same reason: discarding them would kill the live cache. Donation never
  changes the math — outputs are bit-identical with it on or off.

Padding correctness: bucketed prefill left-pads prompts and numbers the pad
positions *negatively* (real tokens are always positions ``0..L-1``). The
attention mask drops every key with ``kv_pos < 0``, and pad cache writes
land on ring slots with negative ``pos`` (masked until real tokens overwrite
them), so bucket padding is invisible to the math: greedy outputs are
bit-identical across bucket choices, wave sizes, and the B=1 reference loop.
Recurrent mixers — mamba/rwkv — get a validity mask derived from the same
negative pad positions (``positions >= 0``), so token shifts, conv windows,
and state updates skip pad lanes and bucketed prefill stays bit-identical
to the unbucketed B=1 loop (see ``repro.serve.runner``).

Everything model-shaped sits behind a :class:`~repro.serve.runner.
ModelRunner`: the engine schedules, buckets, indexes prefixes, and
snapshots host state, while the runner owns the per-slot device state tree
and the prefill/decode executables — one engine serves every family in
``configs/`` (attention decoders, rwkv/mamba/jamba hybrids, MoE, enc-dec).

Structural contracts (``repro.analysis``; run via ``ServeEngine.audit()``)
--------------------------------------------------------------------------

Every promise above that is *structural* — visible in the traced program
rather than in its outputs — is gated declaratively by the jaxpr auditor
(``repro.analysis.contracts``), one contract per compiled surface:

* ``serve_prefill[B,S]`` / ``serve_decode[B]`` (one surface per bucketed
  executable): ``NoWeightFFT`` — no fft over parameter-derived data, i.e.
  the freeze-once promise holds in every trace (the ``paper``/``freq``
  impls legitimately stream *activations* through rfft; ``pallas``/``dft``
  additionally promise total ``NoFFT``); ``DenseFallbackDot`` — no
  ``dot_general`` against a circulant layer's dense-equivalent kernel
  (the silent O(n²) fallback); ``NoWeightConcat`` — fused QKV/gate tables
  are pre-concatenated by ``freeze_params``, never stacked per trace.
* ``serve_params``: ``QuantizedTableDtypes`` — frozen tables are int8 with
  f32 per-block scales under ``quantize='int8'``, plain float under
  ``'off'``.
* ``serve_donation[prefill|decode]``: ``DonatedInputsAliased`` — the
  lowered modules really record input-output aliasing for the donated
  cache (donation silently not taking would re-materialize the cache
  every step).
* Cross-engine (CLI-level, ``audit_config``): launch parity — the int8
  engine launches exactly as many Pallas kernels as the fp32 engine
  (in-kernel dequant adds no launch).

``audit()`` returns the violations; ``prewarm(audit=True)`` gates
compilation on them (raises ``StructuralContractError``). CI runs
``python -m repro.analysis --all-configs`` over every registry config.

Failure semantics (the robustness layer; see ``repro.serve.guard``)
-------------------------------------------------------------------

The deployment targets of the paper — FPGAs, mobile/IoT, always-on
streaming (C-LSTM, arXiv:1803.06305) — make preemption, transient device
faults, and overload the normal operating regime. The engine's contract:

* **Terminal states** — every submitted request ends in exactly one of
  ``FINISHED`` (ran to a stop token / ``max_new``), ``FAILED`` (isolated
  error: launch fault or non-finite logits), ``EXPIRED`` (``deadline_ms``
  exceeded), or ``CANCELLED`` (``cancel()`` or load shedding).
  ``poll``/:class:`RequestState` surface the state plus a human-readable
  ``error`` reason; ``drain`` claims the (possibly partial) tokens of any
  terminal request.

* **Deadlines** — a request with ``deadline_ms`` set is expired by a
  step-boundary watchdog (queued or running; the deadline clock starts at
  ``submit``). Expiry recycles the slot immediately: donor refcounts are
  always zero at a step boundary, so the slot returns to the free pool
  with its prefix-index entries intact (a finished/expired slot remains a
  donor until its rows are overwritten).

* **Error isolation** — every prefill/decode launch is wrapped and the
  error classified (``guard.classify_error``): faults raised *before* the
  executable ran leave the donated buffers intact and abort only the
  implicated requests (decode launches retry once — ``transient``);
  anything that may have consumed a donated buffer mid-launch is
  engine-fatal. Non-finite logits are detected by a per-row finiteness
  flag folded into the existing prefill/decode executables (no new
  compiles — the compile budget is unchanged, test-enforced): only the
  poisoned row's request is ``FAILED``, its slot rows are scrubbed back
  to blank (a masked NaN still contaminates attention through ``0·NaN``),
  and the rest of the batch continues bit-identically.

* **Load shedding** — ``max_queue`` bounds admission; ``shed_policy``
  picks between rejecting new work (``QueueFullError`` backpressure — the
  request is never enqueued) and ``drop-oldest`` (the longest-queued
  request is ``CANCELLED`` to make room). ``generate`` absorbs
  backpressure internally (step-and-retry); streaming callers handle
  ``QueueFullError`` themselves. ``EngineStats`` counts ``rejected``,
  ``aborted``, ``expired``, ``cancelled``, ``recoveries``.

* **Snapshot/restore** — ``snapshot()`` serializes the complete serving
  state (slot table, scheduler queue, per-request outputs and RNG states,
  prefix index, KV cache) through ``ft.checkpoint``'s atomic machinery;
  ``snapshot_every`` automates it at step boundaries (skipping an EMPTY
  engine — a snapshot with nothing to resume is never written, and
  ``restore()`` refuses one with an actionable error). After an
  engine-fatal error (``EngineFatalError`` — the engine refuses further
  work), a *replacement* engine with the same configuration calls
  ``restore()`` and resumes every in-flight decode mid-stream; decoding
  is deterministic (greedy argmax or counter-free per-request RNG whose
  state is captured), so outputs are bit-identical to an uninterrupted
  run (test-enforced).

* **Tenancy** — every :class:`Request` bills to a ``tenant``; the
  scheduler's ``fair`` policy keeps one FIFO queue per tenant and admits
  by weighted deficit-round-robin (``tenant_weights``), so a bursty
  tenant cannot starve the others: each backlogged tenant admits at
  least one request per rotation and in the long run admissions track
  the weights (±1 request per round, bench-enforced). ``EngineStats``
  carries per-tenant counters (submitted/admitted/completed/rejected/
  expired/cancelled/aborted/tokens) and a per-tenant TTFT histogram;
  the fault injector's audit log names the tenants riding each launch.
  Per-request outputs are tenant-independent — fairness reorders
  admission, never the math.

* **SLO instrumentation** — ``EngineStats.ttft_ms`` (submit → first
  token) and ``tok_ms`` (inter-token gap) are streaming
  :class:`LatencyHistogram` s over fixed log-spaced buckets: p50/p99
  read in O(buckets), memory is constant, and ``snapshot()`` serializes
  the bucket counts exactly — a restored engine reports the same
  quantiles. The async front-end (``repro.serve.frontend``) maps
  tenants to SLO *classes* (interactive/standard/batch) that default
  ``deadline_ms`` and DRR weights, and enforces per-tenant token-bucket
  admission upstream of the queue bound. ``QueueFullError`` carries
  ``retry_after_hint`` (queue depth over the observed drain rate) so
  shed callers back off proportionally instead of spinning.

* **Self-healing** — ``repro.serve.supervisor.Supervisor`` owns the
  engine lifecycle: it catches ``EngineFatalError`` mid-step, builds a
  replacement engine from its factory, restores the latest snapshot,
  re-submits in-flight work that post-dates the snapshot (rid-remapped),
  and de-duplicates token emission against per-request high-water marks
  so every stream is delivered at-most-once — zero duplicated and zero
  lost tokens across a heal (chaos-tested). With a
  ``repro.serve.prefix_store.PrefixStore`` attached, evicted prefix
  donors spill to host memory and a replacement engine *adopts* the
  hottest entries back into free slots, warm-starting on hot prompt
  heads instead of cold-prefilling them.
"""

from __future__ import annotations

import bisect
import dataclasses
import heapq
import json
import time
from collections import OrderedDict, deque
from typing import Dict, List, Optional, Sequence, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.ft.checkpoint import (latest_step as ckpt_latest_step,
                                 restore_checkpoint, save_checkpoint)
from repro.ft.driver import StragglerWatchdog
from repro.serve.guard import (CANCELLED, EXPIRED, FAILED, FINISHED, QUEUED,
                               RUNNING, TERMINAL_STATES, EngineFatalError,
                               QueueFullError, classify_error,
                               flatten_state_tree, unflatten_state_tree)
from repro.serve.runner import make_runner, recurrent_mixer_names

__all__ = [
    "make_prefill_step",
    "make_decode_step",
    "SamplingParams",
    "Request",
    "RequestState",
    "Scheduler",
    "LatencyHistogram",
    "TenantStats",
    "EngineStats",
    "ServeEngine",
    "WaveEngine",
    "pow2_buckets",
    "pick_bucket",
    "batch_split",
    "validate_buckets",
]


# ---------------------------------------------------------------------------
# Jittable step builders (also used by launch.dryrun)
# ---------------------------------------------------------------------------


def make_prefill_step(model, cfg: ModelConfig):
    def prefill_step(params, tokens, cache, extra=None, positions=None):
        """tokens (B, S) -> (last logits (B, V), filled cache).

        ``positions`` (B, S) overrides the default ``0..S-1`` numbering. The
        bucketed engines pass left-padded rows whose pad positions are
        *negative*, so padding is masked out of attention (``kv_pos < 0``)
        and out of the cache instead of leaking into the output.
        """
        kwargs = {}
        if cfg.family == "vlm" and extra is not None:
            kwargs["img_embeds"] = extra
        if cfg.family == "encdec":
            logits, new_cache, _ = model.forward(
                params, extra, tokens, cache=cache, logits_mode="last"
            )
            return logits[:, -1], new_cache
        logits, new_cache, _ = model.forward(
            params, tokens, cache=cache, logits_mode="last",
            positions=positions, **kwargs
        )
        return logits[:, -1], new_cache

    return prefill_step


def make_decode_step(model, cfg: ModelConfig):
    def decode_step(params, tokens, cache, pos):
        """tokens (B, 1), pos (B,) -> (logits (B, V), cache)."""
        return model.decode_step(params, tokens, cache, pos)

    return decode_step


# ---------------------------------------------------------------------------
# Shape buckets
# ---------------------------------------------------------------------------


def pow2_buckets(lo: int, hi: int) -> Tuple[int, ...]:
    """Powers of two from ``lo``, always terminated by ``hi`` itself."""
    if hi < 1:
        raise ValueError(f"bucket upper bound must be >= 1, got {hi}")
    out = []
    b = max(1, int(lo))
    while b < hi:
        out.append(b)
        b *= 2
    out.append(int(hi))
    return tuple(sorted(set(out)))


def pick_bucket(n: int, buckets: Sequence[int]) -> int:
    """Smallest bucket >= n."""
    for b in buckets:
        if n <= b:
            return b
    raise ValueError(f"{n} exceeds the largest bucket {max(buckets)}")


def batch_split(m: int, buckets: Sequence[int]) -> List[int]:
    """Greedy decomposition of ``m`` into bucket-sized chunks, largest first.

    ``buckets`` must contain 1 so every m decomposes exactly (the engine's
    batch buckets always do); a list that cannot cover the remainder raises
    ``ValueError`` naming the offending buckets.
    """
    desc = sorted(set(int(b) for b in buckets), reverse=True)
    out: List[int] = []
    rem = int(m)
    while rem > 0:
        b = next((b for b in desc if b <= rem), None)
        if b is None:
            raise ValueError(
                f"batch buckets {sorted(desc)} cannot decompose {m}: no "
                f"bucket <= remainder {rem} (include 1 in the bucket list)"
            )
        out.append(b)
        rem -= b
    return out


def validate_buckets(name: str, buckets: Sequence[int], hi: int,
                     *, require_hi: bool = True) -> Tuple[int, ...]:
    """Normalize a user-supplied bucket list: sorted unique ints in
    ``[1, hi]``, with ``hi`` itself appended when ``require_hi`` so every
    admissible size maps to a bucket. Raises ``ValueError`` naming the
    bucket list otherwise (construction-time — never mid-serving)."""
    try:
        bk = tuple(sorted(set(int(b) for b in buckets)))
    except (TypeError, ValueError):
        raise ValueError(f"{name} must be a sequence of ints; got {buckets!r}")
    if not bk or bk[0] < 1 or bk[-1] > hi:
        raise ValueError(f"{name} must lie in [1, {hi}]; got {bk}")
    if require_hi and bk[-1] != hi:
        bk = bk + (hi,)
    return bk


# ---------------------------------------------------------------------------
# Requests, sampling, scheduling
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request sampling. ``temperature <= 0`` means greedy argmax."""

    temperature: float = 0.0
    top_k: int = 0          # 0 = full vocab
    seed: int = 0

    def make_rng(self) -> np.random.Generator:
        return np.random.default_rng(self.seed)


def _sample_token(logits: np.ndarray, sp: SamplingParams,
                  rng: np.random.Generator) -> int:
    if sp.temperature <= 0.0:
        return int(np.argmax(logits))
    z = logits.astype(np.float64) / float(sp.temperature)
    vocab = z.shape[-1]
    # top_k == 0 or top_k >= vocab both mean the full vocabulary survives
    if 0 < sp.top_k < vocab:
        # exactly top_k candidates, ties at the k-th value broken
        # deterministically toward the lower token id (a `z >= kth` mask
        # would keep every tied candidate — more than top_k survivors).
        # O(V): everything strictly above the k-th value survives, then the
        # lowest-id threshold ties fill the remaining seats (nonzero
        # returns ascending indices).
        kth = np.partition(z, -sp.top_k)[-sp.top_k]
        above = np.nonzero(z > kth)[0]
        ties = np.nonzero(z == kth)[0]
        keep = np.concatenate([above, ties[: sp.top_k - above.size]])
        masked = np.full_like(z, -np.inf)
        masked[keep] = z[keep]
        z = masked
    z = z - z.max()
    p = np.exp(z)
    p /= p.sum()
    return int(rng.choice(p.shape[-1], p=p))


@dataclasses.dataclass
class Request:
    """``deadline_ms``: wall-clock TTL measured from ``submit`` — the
    step-boundary watchdog EXPIREs the request (queued or running) once it
    elapses. ``None`` means no deadline.

    ``extra``: per-request conditioning for families whose runner declares
    ``requires_extra`` — for enc-dec configs, the encoder frame embeddings
    with shape ``(enc_seq, d_model)``. Decoder-only families must leave it
    ``None`` (the runner's ``validate_request`` enforces both ways).

    ``tenant``: the tenant the request bills to. Under the scheduler's
    ``fair`` policy it keys the per-tenant DRR queue; per-tenant counters
    and TTFT histograms in :class:`EngineStats` key on it under every
    policy. The async front-end derives ``deadline_ms`` defaults and
    token-bucket admission from the tenant's SLO class."""

    prompt: np.ndarray
    max_new: int = 16
    stop_tokens: Tuple[int, ...] = ()
    sampling: SamplingParams = dataclasses.field(
        default_factory=SamplingParams)
    deadline_ms: Optional[float] = None
    extra: Optional[np.ndarray] = None
    tenant: str = "default"

    def __post_init__(self):
        # accept any iterable of token ids but store a tuple, so equality,
        # hashing of the field, and `tok in stop_tokens` behave uniformly
        self.stop_tokens = tuple(int(t) for t in self.stop_tokens)
        self.tenant = str(self.tenant)
        if not self.tenant:
            raise ValueError("tenant must be a non-empty string")

    @property
    def prompt_len(self) -> int:
        return int(np.asarray(self.prompt).reshape(-1).shape[0])


@dataclasses.dataclass(frozen=True)
class RequestState:
    """``poll`` snapshot: tokens so far, terminal flag, lifecycle
    ``status`` (``QUEUED``/``RUNNING``/``FINISHED``/``FAILED``/``EXPIRED``/
    ``CANCELLED``) and, for failed terminals, the ``error`` reason.
    ``done`` is True exactly when ``status`` is terminal (``FINISHED`` is
    the only *successful* terminal)."""

    req_id: int
    done: bool
    tokens: Tuple[int, ...]
    status: str = QUEUED
    error: Optional[str] = None


def _validate_request(r: Request, cache_len: int) -> None:
    """Shared admission contract: no silent truncation, no zero budgets."""
    L = r.prompt_len
    if L == 0:
        raise ValueError("empty prompt")
    if r.max_new < 1:
        raise ValueError(f"max_new must be >= 1, got {r.max_new}")
    if r.deadline_ms is not None and r.deadline_ms <= 0:
        raise ValueError(
            f"deadline_ms must be > 0 (or None for no deadline), "
            f"got {r.deadline_ms}")
    if L > cache_len:
        raise ValueError(
            f"prompt length {L} exceeds cache_len={cache_len}: the KV cache "
            f"cannot hold the prompt (raise cache_len or truncate the prompt)"
        )
    # positions written: prompt 0..L-1, then decoded tokens L..L+max_new-2
    # (the final generated token is returned but never fed back)
    if L + r.max_new - 1 > cache_len:
        raise ValueError(
            f"prompt length {L} + max_new={r.max_new} needs "
            f"{L + r.max_new - 1} cache positions but cache_len={cache_len}: "
            f"the ring cache would silently overwrite live context "
            f"(raise cache_len or lower max_new)"
        )


class Scheduler:
    """Admission queue: ``fifo``, ``sjf`` (shortest-prompt-first), or
    ``fair`` (weighted deficit-round-robin across tenants).

    SJF groups short prompts into the same admission round, which tends to
    land them in one prefill bucket (fewer, fuller launches); FIFO preserves
    arrival order. ``fair`` keeps one FIFO queue per ``Request.tenant`` and
    admits by deficit-round-robin: each rotation visit grants a tenant its
    ``tenant_weights`` quantum (default 1), so a backlogged tenant admits
    requests proportional to its weight and no tenant starves — every
    backlogged tenant receives at least one admission per full rotation.
    Per-request outputs are identical under every policy — slots are
    independent — only throughput/latency ordering changes.

    ``max_queue`` bounds the queue depth (load shedding): a ``submit`` at
    the bound either raises :class:`QueueFullError` (``shed_policy
    "reject"`` — backpressure, the item is NOT enqueued; carries the
    engine's ``retry_after_hint`` when a ``retry_hint`` callable is wired)
    or sheds the longest-queued item to make room (``"drop-oldest"``,
    returned to the caller to finalize). ``None`` (default) keeps the
    queue unbounded.

    Internals: live items sit in ``_entries`` (seq -> entry); the policy
    heap (fifo/sjf), the per-tenant deques (fair), and the arrival-order
    heap that serves ``drop_oldest`` all hold *seqs* and delete lazily —
    dead seqs are skipped when popped. ``drop_oldest`` is therefore
    O(log n) amortized (one lazy heap pop) instead of the old O(n) scan +
    ``heapify`` per shed, which made sustained overload quadratic.
    """

    POLICIES = ("fifo", "sjf", "fair")
    SHED_POLICIES = ("reject", "drop-oldest")

    def __init__(self, policy: str = "fifo",
                 max_queue: Optional[int] = None,
                 shed_policy: str = "reject",
                 tenant_weights: Optional[Dict[str, int]] = None,
                 retry_hint=None):
        if policy not in self.POLICIES:
            raise ValueError(
                f"unknown scheduler policy {policy!r}; one of {self.POLICIES}"
            )
        if shed_policy not in self.SHED_POLICIES:
            raise ValueError(
                f"unknown shed policy {shed_policy!r}; one of "
                f"{self.SHED_POLICIES}"
            )
        if max_queue is not None and int(max_queue) < 1:
            raise ValueError(f"max_queue must be >= 1 (or None for "
                             f"unbounded), got {max_queue}")
        if tenant_weights:
            if policy != "fair":
                raise ValueError(
                    f"tenant_weights only apply to the 'fair' policy "
                    f"(got policy={policy!r})")
            for t, w in tenant_weights.items():
                if int(w) < 1:
                    raise ValueError(
                        f"tenant weight must be >= 1; got {t!r}: {w}")
        self.policy = policy
        self.max_queue = None if max_queue is None else int(max_queue)
        self.shed_policy = shed_policy
        self.tenant_weights = {str(t): int(w)
                               for t, w in (tenant_weights or {}).items()}
        self.retry_hint = retry_hint     # zero-arg callable -> seconds|None
        # seq -> (key, item, tenant, prompt_len); insertion order == queue
        # identity for serialization (sorted by seq)
        self._entries: Dict[int, Tuple[int, object, str, int]] = {}
        self._order: list = []           # lazy heap of (key, seq) [fifo/sjf]
        self._arrival: list = []         # lazy min-heap of seq [drop_oldest]
        self._tq: Dict[str, object] = {}  # tenant -> deque of seq [fair]
        self._deficit: Dict[str, float] = {}
        self._rr: List[str] = []         # tenant rotation, first-seen order
        self._rr_pos = 0
        self._seq = 0
        self._front = 0

    def _key(self, prompt_len: int) -> int:
        return prompt_len if self.policy == "sjf" else 0

    def _insert(self, seq: int, key: int, item, tenant: str,
                prompt_len: int, *, front: bool = False) -> None:
        self._entries[seq] = (key, item, tenant, prompt_len)
        heapq.heappush(self._arrival, seq)
        if self.policy == "fair":
            q = self._tq.get(tenant)
            if q is None:
                q = self._tq[tenant] = deque()
                self._deficit.setdefault(tenant, 0.0)
                self._rr.append(tenant)
            (q.appendleft if front else q.append)(seq)
        else:
            heapq.heappush(self._order, (key, seq))

    def submit(self, item, prompt_len: int, tenant: str = "default"):
        """Enqueue; returns the item shed to make room (``drop-oldest`` at
        the bound) or None. Raises :class:`QueueFullError` at the bound
        under ``reject``."""
        dropped = None
        if self.max_queue is not None \
                and len(self._entries) >= self.max_queue:
            if self.shed_policy == "reject":
                hint = self.retry_hint() if self.retry_hint else None
                raise QueueFullError(len(self._entries), self.max_queue,
                                     retry_after_hint=hint)
            dropped = self.drop_oldest()
        self._insert(self._seq, self._key(prompt_len), item, str(tenant),
                     prompt_len)
        self._seq += 1
        return dropped

    def drop_oldest(self):
        """Remove and return the longest-queued item (smallest sequence
        number — arrival order, regardless of policy). O(log n) amortized:
        one lazy pop from the arrival heap; the policy-side reference dies
        lazily."""
        while self._arrival:
            seq = heapq.heappop(self._arrival)
            e = self._entries.pop(seq, None)
            if e is not None:
                return e[1]
        raise IndexError("drop_oldest on an empty queue")

    def purge(self, keep) -> int:
        """Drop every queued item for which ``keep(item)`` is false
        (stale entries: requests cancelled/expired while queued). Returns
        the number dropped. Heap/deque references die lazily."""
        dead = [seq for seq, e in self._entries.items() if not keep(e[1])]
        for seq in dead:
            del self._entries[seq]
        return len(dead)

    def put_front(self, item, prompt_len: int,
                  tenant: str = "default") -> None:
        """Re-enqueue ahead of every same-key item (deferred admissions:
        a request bumped out of a round goes back to the head of the line,
        not the tail). Under ``fair`` the item returns to the head of its
        tenant's queue (its DRR quantum was already charged when first
        taken)."""
        self._front -= 1
        self._insert(self._front, self._key(prompt_len), item, str(tenant),
                     prompt_len, front=True)

    def _take_ordered(self, n: int) -> list:
        out = []
        while self._order and len(out) < n:
            _, seq = heapq.heappop(self._order)
            e = self._entries.pop(seq, None)
            if e is not None:
                out.append(e[1])
        return out

    def _take_fair(self, n: int) -> list:
        out = []
        while self._entries and len(out) < n:
            t = self._rr[self._rr_pos % len(self._rr)]
            self._rr_pos = (self._rr_pos + 1) % len(self._rr)
            q = self._tq[t]
            while q and q[0] not in self._entries:
                q.popleft()              # lazy-deleted (purged/shed) seqs
            if not q:
                # an idle tenant banks no deficit: credit accrues only
                # while backlogged, so a returning tenant cannot burst
                # past its weight
                self._deficit[t] = 0.0
                continue
            self._deficit[t] += float(self.tenant_weights.get(t, 1))
            while q and len(out) < n and self._deficit[t] >= 1.0:
                seq = q.popleft()
                e = self._entries.pop(seq, None)
                if e is None:
                    continue
                out.append(e[1])
                self._deficit[t] -= 1.0
            while q and q[0] not in self._entries:
                q.popleft()
            if not q:
                self._deficit[t] = 0.0
        return out

    def take(self, n: int) -> list:
        if self.policy == "fair":
            return self._take_fair(n)
        return self._take_ordered(n)

    def __len__(self) -> int:
        return len(self._entries)

    # -- serialization (engine snapshot/restore) ----------------------------
    def state_dict(self) -> Dict[str, object]:
        """Everything needed to rebuild the queue bit-identically: live
        entries (sorted by seq — negative front-pushed seqs order ahead of
        arrivals, most recent first, matching deque/heap pop order) plus
        the DRR rotation state. Items must be JSON-serializable (the
        engine queues int rids)."""
        return {
            "entries": [[int(seq), int(e[0]), e[1], e[2], int(e[3])]
                        for seq, e in sorted(self._entries.items())],
            "seq": int(self._seq),
            "front": int(self._front),
            "deficit": [[t, float(d)]
                        for t, d in sorted(self._deficit.items())],
            "rr": list(self._rr),
            "rr_pos": int(self._rr_pos),
        }

    def load_state(self, d: Dict[str, object]) -> None:
        """Inverse of :meth:`state_dict` into a fresh scheduler."""
        if self._entries:
            raise RuntimeError("load_state needs an empty scheduler")
        self._seq = int(d["seq"])
        self._front = int(d["front"])
        # seed the rotation before re-inserting so first-seen order (and
        # therefore the DRR visit order) survives even for tenants whose
        # entries were all consumed
        for t in d.get("rr", []):
            if self.policy == "fair" and t not in self._tq:
                self._tq[t] = deque()
                self._deficit.setdefault(t, 0.0)
                self._rr.append(t)
        for seq, key, item, tenant, plen in d["entries"]:
            self._insert(int(seq), int(key), item, str(tenant), int(plen))
        for t, dv in d.get("deficit", []):
            if t in self._deficit or self.policy != "fair":
                self._deficit[t] = float(dv)
        self._rr_pos = int(d.get("rr_pos", 0))


# ---------------------------------------------------------------------------
# Stats
# ---------------------------------------------------------------------------


class LatencyHistogram:
    """Streaming latency histogram over FIXED log-spaced millisecond
    buckets (1-2-5 series, 10µs..100s, plus overflow), so p50/p99 are
    O(buckets) to read, memory is constant regardless of traffic, and
    ``snapshot()`` serializes the counts exactly (restore resumes the same
    distribution — no reservoir to resample). Quantiles return the upper
    bound of the covering bucket: an upper estimate, bounded-error by the
    bucket spacing (≤ 2.5× the true value), which is what an SLO check
    needs — a reported p99 under the target guarantees the true p99 is."""

    BOUNDS_MS: Tuple[float, ...] = tuple(
        m * (10.0 ** e) for e in range(-2, 5) for m in (1.0, 2.0, 5.0)
    ) + (1e5,)

    def __init__(self, counts: Optional[Sequence[int]] = None):
        n = len(self.BOUNDS_MS) + 1          # + overflow bucket
        if counts is None:
            self.counts = [0] * n
        else:
            if len(counts) != n:
                raise ValueError(
                    f"LatencyHistogram needs {n} bucket counts, "
                    f"got {len(counts)} — snapshot from a different "
                    f"bucket layout")
            self.counts = [int(c) for c in counts]

    @property
    def count(self) -> int:
        return sum(self.counts)

    def observe(self, ms: float) -> None:
        self.counts[bisect.bisect_left(self.BOUNDS_MS, float(ms))] += 1

    def quantile(self, q: float) -> Optional[float]:
        """Upper bound of the bucket containing the q-quantile (``None``
        on an empty histogram; ``inf`` when it falls in overflow)."""
        total = self.count
        if total == 0:
            return None
        target = q * total
        acc = 0
        for i, c in enumerate(self.counts):
            acc += c
            if acc >= target:
                return (self.BOUNDS_MS[i] if i < len(self.BOUNDS_MS)
                        else float("inf"))
        return float("inf")

    @property
    def p50(self) -> Optional[float]:
        return self.quantile(0.50)

    @property
    def p99(self) -> Optional[float]:
        return self.quantile(0.99)

    def as_dict(self) -> Dict[str, object]:
        return {"count": self.count, "p50_ms": self.p50, "p99_ms": self.p99}


@dataclasses.dataclass
class TenantStats:
    """Per-tenant slice of the engine counters plus a TTFT histogram —
    the fairness/SLO evidence (``serve_bench --workload tenants`` asserts
    completed-request shares against the DRR weights from these)."""

    submitted: int = 0
    admitted: int = 0                      # taken from the queue into a slot
    completed: int = 0
    rejected: int = 0
    expired: int = 0
    cancelled: int = 0
    aborted: int = 0
    tokens: int = 0
    ttft_ms: LatencyHistogram = dataclasses.field(
        default_factory=LatencyHistogram)

    def as_dict(self) -> Dict[str, object]:
        return {
            "submitted": self.submitted, "admitted": self.admitted,
            "completed": self.completed, "rejected": self.rejected,
            "expired": self.expired, "cancelled": self.cancelled,
            "aborted": self.aborted, "tokens": self.tokens,
            "ttft": self.ttft_ms.as_dict(),
        }


@dataclasses.dataclass
class EngineStats:
    """Lifetime counters (never reset by ``generate``; compile bounds are
    engine-lifetime properties)."""

    prefill_calls: int = 0
    decode_steps: int = 0
    tokens_generated: int = 0
    requests_completed: int = 0
    padded_prompt_tokens: int = 0          # bucket-padding waste
    slot_steps_active: int = 0             # Σ over decode steps of active slots
    decode_rows: int = 0                   # Σ over decode steps of rows launched
    prefix_lookups: int = 0                # admissions probed against the index
    prefix_hits: int = 0                   # admissions seeded from a donor
    prefill_tokens_saved: int = 0          # Σ matched prefix tokens never rerun
    rejected: int = 0                      # load-shed submissions (both policies)
    aborted: int = 0                       # FAILED terminals (isolated errors)
    expired: int = 0                       # EXPIRED terminals (deadline_ms)
    cancelled: int = 0                     # CANCELLED terminals (cancel/shed)
    recoveries: int = 0                    # successful restore() calls
    snapshots: int = 0                     # snapshot() calls
    launch_retries: int = 0                # transient decode launches retried
    slow_steps: int = 0                    # straggler-watchdog flagged steps
    prefix_spills: int = 0                 # evicted donors spilled to store
    prefix_adoptions: int = 0              # store entries adopted into slots
    prefill_shapes: Set[Tuple[int, int]] = dataclasses.field(
        default_factory=set)
    decode_shapes: Set[int] = dataclasses.field(default_factory=set)
    # SLO instrumentation: streaming p50/p99 over fixed buckets, so the
    # histograms serialize exactly through snapshot()/restore()
    ttft_ms: LatencyHistogram = dataclasses.field(
        default_factory=LatencyHistogram)      # submit -> first token
    tok_ms: LatencyHistogram = dataclasses.field(
        default_factory=LatencyHistogram)      # inter-token (decode) gap
    tenants: Dict[str, TenantStats] = dataclasses.field(
        default_factory=dict)

    def tenant(self, name: str) -> TenantStats:
        """Get-or-create the per-tenant slice."""
        ts = self.tenants.get(name)
        if ts is None:
            ts = self.tenants[name] = TenantStats()
        return ts

    @property
    def tokens_per_decode_step(self) -> float:
        """Mean decoded tokens per decode launch — the batching-efficiency
        signal that carries to hardware (wave stalls push it toward 1·)."""
        if self.decode_steps == 0:
            return 0.0
        return self.slot_steps_active / self.decode_steps

    @property
    def decode_rows_per_token(self) -> float:
        """Mean FFT → ∘ → IFFT rows launched per generated token — the
        decode-side work amplification. Full-slot decode pays ``batch`` rows
        per step regardless of occupancy; slot compaction pays the bucket
        that holds the active set, so tail-heavy workloads pull this toward
        1.0. (Prefill-produced first tokens cost no decode rows, so a
        perfectly compacted engine can sit slightly below 1.)"""
        if self.tokens_generated == 0:
            return 0.0
        return self.decode_rows / self.tokens_generated

    @property
    def prefix_hit_rate(self) -> float:
        """Fraction of prefix-index probes that found a usable donor."""
        if self.prefix_lookups == 0:
            return 0.0
        return self.prefix_hits / self.prefix_lookups

    def as_dict(self) -> Dict[str, object]:
        d = {f.name: getattr(self, f.name)
             for f in dataclasses.fields(self)
             if f.name not in ("prefill_shapes", "decode_shapes",
                               "ttft_ms", "tok_ms", "tenants")}
        d["prefill_shapes"] = sorted(self.prefill_shapes)
        d["decode_shapes"] = sorted(self.decode_shapes)
        d["tokens_per_decode_step"] = self.tokens_per_decode_step
        d["decode_rows_per_token"] = self.decode_rows_per_token
        d["prefix_hit_rate"] = self.prefix_hit_rate
        d["ttft"] = self.ttft_ms.as_dict()
        d["tok"] = self.tok_ms.as_dict()
        d["tenants"] = {t: ts.as_dict()
                        for t, ts in sorted(self.tenants.items())}
        return d


# ---------------------------------------------------------------------------
# The continuous-batching engine
# ---------------------------------------------------------------------------


class ServeEngine:
    """Continuous batching over ``batch`` cache slots with bucketed shapes.

    * admission is per-slot: a finished slot immediately accepts the next
      queued request (``Scheduler`` policy), instead of the whole batch
      waiting for its slowest member;
    * prefill launches are rounded to ``(batch_bucket, prompt_bucket)``
      shapes so the engine compiles at most ``max_prefill_variants``
      prefill executables;
    * decode launches compact the active slots into the smallest
      ``decode_buckets`` batch that holds them (gather rows → decode →
      scatter rows back), so the engine compiles at most
      ``len(decode_buckets)`` decode executables and the tail of a batch
      never pays full-slot row work;
    * frozen frequency weights are computed exactly once at construction
      (``freeze_params``) and shared by every bucketed executable — the
      paper's BRAM-resident FFT(w), with the jitted steps containing no
      ``rfft(w)`` (fused QKV groups additionally read one pre-concatenated
      stacked table — no weight concatenate in any trace);
    * ``prefix_cache=True`` reuses resident KV rows across requests that
      share a prompt head: admission copies the matched rows from a donor
      slot and prefills only the tail (see the module docstring for the
      match → copy → tail-prefill → refcount → evict lifecycle);
    * ``donate=True`` (default) donates the cache into every executable so
      the place-back scatter updates HBM in place — no per-step full-cache
      copy; all callers thread the returned handle.

    Streaming API: ``submit(request) -> req_id`` enqueues, ``step()``
    advances admission plus one decode round, ``poll(req_id)`` snapshots
    progress (:class:`RequestState`) without consuming it, and
    ``drain(req_ids=None)`` runs to idle and claims finished outputs.
    ``generate`` is a thin wrapper (submit all → drain → reorder): a list
    of :class:`Request` in, per-request token lists out in request order.
    Greedy outputs are bit-identical to the B=1 one-request-at-a-time loop,
    to :class:`WaveEngine`, and across ``decode_buckets`` choices — bucket
    padding is attention-masked and slot compaction is a pure permutation,
    never part of the math.

    **ModelRunner contract.** Everything model-shaped sits behind
    ``self.runner`` (:mod:`repro.serve.runner`); the engine holds no model
    reference and composes exactly six runner operations: ``init_state`` /
    ``prefill`` / ``decode`` / ``gather_state`` / ``place_state`` /
    ``reset_rows``.

    * *Pad semantics* — prefill buckets are LEFT-padded with negative pad
      positions; the runner must make pad lanes contribute exactly nothing
      (attention masks ``kv_pos < 0``; recurrent mixers consume a
      ``positions >= 0`` validity mask), so the same request produces
      bit-identical tokens at every bucket shape, including the
      unbucketed B=1 loop.
    * *State-tree shape rules* — the slot state is an arbitrary pytree of
      arrays with one row per slot per leaf; only the runner knows which
      axis is the slot axis (axis 0 for plain decoder groups, axis 1 for
      repeat-stacked groups and enc-dec layer stacks). The engine treats
      the tree as opaque: snapshot/restore flattens leaves generically
      (``guard.flatten_state_tree``) and rebuilds against
      ``init_state``'s structure and dtypes.
    * *Capability flags* — ``supports_prefix_cache`` declares whether
      state rows are position-sliceable; requesting ``prefix_cache=True``
      against a runner without it raises the runner's actionable
      ``prefix_cache_unsupported_reason`` at construction, and the
      prefix index/matcher stay inert regardless. ``min_cache_len``
      bounds ``cache_len`` from below. ``requires_extra`` marks families
      whose requests carry per-request conditioning (``Request.extra`` —
      enc-dec encoder frames), batched into every prefill launch and
      synthesized by ``runner.prewarm_extra`` for warm-up.
    """

    def __init__(self, model, cfg: ModelConfig, params, batch: int,
                 cache_len: int, *,
                 prompt_buckets: Optional[Sequence[int]] = None,
                 decode_buckets: Optional[Sequence[int]] = None,
                 policy: str = "fifo",
                 prefix_cache: bool = False,
                 prefix_block: int = 8,
                 prefix_capacity: int = 256,
                 donate: bool = True,
                 max_queue: Optional[int] = None,
                 shed_policy: str = "reject",
                 snapshot_dir: Optional[str] = None,
                 snapshot_every: int = 0,
                 fault_injector=None,
                 clock=time.monotonic,
                 quantize: str = "off",
                 tenant_weights: Optional[Dict[str, int]] = None,
                 prefix_store=None):
        # fail fast on unknown policies / bad bounds (before param freeze)
        Scheduler(policy, max_queue=max_queue, shed_policy=shed_policy,
                  tenant_weights=tenant_weights)
        if int(snapshot_every) < 0:
            raise ValueError(
                f"snapshot_every must be >= 0, got {snapshot_every}")
        if int(snapshot_every) > 0 and snapshot_dir is None:
            raise ValueError("snapshot_every needs snapshot_dir")
        from repro.kernels.block_circulant.plan import (_check_quantize,
                                                        freeze_params)
        _check_quantize(quantize)
        if quantize != "off" and not cfg.swm.enabled:
            raise ValueError(
                "quantize applies to frozen circulant tables; this config "
                "has swm disabled")
        self.batch, self.cache_len = int(batch), int(cache_len)
        # the runner is the ONLY model surface the engine touches from here
        self.runner = make_runner(model, cfg, self.cache_len)
        if self.cache_len < self.runner.min_cache_len:
            raise ValueError(
                f"cache_len={self.cache_len} is below "
                f"{type(self.runner).__name__}'s minimum of "
                f"{self.runner.min_cache_len}")
        if cfg.swm.enabled:
            params = freeze_params(self.runner.specs(), params,
                                   quantize=quantize)
        self.quantize = quantize
        self.cfg, self.params = cfg, params
        self.policy = policy
        self.prefix_cache = bool(prefix_cache)
        self.prefix_block = int(prefix_block)
        self.prefix_capacity = int(prefix_capacity)
        if self.prefix_cache:
            if self.prefix_block < 1:
                raise ValueError(
                    f"prefix_block must be >= 1, got {prefix_block}")
            if self.prefix_capacity < 1:
                raise ValueError(
                    f"prefix_capacity must be >= 1, got {prefix_capacity}")
            if not self.runner.supports_prefix_cache:
                raise ValueError(
                    f"prefix_cache=True is unsupported for "
                    f"{type(self.runner).__name__}: "
                    f"{self.runner.prefix_cache_unsupported_reason}")
        if prefix_store is not None and not self.prefix_cache:
            raise ValueError(
                "prefix_store needs prefix_cache=True: the store spills "
                "and adopts prefix-index donor rows, which only exist "
                "with the prefix cache on")
        self.donate = bool(donate)
        if prompt_buckets is None:
            prompt_buckets = pow2_buckets(min(8, self.cache_len),
                                          self.cache_len)
        # every admissible prompt must fit -> cache_len always terminates
        self.prompt_buckets = validate_buckets(
            "prompt_buckets", prompt_buckets, self.cache_len)
        self.batch_buckets = pow2_buckets(1, self.batch)
        if decode_buckets is None:
            decode_buckets = self.batch_buckets
        # any active-slot count must map to a bucket -> batch terminates
        self.decode_buckets = validate_buckets(
            "decode_buckets", decode_buckets, self.batch)
        self.stats = EngineStats()
        # raw (unjitted) fns kept for jaxpr introspection in tests
        self._prefill_fn = self.runner.prefill
        self._decode_fn = self.runner.decode
        # donating the cache argument lets XLA alias input and output slot
        # caches: the place-back scatter updates HBM in place instead of
        # writing a second full cache per launch. Every caller threads the
        # returned handle (the donated input is dead after the call).
        if self.donate:
            self._prefill = jax.jit(self._prefill_fn, donate_argnums=(3,))
            self._decode = jax.jit(self._decode_fn, donate_argnums=(2,))
        else:
            self._prefill = jax.jit(self._prefill_fn)
            self._decode = jax.jit(self._decode_fn)
        # robustness knobs: bounded admission, fault injection hooks,
        # injectable clock (deadlines/watchdog), snapshot policy
        self.max_queue = None if max_queue is None else int(max_queue)
        self.shed_policy = shed_policy
        self.tenant_weights = {str(t): int(w)
                               for t, w in (tenant_weights or {}).items()}
        self.snapshot_dir = snapshot_dir
        self.snapshot_every = int(snapshot_every)
        self.faults = fault_injector
        self.prefix_store = prefix_store
        self._clock_fn = clock
        self._watchdog = StragglerWatchdog()
        self._fatal: Optional[str] = None
        self._step_count = 0
        # drain-rate estimate (terminals/sec EWMA) backing QueueFullError's
        # retry_after_hint; per-rid submit/last-token times feed the TTFT
        # and inter-token latency histograms
        self._drain_rate = 0.0
        self._prev_step_t: Optional[float] = None
        self._prev_terminals = 0
        self._terminals = 0
        self._submit_t: Dict[int, float] = {}
        self._last_tok_t: Dict[int, float] = {}
        self._store_fp: Optional[str] = None
        # streaming state: queued/running outputs, claimed-on-drain results,
        # lifecycle status/error, absolute deadlines, rid -> slot map
        self._sched = Scheduler(self.policy, max_queue=self.max_queue,
                                shed_policy=self.shed_policy,
                                tenant_weights=self.tenant_weights,
                                retry_hint=self.retry_after_hint)
        self._next_rid = 0
        self._req: Dict[int, Request] = {}
        self._out: Dict[int, List[int]] = {}
        self._finished: Dict[int, List[int]] = {}
        self._status: Dict[int, str] = {}
        self._error: Dict[int, Optional[str]] = {}
        self._deadline: Dict[int, float] = {}
        self._rid_slot: Dict[int, int] = {}
        self._reset_slots()

    # -- compile accounting -------------------------------------------------
    @property
    def max_prefill_variants(self) -> int:
        """Upper bound on distinct prefill executables over the lifetime."""
        return len(self.batch_buckets) * len(self.prompt_buckets)

    @property
    def max_decode_variants(self) -> int:
        """Upper bound on distinct decode executables over the lifetime."""
        return len(self.decode_buckets)

    @property
    def prefill_compiles(self) -> int:
        return int(self._prefill._cache_size())

    @property
    def decode_compiles(self) -> int:
        return int(self._decode._cache_size())

    # -- host-side slot state ----------------------------------------------
    def _reset_slots(self):
        B = self.batch
        self.cache = self.runner.init_state(B)
        self._active = np.zeros(B, bool)
        self._slot_req: List[Optional[int]] = [None] * B
        self._slot_rng: List[Optional[np.random.Generator]] = [None] * B
        self._slot_pos = np.zeros(B, np.int32)
        self._slot_last = np.zeros(B, np.int32)
        self._slot_left = np.zeros(B, np.int64)
        # prefix-cache state: resident prompt per slot, block-aligned
        # prefix index (LRU), donor refcounts, recency clock
        self._slot_prompt: List[Optional[np.ndarray]] = [None] * B
        self._slot_refs = np.zeros(B, np.int64)
        self._slot_touch = np.zeros(B, np.int64)
        self._prefix_index: "OrderedDict[Tuple[int, bytes], int]" = \
            OrderedDict()
        self._clock = 0

    # -- prefix index -------------------------------------------------------
    def _index_drop_slot(self, slot: int, *, spill: bool = True) -> None:
        """Evict a slot's rows from the prefix index — called exactly when
        the rows are about to be overwritten (slot reassigned to a new
        request, or borrowed as a decode pad lane). Rows referenced by an
        in-flight prefill are pinned and must never get here.

        With a ``prefix_store`` attached the evicted donor's rows are
        spilled to the host store first (this is the last moment they are
        readable — the overwrite follows immediately), except when
        ``spill=False``: scrub paths evict *poisoned* rows that must not
        outlive the engine."""
        assert self._slot_refs[slot] == 0, (
            f"evicting donor slot {slot} with {self._slot_refs[slot]} "
            f"in-flight references"
        )
        if self._slot_prompt[slot] is None:
            return
        if spill and self.prefix_store is not None:
            rows = jax.tree_util.tree_map(
                np.asarray,
                self.runner.gather_state(
                    self.cache, jnp.asarray([slot], jnp.int32)))
            if self.prefix_store.put(self._slot_prompt[slot],
                                     flatten_state_tree(rows),
                                     self._store_fingerprint()):
                self.stats.prefix_spills += 1
        self._slot_prompt[slot] = None
        for key in [k for k, s in self._prefix_index.items() if s == slot]:
            del self._prefix_index[key]

    def _index_insert(self, slot: int, prompt: np.ndarray) -> None:
        """Register a freshly-prefilled slot as a donor: every block-aligned
        prefix of its prompt maps to the slot. The index is LRU-bounded by
        ``prefix_capacity`` (forgetting an entry never frees slot rows).

        Gated on the runner's ``supports_prefix_cache`` as well as the
        engine flag: recurrent/enc-dec state has no per-position rows to
        donate, so indexing those prompts would promise copies the runner
        cannot make."""
        if not self.prefix_cache or not self.runner.supports_prefix_cache:
            return
        self._slot_prompt[slot] = prompt
        self._clock += 1
        self._slot_touch[slot] = self._clock
        raw = prompt.tobytes()                 # one serialization, sliced
        for m in range(self.prefix_block, prompt.shape[0] + 1,
                       self.prefix_block):
            key = (m, raw[: m * prompt.itemsize])
            self._prefix_index[key] = slot
            self._prefix_index.move_to_end(key)
        while len(self._prefix_index) > self.prefix_capacity:
            self._prefix_index.popitem(last=False)

    def _match_prefix(self, prompt: np.ndarray) -> Tuple[Optional[int], int]:
        """Longest usable indexed prefix of ``prompt``: match lengths are
        multiples of ``prefix_block``, capped at ``L - 1`` (the tail must
        produce the first-token logits) and by ``m + tail_bucket <=
        cache_len`` (the tail's pad ring slots must stay clear of the
        copied donor rows). Returns ``(donor_slot, m)`` or ``(None, 0)``."""
        if not self.prefix_cache or not self.runner.supports_prefix_cache \
                or not self._prefix_index:
            return None, 0
        L = int(prompt.shape[0])
        raw = prompt.tobytes()                 # one serialization, sliced
        m = ((L - 1) // self.prefix_block) * self.prefix_block
        while m >= self.prefix_block:
            key = (m, raw[: m * prompt.itemsize])
            slot = self._prefix_index.get(key)
            if slot is not None:
                Sb = pick_bucket(L - m, self.prompt_buckets)
                if m + Sb <= self.cache_len:
                    self._prefix_index.move_to_end(key)
                    self._clock += 1
                    self._slot_touch[slot] = self._clock
                    return int(slot), m
            m -= self.prefix_block
        return None, 0

    def _store_fingerprint(self) -> str:
        """Geometry identity for prefix-store entries: runner class,
        cache_len, and the single-slot gathered-state leaf shapes/dtypes
        (via ``eval_shape`` — no compute). Adopting rows produced under a
        different geometry raises in the store instead of silently
        placing mismatched state."""
        if self._store_fp is None:
            shaped = jax.eval_shape(
                lambda c: self.runner.gather_state(
                    c, jnp.zeros((1,), jnp.int32)), self.cache)
            leaves = [(list(l.shape), str(l.dtype))
                      for l in jax.tree_util.tree_leaves(shaped)]
            self._store_fp = json.dumps(
                {"runner": type(self.runner).__name__,
                 "cache_len": self.cache_len, "leaves": leaves},
                sort_keys=True)
        return self._store_fp

    def adopt_prefixes(self, max_slots: Optional[int] = None) -> int:
        """Warm-start free slots from the attached ``prefix_store``:
        place the hottest stored donor rows into unowned, unindexed,
        unpinned slots and register them in the prefix index, so the next
        admission round's ``_match_prefix`` finds them resident. Returns
        the number of slots adopted. The supervisor calls this after
        building/restoring a replacement engine; callers may also invoke
        it on a cold engine before traffic.

        Uses the same runner ops as serving (``place_state`` is the
        prefill donor-copy primitive), so adopted rows are bit-identical
        to the rows the original engine held — greedy outputs after a
        prefix hit on an adopted donor match the original engine's.
        """
        self._check_alive()
        if self.prefix_store is None or not self.prefix_cache:
            return 0
        budget = self.batch if max_slots is None else int(max_slots)
        free = [s for s in range(self.batch)
                if not self._active[s] and self._slot_refs[s] == 0
                and self._slot_prompt[s] is None]
        adopted = 0
        for prompt, rows in self.prefix_store.hottest():
            if not free or adopted >= budget:
                break
            if prompt.shape[0] > self.cache_len:
                continue
            # already resident? (a restored engine may still hold it)
            raw = prompt.tobytes()
            mtop = (prompt.shape[0] // self.prefix_block) \
                * self.prefix_block
            if mtop >= self.prefix_block and \
                    (mtop, raw[: mtop * prompt.itemsize]) \
                    in self._prefix_index:
                continue
            # geometry guard: the store fingerprint was checked at put
            # time, but a hand-loaded store meets the engine here
            self.prefix_store._check_fingerprint(
                self._store_fingerprint(), "adopt")
            sub = unflatten_state_tree(
                self.runner.init_state(1),
                {k: v for k, v in rows.items()})
            slot = free.pop(0)
            self.cache = self.runner.place_state(
                self.cache, sub, jnp.asarray([slot], jnp.int32))
            self._index_insert(slot, prompt)
            self.prefix_store.touch(prompt)
            adopted += 1
            self.stats.prefix_adoptions += 1
        return adopted

    # -- backpressure -------------------------------------------------------
    def retry_after_hint(self) -> Optional[float]:
        """Estimated seconds until a queue slot frees: queue depth over
        the recently-observed drain rate (terminals/sec EWMA across step
        boundaries). ``None`` until the engine has observed any drain —
        callers fall back to their own backoff. Attached to every
        :class:`QueueFullError` the scheduler raises."""
        if self._drain_rate <= 0.0:
            return None
        depth = max(1, len(self._sched))
        return float(min(60.0, max(1e-3, depth / self._drain_rate)))

    def _observe_drain(self, now: float) -> None:
        """EWMA the terminal-completion rate at each step boundary.
        Terminals accumulate until the clock actually advances (dt > 0) —
        zero-dt steps must not swallow completions into the baseline, or
        a whole burst finishing inside one clock tick would never
        register as drain."""
        if self._prev_step_t is None:
            self._prev_step_t = now
            return
        dt = now - self._prev_step_t
        if dt <= 0:
            return
        rate = (self._terminals - self._prev_terminals) / dt
        a = 0.2
        self._drain_rate = (rate if self._drain_rate == 0.0
                            else a * rate + (1 - a) * self._drain_rate)
        self._prev_step_t = now
        self._prev_terminals = self._terminals

    def _validate(self, r: Request) -> None:
        _validate_request(r, self.cache_len)
        self.runner.validate_request(r)

    # -- lifecycle ----------------------------------------------------------
    def _check_alive(self) -> None:
        if self._fatal is not None:
            raise EngineFatalError(
                f"engine is dead ({self._fatal}); build a replacement "
                f"engine and restore() its latest snapshot"
            )

    def _die(self, e: BaseException) -> None:
        """Engine-fatal error: a launch may have consumed its donated cache
        buffer partway, so no device state can be trusted. Mark the engine
        dead (every subsequent submit/step refuses) and raise."""
        self._fatal = f"{type(e).__name__}: {e}"
        raise EngineFatalError(
            f"engine-fatal serving error ({self._fatal}): donated device "
            f"buffers cannot be trusted after a mid-launch failure — the "
            f"engine is dead; build a replacement engine and restore() its "
            f"latest snapshot"
        ) from e

    def _scrub_slot(self, slot: int) -> None:
        """Overwrite a slot's cache rows with blank (fresh) rows. Needed
        after a non-finite launch row: NaN k/v entries contaminate any
        later read through attention even when masked (``0 · NaN = NaN``),
        including the no-match self-donor seed of the next prefill."""
        idx = jnp.asarray([slot], jnp.int32)
        self.cache = self.runner.reset_rows(self.cache, idx)

    def _finalize(self, rid: int, status: str,
                  error: Optional[str] = None, *,
                  scrub: bool = False) -> None:
        """Move a request to a terminal state. Frees its slot if admitted
        (donor refcounts are zero whenever this runs — step boundaries and
        post-launch paths only), keeps the slot's prefix-index entries
        unless ``scrub`` (non-finite rows: drop from the index AND blank
        the rows), and bumps the matching stats counter. The (possibly
        partial) tokens stay claimable via ``drain``."""
        assert status in TERMINAL_STATES, status
        slot = self._rid_slot.pop(rid, None)
        if slot is not None:
            self._active[slot] = False
            self._slot_req[slot] = None
            self._slot_rng[slot] = None
            if scrub:
                # poisoned rows: never spill them to the prefix store
                self._index_drop_slot(slot, spill=False)
                self._scrub_slot(slot)
        req = self._req.pop(rid, None)
        self._finished[rid] = self._out.pop(rid, [])
        self._deadline.pop(rid, None)
        self._submit_t.pop(rid, None)
        self._last_tok_t.pop(rid, None)
        self._status[rid] = status
        self._error[rid] = error
        self._terminals += 1
        ts = (self.stats.tenant(req.tenant) if req is not None else None)
        if status == FINISHED:
            self.stats.requests_completed += 1
            if ts is not None:
                ts.completed += 1
        elif status == FAILED:
            self.stats.aborted += 1
            if ts is not None:
                ts.aborted += 1
        elif status == EXPIRED:
            self.stats.expired += 1
            if ts is not None:
                ts.expired += 1
        elif status == CANCELLED:
            self.stats.cancelled += 1
            if ts is not None:
                ts.cancelled += 1

    def _expire_overdue(self) -> None:
        """Step-boundary deadline watchdog: EXPIRE every request (queued or
        running) whose ``deadline_ms`` has elapsed. Runs at step boundaries
        only, where donor refcounts are all zero — slot recycling is always
        safe and the slot's prefix-index entries stay valid."""
        if not self._deadline:
            return
        now = self._clock_fn()
        for rid in [r for r, t in self._deadline.items() if now >= t]:
            r = self._req.get(rid)
            ms = None if r is None else r.deadline_ms
            self._finalize(rid, EXPIRED,
                           f"deadline_ms={ms} exceeded at step boundary")

    def _push_token(self, slot: int, logits_row: np.ndarray) -> None:
        rid = self._slot_req[slot]
        r = self._req[rid]
        tok = _sample_token(logits_row, r.sampling, self._slot_rng[slot])
        if r.stop_tokens and tok in r.stop_tokens:
            self._finalize(rid, FINISHED)
            return
        # SLO instrumentation: first emitted token closes the TTFT window
        # (submit -> first token); later tokens feed the inter-token gap
        now = self._clock_fn()
        if not self._out[rid]:
            t0 = self._submit_t.get(rid)
            if t0 is not None:
                ttft = (now - t0) * 1e3
                self.stats.ttft_ms.observe(ttft)
                self.stats.tenant(r.tenant).ttft_ms.observe(ttft)
        else:
            tprev = self._last_tok_t.get(rid)
            if tprev is not None:
                self.stats.tok_ms.observe((now - tprev) * 1e3)
        self._last_tok_t[rid] = now
        self._out[rid].append(tok)
        self.stats.tokens_generated += 1
        self.stats.tenant(r.tenant).tokens += 1
        self._slot_last[slot] = tok
        self._slot_left[slot] -= 1
        if self._slot_left[slot] <= 0:
            self._finalize(rid, FINISHED)

    # -- admission ----------------------------------------------------------
    def _resolve_placement(self, rids: List[int],
                           match: Dict[int, Tuple[Optional[int], int]],
                           free: List[int]):
        """Resolve this round's slot placement under donor pins.

        Placement pool = free slots with no in-flight references. When
        pinned free donors starve it: a donor with a SINGLE consumer hosts
        that consumer itself (the row copy and the overwrite happen in one
        launch — no other launch reads it); other consumers are DEFERRED
        to the next round (put_front: they re-match against the same
        resident donors) rather than burn their matches; if a round would
        otherwise admit nothing, matches are dropped — progress always
        wins over reuse.

        Returns ``(keep, avail, self_place)``: the requests to admit, an
        ordered slot pool covering all of them, and per-request
        self-placement onto their own donor. Pin invariant on return:
        every remaining pin belongs to a kept request's match and is
        released right after the launch that consumes it.
        """
        n = len(rids)
        avail = [i for i in free if self._slot_refs[i] == 0]
        self_place: Dict[int, int] = {}
        if len(avail) >= n:
            return rids, avail, self_place
        keep = list(rids)
        deferred: List[int] = []
        for rid in reversed(rids):
            if len(avail) + len(self_place) >= len(keep):
                break
            donor, _ = match[rid]
            if donor is None or self._active[donor]:
                continue
            if self._slot_refs[donor] == 1:
                self_place[rid] = donor            # sole consumer: host it
                continue
            if len(keep) == 1:
                continue
            keep.remove(rid)
            deferred.append(rid)
            match.pop(rid)
            self._slot_refs[donor] -= 1
            if self._slot_refs[donor] == 0:
                avail.append(donor)
        if len(avail) + len(self_place) < len(keep):
            # still starved (defensive): give up matches (full prefill)
            # so the round still admits
            for rid in keep:
                donor, _ = match[rid]
                if donor is None or self._active[donor] \
                        or rid in self_place:
                    continue
                self._slot_refs[donor] -= 1
                match[rid] = (None, 0)
                if self._slot_refs[donor] == 0:
                    avail.append(donor)
                if len(avail) + len(self_place) >= len(keep):
                    break
        # deferred holds latest-taken first; pushing in that order leaves
        # the earliest-taken at the queue head (original order)
        for rid in deferred:
            self._sched.put_front(rid, self._req[rid].prompt_len,
                                  tenant=self._req[rid].tenant)
        return keep, avail, self_place

    def _on_launch(self, kind: str, index: int, rids) -> None:
        """Fault-injection hook with a tenant-aware audit: pass the sorted
        tenant set riding in the launch when the injector understands it
        (``accepts_tenants``); plain two-argument injectors keep working."""
        if self.faults is None:
            return
        if getattr(self.faults, "accepts_tenants", False):
            tenants = tuple(sorted({self._req[rid].tenant for rid in rids
                                    if rid in self._req}))
            self.faults.on_launch(kind, index, tenants=tenants)
        else:
            self.faults.on_launch(kind, index)

    def _admit(self) -> None:
        free = [i for i in range(self.batch) if not self._active[i]]
        if not free:
            return
        # take from the queue, lazily skipping stale entries (requests
        # cancelled / expired / shed while still queued stay in the heap
        # until taken here — O(1) amortized instead of eager heap surgery)
        rids: List[int] = []
        while len(rids) < len(free) and len(self._sched):
            for rid in self._sched.take(len(free) - len(rids)):
                if rid in self._finished:
                    continue
                rids.append(rid)
        if not rids:
            return
        # prefix matching against the RESIDENT index (donors placed in
        # earlier rounds — active or finished-but-unreclaimed slots); a
        # matched donor is pinned until the launch that copies it has run
        match: Dict[int, Tuple[Optional[int], int]] = {}
        for rid in rids:
            p = np.asarray(self._req[rid].prompt, np.int32).reshape(-1)
            donor, m = self._match_prefix(p)
            match[rid] = (donor, m)
            if donor is not None:
                self._slot_refs[donor] += 1
        rids, avail, self_place = self._resolve_placement(rids, match, free)
        if self.prefix_cache:
            # lookups count ADMITTED requests only (deferred ones re-match
            # next round; counting both would dilute the hit rate)
            self.stats.prefix_lookups += len(rids)
        by_bucket: Dict[int, List[int]] = {}
        for rid in rids:
            tail = self._req[rid].prompt_len - match[rid][1]
            Sb = pick_bucket(tail, self.prompt_buckets)
            by_bucket.setdefault(Sb, []).append(rid)
        for Sb in sorted(by_bucket):
            rids_b = by_bucket[Sb]
            for Bb in batch_split(len(rids_b), self.batch_buckets):
                chunk, rids_b = rids_b[:Bb], rids_b[Bb:]
                slots = []
                for rid in chunk:
                    s = self_place.get(rid)
                    if s is None:
                        s = avail.pop(0)
                    else:
                        # the consumer's own pin; released before eviction
                        # so _index_drop_slot sees an unreferenced slot
                        self._slot_refs[s] -= 1
                    slots.append(s)
                toks = np.zeros((Bb, Sb), np.int32)
                pos = np.zeros((Bb, Sb), np.int32)
                donor_idx = np.asarray(slots, np.int32).copy()
                mlen = np.zeros(Bb, np.int32)
                prompts: List[np.ndarray] = []
                for j, rid in enumerate(chunk):
                    p = np.asarray(self._req[rid].prompt,
                                   np.int32).reshape(-1)
                    prompts.append(p)
                    donor, m = match[rid]
                    T = p.shape[0] - m
                    toks[j, Sb - T:] = p[m:]
                    if m > 0:
                        # tail continues at positions m..m+T-1; pad writes
                        # park on ring slots m+T..m+Sb-1 with NEGATIVE
                        # stored positions (masked), clear of the copied
                        # donor rows [0, m)
                        pos[j, Sb - T:] = m + np.arange(T, dtype=np.int32)
                        pos[j, : Sb - T] = (
                            m + T + np.arange(Sb - T, dtype=np.int32)
                            - self.cache_len)
                        donor_idx[j] = donor
                        mlen[j] = m
                        self.stats.prefix_hits += 1
                        self.stats.prefill_tokens_saved += int(m)
                    else:
                        # pads get negative positions -> attention-masked
                        pos[j] = np.arange(Sb, dtype=np.int32) - (Sb - T)
                    self.stats.padded_prompt_tokens += Sb - T
                for slot in slots:
                    self._index_drop_slot(slot)   # rows being overwritten
                # the optional parts ride as kwargs so the positional
                # layout (donated state at 3) is constant across runners;
                # the kwarg set is fixed per engine configuration, so the
                # jit cache still sees one calling convention
                kw = {}
                if self.prefix_cache:
                    kw["donor_idx"] = jnp.asarray(donor_idx)
                    kw["match_len"] = jnp.asarray(mlen)
                if self.runner.requires_extra:
                    kw["extra"] = jnp.asarray(np.stack([
                        np.asarray(self._req[rid].extra, np.float32)
                        for rid in chunk]))
                try:
                    self._on_launch("prefill", self.stats.prefill_calls,
                                    chunk)
                    logits, ok, self.cache = self._prefill(
                        self.params, jnp.asarray(toks), jnp.asarray(pos),
                        self.cache,
                        jnp.asarray(np.asarray(slots, np.int32)), **kw)
                # lint: allow-broad-except — fault-isolation boundary:
                # classify_error decides request-fatal vs engine-fatal
                except BaseException as e:
                    if classify_error(e) != "request":
                        self._die(e)
                    # transient fault BEFORE the executable ran: buffers
                    # intact, slot rows untouched (still free, already out
                    # of the prefix index). Release this chunk's donor pins
                    # and FAIL only its requests; later chunks continue.
                    for rid in chunk:
                        donor, _ = match[rid]
                        if donor is not None and rid not in self_place:
                            self._slot_refs[donor] -= 1
                        self._finalize(rid, FAILED,
                                       f"prefill launch failed: {e}")
                    continue
                # copies landed: release this chunk's donor pins
                # (self-placed consumers already released theirs)
                for rid in chunk:
                    donor, _ = match[rid]
                    if donor is not None and rid not in self_place:
                        self._slot_refs[donor] -= 1
                self.stats.prefill_calls += 1
                self.stats.prefill_shapes.add((Bb, Sb))
                lg = np.asarray(logits)
                okh = np.asarray(ok)
                for j, (slot, rid) in enumerate(zip(slots, chunk)):
                    if not okh[j]:
                        # poisoned row: its NaN k/v already landed in the
                        # slot — scrub back to blank rows (a masked NaN
                        # still reaches attention via 0·NaN) and never
                        # index/activate. Other rows are unaffected.
                        self._scrub_slot(slot)
                        self._finalize(rid, FAILED,
                                       "non-finite logits in prefill "
                                       "(request aborted; batch continues)")
                        continue
                    r = self._req[rid]
                    self.stats.tenant(r.tenant).admitted += 1
                    self._index_insert(slot, prompts[j])
                    self._slot_req[slot] = rid
                    self._rid_slot[rid] = slot
                    self._slot_rng[slot] = r.sampling.make_rng()
                    self._slot_pos[slot] = r.prompt_len
                    self._slot_left[slot] = r.max_new
                    self._active[slot] = True
                    self._push_token(slot, lg[j])

    # -- decode -------------------------------------------------------------
    def _decode_step(self) -> None:
        act = np.nonzero(self._active)[0]
        n = act.size
        if n == 0:
            return
        Bb = pick_bucket(n, self.decode_buckets)
        # pad lanes borrow *distinct free* slot rows (there are always
        # enough: Bb <= batch so Bb - n <= batch - n). The scatter-back
        # therefore has no duplicate indices, and pad-lane writes land on
        # dead rows that the next admission's prefill fully overwrites.
        # With the prefix cache on, free rows may be resident donors whose
        # rows are still valuable: borrow non-donor rows first, and evict
        # (least-recently-used first) any donor row that must be borrowed —
        # its rows are about to take an unmasked pad write.
        idx = act
        if Bb > n:
            free = np.nonzero(~self._active)[0]
            if self.prefix_cache:
                plain = [int(i) for i in free
                         if self._slot_prompt[i] is None]
                donors = sorted(
                    (int(i) for i in free
                     if self._slot_prompt[i] is not None),
                    key=lambda s: self._slot_touch[s])
                borrow = (plain + donors)[: Bb - n]
                for s in borrow:
                    self._index_drop_slot(s)
                idx = np.concatenate([act, np.asarray(borrow, act.dtype)])
            else:
                idx = np.concatenate([act, free[: Bb - n]])
        idx = idx.astype(np.int32)
        # wrapped launch with ONE retry for transient (pre-launch) faults:
        # the injector's fired-set guarantees a scheduled fault does not
        # refire, so the retry runs the same launch with intact buffers. A
        # second failure — or any error that may have consumed the donated
        # cache mid-execution — is engine-fatal (snapshot/restore path).
        attempt = 0
        while True:
            try:
                self._on_launch("decode", self.stats.decode_steps,
                                [self._slot_req[int(s)] for s in act])
                logits, ok, self.cache = self._decode(
                    self.params, jnp.asarray(self._slot_last[idx][:, None]),
                    self.cache, jnp.asarray(self._slot_pos[idx]),
                    jnp.asarray(idx),
                )
                break
            # lint: allow-broad-except — fault-isolation boundary:
            # classify_error decides retry vs engine-fatal
            except BaseException as e:
                if classify_error(e) != "request" or attempt >= 1:
                    self._die(e)
                attempt += 1
                self.stats.launch_retries += 1
        self.stats.decode_steps += 1
        self.stats.slot_steps_active += int(n)
        self.stats.decode_rows += int(Bb)
        self.stats.decode_shapes.add(int(Bb))
        self._slot_pos[act] += 1
        lg = np.asarray(logits)
        okh = np.asarray(ok)
        for j, slot in enumerate(act):
            slot = int(slot)
            if not okh[j]:
                # poisoned row: abort just this request; scrub its rows
                # (NaN k/v reach attention even masked) and drop it from
                # the prefix index. All other rows continue unaffected.
                self._finalize(self._slot_req[slot], FAILED,
                               "non-finite logits in decode "
                               "(request aborted; batch continues)",
                               scrub=True)
                continue
            self._push_token(slot, lg[j])

    def audit(self, raise_on_violation: bool = False):
        """Run every single-engine structural contract (see the module
        docstring's *Structural contracts* section) and return the
        violations — an empty list is the pass condition. With
        ``raise_on_violation=True`` a non-empty result raises
        :class:`~repro.analysis.contracts.StructuralContractError` whose
        message carries per-violation ``file:line`` provenance."""
        from repro.analysis.contracts import (StructuralContractError,
                                              audit_engine)

        violations = audit_engine(self)
        if raise_on_violation and violations:
            raise StructuralContractError(violations)
        return violations

    def prewarm(self, audit: bool = False) -> int:
        """Compile every (batch-bucket, prompt-bucket) prefill executable
        plus every decode-bucket executable up front, so steady-state
        serving never recompiles. Possible precisely because the bucket
        grid is finite — the wave baseline has no analogue (one executable
        per distinct wave length it happens to see). Returns the number of
        live executables.

        ``audit=True`` gates compilation on the structural contracts: the
        bucketed executables are traced and audited first (``audit()``),
        and any violation raises before a single XLA compile is spent on a
        structurally broken program.

        Warm-up results are COMMITTED, not discarded: the cache argument is
        donated (``donate_argnums``), so the input buffer is invalid after
        every call and discarding the returned handle would kill the live
        cache. Commitment is safe because every warm-up write is masked
        (all-pad prefill rows; decode probes at position ``-1``) — but it
        does touch free slot rows, so prewarm requires an IDLE engine (no
        active slots) and flushes the prefix index (resident donor rows in
        free slots take pad writes).
        """
        self._check_alive()
        if self._active.any():
            raise RuntimeError(
                "prewarm() requires an idle engine: warm-up launches commit "
                "(masked) writes into slot rows that active requests own"
            )
        if audit:
            self.audit(raise_on_violation=True)
        if self.prefix_cache:
            for s in range(self.batch):
                self._index_drop_slot(s)
        for Sb in self.prompt_buckets:
            for Bb in self.batch_buckets:
                toks = jnp.zeros((Bb, Sb), jnp.int32)
                # all-pad rows (every position negative): fully masked,
                # mathematically defined, and shape-identical to real traffic
                pos = (jnp.broadcast_to(jnp.arange(Sb, dtype=jnp.int32),
                                        (Bb, Sb)) - Sb)
                slots = jnp.arange(Bb, dtype=jnp.int32)
                kw = {}
                if self.prefix_cache:
                    # self-donor with match 0: fully-masked seed, same
                    # calling convention (and executable) as real traffic
                    kw["donor_idx"] = slots
                    kw["match_len"] = jnp.zeros((Bb,), jnp.int32)
                ex = self.runner.prewarm_extra(Bb)
                if ex is not None:
                    kw["extra"] = ex
                _, _, self.cache = self._prefill(
                    self.params, toks, pos, self.cache, slots, **kw)
        for Bb in self.decode_buckets:
            # probe at position -1: the ring write lands with a negative
            # stored position (masked), so committing the returned cache
            # leaves the math untouched
            _, _, self.cache = self._decode(
                self.params, jnp.zeros((Bb, 1), jnp.int32), self.cache,
                -jnp.ones((Bb,), jnp.int32),
                jnp.arange(Bb, dtype=jnp.int32),
            )
        return self.prefill_compiles + self.decode_compiles

    # -- public API ---------------------------------------------------------
    def submit(self, request: Request) -> int:
        """Enqueue one request for service; returns its request id. The
        request is admitted to a cache slot by a later ``step()`` (or
        ``drain``/``generate``) as slots free up.

        With ``max_queue`` set, a submit at the bound either raises
        :class:`QueueFullError` (``shed_policy="reject"`` — nothing is
        enqueued, ``stats.rejected`` counts it; retry after draining) or
        sheds the longest-queued request as CANCELLED
        (``"drop-oldest"``). The deadline clock starts now."""
        self._check_alive()
        self._validate(request)
        if self._sched.max_queue is not None:
            # stale heap entries (cancelled/expired while queued) must not
            # count against the bound
            self._sched.purge(lambda rid: rid not in self._finished)
        rid = self._next_rid
        try:
            dropped = self._sched.submit(rid, request.prompt_len,
                                         tenant=request.tenant)
        except QueueFullError:
            self.stats.rejected += 1
            self.stats.tenant(request.tenant).rejected += 1
            raise
        self._next_rid += 1
        self._req[rid] = request
        self._out[rid] = []
        self._submit_t[rid] = self._clock_fn()
        self.stats.tenant(request.tenant).submitted += 1
        if request.deadline_ms is not None:
            self._deadline[rid] = (self._clock_fn()
                                   + request.deadline_ms / 1000.0)
        if dropped is not None:
            self.stats.rejected += 1
            self._finalize(dropped, CANCELLED,
                           "load shed (drop-oldest): queue at max_queue="
                           f"{self._sched.max_queue}")
        return rid

    def cancel(self, req_id: int) -> bool:
        """Cancel a queued or running request: its slot (if any) is
        recycled and its partial tokens stay claimable via ``drain``.
        Returns True if this call cancelled it, False if it was already
        terminal; raises ``KeyError`` for unknown/claimed ids."""
        if req_id in self._finished:
            return False
        if req_id not in self._out:
            raise KeyError(f"unknown or already-claimed request id {req_id}")
        self._finalize(req_id, CANCELLED, "cancelled by caller")
        return True

    def step(self) -> bool:
        """Advance the engine one round: expire overdue deadlines (step-
        boundary watchdog), admit queued requests into free slots (bucketed
        prefill), and run one compacted decode step. Auto-snapshots every
        ``snapshot_every`` steps. Returns True while work remains (active
        slots or queued requests). Raises :class:`EngineFatalError` (and
        marks the engine dead) on unrecoverable launch errors."""
        self._check_alive()
        t0 = self._clock_fn()
        if self.faults is not None:
            self.faults.on_step(self._step_count)
        self._expire_overdue()
        self._admit()
        self._decode_step()
        self._step_count += 1
        now = self._clock_fn()
        self._observe_drain(now)
        if self._watchdog.observe(self._step_count, now - t0) != "ok":
            self.stats.slow_steps += 1
        # auto-snapshot skips an EMPTY engine (no queued, running, or
        # unclaimed requests): such a snapshot resumes nothing — restoring
        # it is refused — and idle-loop callers would otherwise overwrite
        # the last useful snapshot with a useless one
        if (self.snapshot_dir is not None and self.snapshot_every > 0
                and self._step_count % self.snapshot_every == 0
                and (self._req or self._finished)):
            self.snapshot()
        return bool(self._active.any() or len(self._sched))

    def poll(self, req_id: int) -> RequestState:
        """Snapshot a submitted request's progress without consuming it:
        tokens generated so far, lifecycle ``status``, and the ``error``
        reason for failed terminals. Raises ``KeyError`` for unknown or
        already-claimed (drained) request ids."""
        if req_id in self._finished:
            return RequestState(req_id, True, tuple(self._finished[req_id]),
                                self._status.get(req_id, FINISHED),
                                self._error.get(req_id))
        if req_id in self._out:
            status = RUNNING if req_id in self._rid_slot else QUEUED
            return RequestState(req_id, False, tuple(self._out[req_id]),
                                status, None)
        raise KeyError(
            f"unknown or already-claimed request id {req_id}"
        )

    def drain(self, req_ids: Optional[Sequence[int]] = None
              ) -> Dict[int, List[int]]:
        """Run ``step()`` until the engine is idle, then claim finished
        outputs: the requested ids (default: every unclaimed terminal
        request) are removed from the engine and returned as
        ``{req_id: tokens}`` — partial tokens for FAILED/EXPIRED/CANCELLED
        terminals (``poll`` first for the status). Unlisted terminal
        requests stay pollable."""
        while self.step():
            pass
        if req_ids is None:
            req_ids = list(self._finished)
        # validate every id (and reject duplicates) BEFORE popping any, so a
        # bad id cannot discard other requests' already-claimed outputs
        rids = list(req_ids)
        if len(set(rids)) != len(rids):
            raise KeyError(f"duplicate request ids in drain: {rids}")
        for rid in rids:
            if rid not in self._finished:
                raise KeyError(
                    f"request id {rid} is not a finished unclaimed request"
                )
        out = {}
        for rid in rids:
            out[rid] = self._finished.pop(rid)
            self._status.pop(rid, None)
            self._error.pop(rid, None)
        return out

    def generate(self, requests: List[Request]) -> List[List[int]]:
        """Serve a list of requests; returns per-request tokens, in request
        order. A thin wrapper over the streaming loop: submit all, drain to
        idle, claim this call's outputs (earlier ``submit``-ed requests also
        run to completion but stay pollable/claimable). Admission
        interleaves with decoding: slots refill as soon as their request
        finishes (continuous batching).

        Backpressure is absorbed internally: a submit rejected at the
        ``max_queue`` bound steps the engine (freeing queue space) and
        retries — the loop always terminates because every queued request
        has a finite budget. Under ``drop-oldest``, shed requests of this
        call return their (possibly empty) partial tokens."""
        # validate the whole batch before submitting any of it: a bad
        # request must not leave its predecessors enqueued as ghost work
        for r in requests:
            self._validate(r)
        rids = []
        for r in requests:
            while True:
                try:
                    rids.append(self.submit(r))
                    break
                except QueueFullError:
                    self.step()
        done = self.drain(rids)
        return [done[rid] for rid in rids]

    # -- snapshot / restore -------------------------------------------------
    _STAT_FIELDS = (
        "prefill_calls", "decode_steps", "tokens_generated",
        "requests_completed", "padded_prompt_tokens", "slot_steps_active",
        "decode_rows", "prefix_lookups", "prefix_hits",
        "prefill_tokens_saved", "rejected", "aborted", "expired",
        "cancelled", "recoveries", "snapshots", "launch_retries",
        "slow_steps", "prefix_spills", "prefix_adoptions",
    )

    def _fingerprint(self) -> Dict[str, object]:
        """Configuration identity a snapshot is only valid against."""
        return {
            "batch": self.batch, "cache_len": self.cache_len,
            "runner": type(self.runner).__name__,
            "policy": self.policy,
            "prompt_buckets": list(self.prompt_buckets),
            "decode_buckets": list(self.decode_buckets),
            "prefix_cache": self.prefix_cache,
            "prefix_block": self.prefix_block,
            "prefix_capacity": self.prefix_capacity,
            "vocab": int(self.cfg.vocab),
            "max_queue": self.max_queue,
            "shed_policy": self.shed_policy,
            "quantize": self.quantize,
            "tenant_weights": [[k, int(v)] for k, v in
                               sorted(self.tenant_weights.items())],
        }

    def frozen_table_bytes(self) -> int:
        """Resident bytes of the frozen frequency tables (incl. fused
        copies and quantization scales) — the quantization acceptance
        metric (int8 ≤ 0.55× fp32)."""
        from repro.kernels.block_circulant.plan import frozen_table_bytes

        return frozen_table_bytes(self.params)

    def snapshot(self) -> str:
        """Serialize the COMPLETE serving state — KV cache, slot table,
        scheduler queue, per-request outputs and RNG states, prefix index,
        deadlines (as remaining budget), stats — through ``ft.checkpoint``'s
        atomic tmp+rename machinery. A replacement engine with the same
        configuration ``restore()``s it and resumes every in-flight decode
        mid-stream; decoding is deterministic, so greedy outputs are
        bit-identical to an uninterrupted run. Returns the checkpoint path.

        Runs at step boundaries only (``step()`` auto-snapshots via
        ``snapshot_every``); donor refcounts are zero there, so the state
        is closed under restore."""
        self._check_alive()
        if self.snapshot_dir is None:
            raise ValueError("snapshot() needs snapshot_dir")
        assert (self._slot_refs == 0).all(), \
            "snapshot mid-admission: donor rows are pinned"
        now = self._clock_fn()
        extra_rids = sorted(rid for rid, r in self._req.items()
                            if r.extra is not None)
        meta = {
            "version": 3,
            "fingerprint": self._fingerprint(),
            "step_count": self._step_count,
            "next_rid": self._next_rid,
            "prefix_clock": self._clock,
            "extra_rids": extra_rids,
            "requests": [
                [rid, {
                    "prompt": np.asarray(r.prompt, np.int32)
                    .reshape(-1).tolist(),
                    "max_new": int(r.max_new),
                    "stop_tokens": list(r.stop_tokens),
                    "sampling": {
                        "temperature": float(r.sampling.temperature),
                        "top_k": int(r.sampling.top_k),
                        "seed": int(r.sampling.seed)},
                    "deadline_ms": r.deadline_ms,
                    "tenant": r.tenant,
                }] for rid, r in self._req.items()],
            "out": [[rid, list(t)] for rid, t in self._out.items()],
            "finished": [[rid, list(t), self._status.get(rid, FINISHED),
                          self._error.get(rid)]
                         for rid, t in self._finished.items()],
            "deadline_remaining_s": [[rid, max(0.0, t - now)]
                                     for rid, t in self._deadline.items()],
            # submit/last-token times as AGES (like deadlines): absolute
            # clocks don't survive process boundaries, relative ones do
            "timing": {
                "submit_age_s": [[rid, now - t]
                                 for rid, t in self._submit_t.items()],
                "last_tok_age_s": [[rid, now - t]
                                   for rid, t in self._last_tok_t.items()],
            },
            "sched": self._sched.state_dict(),
            "rid_slot": [[rid, int(s)] for rid, s in self._rid_slot.items()],
            "slots": {
                "active": [bool(x) for x in self._active],
                "req": [None if x is None else int(x)
                        for x in self._slot_req],
                "pos": [int(x) for x in self._slot_pos],
                "last": [int(x) for x in self._slot_last],
                "left": [int(x) for x in self._slot_left],
                "touch": [int(x) for x in self._slot_touch],
                "prompt": [None if p is None else p.tolist()
                           for p in self._slot_prompt],
                "rng": [None if g is None else g.bit_generator.state
                        for g in self._slot_rng],
            },
            "prefix_index": [[int(m), raw.hex(), int(slot)]
                             for (m, raw), slot in
                             self._prefix_index.items()],
            "stats": {f: int(getattr(self.stats, f))
                      for f in self._STAT_FIELDS},
            "stats_shapes": {
                "prefill": sorted([int(b), int(s)]
                                  for b, s in self.stats.prefill_shapes),
                "decode": sorted(int(b)
                                 for b in self.stats.decode_shapes)},
            # fixed-bucket histograms serialize exactly: bucket counts in,
            # bucket counts out — restore resumes the same p50/p99
            "stats_hists": {
                "ttft": list(self.stats.ttft_ms.counts),
                "tok": list(self.stats.tok_ms.counts)},
            "stats_tenants": [
                [t, {"submitted": ts.submitted, "admitted": ts.admitted,
                     "completed": ts.completed, "rejected": ts.rejected,
                     "expired": ts.expired, "cancelled": ts.cancelled,
                     "aborted": ts.aborted, "tokens": ts.tokens,
                     "ttft": list(ts.ttft_ms.counts)}]
                for t, ts in sorted(self.stats.tenants.items())],
        }
        # the state tree is serialized OPAQUELY — flat canonical leaf
        # order, no knowledge of the family's tree shape (KV-cache group
        # lists, recurrent-state dicts, enc-dec layer stacks all work)
        state = {
            "cache": flatten_state_tree(self.cache),
            "meta": np.frombuffer(json.dumps(meta).encode("utf-8"),
                                  np.uint8),
        }
        if extra_rids:
            # per-request conditioning (enc-dec encoder frames) rides in
            # the array section; meta["extra_rids"] names the owners
            state["extra"] = {
                f"r{rid:08d}": np.asarray(self._req[rid].extra, np.float32)
                for rid in extra_rids}
        path = save_checkpoint(self.snapshot_dir, self._step_count, state)
        self.stats.snapshots += 1
        return path

    def restore(self, step: Optional[int] = None) -> int:
        """Load a snapshot into THIS engine (which must be fresh and idle —
        the replacement for a dead one, built with the same configuration)
        and resume serving exactly where the snapshot left off. Defaults to
        the latest snapshot in ``snapshot_dir``. Deadlines resume with the
        remaining budget they had at snapshot time. Returns the restored
        step count; ``stats.recoveries`` counts successful restores."""
        self._check_alive()
        if self.snapshot_dir is None:
            raise ValueError("restore() needs snapshot_dir")
        if self._active.any() or len(self._sched) or self._req \
                or self._finished:
            raise RuntimeError(
                "restore() needs a fresh idle engine (no queued, active, "
                "or unclaimed requests): build a replacement engine with "
                "the same configuration and restore into that"
            )
        if step is None:
            step = ckpt_latest_step(self.snapshot_dir)
            if step is None:
                raise FileNotFoundError(
                    f"no snapshot found in {self.snapshot_dir}")
        state = restore_checkpoint(self.snapshot_dir, int(step))
        meta = json.loads(bytes(np.asarray(state["meta"])).decode("utf-8"))
        if int(meta.get("version", 0)) != 3:
            raise ValueError(
                f"snapshot at step {step} has format version "
                f"{meta.get('version')!r}; this build reads version 3 "
                f"(tenant-aware scheduler + latency histograms) — "
                f"re-snapshot with the current build")
        fp = self._fingerprint()
        if meta["fingerprint"] != fp:
            raise ValueError(
                f"snapshot fingerprint mismatch: saved "
                f"{meta['fingerprint']} vs this engine {fp} — restore "
                f"needs an identically-configured engine"
            )
        if not meta["requests"] and not meta["finished"]:
            raise ValueError(
                f"snapshot at step {step} is EMPTY (no queued, running, "
                f"or unclaimed requests) — restoring it would resume "
                f"nothing. Snapshot after work is submitted, or restore "
                f"an earlier non-empty step explicitly")
        # rebuild the opaque state tree against the runner's template
        # (structure + dtypes — the checkpoint round-trips bf16 through
        # f32 files); leaf-count mismatches raise with the family named
        self.cache = unflatten_state_tree(
            self.runner.init_state(self.batch), state["cache"])
        self._step_count = int(meta["step_count"])
        self._next_rid = int(meta["next_rid"])
        self._clock = int(meta["prefix_clock"])
        self._req = {
            int(rid): Request(
                prompt=np.asarray(d["prompt"], np.int32),
                max_new=int(d["max_new"]),
                stop_tokens=tuple(d["stop_tokens"]),
                sampling=SamplingParams(
                    temperature=float(d["sampling"]["temperature"]),
                    top_k=int(d["sampling"]["top_k"]),
                    seed=int(d["sampling"]["seed"])),
                deadline_ms=d["deadline_ms"],
                tenant=d.get("tenant", "default"),
            ) for rid, d in meta["requests"]}
        for rid in meta.get("extra_rids", []):
            self._req[int(rid)].extra = np.asarray(
                state["extra"][f"r{int(rid):08d}"], np.float32)
        self._out = {int(rid): [int(t) for t in toks]
                     for rid, toks in meta["out"]}
        self._finished, self._status, self._error = {}, {}, {}
        for rid, toks, status, err in meta["finished"]:
            self._finished[int(rid)] = [int(t) for t in toks]
            self._status[int(rid)] = status
            self._error[int(rid)] = err
        now = self._clock_fn()
        self._deadline = {int(rid): now + float(rem)
                          for rid, rem in meta["deadline_remaining_s"]}
        tm = meta["timing"]
        self._submit_t = {int(rid): now - float(age)
                          for rid, age in tm["submit_age_s"]}
        self._last_tok_t = {int(rid): now - float(age)
                            for rid, age in tm["last_tok_age_s"]}
        self._sched = Scheduler(self.policy, max_queue=self.max_queue,
                                shed_policy=self.shed_policy,
                                tenant_weights=self.tenant_weights,
                                retry_hint=self.retry_after_hint)
        self._sched.load_state(meta["sched"])
        self._rid_slot = {int(rid): int(s) for rid, s in meta["rid_slot"]}
        sl = meta["slots"]
        self._active = np.asarray(sl["active"], bool)
        self._slot_req = [None if x is None else int(x) for x in sl["req"]]
        self._slot_pos = np.asarray(sl["pos"], np.int32)
        self._slot_last = np.asarray(sl["last"], np.int32)
        self._slot_left = np.asarray(sl["left"], np.int64)
        self._slot_touch = np.asarray(sl["touch"], np.int64)
        self._slot_prompt = [None if p is None else np.asarray(p, np.int32)
                             for p in sl["prompt"]]
        self._slot_rng = []
        for st in sl["rng"]:
            if st is None:
                self._slot_rng.append(None)
            else:
                g = np.random.default_rng(0)
                g.bit_generator.state = st
                self._slot_rng.append(g)
        self._slot_refs = np.zeros(self.batch, np.int64)
        self._prefix_index = OrderedDict(
            ((int(m), bytes.fromhex(raw)), int(slot))
            for m, raw, slot in meta["prefix_index"])
        st = meta["stats"]
        for f in self._STAT_FIELDS:
            setattr(self.stats, f, int(st.get(f, 0)))
        self.stats.prefill_shapes = {
            (int(b), int(s)) for b, s in meta["stats_shapes"]["prefill"]}
        self.stats.decode_shapes = {
            int(b) for b in meta["stats_shapes"]["decode"]}
        hists = meta["stats_hists"]
        self.stats.ttft_ms = LatencyHistogram(hists["ttft"])
        self.stats.tok_ms = LatencyHistogram(hists["tok"])
        self.stats.tenants = {}
        for t, d in meta["stats_tenants"]:
            ts = self.stats.tenant(t)
            ts.submitted = int(d["submitted"])
            ts.admitted = int(d["admitted"])
            ts.completed = int(d["completed"])
            ts.rejected = int(d["rejected"])
            ts.expired = int(d["expired"])
            ts.cancelled = int(d["cancelled"])
            ts.aborted = int(d["aborted"])
            ts.tokens = int(d["tokens"])
            ts.ttft_ms = LatencyHistogram(d["ttft"])
        self.stats.recoveries += 1
        return int(step)


# ---------------------------------------------------------------------------
# The wave baseline (pre-continuous-batching behavior)
# ---------------------------------------------------------------------------


class WaveEngine:
    """Fixed-wave batching baseline: requests are served in waves of
    ``batch``; every wave re-pads to its longest prompt (one recompile per
    distinct wave length) and every slot stalls until the wave's largest
    ``max_new`` finishes. Greedy only.

    Kept as the comparison point for ``benchmarks/serve_bench.py`` and the
    engine-equivalence tests. Shares the masked-padding convention with
    :class:`ServeEngine` (negative pad positions), so its greedy outputs are
    bit-identical to the continuous engine — the old implementation let pad
    tokens leak into attention, which this fixes.
    """

    def __init__(self, model, cfg: ModelConfig, params, batch: int,
                 cache_len: int, *, quantize: str = "off"):
        if cfg.family == "encdec":
            raise ValueError(
                "WaveEngine is a decoder-LM baseline: enc-dec serving "
                "needs a per-request encoder pass — use ServeEngine, "
                "which serves encdec configs through EncDecRunner")
        mix = recurrent_mixer_names(cfg)
        if int(batch) > 1 and mix:
            # a wave of one never pads; larger waves pad to the wave max,
            # and the wave path ships no MoE no-drop dispatch either —
            # batched hybrids belong on ServeEngine's RecurrentRunner
            raise ValueError(
                f"wave prefill left-pads prompts, and the wave baseline "
                f"gives {'/'.join(mix)} layers no pad-validity guarantee "
                f"for their recurrent state — serve this family with "
                f"ServeEngine (pad-aware bucketed prefill) or batch=1 "
                f"waves (never padded)")
        from repro.kernels.block_circulant.plan import (_check_quantize,
                                                        freeze_params)
        _check_quantize(quantize)
        if quantize != "off" and not cfg.swm.enabled:
            raise ValueError(
                "quantize applies to frozen circulant tables; this config "
                "has swm disabled")
        if cfg.swm.enabled:
            params = freeze_params(model.specs(), params, quantize=quantize)
        self.quantize = quantize
        self.model, self.cfg, self.params = model, cfg, params
        self.batch, self.cache_len = int(batch), int(cache_len)
        self.stats = EngineStats()
        self._prefill = jax.jit(make_prefill_step(model, cfg))
        self._decode = jax.jit(make_decode_step(model, cfg))

    @property
    def prefill_compiles(self) -> int:
        return int(self._prefill._cache_size())

    @property
    def decode_compiles(self) -> int:
        return int(self._decode._cache_size())

    def frozen_table_bytes(self) -> int:
        """Resident bytes of the frozen frequency tables (scales included)."""
        from repro.kernels.block_circulant.plan import frozen_table_bytes

        return frozen_table_bytes(self.params)

    def generate(self, requests: List[Request]) -> List[List[int]]:
        """Greedy-decode a list of requests in fixed batched waves."""
        for r in requests:
            _validate_request(r, self.cache_len)
            if r.sampling.temperature > 0 or r.stop_tokens:
                raise ValueError(
                    "WaveEngine is a greedy-only baseline: per-request "
                    "sampling and stop tokens need ServeEngine"
                )
            if r.deadline_ms is not None:
                raise ValueError(
                    "WaveEngine has no request lifecycle: deadlines, "
                    "cancellation, and load shedding need ServeEngine"
                )
        results: List[List[int]] = []
        for i in range(0, len(requests), self.batch):
            results.extend(self._run_wave(requests[i: i + self.batch]))
        return results

    def _run_wave(self, wave: List[Request]) -> List[List[int]]:
        B = self.batch
        plen = max(r.prompt_len for r in wave)
        toks = np.zeros((B, plen), np.int32)
        pos = np.zeros((B, plen), np.int32)
        lens = np.zeros(B, np.int32)
        for j in range(B):
            L = wave[j].prompt_len if j < len(wave) else 0
            lens[j] = L
            if L:
                toks[j, plen - L:] = np.asarray(
                    wave[j].prompt, np.int32).reshape(-1)
            pos[j] = np.arange(plen, dtype=np.int32) - (plen - L)
        cache = self.model.init_cache(B, self.cache_len)
        logits, cache = self._prefill(
            self.params, jnp.asarray(toks), cache, None, jnp.asarray(pos)
        )
        self.stats.prefill_calls += 1
        self.stats.prefill_shapes.add((B, plen))
        outs: List[List[int]] = [[] for _ in wave]
        cur = np.argmax(np.asarray(logits), axis=-1).astype(np.int32)
        for j, r in enumerate(wave):
            outs[j].append(int(cur[j]))
            self.stats.tokens_generated += 1
        max_new = max(r.max_new for r in wave)
        for t in range(max_new - 1):
            logits, cache = self._decode(
                self.params, jnp.asarray(cur[:, None]), cache,
                jnp.asarray(lens + t),
            )
            self.stats.decode_steps += 1
            self.stats.slot_steps_active += sum(
                1 for r in wave if t + 1 < r.max_new)
            self.stats.decode_rows += B
            self.stats.decode_shapes.add(B)
            cur = np.argmax(np.asarray(logits), axis=-1).astype(np.int32)
            for j, r in enumerate(wave):
                if t + 1 < r.max_new:
                    outs[j].append(int(cur[j]))
                    self.stats.tokens_generated += 1
        for _ in wave:
            self.stats.requests_completed += 1
        return outs
