"""Serving fault-tolerance primitives: request lifecycle states, error
classification, load-shedding backpressure, and the serve-path chaos
injector.

The engine (``repro.serve.engine``) is the paper's always-on streaming
deployment target (FPGA/IoT inference, C-LSTM's continuous ASR argument,
arXiv:1803.06305): preemption, transient device faults, and overload are
the *normal* operating regime, not exceptions. This module holds the
pieces of the robustness layer that are independent of the engine itself:

* **Lifecycle states** — every request ends in exactly one terminal state
  (:data:`TERMINAL_STATES`); ``FINISHED`` is the only success. The engine's
  ``poll`` surfaces the state plus a human-readable ``error`` reason.
* **Error classification** — :func:`classify_error` splits launch
  exceptions into ``"request"`` (raised *before* the executable ran, so
  the donated cache buffers are still valid: abort only the implicated
  requests and keep serving) and ``"fatal"`` (anything that may have
  fired a donated executable partway: the cache handle cannot be
  trusted, the engine must die and a replacement restores a snapshot).
* **Backpressure** — :class:`QueueFullError` is the reject-new shedding
  signal: it carries the queue depth so callers can back off.
* **Chaos** — :class:`ServeFaultInjector` extends the training-side
  :class:`repro.ft.driver.FaultInjector` with serve-path hooks (per-kind
  launch schedules, an engine-fatal schedule, artificial step delays,
  seeded random faults) so the chaos suite can drive every failure path
  deterministically. :class:`ManualClock` makes deadline expiry testable
  without wall-clock sleeps.
* **Generic state-tree serialization** — :func:`flatten_state_tree` /
  :func:`unflatten_state_tree` turn any runner state tree (KV-cache
  lists, recurrent-state dicts, enc-dec layer stacks) into the flat
  string-keyed dict ``ft.checkpoint`` persists, and back — snapshot/
  restore never needs to know a family's tree shape.
"""

from __future__ import annotations

import time
from typing import Iterable, Optional, Set, Tuple

from repro.ft.driver import FaultInjector

__all__ = [
    "QUEUED", "RUNNING", "FINISHED", "FAILED", "EXPIRED", "CANCELLED",
    "TERMINAL_STATES",
    "QueueFullError", "EngineFatalError", "InjectedFault",
    "InjectedEngineFatal",
    "classify_error",
    "ManualClock",
    "ServeFaultInjector",
    "flatten_state_tree", "unflatten_state_tree",
]


# ---------------------------------------------------------------------------
# Generic runner-state serialization (snapshot/restore)
# ---------------------------------------------------------------------------


def flatten_state_tree(tree) -> dict:
    """Any pytree of arrays -> a flat ``{"s00000": leaf, ...}`` dict in
    canonical (``jax.tree_util``) leaf order — deterministic across runs,
    so a snapshot taken by one engine restores into a fresh engine built
    from the same config."""
    import jax

    leaves = jax.tree_util.tree_leaves(tree)
    return {f"s{i:05d}": leaf for i, leaf in enumerate(leaves)}


def unflatten_state_tree(template, flat: dict):
    """Inverse of :func:`flatten_state_tree`: rebuild ``template``'s
    structure from the flat dict, casting each leaf to the template
    leaf's dtype (checkpoints round-trip bf16 through f32)."""
    import jax
    import jax.numpy as jnp

    t_leaves, treedef = jax.tree_util.tree_flatten(template)
    keys = [f"s{i:05d}" for i in range(len(t_leaves))]
    if sorted(flat) != keys:
        raise ValueError(
            f"snapshot state has {len(flat)} leaves, the runner's state "
            f"tree has {len(t_leaves)} — the snapshot was taken by a "
            f"different model family or config")
    leaves = [jnp.asarray(flat[k], t.dtype) for k, t in zip(keys, t_leaves)]
    return jax.tree_util.tree_unflatten(treedef, leaves)


# ---------------------------------------------------------------------------
# Request lifecycle states
# ---------------------------------------------------------------------------

QUEUED = "QUEUED"          # submitted, waiting for a slot
RUNNING = "RUNNING"        # admitted to a cache slot, decoding
FINISHED = "FINISHED"      # terminal: ran to stop token / max_new
FAILED = "FAILED"          # terminal: isolated error (launch fault, NaN)
EXPIRED = "EXPIRED"        # terminal: deadline_ms exceeded
CANCELLED = "CANCELLED"    # terminal: cancel() or load shedding

TERMINAL_STATES = frozenset((FINISHED, FAILED, EXPIRED, CANCELLED))


# ---------------------------------------------------------------------------
# Errors
# ---------------------------------------------------------------------------


class QueueFullError(RuntimeError):
    """Reject-new load shedding: the admission queue is at ``max_queue``.

    Backpressure signal — the request was NOT enqueued; the caller should
    retry after draining (``depth``/``max_queue`` say how far over).
    ``retry_after_hint`` (seconds, or None before the engine has observed
    any drain) estimates when a queue slot should free: queue depth over
    the engine's recently-observed drain rate. Callers back off
    proportionally instead of spinning — the async front-end and
    ``launch/serve.py --stream`` both consume it."""

    def __init__(self, depth: int, max_queue: int,
                 retry_after_hint: Optional[float] = None):
        self.depth = int(depth)
        self.max_queue = int(max_queue)
        self.retry_after_hint = (None if retry_after_hint is None
                                 else float(retry_after_hint))
        hint = ("" if self.retry_after_hint is None
                else f" (retry_after_hint={self.retry_after_hint:.3g}s)")
        super().__init__(
            f"admission queue full ({depth} queued, max_queue={max_queue}); "
            f"request rejected — retry after the engine drains "
            f"(backpressure){hint}"
        )


class EngineFatalError(RuntimeError):
    """The engine hit an unrecoverable serving error (a launch may have
    consumed its donated cache buffer partway). The engine is dead; build a
    replacement engine and ``restore()`` its latest snapshot."""


class InjectedFault(RuntimeError):
    """Chaos-injected *transient* launch failure. Raised BEFORE the
    executable runs, so donated buffers are intact — classified
    ``"request"`` (isolate, keep serving)."""


class InjectedEngineFatal(RuntimeError):
    """Chaos-injected engine-fatal fault — classified ``"fatal"``
    (kill the engine, recover via snapshot/restore)."""


def classify_error(e: BaseException) -> str:
    """``"request"`` | ``"fatal"`` for an exception raised around a
    prefill/decode launch.

    Only faults known to fire *before* the executable consumed its donated
    buffers (:class:`InjectedFault`) are request-isolatable; everything
    else — device errors, XLA runtime errors, injected fatals — may have
    invalidated the in-place cache and is engine-fatal."""
    return "request" if isinstance(e, InjectedFault) else "fatal"


# ---------------------------------------------------------------------------
# Deterministic clock (deadline tests / chaos without wall-clock sleeps)
# ---------------------------------------------------------------------------


class ManualClock:
    """Injectable monotonic clock: ``clock()`` reads, ``advance()`` moves.

    The engine takes any zero-arg callable returning seconds
    (``time.monotonic`` by default); tests and the chaos harness pass a
    ManualClock so deadline expiry and step-delay injection are exact and
    instant instead of sleep-based."""

    def __init__(self, t: float = 0.0):
        self.t = float(t)

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> float:
        if dt < 0:
            raise ValueError(f"clock cannot run backwards (dt={dt})")
        self.t += float(dt)
        return self.t


# ---------------------------------------------------------------------------
# Serve-path chaos injector
# ---------------------------------------------------------------------------


class ServeFaultInjector(FaultInjector):
    """Deterministic fault schedule for the serving path.

    Extends the training-side :class:`FaultInjector` (which keys faults by
    train step) with serve-shaped hooks:

    * ``fail_prefill_at`` / ``fail_decode_at`` — successful-launch indices
      (the engine's ``stats.prefill_calls`` / ``stats.decode_steps`` at
      attempt time) at which :meth:`on_launch` raises a *transient*
      :class:`InjectedFault`. Each scheduled index fires at most once, so
      a retried decode launch succeeds on the second attempt.
    * ``fatal_decode_at`` / ``fatal_prefill_at`` — launch indices raising
      :class:`InjectedEngineFatal` (snapshot/restore recovery path; the
      prefill schedule kills the engine mid-admission, exercising the
      supervisor's re-queue of never-admitted work).
    * ``delay_at`` / ``delay_s`` — engine step indices at which
      :meth:`on_step` injects an artificial stall: advancing the supplied
      ``clock`` (a :class:`ManualClock`) when given, else sleeping.
    * ``p_fail`` / ``seed`` — seeded random transient launch failures on
      top of the explicit schedule; the same seed reproduces the same
      fault pattern exactly (test-enforced).

    The audit trail is tenant-aware: the engine passes the set of tenants
    implicated in each launch (``accepts_tenants`` advertises the richer
    hook signature so hand-rolled injectors with the old two-argument
    ``on_launch`` keep working), and every ``launch_log`` entry is
    ``(kind, index, action, tenants)`` — a post-mortem can attribute an
    injected fault to the tenant workload it hit.
    """

    # the engine checks this before passing the ``tenants=`` kwarg, so
    # injector subclasses that override the plain two-argument on_launch
    # signature stay compatible
    accepts_tenants = True

    def __init__(self, fail_prefill_at: Iterable[int] = (),
                 fail_decode_at: Iterable[int] = (),
                 fatal_decode_at: Iterable[int] = (),
                 fatal_prefill_at: Iterable[int] = (),
                 delay_at: Iterable[int] = (), delay_s: float = 0.0,
                 p_fail: float = 0.0, seed: int = 0,
                 clock: Optional[ManualClock] = None):
        super().__init__(fail_at=(), delay_at=delay_at, delay_s=delay_s,
                         p_fail=p_fail, seed=seed)
        self.fail_prefill_at = set(int(i) for i in fail_prefill_at)
        self.fail_decode_at = set(int(i) for i in fail_decode_at)
        self.fatal_decode_at = set(int(i) for i in fatal_decode_at)
        self.fatal_prefill_at = set(int(i) for i in fatal_prefill_at)
        self.clock = clock
        self.launch_log: list = []  # (kind, index, action, tenants) audit

    # -- engine hooks -------------------------------------------------------
    def on_step(self, step: int) -> None:
        """Called at each engine step boundary: artificial step delays."""
        if step in self.delay_at:
            if self.clock is not None:
                self.clock.advance(self.delay_s)
            else:
                time.sleep(self.delay_s)

    def on_launch(self, kind: str, index: int,
                  tenants: Tuple[str, ...] = ()) -> None:
        """Called immediately BEFORE each prefill/decode launch (donated
        buffers still intact). Raises the scheduled fault, once per
        scheduled (kind, index). ``tenants`` names the tenants whose
        requests ride in the launch (sorted; audit only — the schedule
        never keys on it)."""
        key: Tuple[str, int] = (kind, int(index))
        tenants = tuple(tenants)
        if key in self.fired:
            return
        fatal: Set[int] = (self.fatal_prefill_at if kind == "prefill"
                           else self.fatal_decode_at)
        if index in fatal:
            self.fired.add(key)
            self.launch_log.append((kind, index, "fatal", tenants))
            raise InjectedEngineFatal(
                f"injected engine-fatal fault at {kind} launch {index}")
        sched: Set[int] = (self.fail_prefill_at if kind == "prefill"
                           else self.fail_decode_at)
        if index in sched:
            self.fired.add(key)
            self.launch_log.append((kind, index, "fail", tenants))
            raise InjectedFault(
                f"injected {kind} launch failure at launch {index}")
        if self.p_fail > 0.0 and self.rng.random() < self.p_fail:
            self.fired.add(key)
            self.launch_log.append((kind, index, "fail", tenants))
            raise InjectedFault(
                f"injected random {kind} launch failure at launch {index}")
        self.launch_log.append((kind, index, "ok", tenants))
