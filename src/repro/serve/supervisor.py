"""Self-healing engine supervision: fatal → restore → re-queue → resume.

PR 6 built the failure machinery — ``EngineFatalError`` kills the engine,
``snapshot()``/``restore()`` move the complete serving state through the
``ft.checkpoint`` atomics — but recovery was manual: a dead engine stayed
dead until a human built a replacement and called ``restore()``. The
:class:`Supervisor` closes that loop for the always-on deployment shape
the paper targets (FPGA/IoT streaming, C-LSTM's continuous ASR argument,
arXiv:1803.06305):

* **Ownership** — the supervisor holds the engine and an ``engine
  factory``; callers use the supervisor's ``submit/step/poll/drain``
  and never touch a dead engine.
* **Self-heal** — a ``step()`` that raises :class:`EngineFatalError`
  builds a replacement from the factory and restores the latest
  snapshot. Work submitted *after* that snapshot (the engine forgot it)
  is re-submitted in original order under fresh engine rids — the
  supervisor keeps its own rid namespace and a remap table, so caller
  handles survive any number of heals.
* **At-most-once emission** — restoring rolls token streams back to the
  snapshot; deterministic decoding (greedy argmax / captured RNG state)
  then regenerates the identical tokens. :meth:`take_new_tokens` tracks
  a per-request high-water mark and emits only tokens beyond it, so a
  consumer sees every token exactly once across any number of heals —
  zero duplicates, zero losses (chaos-tested against a no-fault run).
* **Warm restart** — with a :class:`~repro.serve.prefix_store.
  PrefixStore` attached to the engines, the replacement adopts the
  hottest spilled prefix donors (``engine.adopt_prefixes``) before
  taking traffic, so shared prompt heads stay warm across engine death.

The supervisor is single-threaded and synchronous, mirroring the engine;
the asyncio front-end (``repro.serve.frontend``) drives either one.
"""

from __future__ import annotations

from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.ft.checkpoint import available_steps
from repro.serve.engine import Request, RequestState, ServeEngine
from repro.serve.guard import (EngineFatalError, QueueFullError,
                               TERMINAL_STATES)

__all__ = ["Supervisor", "SupervisorGaveUp"]


class SupervisorGaveUp(RuntimeError):
    """The engine died more than ``max_restarts`` times; the last fatal
    is chained. Work already delivered stays delivered (the at-most-once
    ledger survives), but the supervisor stops healing."""


class Supervisor:
    """Wrap a :class:`ServeEngine` (or anything engine-shaped) with
    automatic fatal recovery.

    ``factory`` builds a fresh, identically-configured engine; it is
    called once at construction and once per heal. Engines must be built
    with a ``snapshot_dir`` (the heal path restores the latest snapshot;
    without snapshots every heal replays from scratch, which still
    converges but repays all compute) — pass ``require_snapshots=False``
    to allow the replay-from-scratch mode explicitly.

    The supervisor's request ids are its OWN namespace: ``submit``
    returns a supervisor rid, and every public method takes supervisor
    rids. Internally each maps to the current engine's rid
    (re-submission after a heal re-maps it).
    """

    def __init__(self, factory: Callable[[], ServeEngine], *,
                 max_restarts: int = 3,
                 require_snapshots: bool = True):
        self.factory = factory
        self.max_restarts = int(max_restarts)
        self.engine = factory()
        if require_snapshots and self.engine.snapshot_dir is None:
            raise ValueError(
                "Supervisor needs engines built with snapshot_dir (the "
                "heal path restores the latest snapshot); pass "
                "require_snapshots=False to accept replay-from-scratch "
                "recovery")
        self.restarts = 0
        self._next = 0                       # supervisor rid namespace
        self._requests: Dict[int, Request] = {}   # submit-order ledger
        self._order: List[int] = []
        self._eng_rid: Dict[int, int] = {}   # sup rid -> engine rid
        self._emitted: Dict[int, int] = {}   # at-most-once high-water mark
        # terminal results claimed from a PREVIOUS engine (drained there)
        # or carried across a heal; poll()/drain() serve these first
        self._final: Dict[int, RequestState] = {}
        # adopt stored prefixes into the cold first engine too
        self.engine.adopt_prefixes()

    # -- public API ---------------------------------------------------------
    def submit(self, request: Request) -> int:
        """Submit through to the engine; returns a SUPERVISOR rid (stable
        across heals). Backpressure (:class:`QueueFullError`) propagates
        to the caller — the async front-end turns it into bounded
        retry-with-jitter. A fatal raised by the submit path heals and
        retries once."""
        for attempt in (0, 1):
            try:
                eng_rid = self.engine.submit(request)
                break
            except QueueFullError:
                raise
            except EngineFatalError:
                if attempt:
                    raise
                self._heal()
        sup_rid = self._next
        self._next += 1
        self._requests[sup_rid] = request
        self._order.append(sup_rid)
        self._eng_rid[sup_rid] = eng_rid
        self._emitted[sup_rid] = 0
        return sup_rid

    def step(self) -> bool:
        """Advance the engine one round; heal on fatal. Returns True
        while work remains (including the step a heal happened on)."""
        try:
            return self.engine.step()
        except EngineFatalError:
            self._heal()
            return True

    def poll(self, sup_rid: int) -> RequestState:
        """Engine ``poll`` with the supervisor rid, served from the
        claimed-results ledger for requests drained before a heal."""
        if sup_rid in self._final:
            return self._final[sup_rid]
        if sup_rid not in self._eng_rid:
            raise KeyError(f"unknown request id {sup_rid}")
        st = self.engine.poll(self._eng_rid[sup_rid])
        return dataclass_replace_rid(st, sup_rid)

    def take_new_tokens(self, sup_rid: int) -> Tuple[List[int],
                                                     RequestState]:
        """The at-most-once stream: tokens beyond this request's
        high-water mark (empty while a healed engine is still
        regenerating already-delivered tokens), plus the current state.
        Every token is returned by exactly one call across any number of
        heals."""
        st = self.poll(sup_rid)
        mark = self._emitted.get(sup_rid, 0)
        toks = list(st.tokens)
        new = toks[mark:]
        if len(toks) > mark:
            self._emitted[sup_rid] = len(toks)
        return new, st

    def cancel(self, sup_rid: int) -> bool:
        if sup_rid in self._final:
            return False
        return self.engine.cancel(self._eng_rid[sup_rid])

    def drain(self, sup_rids: Optional[Sequence[int]] = None
              ) -> Dict[int, List[int]]:
        """Run to idle (healing as needed) and claim finished outputs by
        supervisor rid. Mirrors ``engine.drain``."""
        while self.step():
            pass
        if sup_rids is None:
            sup_rids = list(self._order)
        out: Dict[int, List[int]] = {}
        claim: List[int] = []
        for r in sup_rids:
            if r in self._final:
                out[r] = list(self._final[r].tokens)
            else:
                claim.append(r)
        if claim:
            # capture terminal states BEFORE engine.drain forgets them,
            # so later poll()/take_new_tokens() keep working
            states = {r: self.poll(r) for r in claim}
            got = self.engine.drain([self._eng_rid[r] for r in claim])
            for r in claim:
                self._final[r] = states[r]
                out[r] = got[self._eng_rid[r]]
        return out

    def snapshot(self) -> str:
        return self.engine.snapshot()

    @property
    def stats(self):
        return self.engine.stats

    # -- heal ---------------------------------------------------------------
    def _heal(self) -> None:
        self.restarts += 1
        if self.restarts > self.max_restarts:
            raise SupervisorGaveUp(
                f"engine died {self.restarts} times "
                f"(max_restarts={self.max_restarts}); last fatal: "
                f"{self.engine._fatal}")
        dead = self.engine
        self.engine = self.factory()
        if self.engine.snapshot_dir is not None:
            # newest snapshot first, walking back past any the engine
            # refuses (empty, corrupt, version-mismatched) — a refused
            # LATEST must not strand recoverable older state
            for step in reversed(available_steps(self.engine.snapshot_dir)):
                try:
                    self.engine.restore(step)
                    break
                except FileNotFoundError:
                    break             # no snapshot at all: replay everything
                except ValueError:
                    continue          # refused this step; try an older one
        # warm-start on spilled prefix donors before taking traffic
        self.engine.adopt_prefixes()
        self._requeue_missing()
        del dead

    def _requeue_missing(self) -> None:
        """Re-submit, in original submit order, every supervisor request
        the restored engine does not know: work submitted after the
        snapshot (or all work, when no snapshot existed). Token streams
        restart from zero on the engine side; the emission high-water
        mark makes redelivery impossible. Backpressure during re-queue is
        absorbed by stepping the engine (queue space frees as slots
        drain)."""
        for sup_rid in self._order:
            if sup_rid in self._final:
                continue
            eng_rid = self._eng_rid[sup_rid]
            try:
                self.engine.poll(eng_rid)
                continue              # the snapshot carried it
            except KeyError:
                pass
            req = self._requests[sup_rid]
            while True:
                try:
                    self._eng_rid[sup_rid] = self.engine.submit(req)
                    break
                except QueueFullError:
                    self.engine.step()

    # -- ledger maintenance -------------------------------------------------
    def retire(self, sup_rid: int) -> None:
        """Forget a terminal, fully-delivered request (frees the ledger;
        optional — the ledger is small: one Request + two ints per
        in-flight id)."""
        st = self.poll(sup_rid)
        if st.status not in TERMINAL_STATES:
            raise ValueError(f"request {sup_rid} is not terminal")
        eng_rid = self._eng_rid.pop(sup_rid, None)
        if eng_rid is not None and sup_rid not in self._final:
            try:
                self.engine.drain([eng_rid])
            except KeyError:
                pass
        self._final.pop(sup_rid, None)
        self._requests.pop(sup_rid, None)
        self._emitted.pop(sup_rid, None)
        if sup_rid in self._order:
            self._order.remove(sup_rid)


def dataclass_replace_rid(st: RequestState, rid: int) -> RequestState:
    return RequestState(req_id=rid, done=st.done, tokens=st.tokens,
                        status=st.status, error=st.error)
