"""Multi-tenant asyncio front-end: admission, fairness, SLOs, backoff.

The engine (and its :class:`~repro.serve.supervisor.Supervisor` wrapper)
is synchronous and single-stepped — the right shape for a device-bound
inner loop, the wrong shape for "heavy traffic from millions of users"
(ROADMAP north-star). :class:`AsyncFrontend` is the concurrency layer on
top of the unchanged ``submit/step/poll/drain`` API:

* **Per-tenant admission** — each tenant gets a token bucket
  (``rate``/``burst`` from its :class:`TenantConfig`); a submit first
  pays one bucket token (awaiting refill when empty) so one tenant's
  burst cannot monopolise the engine's admission queue.
* **Backpressure-aware submit** — ``await frontend.submit(...)``
  converts the engine's :class:`~repro.serve.guard.QueueFullError` into
  a bounded retry with jitter, sleeping ``retry_after_hint`` (the
  engine's queue-depth/drain-rate estimate) scaled by attempt, and
  raises :class:`TenantRejectedError` — tenant-scoped, carrying the
  attempt count and last hint — once the budget is exhausted.
* **SLO classes** — ``interactive``/``standard``/``batch`` map to a
  default ``Request.deadline_ms`` and a DRR fairness weight
  (:data:`SLO_CLASSES`); a request that sets its own ``deadline_ms``
  keeps it. The matching ``tenant_weights`` dict for
  ``ServeEngine(policy="fair", ...)`` comes from
  :meth:`AsyncFrontend.tenant_weights`.
* **Driver loop** — :meth:`run` steps the engine while work remains,
  yielding to the event loop between steps so concurrent ``submit`` /
  ``stream`` coroutines interleave; :meth:`stream` yields each
  request's new tokens as they appear (via the supervisor's
  at-most-once ``take_new_tokens`` when available, else ``poll`` with a
  local high-water mark).

Determinism: all sleeps go through an injectable ``sleep`` coroutine
and jitter through a seeded RNG, so tests drive the whole front-end on
a manual clock without wall-clock waits.
"""

from __future__ import annotations

import asyncio
import dataclasses
import random
import time
from typing import (AsyncIterator, Callable, Dict, List, Optional, Tuple)

from repro.serve.engine import Request, RequestState
from repro.serve.guard import TERMINAL_STATES, QueueFullError

__all__ = [
    "SLO_CLASSES", "SLOClass", "TenantConfig", "TokenBucket",
    "TenantRejectedError", "AsyncFrontend",
]


@dataclasses.dataclass(frozen=True)
class SLOClass:
    """A latency/priority service class: the default request deadline
    and the tenant's weighted-DRR share (``Scheduler`` ``fair`` policy
    quantum)."""
    name: str
    deadline_ms: Optional[float]   # None = no deadline (batch)
    weight: int


SLO_CLASSES: Dict[str, SLOClass] = {
    "interactive": SLOClass("interactive", deadline_ms=2000.0, weight=4),
    "standard": SLOClass("standard", deadline_ms=10000.0, weight=2),
    "batch": SLOClass("batch", deadline_ms=None, weight=1),
}


@dataclasses.dataclass
class TenantConfig:
    """Per-tenant admission policy: SLO class plus token-bucket rate
    limiting (``rate`` submits/second sustained, ``burst`` back-to-back).
    """
    name: str
    slo: str = "standard"
    rate: float = 100.0
    burst: int = 10

    def __post_init__(self):
        if self.slo not in SLO_CLASSES:
            raise ValueError(
                f"unknown SLO class {self.slo!r} for tenant "
                f"{self.name!r}; choose from {sorted(SLO_CLASSES)}")
        if self.rate <= 0 or self.burst < 1:
            raise ValueError(
                f"tenant {self.name!r} needs rate > 0 and burst >= 1 "
                f"(got rate={self.rate}, burst={self.burst})")

    @property
    def slo_class(self) -> SLOClass:
        return SLO_CLASSES[self.slo]


class TokenBucket:
    """Classic token bucket on an injectable clock: ``try_take`` is the
    non-blocking probe, ``wait_time`` says how long until a token
    accrues. Refill is continuous (``rate`` tokens/second, capped at
    ``burst``)."""

    def __init__(self, rate: float, burst: int,
                 clock: Callable[[], float] = time.monotonic):
        self.rate = float(rate)
        self.burst = float(burst)
        self.clock = clock
        self.tokens = float(burst)
        self._last = clock()

    def _refill(self) -> None:
        now = self.clock()
        dt = now - self._last
        if dt > 0:
            self.tokens = min(self.burst, self.tokens + dt * self.rate)
            self._last = now

    def try_take(self) -> bool:
        self._refill()
        if self.tokens >= 1.0:
            self.tokens -= 1.0
            return True
        return False

    def wait_time(self) -> float:
        """Seconds until one token is available (0 if one already is)."""
        self._refill()
        if self.tokens >= 1.0:
            return 0.0
        return (1.0 - self.tokens) / self.rate


class TenantRejectedError(RuntimeError):
    """Tenant-scoped terminal rejection: the bounded retry budget for
    this submit is exhausted (engine queue stayed full) — shed THIS
    tenant's request without touching other tenants' traffic."""

    def __init__(self, tenant: str, attempts: int,
                 last_hint: Optional[float]):
        self.tenant = tenant
        self.attempts = int(attempts)
        self.last_hint = last_hint
        hint = ("" if last_hint is None
                else f"; engine suggested retry_after={last_hint:.3g}s")
        super().__init__(
            f"tenant {tenant!r}: request rejected after {attempts} "
            f"admission attempts (queue full){hint}")


class AsyncFrontend:
    """Asyncio driver for a :class:`ServeEngine` or
    :class:`~repro.serve.supervisor.Supervisor` (anything with
    ``submit/step/poll``; ``take_new_tokens`` is used when present).

    ``tenants`` maps tenant name to :class:`TenantConfig`; unknown
    tenants are rejected at submit (explicit registration is the
    admission contract). ``sleep``/``clock``/``rng`` are injectable for
    deterministic tests.
    """

    def __init__(self, engine, tenants: Dict[str, TenantConfig], *,
                 max_retries: int = 4,
                 base_backoff_s: float = 0.05,
                 max_backoff_s: float = 2.0,
                 jitter: float = 0.25,
                 seed: int = 0,
                 clock: Callable[[], float] = time.monotonic,
                 sleep: Optional[Callable[[float], "asyncio.Future"]] = None):
        if not tenants:
            raise ValueError("AsyncFrontend needs at least one tenant")
        self.engine = engine
        self.tenants = dict(tenants)
        self.max_retries = int(max_retries)
        self.base_backoff_s = float(base_backoff_s)
        self.max_backoff_s = float(max_backoff_s)
        self.jitter = float(jitter)
        self.clock = clock
        self.sleep = sleep if sleep is not None else asyncio.sleep
        self._rng = random.Random(seed)
        self._buckets = {
            name: TokenBucket(cfg.rate, cfg.burst, clock=clock)
            for name, cfg in self.tenants.items()
        }
        self.rejections: Dict[str, int] = {name: 0 for name in self.tenants}

    def tenant_weights(self) -> Dict[str, int]:
        """The ``ServeEngine(tenant_weights=...)`` dict implied by each
        tenant's SLO class — build the engine's ``fair`` scheduler from
        the same source of truth as the front-end."""
        return {name: cfg.slo_class.weight
                for name, cfg in self.tenants.items()}

    # -- admission ----------------------------------------------------------
    def _prepare(self, tenant: str, request: Request) -> Request:
        cfg = self.tenants.get(tenant)
        if cfg is None:
            raise KeyError(
                f"unregistered tenant {tenant!r}; registered: "
                f"{sorted(self.tenants)}")
        updates: Dict[str, object] = {}
        if request.tenant != tenant:
            updates["tenant"] = tenant
        if request.deadline_ms is None \
                and cfg.slo_class.deadline_ms is not None:
            updates["deadline_ms"] = cfg.slo_class.deadline_ms
        return dataclasses.replace(request, **updates) if updates \
            else request

    def _backoff(self, attempt: int, hint: Optional[float]) -> float:
        """Proportional backoff: the engine's hint when it has one
        (scaled by attempt), else exponential from ``base_backoff_s``;
        ± ``jitter`` fraction either way, capped at ``max_backoff_s``."""
        base = (hint * (attempt + 1) if hint is not None
                else self.base_backoff_s * (2.0 ** attempt))
        base *= 1.0 + self.jitter * (2.0 * self._rng.random() - 1.0)
        return max(0.0, min(self.max_backoff_s, base))

    async def submit(self, tenant: str, request: Request) -> int:
        """Admit one request: pay the tenant's bucket token (awaiting
        refill), stamp the SLO deadline, then submit with bounded
        retry-with-jitter on :class:`QueueFullError`. Returns the engine
        (or supervisor) rid; raises :class:`TenantRejectedError` when
        the retry budget is spent."""
        request = self._prepare(tenant, request)
        bucket = self._buckets[tenant]
        while not bucket.try_take():
            await self.sleep(bucket.wait_time())
        last_hint: Optional[float] = None
        for attempt in range(self.max_retries + 1):
            try:
                return self.engine.submit(request)
            except QueueFullError as e:
                last_hint = e.retry_after_hint
                if attempt >= self.max_retries:
                    break
                await self.sleep(self._backoff(attempt, last_hint))
        self.rejections[tenant] += 1
        raise TenantRejectedError(tenant, self.max_retries + 1, last_hint)

    # -- driving ------------------------------------------------------------
    async def run(self, *, idle_rounds: int = 1) -> int:
        """Step the engine until it reports no work for ``idle_rounds``
        consecutive rounds, yielding to the event loop between steps so
        submit/stream coroutines interleave. Returns steps taken."""
        steps = 0
        idle = 0
        while idle < idle_rounds:
            if self.engine.step():
                idle = 0
            else:
                idle += 1
            steps += 1
            await self.sleep(0)
        return steps

    def _take_new(self, rid: int,
                  mark: List[int]) -> Tuple[List[int], RequestState]:
        take = getattr(self.engine, "take_new_tokens", None)
        if take is not None:
            return take(rid)
        st = self.engine.poll(rid)
        toks = list(st.tokens)
        new = toks[mark[0]:]
        mark[0] = max(mark[0], len(toks))
        return new, st

    async def stream(self, rid: int) -> AsyncIterator[int]:
        """Yield the request's tokens as they appear, exactly once each,
        until it terminates. Pair with a concurrently-running
        :meth:`run`."""
        mark = [0]
        while True:
            new, st = self._take_new(rid, mark)
            for t in new:
                yield t
            if st.status in TERMINAL_STATES:
                return
            await self.sleep(0)

    async def result(self, rid: int) -> RequestState:
        """Await a request's terminal state (drive with :meth:`run`)."""
        mark = [0]
        while True:
            _, st = self._take_new(rid, mark)
            if st.status in TERMINAL_STATES:
                return st
            await self.sleep(0)
