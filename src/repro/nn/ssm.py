"""Mamba (selective SSM) block — the jamba hybrid's attention-free mixer.

SWM applicability (DESIGN.md §Arch-applicability): the in/x/dt/out
*projections* are plain weight GEMMs and are circulant-compressible; the
selective scan itself (A, Δ recurrence) is not a weight matrix and is left
untouched.

Training/prefill use a sequential ``lax.scan`` over time with a
(B, d_inner, d_state) carry — memory-light and compile-fast. (A chunked
SSD-style matmul scan is the Pallas hot-path candidate; noted in DESIGN.md.)
Decode carries {conv window, ssm state} in the cache: O(1) per token — this
is what makes jamba's long_500k cell trivial memory-wise.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.nn.linear import Linear
from repro.nn.module import ParamSpec

__all__ = ["Mamba", "init_mamba_cache"]


def init_mamba_cache(batch: int, d_inner: int, d_state: int, d_conv: int, dtype):
    return {
        "conv": jnp.zeros((batch, d_conv - 1, d_inner), dtype),
        "ssm": jnp.zeros((batch, d_inner, d_state), jnp.float32),
    }


@dataclasses.dataclass(frozen=True)
class Mamba:
    cfg: ModelConfig
    stack: Tuple[int, ...] = ()

    @property
    def d_inner(self) -> int:
        return self.cfg.mamba_expand * self.cfg.d_model

    @property
    def dt_rank(self) -> int:
        return self.cfg.mamba_dt_rank or max(1, self.cfg.d_model // 16)

    def _lin(self, i, o, ia, oa, family="mamba_proj"):
        return Linear(
            in_dim=i, out_dim=o, in_axis=ia, out_axis=oa, family=family,
            swm=self.cfg.swm, stack=self.stack, dtype=self.cfg.param_dtype,
        )

    @property
    def in_proj(self):
        return self._lin(self.cfg.d_model, 2 * self.d_inner, "embed", "mlp",
                         family="ffn")
    @property
    def x_proj(self):
        return self._lin(self.d_inner, self.dt_rank + 2 * self.cfg.mamba_d_state,
                         "mlp", None, family="mamba_inner")
    @property
    def dt_proj(self):
        return self._lin(self.dt_rank, self.d_inner, None, "mlp",
                         family="mamba_inner")
    @property
    def out_proj(self):
        return self._lin(self.d_inner, self.cfg.d_model, "mlp", "embed",
                         family="ffn")

    def specs(self):
        di, ds, dc = self.d_inner, self.cfg.mamba_d_state, self.cfg.mamba_d_conv
        lead = self.stack
        la = ("layers",) * len(lead)
        return {
            "in_proj": self.in_proj.specs(),
            "x_proj": self.x_proj.specs(),
            "dt_proj": self.dt_proj.specs(),
            "dt_bias": ParamSpec(lead + (di,), jnp.float32, la + ("mlp",), init="zeros"),
            "out_proj": self.out_proj.specs(),
            "conv_w": ParamSpec(lead + (dc, di), jnp.dtype(self.cfg.param_dtype),
                                la + (None, "mlp"), init="normal", scale=dc**-0.5),
            "conv_b": ParamSpec(lead + (di,), jnp.float32, la + ("mlp",), init="zeros"),
            "A_log": ParamSpec(
                lead + (di, ds), jnp.float32, la + ("mlp", None),
                init=lambda key, shape, dtype: jnp.log(
                    jnp.broadcast_to(jnp.arange(1, shape[-1] + 1, dtype=jnp.float32), shape)
                ).astype(dtype),
            ),
            "D": ParamSpec(lead + (di,), jnp.float32, la + ("mlp",), init="ones"),
        }

    # ------------------------------------------------------------------
    def _conv(self, params, x: jax.Array, conv_state: Optional[jax.Array]):
        """Causal depthwise conv over time. x (B, S, di)."""
        dc = self.cfg.mamba_d_conv
        w = params["conv_w"].astype(x.dtype)                 # (dc, di)
        if conv_state is None:
            pad = jnp.zeros((x.shape[0], dc - 1, x.shape[2]), x.dtype)
        else:
            pad = conv_state.astype(x.dtype)
        xp = jnp.concatenate([pad, x], axis=1)               # (B, S+dc-1, di)
        out = sum(
            xp[:, i : i + x.shape[1], :] * w[i] for i in range(dc)
        ) + params["conv_b"].astype(x.dtype)
        new_state = xp[:, -(dc - 1):, :]
        return out, new_state

    def __call__(
        self, params, x: jax.Array, cache: Optional[dict] = None,
        mask: Optional[jax.Array] = None,
    ) -> Tuple[jax.Array, Optional[dict]]:
        """x (B, S, d) -> (y (B, S, d), new cache).

        ``mask`` (B, S) bool marks valid (non-pad) positions. Pad lanes
        contribute exactly nothing: their conv input is zeroed *before* the
        causal window (so a left-padded window bit-matches the zero-padding
        of a fresh unpadded run) and the SSM state skips their scan steps.
        ``mask=None`` is the original unmasked path, op for op."""
        cfg = self.cfg
        B, S, _ = x.shape
        di, ds = self.d_inner, cfg.mamba_d_state

        xz = self.in_proj(params["in_proj"], x)
        xi, z = jnp.split(xz, 2, axis=-1)                     # (B,S,di) each
        if mask is not None:
            xi = jnp.where(mask[..., None], xi, jnp.zeros_like(xi))

        conv_state = cache["conv"] if cache is not None else None
        xi, new_conv = self._conv(params, xi, conv_state)
        xi = jax.nn.silu(xi)

        xdb = self.x_proj(params["x_proj"], xi).astype(jnp.float32)
        dt, Bc, Cc = jnp.split(
            xdb, [self.dt_rank, self.dt_rank + ds], axis=-1
        )
        dt = jax.nn.softplus(
            self.dt_proj(params["dt_proj"], dt.astype(x.dtype)).astype(jnp.float32)
            + params["dt_bias"]
        )                                                     # (B,S,di)
        A = -jnp.exp(params["A_log"])                         # (di, ds)
        xf = xi.astype(jnp.float32)

        h0 = (
            cache["ssm"]
            if cache is not None
            else jnp.zeros((B, di, ds), jnp.float32)
        )

        def step(h, t):
            dt_t, B_t, C_t, x_t = t[:4]                       # (B,di),(B,ds),(B,ds),(B,di)
            dA = jnp.exp(dt_t[..., None] * A)                 # (B,di,ds)
            dBx = (dt_t * x_t)[..., None] * B_t[:, None, :]   # (B,di,ds)
            h_new = dA * h + dBx
            if mask is not None:
                # pad steps leave the state untouched (decay included)
                h_new = jnp.where(t[4][:, None, None], h_new, h)
            y = jnp.einsum("bds,bs->bd", h_new, C_t)
            return h_new, y

        ts = (
            jnp.moveaxis(dt, 1, 0),
            jnp.moveaxis(Bc, 1, 0),
            jnp.moveaxis(Cc, 1, 0),
            jnp.moveaxis(xf, 1, 0),
        )
        if mask is not None:
            ts = ts + (jnp.moveaxis(mask, 1, 0),)
        from repro.nn.scan import chunked_time_scan
        hT, ys = chunked_time_scan(step, h0, ts, chunk=256,
                                   remat=S > 256)
        y = jnp.moveaxis(ys, 0, 1) + xf * params["D"]         # (B,S,di)
        y = (y.astype(x.dtype)) * jax.nn.silu(z)
        out = self.out_proj(params["out_proj"], y)

        new_cache = None
        if cache is not None:
            new_cache = {"conv": new_conv.astype(cache["conv"].dtype), "ssm": hT}
        return out, new_cache
