"""Shared neural-net building blocks: norms, embeddings, rotary, masks."""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.nn.module import ParamSpec

__all__ = [
    "RMSNorm",
    "Embedding",
    "rotary",
    "apply_rope",
    "causal_mask",
    "sliding_window_mask",
    "prefix_lm_mask",
]


@dataclasses.dataclass(frozen=True)
class RMSNorm:
    dim: int
    stack: Tuple[int, ...] = ()
    eps: float = 1e-6

    def specs(self):
        return {
            "scale": ParamSpec(
                self.stack + (self.dim,),
                jnp.float32,
                ("layers",) * len(self.stack) + (None,),
                init="zeros",   # gemma-style (1 + scale)
            )
        }

    def __call__(self, params, x: jax.Array) -> jax.Array:
        dtype = x.dtype
        x = x.astype(jnp.float32)
        var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
        y = x * jax.lax.rsqrt(var + self.eps) * (1.0 + params["scale"])
        return y.astype(dtype)


@dataclasses.dataclass(frozen=True)
class Embedding:
    vocab: int
    dim: int
    dtype: str = "bfloat16"

    def specs(self):
        return {
            "table": ParamSpec(
                (self.vocab, self.dim),
                jnp.dtype(self.dtype),
                ("vocab", "embed"),
                init="normal",
                scale=1.0,
            )
        }

    def encode(self, params, tokens: jax.Array, scale_by_dim: bool = True):
        x = params["table"][tokens]
        if scale_by_dim:
            x = x * jnp.asarray(self.dim**0.5, x.dtype)
        return x

    def decode(self, params, x: jax.Array) -> jax.Array:
        """Tied logits head: (..., d) @ (vocab, d)^T -> f32 logits."""
        return jnp.einsum(
            "...d,vd->...v", x.astype(jnp.float32),
            params["table"].astype(jnp.float32),
        )


# ---------------------------------------------------------------------------
# Rotary position embedding
# ---------------------------------------------------------------------------


def rotary(positions: jax.Array, head_dim: int, theta: float) -> Tuple[jax.Array, jax.Array]:
    """positions (...,S) -> cos/sin (...,S, head_dim/2), f32."""
    freqs = theta ** (
        -jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    )
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x (B, S, H, D); cos/sin (B, S, D/2) or (S, D/2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if cos.ndim == 2:
        cos = cos[None, :, None, :]
        sin = sin[None, :, None, :]
    else:
        cos = cos[:, :, None, :]
        sin = sin[:, :, None, :]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention masks (log-space additive, f32)
# ---------------------------------------------------------------------------

_NEG = -2.0e38


def causal_mask(q_pos: jax.Array, kv_pos: jax.Array) -> jax.Array:
    """(..., Q), (..., K) -> (..., Q, K) additive mask."""
    ok = q_pos[..., :, None] >= kv_pos[..., None, :]
    return jnp.where(ok, 0.0, _NEG).astype(jnp.float32)


def sliding_window_mask(q_pos, kv_pos, window: int) -> jax.Array:
    d = q_pos[..., :, None] - kv_pos[..., None, :]
    ok = (d >= 0) & (d < window)
    return jnp.where(ok, 0.0, _NEG).astype(jnp.float32)


def prefix_lm_mask(q_pos, kv_pos, prefix_len: int) -> jax.Array:
    """Bidirectional over the first prefix_len positions, causal after
    (PaliGemma image-prefix masking)."""
    causal = q_pos[..., :, None] >= kv_pos[..., None, :]
    in_prefix = kv_pos[..., None, :] < prefix_len
    ok = causal | in_prefix
    return jnp.where(ok, 0.0, _NEG).astype(jnp.float32)
