"""RWKV-6 "Finch" — attention-free mixer with data-dependent decay.

SWM applicability: the r/k/v/g/o and channel-mix *projections* are weight
GEMMs → circulant-compressible. The WKV recurrence (token shift, decay
state update) is elementwise/scan-structured, not a weight matrix → left
native (DESIGN.md §Arch-applicability).

State per layer: token-shift last-x for time-mix and channel-mix, plus the
per-head (hd × hd) WKV matrix state → O(1) memory in sequence length, which
is why rwkv6-7b runs the long_500k decode cell.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.nn.linear import Linear
from repro.nn.module import ParamSpec

__all__ = ["RWKV6TimeMix", "RWKV6ChannelMix", "init_rwkv_cache"]

_MIX_NAMES = ("w", "k", "v", "r", "g")


def init_rwkv_cache(batch: int, d_model: int, n_heads: int, head_dim: int, dtype):
    return {
        "shift_att": jnp.zeros((batch, d_model), dtype),
        "shift_ffn": jnp.zeros((batch, d_model), dtype),
        "wkv": jnp.zeros((batch, n_heads, head_dim, head_dim), jnp.float32),
    }


def _token_shift(x: jax.Array, last: Optional[jax.Array]):
    """x (B,S,d) -> previous-token x; last (B,d) carries across calls."""
    if last is None:
        prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    else:
        prev = jnp.concatenate([last[:, None, :], x[:, :-1]], axis=1)
    return prev


def _prev_valid(mask: jax.Array) -> jax.Array:
    """Validity of each position's *previous* token under a (B, S) validity
    mask: ``True`` at t=0 (the carried ``last`` IS the legitimate previous
    token — for a fresh cache it is zeros, matching an unpadded run
    bit-exactly), ``mask[:, t-1]`` after. A left-pad lane's embedding thus
    never enters a real token's shift mix."""
    return jnp.pad(mask[:, :-1], ((0, 0), (1, 0)), constant_values=True)


@dataclasses.dataclass(frozen=True)
class RWKV6TimeMix:
    cfg: ModelConfig
    stack: Tuple[int, ...] = ()

    @property
    def n_heads(self) -> int:
        return self.cfg.d_model // self.cfg.rwkv_head_dim

    def _lin(self, i, o, oa, family="attn"):
        return Linear(in_dim=i, out_dim=o, in_axis="embed", out_axis=oa,
                      family=family, swm=self.cfg.swm, stack=self.stack,
                      dtype=self.cfg.param_dtype)

    def specs(self):
        d = self.cfg.d_model
        H, hd = self.n_heads, self.cfg.rwkv_head_dim
        L, la = self.stack, ("layers",) * len(self.stack)
        dl, ml = self.cfg.rwkv_decay_lora, self.cfg.rwkv_mix_lora
        f32 = jnp.float32
        return {
            "mu_x": ParamSpec(L + (d,), f32, la + (None,), init="uniform", scale=0.5),
            "mu": ParamSpec(L + (5, d), f32, la + (None, None), init="uniform", scale=0.5),
            "mix_A": ParamSpec(L + (d, 5 * ml), f32, la + (None, None),
                               init="normal", scale=d**-0.5),
            "mix_B": ParamSpec(L + (5, ml, d), f32, la + (None, None, None),
                               init="normal", scale=ml**-0.5),
            "w0": ParamSpec(L + (d,), f32, la + (None,), init="uniform", scale=1.0),
            "w_A": ParamSpec(L + (d, dl), f32, la + (None, None),
                             init="normal", scale=d**-0.5),
            "w_B": ParamSpec(L + (dl, d), f32, la + (None, None),
                             init="normal", scale=dl**-0.5),
            "u": ParamSpec(L + (H, hd), f32, la + ("heads", None),
                           init="uniform", scale=0.5),
            "r": self._lin(d, d, "heads").specs(),
            "k": self._lin(d, d, "heads").specs(),
            "v": self._lin(d, d, "heads").specs(),
            "g": self._lin(d, d, "heads").specs(),
            "o": Linear(in_dim=d, out_dim=d, in_axis="heads", out_axis="embed",
                        family="attn", swm=self.cfg.swm, stack=self.stack,
                        dtype=self.cfg.param_dtype).specs(),
            "ln_scale": ParamSpec(L + (d,), f32, la + (None,), init="ones"),
            "ln_bias": ParamSpec(L + (d,), f32, la + (None,), init="zeros"),
        }

    # ------------------------------------------------------------------
    def __call__(self, params, x, cache: Optional[dict] = None,
                 mask: Optional[jax.Array] = None):
        """``mask`` (B, S) bool marks valid (non-pad) positions. Pad lanes
        contribute exactly nothing: their x never enters a token shift
        (``_prev_valid``) and the WKV state skips their scan steps, so a
        left-padded bucketed prefill is bit-identical to the unpadded B=1
        run. ``mask=None`` (training / unpadded callers) is the original
        unmasked path, op for op."""
        cfg = self.cfg
        B, S, d = x.shape
        H, hd = self.n_heads, cfg.rwkv_head_dim

        last = cache["shift_att"] if cache is not None else None
        prev = _token_shift(x, last)
        if mask is not None:
            prev = jnp.where(_prev_valid(mask)[..., None], prev,
                             jnp.zeros_like(prev))
        dx = (prev - x).astype(jnp.float32)
        xf = x.astype(jnp.float32)

        # data-dependent token-shift mix (Finch ddlerp)
        xx = xf + dx * params["mu_x"]
        lora = jnp.tanh(xx @ params["mix_A"]).reshape(B, S, 5, -1)
        mix = params["mu"] + jnp.einsum("bsfm,fmd->bsfd", lora, params["mix_B"])
        xs = xf[:, :, None, :] + dx[:, :, None, :] * mix      # (B,S,5,d)
        xw, xk, xv, xr, xg = [xs[:, :, i].astype(x.dtype) for i in range(5)]

        # data-dependent decay
        ww = params["w0"] + jnp.tanh(
            xw.astype(jnp.float32) @ params["w_A"]
        ) @ params["w_B"]
        w = jnp.exp(-jnp.exp(ww.astype(jnp.float32)))         # (B,S,d) in (0,1)

        r = self._lin(d, d, "heads")(params["r"], xr).reshape(B, S, H, hd)
        k = self._lin(d, d, "heads")(params["k"], xk).reshape(B, S, H, hd)
        v = self._lin(d, d, "heads")(params["v"], xv).reshape(B, S, H, hd)
        g = self._lin(d, d, "heads")(params["g"], xg)
        wh = w.reshape(B, S, H, hd)
        u = params["u"]

        s0 = (
            cache["wkv"]
            if cache is not None
            else jnp.zeros((B, H, hd, hd), jnp.float32)
        )

        def step(s, t):
            r_t, k_t, v_t, w_t = t[:4]                        # (B,H,hd) each
            kv = k_t[..., :, None] * v_t[..., None, :]        # (B,H,hd,hd)
            y = jnp.einsum(
                "bhk,bhkv->bhv", r_t * u[None], kv
            ) + jnp.einsum("bhk,bhkv->bhv", r_t, s)
            s_new = w_t[..., :, None] * s + kv
            if mask is not None:
                # pad steps leave the state untouched (decay included)
                s_new = jnp.where(t[4][:, None, None, None], s_new, s)
            return s_new, y

        ts = tuple(
            jnp.moveaxis(a.astype(jnp.float32), 1, 0) for a in (r, k, v, wh)
        )
        if mask is not None:
            ts = ts + (jnp.moveaxis(mask, 1, 0),)
        from repro.nn.scan import chunked_time_scan
        sT, ys = chunked_time_scan(step, s0, ts, chunk=256, remat=S > 256)
        y = jnp.moveaxis(ys, 0, 1).reshape(B, S, d)           # (B,S,d) f32

        # per-head groupnorm, then gate
        yh = y.reshape(B, S, H, hd)
        mu = yh.mean(-1, keepdims=True)
        var = yh.var(-1, keepdims=True)
        yh = (yh - mu) * jax.lax.rsqrt(var + 64e-5)
        y = yh.reshape(B, S, d) * params["ln_scale"] + params["ln_bias"]
        y = (y.astype(x.dtype)) * jax.nn.silu(g)
        out = Linear(in_dim=d, out_dim=d, in_axis="heads", out_axis="embed",
                     family="attn", swm=cfg.swm, stack=self.stack,
                     dtype=cfg.param_dtype)(params["o"], y)

        new_cache = None
        if cache is not None:
            new_cache = {
                "shift_att": x[:, -1, :],
                "wkv": sT,
            }
        return out, new_cache


@dataclasses.dataclass(frozen=True)
class RWKV6ChannelMix:
    cfg: ModelConfig
    stack: Tuple[int, ...] = ()

    def specs(self):
        d, dff = self.cfg.d_model, self.cfg.d_ff
        L, la = self.stack, ("layers",) * len(self.stack)
        f32 = jnp.float32
        lin = lambda i, o, ia, oa: Linear(
            in_dim=i, out_dim=o, in_axis=ia, out_axis=oa, family="ffn",
            swm=self.cfg.swm, stack=self.stack, dtype=self.cfg.param_dtype,
        )
        return {
            "mu_k": ParamSpec(L + (d,), f32, la + (None,), init="uniform", scale=0.5),
            "mu_r": ParamSpec(L + (d,), f32, la + (None,), init="uniform", scale=0.5),
            "wk": lin(d, dff, "embed", "mlp").specs(),
            "wr": lin(d, d, "embed", None).specs(),
            "wv": lin(dff, d, "mlp", "embed").specs(),
        }

    def __call__(self, params, x, cache: Optional[dict] = None,
                 mask: Optional[jax.Array] = None):
        """``mask`` as in :class:`RWKV6TimeMix`: pad positions never enter
        the channel-mix token shift."""
        cfg = self.cfg
        d, dff = cfg.d_model, cfg.d_ff
        last = cache["shift_ffn"] if cache is not None else None
        prev = _token_shift(x, last)
        if mask is not None:
            prev = jnp.where(_prev_valid(mask)[..., None], prev,
                             jnp.zeros_like(prev))
        dx = (prev - x).astype(jnp.float32)
        xf = x.astype(jnp.float32)
        xk = (xf + dx * params["mu_k"]).astype(x.dtype)
        xr = (xf + dx * params["mu_r"]).astype(x.dtype)
        lin = lambda i, o, ia, oa: Linear(
            in_dim=i, out_dim=o, in_axis=ia, out_axis=oa, family="ffn",
            swm=cfg.swm, stack=self.stack, dtype=cfg.param_dtype,
        )
        k = lin(d, dff, "embed", "mlp")(params["wk"], xk)
        k = jnp.square(jax.nn.relu(k))
        r = jax.nn.sigmoid(lin(d, d, "embed", None)(params["wr"], xr))
        y = r * lin(dff, d, "mlp", "embed")(params["wv"], k)
        new_cache = {"shift_ffn": x[:, -1, :]} if cache is not None else None
        return y, new_cache
