"""Chunked time scan with per-chunk rematerialization.

A plain ``lax.scan`` over T timesteps saves its carry at every step for the
backward pass — for SSM/RWKV states that is T × (B, d_inner, d_state)
(measured: 17 GB per RWKV layer at T=4096). Scanning chunks-of-steps with a
checkpointed chunk body saves only T/chunk boundary states and recomputes
inside each chunk: memory ÷ chunk, forward ×2 during backward.
"""

from __future__ import annotations

import functools
from typing import Callable, Tuple

import jax
import jax.numpy as jnp

__all__ = ["chunked_time_scan"]


def chunked_time_scan(
    step_fn: Callable,
    carry,
    xs: Tuple,           # tuple of time-major arrays (T, ...)
    *,
    chunk: int = 256,
    remat: bool = True,
):
    """Equivalent to ``lax.scan(step_fn, carry, xs)`` with chunked remat.

    step_fn: (carry, xs_t) -> (carry, y_t). Returns (carry, ys) with ys
    stacked time-major like lax.scan.
    """
    T = jax.tree.leaves(xs)[0].shape[0]
    chunk = max(1, min(chunk, T))
    n, tail = divmod(T, chunk)
    head = jax.tree.map(lambda a: a[: n * chunk], xs)
    xs_c = jax.tree.map(
        lambda a: a.reshape((n, chunk) + a.shape[1:]), head)

    def chunk_body(carry, xs_chunk):
        return jax.lax.scan(step_fn, carry, xs_chunk)

    body = jax.checkpoint(chunk_body) if remat else chunk_body
    carry, ys = jax.lax.scan(body, carry, xs_c)
    ys = jax.tree.map(lambda a: a.reshape((n * chunk,) + a.shape[2:]), ys)
    if tail:   # partial last chunk: plain scan (never padded — padding
        #        would corrupt the carry with phantom steps)
        carry, ys_t = jax.lax.scan(
            step_fn, carry, jax.tree.map(lambda a: a[n * chunk:], xs))
        ys = jax.tree.map(
            lambda a, b: jnp.concatenate([a, b], axis=0), ys, ys_t)
    return carry, ys
