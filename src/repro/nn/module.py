"""Minimal parameter-spec module system (no flax dependency).

Params are plain pytrees (nested dicts of jnp arrays). Every leaf is declared
up front as a :class:`ParamSpec` carrying shape / dtype / *logical* sharding
axes / initializer, so one declaration serves three consumers:

  * ``init_params``    — materialize arrays (seeded per-path, deterministic)
  * ``specs_to_sds``   — ``jax.ShapeDtypeStruct`` stand-ins for the multi-pod
                         dry-run (no device allocation ever happens)
  * ``specs_to_shardings`` — logical axes -> physical mesh axes via the
                         rule table in :mod:`repro.dist.sharding`

Layer "stacks" (scan-over-layers) are expressed directly in the spec: a
stacked parameter simply declares a leading ``layers`` axis. There is no
separate stacking transform.
"""

from __future__ import annotations

import dataclasses
import hashlib
from typing import Any, Callable, Mapping, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "ParamSpec",
    "init_params",
    "specs_to_sds",
    "map_specs",
    "flatten_with_paths",
    "param_count",
    "param_bytes",
]

InitFn = Callable[[jax.Array, Sequence[int], Any], jax.Array]


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """Declaration of a single parameter tensor.

    Attributes:
      shape: full shape, including any leading layer-stack axis.
      dtype: storage dtype (bf16 for big weights, f32 for norms/biases).
      axes:  logical axis names, one per dim (``None`` = never sharded).
             e.g. ``("layers", "embed", "heads")``.
      init:  one of "normal" | "zeros" | "ones" | "uniform" | callable.
      scale: std (normal) or bound (uniform). Layer constructors compute
             fan-in-aware scales themselves.
      tags:  free-form markers consumed by tooling (e.g. "circulant" lets
             kernels.block_circulant.plan.freeze_params find SWM tables).
    """

    shape: tuple
    dtype: Any = jnp.float32
    axes: tuple = ()
    init: Union[str, InitFn] = "normal"
    scale: float = 0.02
    tags: tuple = ()

    def __post_init__(self):
        object.__setattr__(self, "shape", tuple(int(s) for s in self.shape))
        object.__setattr__(self, "axes", tuple(self.axes))
        object.__setattr__(self, "tags", tuple(self.tags))
        if len(self.axes) != len(self.shape):
            raise ValueError(
                f"axes {self.axes} must match shape {self.shape} rank"
            )

    def materialize(self, key: jax.Array) -> jax.Array:
        if callable(self.init):
            return self.init(key, self.shape, self.dtype)
        if self.init == "zeros":
            return jnp.zeros(self.shape, self.dtype)
        if self.init == "ones":
            return jnp.ones(self.shape, self.dtype)
        if self.init == "normal":
            return (
                jax.random.normal(key, self.shape, jnp.float32) * self.scale
            ).astype(self.dtype)
        if self.init == "uniform":
            return jax.random.uniform(
                key, self.shape, jnp.float32, -self.scale, self.scale
            ).astype(self.dtype)
        raise ValueError(f"unknown init {self.init!r}")

    @property
    def sds(self) -> jax.ShapeDtypeStruct:
        return jax.ShapeDtypeStruct(self.shape, self.dtype)


def _is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def _walk(tree, path=()):
    """Yield (path, spec) for every ParamSpec leaf in a nested dict tree."""
    if _is_spec(tree):
        yield path, tree
        return
    if isinstance(tree, Mapping):
        for k in sorted(tree.keys()):
            yield from _walk(tree[k], path + (k,))
        return
    if tree is None:
        return
    raise TypeError(f"spec trees are nested dicts of ParamSpec; got {type(tree)} at {path}")


def flatten_with_paths(tree):
    return list(_walk(tree))


def _path_key(root: jax.Array, path) -> jax.Array:
    """Deterministic per-path RNG: fold a stable hash of the path string."""
    h = int.from_bytes(
        hashlib.blake2b("/".join(map(str, path)).encode(), digest_size=4).digest(),
        "big",
    )
    return jax.random.fold_in(root, h)


def map_specs(fn: Callable[[tuple, ParamSpec], Any], tree):
    """Structure-preserving map over a spec tree; fn(path, spec) -> leaf."""
    if _is_spec(tree):
        return fn((), tree)

    def rec(t, path):
        if _is_spec(t):
            return fn(path, t)
        if isinstance(t, Mapping):
            return {k: rec(v, path + (k,)) for k, v in t.items()}
        if t is None:
            return None
        raise TypeError(f"bad spec tree node {type(t)} at {path}")

    return rec(tree, ())


def init_params(specs, seed: Union[int, jax.Array]):
    """Materialize a spec tree into an array pytree. Deterministic in seed."""
    root = jax.random.PRNGKey(seed) if isinstance(seed, int) else seed
    return map_specs(lambda p, s: s.materialize(_path_key(root, p)), specs)


def specs_to_sds(specs):
    """ShapeDtypeStruct tree for dry-run lowering (no allocation)."""
    return map_specs(lambda p, s: s.sds, specs)


def param_count(specs) -> int:
    return sum(int(np.prod(s.shape)) for _, s in _walk(specs))


def param_bytes(specs) -> int:
    return sum(
        int(np.prod(s.shape)) * jnp.dtype(s.dtype).itemsize for _, s in _walk(specs)
    )
