"""Mixture-of-Experts with scatter-based capacity dispatch (GShard-style).

Expert parallelism: the expert axis carries the 'experts' logical axis →
sharded over the mesh 'model' axis. Token→expert dispatch is a scatter-add
into an (E, C, d) buffer; GSPMD inserts the all-to-all when the token
sharding (batch over 'data') meets the expert sharding ('model').

Routing is a plain dense GEMM + top-k — never SWM-compressed (it is not one
of the paper's weight-matrix targets; see DESIGN.md §Arch-applicability).
Expert FFN weights ARE compressed when `swm.targets` includes 'expert' —
on arctic-480b this is where the paper's O(n)-storage claim bites hardest
(128 experts × 35 layers of circulant tables instead of dense matrices).
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.nn.ffn import SwiGLU
from repro.nn.linear import Linear

__all__ = ["MoE"]


@dataclasses.dataclass(frozen=True)
class MoE:
    d_model: int
    d_ff: int
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25
    swm: "SWMConfig" = None
    stack: Tuple[int, ...] = ()
    dtype: str = "bfloat16"

    @property
    def router(self):
        return Linear(
            in_dim=self.d_model, out_dim=self.n_experts,
            in_axis="embed", out_axis=None, family="router",
            swm=self.swm, stack=self.stack, dtype="float32",
        )

    @property
    def experts(self):
        return SwiGLU(
            d_model=self.d_model, d_ff=self.d_ff, swm=self.swm,
            stack=self.stack, expert_dims=(self.n_experts,),
            family="expert", dtype=self.dtype,
        )

    def specs(self):
        return {"router": self.router.specs(), "experts": self.experts.specs()}

    # ------------------------------------------------------------------
    def __call__(self, params, x: jax.Array, no_drop: bool = False):
        """x (B, S, d) -> (y (B, S, d), aux_loss scalar).

        ``no_drop=True`` is the serving dispatch: capacity is set to N (a
        token's T expert slots are distinct, so per-expert load never
        exceeds N) and nothing is ever dropped. Each token's output then
        depends only on its own row — independent of batch composition and
        bucket padding — which is what lets the serve engine run MoE
        configs bit-identically across bucket shapes. Capacity stays a
        static function of the launch shape, so the compile budget is
        unchanged. Training keeps the capacity-factor drop path (the
        load-balance pressure the aux loss is tuned against)."""
        B, S, d = x.shape
        E, T = self.n_experts, self.top_k
        N = B * S
        xt = x.reshape(N, d)

        logits = self.router(params["router"], xt).astype(jnp.float32)  # (N, E)
        probs = jax.nn.softmax(logits, axis=-1)
        gate, expert_idx = jax.lax.top_k(probs, T)                       # (N, T)
        gate = gate / jnp.clip(gate.sum(-1, keepdims=True), 1e-9)

        # capacity per expert (static)
        if no_drop:
            C = N
        else:
            C = max(1, int(N * T / E * self.capacity_factor))
            C = min(C, N)

        # position of each (token, slot) within its expert's capacity —
        # sort-based, O(N·T) memory. (A cumsum over a one-hot (N·T, E)
        # tensor needs N·T·E ints: 537 GB for qwen3-moe train_4k. Measured;
        # see EXPERIMENTS.md §Perf.)
        flat_e = expert_idx.reshape(-1)                                  # (N·T,)
        order = jnp.argsort(flat_e, stable=True)
        sorted_e = flat_e[order]
        seg_start = jnp.searchsorted(sorted_e, jnp.arange(E))            # (E,)
        pos_sorted = jnp.arange(N * T) - seg_start[sorted_e]
        pos = jnp.zeros((N * T,), jnp.int32).at[order].set(
            pos_sorted.astype(jnp.int32)).reshape(N, T)
        keep = (pos < C)
        pos = jnp.where(keep, pos, 0)

        # dispatch: scatter tokens into (E, C, d)
        disp = jnp.zeros((E, C, d), x.dtype)
        contrib = xt[:, None, :] * keep[..., None].astype(x.dtype)       # (N,T,d)
        disp = disp.at[expert_idx, pos].add(contrib)

        # expert compute — vmap the SwiGLU over the expert axis; the expert
        # hiddens (E, C, d_ff) are rematerialized in backward (arctic:
        # 128 experts × capacity × 4864 would otherwise dominate HBM)
        @jax.checkpoint
        def one_expert(p, xe):
            return SwiGLU(
                d_model=self.d_model, d_ff=self.d_ff, swm=self.swm,
                stack=(), expert_dims=(), family="expert", dtype=self.dtype,
            )(p, xe)

        y_exp = jax.vmap(one_expert)(params["experts"], disp)            # (E, C, d)

        # combine: gather each token's expert outputs
        y_tok = y_exp[expert_idx, pos]                                   # (N, T, d)
        w = (gate * keep.astype(gate.dtype))[..., None].astype(x.dtype)
        y = (y_tok * w).sum(axis=1).reshape(B, S, d)

        # load-balance aux loss (Switch): E · Σ_e f_e · P_e
        f = jnp.zeros((E,), jnp.float32).at[flat_e].add(1.0) / (N * T)
        P = probs.mean(axis=0)
        aux = E * jnp.sum(f * P)
        return y, aux
