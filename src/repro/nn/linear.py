"""Linear projections — dense or block-circulant (SWM), one API.

``Linear`` is the single projection primitive used everywhere in the model
zoo. When the layer's family is in ``swm.targets`` and the dims admit a
block size > 1, the parameter is the (p, q, k) circulant block table instead
of the (in, out) dense kernel — the paper's compression applied as a
first-class feature, not a bolt-on.

Sharding: the circulant table keeps the *same logical axis names* as the
dense kernel would have — q-axis (input blocks) gets the input logical axis,
p-axis (output blocks) the output logical axis — so the TP/FSDP rule table
applies unchanged (column-/row-parallel circulant layers).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import SWMConfig
from repro.core import circulant as circ
from repro.nn.module import ParamSpec

__all__ = ["Linear", "linear_specs", "linear_apply"]


@dataclasses.dataclass(frozen=True)
class Linear:
    """A (possibly stacked) projection ``(..., in_dim) -> (..., out_dim)``.

    stack: leading layer-stack dims (scan-over-layers), e.g. (n_repeat,).
    in_axis/out_axis: logical sharding axis names.
    family: 'attn' | 'ffn' | 'expert' | 'head' | ... — SWM applicability.
    expert_dims: extra leading *expert* dims (E,) for MoE weights; these get
      the 'experts' logical axis.
    """

    in_dim: int
    out_dim: int
    in_axis: Optional[str] = None
    out_axis: Optional[str] = None
    family: str = "ffn"
    swm: SWMConfig = dataclasses.field(default_factory=SWMConfig)
    stack: Tuple[int, ...] = ()
    expert_dims: Tuple[int, ...] = ()
    dtype: str = "bfloat16"
    scale: Optional[float] = None       # default: 1/sqrt(in_dim)

    # --------------------------------------------------------------
    @property
    def block_size(self) -> int:
        if not self.swm.applies_to(self.family):
            return 1
        return circ.valid_block_size(self.swm.block_size, self.in_dim, self.out_dim)

    @property
    def is_circulant(self) -> bool:
        return self.block_size > 1

    def specs(self):
        k = self.block_size
        lead = self.stack + self.expert_dims
        lead_axes = ("layers",) * len(self.stack) + ("experts",) * len(
            self.expert_dims
        )
        # Variance-preserving init: dense var 1/in_dim. A circulant row has
        # in_dim/k blocks × k entries reused k times; matching output variance
        # requires var(w) = 1/in_dim as well (each output sums in_dim terms).
        std = self.scale if self.scale is not None else self.in_dim**-0.5
        if k > 1:
            p, q = self.out_dim // k, self.in_dim // k
            w = ParamSpec(
                lead + (p, q, k),
                jnp.dtype(self.dtype),
                lead_axes + (self.out_axis, self.in_axis, None),
                init="normal",
                scale=std,
                tags=("circulant",),
            )
        else:
            w = ParamSpec(
                lead + (self.in_dim, self.out_dim),
                jnp.dtype(self.dtype),
                lead_axes + (self.in_axis, self.out_axis),
                init="normal",
                scale=std,
            )
        return {"w": w}

    def __call__(self, params, x: jax.Array, *,
                 bias: Optional[jax.Array] = None,
                 activation: str = "none") -> jax.Array:
        """Apply. params['w'] must already have stack/expert dims consumed
        (scan slices the stack axis; MoE vmaps the expert axis).

        ``bias`` / ``activation`` run as the fused epilogue on the circulant
        path (inside the Pallas kernel's writeback). When the params carry
        frozen frequency weights (``wr`` / ``wi``, attached once by
        ``kernels.block_circulant.plan.freeze_params`` at serve time) the
        per-call ``rfft(w)`` is skipped — the paper's BRAM-resident FFT(w).
        """
        if self.is_circulant:
            # frozen (serve) trees drop the time-domain table entirely —
            # k comes from the layer config, never from w's shape
            return circ.block_circulant_apply_fused(
                x, params.get("w"), impl=self.swm.impl,
                karatsuba=self.swm.karatsuba,
                bias=bias, activation=activation,
                w_freq=self.frozen_freq(params),
                w_scale=self.frozen_scale(params), k=self.block_size,
            )
        w = params["w"]
        y = jnp.einsum("...i,io->...o", x, w.astype(x.dtype))
        if bias is not None:
            y = y + bias.astype(y.dtype)
        from repro.kernels.block_circulant.kernel import apply_activation

        return apply_activation(y, activation)

    def frozen_freq(self, params):
        """(wr, wi) when frozen frequency weights are attached, else None."""
        if self.is_circulant and "wr" in params and "wi" in params:
            return (params["wr"], params["wi"])
        return None

    def frozen_scale(self, params):
        """Per-block int8 scales when the frozen tables are quantized."""
        if self.is_circulant and "wr" in params:
            return params.get("w_scale")
        return None

    # convenience for param counting / compression reporting
    @property
    def n_params(self) -> int:
        k = self.block_size
        base = (self.in_dim * self.out_dim) // k if k > 1 else self.in_dim * self.out_dim
        for d in self.stack + self.expert_dims:
            base *= d
        return base

    @property
    def compression(self) -> float:
        return float(self.block_size)


def linear_specs(lin: Linear):
    return lin.specs()


def linear_apply(lin: Linear, params, x):
    return lin(params, x)
