"""Grouped-query attention: rotary, qk-norm, sliding window, KV cache, flash.

Covers every assigned attention variant:
  * GQA / MQA (n_kv_heads ∈ {1..n_heads})
  * qk-norm (qwen3), attention logit softcapping (config)
  * gemma3 local:global interleave — local layers use a sliding-window mask
    and, in decode, a **ring-buffer KV cache of window size** (5/6 of gemma3
    layers hold 1024-entry caches instead of 524k — this is what makes the
    long_500k cell feasible)
  * prefix-LM masking (paligemma) and bidirectional encoders (seamless-m4t)
  * cross-attention (enc-dec) — KV cached once from the encoder

Masks are never materialized globally: they are predicates over absolute
positions evaluated per score tile. Long sequences (train_4k / prefill_32k)
use a **flash-style chunked attention** — lax.scan over KV chunks with
running (max, sum, acc) — so peak memory is O(S·chunk) not O(S²). The KV
cache stores absolute positions alongside k/v, so ring-buffer wraparound
masks stale slots exactly.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.nn.layers import RMSNorm, apply_rope, rotary
from repro.nn.linear import Linear

__all__ = ["Attention", "init_kv_cache", "flash_attention"]

_NEG = -2.0e38


def init_kv_cache(batch, cache_len, n_kv, head_dim, dtype):
    """Empty cache; pos = -1 marks an unfilled (always-masked) slot."""
    return {
        "k": jnp.zeros((batch, cache_len, n_kv, head_dim), dtype),
        "v": jnp.zeros((batch, cache_len, n_kv, head_dim), dtype),
        "pos": -jnp.ones((batch, cache_len), jnp.int32),
    }


# ---------------------------------------------------------------------------
# Position-predicate masks (computed per tile, never O(S²) global)
# ---------------------------------------------------------------------------


def _mask_bias(
    q_pos: jax.Array,           # (B, Sq)
    kv_pos: jax.Array,          # (B, Skv)
    *,
    causal: bool,
    window: int,
    prefix_len: int,
) -> jax.Array:
    """(B, Sq, Skv) additive f32 bias from position predicates."""
    qp = q_pos[:, :, None]
    kp = kv_pos[:, None, :]
    ok = kp >= 0                               # valid cache slots
    if causal:
        c = kp <= qp
        if prefix_len > 0:                     # prefix-LM: bidir over prefix
            c = c | (kp < prefix_len)
        ok = ok & c
    if window > 0:
        ok = ok & (qp - kp < window)
    return jnp.where(ok, 0.0, _NEG).astype(jnp.float32)


# ---------------------------------------------------------------------------
# Flash-style chunked attention (XLA-level; O(S·chunk) memory)
# ---------------------------------------------------------------------------


def flash_attention(
    q: jax.Array,               # (B, Sq, HKV, G, hd)
    k: jax.Array,               # (B, Skv, HKV, hd)
    v: jax.Array,               # (B, Skv, HKV, hd)
    q_pos: jax.Array,           # (B, Sq)
    kv_pos: jax.Array,          # (B, Skv)
    *,
    causal: bool,
    window: int = 0,
    prefix_len: int = 0,
    softcap: float = 0.0,
    q_chunk: int = 512,
    kv_chunk: int = 1024,
) -> jax.Array:
    """Lazy-softmax attention over KV chunks. Returns (B, Sq, HKV, G, hd)."""
    B, Sq, HKV, G, hd = q.shape
    Skv = k.shape[1]
    scale = hd**-0.5
    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Skv)
    # pad to multiples
    pq = (-Sq) % q_chunk
    pk = (-Skv) % kv_chunk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, ((0, 0), (0, pq)), constant_values=0)
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, ((0, 0), (0, pk)), constant_values=-1)
    nq, nk = q.shape[1] // q_chunk, k.shape[1] // kv_chunk

    qs = q.reshape(B, nq, q_chunk, HKV, G, hd)
    qp = q_pos.reshape(B, nq, q_chunk)
    Skv_pad = k.shape[1]

    # Sliding-window KV-span slicing: a q chunk starting at position s only
    # attends to KV in [s + qc - 1 - window + 1, s + qc - 1]; with aligned
    # positions each q chunk needs a FIXED-SIZE span (window + q_chunk,
    # rounded to kv_chunk) at a dynamic offset — static shapes, 1/(S/span)
    # of the fully-masked chunk compute skipped (gemma3's 52/62 local
    # layers: ~16× less attention work at 32k prefill).
    aligned = bool(window) and causal and Sq == Skv and prefix_len == 0
    if aligned:
        span = min(Skv_pad,
                   ((window + q_chunk + kv_chunk - 1) // kv_chunk) * kv_chunk)
    else:
        span = Skv_pad
    n_span = span // kv_chunk

    def q_block(qi, qpi, qidx):
        if aligned and span < Skv_pad:
            start = jnp.clip(qidx * q_chunk + q_chunk - span, 0,
                             Skv_pad - span)
            ks = jax.lax.dynamic_slice_in_dim(k, start, span, 1)
            vs_ = jax.lax.dynamic_slice_in_dim(v, start, span, 1)
            kp_ = jax.lax.dynamic_slice_in_dim(kv_pos, start, span, 1)
        else:
            ks, vs_, kp_ = k, v, kv_pos
        ks = ks.reshape(B, n_span, kv_chunk, HKV, hd)
        vs_ = vs_.reshape(B, n_span, kv_chunk, HKV, hd)
        kp_ = kp_.reshape(B, n_span, kv_chunk)

        # qi (B, qc, HKV, G, hd); scan over kv chunks
        def kv_step(carry, xs):
            m, l, acc = carry
            ki, vi, kpi = xs                    # (B,kc,HKV,hd),(...),(B,kc)
            s = jnp.einsum(
                "bqhgd,bkhd->bhgqk", qi, ki,
                preferred_element_type=jnp.float32,
            ) * scale
            if softcap > 0:
                s = jnp.tanh(s / softcap) * softcap
            bias = _mask_bias(qpi, kpi, causal=causal, window=window,
                              prefix_len=prefix_len)
            s = s + bias[:, None, None, :, :]
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p.astype(qi.dtype), vi,
                preferred_element_type=jnp.float32,
            )
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, HKV, G, q_chunk), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, HKV, G, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, HKV, G, q_chunk, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (jnp.moveaxis(ks, 1, 0), jnp.moveaxis(vs_, 1, 0),
             jnp.moveaxis(kp_, 1, 0)),
        )
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return jnp.transpose(out, (0, 3, 1, 2, 4))  # (B, qc, HKV, G, hd)

    outs = jax.lax.map(
        lambda xs: q_block(*xs),
        (jnp.moveaxis(qs, 1, 0), jnp.moveaxis(qp, 1, 0),
         jnp.arange(nq)),
    )                                               # (nq, B, qc, HKV, G, hd)
    out = jnp.moveaxis(outs, 0, 1).reshape(B, nq * q_chunk, HKV, G, hd)
    return out[:, :Sq].astype(q.dtype)


def _direct_attention(q, k, v, q_pos, kv_pos, *, causal, window, prefix_len,
                      softcap):
    """Small-Sq path (decode): one materialized score tensor."""
    B, Sq, HKV, G, hd = q.shape
    s = jnp.einsum(
        "bqhgd,bkhd->bhgqk", q, k, preferred_element_type=jnp.float32
    ) * (hd**-0.5)
    if softcap > 0:
        s = jnp.tanh(s / softcap) * softcap
    bias = _mask_bias(q_pos, kv_pos, causal=causal, window=window,
                      prefix_len=prefix_len)
    s = s + bias[:, None, None, :, :]
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", w.astype(q.dtype), v)
    return out


# ---------------------------------------------------------------------------
# The attention layer
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Attention:
    cfg: ModelConfig
    local: bool = False            # sliding-window variant
    cross: bool = False            # enc-dec cross attention
    causal: bool = True            # False for encoder self-attn
    prefix_len: int = 0            # VLM prefix-LM bidirectional span
    stack: Tuple[int, ...] = ()

    # -- projections ----------------------------------------------------
    def _proj(self, i, o, oa):
        return Linear(in_dim=i, out_dim=o, in_axis="embed", out_axis=oa,
                      family="attn", swm=self.cfg.swm, stack=self.stack,
                      dtype=self.cfg.param_dtype)

    @property
    def q_proj(self):
        return self._proj(self.cfg.d_model, self.cfg.n_heads * self.cfg.head_dim, "heads")

    @property
    def k_proj(self):
        return self._proj(self.cfg.d_model, self.cfg.n_kv_heads * self.cfg.head_dim, "kv_heads")

    @property
    def v_proj(self):
        return self._proj(self.cfg.d_model, self.cfg.n_kv_heads * self.cfg.head_dim, "kv_heads")

    @property
    def o_proj(self):
        return Linear(in_dim=self.cfg.n_heads * self.cfg.head_dim,
                      out_dim=self.cfg.d_model, in_axis="heads",
                      out_axis="embed", family="attn", swm=self.cfg.swm,
                      stack=self.stack, dtype=self.cfg.param_dtype)

    def specs(self):
        s = {"q": self.q_proj.specs(), "k": self.k_proj.specs(),
             "v": self.v_proj.specs(), "o": self.o_proj.specs()}
        if self.cfg.qk_norm:
            hd = self.cfg.head_dim
            s["q_norm"] = RMSNorm(hd, stack=self.stack).specs()
            s["k_norm"] = RMSNorm(hd, stack=self.stack).specs()
        return s

    def _fused_qkv(self, params, x):
        """Q/K/V as ONE stacked-p circulant launch when all three tables are
        circulant with one block size (they share the input x, so the
        forward transform of x and the kernel pipeline are amortized 3-way).
        Returns (q, k, v) flat projections or None when not fusable.

        Frozen (serve) trees carry the pre-concatenated stacked table that
        ``plan.freeze_params`` attaches under ``plan.FUSED_KEY`` — the
        launch then reads one resident (Σp_i, q, K) table and its trace
        contains no weight-side concatenate."""
        qp, kp, vp = self.q_proj, self.k_proj, self.v_proj
        kb = qp.block_size
        if not (qp.is_circulant and kp.is_circulant and vp.is_circulant
                and kp.block_size == kb and vp.block_size == kb):
            return None
        from repro.core import circulant as circ
        from repro.kernels.block_circulant.plan import FUSED_KEY

        fused = params.get(FUSED_KEY)
        if fused is not None:
            return circ.block_circulant_apply_multi(
                x, None, impl=self.cfg.swm.impl,
                w_freq_cat=(fused["wr"], fused["wi"]),
                w_scale_cat=fused.get("w_scale"),
                splits=tuple(p.out_dim // kb for p in (qp, kp, vp)),
                k=kb, karatsuba=self.cfg.swm.karatsuba,
            )
        names = ("q", "k", "v")
        frozen = all("wr" in params[n] and "wi" in params[n] for n in names)
        return circ.block_circulant_apply_multi(
            x,
            None if frozen else [params[n]["w"] for n in names],
            impl=self.cfg.swm.impl,
            # int8 per-projection tables dequantize here (the multi path
            # concatenates to complex64, which must see f32 tables)
            w_freqs=([circ.dequantize_freq_pair(
                params[n]["wr"], params[n]["wi"], params[n].get("w_scale"))
                for n in names] if frozen else None),
            k=kb,
            karatsuba=self.cfg.swm.karatsuba,
        )

    @property
    def window(self) -> int:
        return self.cfg.sliding_window if self.local else 0

    def _rope_theta(self) -> float:
        return self.cfg.rope_theta_local if self.local else self.cfg.rope_theta

    # -- forward ---------------------------------------------------------
    def __call__(
        self,
        params,
        x: jax.Array,                       # (B, S, D)
        positions: jax.Array,               # (B, S)
        *,
        cache: Optional[dict] = None,
        kv_x: Optional[jax.Array] = None,   # cross-attn source
        kv_positions: Optional[jax.Array] = None,
        update_cache: bool = True,
    ) -> Tuple[jax.Array, Optional[dict]]:
        cfg = self.cfg
        B, S, _ = x.shape
        hd, HQ, HKV = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
        G = HQ // HKV

        qkv = self._fused_qkv(params, x) if kv_x is None and not self.cross \
            else None
        if qkv is not None:
            qh, kh, vh = qkv
            q = qh.reshape(B, S, HQ, hd)
            k = kh.reshape(B, S, HKV, hd)
            v = vh.reshape(B, S, HKV, hd)
        else:
            q = self.q_proj(params["q"], x).reshape(B, S, HQ, hd)
            if self.cross and cache is not None and kv_x is None:
                k = v = None                 # cross-attn decode: KV from cache
            else:
                src = x if kv_x is None else kv_x
                k = self.k_proj(params["k"], src).reshape(B, src.shape[1], HKV, hd)
                v = self.v_proj(params["v"], src).reshape(B, src.shape[1], HKV, hd)

        if cfg.qk_norm:
            q = RMSNorm(hd, stack=self.stack)(params["q_norm"], q)
            if k is not None:
                k = RMSNorm(hd, stack=self.stack)(params["k_norm"], k)

        if not self.cross:
            theta = self._rope_theta()
            qc, qs = rotary(positions, hd, theta)
            q = apply_rope(q, qc, qs)
            if k is not None:
                kpos = positions if kv_positions is None else kv_positions
                kc, ks = rotary(kpos, hd, theta)
                k = apply_rope(k, kc, ks)

        new_cache = None
        if cache is not None:
            if self.cross:
                if k is not None and update_cache:   # prefill: stash enc KV
                    new_cache = {"k": k.astype(cache["k"].dtype),
                                 "v": v.astype(cache["v"].dtype),
                                 "pos": kv_positions.astype(jnp.int32)}
                else:
                    new_cache = cache
                k_att = new_cache["k"].astype(x.dtype)
                v_att = new_cache["v"].astype(x.dtype)
                kv_pos = new_cache["pos"]
            else:
                new_cache = self._write_cache(cache, k, v, positions)
                if S == 1 or S < cache["k"].shape[1]:
                    # decode / short append: attend over the cache
                    k_att = new_cache["k"].astype(x.dtype)
                    v_att = new_cache["v"].astype(x.dtype)
                    kv_pos = new_cache["pos"]
                else:
                    # prefill covering the whole cache: attend over fresh kv
                    k_att, v_att, kv_pos = k, v, positions
        else:
            k_att, v_att, kv_pos = k, v, (
                positions if kv_positions is None else kv_positions
            )

        causal = self.causal and not self.cross
        if S > cfg.flash_q_chunk:
            out = flash_attention(
                q.reshape(B, S, HKV, G, hd), k_att, v_att, positions, kv_pos,
                causal=causal, window=self.window, prefix_len=self.prefix_len,
                softcap=cfg.logit_softcap,
                q_chunk=cfg.flash_q_chunk, kv_chunk=cfg.flash_kv_chunk,
            )
        else:
            out = _direct_attention(
                q.reshape(B, S, HKV, G, hd), k_att, v_att, positions, kv_pos,
                causal=causal, window=self.window, prefix_len=self.prefix_len,
                softcap=cfg.logit_softcap,
            )
        out = self.o_proj(params["o"], out.reshape(B, S, HQ * hd))
        return out, new_cache

    # -- cache write -------------------------------------------------------
    def _write_cache(self, cache, k, v, positions):
        """Ring-buffer write at slot = pos % cache_len. If the incoming span
        exceeds the cache, only the trailing cache_len tokens are written
        (their slots are unique, so the scatter is well-defined)."""
        B, S = positions.shape
        cache_len = cache["k"].shape[1]
        if S >= cache_len:
            k, v = k[:, -cache_len:], v[:, -cache_len:]
            positions = positions[:, -cache_len:]
        slots = (positions % cache_len).astype(jnp.int32)
        bidx = jnp.arange(B, dtype=jnp.int32)[:, None]
        return {
            "k": cache["k"].at[bidx, slots].set(k.astype(cache["k"].dtype)),
            "v": cache["v"].at[bidx, slots].set(v.astype(cache["v"].dtype)),
            "pos": cache["pos"].at[bidx, slots].set(positions.astype(jnp.int32)),
        }
