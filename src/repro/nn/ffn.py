"""Feed-forward blocks: SwiGLU (LM family) and GeLU MLP (enc-dec), SWM-aware."""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.nn.linear import Linear

__all__ = ["SwiGLU", "MLP"]


@dataclasses.dataclass(frozen=True)
class SwiGLU:
    """wo( silu(wi(x)) * wu(x) ) — llama/gemma/qwen FFN."""

    d_model: int
    d_ff: int
    swm: "SWMConfig" = None
    stack: Tuple[int, ...] = ()
    expert_dims: Tuple[int, ...] = ()
    family: str = "ffn"
    dtype: str = "bfloat16"

    def _lin(self, i, o, ia, oa):
        return Linear(
            in_dim=i, out_dim=o, in_axis=ia, out_axis=oa,
            family=self.family, swm=self.swm, stack=self.stack,
            expert_dims=self.expert_dims, dtype=self.dtype,
        )

    @property
    def wi(self):
        return self._lin(self.d_model, self.d_ff, "embed", "mlp")

    @property
    def wu(self):
        return self._lin(self.d_model, self.d_ff, "embed", "mlp")

    @property
    def wo(self):
        return self._lin(self.d_ff, self.d_model, "mlp", "embed")

    def specs(self):
        return {"wi": self.wi.specs(), "wu": self.wu.specs(), "wo": self.wo.specs()}

    def __call__(self, params, x: jax.Array) -> jax.Array:
        wi, wu = self.wi, self.wu
        if (wi.is_circulant and wu.is_circulant
                and wi.block_size == wu.block_size
                and self.swm is not None and self.swm.impl == "dft"
                and not self.expert_dims
                and "w" in params["wi"]):
            # frozen (serve) trees have no time-domain tables; the per-Linear
            # frozen path below is the faster route there anyway (no rfft(w))
            # fused pair: the gate/up projections share one forward DFT
            from repro.core.circulant import block_circulant_apply_pair
            gi, ui = block_circulant_apply_pair(
                x, params["wi"]["w"], params["wu"]["w"])
            g, u = jax.nn.silu(gi), ui
        else:
            g = jax.nn.silu(wi(params["wi"], x))
            u = wu(params["wu"], x)
        return self.wo(params["wo"], g * u)


@dataclasses.dataclass(frozen=True)
class MLP:
    """wo(gelu(wi(x))) — classic 2-matrix FFN (seamless-m4t, paper's MLPs)."""

    d_model: int
    d_ff: int
    swm: "SWMConfig" = None
    stack: Tuple[int, ...] = ()
    family: str = "ffn"
    dtype: str = "bfloat16"

    @property
    def wi(self):
        return Linear(
            in_dim=self.d_model, out_dim=self.d_ff, in_axis="embed",
            out_axis="mlp", family=self.family, swm=self.swm,
            stack=self.stack, dtype=self.dtype,
        )

    @property
    def wo(self):
        return Linear(
            in_dim=self.d_ff, out_dim=self.d_model, in_axis="mlp",
            out_axis="embed", family=self.family, swm=self.swm,
            stack=self.stack, dtype=self.dtype,
        )

    def specs(self):
        return {"wi": self.wi.specs(), "wo": self.wo.specs()}

    def __call__(self, params, x: jax.Array) -> jax.Array:
        return self.wo(params["wo"], jax.nn.gelu(self.wi(params["wi"], x)))
