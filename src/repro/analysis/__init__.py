"""Static analysis: structural contracts over traces, and a repo AST lint.

The paper's complexity claims (arXiv:1804.11239 — O(n log n) block-circulant
inference/training, frozen BRAM-resident FFT(w) tables) are only real if the
*compiled programs* have the promised structure. Numerics can be right while
the structure silently regresses: a dense ``dot_general`` fallback, a
re-traced weight ``rfft``, an extra kernel launch, a per-trace weight concat
— all bit-identical, all destroying the asymptotics the repo exists to
demonstrate. This package turns those one-off assertions into a subsystem:

* :mod:`repro.analysis.walker` — the recursive jaxpr traversal (descends
  ``pjit``/``scan``/``while``/``cond``/``custom_vjp`` sub-jaxprs; stops at
  ``pallas_call`` bodies) with ``file:line`` provenance from
  ``eqn.source_info``. ``kernels.block_circulant.ops``'s public probes
  (``count_pallas_launches``/``outer_dot_shapes``) are wrappers over it.
* :mod:`repro.analysis.rules` — named declarative rules (``NoFFT``,
  ``NoWeightFFT``, ``NoDenseDotGeneral``, ``DenseFallbackDot``,
  ``LaunchBudget``, ``NoWeightConcat``, ``QuantizedTableDtypes``,
  ``DonatedInputsAliased``) that return :class:`Violation`\\ s, never bare
  booleans.
* :mod:`repro.analysis.contracts` — rules grouped into per-surface
  contracts (frozen-plan forward, train step, every serve prefill/decode
  bucket, int8 serve + launch parity). ``ServeEngine.audit()`` and
  ``train.loop.make_grad_step(audit_args=...)`` hook these into runtime
  gates; ``audit_config`` audits one registry config end to end.
* :mod:`repro.analysis.lint` — AST lint for repo-specific hazards: fft
  outside the blessed modules, wall-clock/unseeded-rng nondeterminism and
  blocking host sync inside ``serve/``, unmarked broad ``except``.

CLI: ``python -m repro.analysis --all-configs --json report.json`` audits
every registry config × surface plus the lint and exits non-zero on any
violation — the CI ``static-analysis`` job's entry point.
"""

from repro.analysis.contracts import (Contract, StructuralContractError,
                                      audit_config, audit_engine,
                                      run_contract)
from repro.analysis.lint import lint_file, lint_paths
from repro.analysis.rules import (DenseFallbackDot, DonatedInputsAliased,
                                  LaunchBudget, NoDenseDotGeneral, NoFFT,
                                  NoWeightConcat, NoWeightFFT,
                                  QuantizedTableDtypes, Violation)
from repro.analysis.walker import (collect_pure_vars, iter_eqns,
                                   source_location)

__all__ = [
    "Contract",
    "StructuralContractError",
    "Violation",
    "NoFFT",
    "NoWeightFFT",
    "NoDenseDotGeneral",
    "DenseFallbackDot",
    "LaunchBudget",
    "NoWeightConcat",
    "QuantizedTableDtypes",
    "DonatedInputsAliased",
    "audit_config",
    "audit_engine",
    "run_contract",
    "collect_pure_vars",
    "iter_eqns",
    "source_location",
    "lint_file",
    "lint_paths",
]
