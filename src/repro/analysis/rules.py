"""Declarative structural rules over traced programs.

Each rule is a small named object with a ``check`` method returning
:class:`Violation`\\ s — never booleans — so every failure carries the rule
name, the offending primitive, and ``file:line`` provenance from
``eqn.source_info``. Rules are grouped into per-surface contracts by
``analysis.contracts``; see that module for which rule gates which surface.

Jaxpr rules (``check(jaxpr)``):

* :class:`NoFFT` — no ``fft`` primitive anywhere in the trace. The frozen
  frequency-domain contract for surfaces whose whole dataflow is
  kernel-/DFT-backed (``impl='pallas'``/``'dft'``, ``BCPlan`` paths).
* :class:`NoWeightFFT` — no ``fft`` consuming *parameter-derived* data,
  decided by a purity taint analysis (``walker.collect_pure_vars``), not by
  shape matching — activation blocks ``(B*S, q, k)`` collide with other
  layers' table shapes. The freeze contract for ``impl='paper'``/``'freq'``
  surfaces, whose activation-side transforms are the paper's dataflow and
  legitimate.
* :class:`NoDenseDotGeneral` — zero ``dot_general`` outside ``pallas_call``
  bodies. Only pure-circulant surfaces can promise this.
* :class:`DenseFallbackDot` — no ``dot_general`` whose parameter-derived
  rank-2 operand has a circulant layer's dense-equivalent ``(in, out)``
  shape: the signature of a silent dense fallback inside a full model that
  also contains legitimate attention/MoE contractions.
* :class:`LaunchBudget` — exact/max ``pallas_call`` count.
* :class:`NoWeightConcat` — no ``concatenate`` producing a stacked frozen
  table shape (fused QKV/LSTM-gate groups must be pre-concatenated by
  ``freeze_params``, never concatenated per-trace).

Value rules (checked against non-jaxpr artifacts):

* :class:`QuantizedTableDtypes` (``check_params``) — frozen tables are int8
  with f32 per-block scales (``quantize='int8'``) or plain f32 (``'off'``).
* :class:`DonatedInputsAliased` (``check_lowered``) — the lowered module
  text records input-output aliasing for donated buffers.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, List, Optional, Sequence, Set, Tuple

from repro.analysis.walker import (collect_pure_vars, iter_eqns,
                                   source_location)

__all__ = [
    "Violation",
    "NoFFT",
    "NoWeightFFT",
    "NoDenseDotGeneral",
    "DenseFallbackDot",
    "LaunchBudget",
    "NoWeightConcat",
    "QuantizedTableDtypes",
    "DonatedInputsAliased",
]


@dataclasses.dataclass(frozen=True)
class Violation:
    """One broken contract: which rule, on which surface, where in the code."""

    rule: str
    message: str
    surface: str = ""
    primitive: str = ""
    where: Optional[str] = None        # "file.py:line" (or None)

    def __str__(self) -> str:
        loc = f" at {self.where}" if self.where else ""
        prim = f" [{self.primitive}]" if self.primitive else ""
        surf = f"{self.surface}: " if self.surface else ""
        return f"{surf}{self.rule}: {self.message}{prim}{loc}"

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


def _flag(rule: str, message: str, eqn=None) -> Violation:
    return Violation(
        rule=rule,
        message=message,
        primitive=eqn.primitive.name if eqn is not None else "",
        where=source_location(eqn) if eqn is not None else None,
    )


class NoFFT:
    """No ``fft`` primitive anywhere (weights *and* activations frozen out)."""

    name = "NoFFT"

    def check(self, jaxpr) -> List[Violation]:
        out = []
        for eqn in iter_eqns(jaxpr):
            if eqn.primitive.name == "fft":
                kind = eqn.params.get("fft_type")
                kind = getattr(kind, "name", kind)
                shape = tuple(eqn.invars[0].aval.shape)
                out.append(_flag(
                    self.name,
                    f"fft ({kind}) over operand shape {shape} in a trace "
                    f"that promises frozen frequency tables and no "
                    f"transform work",
                    eqn,
                ))
        return out


class NoWeightFFT:
    """No ``fft`` consuming parameter-derived (weight) data.

    ``n_param_invars`` is the number of leading flattened invars that are
    parameter leaves (``len(jax.tree.leaves(params))`` when the traced
    callable takes ``params`` first). An fft whose operand derives *only*
    from those invars and trace constants is a weight-side transform — the
    freeze contract broken. Activation transforms are tainted by
    tokens/cache and pass, whatever their shapes (shape matching is not
    sound: ``(B*S, q, k)`` activation blocks collide with other layers'
    ``(p', q', k)`` tables).
    """

    name = "NoWeightFFT"

    def __init__(self, n_param_invars: int):
        self.n_param_invars = int(n_param_invars)

    def check(self, jaxpr) -> List[Violation]:
        pure = collect_pure_vars(jaxpr, [True] * self.n_param_invars)
        out = []
        for eqn in iter_eqns(jaxpr):
            if eqn.primitive.name != "fft":
                continue
            op = eqn.invars[0]
            if hasattr(op, "val") or op not in pure:
                continue                        # token-/cache-tainted: ok
            src = tuple(op.aval.shape)
            dst = tuple(eqn.outvars[0].aval.shape)
            out.append(_flag(
                self.name,
                f"weight-side fft over parameter-derived data "
                f"{src} -> {dst}; frozen plans must carry rfft(w) as "
                f"data (freeze_params), never re-transform per trace",
                eqn,
            ))
        return out


class NoDenseDotGeneral:
    """Zero ``dot_general`` outside ``pallas_call`` bodies (strict)."""

    name = "NoDenseDotGeneral"

    def check(self, jaxpr) -> List[Violation]:
        out = []
        for eqn in iter_eqns(jaxpr):
            if eqn.primitive.name == "dot_general":
                shapes = [tuple(v.aval.shape) for v in eqn.invars]
                out.append(_flag(
                    self.name,
                    f"dense dot_general over {shapes} outside any "
                    f"pallas_call — the circulant path must not fall back "
                    f"to XLA contractions",
                    eqn,
                ))
        return out


class DenseFallbackDot:
    """No ``dot_general`` whose *parameter-derived* rank-2 operand matches a
    circulant layer's dense-equivalent ``(in, out) = (q*k, p*k)`` kernel
    shape. Without ``n_param_invars`` any matching rank-2 operand is
    flagged; with it, token-tainted operands (activations that einsum
    lowering collapsed to ``(B*S, d)`` matrices) pass."""

    name = "DenseFallbackDot"

    def __init__(self, dense_shapes: Iterable[Tuple[int, int]],
                 n_param_invars: Optional[int] = None):
        shapes = {tuple(int(d) for d in s) for s in dense_shapes}
        self.dense_shapes = shapes | {(o, i) for (i, o) in shapes}
        self.n_param_invars = n_param_invars

    def check(self, jaxpr) -> List[Violation]:
        pure = None
        if self.n_param_invars is not None:
            pure = collect_pure_vars(jaxpr, [True] * self.n_param_invars)
        out = []
        for eqn in iter_eqns(jaxpr):
            if eqn.primitive.name != "dot_general":
                continue
            for v in eqn.invars:
                shape = tuple(v.aval.shape)
                if pure is not None and not (hasattr(v, "val") or v in pure):
                    continue
                if len(shape) == 2 and shape in self.dense_shapes:
                    out.append(_flag(
                        self.name,
                        f"dot_general against a {shape} operand — the "
                        f"dense-equivalent kernel of a circulant layer "
                        f"(silent O(n^2) fallback)",
                        eqn,
                    ))
                    break
        return out


class LaunchBudget:
    """Exact (or bounded) number of ``pallas_call`` launches in the trace."""

    name = "LaunchBudget"

    def __init__(self, exact: Optional[int] = None,
                 max_launches: Optional[int] = None):
        if (exact is None) == (max_launches is None):
            raise ValueError("LaunchBudget takes exactly one of "
                             "exact= / max_launches=")
        self.exact, self.max_launches = exact, max_launches

    def check(self, jaxpr) -> List[Violation]:
        launches = [e for e in iter_eqns(jaxpr)
                    if e.primitive.name == "pallas_call"]
        n = len(launches)
        budget = self.exact if self.exact is not None else self.max_launches
        over = (n != self.exact if self.exact is not None
                else n > self.max_launches)
        if not over:
            return []
        kind = "exactly" if self.exact is not None else "at most"
        # point at the first launch beyond the budget when there is one —
        # that is the eqn a regression added
        culprit = launches[budget] if n > budget else (
            launches[-1] if launches else None)
        return [_flag(
            self.name,
            f"{n} pallas_call launches, contract requires {kind} {budget}",
            culprit,
        )]


class NoWeightConcat:
    """No in-trace ``concatenate`` assembling weight tables.

    Strict mode (no arguments): zero concatenate eqns at all — for
    pure-kernel surfaces. Serve mode: pass the fused-group ``(sum_p, q, K)``
    ``table_shapes`` (from the frozen params) and ``n_param_invars``; a
    concat is flagged only when it produces a stacked-table shape *and*
    every operand is parameter-derived — legitimate activation concats
    (e.g. the LSTM ``[x_t ; y_prev]``) are token-tainted and pass.
    """

    name = "NoWeightConcat"

    def __init__(self,
                 table_shapes: Optional[Iterable[Tuple[int, ...]]] = None,
                 n_param_invars: Optional[int] = None):
        self.table_shapes = (
            None if table_shapes is None
            else {tuple(int(d) for d in s) for s in table_shapes}
        )
        self.n_param_invars = n_param_invars

    def check(self, jaxpr) -> List[Violation]:
        pure = None
        if self.n_param_invars is not None:
            pure = collect_pure_vars(jaxpr, [True] * self.n_param_invars)
        out = []
        for eqn in iter_eqns(jaxpr):
            if eqn.primitive.name != "concatenate":
                continue
            shape = tuple(eqn.outvars[0].aval.shape)
            if self.table_shapes is not None and shape not in self.table_shapes:
                continue
            if pure is not None and not all(
                    hasattr(v, "val") or v in pure for v in eqn.invars):
                continue
            out.append(_flag(
                self.name,
                f"concatenate producing {shape} — fused weight groups must "
                f"be pre-concatenated once by freeze_params, not stacked "
                f"inside every cached executable",
                eqn,
            ))
        return out


class QuantizedTableDtypes:
    """Frozen-table dtype contract over a params tree (value rule).

    ``mode='int8'``: every frozen group (a dict carrying ``wr``/``wi``) must
    store int8 tables with a float32 ``w_scale``. ``mode='off'``: tables are
    float32 and carry no scale.
    """

    name = "QuantizedTableDtypes"

    def __init__(self, mode: str = "int8"):
        if mode not in ("off", "int8"):
            raise ValueError(f"unknown quantize mode {mode!r}")
        self.mode = mode

    def check_params(self, params) -> List[Violation]:
        out: List[Violation] = []

        def visit(node, path):
            if isinstance(node, dict):
                if "wr" in node and "wi" in node:
                    out.extend(self._check_group(node, path))
                for k, v in node.items():
                    visit(v, path + (str(k),))
            elif isinstance(node, (tuple, list)):
                for i, v in enumerate(node):
                    visit(v, path + (str(i),))

        visit(params, ())
        return out

    def _check_group(self, group: dict, path) -> List[Violation]:
        import jax.numpy as jnp

        loc = "/".join(path) or "<root>"
        wr, wi = group["wr"], group["wi"]
        scale = group.get("w_scale")
        bad = []
        if self.mode == "int8":
            if scale is None:
                bad.append(f"frozen table {loc!r} has no w_scale under "
                           f"quantize='int8'")
            else:
                if scale.dtype != jnp.float32:
                    bad.append(f"{loc}/w_scale is {scale.dtype}, "
                               f"contract requires float32")
                for name, t in (("wr", wr), ("wi", wi)):
                    if t.dtype != jnp.int8:
                        bad.append(f"{loc}/{name} is {t.dtype}, "
                                   f"contract requires int8")
        else:
            if scale is not None:
                bad.append(f"frozen table {loc!r} carries w_scale under "
                           f"quantize='off'")
            for name, t in (("wr", wr), ("wi", wi)):
                if not jnp.issubdtype(t.dtype, jnp.floating):
                    bad.append(f"{loc}/{name} is {t.dtype}, contract "
                               f"requires a float dtype")
        return [Violation(rule=self.name, message=m) for m in bad]


class DonatedInputsAliased:
    """Donated buffers actually alias outputs in the lowered module.

    Donation is invisible in jaxprs; the evidence lives in the StableHLO
    text as ``tf.aliasing_output`` (jax<=0.4) / ``jax.buffer_donor``
    argument attributes. ``check_lowered`` takes ``lowered.as_text()``.
    """

    name = "DonatedInputsAliased"
    MARKERS = ("tf.aliasing_output", "jax.buffer_donor")

    def check_lowered(self, text: str,
                      surface: str = "") -> List[Violation]:
        if any(m in text for m in self.MARKERS):
            return []
        return [Violation(
            rule=self.name,
            surface=surface,
            message="no input-output aliasing attribute in the lowered "
                    "module — donate_argnums did not take, so decode "
                    "round-trips the cache through fresh HBM",
        )]
