"""Repo-specific AST lint: hazards a generic linter cannot know about.

Four rules, each encoding a contract this codebase depends on:

* ``fft-outside-core`` — ``jnp.fft.*``/``np.fft.*`` calls anywhere but
  ``core/circulant.py`` and ``kernels/``. The whole point of the frozen-plan
  architecture is that transforms happen in exactly two blessed places
  (the impl dispatch and the freeze path); an fft call sprouting elsewhere
  bypasses the freeze accounting the no-fft jaxpr contracts audit.
* ``nondeterminism-in-serve`` — calls to wall-clock time
  (``time.time``/``monotonic``/``perf_counter``, ``datetime.now``) or
  unseeded module-level ``random.*`` inside ``serve/``. Snapshot/restore
  bit-equality and the chaos suite depend on injected clocks
  (``ServeEngine(clock=...)``) and seeded rngs (``random.Random(seed)`` and
  ``np.random.default_rng(seed)`` stay allowed; *references* like the
  ``clock=time.monotonic`` default are not calls and pass).
* ``blocking-sync-in-serve`` — ``.block_until_ready()`` / ``jax.device_get``
  inside ``serve/``: a host sync in the engine step path serializes the
  dispatch pipeline the continuous-batching numbers depend on.
  (``np.asarray`` is deliberately NOT flagged: the engine uses it
  pervasively on host-side scheduling state, and its device→host uses are
  the step loop's *intentional* sync points — the ones that read sampled
  tokens back to make admission decisions.)
* ``broad-except`` — ``except Exception:`` / bare ``except:`` without an
  explicit ``lint: allow-broad-except`` marker comment on the handler line.
  The dryrun best-effort backend introspection is the only allowlisted
  family; everything else must name the exceptions it absorbs.

``lint_paths`` walks ``src/repro`` by default and returns
:class:`~repro.analysis.rules.Violation`\\ s with ``file:line`` provenance.
"""

from __future__ import annotations

import ast
import os
from typing import List, Optional, Sequence

from repro.analysis.rules import Violation

__all__ = ["lint_file", "lint_paths", "ALLOW_BROAD_EXCEPT_MARKER"]

ALLOW_BROAD_EXCEPT_MARKER = "lint: allow-broad-except"

#: files/dirs (relative to the lint root) where fft calls are legitimate:
#: the impl dispatch and the freeze/kernel layer.
FFT_ALLOWED = ("core/circulant.py", "kernels/")

_FFT_ROOTS = {"jnp", "np", "jax", "numpy", "scipy", "fft"}
_TIME_CALLS = {
    ("time", "time"), ("time", "monotonic"), ("time", "perf_counter"),
    ("time", "monotonic_ns"), ("time", "time_ns"),
    ("datetime", "now"), ("datetime", "utcnow"),
}
#: random-module constructors that take an explicit seed and are therefore
#: deterministic; anything else on the module is ambient-seeded.
_RANDOM_SEEDED = {"Random", "SystemRandom"}


def _dotted(node: ast.AST) -> Optional[List[str]]:
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return parts[::-1]
    return None


def _v(rule: str, message: str, rel: str, node: ast.AST) -> Violation:
    return Violation(rule=rule, message=message, surface="lint",
                     where=f"{rel}:{node.lineno}")


def _lint_fft(tree: ast.AST, rel: str) -> List[Violation]:
    if any(rel == a or (a.endswith("/") and rel.startswith(a))
           for a in FFT_ALLOWED):
        return []
    out = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Attribute):
            continue
        parts = _dotted(node)
        if not parts or "fft" not in parts[:-1] or parts[0] not in _FFT_ROOTS:
            continue
        out.append(_v(
            "fft-outside-core",
            f"{'.'.join(parts)} outside core/circulant.py and kernels/ — "
            f"transforms must go through the blessed impl/freeze paths so "
            f"the no-fft trace contracts stay meaningful",
            rel, node))
    return out


def _lint_serve_nondet(tree: ast.AST, rel: str) -> List[Violation]:
    out = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        parts = _dotted(node.func)
        if not parts or len(parts) < 2:
            continue
        pair = (parts[0], parts[-1])
        if pair in _TIME_CALLS:
            out.append(_v(
                "nondeterminism-in-serve",
                f"{'.'.join(parts)}() inside serve/ — use the engine's "
                f"injected clock so snapshot/restore stays bit-equal "
                f"and chaos tests stay reproducible",
                rel, node))
        elif parts[0] == "random" and parts[1] not in _RANDOM_SEEDED:
            out.append(_v(
                "nondeterminism-in-serve",
                f"{'.'.join(parts)}() inside serve/ draws from the "
                f"ambient-seeded global rng — construct a seeded "
                f"random.Random(seed) instead",
                rel, node))
    return out


def _lint_serve_sync(tree: ast.AST, rel: str) -> List[Violation]:
    out = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        parts = _dotted(node.func)
        if parts and parts[-1] == "block_until_ready":
            out.append(_v(
                "blocking-sync-in-serve",
                "block_until_ready() in serve/ stalls the dispatch "
                "pipeline; let the jitted step's data dependency "
                "synchronize instead",
                rel, node))
        elif parts and tuple(parts[:2]) == ("jax", "device_get"):
            out.append(_v(
                "blocking-sync-in-serve",
                "jax.device_get() in serve/ is a blocking host transfer "
                "in the step path",
                rel, node))
    return out


def _lint_broad_except(tree: ast.AST, rel: str,
                       lines: Sequence[str]) -> List[Violation]:
    out = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.ExceptHandler):
            continue
        broad = node.type is None or (
            isinstance(node.type, ast.Name) and node.type.id in
            ("Exception", "BaseException"))
        if not broad:
            continue
        # marker on the handler line, or on a comment within the two lines
        # above it (the idiomatic place for a multi-line justification)
        lo = max(0, node.lineno - 3)
        window = lines[lo:node.lineno]
        if any(ALLOW_BROAD_EXCEPT_MARKER in ln for ln in window):
            continue
        out.append(_v(
            "broad-except",
            f"bare `except {'Exception' if node.type else ''}` — name the "
            f"exceptions this handler absorbs, or mark the line with "
            f"`# {ALLOW_BROAD_EXCEPT_MARKER}: <reason>`",
            rel, node))
    return out


def lint_file(path: str, rel: Optional[str] = None) -> List[Violation]:
    """Lint one file; ``rel`` is the path to report (defaults to ``path``)."""
    rel = (rel or path).replace(os.sep, "/")
    with open(path, "r", encoding="utf-8") as f:
        src = f.read()
    try:
        tree = ast.parse(src, filename=path)
    except SyntaxError as e:
        return [Violation(rule="parse-error", surface="lint",
                          message=str(e), where=f"{rel}:{e.lineno or 0}")]
    out = _lint_fft(tree, rel)
    if rel.startswith("serve/") or "/serve/" in rel:
        out += _lint_serve_nondet(tree, rel)
        out += _lint_serve_sync(tree, rel)
    out += _lint_broad_except(tree, rel, src.splitlines())
    return sorted(out, key=lambda v: (v.where or "", v.rule))


def lint_paths(root: Optional[str] = None) -> List[Violation]:
    """Lint every ``.py`` file under ``root`` (default: the installed
    ``repro`` package tree — what CI audits)."""
    if root is None:
        root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    out: List[Violation] = []
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames[:] = [d for d in dirnames if d != "__pycache__"]
        for fn in sorted(filenames):
            if not fn.endswith(".py"):
                continue
            path = os.path.join(dirpath, fn)
            rel = os.path.relpath(path, root)
            out.extend(lint_file(path, rel))
    return out
