"""Per-surface structural contracts over the repo's traced programs.

A :class:`Contract` names a surface (one traceable executable) and the rule
set it must satisfy; ``audit_config`` instantiates every surface for one
registry config and returns the violations. The surface × rule table:

======================  =====================================================
surface                 rules
======================  =====================================================
plan_forward            NoFFT, NoDenseDotGeneral, LaunchBudget(1),
                        NoWeightConcat (strict) — a fused multi-projection
                        ``BCPlan`` forward at the config's block geometry.
plan_train_step         NoFFT, NoDenseDotGeneral, LaunchBudget(3: forward z
                        + dx + dw), NoWeightConcat (strict) — SGD
                        value_and_grad through the frozen plan.
serve_prefill[...]      NoWeightFFT, DenseFallbackDot, NoWeightConcat
serve_decode[...]       (fused shapes); plus NoFFT when the config's impl is
                        kernel-/DFT-backed (``pallas``/``dft`` — the
                        ``paper``/``freq`` impls legitimately transform
                        *activations*, so only the weight side is
                        contractual); one surface per engine bucket.
serve_params            QuantizedTableDtypes (engine's quantize mode).
serve_donation          DonatedInputsAliased on the lowered decode/prefill
                        modules (engines built with ``donate=True``).
serve_launch_parity     int8 and fp32 engines launch the same number of
                        Pallas kernels per bucket (in-kernel dequant adds
                        no launch) — cross-engine, so it lives in
                        ``audit_config``, not ``ServeEngine.audit``.
======================  =====================================================

``ServeEngine.audit()`` runs the ``serve_*`` single-engine surfaces for a
live engine (``prewarm(audit=True)`` gates compilation on it); the
``python -m repro.analysis`` CLI runs everything for every registry config.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.rules import (DenseFallbackDot, DonatedInputsAliased,
                                  LaunchBudget, NoDenseDotGeneral, NoFFT,
                                  NoWeightConcat, NoWeightFFT,
                                  QuantizedTableDtypes, Violation)

__all__ = [
    "Contract",
    "StructuralContractError",
    "run_contract",
    "circulant_table_shapes",
    "dense_equivalent_shapes",
    "fused_table_shapes",
    "plan_surfaces",
    "audit_engine",
    "audit_config",
]


class StructuralContractError(AssertionError):
    """Raised when an audit gate (prewarm / train-step) finds violations."""

    def __init__(self, violations: Sequence[Violation]):
        self.violations = list(violations)
        lines = "\n".join(f"  - {v}" for v in self.violations)
        super().__init__(
            f"{len(self.violations)} structural contract violation(s):\n"
            f"{lines}"
        )


@dataclasses.dataclass(frozen=True)
class Contract:
    """A named surface and the jaxpr rules that gate it."""

    name: str
    rules: Tuple[Any, ...]


def run_contract(contract: Contract, jaxpr) -> List[Violation]:
    """Apply every rule of ``contract`` to one traced jaxpr; violations come
    back stamped with the surface name."""
    out: List[Violation] = []
    for rule in contract.rules:
        for v in rule.check(jaxpr):
            out.append(dataclasses.replace(v, surface=contract.name))
    return out


# ---------------------------------------------------------------------------
# Shape vocabularies derived from a model's specs / frozen params
# ---------------------------------------------------------------------------


def circulant_table_shapes(specs) -> List[Tuple[int, int, int]]:
    """Per-layer ``(p, q, k)`` time-domain table shapes of every
    circulant-tagged spec (stack/expert lead dims stripped — that is how
    the tables appear inside traced layers)."""
    from repro.nn.module import flatten_with_paths

    shapes = []
    for _, spec in flatten_with_paths(specs):
        if "circulant" in getattr(spec, "tags", ()):
            shapes.append(tuple(int(d) for d in spec.shape[-3:]))
    return sorted(set(shapes))


def dense_equivalent_shapes(specs) -> List[Tuple[int, int]]:
    """``(in, out) = (q*k, p*k)`` dense kernels the circulant layers
    replaced — the shapes a silent dense fallback would contract against.

    Shapes that some *legitimately dense* spec shares (MoE experts, the
    tied logits head, non-SWM projections) are excluded: a same-shaped
    legit contraction is indistinguishable from a fallback by shape alone,
    and a rule that cries wolf gates nothing. The rule therefore covers the
    shapes unique to circulant layers."""
    from repro.nn.module import flatten_with_paths

    legit = set()
    for _, spec in flatten_with_paths(specs):
        if ("circulant" not in getattr(spec, "tags", ())
                and len(spec.shape) >= 2):
            s = tuple(int(d) for d in spec.shape[-2:])
            legit |= {s, s[::-1]}
    return sorted({(q * k, p * k)
                   for (p, q, k) in circulant_table_shapes(specs)
                   if (q * k, p * k) not in legit})


def fused_table_shapes(params) -> List[Tuple[int, ...]]:
    """Shapes of every pre-concatenated fused frozen table in ``params``
    (the ``FUSED_KEY`` stacked ``(sum_p, q, K)`` groups) — the shapes an
    in-trace weight concat would produce."""
    from repro.kernels.block_circulant.plan import FUSED_KEY

    shapes = set()

    def visit(node):
        if isinstance(node, dict):
            fused = node.get(FUSED_KEY)
            if isinstance(fused, dict) and "wr" in fused:
                shapes.add(tuple(int(d) for d in fused["wr"].shape))
            for v in node.values():
                visit(v)
        elif isinstance(node, (tuple, list)):
            for v in node:
                visit(v)

    visit(params)
    return sorted(shapes)


# ---------------------------------------------------------------------------
# Plan surfaces (kernel path at the config's block geometry)
# ---------------------------------------------------------------------------


def _plan_geometry(cfg) -> Tuple[int, int, int]:
    from repro.core import circulant as circ

    d = int(cfg.d_model)
    k = circ.valid_block_size(int(cfg.swm.block_size), d, d)
    if k <= 1:
        raise ValueError(
            f"config {cfg.name!r} admits no circulant block on "
            f"(d_model={d}); plan surfaces need swm enabled")
    return d // k, d // k, k


def plan_surfaces(cfg) -> List[Tuple[Contract, Any]]:
    """(contract, jaxpr) pairs for the frozen-plan kernel path at this
    config's block geometry: a fused 3-projection forward (one launch) and
    an SGD train step through a frozen plan (exactly 3 launches)."""
    from repro.kernels.block_circulant import build_multi_plan, build_plan

    p, q, k = _plan_geometry(cfg)
    key = jax.random.PRNGKey(0)
    scale = (q * k) ** -0.5
    ws = [jax.random.normal(jax.random.fold_in(key, i), (p, q, k),
                            jnp.float32) * scale for i in range(3)]
    x = jax.random.normal(jax.random.fold_in(key, 7), (4, q * k), jnp.float32)

    mp = build_multi_plan(ws)
    fwd_jaxpr = jax.make_jaxpr(mp.apply_multi)(x)
    fwd = Contract(
        name=f"plan_forward[k={k}]",
        rules=(NoFFT(), NoDenseDotGeneral(), LaunchBudget(exact=1),
               NoWeightConcat()),
    )

    plan = build_plan(ws[0])
    y = jax.random.normal(jax.random.fold_in(key, 8), (4, p * k), jnp.float32)
    loss = lambda pl, b: ((pl.apply(b["x"]) - b["y"]) ** 2).mean()
    step_jaxpr = jax.make_jaxpr(jax.value_and_grad(loss))(
        plan, {"x": x, "y": y})
    step = Contract(
        name=f"plan_train_step[k={k}]",
        rules=(NoFFT(), NoDenseDotGeneral(), LaunchBudget(exact=3),
               NoWeightConcat()),
    )
    return [(fwd, fwd_jaxpr), (step, step_jaxpr)]


def audit_plan_surfaces(cfg) -> List[Violation]:
    out: List[Violation] = []
    for contract, jaxpr in plan_surfaces(cfg):
        out.extend(run_contract(contract, jaxpr))
    return out


# ---------------------------------------------------------------------------
# Serve surfaces (one live engine, every bucketed executable)
# ---------------------------------------------------------------------------

#: impls whose whole dataflow is kernel-/matmul-backed — their serve traces
#: must contain no fft primitive at all. The ``paper``/``freq`` impls stream
#: activations through rfft by design; for them only the weight side
#: (NoWeightFFT) is contractual.
FFT_FREE_IMPLS = ("pallas", "dft")


def _serve_trace_args(engine, Bb: int, Sb: Optional[int]):
    """Shape-faithful trace arguments for one bucket, mirroring
    ``ServeEngine.prewarm``'s synthesis (all-pad prefill rows / decode
    probes) — shapes are what matter to ``jax.make_jaxpr``."""
    if Sb is None:                           # decode bucket
        args = (engine.params, jnp.zeros((Bb, 1), jnp.int32), engine.cache,
                -jnp.ones((Bb,), jnp.int32), jnp.arange(Bb, dtype=jnp.int32))
        return args, {}
    toks = jnp.zeros((Bb, Sb), jnp.int32)
    pos = (jnp.broadcast_to(jnp.arange(Sb, dtype=jnp.int32), (Bb, Sb)) - Sb)
    slots = jnp.arange(Bb, dtype=jnp.int32)
    kw: Dict[str, Any] = {}
    if engine.prefix_cache:
        kw["donor_idx"] = slots
        kw["match_len"] = jnp.zeros((Bb,), jnp.int32)
    ex = engine.runner.prewarm_extra(Bb)
    if ex is not None:
        kw["extra"] = ex
    return (engine.params, toks, pos, engine.cache, slots), kw


def serve_trace_jaxprs(engine) -> List[Tuple[str, Any]]:
    """``(surface_name, jaxpr)`` for every prefill/decode bucket executable
    of a live engine — the exact functions ``prewarm`` compiles, traced
    unjitted so the structure is inspectable.

    Keyword operands (prefix-cache donors, encoder ``extra`` tokens) are
    threaded as *traced arguments*, not closure captures: a closed-over
    array becomes a trace constant, and the purity analysis would then
    read data derived from it (e.g. a whole encoder pass) as weight-side.
    """
    out = []
    for Sb in engine.prompt_buckets:
        for Bb in engine.batch_buckets:
            args, kw = _serve_trace_args(engine, Bb, Sb)
            kw_leaves, kw_tree = jax.tree.flatten(kw)
            jp = jax.make_jaxpr(
                lambda a, k: engine._prefill_fn(
                    *a, **jax.tree.unflatten(kw_tree, k))
            )(args, kw_leaves)
            out.append((f"serve_prefill[B{Bb},S{Sb}]", jp))
    for Bb in engine.decode_buckets:
        args, _ = _serve_trace_args(engine, Bb, None)
        jp = jax.make_jaxpr(engine._decode_fn)(*args)
        out.append((f"serve_decode[B{Bb}]", jp))
    return out


def _serve_rules(engine) -> Tuple[Any, ...]:
    specs = engine.runner.specs()
    n_params = len(jax.tree.leaves(engine.params))
    rules: List[Any] = [
        NoWeightFFT(n_param_invars=n_params),
        DenseFallbackDot(dense_equivalent_shapes(specs),
                         n_param_invars=n_params),
        NoWeightConcat(fused_table_shapes(engine.params),
                       n_param_invars=n_params),
    ]
    if engine.cfg.swm.impl in FFT_FREE_IMPLS:
        rules.insert(0, NoFFT())
    return tuple(rules)


def audit_engine(engine, traces=None) -> List[Violation]:
    """All single-engine serve contracts: every bucketed executable's trace
    rules, the frozen-table dtype contract for the engine's quantize mode,
    and lowered-module donation aliasing when ``donate=True``.

    ``traces`` (from :func:`serve_trace_jaxprs`) can be passed in to avoid
    re-tracing when the caller also needs the jaxprs (launch parity)."""
    out: List[Violation] = []
    if not engine.cfg.swm.enabled:
        return out                          # dense config: nothing to promise
    rules = _serve_rules(engine)
    traces = serve_trace_jaxprs(engine) if traces is None else traces
    for name, jp in traces:
        out.extend(run_contract(Contract(name=name, rules=rules), jp))

    for v in QuantizedTableDtypes(engine.quantize).check_params(
            engine.params):
        out.append(dataclasses.replace(v, surface="serve_params"))

    if engine.donate:
        donated = DonatedInputsAliased()
        for argnums, Sb in (((3,), int(engine.prompt_buckets[0])),
                            ((2,), None)):
            Bb = int(engine.batch_buckets[0] if Sb is not None
                     else engine.decode_buckets[0])
            args, kw = _serve_trace_args(engine, Bb, Sb)
            fn = engine._prefill_fn if Sb is not None else engine._decode_fn
            text = jax.jit(
                lambda *a: fn(*a, **kw), donate_argnums=argnums,
            ).lower(*args).as_text()
            kind = "prefill" if Sb is not None else "decode"
            out.extend(donated.check_lowered(
                text, surface=f"serve_donation[{kind}]"))
    return out


def launch_counts(engine, traces=None) -> Dict[str, int]:
    """Pallas launches per bucketed executable (for cross-engine parity)."""
    from repro.analysis.walker import iter_eqns

    traces = serve_trace_jaxprs(engine) if traces is None else traces
    return {
        name: sum(1 for e in iter_eqns(jp)
                  if e.primitive.name == "pallas_call")
        for name, jp in traces
    }


# ---------------------------------------------------------------------------
# Whole-config audit (the CLI's unit of work)
# ---------------------------------------------------------------------------


def _smoke_engine(model, cfg, params, quantize: str):
    from repro.serve.engine import ServeEngine

    return ServeEngine(model, cfg, params, batch=2, cache_len=32,
                       prompt_buckets=(8,), decode_buckets=(2,),
                       quantize=quantize)


def audit_config(arch: str, quantize_legs: Sequence[str] = ("off", "int8"),
                 ) -> Dict[str, Any]:
    """Audit every surface of one registry config (SMOKE shapes — the
    contracts are structural, so tiny geometry proves the same jaxprs).

    Returns ``{"arch", "impl", "surfaces", "violations": [...]}``; an empty
    ``violations`` list is the pass condition.
    """
    from repro.configs.registry import get_smoke
    from repro.launch.specs import build_model
    from repro.nn.module import init_params

    cfg = get_smoke(arch)
    violations: List[Violation] = []
    surfaces: List[str] = []

    if cfg.swm.enabled:
        for contract, jaxpr in plan_surfaces(cfg):
            surfaces.append(contract.name)
            violations.extend(run_contract(contract, jaxpr))

    model = build_model(cfg)
    params = init_params(model.specs(), 0)
    parity: Dict[str, Dict[str, int]] = {}
    for quantize in quantize_legs:
        if quantize != "off" and not cfg.swm.enabled:
            continue
        eng = _smoke_engine(model, cfg, params, quantize)
        traces = serve_trace_jaxprs(eng)
        vs = audit_engine(eng, traces=traces)
        tag = f"q={quantize}"
        surfaces.extend(f"{n}[{tag}]" for n, _ in traces)
        violations.extend(
            dataclasses.replace(v, surface=f"{v.surface}[{tag}]")
            for v in vs)
        parity[quantize] = launch_counts(eng, traces=traces)

    if "off" in parity and "int8" in parity:
        surfaces.append("serve_launch_parity")
        for name, n_off in parity["off"].items():
            n_q = parity["int8"].get(name)
            if n_q != n_off:
                violations.append(Violation(
                    rule="LaunchParity",
                    surface=f"serve_launch_parity[{name}]",
                    message=f"int8 engine launches {n_q} Pallas kernels "
                            f"where fp32 launches {n_off} — in-kernel "
                            f"dequant must add no launch",
                ))

    return {
        "arch": arch,
        "impl": cfg.swm.impl if cfg.swm.enabled else "dense",
        "surfaces": surfaces,
        "violations": [v.to_json() for v in violations],
    }
