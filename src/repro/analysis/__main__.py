"""CLI: audit registry configs × structural surfaces + the repo AST lint.

    python -m repro.analysis --all-configs --json BENCH_analysis.json
    python -m repro.analysis --config qwen3-0.6b --no-lint

Exit status 0 iff zero violations — the CI ``static-analysis`` job fails on
any. The JSON report is a ``BENCH_*``-style artifact: per-config surface
lists and violations (rule, surface, message, primitive, ``file:line``),
plus the lint findings, so structural evidence is diffable across PRs.
"""

from __future__ import annotations

import argparse
import json
import sys
import time


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="structural contract auditor + repo AST lint")
    ap.add_argument("--all-configs", action="store_true",
                    help="audit every registry arch (SMOKE shapes)")
    ap.add_argument("--config", action="append", default=[],
                    metavar="ARCH", help="audit one arch (repeatable)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the report artifact here")
    ap.add_argument("--no-lint", action="store_true",
                    help="skip the AST lint pass")
    ap.add_argument("--lint-root", default=None,
                    help="lint this tree instead of the repro package")
    args = ap.parse_args(argv)

    from repro.configs.registry import ARCHS

    archs = list(ARCHS) if args.all_configs else list(args.config)
    if not archs and args.no_lint:
        ap.error("nothing to do: pass --all-configs, --config, or lint")

    report = {"schema": "repro.analysis/v1", "configs": [], "lint": []}
    n_viol = 0

    from repro.analysis.contracts import audit_config

    for arch in archs:
        t0 = time.perf_counter()
        entry = audit_config(arch)
        entry["seconds"] = round(time.perf_counter() - t0, 3)
        report["configs"].append(entry)
        bad = entry["violations"]
        n_viol += len(bad)
        status = "FAIL" if bad else "ok"
        print(f"[{status:>4}] {arch:<22} impl={entry['impl']:<7} "
              f"{len(entry['surfaces'])} surfaces, "
              f"{len(bad)} violation(s), {entry['seconds']:.1f}s",
              flush=True)
        for v in bad:
            print(f"       - {v['surface']}: {v['rule']}: {v['message']}"
                  + (f" [{v['where']}]" if v.get("where") else ""))

    if not args.no_lint:
        from repro.analysis.lint import lint_paths

        lint = lint_paths(args.lint_root)
        report["lint"] = [v.to_json() for v in lint]
        n_viol += len(lint)
        print(f"[{'FAIL' if lint else 'ok':>4}] lint"
              f"{'' if args.lint_root is None else ' ' + args.lint_root}: "
              f"{len(lint)} violation(s)")
        for v in lint:
            print(f"       - {v.rule}: {v.message} [{v.where}]")

    report["violations_total"] = n_viol
    if args.json:
        with open(args.json, "w") as f:
            json.dump(report, f, indent=1)
        print(f"wrote {args.json}")
    print(f"total: {n_viol} violation(s) across "
          f"{len(archs)} config(s)" + ("" if args.no_lint else " + lint"))
    return 1 if n_viol else 0


if __name__ == "__main__":
    sys.exit(main())
