"""Recursive jaxpr walker with source provenance.

The single traversal primitive behind every structural probe in the repo.
``iter_eqns`` yields each equation of a (closed) jaxpr *and* of every
sub-jaxpr reachable through equation params — ``pjit`` bodies, ``scan`` /
``while`` / ``cond`` branches, ``custom_vjp``/``custom_jvp`` calls, and any
future higher-order primitive that stashes a Jaxpr/ClosedJaxpr (or a
tuple/list/dict of them) in its params. The one deliberate boundary is
``pallas_call``: kernel bodies are tiled VMEM programs, not XLA dataflow,
so rules that ask "does the *outer* program contain X" must not see inside
a launch. Pass ``into_pallas=True`` to lift that boundary.

``source_location`` maps an equation back to the user frame that traced it
(``file.py:line``), so rule violations point at code, not at a count
mismatch.

This module must stay dependency-free within ``repro`` — it is imported by
``kernels.block_circulant.ops`` (whose public probes are thin wrappers over
``iter_eqns``) and by ``analysis.rules``/``analysis.contracts``.
"""

from __future__ import annotations

from typing import Iterator, Optional

__all__ = [
    "as_jaxpr",
    "collect_pure_vars",
    "iter_eqns",
    "iter_sub_jaxprs",
    "source_location",
]


def as_jaxpr(jaxpr):
    """Unwrap a ClosedJaxpr (or anything with ``.jaxpr``) to the bare Jaxpr."""
    return getattr(jaxpr, "jaxpr", jaxpr)


def iter_sub_jaxprs(val) -> Iterator:
    """Yield every (bare) Jaxpr held inside an eqn-params value.

    Handles Jaxpr, ClosedJaxpr, and arbitrarily nested tuples/lists/dicts of
    them (``cond`` stores a tuple of branches; ``scan``/``pjit`` store a
    single ClosedJaxpr; ``custom_vjp`` stores callables wrapping jaxprs —
    those surface through their ``call_jaxpr``/``fun_jaxpr`` params).
    """
    if hasattr(val, "jaxpr"):                   # ClosedJaxpr (also has .eqns)
        yield val.jaxpr
    elif hasattr(val, "eqns"):                  # bare Jaxpr
        yield val
    elif isinstance(val, (tuple, list)):
        for v in val:
            yield from iter_sub_jaxprs(v)
    elif isinstance(val, dict):
        for v in val.values():
            yield from iter_sub_jaxprs(v)


def iter_eqns(jaxpr, *, into_pallas: bool = False) -> Iterator:
    """Depth-first over every eqn in ``jaxpr`` and all nested sub-jaxprs.

    ``pallas_call`` eqns are always yielded themselves; their kernel body is
    only descended into when ``into_pallas=True``.
    """
    stack = [as_jaxpr(jaxpr)]
    while stack:
        jx = stack.pop()
        for eqn in jx.eqns:
            yield eqn
            if eqn.primitive.name == "pallas_call" and not into_pallas:
                continue
            for val in eqn.params.values():
                stack.extend(iter_sub_jaxprs(val))


def _is_literal(v) -> bool:
    return hasattr(v, "val")                   # Literal carries a value


def collect_pure_vars(jaxpr, pure_invars) -> set:
    """Vars (at any nesting depth) that derive ONLY from the invars marked
    pure plus trace constants — i.e. carry no dependence on the impure
    invars.

    ``pure_invars`` is a bool per top-level invar (e.g. True for the
    flattened params leaves, False for tokens/cache). Constvars and
    literal-/iota-style no-input eqns count as pure: a weight table baked
    into the trace as a constant is still weight data. The serve contracts
    use this to tell a weight-side ``rfft`` (pure operand — the freeze
    contract broken) from the paper's legitimate activation transforms
    (token-tainted operands).

    Sub-jaxpr invars are aligned to the tail of ``eqn.invars`` (the layout
    of scan/pjit/cond operand conventions); unalignable leading invars are
    conservatively impure, so approximation errors only ever *hide* a pure
    var, never invent one.

    Sub-jaxprs are deduplicated by the tracer (two ``rfft`` call sites share
    one jaxpr object, hence one set of inner vars), so a sub-jaxpr's mask is
    the meet (AND) of its masks over *all* call sites, iterated to fixpoint:
    an inner var is pure only if every caller feeds it pure data. Same
    conservative direction — sharing can only demote, never promote.
    """
    root = as_jaxpr(jaxpr)
    mask0 = list(pure_invars) + [False] * (len(root.invars) - len(pure_invars))
    masks = {id(root): mask0[:len(root.invars)]}

    def meet(jx, mask) -> bool:
        old = masks.get(id(jx))
        if old is None:
            masks[id(jx)] = list(mask)
            return True
        new = [a and b for a, b in zip(old, mask)]
        if new != old:
            masks[id(jx)] = new
            return True
        return False

    changed = True
    pure: set = set()
    while changed:
        changed = False
        pure = set()

        def visit(jx):
            nonlocal changed
            pure.update(jx.constvars)
            for v, is_pure in zip(jx.invars, masks[id(jx)]):
                if is_pure:
                    pure.add(v)
            for eqn in jx.eqns:
                if all(_is_literal(v) or v in pure for v in eqn.invars):
                    pure.update(eqn.outvars)
                if eqn.primitive.name == "pallas_call":
                    continue
                for val in eqn.params.values():
                    for sub in iter_sub_jaxprs(val):
                        m = len(sub.invars)
                        tail = eqn.invars[-m:] if m else []
                        sub_mask = [False] * (m - len(tail)) + [
                            _is_literal(v) or v in pure for v in tail]
                        if meet(sub, sub_mask):
                            changed = True
                        visit(sub)

        visit(root)
    return pure


def source_location(eqn) -> Optional[str]:
    """``"path/to/file.py:line"`` of the user frame that traced ``eqn``,
    or None when provenance is unavailable (e.g. synthesized eqns)."""
    try:
        from jax._src import source_info_util

        frame = source_info_util.user_frame(eqn.source_info)
        if frame is None:
            # fall back to the innermost frame (library code) rather than
            # dropping provenance entirely
            frames = list(source_info_util.user_frames(eqn.source_info))
            frame = frames[0] if frames else None
        if frame is None:
            return None
        return f"{frame.file_name}:{frame.start_line}"
    except (ImportError, AttributeError):  # jax-internal API drift
        return None
