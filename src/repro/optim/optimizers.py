"""Optimizers: AdamW (configurable moment dtype) and Adafactor (factored v).

State trees mirror the param tree so the sharding rule tables apply leaf-
for-leaf (dist.sharding.opt_shardings adds the ZeRO-1 'data' extension).
Spec builders let the dry-run construct optimizer state as ShapeDtypeStructs
without ever allocating.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig
from repro.nn.module import ParamSpec, map_specs

__all__ = ["adamw_state_specs", "adamw_init", "adamw_update", "lr_schedule",
           "global_norm", "clip_by_global_norm"]


def lr_schedule(tcfg: TrainConfig, step: jax.Array) -> jax.Array:
    """Linear warmup then cosine decay to 10%."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / max(1, tcfg.warmup_steps), 1.0)
    t = jnp.clip(
        (step - tcfg.warmup_steps)
        / max(1, tcfg.total_steps - tcfg.warmup_steps),
        0.0, 1.0,
    )
    cos = 0.1 + 0.45 * (1 + jnp.cos(jnp.pi * t))
    return tcfg.learning_rate * warm * cos


def adamw_state_specs(param_specs, tcfg: TrainConfig):
    """Moment ParamSpecs mirroring the params (for dry-run SDS + sharding)."""
    mdt = jnp.dtype(tcfg.moment_dtype)

    def mom(path, s: ParamSpec):
        return ParamSpec(s.shape, mdt, s.axes, init="zeros")

    return {
        "m": map_specs(mom, param_specs),
        "v": map_specs(mom, param_specs),
    }


def adamw_init(params, tcfg: TrainConfig):
    mdt = jnp.dtype(tcfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, mdt)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
    }


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), norm


def adamw_update(params, grads, opt, step, tcfg: TrainConfig):
    """One AdamW step. Math in f32; params/moments cast back to storage dtype."""
    lr = lr_schedule(tcfg, step)
    b1, b2, eps, wd = tcfg.b1, tcfg.b2, tcfg.eps, tcfg.weight_decay
    t = step.astype(jnp.float32) + 1.0
    c1 = 1.0 - b1**t
    c2 = 1.0 - b2**t

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m32 = m.astype(jnp.float32) * b1 + g32 * (1 - b1)
        v32 = v.astype(jnp.float32) * b2 + jnp.square(g32) * (1 - b2)
        mhat = m32 / c1
        vhat = v32 / c2
        p32 = p.astype(jnp.float32)
        new_p = p32 - lr * (mhat / (jnp.sqrt(vhat) + eps) + wd * p32)
        return new_p.astype(p.dtype), m32.astype(m.dtype), v32.astype(v.dtype)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt["m"])
    flat_v = jax.tree.leaves(opt["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_params, {"m": new_m, "v": new_v}


# ---------------------------------------------------------------------------
# Adafactor (Shazeer & Stern, 2018) — factored second moment.
#
# For a (…, r, c) parameter the O(r·c) second moment is replaced by row/col
# accumulators of size O(r + c): the memory that makes AdamW-f32 infeasible
# for the dense arctic-480b baseline (EXPERIMENTS.md §Dry-run) disappears.
# State specs are ParamSpec trees (axes preserved minus the reduced dim), so
# dist.sharding's rule table applies to the factored state unchanged.
# ---------------------------------------------------------------------------


def adafactor_state_specs(param_specs, tcfg: TrainConfig):
    from repro.nn.module import ParamSpec as PS

    def vr(path, s):      # reduce last dim
        if len(s.shape) >= 2:
            return PS(s.shape[:-1], jnp.float32, s.axes[:-1], init="zeros")
        return PS(s.shape, jnp.float32, s.axes, init="zeros")

    def vc(path, s):      # reduce second-to-last dim
        if len(s.shape) >= 2:
            return PS(s.shape[:-2] + s.shape[-1:], jnp.float32,
                      s.axes[:-2] + s.axes[-1:], init="zeros")
        return PS((1,), jnp.float32, (None,), init="zeros")

    return {"vr": map_specs(vr, param_specs), "vc": map_specs(vc, param_specs)}


def adafactor_init(params, tcfg: TrainConfig):
    def vr(p):
        return jnp.zeros(p.shape[:-1] if p.ndim >= 2 else p.shape, jnp.float32)

    def vc(p):
        return jnp.zeros(p.shape[:-2] + p.shape[-1:] if p.ndim >= 2 else (1,),
                         jnp.float32)

    return {"vr": jax.tree.map(vr, params), "vc": jax.tree.map(vc, params)}


def adafactor_update(params, grads, opt, step, tcfg: TrainConfig):
    """Factored RMS update (no first moment), decay 1 - t^-0.8, update
    clipping at RMS 1.0, weight decay as in AdamW."""
    lr = lr_schedule(tcfg, step)
    t = step.astype(jnp.float32) + 1.0
    beta2 = 1.0 - t ** -0.8
    eps = 1e-30
    wd = tcfg.weight_decay

    def upd(p, g, vr, vc):
        g32 = g.astype(jnp.float32)
        g2 = jnp.square(g32) + eps
        if p.ndim >= 2:
            vr_n = beta2 * vr + (1 - beta2) * g2.mean(axis=-1)
            vc_n = beta2 * vc + (1 - beta2) * g2.mean(axis=-2)
            denom = (
                vr_n[..., :, None] * vc_n[..., None, :]
                / jnp.maximum(vr_n.mean(-1)[..., None, None], eps)
            )
            upd_ = g32 * jax.lax.rsqrt(denom + eps)
        else:
            vr_n = beta2 * vr + (1 - beta2) * g2
            vc_n = vc
            upd_ = g32 * jax.lax.rsqrt(vr_n + eps)
        # relative update clipping
        rms = jnp.sqrt(jnp.mean(jnp.square(upd_)) + eps)
        upd_ = upd_ / jnp.maximum(1.0, rms)
        p32 = p.astype(jnp.float32)
        new_p = p32 - lr * (upd_ + wd * p32)
        return new_p.astype(p.dtype), vr_n, vc_n

    flat_p, treedef = jax.tree.flatten(params)
    out = [upd(p, g, vr, vc) for p, g, vr, vc in zip(
        flat_p, jax.tree.leaves(grads), jax.tree.leaves(opt["vr"]),
        jax.tree.leaves(opt["vc"]))]
    return (jax.tree.unflatten(treedef, [o[0] for o in out]),
            {"vr": jax.tree.unflatten(treedef, [o[1] for o in out]),
             "vc": jax.tree.unflatten(treedef, [o[2] for o in out])})
