"""Distribution utilities: sharding rule tables and gradient compression."""
