"""Gradient compression for the DP all-reduce: chunked int8 + error feedback.

The paper's bandwidth argument (weights live in BRAM, only activations move)
has a training-time analogue: the DP gradient all-reduce is the dominant
inter-chip traffic, and 4x shrinks it to int8 with a per-chunk max-abs scale.
Error feedback keeps the scheme unbiased over time: whatever the quantizer
rounds away this step is carried into the next step's gradient, so the
*telescoped* sum of transmitted gradients equals the true sum exactly
(tests/test_compress.py::test_error_feedback_telescopes).
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "CHUNK",
    "int8_compress",
    "int8_decompress",
    "apply_error_feedback",
    "compressed_psum_grads",
]

# Quantization chunk: one scale per CHUNK contiguous values. 256 keeps the
# scale overhead at 1/64 of the int8 payload (f32 scale per 256 bytes).
CHUNK = 256


def int8_compress(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """g (any shape) -> (q int8 (n_chunks, CHUNK), scale f32 (n_chunks,)).

    Per-chunk symmetric max-abs scaling: q = round(g / s), s = max|g| / 127.
    Worst-case per-element error is s/2.
    """
    flat = g.astype(jnp.float32).reshape(-1)
    pad = (-flat.size) % CHUNK
    flat = jnp.pad(flat, (0, pad))
    chunks = flat.reshape(-1, CHUNK)
    scale = jnp.max(jnp.abs(chunks), axis=1) / 127.0
    scale = jnp.maximum(scale, 1e-30)
    q = jnp.clip(jnp.round(chunks / scale[:, None]), -127, 127)
    return q.astype(jnp.int8), scale


def int8_decompress(q: jax.Array, scale: jax.Array, shape, size: int,
                    dtype=jnp.float32) -> jax.Array:
    """Inverse of :func:`int8_compress` (drops the chunk padding).

    ``dtype`` restores the caller's gradient dtype: the dequant math runs in
    f32 (scales are f32), but a bf16 gradient tree must come back bf16 —
    otherwise one `_roundtrip` silently promotes the whole EF residual tree
    and `compressed_psum_grads` no longer round-trips dtypes.
    """
    deq = q.astype(jnp.float32) * scale[:, None]
    return deq.reshape(-1)[:size].reshape(shape).astype(dtype)


def _roundtrip(g: jax.Array) -> jax.Array:
    q, s = int8_compress(g)
    return int8_decompress(q, s, g.shape, g.size, g.dtype)


def apply_error_feedback(g: jax.Array, residual: jax.Array
                         ) -> Tuple[jax.Array, jax.Array]:
    """(transmitted, new_residual) for one step of EF-compressed SGD.

    transmitted = Q(g + residual); new_residual = (g + residual) - transmitted.
    Summing over steps telescopes: Σ tx_t + residual_T == Σ g_t. Both outputs
    come back in ``g.dtype`` (the error accumulation itself runs in f32 so a
    bf16 residual loses no more than bf16 storage demands).
    """
    corrected = g.astype(jnp.float32) + residual.astype(jnp.float32)
    tx = _roundtrip(corrected).astype(g.dtype)
    new_residual = (corrected - tx.astype(jnp.float32)).astype(g.dtype)
    return tx, new_residual


def compressed_psum_grads(grads, residuals, mesh, axes=("data",)):
    """EF-int8 gradient all-reduce, for use *inside* shard_map over ``axes``.

    Each shard quantizes its (error-corrected) local gradient, the dequantized
    payload is psum'd over the DP axes, and the local quantization error
    becomes the new residual. Returns (reduced_grads, new_residuals), trees
    matching ``grads``.
    """
    axes = tuple(axes)
    axis = axes if len(axes) > 1 else axes[0]

    def one(g, r):
        tx, new_r = apply_error_feedback(g, r)
        return jax.lax.psum(tx, axis), new_r

    pairs = jax.tree.map(one, grads, residuals)
    is_pair = lambda t: isinstance(t, tuple)
    reduced = jax.tree.map(lambda t: t[0], pairs, is_leaf=is_pair)
    new_res = jax.tree.map(lambda t: t[1], pairs, is_leaf=is_pair)
    return reduced, new_res
