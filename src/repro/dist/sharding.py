"""Logical-axis -> mesh-axis sharding rules (GSPMD), one table for everything.

Every parameter declares *logical* axis names (:class:`repro.nn.module.ParamSpec`);
this module maps them onto physical mesh axes:

  * TP rules: ``mlp`` / ``heads`` / ``kv_heads`` / ``vocab`` / ``experts``
    prefer the ``model`` axis (column-/row-parallel). Circulant block tables
    carry the same logical names on their (p, q) dims, so SWM layers inherit
    dense TP behavior unchanged.
  * FSDP: ``embed`` additionally shards over the ``data`` axis.
  * ZeRO-1: optimizer moments extend the param spec with the ``data`` axis on
    the first still-replicated, divisible dim.
  * A mesh axis is never assigned twice within one tensor, and an assignment
    is dropped whenever the dim is not divisible by the mesh-axis size (the
    GSPMD-legal subset — see tests/test_sharding.py).

An *ambient mesh* (set by the launchers) lets deep call sites — activation
constraints in the decoder, shard-local FFTs in core.circulant — pick up the
production mesh without threading it through every signature.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.nn.module import ParamSpec, map_specs

__all__ = [
    "data_axes",
    "batch_pspec",
    "make_param_rules",
    "make_act_rules",
    "spec_to_pspec",
    "param_shardings",
    "opt_shardings",
    "set_ambient_mesh",
    "constrain_batch_leading",
    "_AMBIENT_MESH",
]

# Data-parallel mesh axes, in nesting order (multi-pod meshes lead with pod).
_DP_NAMES = ("pod", "data")

# Logical axes that prefer the tensor-parallel 'model' axis.
_TP_LOGICAL = ("experts", "mlp", "heads", "kv_heads", "vocab")


def data_axes(mesh) -> Tuple[str, ...]:
    """The mesh's data-parallel axes, in mesh order (e.g. ('pod', 'data'))."""
    return tuple(a for a in mesh.axis_names if a in _DP_NAMES)


def _dp_size(mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in data_axes(mesh)] or [1]))


def batch_pspec(mesh, ndim: int, batch: Optional[int] = None) -> P:
    """PartitionSpec sharding the leading (batch) dim over the DP axes.

    ``batch`` (when known) gates divisibility: batch=1 cells (long_500k)
    replicate instead of producing an invalid sharding.
    """
    dp = data_axes(mesh)
    if not dp or (batch is not None and batch % _dp_size(mesh) != 0):
        return P(*([None] * ndim))
    lead = dp if len(dp) > 1 else dp[0]
    return P(lead, *([None] * (ndim - 1)))


def make_param_rules(mesh, fsdp: bool = False,
                     low_tp: bool = False) -> Dict[str, object]:
    """Logical axis -> preferred mesh axis (or axis tuple) for parameters."""
    rules: Dict[str, object] = {}
    if "model" in mesh.axis_names:
        tp = _TP_LOGICAL if not low_tp else ("experts",)
        for name in tp:
            rules[name] = "model"
    if fsdp:
        dp = data_axes(mesh)
        if dp:
            rules["embed"] = dp if len(dp) > 1 else dp[0]
    return rules


def make_act_rules(mesh) -> Dict[str, object]:
    """Logical axis -> mesh axis for *activations* (batch over DP, TP dims
    matching the param table so layer outputs land pre-sharded)."""
    rules: Dict[str, object] = {}
    dp = data_axes(mesh)
    if dp:
        rules["batch"] = dp if len(dp) > 1 else dp[0]
    if "model" in mesh.axis_names:
        for name in ("mlp", "heads", "kv_heads"):
            rules[name] = "model"
    return rules


def _axis_size(mesh, axis) -> int:
    if isinstance(axis, (tuple, list)):
        return int(np.prod([mesh.shape[a] for a in axis]))
    return int(mesh.shape[axis])


def spec_to_pspec(axes, shape, rules: Dict[str, object], mesh) -> P:
    """Assign mesh axes dim-by-dim: honor the rule table, never reuse a mesh
    axis within a tensor, drop assignments on non-divisible dims."""
    used = set()
    entries = []
    for name, dim in zip(axes, shape):
        axis = rules.get(name) if name is not None else None
        if axis is None:
            entries.append(None)
            continue
        flat = tuple(axis) if isinstance(axis, (tuple, list)) else (axis,)
        if (any(a in used for a in flat)
                or any(a not in mesh.axis_names for a in flat)
                or dim % _axis_size(mesh, axis) != 0):
            entries.append(None)
            continue
        used.update(flat)
        entries.append(axis if not isinstance(axis, list) else tuple(axis))
    return P(*entries)


def param_shardings(mesh, specs, *, fsdp: bool = False,
                    low_tp: bool = False):
    """ParamSpec tree -> NamedSharding tree under the param rule table."""
    rules = make_param_rules(mesh, fsdp, low_tp)
    return map_specs(
        lambda path, s: NamedSharding(
            mesh, spec_to_pspec(s.axes, s.shape, rules, mesh)
        ),
        specs,
    )


def opt_shardings(mesh, specs, *, fsdp: bool = False, low_tp: bool = False,
                  zero1: bool = True):
    """Optimizer-moment shardings: the param spec, ZeRO-1-extended.

    ZeRO-1 shards each moment over the DP axes on the first dim that is
    still replicated and divisible — moments never need to be resident
    full-size on every data replica.
    """
    rules = make_param_rules(mesh, fsdp, low_tp)
    dp = data_axes(mesh)
    dp_entry = (dp if len(dp) > 1 else dp[0]) if dp else None
    dp_size = _dp_size(mesh)

    def one(path, s: ParamSpec):
        base = list(spec_to_pspec(s.axes, s.shape, rules, mesh))
        base += [None] * (len(s.shape) - len(base))
        if zero1 and dp_entry is not None:
            used = set()
            for e in base:
                used.update(e if isinstance(e, tuple) else (e,))
            if not (set(dp) & used):
                for i, (e, dim) in enumerate(zip(base, s.shape)):
                    if e is None and dim % dp_size == 0 and dim > 1:
                        base[i] = dp_entry
                        break
                else:
                    # all dims taken or non-divisible (incl. dim==1 moments
                    # on a 1-sized DP mesh): fall back to the first free
                    # divisible dim regardless of size
                    for i, (e, dim) in enumerate(zip(base, s.shape)):
                        if e is None and dim % dp_size == 0:
                            base[i] = dp_entry
                            break
        return NamedSharding(mesh, P(*base))

    return map_specs(one, specs)


# ---------------------------------------------------------------------------
# Ambient mesh
# ---------------------------------------------------------------------------

# Single-element mutable cell so deep call sites can read the production mesh
# without signature plumbing; [None] means "no mesh registered" (unit tests,
# single-host examples).
_AMBIENT_MESH = [None]


def set_ambient_mesh(mesh) -> None:
    """Register (or clear, with None) the process-wide production mesh."""
    _AMBIENT_MESH[0] = mesh


def constrain_batch_leading(x):
    """with_sharding_constraint(P(dp, None, ...)) under the ambient mesh.

    No-op when no mesh is registered or the leading dim is not divisible —
    safe to call unconditionally from model code.
    """
    mesh = _AMBIENT_MESH[0]
    if mesh is None:
        return x
    dp = data_axes(mesh)
    if not dp or x.shape[0] % _dp_size(mesh) != 0:
        return x
    import jax

    spec = batch_pspec(mesh, x.ndim, batch=x.shape[0])
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
