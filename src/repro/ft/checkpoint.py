"""Sharded, atomic, async checkpointing with elastic-remesh restore.

Layout:  <dir>/step_<N>/
            MANIFEST.json           tree structure, shapes, dtypes, mesh
            <flat-path>.<shard>.npy one file per addressable shard per leaf
         <dir>/LATEST               atomic pointer (tmp+rename)

Design points for real clusters (works degenerately on 1 host):
  * every process writes only its addressable shards (no host gather of the
    full array — required at 480B scale);
  * the step directory is written under a tmp name and renamed only after
    all leaves + manifest are fsynced → a crash never leaves a half
    checkpoint visible;
  * restore REASSEMBLES arrays under the *current* mesh: if the mesh shape
    changed (elastic shrink/grow, pod loss), shards are re-split from the
    loaded global view — checkpoint-portable resharding;
  * ``AsyncCheckpointer`` snapshots device arrays to host (cheap, blocking)
    then serializes on a background thread — training resumes immediately.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step",
           "available_steps", "AsyncCheckpointer"]


def _flatten(tree, prefix=()):
    if isinstance(tree, dict):
        for k in sorted(tree.keys()):
            yield from _flatten(tree[k], prefix + (str(k),))
    else:
        yield ".".join(prefix), tree


def _unflatten(flat: Dict[str, Any]):
    root: Dict[str, Any] = {}
    for path, v in flat.items():
        parts = path.split(".")
        d = root
        for p in parts[:-1]:
            d = d.setdefault(p, {})
        d[parts[-1]] = v
    return root


def _pspec_to_json(sharding) -> list:
    spec = getattr(sharding, "spec", None)
    if spec is None:
        return []
    out = []
    for e in spec:
        if e is None:
            out.append(None)
        elif isinstance(e, (tuple, list)):
            out.append(list(e))
        else:
            out.append([e])
    return out


def save_checkpoint(ckpt_dir: str, step: int, state) -> str:
    """Write state (pytree of jax Arrays) for `step`. Atomic."""
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    # Sweep stale step_*.tmp dirs left by writers that crashed between the
    # shard writes and the rename — they are invisible to restore (the
    # rename never happened) but would otherwise accumulate forever.
    if os.path.isdir(ckpt_dir):
        for name in os.listdir(ckpt_dir):
            if name.startswith("step_") and name.endswith(".tmp"):
                shutil.rmtree(os.path.join(ckpt_dir, name),
                              ignore_errors=True)
    os.makedirs(tmp, exist_ok=True)

    manifest = {"step": step, "leaves": {}}
    for path, leaf in _flatten(state):
        info = {
            "shape": list(np.shape(leaf)),
            "dtype": str(jnp.asarray(leaf).dtype)
            if not hasattr(leaf, "dtype") else str(leaf.dtype),
            "spec": _pspec_to_json(getattr(leaf, "sharding", None)),
            "shards": [],
        }
        if hasattr(leaf, "addressable_shards"):
            for si, shard in enumerate(leaf.addressable_shards):
                if shard.replica_id != 0:      # one replica writes
                    continue
                fn = f"{path}.{si}.npy"
                idx = [[s.start, s.stop]
                       for s in _norm_index(shard.index, leaf.shape)]
                data = np.asarray(jax.device_get(shard.data))
                if data.dtype == jnp.bfloat16:
                    data = data.astype(np.float32)
                np.save(os.path.join(tmp, fn), data)
                info["shards"].append({"file": fn, "index": idx})
        else:                                   # host numpy leaf
            fn = f"{path}.0.npy"
            data = np.asarray(leaf)
            if data.dtype == jnp.bfloat16:
                data = data.astype(np.float32)
            np.save(os.path.join(tmp, fn), data)
            info["shards"].append(
                {"file": fn,
                 "index": [[0, d] for d in np.shape(leaf)]}
            )
        manifest["leaves"][path] = info

    with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    # atomic LATEST pointer
    ptr_tmp = os.path.join(ckpt_dir, ".LATEST.tmp")
    with open(ptr_tmp, "w") as f:
        f.write(str(step))
    os.replace(ptr_tmp, os.path.join(ckpt_dir, "LATEST"))
    return final


def _norm_index(index, shape):
    out = []
    for s, dim in zip(index, shape):
        start = 0 if s.start is None else s.start
        stop = dim if s.stop is None else s.stop
        out.append(slice(start, stop))
    return out


def latest_step(ckpt_dir: str) -> Optional[int]:
    p = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(p):
        return None
    with open(p) as f:
        return int(f.read().strip())


def available_steps(ckpt_dir: str) -> list:
    """All fully-written step numbers under ``ckpt_dir``, ascending.

    Only renamed (complete) step dirs count — ``.tmp`` dirs from crashed
    writers are invisible, same as to ``restore_checkpoint``. Restore
    policies that fall back past a bad LATEST (the serve supervisor's
    heal path) walk this list from the end."""
    if not os.path.isdir(ckpt_dir):
        return []
    steps = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            try:
                steps.append(int(name[len("step_"):]))
            except ValueError:
                continue
    return sorted(steps)


def restore_checkpoint(ckpt_dir: str, step: int, shardings=None,
                       mesh: Optional[Mesh] = None):
    """Load `step`. `shardings`: pytree of NamedSharding for the CURRENT
    mesh (may differ from the saving mesh — elastic restore); None loads
    host arrays."""
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "MANIFEST.json")) as f:
        manifest = json.load(f)

    flat_shardings = dict(_flatten(shardings)) if shardings is not None else {}
    flat = {}
    for path, info in manifest["leaves"].items():
        shape = tuple(info["shape"])
        dtype = np.dtype(info["dtype"]) if info["dtype"] != "bfloat16" else jnp.bfloat16
        full = np.zeros(shape, dtype=np.float32 if dtype == jnp.bfloat16 else dtype)
        for sh in info["shards"]:
            arr = np.load(os.path.join(d, sh["file"]))
            idx = tuple(slice(*s) for s in sh["index"])
            full[idx] = arr
        sharding = flat_shardings.get(path)
        if sharding is not None:
            flat[path] = jax.device_put(
                jnp.asarray(full, dtype=dtype), sharding
            )
        else:
            flat[path] = jnp.asarray(full, dtype=dtype)
    return _unflatten(flat)


class AsyncCheckpointer:
    """Double-buffered async writer: snapshot → background serialize."""

    def __init__(self, ckpt_dir: str):
        self.ckpt_dir = ckpt_dir
        os.makedirs(ckpt_dir, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def save(self, step: int, state):
        self.wait()
        # snapshot to host synchronously (correctness), serialize async
        host_state = jax.tree.map(
            lambda x: np.asarray(jax.device_get(x)), state
        )

        def work():
            try:
                save_checkpoint(self.ckpt_dir, step, host_state)
            # lint: allow-broad-except — background writer thread; the
            # error (whatever it is) must reach the caller on wait()
            except BaseException as e:   # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()
