"""Fault tolerance: atomic checkpointing and supervised drivers.

Shared by the training loop (``TrainDriver`` auto-restart) and the serving
engine (``ServeEngine.snapshot``/``restore`` ride on the same atomic
checkpoint machinery; ``repro.serve.guard.ServeFaultInjector`` extends
``FaultInjector`` to the serve path).
"""

from repro.ft.checkpoint import (AsyncCheckpointer, latest_step,
                                 restore_checkpoint, save_checkpoint)
from repro.ft.driver import FaultInjector, StragglerWatchdog, TrainDriver

__all__ = [
    "AsyncCheckpointer", "latest_step", "restore_checkpoint",
    "save_checkpoint", "FaultInjector", "StragglerWatchdog", "TrainDriver",
]
