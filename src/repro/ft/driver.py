"""Fault-tolerant training driver: auto-restart, straggler watchdog, elastic.

``TrainDriver.run`` wraps the jitted train_step in a supervisor loop:

  * periodic async checkpoints (tcfg.checkpoint_every);
  * on a step failure (device error, injected fault, preemption signal) it
    restores the latest checkpoint and resumes — steps are idempotent
    because the data pipeline is keyed by step number;
  * a straggler watchdog tracks per-step wall time with an EWMA; steps
    slower than ``mean + straggler_k·std`` are logged, and after
    ``max_consecutive_slow`` the driver requests a checkpoint + re-mesh
    (on real pods: drop the slow host; here: the hook fires and is tested
    via injected delays);
  * elastic re-mesh: ``restore_elastic`` reloads any checkpoint onto the
    *current* mesh shape (ft.checkpoint reshards through host memory).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Optional

import jax
import numpy as np

from repro.configs.base import TrainConfig
from repro.ft.checkpoint import (AsyncCheckpointer, latest_step,
                                 restore_checkpoint)

__all__ = ["TrainDriver", "StragglerWatchdog", "FaultInjector"]


class StragglerWatchdog:
    """EWMA step-time tracker; flags outliers and escalates."""

    def __init__(self, k: float = 3.0, max_consecutive: int = 3,
                 warmup: int = 5):
        self.k, self.max_consecutive, self.warmup = k, max_consecutive, warmup
        self.mean = 0.0
        self.var = 0.0
        self.n = 0
        self.consecutive = 0
        self.events = []          # (step, dt, severity)

    def observe(self, step: int, dt: float) -> str:
        """Returns 'ok' | 'slow' | 'escalate'."""
        self.n += 1
        if self.n <= self.warmup:
            a = 1.0 / self.n
            self.mean += a * (dt - self.mean)
            self.var = max(self.var, (dt - self.mean) ** 2)
            return "ok"
        std = max(self.var, 1e-12) ** 0.5
        slow = dt > self.mean + self.k * std and dt > 1.2 * self.mean
        a = 0.1
        if not slow:              # don't poison stats with outliers
            self.mean += a * (dt - self.mean)
            self.var = (1 - a) * self.var + a * (dt - self.mean) ** 2
            self.consecutive = 0
            return "ok"
        self.consecutive += 1
        self.events.append((step, dt, "slow"))
        if self.consecutive >= self.max_consecutive:
            self.consecutive = 0
            self.events.append((step, dt, "escalate"))
            return "escalate"
        return "slow"


class FaultInjector:
    """Deterministic fault schedule for tests: raise at given steps.

    ``p_fail``/``seed`` layer seeded *random* faults on top of the explicit
    schedule: each ``maybe_fire`` call draws once from a private
    ``np.random.default_rng(seed)`` stream, so the same seed reproduces the
    exact same fault pattern (test-enforced). Each step fires at most once
    (``fired``), so a restarted run passes the step it died on."""

    def __init__(self, fail_at=(), delay_at=(), delay_s: float = 0.0,
                 p_fail: float = 0.0, seed: int = 0):
        self.fail_at = set(fail_at)
        self.delay_at = set(delay_at)
        self.delay_s = delay_s
        self.p_fail = float(p_fail)
        self.seed = int(seed)
        self.rng = np.random.default_rng(seed)
        self.fired = set()

    def maybe_fire(self, step: int):
        if step in self.delay_at:
            time.sleep(self.delay_s)
        if step in self.fired:
            return
        if step in self.fail_at:
            self.fired.add(step)
            raise RuntimeError(f"injected fault at step {step}")
        if self.p_fail > 0.0 and self.rng.random() < self.p_fail:
            self.fired.add(step)
            raise RuntimeError(f"injected random fault at step {step}")


class TrainDriver:
    def __init__(self, train_step, tcfg: TrainConfig, data_fn,
                 state_shardings=None, mesh=None,
                 fault_injector: Optional[FaultInjector] = None,
                 on_remesh: Optional[Callable] = None):
        self.train_step = train_step
        self.tcfg = tcfg
        self.data_fn = data_fn                   # step -> batch pytree
        self.state_shardings = state_shardings
        self.mesh = mesh
        self.ckpt = AsyncCheckpointer(tcfg.checkpoint_dir)
        self.watchdog = StragglerWatchdog()
        self.faults = fault_injector
        self.on_remesh = on_remesh
        self.restarts = 0
        self.metrics_log = []

    # ------------------------------------------------------------------
    def _restore(self, state):
        step = latest_step(self.tcfg.checkpoint_dir)
        if step is None:
            return state, 0
        restored = restore_checkpoint(
            self.tcfg.checkpoint_dir, step,
            shardings=self.state_shardings, mesh=self.mesh,
        )
        return restored, int(step)

    def run(self, state, n_steps: int, start_step: int = 0,
            max_restarts: int = 8):
        step = start_step
        while step < n_steps:
            try:
                t0 = time.perf_counter()
                if self.faults is not None:
                    self.faults.maybe_fire(step)
                batch = self.data_fn(step)
                state, metrics = self.train_step(state, batch)
                jax.block_until_ready(metrics["loss"])
                dt = time.perf_counter() - t0
                verdict = self.watchdog.observe(step, dt)
                if verdict == "escalate" and self.on_remesh is not None:
                    self.ckpt.wait()
                    self.ckpt.save(step + 1, state)
                    self.ckpt.wait()
                    state = self.on_remesh(state)
                self.metrics_log.append(
                    {"step": step, "dt": dt,
                     "loss": float(metrics["loss"])}
                )
                step += 1
                if step % self.tcfg.checkpoint_every == 0:
                    self.ckpt.save(step, state)
            except (RuntimeError, jax.errors.JaxRuntimeError) as e:
                self.restarts += 1
                if self.restarts > max_restarts:
                    raise
                self.ckpt.wait()
                state, step = self._restore(state)
        self.ckpt.wait()
        return state
