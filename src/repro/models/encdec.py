"""Encoder–decoder transformer backbone (seamless-m4t-medium).

Per the assignment, the speech/multimodal frontend is a STUB: ``input_specs``
feeds precomputed frame embeddings (B, T_enc, d_model) straight into the
encoder. The backbone — bidirectional encoder, causal decoder with
cross-attention — is fully implemented, with SWM compression on every
projection (enc/dec self-attn, cross-attn, FFN).

Decode caches: decoder self-attn KV (ring buffer) + cross-attn KV computed
once from the encoder output during prefill.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.nn.attention import Attention, init_kv_cache
from repro.nn.ffn import MLP
from repro.nn.layers import Embedding, RMSNorm
from repro.nn.module import ParamSpec

__all__ = ["EncDecLM"]


@dataclasses.dataclass(frozen=True)
class EncDecLM:
    cfg: ModelConfig

    # ------------------------------------------------------------------
    def _enc_layer_specs(self, stack):
        cfg = self.cfg
        return {
            "ln1": RMSNorm(cfg.d_model, stack=stack).specs(),
            "attn": Attention(cfg, causal=False, stack=stack).specs(),
            "ln2": RMSNorm(cfg.d_model, stack=stack).specs(),
            "ffn": MLP(d_model=cfg.d_model, d_ff=cfg.d_ff, swm=cfg.swm,
                       stack=stack, dtype=cfg.param_dtype).specs(),
        }

    def _dec_layer_specs(self, stack):
        cfg = self.cfg
        return {
            "ln1": RMSNorm(cfg.d_model, stack=stack).specs(),
            "self_attn": Attention(cfg, causal=True, stack=stack).specs(),
            "ln_x": RMSNorm(cfg.d_model, stack=stack).specs(),
            "cross_attn": Attention(cfg, cross=True, stack=stack).specs(),
            "ln2": RMSNorm(cfg.d_model, stack=stack).specs(),
            "ffn": MLP(d_model=cfg.d_model, d_ff=cfg.d_ff, swm=cfg.swm,
                       stack=stack, dtype=cfg.param_dtype).specs(),
        }

    def specs(self):
        cfg = self.cfg
        ne = cfg.n_enc_layers or cfg.n_layers
        nd = cfg.n_layers
        return {
            "embed": Embedding(cfg.vocab, cfg.d_model,
                               dtype=cfg.param_dtype).specs(),
            "enc_norm": RMSNorm(cfg.d_model).specs(),
            "dec_norm": RMSNorm(cfg.d_model).specs(),
            "encoder": self._enc_layer_specs((ne,)),
            "decoder": self._dec_layer_specs((nd,)),
        }

    # ------------------------------------------------------------------
    def encode(self, params, frames: jax.Array):
        """frames (B, T, d_model) -> encoder output (B, T, d)."""
        cfg = self.cfg
        B, T, _ = frames.shape
        pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
        x = frames.astype(cfg.dtype)

        def body(carry, p):
            x = carry
            h = RMSNorm(cfg.d_model)(p["ln1"], x)
            a, _ = Attention(cfg, causal=False)(p["attn"], h, pos)
            x = x + a
            h = RMSNorm(cfg.d_model)(p["ln2"], x)
            x = x + MLP(d_model=cfg.d_model, d_ff=cfg.d_ff, swm=cfg.swm,
                        dtype=cfg.param_dtype)(p["ffn"], h)
            return x, None

        body_fn = jax.checkpoint(body) if cfg.remat != "none" else body
        x, _ = jax.lax.scan(body_fn, x, params["encoder"])
        return RMSNorm(cfg.d_model)(params["enc_norm"], x), pos

    def _decode_stack(self, params, x, positions, enc_out, enc_pos, cache):
        cfg = self.cfg
        use_cache = cache is not None

        def body(carry, xs):
            x = carry
            p, c = xs
            h = RMSNorm(cfg.d_model)(p["ln1"], x)
            a, nc_self = Attention(cfg, causal=True)(
                p["self_attn"], h, positions,
                cache=c["self"] if use_cache else None,
            )
            x = x + a
            h = RMSNorm(cfg.d_model)(p["ln_x"], x)
            ca = Attention(cfg, cross=True)
            if use_cache:
                a, nc_cross = ca(
                    p["cross_attn"], h, positions,
                    cache=c["cross"],
                    kv_x=enc_out, kv_positions=enc_pos,
                    update_cache=enc_out is not None,
                )
            else:
                a, _ = ca(p["cross_attn"], h, positions,
                          kv_x=enc_out, kv_positions=enc_pos)
                nc_cross = None
            x = x + a
            h = RMSNorm(cfg.d_model)(p["ln2"], x)
            x = x + MLP(d_model=cfg.d_model, d_ff=cfg.d_ff, swm=cfg.swm,
                        dtype=cfg.param_dtype)(p["ffn"], h)
            nc = {"self": nc_self, "cross": nc_cross} if use_cache else None
            return x, nc

        body_fn = jax.checkpoint(body) if cfg.remat != "none" else body
        x, new_cache = jax.lax.scan(
            body_fn, x, (params["decoder"], cache)
        )
        return x, new_cache

    # ------------------------------------------------------------------
    def forward(self, params, frames: jax.Array, tokens: jax.Array,
                cache=None, logits_mode: str = "all",
                positions: Optional[jax.Array] = None):
        """Teacher-forced training / prefill: returns (logits, cache, aux).

        ``positions`` overrides the default ``arange`` decoder positions —
        the serve engine passes left-padded buckets with negative pad
        positions, which the causal self-attention masks out (encoder
        positions are all >= 0, so cross-attention sees the full encoder
        output from every real decoder position)."""
        cfg = self.cfg
        enc_out, enc_pos = self.encode(params, frames)
        emb = Embedding(cfg.vocab, cfg.d_model, dtype=cfg.param_dtype)
        x = emb.encode(params["embed"], tokens)
        B, S, _ = x.shape
        pos = (positions if positions is not None else
               jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S)))
        x, new_cache = self._decode_stack(
            params, x, pos, enc_out, enc_pos, cache
        )
        x = RMSNorm(cfg.d_model)(params["dec_norm"], x)
        if logits_mode == "none":
            return x, new_cache, jnp.zeros((), jnp.float32)
        if logits_mode == "last":
            x = x[:, -1:]
        logits = emb.decode(params["embed"], x)
        return logits, new_cache, jnp.zeros((), jnp.float32)

    def forward_hidden(self, params, tokens, *, frames=None, img_embeds=None):
        h, _, aux = self.forward(params, frames, tokens, logits_mode="none")
        return h, aux

    def output_table(self, params) -> jax.Array:
        return params["embed"]["table"]

    def init_cache(self, batch: int, cache_len: int) -> dict:
        cfg = self.cfg
        nd = cfg.n_layers
        enc_len = cfg.enc_seq or cache_len
        one_self = init_kv_cache(batch, cache_len, cfg.n_kv_heads,
                                 cfg.head_dim, cfg.dtype)
        one_cross = init_kv_cache(batch, enc_len, cfg.n_kv_heads,
                                  cfg.head_dim, cfg.dtype)
        stack = lambda c: jax.tree.map(
            lambda a: jnp.broadcast_to(a, (nd,) + a.shape).copy(), c
        )
        return {"self": stack(one_self), "cross": stack(one_cross)}

    def decode_step(self, params, tokens: jax.Array, cache, pos: jax.Array):
        """One decoder token; cross KV comes from the prefilled cache."""
        cfg = self.cfg
        emb = Embedding(cfg.vocab, cfg.d_model, dtype=cfg.param_dtype)
        x = emb.encode(params["embed"], tokens)
        positions = pos[:, None].astype(jnp.int32)
        x, new_cache = self._decode_stack(
            params, x, positions, None, None, cache
        )
        x = RMSNorm(cfg.d_model)(params["dec_norm"], x)
        logits = emb.decode(params["embed"], x)
        return logits[:, -1], new_cache
