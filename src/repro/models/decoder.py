"""HybridDecoderLM — the decoder-only backbone for the LM-family archs.

One model class covers: dense transformers (qwen3, deepseek, internlm2),
local:global interleave (gemma3), MoE (arctic — parallel dense residual;
qwen3-moe), prefix-LM VLM decoding (paligemma), Mamba+attention hybrids with
alternating MoE (jamba), and attention-free RWKV-6.

Layer structure is declared as repeated **layer groups** (configs/base.py):
params for a group are stacked on a leading ``repeat`` axis and executed via
``lax.scan`` (HLO size O(1) in depth — required to keep 94-layer dry-run
compiles tractable), with remat per scan body. Heterogeneous patterns
(gemma3's 5:1, jamba's 1:7+MoE-every-2) scan over the *pattern period*
with the distinct layers unrolled inside the body.

Caches mirror the group structure: a list (one entry per group) of dicts
keyed ``l{i}`` with a leading repeat axis, scanned as xs/ys alongside params.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import LayerGroup, LayerSpec, ModelConfig
from repro.nn.attention import Attention, init_kv_cache
from repro.nn.ffn import SwiGLU
from repro.nn.layers import Embedding, RMSNorm
from repro.nn.moe import MoE
from repro.nn.module import ParamSpec
from repro.nn.rwkv import RWKV6ChannelMix, RWKV6TimeMix, init_rwkv_cache
from repro.nn.ssm import Mamba, init_mamba_cache

__all__ = ["HybridDecoderLM", "local_attn_cache_len"]


def local_attn_cache_len(cfg: ModelConfig, cache_len: int) -> int:
    """Ring length an ``attn_local`` layer's KV cache is allocated with.

    Single source of truth shared by cache allocation (``_layer_cache``)
    and the serve engine's prefix-cache guard (a ring shorter than
    ``cache_len`` overwrites donor rows past the window, so prefix reuse
    must refuse those configs)."""
    w = cfg.sliding_window or cache_len
    return min(w, cache_len)


@dataclasses.dataclass(frozen=True)
class HybridDecoderLM:
    cfg: ModelConfig

    # ------------------------------------------------------------------
    # layer construction
    # ------------------------------------------------------------------
    def _mixer(self, spec: LayerSpec, stack):
        cfg = self.cfg
        if spec.mixer == "attn":
            return Attention(cfg, local=False, stack=stack,
                             prefix_len=cfg.n_img_tokens)
        if spec.mixer == "attn_local":
            return Attention(cfg, local=True, stack=stack,
                             prefix_len=cfg.n_img_tokens)
        if spec.mixer == "mamba":
            return Mamba(cfg, stack=stack)
        if spec.mixer == "rwkv":
            return RWKV6TimeMix(cfg, stack=stack)
        raise ValueError(spec.mixer)

    def _ffn(self, spec: LayerSpec, stack):
        cfg = self.cfg
        out = {}
        if spec.mixer == "rwkv":
            out["dense"] = RWKV6ChannelMix(cfg, stack=stack)
            return out
        if spec.ffn in ("dense", "dense+moe"):
            out["dense"] = SwiGLU(d_model=cfg.d_model, d_ff=cfg.d_ff,
                                  swm=cfg.swm, stack=stack,
                                  dtype=cfg.param_dtype)
        if spec.ffn in ("moe", "dense+moe"):
            out["moe"] = MoE(d_model=cfg.d_model,
                             d_ff=cfg.d_ff_expert or cfg.d_ff,
                             n_experts=cfg.n_experts,
                             top_k=cfg.n_experts_per_token,
                             capacity_factor=cfg.capacity_factor,
                             swm=cfg.swm, stack=stack, dtype=cfg.param_dtype)
        return out

    def _layer_specs(self, spec: LayerSpec, stack):
        cfg = self.cfg
        s: Dict[str, Any] = {
            "ln1": RMSNorm(cfg.d_model, stack=stack).specs(),
            "mixer": self._mixer(spec, stack).specs(),
            "ln2": RMSNorm(cfg.d_model, stack=stack).specs(),
        }
        for name, mod in self._ffn(spec, stack).items():
            s[f"ffn_{name}"] = mod.specs()
        return s

    def specs(self):
        cfg = self.cfg
        s: Dict[str, Any] = {
            "embed": Embedding(cfg.vocab, cfg.d_model,
                               dtype=cfg.param_dtype).specs(),
            "final_norm": RMSNorm(cfg.d_model).specs(),
        }
        if not cfg.tie_embeddings:
            s["lm_head"] = {
                "w": ParamSpec((cfg.d_model, cfg.vocab),
                               jnp.dtype(cfg.param_dtype),
                               ("embed", "vocab"), init="normal",
                               scale=cfg.d_model**-0.5)
            }
        for gi, group in enumerate(cfg.layer_groups()):
            stack = (group.repeat,) if group.repeat > 1 else ()
            s[f"group{gi}"] = {
                f"l{li}": self._layer_specs(lspec, stack)
                for li, lspec in enumerate(group.layers)
            }
        return s

    # ------------------------------------------------------------------
    # caches
    # ------------------------------------------------------------------
    def init_cache(self, batch: int, cache_len: int) -> List[dict]:
        """One dict per group: {l{i}: percache (repeat-stacked)}."""
        cfg = self.cfg
        caches = []
        for group in cfg.layer_groups():
            g = {}
            for li, lspec in enumerate(group.layers):
                c = self._layer_cache(lspec, batch, cache_len)
                if group.repeat > 1:
                    c = jax.tree.map(
                        lambda a: jnp.broadcast_to(
                            a, (group.repeat,) + a.shape
                        ).copy(),
                        c,
                    )
                g[f"l{li}"] = c
            caches.append(g)
        return caches

    def _layer_cache(self, lspec: LayerSpec, batch, cache_len):
        cfg = self.cfg
        if lspec.mixer == "attn":
            return init_kv_cache(batch, cache_len, cfg.n_kv_heads,
                                 cfg.head_dim, cfg.dtype)
        if lspec.mixer == "attn_local":
            return init_kv_cache(batch, local_attn_cache_len(cfg, cache_len),
                                 cfg.n_kv_heads, cfg.head_dim, cfg.dtype)
        if lspec.mixer == "mamba":
            m = Mamba(cfg)
            return init_mamba_cache(batch, m.d_inner, cfg.mamba_d_state,
                                    cfg.mamba_d_conv, cfg.dtype)
        if lspec.mixer == "rwkv":
            return init_rwkv_cache(batch, cfg.d_model,
                                   cfg.d_model // cfg.rwkv_head_dim,
                                   cfg.rwkv_head_dim, cfg.dtype)
        raise ValueError(lspec.mixer)

    # ------------------------------------------------------------------
    # one layer
    # ------------------------------------------------------------------
    def _apply_layer(self, lspec: LayerSpec, stack, p, x, positions, cache,
                     mask=None, moe_no_drop=False):
        cfg = self.cfg
        ln1 = RMSNorm(cfg.d_model, stack=stack)
        ln2 = RMSNorm(cfg.d_model, stack=stack)
        aux = jnp.zeros((), jnp.float32)

        h = ln1(p["ln1"], x)
        mixer = self._mixer(lspec, stack)
        if lspec.mixer in ("attn", "attn_local"):
            # attention masks pads through negative positions already; the
            # validity mask is only threaded to the recurrent mixers so
            # attention-family jaxprs are unchanged
            mo, new_cache = mixer(p["mixer"], h, positions, cache=cache)
        elif mask is not None:
            mo, new_cache = mixer(p["mixer"], h, cache=cache, mask=mask)
        else:
            mo, new_cache = mixer(p["mixer"], h, cache=cache)
        x = x + mo

        h = ln2(p["ln2"], x)
        ffns = self._ffn(lspec, stack)
        out = jnp.zeros_like(x)
        ffn_cache = None
        if "dense" in ffns:
            if lspec.mixer == "rwkv":
                if mask is not None:
                    fo, ffn_cache = ffns["dense"](p["ffn_dense"], h,
                                                  cache=cache, mask=mask)
                else:
                    fo, ffn_cache = ffns["dense"](p["ffn_dense"], h,
                                                  cache=cache)
            else:
                fo = ffns["dense"](p["ffn_dense"], h)
            out = out + fo
        if "moe" in ffns:
            fo, a = ffns["moe"](p["ffn_moe"], h, no_drop=moe_no_drop)
            out = out + fo
            aux = aux + a
        x = x + out
        if ffn_cache is not None and new_cache is not None:
            new_cache = {**new_cache, **ffn_cache}
        return x, new_cache, aux

    # ------------------------------------------------------------------
    # group execution (scan over repeats)
    # ------------------------------------------------------------------
    def _apply_group(self, gi, group: LayerGroup, params_g, x, positions,
                     cache_g, mask=None, moe_no_drop=False):
        cfg = self.cfg
        stack = (group.repeat,) if group.repeat > 1 else ()
        use_cache = cache_g is not None

        # Remat at LAYER granularity: a multi-layer group body (gemma3's
        # 6-layer 5:1 pattern, jamba's 8-layer period) must not require all
        # of its layers' intermediates live at once in the backward pass —
        # measured 310 GB/dev on gemma3 train_4k with body-level remat only.
        # ``mask`` rides as a traced arg (None is an empty pytree);
        # ``moe_no_drop`` is a static Python bool closed over, never traced.
        def one_layer(lspec, p_li, x, positions, mask, c):
            return self._apply_layer(lspec, (), p_li, x, positions, c,
                                     mask=mask, moe_no_drop=moe_no_drop)

        layer_fn = (jax.checkpoint(one_layer, static_argnums=(0,))
                    if cfg.remat != "none" else one_layer)

        def body(carry, xs):
            x, aux = carry
            p_slice, c_slice = xs
            new_c = {}
            for li, lspec in enumerate(group.layers):
                c = c_slice[f"l{li}"] if use_cache else None
                x, nc, a = layer_fn(
                    lspec, p_slice[f"l{li}"], x, positions, mask, c
                )
                if use_cache:
                    new_c[f"l{li}"] = nc
                aux = aux + a
            return (x, aux), (new_c if use_cache else None)

        # layer_fn already remats each layer; the scan saves only the
        # inter-layer residual stream per step (checkpointing the body as
        # well would triple forward work for no memory win).
        aux0 = jnp.zeros((), jnp.float32)
        if group.repeat == 1:
            (x, aux), new_cache = body(
                (x, aux0), (params_g, cache_g if use_cache else None)
            )
            return x, new_cache, aux

        (x, aux), new_cache = jax.lax.scan(
            body, (x, aux0),
            (params_g, cache_g if use_cache else None),
        )
        return x, new_cache, aux

    # ------------------------------------------------------------------
    # public entry points
    # ------------------------------------------------------------------
    def forward(
        self,
        params,
        tokens: jax.Array,                        # (B, S)
        *,
        positions: Optional[jax.Array] = None,
        img_embeds: Optional[jax.Array] = None,   # VLM prefix (B, P, D)
        cache: Optional[List[dict]] = None,
        logits_mode: str = "all",                 # all | last | none
        moe_no_drop: bool = False,
    ):
        """Training / prefill forward. Returns (logits, new_cache, aux).

        ``logits_mode='none'`` returns the final *hidden* states instead of
        logits (training computes the loss chunked over the vocab);
        ``'last'`` projects only the final position (prefill) — the full
        (B, S, V) tensor is never materialized for large-vocab configs.

        When ``positions`` is given and the config has recurrent mixers
        (mamba/rwkv), a validity mask ``positions >= 0`` is threaded to
        them: the serve engine's left-pad lanes carry negative positions,
        and the mask makes them contribute exactly nothing to recurrent
        state (attention already masks pads via negative positions, so
        attention-family traces are unchanged). ``moe_no_drop=True`` is the
        serving MoE dispatch (see :class:`repro.nn.moe.MoE`).
        """
        cfg = self.cfg
        emb = Embedding(cfg.vocab, cfg.d_model, dtype=cfg.param_dtype)
        x = emb.encode(params["embed"], tokens)
        if img_embeds is not None:
            x = jnp.concatenate([img_embeds.astype(x.dtype), x], axis=1)
        from repro.dist.sharding import constrain_batch_leading
        x = constrain_batch_leading(x)
        B, S, _ = x.shape
        mask = None
        if positions is not None and self._has_recurrent():
            mask = positions >= 0
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

        aux = jnp.zeros((), jnp.float32)
        new_caches = []
        for gi, group in enumerate(cfg.layer_groups()):
            cg = cache[gi] if cache is not None else None
            x, nc, a = self._apply_group(
                gi, group, params[f"group{gi}"], x, positions, cg,
                mask=mask, moe_no_drop=moe_no_drop,
            )
            new_caches.append(nc)
            aux = aux + a

        x = RMSNorm(cfg.d_model)(params["final_norm"], x)
        if logits_mode == "none":
            out = x
        elif logits_mode == "last":
            out = self._logits(params, x[:, -1:])
        else:
            out = self._logits(params, x)
        return out, (new_caches if cache is not None else None), aux

    def forward_hidden(self, params, tokens, *, img_embeds=None):
        """Final hidden states for chunked-loss training."""
        h, _, aux = self.forward(
            params, tokens, img_embeds=img_embeds, logits_mode="none"
        )
        return h, aux

    def output_table(self, params) -> jax.Array:
        """(V, D) matrix used by the chunked CE (tied or untied head)."""
        if self.cfg.tie_embeddings:
            return params["embed"]["table"]
        return params["lm_head"]["w"].T

    def _logits(self, params, x):
        cfg = self.cfg
        emb = Embedding(cfg.vocab, cfg.d_model, dtype=cfg.param_dtype)
        if cfg.tie_embeddings:
            return emb.decode(params["embed"], x)
        return jnp.einsum(
            "...d,dv->...v", x.astype(jnp.float32),
            params["lm_head"]["w"].astype(jnp.float32),
        )

    def _has_recurrent(self) -> bool:
        """True when any layer carries recurrent (mamba/rwkv) state."""
        return any(l.mixer in ("mamba", "rwkv")
                   for g in self.cfg.layer_groups() for l in g.layers)

    def decode_step(
        self,
        params,
        tokens: jax.Array,       # (B, 1)
        cache: List[dict],
        pos: jax.Array,          # (B,) current absolute position
        moe_no_drop: bool = False,
    ):
        """One-token decode against the cache. Returns (logits, cache)."""
        positions = pos[:, None].astype(jnp.int32)
        logits, new_cache, _ = self.forward(
            params, tokens, positions=positions, cache=cache,
            moe_no_drop=moe_no_drop,
        )
        return logits[:, -1], new_cache

    def prefill(self, params, tokens, cache, img_embeds=None):
        logits, new_cache, aux = self.forward(
            params, tokens, cache=cache, img_embeds=img_embeds,
            logits_mode="last",
        )
        return logits[:, -1], new_cache
