"""The paper's own evaluation models (§6, Tables 1–2).

  * MNIST MLP-1/2 — small multi-layer perceptrons (92.9% / 95.6% rows)
  * ASIC net      — the exact 512-512-512-64-10 network of Table 2, with
                    64-point FFT blocks (k=64) on all but the output layer
                    (the paper keeps the 64×10 output dense)
  * LeNet-like CNN— the 99.0% MNIST row (CONV layers block-circulant per
                    CirCNN)
  * SWM-LSTM ASR  — Google-LSTM (2×1024 cells, 512 proj) on TIMIT-like
                    features; FFT8 / FFT16 variants (Table 1 LSTM rows)
"""

from __future__ import annotations

import dataclasses
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import SWMConfig
from repro.core.conv import CirculantConv2D
from repro.core.lstm import SWMLSTM
from repro.core.quant import fixed_point
from repro.nn.linear import Linear
from repro.nn.module import ParamSpec

__all__ = ["SWMMLP", "ASICNet", "SWMCNN", "SWMLSTMASR"]


@dataclasses.dataclass(frozen=True)
class SWMMLP:
    """MLP with block-circulant hidden layers; dense output layer."""

    dims: Tuple[int, ...] = (784, 512, 512, 10)
    block_size: int = 64
    quant_bits: int = 0          # 0 = off; 12 reproduces the paper's DCNN rows
    impl: str = "freq"

    def _swm(self):
        return SWMConfig(block_size=self.block_size, impl=self.impl,
                         targets=("ffn",))

    def _layers(self):
        out = []
        for i in range(len(self.dims) - 1):
            last = i == len(self.dims) - 2
            out.append(Linear(
                in_dim=self.dims[i], out_dim=self.dims[i + 1],
                in_axis=None, out_axis=None,
                family="head" if last else "ffn",      # output stays dense
                swm=self._swm(), dtype="float32",
            ))
        return out

    def specs(self):
        s = {}
        for i, lin in enumerate(self._layers()):
            s[f"fc{i}"] = lin.specs()
            s[f"b{i}"] = ParamSpec((self.dims[i + 1],), jnp.float32, (None,),
                                   init="zeros")
        return s

    def __call__(self, params, x: jax.Array) -> jax.Array:
        layers = self._layers()
        for i, lin in enumerate(layers):
            w = params[f"fc{i}"]
            if self.quant_bits:
                w = jax.tree.map(
                    lambda a: fixed_point(a, self.quant_bits, self.quant_bits - 4), w
                )
                x = fixed_point(x, self.quant_bits, self.quant_bits - 4)
            x = lin(w, x) + params[f"b{i}"]
            if i < len(layers) - 1:
                x = jax.nn.relu(x)
        return x

    @property
    def n_params_dense(self) -> int:
        return sum(self.dims[i] * self.dims[i + 1] for i in range(len(self.dims) - 1))

    @property
    def n_params(self) -> int:
        return sum(l.n_params for l in self._layers())


def ASICNet(block_size: int = 64, quant_bits: int = 12) -> SWMMLP:
    """Table 2's exact network: 512-512-512-64-10, 64-point FFT blocks."""
    return SWMMLP(dims=(512, 512, 512, 64, 10), block_size=block_size,
                  quant_bits=quant_bits)


@dataclasses.dataclass(frozen=True)
class SWMCNN:
    """LeNet-like CNN with block-circulant CONV + FC (99.0% MNIST row)."""

    in_hw: int = 28
    channels: Tuple[int, ...] = (1, 32, 64)
    fc_dims: Tuple[int, ...] = (1024, 128, 10)
    conv_block: int = 8
    fc_block: int = 64
    quant_bits: int = 0

    def _convs(self):
        return [
            CirculantConv2D(in_ch=self.channels[i], out_ch=self.channels[i + 1],
                            ksize=5, block_size=self.conv_block)
            for i in range(len(self.channels) - 1)
        ]

    def _fcs(self):
        swm = SWMConfig(block_size=self.fc_block, targets=("ffn",))
        out = []
        for i in range(len(self.fc_dims) - 1):
            last = i == len(self.fc_dims) - 2
            out.append(Linear(
                in_dim=self.fc_dims[i], out_dim=self.fc_dims[i + 1],
                in_axis=None, out_axis=None,
                family="head" if last else "ffn", swm=swm, dtype="float32",
            ))
        return out

    def specs(self):
        s = {}
        for i, c in enumerate(self._convs()):
            s[f"conv{i}"] = c.specs()
        for i, l in enumerate(self._fcs()):
            s[f"fc{i}"] = l.specs()
            s[f"fb{i}"] = ParamSpec((self.fc_dims[i + 1],), jnp.float32,
                                    (None,), init="zeros")
        return s

    def __call__(self, params, x: jax.Array) -> jax.Array:
        """x (B, H, W, 1) -> logits (B, 10)."""
        for i, conv in enumerate(self._convs()):
            x = jax.nn.relu(conv(params[f"conv{i}"], x))
            # 2×2 max-pool (paper: POOL is O(n), max-pooling dominant type)
            B, H, W, C = x.shape
            x = x[:, : H // 2 * 2, : W // 2 * 2, :]
            x = x.reshape(B, H // 2, 2, W // 2, 2, C).max(axis=(2, 4))
        x = x.reshape(x.shape[0], -1)
        fcs = self._fcs()
        # project flattened features to fc_dims[0] expectations
        assert x.shape[-1] == self.fc_dims[0], (x.shape, self.fc_dims)
        for i, lin in enumerate(fcs):
            x = lin(params[f"fc{i}"], x) + params[f"fb{i}"]
            if i < len(fcs) - 1:
                x = jax.nn.relu(x)
        return x


@dataclasses.dataclass(frozen=True)
class SWMLSTMASR:
    """Stacked Google-LSTM for TIMIT-like ASR (Table 1 LSTM rows).

    ESE-matched geometry: input 153 features (fbank+deltas context window),
    2 layers × 1024 cells, 512 projection, 39-phone output.
    """

    d_in: int = 153
    d_cell: int = 1024
    d_proj: int = 512
    n_layers: int = 2
    n_phones: int = 39
    block_size: int = 16          # FFT16 → "LSTM1"; 8 → "LSTM2"

    def _swm(self):
        return SWMConfig(block_size=self.block_size, targets=("lstm",))

    @property
    def d_in_padded(self) -> int:
        """ESE's 153 fbank features zero-padded to a block multiple so the
        input gate matrices are circulant too (deployments pad; gcd(153,
        1024)=1 would otherwise force layer-0 W·x dense)."""
        k = max(1, self.block_size)
        return ((self.d_in + k - 1) // k) * k

    def _cells(self):
        cells = []
        for i in range(self.n_layers):
            cells.append(SWMLSTM(
                d_in=self.d_in_padded if i == 0 else self.d_proj,
                d_cell=self.d_cell, d_proj=self.d_proj, swm=self._swm(),
            ))
        return cells

    def specs(self):
        s = {}
        for i, c in enumerate(self._cells()):
            s[f"lstm{i}"] = c.specs()
        s["out"] = Linear(in_dim=self.d_proj, out_dim=self.n_phones,
                          in_axis=None, out_axis=None, family="head",
                          swm=self._swm(), dtype="float32").specs()
        s["out_b"] = ParamSpec((self.n_phones,), jnp.float32, (None,),
                               init="zeros")
        return s

    def __call__(self, params, xs: jax.Array) -> jax.Array:
        """xs (B, T, d_in) -> per-frame phone logits (B, T, n_phones)."""
        pad = self.d_in_padded - self.d_in
        h = jnp.pad(xs, ((0, 0), (0, 0), (0, pad))) if pad else xs
        for i, cell in enumerate(self._cells()):
            h, _ = cell(params[f"lstm{i}"], h)
        out = Linear(in_dim=self.d_proj, out_dim=self.n_phones,
                     in_axis=None, out_axis=None, family="head",
                     swm=self._swm(), dtype="float32")(params["out"], h)
        return out + params["out_b"]
