"""Roofline analysis over dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads experiments/dryrun/*.json (produced by launch.dryrun) and derives the
three roofline terms per (arch × shape × impl) cell on the single-pod mesh:

    compute    = FLOPs_per_chip / 197 TFLOP/s          (bf16 MXU peak)
    memory     = bytes_per_chip / 819 GB/s             (HBM)
    collective = collective_bytes_per_chip / 50 GB/s   (ICI per-link)

cost_analysis runs on the post-SPMD per-device module, so its numbers are
already per-chip; collective bytes are summed from the per-device HLO the
same way. MODEL_FLOPS uses the assignment's definition — 6·N·D (train) /
2·N·D (prefill/decode) with N = *active stored* params — so the
MODEL_FLOPS / HLO_FLOPs ratio surfaces remat recompute, transform overhead
(the SWM FFT/DFT work), and capacity-padding waste.

Usage:
    python -m repro.launch.roofline [--dir experiments/dryrun] [--md out.md]
"""

from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List

PEAK = 197e12      # bf16 FLOP/s per chip
HBM = 819e9        # B/s per chip
ICI = 50e9         # B/s per link

HINTS = {
    "compute": ("cut transform overhead: fuse wi/wu forward DFTs, larger "
                "block k, Karatsuba complex product, Pallas fused kernel"),
    "memory": ("cut HBM traffic: fuse freq-domain ops, bf16 intermediates, "
               "larger flash chunks, keep frozen FFT(w) resident"),
    "collective": ("reshard: move the dominant all-gather/all-reduce to a "
                   "smaller axis, overlap with compute, int8 gradient "
                   "compression for the DP all-reduce"),
}


def load(dir_: str) -> List[dict]:
    rows = []
    for p in sorted(glob.glob(os.path.join(dir_, "*.json"))):
        with open(p) as f:
            r = json.load(f)
        r["_file"] = os.path.basename(p)
        rows.append(r)
    return rows


_PCACHE: Dict[str, dict] = {}


def _params_info(arch: str) -> dict:
    """flops_n / embed breakdown (recomputed live — older artifacts lack it)."""
    if arch not in _PCACHE:
        from repro.configs.registry import get_config
        from repro.launch.specs import count_params
        _PCACHE[arch] = count_params(get_config(arch))
    return _PCACHE[arch]


def _analytic(r: dict) -> dict:
    """Prefer recorded analytic terms; recompute live for older artifacts
    (pure math — no compilation)."""
    if "analytic" in r:
        return r["analytic"]
    import dataclasses as dc
    from repro.configs.base import SHAPES
    from repro.configs.registry import get_config
    from repro.launch.analytic import cell_model
    cfg = get_config(r["arch"])
    impl = r.get("impl")
    if impl and impl != "dense":
        cfg = dc.replace(cfg, swm=dc.replace(cfg.swm, impl=impl))
    elif impl == "dense":
        cfg = dc.replace(cfg, swm=dc.replace(cfg.swm, block_size=0))
    return cell_model(cfg, SHAPES[r["shape"]], chips=r.get("devices", 256))


def analyse(r: dict) -> dict:
    if "error" in r or "flops" not in r:
        return {**r, "status": "FAIL" if "error" in r else "PARTIAL"}
    a = _analytic(r)
    # primary terms: the structural model (XLA cost_analysis counts while
    # bodies once — see launch/analytic.py docstring); artifact terms kept
    # as secondary columns.
    t_c = a["a_flops_per_chip"] / PEAK
    t_m = a["a_bytes_per_chip"] / HBM
    t_x = a["a_coll_per_chip"] / ICI
    coll_w = r.get("collective_bytes_weighted")
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    dominant = max(terms, key=terms.get)
    # artifact (secondary)
    flops = r["flops"]
    h_c = flops / PEAK
    h_m = r.get("bytes_accessed", 0.0) / HBM
    h_x = sum(r.get("collective_bytes", {}).values()) / ICI
    # MODEL_FLOPS (global): 6·N·D train, 2·N·D serve; N excludes embedding
    # gathers but includes the vocab head (launch.specs.count_params).
    pinfo = r.get("params") or {}
    if "flops_n" not in pinfo:
        try:
            pinfo = _params_info(r["arch"])
        except (KeyError, ImportError, AttributeError):
            # unknown arch in an old artifact, or a registry module that
            # moved since the dryrun was recorded — report zero MODEL_FLOPS
            # rather than refusing to summarize the rest of the cell
            pinfo = {"flops_n": 0, "stored": 0}
    from repro.configs.base import SHAPES
    shape = SHAPES[r["shape"]]
    kind = r.get("kind", shape.kind)
    tokens = r.get("tokens") or (
        shape.global_batch * shape.seq_len if kind != "decode"
        else shape.global_batch)
    body_n = pinfo.get("body_n", pinfo.get("flops_n", 0))
    head_n = pinfo.get("head_n", 0)
    head_tokens = tokens if kind == "train" else shape.global_batch
    mult = 6 if kind == "train" else 2
    model_flops = mult * (body_n * tokens + head_n * head_tokens)
    chips = r.get("devices", 256)
    ratio = model_flops / max(a["a_flops"], 1.0)
    # Ideal time = the unavoidable cost under EITHER resource: MODEL_FLOPS
    # at MXU peak, or the minimal byte stream (weights once per TP shard +
    # KV once) at full HBM bandwidth.
    ideal_c = model_flops / (chips * PEAK)
    ideal_m = a.get("a_min_bytes_per_chip", 0) / HBM
    ideal = max(ideal_c, ideal_m)
    frac = ideal / max(max(terms.values()), 1e-30)
    return {
        **r,
        "status": "OK",
        "t_compute_s": t_c, "t_memory_s": t_m, "t_collective_s": t_x,
        "hlo_t_compute_s": h_c, "hlo_t_memory_s": h_m,
        "hlo_t_collective_s": h_x,
        "hlo_w_collective_s": (sum(coll_w.values()) / ICI) if coll_w else None,
        "dominant": dominant,
        "model_flops": model_flops,
        "ideal_s": ideal,
        "useful_ratio": ratio,
        "roofline_fraction": frac,
        "hint": HINTS[dominant],
    }


def fmt_md(rows: List[dict], mesh: str = "single") -> str:
    out = ["| arch | shape | impl | compute s | memory s | collective s |"
           " dominant | MODEL_FLOPS | useful | roofline frac |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r.get("mesh") != mesh:
            continue
        if r.get("status") != "OK":
            out.append(f"| {r.get('arch')} | {r.get('shape')} | "
                       f"{r.get('impl','?')} | — | — | — | "
                       f"{r.get('status')}: {r.get('error','')[:60]} | | |")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['impl']} "
            f"| {r['t_compute_s']:.3e} | {r['t_memory_s']:.3e} "
            f"| {r['t_collective_s']:.3e} | **{r['dominant']}** "
            f"| {r['model_flops']:.2e} | {r['useful_ratio']:.3f} "
            f"| {r['roofline_fraction']:.3f} |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--json-out", default="experiments/roofline.json")
    ap.add_argument("--mesh", default="single")
    args = ap.parse_args()
    rows = [analyse(r) for r in load(args.dir)]
    with open(args.json_out, "w") as f:
        json.dump(rows, f, indent=1, default=str)
    print(fmt_md(rows, args.mesh))


if __name__ == "__main__":
    main()
