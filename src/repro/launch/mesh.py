"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state — the dry-run sets
XLA_FLAGS before any jax initialization and only then builds meshes.
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """Single-pod (data=16, model=16)=256 chips; multi-pod adds pod=2."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh():
    """Whatever devices exist, as a 1-D data mesh (CPU tests, examples)."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))
