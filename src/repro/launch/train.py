"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b \
        --shape train_4k --steps 100 --mesh single          # on a pod
    PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b \
        --smoke --steps 50 --mesh local                     # on this host

Builds the mesh, sharded train state, host-sharded data pipeline, and runs
under the fault-tolerant TrainDriver (auto-restart from checkpoints,
straggler watchdog). The same script is what a multi-host deployment runs
per process — jax.distributed.initialize() is called when the usual TPU
environment variables are present.
"""

from __future__ import annotations

import argparse
import dataclasses
import os

import jax
import numpy as np

from repro.configs.base import SHAPES, TrainConfig
from repro.configs.registry import get_config, get_smoke
from repro.data.pipeline import SyntheticLM, host_sharded_batch
from repro.dist.sharding import param_shardings, opt_shardings
from repro.ft.driver import TrainDriver
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.launch.specs import build_model, state_specs
from repro.nn.module import init_params
from repro.train.loop import init_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k", choices=list(SHAPES))
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config + small synthetic shapes (CPU)")
    ap.add_argument("--mesh", default="local",
                    choices=["local", "single", "multi"])
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=0)
    ap.add_argument("--batch", type=int, default=0)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    if "JAX_COORDINATOR" in os.environ:          # multi-host pod entry
        jax.distributed.initialize()

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    shape = SHAPES[args.shape]
    seq = args.seq or (64 if args.smoke else shape.seq_len)
    batch = args.batch or (8 if args.smoke else shape.global_batch)
    tcfg = TrainConfig(learning_rate=args.lr, total_steps=args.steps,
                       microbatch=args.microbatch,
                       checkpoint_every=args.ckpt_every,
                       checkpoint_dir=args.ckpt_dir,
                       z_loss=0.0 if args.smoke else 1e-4)

    mesh = (make_local_mesh() if args.mesh == "local"
            else make_production_mesh(multi_pod=args.mesh == "multi"))
    model = build_model(cfg)
    from repro.dist.sharding import set_ambient_mesh
    set_ambient_mesh(mesh)
    _, shardings = state_specs(cfg, tcfg, mesh)

    with mesh:
        params = init_params(model.specs(), tcfg.seed)
        state = init_train_state(params, tcfg)
        state = jax.device_put(state, shardings)
        step_fn = jax.jit(make_train_step(model, cfg, tcfg, mesh=mesh),
                          in_shardings=(shardings, None),
                          donate_argnums=(0,))
        data = SyntheticLM(vocab=cfg.vocab, seq_len=seq, batch=batch,
                           seed=tcfg.seed)

        def data_fn(step: int):
            return host_sharded_batch(mesh, data.batch_np(step))

        driver = TrainDriver(step_fn, tcfg, data_fn,
                             state_shardings=shardings, mesh=mesh)
        state = driver.run(state, n_steps=args.steps)

    for m in driver.metrics_log[-5:]:
        print(f"step {m['step']:5d} loss {m['loss']:.4f} ({m['dt']*1e3:.0f} ms)")
    print(f"restarts={driver.restarts} straggler_events={len(driver.watchdog.events)}")


if __name__ == "__main__":
    main()
