"""Serving launcher: loads (or inits) params and serves batched requests.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --smoke \
        --batch 4 --cache-len 64
"""

from __future__ import annotations

import argparse

import numpy as np
import jax

from repro.configs.registry import get_config, get_smoke
from repro.ft.checkpoint import latest_step, restore_checkpoint
from repro.launch.specs import build_model
from repro.nn.module import init_params
from repro.serve.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--n-requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg)
    if args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
        step = latest_step(args.ckpt_dir)
        state = restore_checkpoint(args.ckpt_dir, step)
        params = state["params"]
        print(f"restored checkpoint step {step}")
    else:
        params = init_params(model.specs(), 0)
        print("serving freshly initialized params (demo mode)")

    engine = ServeEngine(model, cfg, params, batch=args.batch,
                         cache_len=args.cache_len)
    rng = np.random.default_rng(0)
    reqs = [Request(rng.integers(0, cfg.vocab, size=rng.integers(3, 9)).astype(np.int32),
                    max_new=args.max_new)
            for _ in range(args.n_requests)]
    outs = engine.generate(reqs)
    for i, o in enumerate(outs):
        print(f"request {i}: {o}")


if __name__ == "__main__":
    main()
