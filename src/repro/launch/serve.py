"""Serving launcher: loads (or inits) params and serves batched requests
through the continuous-batching engine (or the wave baseline).

    PYTHONPATH=src python -m repro.launch.serve --model qwen3-0.6b --smoke \
        --batch 4 --cache-len 64 --prompt-buckets 8,16,32 \
        --decode-buckets 1,2,4 --policy sjf

``--model`` (alias ``--arch``) picks any registry entry — attention
decoders, rwkv6/mamba/jamba hybrids, MoE, and enc-dec configs all serve
through the continuous engine's ModelRunner protocol (underscores in the
name normalize to hyphens, so ``--model rwkv6_7b`` works). Enc-dec
configs synthesize random encoder frames per request (the frontend is a
stub; see ``repro.models.encdec``).

The engine rounds prefill launches to (batch-bucket, prompt-bucket) shapes,
compacts decode launches to the smallest decode bucket holding the active
slots (bounded jit recompilation on both paths), and freezes the circulant
frequency weights once at load — see repro.serve.engine for the serving
model. ``--stream`` demos the open-ended submit()/step()/poll()/drain()
API instead of the closed generate() call.
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from repro.configs.registry import ARCHS, get_config, get_smoke
from repro.ft.checkpoint import latest_step, restore_checkpoint
from repro.launch.specs import build_model
from repro.nn.module import init_params
from repro.serve.engine import (Request, SamplingParams, Scheduler,
                                ServeEngine, WaveEngine)
from repro.serve.frontend import (SLO_CLASSES, AsyncFrontend, TenantConfig,
                                  TenantRejectedError)
from repro.serve.guard import QueueFullError
from repro.serve.runner import recurrent_mixer_names


def _parse_buckets(ap: argparse.ArgumentParser, text: str, flag: str):
    """Comma-separated bucket list -> tuple of ints, malformed input (empty
    fields from trailing commas, non-integers) routed through ap.error with
    the offending string instead of a raw ValueError traceback."""
    if not text:
        return None
    try:
        return tuple(int(tok) for tok in text.split(","))
    except ValueError:
        ap.error(f"{flag} must be comma-separated ints, got {text!r}")


def _parse_pos_int(ap: argparse.ArgumentParser, text: str, flag: str,
                   default: int) -> int:
    """Positive-int flag value; malformed or non-positive input routed
    through ap.error (same contract as the bucket flags)."""
    if not text:
        return default
    try:
        v = int(text)
    except ValueError:
        ap.error(f"{flag} must be a positive int, got {text!r}")
    if v < 1:
        ap.error(f"{flag} must be a positive int, got {text!r}")
    return v


def _parse_pos_float(ap: argparse.ArgumentParser, text: str, flag: str):
    """Positive-float flag value (or None when unset); malformed or
    non-positive input routed through ap.error."""
    if not text:
        return None
    try:
        v = float(text)
    except ValueError:
        ap.error(f"{flag} must be a positive number, got {text!r}")
    if v <= 0:
        ap.error(f"{flag} must be a positive number, got {text!r}")
    return v


def _parse_tenants(ap: argparse.ArgumentParser, text: str,
                   default_slo: str):
    """``name[:slo],name[:slo],...`` -> {name: TenantConfig}; malformed
    entries and unknown SLO classes route through ap.error."""
    if not text:
        return {}
    out = {}
    for tok in text.split(","):
        tok = tok.strip()
        if not tok:
            ap.error(f"--tenants has an empty entry in {text!r}")
        name, _, slo = tok.partition(":")
        slo = slo or default_slo
        if slo not in SLO_CLASSES:
            ap.error(f"--tenants: unknown SLO class {slo!r} for tenant "
                     f"{name!r}; choices: {sorted(SLO_CLASSES)}")
        if name in out:
            ap.error(f"--tenants lists tenant {name!r} twice")
        out[name] = TenantConfig(name, slo=slo)
    return out


def _resolve_arch(ap: argparse.ArgumentParser, name: str) -> str:
    """Registry lookup with underscore->hyphen normalization; unknown
    names route through ap.error listing the valid choices instead of a
    raw KeyError traceback."""
    normalized = name.strip().lower().replace("_", "-")
    if normalized not in ARCHS:
        ap.error(f"unknown model {name!r}; choices: {sorted(ARCHS)}")
    return normalized


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="",
                    help="registry model name (repro.configs.registry), "
                         "e.g. rwkv6-7b / rwkv6_7b — every family serves "
                         "through the continuous engine")
    ap.add_argument("--arch", default="", help="alias for --model")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4,
                    help="cache slots (continuous) / wave size (wave)")
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--n-requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--engine", choices=("continuous", "wave"),
                    default="continuous")
    ap.add_argument("--policy", choices=Scheduler.POLICIES, default="fifo",
                    help="admission order: fifo | sjf (shortest prompt first)")
    ap.add_argument("--prompt-buckets", default="",
                    help="comma-separated prompt-length buckets, e.g. "
                         "8,16,32 (default: powers of two up to cache-len)")
    ap.add_argument("--decode-buckets", default="",
                    help="comma-separated decode batch buckets, e.g. 1,2,4 "
                         "(default: powers of two up to --batch); active "
                         "slots are compacted into the smallest bucket that "
                         "holds them before each decode launch")
    ap.add_argument("--stream", action="store_true",
                    help="demo the streaming submit()/step()/poll()/drain() "
                         "API: requests trickle in while the engine runs "
                         "(continuous engine only)")
    ap.add_argument("--prefix-cache", choices=("on", "off"), default="off",
                    help="reuse resident KV rows across requests sharing a "
                         "prompt head: admission copies the matched rows "
                         "from a donor slot and prefills only the tail "
                         "(continuous engine only)")
    ap.add_argument("--prefix-capacity", default="",
                    help="max entries in the prefix index (LRU; default "
                         "256). Forgetting an entry never frees slot rows.")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="0 = greedy")
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--stop-token", type=int, action="append", default=[],
                    help="stop generation when this token id is produced "
                         "(repeatable)")
    ap.add_argument("--prewarm", action="store_true",
                    help="compile every bucket executable before serving "
                         "(continuous engine only)")
    ap.add_argument("--deadline-ms", default="",
                    help="per-request TTL in milliseconds: a step-boundary "
                         "watchdog EXPIREs overdue requests and recycles "
                         "their slots (continuous engine only)")
    ap.add_argument("--max-queue", default="",
                    help="bound the admission queue: submissions at the "
                         "bound are load-shed per --shed-policy "
                         "(continuous engine only; default unbounded)")
    ap.add_argument("--shed-policy", choices=Scheduler.SHED_POLICIES,
                    default="reject",
                    help="at the --max-queue bound: 'reject' new work "
                         "(backpressure) or 'drop-oldest' queued request")
    ap.add_argument("--snapshot-dir", default="",
                    help="serve-state snapshot directory: the engine "
                         "checkpoints its full state (slots, queue, KV "
                         "cache) every --snapshot-every steps so a "
                         "replacement engine can resume mid-stream "
                         "(continuous engine only)")
    ap.add_argument("--snapshot-every", default="",
                    help="steps between automatic snapshots (default 8; "
                         "needs --snapshot-dir)")
    ap.add_argument("--tenants", default="",
                    help="comma-separated tenant list, each 'name' or "
                         "'name:slo' (slo in interactive|standard|batch; "
                         "default from --slo-class). Requests are assigned "
                         "round-robin; with --stream the asyncio front-end "
                         "drives per-tenant token-bucket admission "
                         "(continuous engine only)")
    ap.add_argument("--slo-class", choices=sorted(SLO_CLASSES),
                    default="standard",
                    help="default SLO class for --tenants entries without "
                         "an explicit one: sets the deadline_ms default "
                         "and the DRR fairness weight")
    ap.add_argument("--fair", action="store_true",
                    help="shortcut for --policy fair with per-tenant DRR "
                         "weights taken from each tenant's SLO class "
                         "(needs --tenants)")
    ap.add_argument("--quantize", choices=("off", "int8"), default="off",
                    help="int8: freeze the circulant frequency tables as "
                         "int8 with per-block scales (dequantized inside "
                         "the kernel); halves resident table bytes at "
                         "identical launch counts")
    args = ap.parse_args()

    if bool(args.model) == bool(args.arch):
        ap.error("pass exactly one of --model / --arch (they are aliases)")
    arch = _resolve_arch(ap, args.model or args.arch)
    cfg = get_smoke(arch) if args.smoke else get_config(arch)
    model = build_model(cfg)
    # one directory scan per load (latest_step used to run twice)
    step = latest_step(args.ckpt_dir) if args.ckpt_dir else None
    if step is not None:
        state = restore_checkpoint(args.ckpt_dir, step)
        params = state["params"]
        print(f"restored checkpoint step {step}")
    else:
        params = init_params(model.specs(), 0)
        print("serving freshly initialized params (demo mode)")

    prompt_buckets = _parse_buckets(ap, args.prompt_buckets,
                                    "--prompt-buckets")
    decode_buckets = _parse_buckets(ap, args.decode_buckets,
                                    "--decode-buckets")
    prefix_cache = args.prefix_cache == "on"
    prefix_capacity = _parse_pos_int(ap, args.prefix_capacity,
                                     "--prefix-capacity", 256)
    if args.prefix_capacity and not prefix_cache:
        ap.error("--prefix-capacity has no effect without --prefix-cache on")
    deadline_ms = _parse_pos_float(ap, args.deadline_ms, "--deadline-ms")
    max_queue = (_parse_pos_int(ap, args.max_queue, "--max-queue", 0)
                 if args.max_queue else None)
    tenants = _parse_tenants(ap, args.tenants, args.slo_class)
    if args.fair and not tenants:
        ap.error("--fair needs --tenants (the DRR weights come from each "
                 "tenant's SLO class)")
    policy = "fair" if args.fair else args.policy
    tenant_weights = None
    if args.fair:
        tenant_weights = {n: c.slo_class.weight for n, c in tenants.items()}
    snapshot_dir = args.snapshot_dir or None
    snapshot_every = _parse_pos_int(ap, args.snapshot_every,
                                    "--snapshot-every", 8)
    if args.snapshot_every and not snapshot_dir:
        ap.error("--snapshot-every has no effect without --snapshot-dir")
    if args.shed_policy != "reject" and max_queue is None:
        ap.error("--shed-policy has no effect without --max-queue")
    if args.engine == "wave":
        if args.temperature > 0 or args.top_k or args.stop_token:
            ap.error("--engine wave is a greedy-only baseline; "
                     "--temperature/--top-k/--stop-token need the "
                     "continuous engine")
        if (args.prompt_buckets or args.decode_buckets
                or args.policy != "fifo" or args.prewarm or args.stream
                or prefix_cache or args.prefix_capacity):
            ap.error("--prompt-buckets/--decode-buckets/--policy/--prewarm/"
                     "--stream/--prefix-cache/--prefix-capacity only apply "
                     "to the continuous engine")
        if (deadline_ms is not None or max_queue is not None
                or snapshot_dir or args.snapshot_every
                or args.shed_policy != "reject"):
            ap.error("--deadline-ms/--max-queue/--shed-policy/"
                     "--snapshot-dir/--snapshot-every only apply to the "
                     "continuous engine (WaveEngine has no request "
                     "lifecycle)")
        if tenants or args.fair:
            ap.error("--tenants/--fair only apply to the continuous "
                     "engine (WaveEngine has no admission queue)")
        # the wave baseline is decoder-LM only; the continuous engine's
        # runners cover the other families
        if cfg.family == "encdec":
            ap.error(f"--engine wave cannot serve enc-dec config {arch!r}: "
                     f"use the continuous engine (EncDecRunner)")
        mix = recurrent_mixer_names(cfg)
        if args.batch > 1 and mix:
            ap.error(f"--engine wave pads batched prompts and gives "
                     f"{'/'.join(mix)} layers no pad-validity guarantee: "
                     f"use the continuous engine (pad-aware "
                     f"RecurrentRunner) or --batch 1")
        engine = WaveEngine(model, cfg, params, batch=args.batch,
                            cache_len=args.cache_len,
                            quantize=args.quantize)
    else:
        try:
            engine = ServeEngine(model, cfg, params, batch=args.batch,
                                 cache_len=args.cache_len,
                                 prompt_buckets=prompt_buckets,
                                 decode_buckets=decode_buckets,
                                 policy=policy,
                                 tenant_weights=tenant_weights,
                                 prefix_cache=prefix_cache,
                                 prefix_capacity=prefix_capacity,
                                 max_queue=max_queue,
                                 shed_policy=args.shed_policy,
                                 snapshot_dir=snapshot_dir,
                                 snapshot_every=(snapshot_every
                                                 if snapshot_dir else 0),
                                 quantize=args.quantize)
        except ValueError as e:
            # misconfiguration (bad bucket lists, prefix cache against a
            # runner that cannot donate rows) is a usage error, not a crash
            if "_buckets" in str(e) or "prefix_cache" in str(e):
                ap.error(str(e))
            raise
        print(f"buckets: batch={engine.batch_buckets} "
              f"prompt={engine.prompt_buckets} "
              f"decode={engine.decode_buckets} "
              f"(<= {engine.max_prefill_variants} prefill + "
              f"{engine.max_decode_variants} decode executables)")
        if args.prewarm:
            n = engine.prewarm()
            print(f"prewarmed {n} executables")
    if args.quantize != "off":
        print(f"quantize={args.quantize}: frozen table bytes = "
              f"{engine.frozen_table_bytes()}")

    sampling = SamplingParams(temperature=args.temperature,
                              top_k=args.top_k, seed=args.seed)
    rng = np.random.default_rng(args.seed)
    # with the prefix cache on, draw prompts from a few shared heads so the
    # reuse path actually fires (head length clipped to leave decode room)
    head_len = min(args.cache_len // 4, max(0, args.cache_len
                                            - args.max_new - 8))
    heads = []
    if prefix_cache and head_len >= 8:
        heads = [rng.integers(0, cfg.vocab, size=head_len).astype(np.int32)
                 for _ in range(2)]

    def _prompt(i):
        tail = rng.integers(0, cfg.vocab,
                            size=int(rng.integers(3, 9))).astype(np.int32)
        if heads:
            return np.concatenate([heads[i % len(heads)], tail])
        return tail

    def _extra():
        # enc-dec requests carry per-request encoder frames (the speech
        # frontend is a stub, so random embeddings stand in)
        if cfg.family != "encdec":
            return None
        enc_len = cfg.enc_seq or args.cache_len
        return rng.standard_normal((enc_len, cfg.d_model)).astype(np.float32)

    tenant_names = sorted(tenants) if tenants else []
    reqs = [
        Request(
            _prompt(i),
            max_new=args.max_new,
            stop_tokens=tuple(args.stop_token),
            sampling=sampling,
            deadline_ms=deadline_ms,
            extra=_extra(),
            tenant=(tenant_names[i % len(tenant_names)]
                    if tenant_names else "default"),
        )
        for i in range(args.n_requests)
    ]
    t0 = time.perf_counter()
    if args.stream and tenants:
        # multi-tenant async mode: the asyncio front-end owns admission
        # (per-tenant token buckets, SLO deadline defaults, bounded
        # retry-with-jitter on backpressure) while run() drives the
        # engine on the same event loop
        import asyncio

        frontend = AsyncFrontend(engine, tenants)

        async def _serve():
            rids = []

            async def _feed():
                for r in reqs:
                    try:
                        rid = await frontend.submit(r.tenant, r)
                    except TenantRejectedError as e:
                        print(f"shed: {e}")
                        continue
                    rids.append(rid)
                    print(f"submitted req {rid} tenant={r.tenant} "
                          f"(prompt_len={r.prompt_len})")

            runner = asyncio.ensure_future(frontend.run(idle_rounds=2))
            await _feed()
            await runner
            while engine.step():   # submits that landed after run() idled
                pass
            # poll before drain: drain claims (forgets) the requests, and
            # an EXPIRED/FAILED terminal should print as such rather than
            # masquerade as a short finish
            for rid in rids:
                v = engine.poll(rid)
                if v.status != "FINISHED":
                    print(f"req {rid}: {v.status}"
                          + (f" ({v.error})" if v.error else ""))
            done = engine.drain(rids)
            return [done[rid] for rid in rids]

        outs = asyncio.run(_serve())
    elif args.stream:
        # open-ended serving: trickle submissions in while the engine steps,
        # poll for incremental tokens, then drain the stragglers. A submit
        # rejected at the --max-queue bound is backpressure: back off
        # proportionally to the engine's retry_after_hint (stepping while
        # the hint window elapses) instead of retrying every step.
        rids = []
        for i, r in enumerate(reqs):
            while True:
                try:
                    rid = engine.submit(r)
                    break
                except QueueFullError as e:
                    print(f"backpressure: {e}")
                    hold = time.perf_counter() + (e.retry_after_hint or 0.0)
                    engine.step()
                    while time.perf_counter() < hold and engine.step():
                        pass
            rids.append(rid)
            engine.step()
            v = engine.poll(rid)
            print(f"submitted req {rid} (prompt_len={r.prompt_len}); "
                  f"poll -> status={v.status} tokens={list(v.tokens)}")
        done = engine.drain(rids)
        outs = [done[rid] for rid in rids]
    else:
        outs = engine.generate(reqs)
    dt = time.perf_counter() - t0
    for i, o in enumerate(outs):
        print(f"request {i}: {o}")
    n_tok = sum(len(o) for o in outs)
    extra = ""
    if args.engine == "continuous":
        extra = (f" decode-shapes={sorted(engine.stats.decode_shapes)}"
                 f" decode-rows/token="
                 f"{engine.stats.decode_rows_per_token:.2f}")
        if prefix_cache:
            extra += (f" prefix-hit-rate="
                      f"{engine.stats.prefix_hit_rate:.2f}"
                      f" prefill-tokens-saved="
                      f"{engine.stats.prefill_tokens_saved}")
        s = engine.stats
        if s.rejected or s.expired or s.aborted or s.cancelled or s.snapshots:
            extra += (f" rejected={s.rejected} expired={s.expired}"
                      f" aborted={s.aborted} cancelled={s.cancelled}"
                      f" snapshots={s.snapshots}")
        if s.ttft_ms.count:
            extra += (f" ttft-p50={s.ttft_ms.p50:.3g}ms"
                      f" ttft-p99={s.ttft_ms.p99:.3g}ms")
        for t in sorted(s.tenants):
            ts = s.tenants[t]
            extra += (f"\n  tenant {t}: submitted={ts.submitted} "
                      f"completed={ts.completed} tokens={ts.tokens} "
                      f"rejected={ts.rejected}"
                      + (f" ttft-p99={ts.ttft_ms.p99:.3g}ms"
                         if ts.ttft_ms.count else ""))
    print(f"{n_tok} tokens in {dt:.2f}s ({n_tok / max(dt, 1e-9):.1f} tok/s); "
          f"prefill compiles={engine.prefill_compiles} "
          f"decode compiles={engine.decode_compiles} "
          f"tokens/decode-step={engine.stats.tokens_per_decode_step:.2f}"
          f"{extra}")


if __name__ == "__main__":
    main()
