"""Analytic roofline model: FLOPs / HBM bytes / collective bytes per cell.

Why this exists: XLA's ``cost_analysis()`` counts every while-loop body
ONCE — scan-over-layers, microbatch accumulation, CE chunking and flash
attention all lower to while loops, so compiled-artifact numbers undercount
by the product of trip counts (measured 19× on internlm2 train_4k). The
dry-run keeps the artifact numbers (assignment-prescribed; corrected by a
trip-count-weighted HLO parse), and THIS module provides the structural
ground truth the roofline table is ranked by: straight napkin math over the
known model graph — every term auditable.

Conventions (global, one step):
  * matmul FLOPs = 2·m·n·k; SWM layer FLOPs via core.circulant.swm_flops.
  * training total = 3 × forward (backward = 2×fwd), ×(4/3) when remat
    recomputes the forward (cfg.remat != 'none').
  * bytes: parameter traffic + optimizer state r/w + inter-layer activation
    traffic + attention KV traffic. Elementwise fusion is assumed (only
    layer-boundary tensors hit HBM) — an optimistic-but-standard model.
  * collectives (per chip): ring all-reduce ≈ 2·N bytes on the wire per
    chip; all-gather ≈ N·(s-1)/s ≈ N.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict

from repro.configs.base import LayerSpec, ModelConfig, ShapeConfig
from repro.core.circulant import dense_flops, swm_flops, valid_block_size

BF16 = 2
F32 = 4


def _proj_flops(cfg: ModelConfig, tokens: int, m: int, n: int,
                family: str) -> float:
    """One projection (n -> m) applied to `tokens` rows."""
    if cfg.swm.applies_to(family):
        k = valid_block_size(cfg.swm.block_size, n, m)
        if k > 1:
            return swm_flops(tokens, m, n, k, impl=cfg.swm.impl)
    return dense_flops(tokens, m, n)


def _proj_bytes(cfg: ModelConfig, m: int, n: int, family: str) -> float:
    """Weight bytes of one projection (read once per step)."""
    if cfg.swm.applies_to(family):
        k = valid_block_size(cfg.swm.block_size, n, m)
        if k > 1:
            return m * n / k * BF16
    return m * n * BF16


def _layer_terms(cfg: ModelConfig, spec: LayerSpec, tokens: int,
                 s_q: int, s_kv: int, kind: str) -> Dict[str, float]:
    """FLOPs + weight bytes + KV traffic for one layer application."""
    d, hd = cfg.d_model, cfg.head_dim
    HQ, HKV = cfg.n_heads, cfg.n_kv_heads
    f = b = kvb = 0.0
    if spec.mixer in ("attn", "attn_local"):
        q_out, kv_out = HQ * hd, HKV * hd
        f += _proj_flops(cfg, tokens, q_out, d, "attn")
        f += 2 * _proj_flops(cfg, tokens, kv_out, d, "attn")
        f += _proj_flops(cfg, tokens, d, q_out, "attn")
        b += _proj_bytes(cfg, q_out, d, "attn") * 2 \
            + _proj_bytes(cfg, kv_out, d, "attn") * 2
        eff_kv = min(s_kv, cfg.sliding_window) \
            if (spec.mixer == "attn_local" and cfg.sliding_window) else s_kv
        causal_f = 0.5 if (kind != "decode" and s_q == s_kv) else 1.0
        f += 4 * tokens * eff_kv * HQ * hd * causal_f  # scores + values
        # KV cache traffic: decode reads the whole cache per step
        if kind == "decode":
            kvb += 2 * (tokens * eff_kv) * HKV * hd * BF16
        else:
            kvb += 2 * tokens * HKV * hd * BF16        # write-once
    elif spec.mixer == "mamba":
        di, ds = cfg.mamba_expand * d, cfg.mamba_d_state
        dtr = cfg.mamba_dt_rank or max(1, d // 16)
        f += _proj_flops(cfg, tokens, 2 * di, d, "ffn")
        f += _proj_flops(cfg, tokens, d, di, "ffn")
        f += dense_flops(tokens, dtr + 2 * ds, di)
        f += dense_flops(tokens, di, dtr)
        f += tokens * di * (2 * cfg.mamba_d_conv + 6 * ds)   # conv + scan
        b += _proj_bytes(cfg, 2 * di, d, "ffn") + _proj_bytes(cfg, d, di, "ffn")
        kvb += 0 if kind != "decode" else tokens * di * ds * F32 * 2
    elif spec.mixer == "rwkv":
        f += 5 * _proj_flops(cfg, tokens, d, d, "attn")      # r,k,v,g,o
        f += tokens * (d * cfg.rwkv_decay_lora * 2 + d * cfg.rwkv_mix_lora * 10)
        H = d // cfg.rwkv_head_dim
        f += tokens * H * cfg.rwkv_head_dim ** 2 * 6          # wkv update
        b += 5 * _proj_bytes(cfg, d, d, "attn")
        kvb += 0 if kind != "decode" else \
            tokens * H * cfg.rwkv_head_dim ** 2 * F32 * 2

    # ffn
    if spec.mixer == "rwkv":
        f += _proj_flops(cfg, tokens, cfg.d_ff, d, "ffn")
        f += _proj_flops(cfg, tokens, d, d, "ffn")
        f += _proj_flops(cfg, tokens, d, cfg.d_ff, "ffn")
        b += (_proj_bytes(cfg, cfg.d_ff, d, "ffn")
              + _proj_bytes(cfg, d, d, "ffn")
              + _proj_bytes(cfg, d, cfg.d_ff, "ffn"))
    else:
        if spec.ffn in ("dense", "dense+moe"):
            f += 2 * _proj_flops(cfg, tokens, cfg.d_ff, d, "ffn")
            f += _proj_flops(cfg, tokens, d, cfg.d_ff, "ffn")
            b += 2 * _proj_bytes(cfg, cfg.d_ff, d, "ffn") \
                + _proj_bytes(cfg, d, cfg.d_ff, "ffn")
        if spec.ffn in ("moe", "dense+moe"):
            E, T = cfg.n_experts, cfg.n_experts_per_token
            dff = cfg.d_ff_expert or cfg.d_ff
            cap_tokens = tokens * T * cfg.capacity_factor
            f += dense_flops(tokens, E, d)                    # router
            f += 2 * _proj_flops(cfg, int(cap_tokens), dff, d, "expert")
            f += _proj_flops(cfg, int(cap_tokens), d, dff, "expert")
            b += E * (2 * _proj_bytes(cfg, dff, d, "expert")
                      + _proj_bytes(cfg, d, dff, "expert"))
    return {"flops": f, "wbytes": b, "kvbytes": kvb}


def cell_model(cfg: ModelConfig, shape: ShapeConfig, chips: int = 256,
               dp: int = 16, tp: int = 16) -> Dict[str, float]:
    """Global analytic terms for one (arch × shape) cell."""
    kind = shape.kind
    if kind == "decode":
        tokens = shape.global_batch              # one token per sequence
        s_q, s_kv = 1, shape.seq_len
    else:
        tokens = shape.global_batch * shape.seq_len
        s_q = s_kv = shape.seq_len

    enc_tokens = 0
    if cfg.family == "encdec":
        enc = min(shape.seq_len, cfg.enc_seq or shape.seq_len)
        enc_tokens = shape.global_batch * enc

    flops = wbytes = kvbytes = 0.0
    for group in cfg.layer_groups():
        for spec in group.layers:
            t = _layer_terms(cfg, spec, tokens, s_q, s_kv, kind)
            flops += t["flops"] * group.repeat
            wbytes += t["wbytes"] * group.repeat
            kvbytes += t["kvbytes"] * group.repeat
    if cfg.family == "encdec":
        ne = cfg.n_enc_layers or cfg.n_layers
        enc_len = min(shape.seq_len, cfg.enc_seq or shape.seq_len)
        if kind != "decode":
            # encoder stack over the frame sequence (bidirectional)
            t = _layer_terms(cfg, LayerSpec(mixer="attn", ffn="dense"),
                             enc_tokens, enc_len, enc_len, "prefill")
            flops += t["flops"] * ne
            wbytes += t["wbytes"] * ne
        # decoder cross-attention: q/o projections + attend over enc KV
        d, hd, HQ, HKV = cfg.d_model, cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
        xf = (_proj_flops(cfg, tokens, HQ * hd, d, "attn")
              + _proj_flops(cfg, tokens, d, HQ * hd, "attn")
              + 4 * tokens * enc_len * HQ * hd)
        flops += xf * cfg.n_layers
        wbytes += 2 * _proj_bytes(cfg, HQ * hd, d, "attn") * cfg.n_layers
        if kind == "decode":
            kvbytes += 2 * tokens * enc_len * HKV * hd * BF16 * cfg.n_layers

    # vocab head
    head_tokens = tokens if kind == "train" else shape.global_batch
    flops += 2 * head_tokens * cfg.d_model * cfg.vocab
    wbytes += cfg.vocab * cfg.d_model * BF16

    # ---- per-chip totals ------------------------------------------------
    # Weights are TP-sharded only: every DP replica streams its model shard
    # each step (FSDP shards further but all-gathers back per microbatch).
    # Activations / KV / optimizer state divide by the full chip count
    # (batch over DP, heads/experts over TP, ZeRO-1 moments over DP).
    mb = 8 if kind == "train" else 1                 # production microbatches
    if kind == "train":
        remat_mult = 4.0 if cfg.remat != "none" else 3.0
        flops_total = flops * remat_mult            # fwd + 2×bwd (+ remat fwd)
        from repro.launch.specs import count_params
        n = count_params(cfg)["stored"]
        # params+grads+opt traffic: p read(bf16)+write + grad f32 + m,v r/w
        opt_bytes_chip = n * (2 * BF16 + F32 + 4 * F32) / chips
        w_chip = (wbytes / tp) * 3.0                 # fwd + remat-fwd + bwd
        act_chip = tokens * cfg.d_model * BF16 * cfg.n_layers * 3 / chips
        bytes_chip = w_chip + opt_bytes_chip + act_chip + kvbytes / chips
        # collectives per chip: grad ring all-reduce (f32, TP-sharded),
        # 2 TP all-reduces per layer on activations (fwd+bwd), MoE a2a,
        # FSDP param regather per microbatch
        grads_per_chip = n * F32 / tp
        tp_act = 2 * (tokens / dp) * cfg.d_model * BF16 * cfg.n_layers * 2
        coll = 2 * grads_per_chip + tp_act
        if cfg.is_moe:
            coll += 2 * (tokens / chips) * cfg.n_experts_per_token \
                * cfg.d_model * BF16 * (cfg.n_layers // cfg.moe_every) * 3
        if cfg.fsdp:
            coll += mb * n * BF16 / dp
    else:
        flops_total = flops
        w_chip = wbytes / tp
        act_chip = tokens * cfg.d_model * BF16 * cfg.n_layers * 2 / chips
        bytes_chip = w_chip + act_chip + kvbytes / chips
        tp_act = 2 * (tokens / max(dp, 1)) * cfg.d_model * BF16 * cfg.n_layers
        coll = tp_act
        if cfg.is_moe:
            coll += 2 * (tokens / chips) * cfg.n_experts_per_token \
                * cfg.d_model * BF16 * (cfg.n_layers // cfg.moe_every)

    # minimal unavoidable per-chip byte stream: weights once (TP shard) +
    # KV/state once — the memory-roofline ideal for serve cells
    min_bytes_chip = wbytes / tp + kvbytes / chips
    return {
        "a_flops": flops_total,
        "a_bytes": bytes_chip * chips,
        "a_coll_per_chip": coll,
        "a_flops_per_chip": flops_total / chips,
        "a_bytes_per_chip": bytes_chip,
        "a_min_bytes_per_chip": min_bytes_chip,
    }
