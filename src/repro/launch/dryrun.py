import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

This is how the distribution config is proven coherent without hardware:
512 placeholder host devices stand in for 2 pods × 256 chips; ``jax.jit``
with the production in/out shardings runs the full GSPMD pipeline, and the
compiled artifact yields memory_analysis (fits?), cost_analysis (FLOPs,
bytes) and the post-SPMD HLO (collective schedule) for §Roofline.

Usage:
  python -m repro.launch.dryrun --arch qwen3-0.6b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all            # every cell, both meshes
  python -m repro.launch.dryrun --arch ... --impl freq   # beyond-paper impl

Results land in experiments/dryrun/<arch>__<shape>__<mesh>[__<impl>].json.
"""

import argparse
import dataclasses
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import SHAPES, TrainConfig
from repro.configs.registry import ARCHS, LONG_CONTEXT_ARCHS, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import build_model, input_specs
from repro.serve.engine import make_decode_step, make_prefill_step
from repro.train.loop import make_train_step

COLLECTIVE_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\w[\w\d_\[\]]*?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)",
)

_HLO_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}


def _shape_bytes(shape_str: str) -> int:
    """'f32[8,128]{1,0}' -> bytes. Tuples handled by caller."""
    m = re.match(r"(\w+)\[([\d,]*)\]", shape_str)
    if not m:
        return 0
    dt, dims = m.group(1), m.group(2)
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _HLO_DTYPE_BYTES.get(dt, 4)


_OP_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:\w+\[[\d,]*\][^ ]*))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\("
)


def _parse_computations(hlo_text: str):
    """Split post-optimization HLO into computations: name -> list of lines."""
    comps = {}
    cur = None
    for line in hlo_text.splitlines():
        m = re.match(r"\s*(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*->.*\{\s*$", line)
        if m and not line.lstrip().startswith("ROOT"):
            cur = m.group(1)
            comps[cur] = []
            continue
        if cur is not None:
            if line.strip() == "}":
                cur = None
                continue
            comps[cur].append(line)
    return comps


def _while_multipliers(comps):
    """Trip-count multiplier per computation.

    XLA cost analysis (and a naive text scan) counts a while body ONCE; real
    traffic is body × trip count. jax scans lower to whiles comparing the
    induction variable against a constant — recover it from the condition
    computation and propagate products down the call graph.
    """
    while_re = re.compile(
        r"while\(.*?condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)", )
    const_re = re.compile(r"constant\((\d+)\)")
    trips = {}       # body comp -> trip count
    children = {}    # comp -> [(body, trips)]
    for name, lines in comps.items():
        kids = []
        for line in lines:
            m = while_re.search(line)
            if not m:
                continue
            cond, body = m.group(1), m.group(2)
            consts = [int(c) for c in const_re.findall(
                "\n".join(comps.get(cond, [])))]
            trip = max(consts) if consts else 1
            kids.append((body, max(trip, 1)))
        children[name] = kids
    mult = {}

    def visit(name, m):
        mult[name] = max(mult.get(name, 0), m)
        for body, trip in children.get(name, []):
            visit(body, m * trip)

    roots = set(comps) - {b for kids in children.values() for b, _ in kids}
    for r in roots:
        visit(r, 1)
    return mult


def collective_bytes(hlo_text: str):
    """Per-device collective bytes by kind: raw (each op once — the naive
    assignment-prescribed scan) and trip-weighted (× enclosing while-loop
    trip counts — the physically meaningful number)."""
    raw = {"all-gather": 0, "all-reduce": 0, "reduce-scatter": 0,
           "all-to-all": 0, "collective-permute": 0}
    weighted = dict.fromkeys(raw, 0)
    count = dict.fromkeys(raw, 0)
    comps = _parse_computations(hlo_text)
    mult = _while_multipliers(comps)
    for name, lines in comps.items():
        m_comp = mult.get(name, 1)
        for line in lines:
            m = _OP_RE.search(line)
            if not m or "-done(" in line:
                continue
            shape_str, kind = m.group(1), m.group(2)
            if shape_str.startswith("("):
                total = sum(_shape_bytes(s.strip())
                            for s in shape_str[1:-1].split(",") if "[" in s)
            else:
                total = _shape_bytes(shape_str)
            raw[kind] += total
            weighted[kind] += total * m_comp
            count[kind] += 1
    return raw, count, weighted


def run_cell(arch: str, shape_name: str, mesh_kind: str, impl: str = None,
             seq_override: int = None):
    cfg = get_config(arch)
    if impl:
        cfg = dataclasses.replace(
            cfg, swm=dataclasses.replace(cfg.swm, impl=impl)
            if impl != "dense"
            else dataclasses.replace(cfg.swm, block_size=0))
    shape = SHAPES[shape_name]
    if seq_override:
        shape = dataclasses.replace(shape, seq_len=seq_override)
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    # production training uses gradient accumulation (8 microbatches):
    # activation memory fits the 16 GB/chip envelope (EXPERIMENTS.md §Dry-run)
    tcfg = TrainConfig(microbatch=8)
    t0 = time.time()

    from repro.dist.sharding import set_ambient_mesh
    set_ambient_mesh(mesh)
    with mesh:
        specs = input_specs(cfg, shape, mesh, tcfg)
        model = specs["model"]
        if shape.kind == "train":
            step = make_train_step(model, cfg, tcfg, mesh=mesh)
            jitted = jax.jit(
                step,
                in_shardings=(specs["state_shardings"],
                              specs["batch_shardings"]),
                donate_argnums=(0,),
            )
            lowered = jitted.lower(specs["state_sds"], specs["batch_sds"])
        elif shape.kind == "prefill":
            step = make_prefill_step(model, cfg)
            args = [specs["params_sds"], specs["tokens_sds"],
                    specs["cache_sds"]]
            shardings = [specs["params_shardings"],
                         specs["tokens_shardings"],
                         specs["cache_shardings"]]
            if "extra_sds" in specs:
                args.append(specs["extra_sds"])
                shardings.append(specs["extra_shardings"])
            jitted = jax.jit(step, in_shardings=tuple(shardings),
                             donate_argnums=(2,))
            lowered = jitted.lower(*args)
        else:
            step = make_decode_step(model, cfg)
            jitted = jax.jit(
                step,
                in_shardings=(specs["params_shardings"],
                              specs["tokens_shardings"],
                              specs["cache_shardings"],
                              specs["pos_shardings"]),
                donate_argnums=(2,),
            )
            lowered = jitted.lower(specs["params_sds"], specs["tokens_sds"],
                                   specs["cache_sds"], specs["pos_sds"])
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    from repro.launch.specs import count_params

    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "impl": impl or cfg.swm.impl, "kind": shape.kind,
        "devices": int(np.prod(list(mesh.shape.values()))),
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "params": count_params(cfg),
        "tokens": (shape.global_batch * shape.seq_len
                   if shape.kind != "decode" else shape.global_batch),
    }
    try:
        ma = compiled.memory_analysis()
        if ma is not None:
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes",
                      "alias_size_in_bytes"):
                v = getattr(ma, k, None)
                if v is not None:
                    result[k] = int(v)
    except Exception as e:  # lint: allow-broad-except — best-effort backend introspection
        result["memory_analysis_error"] = str(e)
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        if ca:
            result["flops"] = float(ca.get("flops", -1))
            result["bytes_accessed"] = float(ca.get("bytes accessed", -1))
            result["transcendentals"] = float(ca.get("transcendentals", -1))
    except Exception as e:  # lint: allow-broad-except — best-effort backend introspection
        result["cost_analysis_error"] = str(e)
    try:
        hlo = compiled.as_text()
        cb, cc, cw = collective_bytes(hlo)
        result["collective_bytes"] = cb
        result["collective_counts"] = cc
        result["collective_bytes_weighted"] = cw
        result["hlo_lines"] = hlo.count("\n")
    except Exception as e:  # lint: allow-broad-except — best-effort backend introspection
        result["hlo_error"] = str(e)
    # analytic (structural) roofline terms — immune to the while-loop
    # once-counting of cost_analysis; see launch/analytic.py
    try:
        from repro.launch.analytic import cell_model
        result["analytic"] = cell_model(
            cfg, shape, chips=int(np.prod(list(mesh.shape.values()))))
    except Exception as e:  # lint: allow-broad-except — best-effort analytic model
        result["analytic_error"] = str(e)
    return result


def cells(include_long=True):
    for arch in ARCHS:
        for shape_name in SHAPES:
            if shape_name == "long_500k" and arch not in LONG_CONTEXT_ARCHS:
                continue  # skipped per DESIGN.md §Arch-applicability
            if not include_long and shape_name == "long_500k":
                continue
            yield arch, shape_name


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi"], default="single")
    ap.add_argument("--impl", default=None,
                    help="override swm impl: paper|freq|dft|pallas|dense")
    ap.add_argument("--seq", type=int, default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    todo = []
    if args.all:
        for arch, shape in cells():
            for mesh in ("single", "multi"):
                todo.append((arch, shape, mesh))
    else:
        todo.append((args.arch, args.shape, args.mesh))

    for arch, shape, mesh in todo:
        tag = f"{arch}__{shape}__{mesh}" + (f"__{args.impl}" if args.impl else "")
        path = os.path.join(args.out, tag + ".json")
        if os.path.exists(path) and not args.force:
            print(f"[skip] {tag}")
            continue
        print(f"[run ] {tag}", flush=True)
        try:
            res = run_cell(arch, shape, mesh, args.impl, args.seq)
            status = "OK"
        except Exception as e:  # lint: allow-broad-except — record per-cell failures in the artifact
            res = {"arch": arch, "shape": shape, "mesh": mesh,
                   "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-4000:]}
            status = "FAIL"
        with open(path, "w") as f:
            json.dump(res, f, indent=1)
        print(f"[{status}] {tag} "
              f"flops={res.get('flops')} "
              f"coll={res.get('collective_bytes')}", flush=True)


if __name__ == "__main__":
    main()
