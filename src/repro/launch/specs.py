"""input_specs — ShapeDtypeStruct stand-ins for every (arch × shape) cell.

Nothing here allocates: params/opt-state come straight from ParamSpecs,
caches via ``jax.eval_shape`` over ``model.init_cache``. Shardings are
produced alongside so the dry-run can pass in_shardings that match what the
production launcher would use.

Shape-kind → lowered program:
  train_*    → train_step(state, batch)
  prefill_*  → prefill_step(params, tokens, cache[, frontend stub])
  decode_* / long_* → decode_step(params, tokens(B,1), cache, pos)
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig, TrainConfig, SHAPES
from repro.dist.sharding import (batch_pspec, data_axes, make_act_rules,
                                 param_shardings, opt_shardings,
                                 spec_to_pspec)
from repro.models.decoder import HybridDecoderLM
from repro.models.encdec import EncDecLM
from repro.nn.module import specs_to_sds
from repro.optim.optimizers import adafactor_state_specs, adamw_state_specs

__all__ = ["build_model", "input_specs", "state_specs", "cache_sds",
           "cache_shardings"]


def build_model(cfg: ModelConfig):
    if cfg.family == "encdec":
        return EncDecLM(cfg)
    return HybridDecoderLM(cfg)


def count_params(cfg: ModelConfig) -> Dict[str, float]:
    """Stored + active + dense-equivalent parameter counts.

    * stored: what actually lives in HBM (SWM tables are m·n/k)
    * active: MoE experts scaled by top_k/E (MODEL_FLOPS uses this)
    * dense_*: the same model with SWM off — the compression denominator
    """
    from repro.nn.module import flatten_with_paths

    def counts(c: ModelConfig):
        model = build_model(c)
        total = active = embed = 0
        frac = (c.n_experts_per_token / c.n_experts) if c.n_experts else 1.0
        for path, spec in flatten_with_paths(model.specs()):
            n = int(np.prod(spec.shape))
            total += n
            in_moe = any("ffn_moe" in p or p == "experts" for p in path)
            active += n * (frac if in_moe else 1.0)
            if path[0] == "embed":
                embed += n
        return total, active, embed

    stored, stored_active, embed = counts(cfg)
    dense_cfg = dataclasses.replace(
        cfg, swm=dataclasses.replace(cfg.swm, block_size=0)
    )
    dense, dense_active, _ = counts(dense_cfg)
    # FLOP-relevant N: embedding *gather* contributes ~0 FLOPs; the vocab
    # projection (tied or untied head) contributes one d×V matmul per token
    # — but only on positions where logits are computed (all for training,
    # last-token for prefill/decode), so body and head are split.
    head = cfg.d_model * cfg.vocab
    body = stored_active - embed - (0 if cfg.tie_embeddings else head)
    return {
        "stored": stored, "stored_active": stored_active,
        "dense": dense, "dense_active": dense_active,
        "embed": embed,
        "head_n": head,
        "body_n": max(body, 0),
        "flops_n": max(body, 0) + head,
        "compression": dense / max(stored, 1),
    }


def state_specs(cfg: ModelConfig, tcfg: TrainConfig, mesh: Mesh):
    """(state SDS, state shardings) for train_step."""
    model = build_model(cfg)
    pspecs = model.specs()
    if cfg.optimizer == "adafactor":
        opt = adafactor_state_specs(pspecs, tcfg)
    else:
        opt = adamw_state_specs(pspecs, tcfg)
    sds = {
        "params": specs_to_sds(pspecs),
        "opt": {k: specs_to_sds(v) for k, v in opt.items()},
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }
    shardings = {
        "params": param_shardings(mesh, pspecs, fsdp=cfg.fsdp, low_tp=cfg.low_tp),
        "opt": {k: opt_shardings(mesh, v, fsdp=cfg.fsdp, low_tp=cfg.low_tp)
                for k, v in opt.items()},
        "step": NamedSharding(mesh, P()),
    }
    return sds, shardings


def _frontend_dim(cfg: ModelConfig) -> int:
    return cfg.d_model


def batch_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh):
    """Training batch SDS + shardings (tokens carry S+1 for next-token)."""
    B, S = shape.global_batch, shape.seq_len
    sds = {"tokens": jax.ShapeDtypeStruct((B, S + 1), jnp.int32)}
    if cfg.family == "vlm":
        sds["img"] = jax.ShapeDtypeStruct(
            (B, cfg.n_img_tokens, _frontend_dim(cfg)), jnp.bfloat16
        )
    if cfg.family == "encdec":
        enc = min(S, cfg.enc_seq or S)
        sds["frames"] = jax.ShapeDtypeStruct(
            (B, enc, _frontend_dim(cfg)), jnp.bfloat16
        )
    shardings = {
        k: NamedSharding(mesh, batch_pspec(mesh, v.ndim, batch=v.shape[0]))
        for k, v in sds.items()
    }
    return sds, shardings


def cache_sds(cfg: ModelConfig, batch: int, cache_len: int):
    model = build_model(cfg)
    return jax.eval_shape(lambda: model.init_cache(batch, cache_len))


def cache_shardings(cfg: ModelConfig, cache_tree, mesh: Mesh):
    """Shard caches: batch over DP (when divisible), kv heads over model.

    Leaf layouts (possibly with leading stack dims):
      kv cache k/v: (..., B, S, HKV, hd); pos: (..., B, S)
      mamba: conv (..., B, dc-1, di), ssm (..., B, di, ds)
      rwkv:  shift (..., B, d), wkv (..., B, H, hd, hd)
    We identify the batch dim as the first dim equal to `batch`, shard it
    over the DP axes if divisible; shard any dim divisible by the model
    axis that matches known head/channel dims.
    """
    dp = data_axes(mesh)
    dp_size = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1
    model_ok = "model" in mesh.axis_names
    msize = mesh.shape["model"] if model_ok else 1

    model_dims = set()
    if cfg.n_kv_heads % max(msize, 1) == 0:
        model_dims.add(cfg.n_kv_heads)
    for d in (cfg.mamba_expand * cfg.d_model, cfg.d_ff, cfg.d_model,
              cfg.d_model // max(cfg.rwkv_head_dim, 1)):
        if d and d % max(msize, 1) == 0:
            model_dims.add(d)

    def one(leaf):
        entries = [None] * leaf.ndim
        used_dp = used_model = False
        for i, d in enumerate(leaf.shape):
            if not used_dp and dp and d != 1 and d % dp_size == 0 and i <= 1:
                # batch-like leading dim
                entries[i] = dp if len(dp) > 1 else dp[0]
                used_dp = True
                continue
            if (not used_model and model_ok and d in model_dims
                    and d % msize == 0 and i >= 1):
                entries[i] = "model"
                used_model = True
        return NamedSharding(mesh, P(*entries))

    return jax.tree.map(one, cache_tree)


def input_specs(
    cfg: ModelConfig,
    shape: ShapeConfig,
    mesh: Mesh,
    tcfg: Optional[TrainConfig] = None,
) -> Dict[str, Any]:
    """Everything the dry-run needs to lower one cell."""
    tcfg = tcfg or TrainConfig()
    model = build_model(cfg)
    out: Dict[str, Any] = {"model": model, "kind": shape.kind}
    if shape.kind == "train":
        sds, sh = state_specs(cfg, tcfg, mesh)
        bsds, bsh = batch_specs(cfg, shape, mesh)
        out.update(state_sds=sds, state_shardings=sh,
                   batch_sds=bsds, batch_shardings=bsh)
        return out

    # serving cells: params only (no optimizer state)
    pspecs = model.specs()
    out["params_sds"] = specs_to_sds(pspecs)
    out["params_shardings"] = param_shardings(mesh, pspecs, fsdp=False)
    B, S = shape.global_batch, shape.seq_len

    if shape.kind == "prefill":
        csds = cache_sds(cfg, B, S)
        out["cache_sds"] = csds
        out["cache_shardings"] = cache_shardings(cfg, csds, mesh)
        out["tokens_sds"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
        out["tokens_shardings"] = NamedSharding(mesh, batch_pspec(mesh, 2, batch=B))
        if cfg.family == "vlm":
            out["extra_sds"] = jax.ShapeDtypeStruct(
                (B, cfg.n_img_tokens, cfg.d_model), jnp.bfloat16)
            out["extra_shardings"] = NamedSharding(mesh, batch_pspec(mesh, 3, batch=B))
        if cfg.family == "encdec":
            enc = min(S, cfg.enc_seq or S)
            out["extra_sds"] = jax.ShapeDtypeStruct(
                (B, enc, cfg.d_model), jnp.bfloat16)
            out["extra_shardings"] = NamedSharding(mesh, batch_pspec(mesh, 3, batch=B))
        return out

    # decode: one new token against a seq_len cache
    csds = cache_sds(cfg, B, S)
    out["cache_sds"] = csds
    out["cache_shardings"] = cache_shardings(cfg, csds, mesh)
    out["tokens_sds"] = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    out["tokens_shardings"] = NamedSharding(mesh, batch_pspec(mesh, 2, batch=B))
    out["pos_sds"] = jax.ShapeDtypeStruct((B,), jnp.int32)
    out["pos_shardings"] = NamedSharding(mesh, batch_pspec(mesh, 1, batch=B))
    return out
