"""The trace auditor audits itself: walker recursion, purity taint,
seeded-violation fixtures per rule (each must FAIL with a source-located
diagnostic), the AST lint on synthetic files, and the CLI report schema.

The seeded fixtures are the auditor's own regression floor: a rule that
stops firing on the violation it exists to catch would silently turn the
CI gate green, so every rule here is driven over both a conforming and a
deliberately broken program.
"""

import json
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import (Contract, DenseFallbackDot, DonatedInputsAliased,
                            LaunchBudget, NoDenseDotGeneral, NoFFT,
                            NoWeightConcat, NoWeightFFT, QuantizedTableDtypes,
                            StructuralContractError, collect_pure_vars,
                            iter_eqns, run_contract, source_location)
from repro.analysis.lint import ALLOW_BROAD_EXCEPT_MARKER, lint_file
from repro.kernels.block_circulant import build_plan
from repro.kernels.block_circulant.ops import (count_pallas_launches,
                                               outer_dot_shapes)

jax.config.update("jax_platform_name", "cpu")


def _rand(shape, seed=0):
    return jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32)


# ---------------------------------------------------------------------------
# Walker: recursion into higher-order primitives
# ---------------------------------------------------------------------------


def test_walker_counts_launch_inside_scan():
    """The regression the old hand-rolled visit loops missed: a pallas_call
    nested under lax.scan (and under jit) must be seen by the walker —
    and therefore by count_pallas_launches/outer_dot_shapes."""
    plan = build_plan(_rand((3, 3, 8)))          # square: scan-carry shaped
    x0 = _rand((4, 24), seed=1)

    def scanned(x):
        def body(carry, _):
            return plan.apply(carry) * 0 + carry, ()
        y, _ = jax.lax.scan(body, x, jnp.arange(3))
        return y

    jp = jax.make_jaxpr(jax.jit(scanned))(x0)
    # the launch sits two levels down: pjit -> scan -> pallas_call
    assert count_pallas_launches(jp) == 1
    assert LaunchBudget(exact=1).check(jp) == []


def test_walker_counts_dot_inside_cond_branches():
    def f(x, w):
        return jax.lax.cond(x.sum() > 0,
                            lambda: x @ w,
                            lambda: (x * 2.0) @ w)

    jp = jax.make_jaxpr(f)(_rand((3, 4)), _rand((4, 5), seed=1))
    dots = [e for e in iter_eqns(jp) if e.primitive.name == "dot_general"]
    assert len(dots) == 2                      # one per cond branch
    assert outer_dot_shapes(jp) != []


def test_walker_does_not_descend_into_pallas_bodies():
    """Kernel-internal dots are not "outer" contractions: the launch itself
    is yielded, its VMEM program is not (unless asked)."""
    plan = build_plan(_rand((2, 3, 8)))
    jp = jax.make_jaxpr(plan.apply)(_rand((4, 24), seed=1))
    assert outer_dot_shapes(jp) == []
    outer = [e.primitive.name for e in iter_eqns(jp)]
    inner = [e.primitive.name for e in iter_eqns(jp, into_pallas=True)]
    assert "pallas_call" in outer
    assert len(inner) > len(outer)             # the body only shows up opted-in


def test_source_location_points_at_user_code():
    jp = jax.make_jaxpr(lambda w: jnp.fft.rfft(w, axis=-1))(_rand((2, 8)))
    (eqn,) = [e for e in iter_eqns(jp) if e.primitive.name == "fft"]
    where = source_location(eqn)
    assert where and "test_analysis.py" in where


# ---------------------------------------------------------------------------
# Purity taint analysis
# ---------------------------------------------------------------------------


def test_purity_separates_weight_from_activation():
    # w and x get different shapes so the tracer does NOT dedup their rfft
    # sub-jaxprs (see test_purity_shared_subjaxpr_meets_impure)
    def f(w, x):
        wf = jnp.fft.rfft(w, axis=-1)           # pure: derives from w only
        xf = jnp.fft.rfft(x, axis=-1)           # impure: derives from x
        return jnp.fft.irfft(wf[:2] * xf, n=8, axis=-1)

    jp = jax.make_jaxpr(f)(_rand((3, 8)), _rand((2, 8), seed=1))
    pure = collect_pure_vars(jp, [True, False])  # w pure, x not
    ffts = [e for e in iter_eqns(jp) if e.primitive.name == "fft"]
    assert len(ffts) == 3
    purities = sorted(e.invars[0] in pure for e in ffts)
    assert purities == [False, False, True]      # only rfft(w) is weight-side
    # NoWeightFFT flags exactly that one, with provenance
    vs = NoWeightFFT(n_param_invars=1).check(jp)
    assert len(vs) == 1 and "test_analysis.py" in vs[0].where


def test_purity_shared_subjaxpr_meets_impure():
    """Same-shape rfft call sites share one traced sub-jaxpr object; its
    inner vars take the meet (AND) of every caller's purity, so sharing
    demotes to impure — conservative (can hide a weight fft at a shared
    call site, never invent one)."""
    def f(w, x):
        return (jnp.fft.rfft(w, axis=-1).real.sum()
                + jnp.fft.rfft(x, axis=-1).real.sum())

    jp = jax.make_jaxpr(f)(_rand((2, 8)), _rand((2, 8), seed=1))
    pure = collect_pure_vars(jp, [True, False])
    inner = [e for e in iter_eqns(jp) if e.primitive.name == "fft"]
    assert all(e.invars[0] not in pure for e in inner)
    assert NoWeightFFT(n_param_invars=1).check(jp) == []


def test_purity_taint_propagates_through_scan():
    """Taint must survive a scan boundary: an fft of a scan carry seeded
    from activations is NOT weight-side."""
    def f(w, x):
        def body(carry, _):
            return carry + w, jnp.fft.rfft(carry, axis=-1).real.sum()
        _, ys = jax.lax.scan(body, x, jnp.arange(2))
        return ys

    jp = jax.make_jaxpr(f)(_rand((2, 8)), _rand((2, 8), seed=1))
    assert NoWeightFFT(n_param_invars=1).check(jp) == []


def test_purity_closed_over_constants_are_pure():
    """A weight baked into the trace as a constant is still weight data —
    the NoWeightFFT fixture a closure would otherwise smuggle past."""
    w = _rand((2, 8))

    def f(x):
        return jnp.fft.rfft(w, axis=-1).real.sum() + x.sum()

    jp = jax.make_jaxpr(f)(_rand((4,), seed=1))
    vs = NoWeightFFT(n_param_invars=0).check(jp)
    assert len(vs) == 1


# ---------------------------------------------------------------------------
# Seeded-violation fixtures: every rule fires on the program it exists for
# ---------------------------------------------------------------------------


def test_no_fft_rule_fires_with_location():
    jp = jax.make_jaxpr(lambda x: jnp.fft.irfft(
        jnp.fft.rfft(x, axis=-1), n=8, axis=-1))(_rand((2, 8)))
    vs = NoFFT().check(jp)
    assert len(vs) == 2
    assert all(v.primitive == "fft" for v in vs)
    assert all(v.where and "test_analysis.py:" in v.where for v in vs)
    assert NoFFT().check(jax.make_jaxpr(lambda x: x * 2)(_rand((2,)))) == []


def test_dense_fallback_rule_fires_only_on_weight_side():
    w = _rand((24, 40), seed=1)

    def fallback(w, x):
        return x @ w                              # the silent dense path

    jp = jax.make_jaxpr(fallback)(w, _rand((4, 24), seed=2))
    vs = DenseFallbackDot([(24, 40)], n_param_invars=1).check(jp)
    assert len(vs) == 1 and vs[0].primitive == "dot_general"
    # same shape as a pure activation contraction: not a fallback
    def act(w, a, b):
        return (a @ b) @ w[:40, :4]

    jp2 = jax.make_jaxpr(act)(w.T, _rand((24, 24), seed=3),
                              _rand((24, 40), seed=4))
    vs2 = DenseFallbackDot([(24, 40)], n_param_invars=1).check(jp2)
    assert all("(24, 40)" not in str(v) or v.primitive != "dot_general"
               for v in vs2) or vs2 == []


def test_launch_budget_points_at_excess_launch():
    plan = build_plan(_rand((3, 3, 8)))
    x = _rand((4, 24), seed=1)
    jp = jax.make_jaxpr(lambda x: plan.apply(plan.apply(x) * 0 + x))(x)
    assert LaunchBudget(exact=2).check(jp) == []
    vs = LaunchBudget(exact=1).check(jp)
    assert len(vs) == 1 and vs[0].primitive == "pallas_call"
    assert vs[0].where                            # source-located culprit
    assert LaunchBudget(max_launches=2).check(jp) == []
    with pytest.raises(ValueError):
        LaunchBudget()
    with pytest.raises(ValueError):
        LaunchBudget(exact=1, max_launches=2)


def test_no_weight_concat_distinguishes_sides():
    wa, wb = _rand((4, 3, 8)), _rand((4, 3, 8), seed=1)

    def weight_stack(wa, wb, x):
        return (jnp.concatenate([wa, wb], axis=0) * x).sum()

    def act_stack(wa, wb, x):
        return jnp.concatenate([x, x], axis=0).sum() + (wa + wb).sum()

    x = _rand((8, 3, 8), seed=2)
    jp_w = jax.make_jaxpr(weight_stack)(wa, wb, x)
    jp_a = jax.make_jaxpr(act_stack)(wa, wb, x)
    rule = NoWeightConcat(table_shapes=[(8, 3, 8)], n_param_invars=2)
    vs = rule.check(jp_w)
    assert len(vs) == 1 and vs[0].primitive == "concatenate"
    assert rule.check(jp_a) == []                 # activation concat passes
    # strict mode flags any concatenate at all
    assert len(NoWeightConcat().check(jp_a)) == 1


def test_quantized_dtype_rule_names_the_bad_path():
    good = {"layer": {"wr": jnp.zeros((2, 3, 5), jnp.int8),
                      "wi": jnp.zeros((2, 3, 5), jnp.int8),
                      "w_scale": jnp.ones((2, 3, 1), jnp.float32)}}
    assert QuantizedTableDtypes("int8").check_params(good) == []
    bad = {"layer": {"wr": jnp.zeros((2, 3, 5), jnp.float32),
                     "wi": jnp.zeros((2, 3, 5), jnp.int8),
                     "w_scale": jnp.ones((2, 3, 1), jnp.float16)}}
    vs = QuantizedTableDtypes("int8").check_params(bad)
    msgs = "\n".join(v.message for v in vs)
    assert "layer/wr" in msgs and "layer/w_scale" in msgs
    with pytest.raises(ValueError):
        QuantizedTableDtypes("int4")


def test_donation_rule_reads_lowered_text():
    def f(x):
        return x + 1

    x = jnp.zeros((8,), jnp.float32)
    donated = jax.jit(f, donate_argnums=(0,)).lower(x).as_text()
    plain = jax.jit(f).lower(x).as_text()
    rule = DonatedInputsAliased()
    assert rule.check_lowered(donated) == []
    vs = rule.check_lowered(plain, surface="serve_donation[decode]")
    assert len(vs) == 1 and vs[0].surface == "serve_donation[decode]"


def test_contract_stamps_surface_and_error_formats():
    jp = jax.make_jaxpr(lambda x: jnp.fft.rfft(x, axis=-1))(_rand((2, 8)))
    c = Contract(name="plan_forward[k=8]", rules=(NoFFT(),))
    vs = run_contract(c, jp)
    assert vs and vs[0].surface == "plan_forward[k=8]"
    err = StructuralContractError(vs)
    assert "plan_forward[k=8]" in str(err) and "NoFFT" in str(err)
    assert "test_analysis.py" in str(err)          # provenance in the message
    # violations serialize losslessly for the CLI artifact
    rt = json.loads(json.dumps(vs[0].to_json()))
    assert rt["rule"] == "NoFFT" and rt["surface"] == "plan_forward[k=8]"


# ---------------------------------------------------------------------------
# AST lint on synthetic files
# ---------------------------------------------------------------------------


def _lint_src(tmp_path, rel, src):
    p = tmp_path / rel.replace("/", "__")
    p.write_text(textwrap.dedent(src))
    return lint_file(str(p), rel=rel)


def test_lint_fft_outside_core(tmp_path):
    src = """
        import jax.numpy as jnp
        def f(w):
            return jnp.fft.rfft(w, axis=-1)
    """
    vs = _lint_src(tmp_path, "serve/helper.py", src)
    assert any(v.rule == "fft-outside-core" and ":4" in v.where for v in vs)
    # the blessed locations pass
    assert _lint_src(tmp_path, "core/circulant.py", src) == []
    assert _lint_src(tmp_path, "kernels/block_circulant/opsx.py", src) == []


def test_lint_nondeterminism_and_sync_only_in_serve(tmp_path):
    src = """
        import random, time, jax
        def step(x):
            t0 = time.monotonic()
            if random.random() < 0.5:
                x.block_until_ready()
            return jax.device_get(x), t0
        rng = random.Random(0)          # seeded: allowed
    """
    vs = _lint_src(tmp_path, "serve/engine2.py", src)
    rules = sorted(v.rule for v in vs)
    assert rules == ["blocking-sync-in-serve", "blocking-sync-in-serve",
                     "nondeterminism-in-serve", "nondeterminism-in-serve"]
    # identical code outside serve/ is not this lint's business
    assert _lint_src(tmp_path, "train/loop2.py", src) == []


def test_lint_broad_except_and_marker(tmp_path):
    bad = """
        def f():
            try:
                return 1
            except Exception:
                return 0
    """
    vs = _lint_src(tmp_path, "launch/x.py", bad)
    assert [v.rule for v in vs] == ["broad-except"]
    ok = f"""
        def f():
            try:
                return 1
            # {ALLOW_BROAD_EXCEPT_MARKER} — fixture
            except BaseException:
                return 0
    """
    assert _lint_src(tmp_path, "launch/x.py", ok) == []


def test_lint_reports_syntax_errors_as_violations(tmp_path):
    vs = _lint_src(tmp_path, "serve/broken.py", "def f(:\n")
    assert [v.rule for v in vs] == ["parse-error"]


# ---------------------------------------------------------------------------
# CLI / whole-config audit
# ---------------------------------------------------------------------------


def test_cli_single_config_report(tmp_path, capsys):
    from repro.analysis.__main__ import main

    out = tmp_path / "report.json"
    rc = main(["--config", "qwen3-0.6b", "--no-lint", "--json", str(out)])
    assert rc == 0
    report = json.loads(out.read_text())
    assert report["schema"] == "repro.analysis/v1"
    assert report["violations_total"] == 0
    (entry,) = report["configs"]
    assert entry["arch"] == "qwen3-0.6b" and entry["violations"] == []
    names = " ".join(entry["surfaces"])
    for expect in ("plan_forward", "plan_train_step", "serve_prefill",
                   "serve_decode", "serve_launch_parity"):
        assert expect in names, names
    assert "ok]" in capsys.readouterr().out


def test_cli_lint_only_on_clean_tree(tmp_path):
    from repro.analysis.__main__ import main

    (tmp_path / "m.py").write_text("x = 1\n")
    assert main(["--lint-root", str(tmp_path)]) == 0


def test_cli_exits_nonzero_on_lint_violation(tmp_path):
    from repro.analysis.__main__ import main

    (tmp_path / "m.py").write_text(
        "try:\n    pass\nexcept Exception:\n    pass\n")
    assert main(["--lint-root", str(tmp_path)]) == 1


def test_audit_config_rejects_unknown_arch():
    from repro.analysis.contracts import audit_config

    with pytest.raises(KeyError):
        audit_config("no-such-arch")
