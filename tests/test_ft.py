"""Fault tolerance: checkpoint atomicity, async writer, restart driver,
straggler watchdog, elastic restore."""

import os
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig, SWMConfig, TrainConfig
from repro.data.pipeline import SyntheticLM
from repro.ft.checkpoint import (AsyncCheckpointer, latest_step,
                                 restore_checkpoint, save_checkpoint)
from repro.ft.driver import FaultInjector, StragglerWatchdog, TrainDriver
from repro.models.decoder import HybridDecoderLM
from repro.nn.module import init_params
from repro.train.loop import init_train_state, make_train_step

jax.config.update("jax_platform_name", "cpu")


def _tiny():
    cfg = ModelConfig(name="t", n_layers=2, d_model=32, n_heads=2,
                      n_kv_heads=2, head_dim=16, d_ff=64, vocab=64,
                      remat="none", param_dtype="float32",
                      compute_dtype="float32",
                      swm=SWMConfig(block_size=8))
    return cfg, HybridDecoderLM(cfg)


def _tree_allclose(a, b):
    la = jax.tree.leaves(a)
    lb = jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_allclose(np.asarray(x, np.float32),
                                   np.asarray(y, np.float32), rtol=1e-6)


def test_checkpoint_roundtrip_and_latest():
    cfg, model = _tiny()
    state = init_train_state(init_params(model.specs(), 0), TrainConfig())
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 3, state)
        save_checkpoint(d, 7, state)
        assert latest_step(d) == 7
        restored = restore_checkpoint(d, 7)
        _tree_allclose(state, restored)


def test_checkpoint_atomic_no_partial_visible():
    cfg, model = _tiny()
    state = init_train_state(init_params(model.specs(), 0), TrainConfig())
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 1, state)
        # a stale tmp dir from a "crashed" writer must not be visible
        os.makedirs(os.path.join(d, "step_00000002.tmp"), exist_ok=True)
        assert latest_step(d) == 1
        restore_checkpoint(d, 1)


def test_async_checkpointer():
    cfg, model = _tiny()
    state = init_train_state(init_params(model.specs(), 0), TrainConfig())
    with tempfile.TemporaryDirectory() as d:
        ck = AsyncCheckpointer(d)
        ck.save(5, state)
        ck.wait()
        assert latest_step(d) == 5
        _tree_allclose(state, restore_checkpoint(d, 5))


def test_driver_restart_resumes_from_checkpoint():
    """Injected fault mid-run: driver must restore and finish all steps,
    and the result must equal an uninterrupted run (idempotent steps)."""
    cfg, model = _tiny()
    data = SyntheticLM(vocab=64, seq_len=16, batch=4)
    with tempfile.TemporaryDirectory() as d:
        tcfg = TrainConfig(learning_rate=1e-3, checkpoint_every=2,
                           checkpoint_dir=d, z_loss=0.0)
        step_fn = jax.jit(make_train_step(model, cfg, tcfg))
        state0 = init_train_state(init_params(model.specs(), 0), tcfg)

        faults = FaultInjector(fail_at=(5,))
        drv = TrainDriver(step_fn, tcfg, lambda s: data.batch_jax(s),
                          fault_injector=faults)
        final = drv.run(state0, n_steps=8)
        assert drv.restarts == 1

    with tempfile.TemporaryDirectory() as d2:
        tcfg2 = TrainConfig(learning_rate=1e-3, checkpoint_every=2,
                            checkpoint_dir=d2, z_loss=0.0)
        state0 = init_train_state(init_params(model.specs(), 0), tcfg2)
        drv2 = TrainDriver(step_fn, tcfg2, lambda s: data.batch_jax(s))
        clean = drv2.run(state0, n_steps=8)
    _tree_allclose(final["params"], clean["params"])


def test_straggler_watchdog_detects_and_escalates():
    wd = StragglerWatchdog(k=3.0, max_consecutive=2, warmup=3)
    for s in range(10):
        assert wd.observe(s, 0.10 + 0.001 * (s % 3)) == "ok"
    assert wd.observe(10, 1.0) == "slow"
    assert wd.observe(11, 1.0) == "escalate"
    assert any(e[2] == "escalate" for e in wd.events)
    # recovery: normal steps reset the consecutive counter
    assert wd.observe(12, 0.1) == "ok"


def test_elastic_restore_new_topology():
    """Save on one 'mesh', restore with different shardings (here: host →
    device roundtrip with explicit single-device shardings)."""
    cfg, model = _tiny()
    state = init_train_state(init_params(model.specs(), 0), TrainConfig())
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    shardings = jax.tree.map(
        lambda _: NamedSharding(mesh, P()), state)
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 1, state)
        restored = restore_checkpoint(d, 1, shardings=shardings, mesh=mesh)
        _tree_allclose(state, restored)
        leaf = jax.tree.leaves(restored)[0]
        assert isinstance(leaf.sharding, NamedSharding)


def test_crash_between_shards_and_rename_is_invisible(monkeypatch):
    """A writer that dies after the shard writes but before the directory
    rename must leave the previous checkpoint as LATEST and only a .tmp
    corpse behind — and the next successful save must sweep that corpse."""
    cfg, model = _tiny()
    state = init_train_state(init_params(model.specs(), 0), TrainConfig())
    fired = []
    real_rename = os.rename

    def flaky_rename(src, dst):
        if str(src).endswith(".tmp") and not fired:
            fired.append(src)
            raise OSError("injected crash between shard writes and rename")
        real_rename(src, dst)

    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 1, state)
        monkeypatch.setattr("repro.ft.checkpoint.os.rename", flaky_rename)
        with pytest.raises(OSError, match="injected crash"):
            save_checkpoint(d, 2, state)
        tmp = os.path.join(d, "step_00000002.tmp")
        assert latest_step(d) == 1, "half-written step must not be LATEST"
        assert os.path.isdir(tmp), "crash leaves the tmp corpse behind"
        assert not os.path.isdir(os.path.join(d, "step_00000002"))
        _tree_allclose(state, restore_checkpoint(d, 1))
        # next save (the injector fires only once) sweeps the stale corpse
        save_checkpoint(d, 3, state)
        assert latest_step(d) == 3
        assert not os.path.exists(tmp), "stale .tmp dirs must be swept"


def test_fault_injector_seed_reproduces_pattern():
    """Same seed -> the exact same random-fault pattern; a fired step is
    passed on replay (so a restarted run survives the step it died on)."""

    def pattern(seed):
        inj = FaultInjector(p_fail=0.3, seed=seed)
        out = []
        for s in range(64):
            try:
                inj.maybe_fire(s)
                out.append(False)
            except RuntimeError:
                out.append(True)
        return inj, out

    inj_a, a = pattern(7)
    _, b = pattern(7)
    assert a == b, "seeded fault pattern must be reproducible"
    assert any(a) and not all(a)
    _, c = pattern(8)
    assert c != a, "different seeds must give different patterns"
    replay = next(s for s, f in enumerate(a) if f)
    inj_a.maybe_fire(replay)          # fired step passes on replay


def test_straggler_watchdog_warmup_tolerates_outliers():
    """Warmup observations never flag (compile steps are slow by nature);
    once stats stabilize, a genuine straggler run trips escalation."""
    wd = StragglerWatchdog(k=3.0, max_consecutive=2, warmup=4)
    assert wd.observe(0, 0.1) == "ok"
    assert wd.observe(1, 60.0) == "ok"      # huge outlier inside warmup
    assert wd.observe(2, 0.1) == "ok"
    assert wd.observe(3, 0.1) == "ok"
    assert wd.events == []

    wd2 = StragglerWatchdog(k=3.0, max_consecutive=2, warmup=3)
    for s in range(6):
        assert wd2.observe(s, 0.1 + 0.001 * (s % 2)) == "ok"
    assert wd2.observe(6, 5.0) == "slow"
    assert wd2.observe(7, 5.0) == "escalate"
    assert [e[2] for e in wd2.events] == ["slow", "slow", "escalate"]
