"""Flash (chunked lazy-softmax) attention vs direct attention, all masks."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.nn.attention import _direct_attention, flash_attention

jax.config.update("jax_platform_name", "cpu")


def _mk(B=2, Sq=64, Skv=64, HKV=2, G=2, hd=8, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, Sq, HKV, G, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, Skv, HKV, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, Skv, HKV, hd), jnp.float32)
    qp = jnp.broadcast_to(jnp.arange(Sq), (B, Sq))
    kp = jnp.broadcast_to(jnp.arange(Skv), (B, Skv))
    return q, k, v, qp, kp


@pytest.mark.parametrize("causal,window,prefix", [
    (True, 0, 0), (True, 16, 0), (True, 0, 10), (False, 0, 0),
])
def test_flash_matches_direct(causal, window, prefix):
    q, k, v, qp, kp = _mk()
    out_f = flash_attention(q, k, v, qp, kp, causal=causal, window=window,
                            prefix_len=prefix, q_chunk=16, kv_chunk=16)
    out_d = _direct_attention(q, k, v, qp, kp, causal=causal, window=window,
                              prefix_len=prefix, softcap=0.0)
    np.testing.assert_allclose(np.asarray(out_f), np.asarray(out_d),
                               rtol=2e-5, atol=2e-5)


def test_flash_softcap():
    q, k, v, qp, kp = _mk(seed=3)
    out_f = flash_attention(q, k, v, qp, kp, causal=True, softcap=20.0,
                            q_chunk=16, kv_chunk=32)
    out_d = _direct_attention(q, k, v, qp, kp, causal=True, window=0,
                              prefix_len=0, softcap=20.0)
    np.testing.assert_allclose(np.asarray(out_f), np.asarray(out_d),
                               rtol=2e-5, atol=2e-5)


@given(st.integers(1, 3), st.sampled_from([17, 33, 64]),
       st.sampled_from([8, 16, 48]))
@settings(max_examples=10, deadline=None)
def test_flash_ragged_chunk_property(B, Sq, chunk):
    """Padding/chunking must never change the result (property)."""
    q, k, v, qp, kp = _mk(B=B, Sq=Sq, Skv=Sq)
    ref = _direct_attention(q, k, v, qp, kp, causal=True, window=0,
                            prefix_len=0, softcap=0.0)
    out = flash_attention(q, k, v, qp, kp, causal=True,
                          q_chunk=chunk, kv_chunk=chunk)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=3e-5, atol=3e-5)


def test_invalid_slots_masked():
    """pos=-1 cache slots must contribute zero attention weight."""
    q, k, v, qp, kp = _mk(Skv=32)
    kp_invalid = kp.at[:, 16:].set(-1)
    out = _direct_attention(q, k, v, qp, kp_invalid, causal=False, window=0,
                            prefix_len=0, softcap=0.0)
    out_ref = _direct_attention(q[:, :, :, :, :], k[:, :16], v[:, :16],
                                qp, kp[:, :16], causal=False, window=0,
                                prefix_len=0, softcap=0.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_ref),
                               rtol=1e-5, atol=1e-5)


@given(st.sampled_from([32, 64, 100]), st.sampled_from([8, 16, 32]),
       st.sampled_from([16, 32]))
@settings(max_examples=12, deadline=None)
def test_windowed_span_slicing_property(S, window, chunk):
    """The KV-span-sliced windowed flash must equal direct attention for
    arbitrary (S, window, chunk) combinations (covers span < padded-KV)."""
    q, k, v, qp, kp = _mk(B=2, Sq=S, Skv=S)
    ref = _direct_attention(q, k, v, qp, kp, causal=True, window=window,
                            prefix_len=0, softcap=0.0)
    out = flash_attention(q, k, v, qp, kp, causal=True, window=window,
                          q_chunk=chunk, kv_chunk=chunk)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=3e-5, atol=3e-5)
