"""Cross-layer shape conformance: kernel == plan == multi-plan == oracle.

Pins `block_circulant_matmul` / `BCPlan` / `build_multi_plan` against the
dense oracle (`ref.block_circulant_matmul_ref`) over a (p, q, k, B) grid
that includes the shapes serving actually produces: odd k, k=1 (degenerate
1x1 circulant blocks), block grids that don't divide the tile sizes, B=1
decode shapes, and Linear layers whose dims don't admit the requested block
size. Everything runs the Pallas kernel in interpret mode (CPU container).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.block_circulant import (block_circulant_matmul,
                                           block_circulant_matmul_multi,
                                           build_multi_plan, build_plan,
                                           freq_weights)
from repro.kernels.block_circulant.ref import (block_circulant_matmul_ref,
                                               blocks_to_dense)

jax.config.update("jax_platform_name", "cpu")

REL_TOL = 2e-5          # fp32 kernel vs fp32 dense oracle


def _rand(shape, seed=0, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(seed), shape,
                             jnp.float32) * scale


def _relerr(y, y_ref):
    return float(jnp.max(jnp.abs(y - y_ref)) /
                 jnp.maximum(jnp.max(jnp.abs(y_ref)), 1e-6))


# k: power-of-two, even non-pow2, odd, and the k=1 degenerate case
K_GRID = (1, 2, 5, 8, 12)
# (p, q): square-minimal, rectangular, and p > q (output-heavy)
PQ_GRID = ((1, 1), (2, 3), (5, 2))
B_GRID = (1, 4)        # B=1 is the decode shape


# ---------------------------------------------------------------------------
# Kernel vs dense oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("k", K_GRID)
@pytest.mark.parametrize("p,q", PQ_GRID)
@pytest.mark.parametrize("B", B_GRID)
def test_kernel_matches_oracle(B, p, q, k):
    w = _rand((p, q, k), seed=1, scale=(q * k) ** -0.5)
    x = _rand((B, q * k), seed=2)
    y = block_circulant_matmul(x, w)
    y_ref = block_circulant_matmul_ref(x, w)
    assert y.shape == y_ref.shape == (B, p * k)
    assert _relerr(y, y_ref) <= REL_TOL


@pytest.mark.parametrize("k", (1, 5, 12))
def test_frozen_freq_path_matches_oracle(k):
    """The w_freq path with explicit k (odd k makes K ambiguous) — the exact
    form serving uses after freeze_params."""
    p, q, B = 3, 2, 4
    w = _rand((p, q, k), seed=1, scale=(q * k) ** -0.5)
    x = _rand((B, q * k), seed=2)
    y = block_circulant_matmul(x, None, w_freq=freq_weights(w), k=k, q=q)
    assert _relerr(y, block_circulant_matmul_ref(x, w)) <= REL_TOL


# ---------------------------------------------------------------------------
# Plans vs oracle (and bitwise vs the per-call kernel)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("k", K_GRID)
@pytest.mark.parametrize("p,q", PQ_GRID)
def test_plan_matches_oracle_and_kernel(p, q, k):
    B = 3
    w = _rand((p, q, k), seed=1, scale=(q * k) ** -0.5)
    x = _rand((B, q * k), seed=2)
    plan = build_plan(w)
    y_plan = plan.apply(x)
    assert _relerr(y_plan, block_circulant_matmul_ref(x, w)) <= REL_TOL
    # the plan's frozen geometry must not change the math vs the per-call op
    assert bool(jnp.all(y_plan == block_circulant_matmul(x, w)))


@pytest.mark.parametrize("k", (1, 5, 8))
def test_multi_plan_matches_per_projection(k):
    """Stacked-p fusion over mixed widths, including B=1 decode shape."""
    q, ps = 2, (2, 1, 3)
    ws = [_rand((p, q, k), seed=10 + i, scale=(q * k) ** -0.5)
          for i, p in enumerate(ps)]
    mp = build_multi_plan(ws)
    for B in (1, 4):
        x = _rand((B, q * k), seed=20 + B)
        outs = mp.apply_multi(x)
        fused = block_circulant_matmul_multi(x, ws)
        for y, y_fused, w in zip(outs, fused, ws):
            y_ref = block_circulant_matmul_ref(x, w)
            assert _relerr(y, y_ref) <= REL_TOL
            assert _relerr(y_fused, y_ref) <= REL_TOL


@pytest.mark.parametrize("k", (1, 5, 8))
def test_b1_decode_shape_with_leading_dims(k):
    """Decode calls arrive as (B, 1, d) — leading dims must pass through."""
    p, q = 2, 3
    w = _rand((p, q, k), seed=1, scale=(q * k) ** -0.5)
    x = _rand((1, 1, q * k), seed=2)
    y = block_circulant_matmul(x, w)
    assert y.shape == (1, 1, p * k)
    y_ref = block_circulant_matmul_ref(x.reshape(1, -1), w)
    assert _relerr(y.reshape(1, -1), y_ref) <= REL_TOL


# ---------------------------------------------------------------------------
# Linear-level: dims that don't admit the requested block size
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("in_dim,out_dim,requested,expect_k", [
    (20, 12, 8, 4),     # gcd fallback: 8 -> 4
    (9, 6, 8, 3),       # odd fallback: 8 -> 3
    (7, 5, 8, 1),       # coprime dims -> dense layout (k=1)
])
def test_linear_non_divisible_dims(in_dim, out_dim, requested, expect_k):
    from repro.configs.base import SWMConfig
    from repro.nn.linear import Linear
    from repro.nn.module import init_params

    lin = Linear(in_dim=in_dim, out_dim=out_dim, family="ffn",
                 swm=SWMConfig(block_size=requested, impl="pallas"),
                 dtype="float32")
    assert lin.block_size == expect_k
    params = init_params(lin.specs(), 0)
    x = _rand((4, in_dim), seed=2)
    y = lin(params, x)
    assert y.shape == (4, out_dim)
    if lin.is_circulant:
        W = blocks_to_dense(params["w"].astype(jnp.float32))
        y_ref = x @ W.T
    else:
        y_ref = x @ params["w"].astype(jnp.float32)
    assert _relerr(y, y_ref) <= REL_TOL
