"""Adafactor: factored state shapes, sharding-compatible specs, convergence."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, SWMConfig, TrainConfig
from repro.data.pipeline import SyntheticLM
from repro.models.decoder import HybridDecoderLM
from repro.nn.module import flatten_with_paths, init_params, param_count
from repro.optim.optimizers import (adafactor_init, adafactor_state_specs,
                                    adafactor_update, adamw_state_specs)
from repro.train.loop import init_train_state, make_train_step

jax.config.update("jax_platform_name", "cpu")


def _cfg():
    return ModelConfig(name="t", n_layers=2, d_model=64, n_heads=4,
                       n_kv_heads=2, head_dim=16, d_ff=128, vocab=64,
                       remat="none", param_dtype="float32",
                       compute_dtype="float32", optimizer="adafactor",
                       swm=SWMConfig(block_size=8, impl="dft"))


def test_factored_state_is_small():
    """Adafactor state must be O(r+c) per matrix, not O(r·c)."""
    model = HybridDecoderLM(_cfg())
    pspecs = model.specs()
    tcfg = TrainConfig()
    af = adafactor_state_specs(pspecs, tcfg)
    aw = adamw_state_specs(pspecs, tcfg)
    n_af = param_count(af["vr"]) + param_count(af["vc"])
    n_aw = param_count(aw["m"]) + param_count(aw["v"])
    assert n_af < 0.2 * n_aw, (n_af, n_aw)
    # axes preserved for the sharding rule table
    for path, spec in flatten_with_paths(af["vr"]):
        assert len(spec.axes) == len(spec.shape)


def test_adafactor_trains():
    cfg = _cfg()
    tcfg = TrainConfig(learning_rate=2e-2, warmup_steps=5, z_loss=0.0)
    model = HybridDecoderLM(cfg)
    state = init_train_state(init_params(model.specs(), 0), tcfg,
                             optimizer="adafactor")
    step = jax.jit(make_train_step(model, cfg, tcfg), donate_argnums=0)
    data = SyntheticLM(vocab=64, seq_len=32, batch=16)
    losses = []
    for s in range(40):
        state, m = step(state, data.batch_jax(s))
        losses.append(float(m["loss"]))
    assert all(np.isfinite(l) for l in losses)
    assert losses[-1] < losses[0] - 0.5, (losses[0], losses[-1])
