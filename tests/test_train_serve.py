"""Training loop behaviour + serving engine end-to-end."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig, SWMConfig, TrainConfig
from repro.data.pipeline import SyntheticLM
from repro.models.decoder import HybridDecoderLM
from repro.nn.module import init_params
from repro.optim.optimizers import lr_schedule
from repro.serve.engine import Request, ServeEngine
from repro.train.loop import init_train_state, make_train_step

jax.config.update("jax_platform_name", "cpu")


def _cfg(**kw):
    base = dict(name="t", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                head_dim=16, d_ff=128, vocab=64, remat="none",
                param_dtype="float32", compute_dtype="float32",
                swm=SWMConfig(block_size=8, impl="dft"))
    base.update(kw)
    return ModelConfig(**base)


def test_loss_decreases():
    cfg = _cfg()
    tcfg = TrainConfig(learning_rate=1e-2, warmup_steps=5, total_steps=60,
                       z_loss=0.0)
    model = HybridDecoderLM(cfg)
    state = init_train_state(init_params(model.specs(), 0), tcfg)
    step = jax.jit(make_train_step(model, cfg, tcfg), donate_argnums=0)
    data = SyntheticLM(vocab=64, seq_len=32, batch=16)
    losses = []
    for s in range(40):
        state, m = step(state, data.batch_jax(s))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5
    assert all(np.isfinite(l) for l in losses)


def test_microbatch_loss_matches_full_batch():
    cfg = _cfg()
    model = HybridDecoderLM(cfg)
    data = SyntheticLM(vocab=64, seq_len=32, batch=16)
    batch = data.batch_jax(0)
    losses = {}
    for mb in (0, 4):
        tcfg = TrainConfig(learning_rate=1e-2, microbatch=mb, z_loss=0.0)
        state = init_train_state(init_params(model.specs(), 0), tcfg)
        step = jax.jit(make_train_step(model, cfg, tcfg))
        _, m = step(state, batch)
        losses[mb] = float(m["loss"])
    assert losses[0] == pytest.approx(losses[4], rel=1e-4)


def test_grad_clip_caps_update():
    from repro.optim.optimizers import clip_by_global_norm
    g = {"a": jnp.full((10,), 100.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    from repro.optim.optimizers import global_norm
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)
    assert float(norm) > 100


def test_lr_schedule_shape():
    tcfg = TrainConfig(learning_rate=1e-3, warmup_steps=10, total_steps=100)
    lrs = [float(lr_schedule(tcfg, jnp.asarray(s))) for s in range(0, 101, 10)]
    assert lrs[0] == 0.0
    assert max(lrs) == pytest.approx(1e-3, rel=1e-3)
    assert lrs[-1] < 0.2 * 1e-3 + 1e-9


def test_serve_engine_greedy_matches_forward():
    """Engine's greedy decode must equal argmax over the full forward."""
    cfg = _cfg()
    model = HybridDecoderLM(cfg)
    params = init_params(model.specs(), 0)
    engine = ServeEngine(model, cfg, params, batch=2, cache_len=32)
    prompts = [np.array([3, 9, 27], np.int32),
               np.array([5, 10, 15, 20], np.int32)]
    outs = engine.generate([Request(p, max_new=4) for p in prompts])
    for p, o in zip(prompts, outs):
        seq = list(p)
        for t in range(4):
            logits, _, _ = model.forward(
                params, jnp.asarray(np.array(seq, np.int32))[None])
            nxt = int(jnp.argmax(logits[0, -1]))
            assert nxt == o[t], (seq, o)
            seq.append(nxt)


def test_serve_engine_batches_more_requests_than_slots():
    cfg = _cfg()
    model = HybridDecoderLM(cfg)
    params = init_params(model.specs(), 0)
    engine = ServeEngine(model, cfg, params, batch=2, cache_len=32)
    reqs = [Request(np.array([i + 1, i + 2], np.int32), max_new=3)
            for i in range(5)]
    outs = engine.generate(reqs)
    assert len(outs) == 5 and all(len(o) == 3 for o in outs)
