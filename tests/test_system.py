"""End-to-end system tests: the full train→checkpoint→restart→serve cycle
on a compressed (SWM) model — the paper's technique exercised through every
framework layer at once."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, SWMConfig, TrainConfig
from repro.data.pipeline import SyntheticLM
from repro.ft.driver import FaultInjector, TrainDriver
from repro.launch.specs import count_params
from repro.models.decoder import HybridDecoderLM
from repro.nn.module import init_params
from repro.serve.engine import Request, ServeEngine
from repro.train.loop import init_train_state, make_train_step

jax.config.update("jax_platform_name", "cpu")


def test_full_lifecycle_train_crash_restart_serve():
    cfg = ModelConfig(
        name="e2e", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        head_dim=16, d_ff=128, vocab=64, remat="none",
        param_dtype="float32", compute_dtype="float32",
        swm=SWMConfig(block_size=8, impl="dft"),
    )
    model = HybridDecoderLM(cfg)
    counts = count_params(cfg)
    assert counts["compression"] > 2.0     # the paper's storage claim

    with tempfile.TemporaryDirectory() as d:
        tcfg = TrainConfig(learning_rate=5e-3, warmup_steps=5,
                           total_steps=30, checkpoint_every=5,
                           checkpoint_dir=d, z_loss=0.0)
        data = SyntheticLM(vocab=64, seq_len=32, batch=16)
        step = jax.jit(make_train_step(model, cfg, tcfg), donate_argnums=0)
        state = init_train_state(init_params(model.specs(), 0), tcfg)

        driver = TrainDriver(step, tcfg, lambda s: data.batch_jax(s),
                             fault_injector=FaultInjector(fail_at=(12,)))
        state = driver.run(state, n_steps=30)
        assert driver.restarts == 1
        losses = [m["loss"] for m in driver.metrics_log]
        assert losses[-1] < losses[0]

        # serve from the trained params
        engine = ServeEngine(model, cfg, state["params"], batch=2,
                             cache_len=64)
        outs = engine.generate(
            [Request(np.array([3, 7, 12], np.int32), max_new=5)])
        assert len(outs[0]) == 5
        assert all(0 <= t < 64 for t in outs[0])


def test_swm_and_dense_models_share_the_framework():
    """Same config ± SWM: both must train; SWM must be smaller."""
    mk = lambda k: ModelConfig(
        name="x", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        head_dim=16, d_ff=128, vocab=64, remat="none",
        param_dtype="float32", compute_dtype="float32",
        swm=SWMConfig(block_size=k, impl="dft"))
    from repro.nn.module import param_count
    tcfg = TrainConfig(learning_rate=1e-2, z_loss=0.0)
    data = SyntheticLM(vocab=64, seq_len=16, batch=8)
    sizes = {}
    for k in (0, 16):
        cfg = mk(k)
        model = HybridDecoderLM(cfg)
        sizes[k] = param_count(model.specs())
        state = init_train_state(init_params(model.specs(), 0), tcfg)
        step = jax.jit(make_train_step(model, cfg, tcfg))
        state, m = step(state, data.batch_jax(0))
        assert np.isfinite(float(m["loss"]))
    assert sizes[0] > 3 * sizes[16]
