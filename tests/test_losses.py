"""Chunked CE must equal direct CE; z-loss and masking semantics."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.train.losses import chunked_cross_entropy, softmax_cross_entropy

jax.config.update("jax_platform_name", "cpu")


def _setup(B=2, S=24, D=16, V=50, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    h = jax.random.normal(ks[0], (B, S, D), jnp.float32)
    t = jax.random.normal(ks[1], (V, D), jnp.float32)
    l = jax.random.randint(ks[2], (B, S), 0, V)
    return h, t, l


@given(st.sampled_from([1, 4, 7, 24, 100]), st.floats(0, 1e-3))
@settings(max_examples=12, deadline=None)
def test_chunked_equals_direct(chunk, z):
    h, t, l = _setup()
    logits = jnp.einsum("bsd,vd->bsv", h, t)
    direct, _ = softmax_cross_entropy(logits, l, z_loss=z)
    chunked, _ = chunked_cross_entropy(h, t, l, z_loss=z, chunk=chunk)
    np.testing.assert_allclose(float(direct), float(chunked), rtol=1e-5)


def test_mask_semantics():
    h, t, l = _setup()
    mask = jnp.zeros((2, 24)).at[:, :10].set(1.0)
    full, _ = chunked_cross_entropy(h[:, :10], t, l[:, :10], chunk=5)
    masked, _ = chunked_cross_entropy(h, t, l, mask=mask, chunk=5)
    np.testing.assert_allclose(float(full), float(masked), rtol=1e-5)


def test_grads_flow_and_match():
    h, t, l = _setup(S=8)
    logits_loss = lambda h: softmax_cross_entropy(
        jnp.einsum("bsd,vd->bsv", h, t), l)[0]
    chunk_loss = lambda h: chunked_cross_entropy(h, t, l, chunk=4)[0]
    g1 = jax.grad(logits_loss)(h)
    g2 = jax.grad(chunk_loss)(h)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                               rtol=1e-4, atol=1e-5)
