"""Paper's own models: circulant conv oracle, SWM-LSTM, quantization STE."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SWMConfig
from repro.core.conv import CirculantConv2D
from repro.core.lstm import SWMLSTM
from repro.core.quant import fixed_point, quantize_tree
from repro.models.paper_models import ASICNet, SWMCNN, SWMLSTMASR, SWMMLP
from repro.nn.module import flatten_with_paths, init_params, param_count

jax.config.update("jax_platform_name", "cpu")


def test_circulant_conv_matches_dense_expansion():
    """k>1 conv must equal a dense conv whose taps are circulant blocks."""
    conv = CirculantConv2D(in_ch=8, out_ch=8, ksize=3, block_size=4)
    params = init_params(conv.specs(), 0)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 10, 10, 8))
    y = conv(params, x)
    # dense expansion of each tap's block table
    from repro.core.circulant import blocks_to_dense
    w = params["w"]                                    # (9, p, q, k)
    taps = [blocks_to_dense(w[t]) for t in range(9)]   # each (P, C)
    patches = jnp.stack(
        [x[:, i:i + 8, j:j + 8, :] for i in range(3) for j in range(3)],
        axis=3)
    y_ref = jnp.einsum("bhwtc,tpc->bhwp", patches,
                       jnp.stack(taps)) + params["b"]
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)


def test_conv_param_reduction():
    dense = CirculantConv2D(in_ch=16, out_ch=16, ksize=3, block_size=1)
    swm = CirculantConv2D(in_ch=16, out_ch=16, ksize=3, block_size=8)
    assert param_count(dense.specs()) > 7 * param_count(swm.specs())


def test_swm_lstm_shapes_and_state():
    cell = SWMLSTM(d_in=24, d_cell=32, d_proj=16,
                   swm=SWMConfig(block_size=8, targets=("lstm",)))
    params = init_params(cell.specs(), 0)
    xs = jax.random.normal(jax.random.PRNGKey(0), (3, 10, 24))
    ys, (yT, cT) = cell(params, xs)
    assert ys.shape == (3, 10, 16) and cT.shape == (3, 32)
    assert bool(jnp.isfinite(ys).all())
    # stepwise equals scan
    y, c = jnp.zeros((3, 16)), jnp.zeros((3, 32))
    for t in range(10):
        y, c = cell.step(params, xs[:, t], y, c)
        np.testing.assert_allclose(np.asarray(y), np.asarray(ys[:, t]),
                                   rtol=1e-5, atol=1e-5)


def test_lstm_compression_matches_paper_ratios():
    """Gate matrices are k× smaller; whole-model ratio between 1 and k."""
    dense = param_count(SWMLSTMASR(block_size=0).specs())
    for k, lo in ((8, 5.0), (16, 8.0)):
        n = param_count(SWMLSTMASR(block_size=k).specs())
        assert dense / n > lo, (k, dense / n)


def test_fixed_point_quantization():
    x = jnp.asarray([0.1234567, -1.5, 100.0, -100.0])
    q = fixed_point(x, bits=12, frac_bits=8)
    # representable grid 1/256, clipped to ±(2^11)/256 = ±8
    assert float(q[2]) == pytest.approx(2047 / 256)
    assert float(q[3]) == pytest.approx(-2048 / 256)
    np.testing.assert_allclose(float(q[0]), round(0.1234567 * 256) / 256)
    # clipped straight-through gradient: identity inside the representable
    # range, ZERO where the forward saturated at the rails (a weight pinned
    # at the rail can't express the update the raw STE would feed it)
    g = jax.grad(lambda x: fixed_point(x, 12, 8).sum())(x)
    np.testing.assert_allclose(np.asarray(g), [1.0, 1.0, 0.0, 0.0])


@pytest.mark.parametrize("bits", (8, 12, 16))
def test_fixed_point_clipped_ste_bitwidth_sweep(bits):
    """Gradient mask tracks the rails across bit widths: the narrower the
    format, the more of the real line is saturated and gradient-free."""
    frac = bits - 4
    scale = 2.0 ** frac
    lo = -(2 ** (bits - 1)) / scale
    hi = (2 ** (bits - 1) - 1) / scale
    x = jnp.asarray([lo - 1.0, lo, lo / 2, 0.0, hi / 2, hi, hi + 1.0])
    t = jnp.asarray([3.0, -2.0, 1.0, 5.0, -1.0, 2.0, 4.0])
    g = jax.grad(lambda x: (fixed_point(x, bits, frac) * t).sum())(x)
    expect = np.asarray(t) * np.asarray([0, 1, 1, 1, 1, 1, 0], np.float32)
    np.testing.assert_allclose(np.asarray(g), expect)
    # the forward is unchanged by the bwd fix: rails still clip
    q = fixed_point(x, bits, frac)
    assert float(q[0]) == lo and float(q[-1]) == hi
    # quantize_tree inherits the clipped STE on every floating leaf
    gt = jax.grad(
        lambda tr: (quantize_tree(tr, bits, frac)["w"] * t).sum())({"w": x})
    np.testing.assert_allclose(np.asarray(gt["w"]), expect)


def test_asic_net_structure():
    """Table 2: weight structure 8×8×64 / 8×8×64 / 1×8×64 / dense 64×10."""
    net = ASICNet()
    shapes = [s.shape for p, s in flatten_with_paths(net.specs())
              if p[-1] == "w"]
    assert (8, 8, 64) in shapes and (1, 8, 64) in shapes
    assert (64, 10) in shapes          # output layer stays dense (paper)
    params = init_params(net.specs(), 0)
    y = net(params, jax.random.normal(jax.random.PRNGKey(0), (4, 512)))
    assert y.shape == (4, 10) and bool(jnp.isfinite(y).all())


def test_cnn_forward():
    cnn = SWMCNN()
    params = init_params(cnn.specs(), 0)
    y = cnn(params, jax.random.normal(jax.random.PRNGKey(0), (2, 28, 28, 1)))
    assert y.shape == (2, 10) and bool(jnp.isfinite(y).all())
