"""Ring-buffer KV cache wraparound: decode far past the cache length on a
sliding-window model must keep matching the full-context forward — the
small-scale proof of the gemma3 long_500k mechanism (local layers hold
window-sized caches while decoding 500k+ positions)."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.configs.base import ModelConfig, SWMConfig
from repro.models.decoder import HybridDecoderLM
from repro.nn.module import init_params

jax.config.update("jax_platform_name", "cpu")


def _model(window=6, pattern=5):
    cfg = ModelConfig(
        name="ring", n_layers=6, d_model=32, n_heads=2, n_kv_heads=2,
        head_dim=16, d_ff=64, vocab=64, sliding_window=window,
        local_global_pattern=pattern, remat="none",
        param_dtype="float32", compute_dtype="float32",
        swm=SWMConfig(block_size=8, impl="dft"),
    )
    m = HybridDecoderLM(cfg)
    return cfg, m, init_params(m.specs(), 0)


def test_decode_wraps_ring_buffer_many_times():
    """Decode to 4× the local cache length; every step must equal the
    full forward (local layers' ring buffers wrap repeatedly)."""
    cfg, m, p = _model(window=6)
    B, S = 2, 26                       # local cache_len = 6 -> wraps 4x
    toks = jax.random.randint(jax.random.PRNGKey(0), (B, S), 0, cfg.vocab)
    full, _, _ = m.forward(p, toks)
    cache = m.init_cache(B, S)         # global layers full-length; locals=6
    Sp = 2
    _, cache = m.prefill(p, toks[:, :Sp], cache)
    for t in range(Sp, S):
        pos = jnp.full((B,), t, jnp.int32)
        lg, cache = m.decode_step(p, toks[:, t:t + 1], cache, pos)
        np.testing.assert_allclose(
            np.asarray(lg), np.asarray(full[:, t]), rtol=2e-4, atol=2e-4,
            err_msg=f"divergence at position {t}")


def test_local_cache_is_window_sized():
    cfg, m, p = _model(window=6)
    cache = m.init_cache(2, 1000)
    # group0 = 6-layer pattern (5 local + 1 global)
    g0 = cache[0]
    assert g0["l0"]["k"].shape[1] == 6        # local: ring of window size
    assert g0["l5"]["k"].shape[1] == 1000     # global: full length


@given(st.integers(3, 10), st.integers(12, 30))
@settings(max_examples=6, deadline=None)
def test_wraparound_property(window, S):
    """Arbitrary (window, S) combinations: prefill+decode == full forward."""
    cfg, m, p = _model(window=window)
    B = 1
    toks = jax.random.randint(jax.random.PRNGKey(window * 100 + S),
                              (B, S), 0, cfg.vocab)
    full, _, _ = m.forward(p, toks)
    cache = m.init_cache(B, S)
    Sp = max(1, S // 3)
    _, cache = m.prefill(p, toks[:, :Sp], cache)
    for t in range(Sp, S):
        pos = jnp.full((B,), t, jnp.int32)
        lg, cache = m.decode_step(p, toks[:, t:t + 1], cache, pos)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(full[:, -1]),
                               rtol=3e-4, atol=3e-4)
