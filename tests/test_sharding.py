"""Sharding-rule unit tests (mesh mocked — no 512 devices needed here;
the real multi-device pass is launch/dryrun.py)."""

import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.dist import sharding as sh
from repro.nn.module import ParamSpec


class FakeMesh:
    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)


MESH1 = FakeMesh({"data": 16, "model": 16})
MESH2 = FakeMesh({"pod": 2, "data": 16, "model": 16})


def _pspec(axes, shape, mesh=MESH1, fsdp=False):
    rules = sh.make_param_rules(mesh, fsdp)
    return sh.spec_to_pspec(axes, shape, rules, mesh)


def test_tp_rules():
    assert _pspec(("embed", "mlp"), (4096, 16384)) == P(None, "model")
    assert _pspec(("mlp", "embed"), (16384, 4096)) == P("model", None)
    assert _pspec(("vocab", "embed"), (151936, 1024)) == P("model", None)


def test_circulant_tables_inherit_dense_axes():
    # (p, q, k) with (out=mlp, in=embed, None)
    assert _pspec(("mlp", "embed", None), (128, 32, 128)) == P("model", None, None)


def test_non_divisible_dims_dropped():
    # 92544 % 16 == 0 but 10 % 16 != 0 -> dropped
    assert _pspec(("vocab", None), (10, 4)) == P(None, None)
    # kv_heads = 8 not divisible by model=16 -> replicated
    assert _pspec(("embed", "kv_heads"), (1024, 8)) == P(None, None)


def test_axis_never_reused():
    spec = _pspec(("experts", "embed", "mlp"), (128, 7168, 4864))
    # experts takes 'model'; mlp cannot reuse it
    assert spec == P("model", None, None)


def test_fsdp_adds_data_axis():
    spec = _pspec(("experts", "embed", "mlp"), (128, 7168, 4864), fsdp=True)
    assert spec == P("model", "data", None)


def test_multipod_batch_axes():
    assert sh.data_axes(MESH2) == ("pod", "data")
    bp = sh.batch_pspec(MESH2, 2, batch=256)
    assert bp == P(("pod", "data"), None)
    # batch=1 (long_500k): replicate
    assert sh.batch_pspec(MESH2, 2, batch=1) == P(None, None)


def test_zero1_extends_moments():
    import jax.numpy as jnp
    import jax

    mesh = None
    # need a real mesh for NamedSharding; single-device (1,1) still
    # exercises the pspec construction path
    mesh = __import__("jax").make_mesh((1, 1), ("data", "model"))
    specs = {"w": ParamSpec((64, 128), jnp.float32, ("embed", "mlp"))}
    shards = sh.opt_shardings(mesh, specs, zero1=True)
    assert "data" in str(shards["w"].spec)
