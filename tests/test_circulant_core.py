"""Core SWM math: every implementation vs the dense oracle + properties."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import circulant as C

jax.config.update("jax_platform_name", "cpu")


def _rand(shape, seed=0):
    return jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32)


@pytest.mark.parametrize("impl", ["paper", "freq", "dft"])
@pytest.mark.parametrize("p,q,k", [(3, 5, 8), (2, 2, 128), (1, 3, 64),
                                   (4, 4, 16), (2, 2, 2), (2, 3, 5)])
def test_impls_match_dense(impl, p, q, k):
    w = _rand((p, q, k))
    x = _rand((4, q * k), seed=1)
    y_ref = x @ C.blocks_to_dense(w).T
    y = C.block_circulant_apply(x, w, impl=impl)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)


def test_karatsuba_matches():
    w, x = _rand((3, 4, 16)), _rand((5, 64), seed=2)
    y0 = C.block_circulant_matvec_dft(x, w, karatsuba=False)
    y1 = C.block_circulant_matvec_dft(x, w, karatsuba=True)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                               rtol=1e-4, atol=1e-5)


def test_frozen_freq_weights():
    """The paper stores FFT(w) in BRAM — frozen path must equal live path."""
    w, x = _rand((2, 3, 8)), _rand((4, 24), seed=3)
    wf = jnp.fft.rfft(w, axis=-1)
    y0 = C.block_circulant_matvec_freq(x, w)
    y1 = C.block_circulant_matvec_freq(x, None, w_freq=wf)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1), rtol=1e-6)


def test_lstsq_projection_roundtrip():
    w = _rand((3, 2, 8))
    W = C.blocks_to_dense(w)
    np.testing.assert_allclose(
        np.asarray(C.dense_to_blocks_lstsq(W, 8)), np.asarray(w), atol=1e-6
    )


def test_lstsq_is_frobenius_projection():
    """Projection residual must be orthogonal to the circulant subspace."""
    W = _rand((8, 8), seed=7)
    wb = C.dense_to_blocks_lstsq(W, 4)
    proj = C.blocks_to_dense(wb)
    resid = np.asarray(W - proj)
    # inner product of residual with any circulant basis element == 0
    for d in range(4):
        basis = np.zeros((4, 4))
        for a in range(4):
            basis[a, (a - d) % 4] = 1.0
        big = np.kron(np.ones((2, 2)), basis) * 0
        for i in range(2):
            for j in range(2):
                blk = resid[i * 4:(i + 1) * 4, j * 4:(j + 1) * 4]
                assert abs((blk * basis).sum()) < 1e-4


@given(st.integers(1, 4), st.integers(1, 4),
       st.sampled_from([2, 4, 8, 16]), st.integers(1, 6))
@settings(max_examples=20, deadline=None)
def test_linearity_property(p, q, k, batch):
    """f(ax+by) == a f(x) + b f(y): the layer is exactly linear."""
    w = _rand((p, q, k))
    x = _rand((batch, q * k), seed=4)
    y = _rand((batch, q * k), seed=5)
    f = lambda v: C.block_circulant_apply(v, w, impl="freq")
    lhs = f(2.0 * x - 3.0 * y)
    rhs = 2.0 * f(x) - 3.0 * f(y)
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs),
                               rtol=1e-3, atol=1e-3)


@given(st.integers(1, 3), st.integers(1, 3), st.sampled_from([2, 4, 8]))
@settings(max_examples=15, deadline=None)
def test_composition_is_matmul_property(p, q, k):
    """Composing two SWM layers == product of their dense expansions."""
    w1 = _rand((p, q, k), seed=1)
    w2 = _rand((q, p, k), seed=2)
    x = _rand((2, q * k), seed=3)
    y = C.block_circulant_apply(
        C.block_circulant_apply(x, w1, impl="freq"), w2, impl="freq")
    W = C.blocks_to_dense(w2) @ C.blocks_to_dense(w1)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ W.T),
                               rtol=2e-3, atol=2e-3)


def test_valid_block_size():
    assert C.valid_block_size(128, 11008, 4096) == 128
    assert C.valid_block_size(128, 300, 200) == 100
    assert C.valid_block_size(64, 64, 10) == 2
    assert C.valid_block_size(0, 64, 64) == 1
    assert C.valid_block_size(7, 49, 21) == 7


def test_storage_and_flops_accounting():
    """O(n²)→O(n) storage and ~k/4 FLOP cut (paper §3)."""
    m = n = 1024
    k = 64
    dense_params = m * n
    swm_params = (m // k) * (n // k) * k
    assert dense_params / swm_params == k
    f_dense = C.dense_flops(1, m, n)
    f_swm = C.swm_flops(1, m, n, k, impl="freq")
    assert f_dense / f_swm > k / 8  # comfortably super-linear reduction
    # paper dataflow does p×q IFFTs (more transforms than freq-accumulated)
    assert C.swm_flops(1, m, n, k, "paper") > C.swm_flops(1, m, n, k, "freq")


def test_gradients_match_dense():
    w = _rand((2, 3, 8))
    x = _rand((4, 24), seed=9)
    for impl in ("paper", "freq", "dft"):
        g_impl = jax.grad(
            lambda w: (C.block_circulant_apply(x, w, impl=impl) ** 2).sum()
        )(w)
        g_ref = jax.grad(
            lambda w: ((x @ C.blocks_to_dense(w).T) ** 2).sum()
        )(w)
        np.testing.assert_allclose(np.asarray(g_impl), np.asarray(g_ref),
                                   rtol=1e-3, atol=1e-3)


def test_freq_shmap_matches_without_mesh():
    """impl='freq_shmap' degrades to the plain path when no mesh is set."""
    from repro.dist.sharding import set_ambient_mesh
    set_ambient_mesh(None)
    w = _rand((3, 5, 8))
    x = _rand((4, 40), seed=11)
    y0 = C.block_circulant_apply(x, w, impl="freq")
    y1 = C.block_circulant_apply(x, w, impl="freq_shmap")
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1), rtol=1e-6)


def test_dft_custom_vjp_matches_karatsuba_grads():
    w = _rand((2, 3, 8))
    x = _rand((4, 24), seed=12)
    t = _rand((4, 16), seed=13)
    g0 = jax.grad(lambda w: (C.block_circulant_apply(x, w, impl="dft") * t).sum())(w)
    g1 = jax.grad(lambda w: (C.block_circulant_apply(
        x, w, impl="dft", karatsuba=True) * t).sum())(w)
    np.testing.assert_allclose(np.asarray(g0), np.asarray(g1), rtol=1e-4,
                               atol=1e-5)


def test_fused_pair_matches_separate():
    """wi/wu fused pair op (shared forward DFT) == two separate applies."""
    w1 = _rand((3, 5, 8), seed=20)
    w2 = _rand((4, 5, 8), seed=21)
    x = _rand((6, 40), seed=22)
    y1, y2 = C.block_circulant_apply_pair(x, w1, w2)
    np.testing.assert_allclose(
        np.asarray(y1), np.asarray(C.block_circulant_apply(x, w1, impl="dft")),
        rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(
        np.asarray(y2), np.asarray(C.block_circulant_apply(x, w2, impl="dft")),
        rtol=1e-4, atol=1e-5)
    # grads via the pair VJP vs dense autodiff
    t1 = _rand((6, 24), seed=23)
    t2 = _rand((6, 32), seed=24)

    def loss_pair(x, w1, w2):
        a, b = C.block_circulant_apply_pair(x, w1, w2)
        return (a * t1).sum() + (b * t2).sum()

    def loss_ref(x, w1, w2):
        a = x @ C.blocks_to_dense(w1).T
        b = x @ C.blocks_to_dense(w2).T
        return (a * t1).sum() + (b * t2).sum()

    gp = jax.grad(loss_pair, (0, 1, 2))(x, w1, w2)
    gr = jax.grad(loss_ref, (0, 1, 2))(x, w1, w2)
    for a, b in zip(gp, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-3, atol=1e-4)
