"""int8-quantized frozen frequency tables, end to end.

The quantization contract under test: ``freeze_params(quantize="int8")``
stores the frozen rfft(w) tables as int8 with one f32 symmetric scale per
(p, q) block (shared across the K bins and the re/im parts), and every
consumer — the Pallas kernel (dequant on the VMEM tile), the XLA freq
path, fused QKV/LSTM groups, the serving engines — produces outputs
BIT-identical to running the host-dequantized fp32 tables through the
fp32 path. int8 -> f32 * scale is exact, so quantized serving is not an
approximation of the fake-quantized weights; it IS them, at ~0.35x the
resident table bytes and an unchanged launch/compile budget.

Also pins the three quantization-path bugfixes that rode along:
``quantize_tree`` quantizing complex leaves (they used to escape the
float-dtype check) while exempting biases/norm scales; the dist
compressor preserving bf16 gradient dtypes through decompress and error
feedback; and the QAT train loop fake-quantizing params inside the loss.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig, SWMConfig, TrainConfig
from repro.core.quant import (default_exempt, dequantize_symmetric,
                              fake_quant_symmetric, fixed_point,
                              quantize_symmetric, quantize_tree,
                              symmetric_scales)
from repro.kernels.block_circulant import (block_circulant_matmul,
                                           build_plan, freq_weights)
from repro.analysis import NoFFT, QuantizedTableDtypes
from repro.kernels.block_circulant.ops import count_pallas_launches
from repro.kernels.block_circulant.plan import (FUSED_KEY, dequantize_frozen,
                                                freeze_params,
                                                frozen_table_bytes)
from repro.kernels.block_circulant.ref import block_circulant_matmul_ref
from repro.models.decoder import HybridDecoderLM
from repro.nn.module import init_params
from repro.serve.engine import Request, ServeEngine, WaveEngine

jax.config.update("jax_platform_name", "cpu")


def _rand(shape, seed=0):
    return jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32)


# ---------------------------------------------------------------------------
# 1. In-kernel int8 dequant vs the fake-quant fp32 oracle (conformance grid)
# ---------------------------------------------------------------------------

# odd k (5), non-power-of-two k (12), k=1 degenerate blocks, B=1 rows
GRID = [(1, 1, 1, 5), (4, 2, 3, 8), (1, 5, 2, 12), (4, 2, 2, 1),
        (4, 3, 5, 8)]


@pytest.mark.parametrize("B,p,q,k", GRID)
def test_int8_kernel_matches_fake_quant_oracle(B, p, q, k):
    """The kernel consuming int8 tables + scales must equal, bit for bit,
    the fp32 kernel consuming the host-dequantized tables — same scales,
    same values, only the dequant site differs."""
    x = _rand((B, q * k), seed=0)
    w = _rand((p, q, k), seed=1) * (q * k) ** -0.5
    wr, wi = freq_weights(w)
    scale = symmetric_scales(wr, wi)
    qr, qi = quantize_symmetric(wr, scale), quantize_symmetric(wi, scale)

    y_q = block_circulant_matmul(x, None, w_freq=(qr, qi), w_scale=scale,
                                 k=k, q=q)
    y_o = block_circulant_matmul(
        x, None,
        w_freq=(dequantize_symmetric(qr, scale),
                dequantize_symmetric(qi, scale)),
        k=k, q=q)
    assert y_q.shape == (B, p * k)
    assert bool(jnp.array_equal(y_q, y_o)), (
        "in-kernel dequant diverged from the host-dequantized oracle")
    # and loosely close to the unquantized dense reference (8-bit tables)
    y_ref = block_circulant_matmul_ref(x, w)
    rel = float(jnp.max(jnp.abs(y_q - y_ref))
                / jnp.maximum(jnp.max(jnp.abs(y_ref)), 1e-6))
    assert rel < 0.05, f"int8 tables are {rel:.3f} off the fp32 reference"


def test_fake_quant_symmetric_matches_storage_roundtrip():
    """fake_quant_symmetric (the QAT forward) and the int8 storage
    round-trip must land on identical values — training sees exactly what
    serving will load."""
    wr, wi = freq_weights(_rand((3, 4, 8), seed=2))
    fr, fi, scale = fake_quant_symmetric(wr, wi)
    qr, qi = quantize_symmetric(wr, scale), quantize_symmetric(wi, scale)
    assert bool(jnp.array_equal(fr, dequantize_symmetric(qr, scale)))
    assert bool(jnp.array_equal(fi, dequantize_symmetric(qi, scale)))


# ---------------------------------------------------------------------------
# 2. Quantized plans: bitwise oracle match, launch parity, bytes, no fft
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B,p,q,k", [(4, 3, 5, 8), (7, 2, 3, 12)])
def test_quantized_plan_bitwise_and_structural(B, p, q, k):
    x = _rand((B, q * k), seed=0)
    w = _rand((p, q, k), seed=1) * (q * k) ** -0.5
    b = _rand((p * k,), seed=2)
    plan_f = build_plan(w, bias=b, activation="relu")
    plan_q = build_plan(w, bias=b, activation="relu", quantize="int8")
    assert plan_q.quantized and not plan_f.quantized
    assert plan_q.wr.dtype == jnp.int8 and plan_q.scale.dtype == jnp.float32

    plan_o = dataclasses.replace(
        plan_q,
        wr=dequantize_symmetric(plan_q.wr, plan_q.scale),
        wi=dequantize_symmetric(plan_q.wi, plan_q.scale),
        scale=None,
    )
    y_q, y_o = plan_q.apply(x), plan_o.apply(x)
    assert bool(jnp.array_equal(y_q, y_o))

    jp_q = jax.make_jaxpr(plan_q.apply)(x)
    assert count_pallas_launches(jp_q) == count_pallas_launches(
        jax.make_jaxpr(plan_f.apply)(x)), "dequant must not add a launch"
    assert NoFFT().check(jp_q) == []
    ratio = plan_q.table_bytes() / plan_f.table_bytes()
    assert ratio <= 0.55, f"int8 tables at {ratio:.3f}x fp32 bytes"


def test_build_plan_rejects_unknown_quantize_mode():
    w = _rand((2, 2, 8))
    with pytest.raises(ValueError, match="quantize"):
        build_plan(w, quantize="int4")


# ---------------------------------------------------------------------------
# 3. Fused frozen groups (attention QKV, LSTM gates) with scales
# ---------------------------------------------------------------------------


def _attn_cfg(impl="dft"):
    return ModelConfig(name="quant-fuse", n_layers=2, d_model=32, n_heads=2,
                       n_kv_heads=1, head_dim=16, d_ff=64, vocab=48,
                       remat="none", param_dtype="float32",
                       compute_dtype="float32",
                       swm=SWMConfig(block_size=8, impl=impl))


@pytest.mark.parametrize("impl", ["dft", "pallas"])
def test_quantized_freeze_fuses_attention_qkv(impl):
    """int8 freeze pre-concatenates the Q/K/V tables AND their per-block
    scales (scales are per-(p, q) block, so concatenation along p commutes
    with quantization): the fused launch is bit-identical to the
    per-projection quantized path and close to the fp32 frozen path."""
    from repro.nn.attention import Attention

    att = Attention(_attn_cfg(impl))
    params = init_params(att.specs(), 0)
    frozen_f = freeze_params(att.specs(), params)
    frozen_q = freeze_params(att.specs(), params, quantize="int8")
    fused = frozen_q[FUSED_KEY]
    assert fused["wr"].dtype == jnp.int8
    assert fused["w_scale"].shape == fused["wr"].shape[:-1]

    x = _rand((2, 3, 32), seed=1)
    pos = jnp.broadcast_to(jnp.arange(3, dtype=jnp.int32), (2, 3))
    y_fused, _ = att(frozen_q, x, pos)
    nofuse = {k: v for k, v in frozen_q.items() if k != FUSED_KEY}
    y_perproj, _ = att(nofuse, x, pos)
    assert bool(jnp.all(y_fused == y_perproj)), (
        "fused quantized QKV diverged from the per-projection path")
    y_f32, _ = att(frozen_f, x, pos)
    np.testing.assert_allclose(np.asarray(y_fused), np.asarray(y_f32),
                               rtol=0.05, atol=0.05)


def test_quantized_freeze_fuses_lstm_gates():
    from repro.core.lstm import SWMLSTM

    lstm = SWMLSTM(d_in=16, d_cell=32, d_proj=16,
                   swm=SWMConfig(block_size=8, impl="dft",
                                 targets=("attn", "ffn", "lstm")))
    params = init_params(lstm.specs(), 0)
    frozen_q = freeze_params(lstm.specs(), params, quantize="int8")
    fused = frozen_q[FUSED_KEY]
    assert fused["wr"].dtype == jnp.int8
    # 4 gates x (dc/k = 4) stacked along p; (di + dp)/k = 4 along q
    assert fused["w_scale"].shape == (16, 4)

    xs = _rand((2, 4, 16), seed=2)
    y_fused, _ = lstm(frozen_q, xs)
    nofuse = {k: v for k, v in frozen_q.items() if k != FUSED_KEY}
    y_perproj, _ = lstm(nofuse, xs)
    assert bool(jnp.all(y_fused == y_perproj)), (
        "fused quantized LSTM gates diverged from the per-gate path")


def test_requantize_already_frozen_tree_rebuilds_fused():
    """Freezing fp32 first and re-freezing with quantize="int8" must
    quantize the existing tables in place (no new rfft) and rebuild the
    fused group with scales — a stale fp32 fused entry would silently
    serve unquantized weights."""
    from repro.nn.attention import Attention

    att = Attention(_attn_cfg("dft"))
    params = init_params(att.specs(), 0)
    frozen_f = freeze_params(att.specs(), params)
    frozen_q = freeze_params(att.specs(), frozen_f, quantize="int8")
    assert frozen_q[FUSED_KEY]["wr"].dtype == jnp.int8
    assert "w_scale" in frozen_q[FUSED_KEY]
    # the dtype contract over the whole tree (every group, fused included)
    assert QuantizedTableDtypes("int8").check_params(frozen_q) == []
    assert QuantizedTableDtypes("off").check_params(frozen_f) == []
    # and cross-mode trees are rejected with a path-naming message
    bad = QuantizedTableDtypes("off").check_params(frozen_q)
    assert bad and "w_scale" in bad[0].message
    # matches quantizing the raw tree directly
    direct = freeze_params(att.specs(), params, quantize="int8")
    x = _rand((2, 3, 32), seed=1)
    pos = jnp.broadcast_to(jnp.arange(3, dtype=jnp.int32), (2, 3))
    y_a, _ = att(frozen_q, x, pos)
    y_b, _ = att(direct, x, pos)
    assert bool(jnp.all(y_a == y_b))
    # idempotent under both modes; "off" never silently dequantizes
    assert freeze_params(att.specs(), frozen_q, quantize="int8") is frozen_q
    assert freeze_params(att.specs(), frozen_q) is frozen_q


def test_dequantize_frozen_roundtrip_and_bytes():
    from repro.nn.attention import Attention

    att = Attention(_attn_cfg("dft"))
    params = init_params(att.specs(), 0)
    frozen_f = freeze_params(att.specs(), params)
    frozen_q = freeze_params(att.specs(), params, quantize="int8")
    ratio = frozen_table_bytes(frozen_q) / frozen_table_bytes(frozen_f)
    assert ratio <= 0.55, f"quantized tree at {ratio:.3f}x fp32 bytes"
    deq = dequantize_frozen(frozen_q)
    for name in ("q", "k", "v", "o"):
        assert "w_scale" not in deq[name]
        assert deq[name]["wr"].dtype == jnp.float32
        want = dequantize_symmetric(frozen_q[name]["wr"],
                                    frozen_q[name]["w_scale"])
        assert bool(jnp.array_equal(deq[name]["wr"], want))


# ---------------------------------------------------------------------------
# 4. quantize_tree bugfix: complex leaves quantize, biases/norms exempt
# ---------------------------------------------------------------------------


def test_quantize_tree_quantizes_complex_leaves():
    """Regression: complex64 leaves used to escape the floating-dtype
    check and pass through unquantized — frozen frequency tables were
    silently exempt from QAT."""
    wf = jnp.asarray([0.3 + 0.7j, -1.13 - 0.01j], jnp.complex64)
    tree = {"wf": wf, "w": jnp.asarray([0.3, -1.13], jnp.float32)}
    q = quantize_tree(tree, 8, 4)
    assert q["wf"].dtype == jnp.complex64
    want = (fixed_point(jnp.real(wf), 8, 4)
            + 1j * fixed_point(jnp.imag(wf), 8, 4)).astype(jnp.complex64)
    assert bool(jnp.array_equal(q["wf"], want))
    assert not bool(jnp.array_equal(q["wf"], wf)), (
        "complex leaf passed through unquantized")
    assert bool(jnp.array_equal(q["w"], fixed_point(tree["w"], 8, 4)))


def test_quantize_tree_exempts_biases_and_norm_scales():
    tree = {
        "lin": {"w": jnp.asarray([0.33], jnp.float32),
                "bias": jnp.asarray([0.333], jnp.float32)},
        "norm": {"scale": jnp.asarray([1.001], jnp.float32)},
        "lstm": {"bi": jnp.asarray([0.123], jnp.float32),
                 "out_b": jnp.asarray([0.321], jnp.float32)},
    }
    q = quantize_tree(tree, 8, 4, exempt=default_exempt)
    assert bool(jnp.array_equal(q["lin"]["bias"], tree["lin"]["bias"]))
    assert bool(jnp.array_equal(q["norm"]["scale"], tree["norm"]["scale"]))
    assert bool(jnp.array_equal(q["lstm"]["bi"], tree["lstm"]["bi"]))
    assert bool(jnp.array_equal(q["lstm"]["out_b"], tree["lstm"]["out_b"]))
    assert not bool(jnp.array_equal(q["lin"]["w"], tree["lin"]["w"]))


def test_quantize_tree_ste_gradient_flows():
    """Clipped STE: in-range leaves pass unit gradient through the
    quantizer (positional-arg form kept for callers predating exempt)."""
    tree = {"w": jnp.asarray([0.1, -0.2, 0.3], jnp.float32)}
    g = jax.grad(lambda t: quantize_tree(t, 12, 8)["w"].sum())(tree)
    assert bool(jnp.array_equal(g["w"], jnp.ones(3)))


# ---------------------------------------------------------------------------
# 5. dist compressor bugfix: bf16 dtype preserved, EF still telescopes
# ---------------------------------------------------------------------------


def test_compress_roundtrip_preserves_bf16():
    from repro.dist.compress import int8_compress, int8_decompress

    g = _rand((33,), seed=3).astype(jnp.bfloat16)
    q, s = int8_compress(g)
    out = int8_decompress(q, s, g.shape, g.size, dtype=g.dtype)
    assert out.dtype == jnp.bfloat16, (
        "decompress promoted the gradient tree to f32")


def test_error_feedback_preserves_dtype_and_telescopes():
    from repro.dist.compress import apply_error_feedback

    gs = [_rand((64,), seed=10 + i).astype(jnp.bfloat16) for i in range(6)]
    residual = jnp.zeros((64,), jnp.bfloat16)
    total_tx = jnp.zeros((64,), jnp.float32)
    for g in gs:
        tx, residual = apply_error_feedback(g, residual)
        assert tx.dtype == jnp.bfloat16 and residual.dtype == jnp.bfloat16
        total_tx = total_tx + tx.astype(jnp.float32)
    total_g = sum(g.astype(jnp.float32) for g in gs)
    # Σ tx + residual_T == Σ g up to bf16 storage error per step
    np.testing.assert_allclose(
        np.asarray(total_tx + residual.astype(jnp.float32)),
        np.asarray(total_g), atol=0.15)


# ---------------------------------------------------------------------------
# 6. Serving engines: int8 vs dequantized oracle, fingerprints, guards
# ---------------------------------------------------------------------------

BATCH, CACHE = 2, 32


def _serve_cfg(**kw):
    base = dict(name="quant-serve", n_layers=2, d_model=32, n_heads=2,
                n_kv_heads=1, head_dim=16, d_ff=64, vocab=48, remat="none",
                param_dtype="float32", compute_dtype="float32",
                swm=SWMConfig(block_size=8, impl="dft"))
    base.update(kw)
    return ModelConfig(**base)


def _mix(seed, n, vocab=48):
    rng = np.random.default_rng(seed)
    return [
        Request(rng.integers(0, vocab,
                             size=int(rng.integers(1, 11))).astype(np.int32),
                max_new=int(rng.integers(1, 7)))
        for _ in range(n)
    ]


@pytest.fixture(scope="module")
def lm():
    cfg = _serve_cfg()
    model = HybridDecoderLM(cfg)
    params = init_params(model.specs(), 0)
    return cfg, model, params


def test_engine_int8_matches_dequantized_oracle(lm):
    cfg, model, params = lm
    reqs = _mix(0, 6)
    eng_f = ServeEngine(model, cfg, params, batch=BATCH, cache_len=CACHE)
    eng_q = ServeEngine(model, cfg, params, batch=BATCH, cache_len=CACHE,
                        quantize="int8")
    oracle = ServeEngine(model, cfg, dequantize_frozen(eng_q.params),
                         batch=BATCH, cache_len=CACHE)
    outs_f = eng_f.generate(reqs)
    outs_q = eng_q.generate(reqs)
    outs_o = oracle.generate(reqs)
    assert outs_q == outs_o, (
        "int8 engine diverged from its dequantized-table oracle")
    assert eng_q.prefill_compiles == eng_f.prefill_compiles
    assert eng_q.decode_compiles == eng_f.decode_compiles
    ratio = eng_q.frozen_table_bytes() / eng_f.frozen_table_bytes()
    assert ratio <= 0.55, f"engine tables at {ratio:.3f}x fp32 bytes"


def test_wave_engine_int8_matches_dequantized_oracle(lm):
    cfg, model, params = lm
    reqs = _mix(1, 4)
    q = WaveEngine(model, cfg, params, batch=BATCH, cache_len=CACHE,
                   quantize="int8")
    oracle = WaveEngine(model, cfg, dequantize_frozen(q.params),
                        batch=BATCH, cache_len=CACHE)
    assert q.generate(reqs) == oracle.generate(reqs)
    fp = WaveEngine(model, cfg, params, batch=BATCH, cache_len=CACHE)
    assert q.frozen_table_bytes() <= 0.55 * fp.frozen_table_bytes()


def test_engine_rejects_bad_quantize_args(lm):
    cfg, model, params = lm
    with pytest.raises(ValueError, match="quantize"):
        ServeEngine(model, cfg, params, batch=BATCH, cache_len=CACHE,
                    quantize="int4")
    cfg_off = _serve_cfg(swm=SWMConfig(block_size=0))
    model_off = HybridDecoderLM(cfg_off)
    params_off = init_params(model_off.specs(), 0)
    with pytest.raises(ValueError, match="swm"):
        ServeEngine(model_off, cfg_off, params_off, batch=BATCH,
                    cache_len=CACHE, quantize="int8")


def test_snapshot_refuses_cross_quantize_restore(lm, tmp_path):
    """The engine fingerprint carries the quantize mode: a snapshot taken
    by an fp32 engine must not restore into an int8 engine (the KV cache
    is valid, but silently swapping table precision mid-stream would
    change outputs)."""
    cfg, model, params = lm
    eng = ServeEngine(model, cfg, params, batch=BATCH, cache_len=CACHE,
                      snapshot_dir=str(tmp_path))
    eng.submit(_mix(2, 1)[0])
    eng.snapshot()
    other = ServeEngine(model, cfg, params, batch=BATCH, cache_len=CACHE,
                        snapshot_dir=str(tmp_path), quantize="int8")
    with pytest.raises(ValueError, match="fingerprint"):
        other.restore()


# ---------------------------------------------------------------------------
# 7. Chaos: snapshot/restore mid-stream with quantized tables
# ---------------------------------------------------------------------------


def _drive(eng, max_steps=500):
    steps = 0
    while eng.step():
        steps += 1
        assert steps < max_steps, "engine did not go idle: hang"
    return steps


def test_quantized_snapshot_restore_resumes_mid_stream(lm, tmp_path):
    """Quantized engines snapshot only cache + metadata (never params):
    the twin rebuilds its int8 tables deterministically at construction
    and must resume every in-flight request bit-identically."""
    cfg, model, params = lm
    reqs = _mix(5, 5)
    eng = ServeEngine(model, cfg, params, batch=BATCH, cache_len=CACHE,
                      snapshot_dir=str(tmp_path), quantize="int8")
    rids = [eng.submit(r) for r in reqs]
    for _ in range(3):
        eng.step()                   # decode a few tokens mid-stream
    eng.snapshot()
    assert eng.stats.snapshots == 1
    _drive(eng)
    want = {rid: eng.poll(rid) for rid in rids}

    twin = ServeEngine(model, cfg, params, batch=BATCH, cache_len=CACHE,
                       snapshot_dir=str(tmp_path), quantize="int8")
    twin.restore()
    assert twin.stats.recoveries == 1
    _drive(twin)
    for rid in rids:
        got = twin.poll(rid)
        assert got.status == want[rid].status
        assert got.tokens == want[rid].tokens, (
            "restored quantized engine diverged mid-stream")
    assert not twin._active.any() and len(twin._sched) == 0


# ---------------------------------------------------------------------------
# 8. QAT train-step smoke
# ---------------------------------------------------------------------------


def test_qat_train_step_smoke():
    """One QAT train step on the tiny LM: quantization actually happens
    (fake-quantized loss differs from fp32), loss and grads stay finite,
    and the fp32 master copy keeps updating off-grid values."""
    from repro.train.loop import init_train_state, make_loss_fn, \
        make_train_step

    cfg = _serve_cfg(name="quant-train")
    model = HybridDecoderLM(cfg)
    params = init_params(model.specs(), 0)
    tokens = jnp.asarray(
        np.random.default_rng(0).integers(0, 48, size=(2, 9)), jnp.int32)
    batch = {"tokens": tokens}

    tcfg_fp = TrainConfig(learning_rate=1e-3, warmup_steps=1, total_steps=4)
    tcfg_q = dataclasses.replace(tcfg_fp, qat_bits=8)
    loss_fp, _ = make_loss_fn(model, cfg, tcfg_fp)(params, batch)
    loss_q, _ = make_loss_fn(model, cfg, tcfg_q)(params, batch)
    assert np.isfinite(float(loss_q))
    assert float(loss_q) != float(loss_fp), (
        "qat_bits=8 produced the fp32 loss: fake quantization never ran")

    step = make_train_step(model, cfg, tcfg_q)
    state = init_train_state(params, tcfg_q)
    state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    state, _ = step(state, batch)      # step 2: past the LR warmup ramp
    # the fp32 master copy keeps updating (QAT never freezes the weights)
    moved = any(
        not bool(jnp.array_equal(a, b))
        for a, b in zip(jax.tree.leaves(params),
                        jax.tree.leaves(state["params"]))
    )
    assert moved, "params did not update"
