"""Chaos suite for the fault-tolerant serving path.

Drives every failure mode of ``ServeEngine`` deterministically — injected
launch faults (transient and fatal), NaN-poisoned requests, deadline
expiry on a manual clock, cancellation, load shedding, and
snapshot/restore — and asserts the robustness contract: no hang, every
request ends in exactly one terminal state, no slot or refcount leak,
unaffected requests' greedy outputs stay bit-identical to a fault-free
run, and the compile budget is unchanged (the finiteness guard rides in
the existing prefill/decode executables, no extra compiles).

NaN poisoning uses an untied-embedding config with one NaN row in the
embedding table: the row is gather-only, so exactly the requests that
feed the poison token see non-finite activations — per-request fault
isolation is testable without touching shared weights. The poison token
is chosen dynamically as one the fault-free baseline never emits (an
untrained model may generate any token id).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig, SWMConfig
from repro.models.decoder import HybridDecoderLM
from repro.nn.module import init_params
from repro.serve.engine import (Request, SamplingParams, ServeEngine,
                                WaveEngine)
from repro.serve.guard import (CANCELLED, EXPIRED, FAILED, FINISHED,
                               TERMINAL_STATES, EngineFatalError,
                               InjectedFault, ManualClock, QueueFullError,
                               ServeFaultInjector)

jax.config.update("jax_platform_name", "cpu")

BATCH, CACHE = 2, 32


def _cfg(**kw):
    base = dict(name="chaos", n_layers=2, d_model=32, n_heads=2,
                n_kv_heads=1, head_dim=16, d_ff=64, vocab=48, remat="none",
                param_dtype="float32", compute_dtype="float32",
                swm=SWMConfig(block_size=8, impl="dft"))
    base.update(kw)
    return ModelConfig(**base)


def _mix(seed, n, vocab=48, plen_hi=11, new_hi=7):
    rng = np.random.default_rng(seed)
    return [
        Request(rng.integers(0, vocab,
                             size=int(rng.integers(1, plen_hi))
                             ).astype(np.int32),
                max_new=int(rng.integers(1, new_hi)))
        for _ in range(n)
    ]


def _engine(lm, **kw):
    cfg, model, params = lm
    kw.setdefault("batch", BATCH)
    kw.setdefault("cache_len", CACHE)
    return ServeEngine(model, cfg, params, **kw)


def _drive(eng, clk=None, dt=0.0, max_steps=500):
    """Step to idle with a hard hang guard; optionally tick a ManualClock."""
    steps = 0
    while eng.step():
        steps += 1
        assert steps < max_steps, "engine did not go idle: hang"
        if clk is not None and dt:
            clk.advance(dt)
    return steps


def _no_leaks(eng):
    """Slot/refcount/queue invariants that must hold at idle regardless of
    how requests terminated."""
    assert not eng._active.any(), "slot leak: active mask not clear"
    assert (eng._slot_refs == 0).all(), "prefix refcount leak"
    assert len(eng._sched) == 0, "scheduler queue not drained"
    assert not eng._rid_slot, "rid->slot map leak"


@pytest.fixture(scope="module")
def lm():
    cfg = _cfg()
    model = HybridDecoderLM(cfg)
    params = init_params(model.specs(), 0)
    return cfg, model, params


@pytest.fixture(scope="module")
def base6(lm):
    """Fault-free outputs for the standard 6-request mix."""
    return _engine(lm).generate(_mix(0, 6))


@pytest.fixture(scope="module")
def poisoned():
    """Untied config + params with one NaN embedding row, the dynamically
    chosen poison token, and the fault-free baseline for a clean mix whose
    prompts never touch the poison row."""
    cfg = dataclasses.replace(_cfg(), name="chaos-nan",
                              tie_embeddings=False)
    model = HybridDecoderLM(cfg)
    params = init_params(model.specs(), 0)
    reqs = _mix(3, 5, vocab=40)      # prompts < 40: poison lives in 40..47
    base = _engine((cfg, model, params)).generate(reqs)
    used = {t for o in base for t in o}
    poison = next(t for t in range(cfg.vocab - 1, 39, -1) if t not in used)
    pp = jax.tree.map(lambda x: x, params)
    pp["embed"]["table"] = pp["embed"]["table"].at[poison].set(jnp.nan)
    return cfg, model, pp, poison, reqs, base


# ---------------------------------------------------------------------------
# Injected launch faults
# ---------------------------------------------------------------------------


def test_prefill_launch_failure_isolates_chunk(lm, base6):
    reqs = _mix(0, 6)
    inj = ServeFaultInjector(fail_prefill_at={0})
    eng = _engine(lm, fault_injector=inj)
    rids = [eng.submit(r) for r in reqs]
    _drive(eng)
    states = [eng.poll(rid) for rid in rids]
    assert all(s.status in TERMINAL_STATES for s in states)
    failed = [s for s in states if s.status == FAILED]
    assert failed and all("prefill launch failed" in s.error
                          for s in failed)
    # the fault killed exactly the first admitted chunk; everyone else runs
    # to completion bit-identically
    for s, b in zip(states, base6):
        if s.status == FINISHED:
            assert list(s.tokens) == b
    assert sum(s.status == FINISHED for s in states) == 6 - len(failed)
    assert eng.stats.aborted == len(failed)
    _no_leaks(eng)


def test_decode_launch_failure_retries_once(lm, base6):
    inj = ServeFaultInjector(fail_decode_at={1})
    eng = _engine(lm, fault_injector=inj)
    outs = eng.generate(_mix(0, 6))
    assert outs == base6, "retried decode launch must not perturb outputs"
    assert eng.stats.launch_retries == 1
    assert eng.stats.aborted == 0
    _no_leaks(eng)


class _AlwaysFailDecode(ServeFaultInjector):
    def on_launch(self, kind, index):
        if kind == "decode":
            raise InjectedFault(f"decode launch {index} always fails")


def test_decode_launch_failure_twice_is_fatal(lm):
    # a decode launch failing on the retry too -> donated cache can no
    # longer be trusted -> engine-fatal
    eng = _engine(lm, fault_injector=_AlwaysFailDecode())
    for r in _mix(0, 4):
        eng.submit(r)
    with pytest.raises(EngineFatalError):
        _drive(eng)
    # a dead engine refuses everything
    with pytest.raises(EngineFatalError):
        eng.submit(_mix(9, 1)[0])
    with pytest.raises(EngineFatalError):
        eng.step()


# ---------------------------------------------------------------------------
# NaN isolation (device-side finiteness guard)
# ---------------------------------------------------------------------------


def test_nan_prefill_aborts_only_poisoned_request(poisoned):
    cfg, model, pp, poison, reqs, base = poisoned
    eng = _engine((cfg, model, pp))
    bad = Request(np.asarray([3, poison, 7], np.int32), max_new=4)
    rids = [eng.submit(r) for r in reqs + [bad]]
    _drive(eng)
    sbad = eng.poll(rids[-1])
    assert sbad.status == FAILED
    assert "non-finite logits in prefill" in sbad.error
    assert sbad.tokens == ()
    for rid, b in zip(rids[:-1], base):
        s = eng.poll(rid)
        assert s.status == FINISHED and list(s.tokens) == b
    _no_leaks(eng)


def test_nan_decode_aborts_and_scrubs_slot(poisoned):
    cfg, model, pp, _, reqs, base = poisoned
    # poison the first token some request *generates* (and does not carry
    # in its prompt): the NaN enters when the token is fed back at the
    # next decode step, i.e. mid-stream, not at prefill
    victim = tok0 = None
    for v in range(len(reqs)):
        if len(base[v]) >= 2 and base[v][0] not in np.asarray(
                reqs[v].prompt):
            victim, tok0 = v, base[v][0]
            break
    assert victim is not None, "workload seed yields no decode-NaN victim"
    pp2 = jax.tree.map(lambda x: x, pp)
    pp2["embed"]["table"] = (
        pp2["embed"]["table"].at[tok0].set(jnp.nan))
    safe = [i for i in range(len(reqs))
            if i != victim and tok0 not in base[i]
            and tok0 not in np.asarray(reqs[i].prompt)]
    assert safe, "workload seed must leave at least one unpoisoned request"
    eng = _engine((cfg, model, pp2))
    rids = [eng.submit(r) for r in reqs]
    _drive(eng)
    s0 = eng.poll(rids[victim])
    assert s0.status == FAILED
    assert "non-finite logits in decode" in s0.error
    assert list(s0.tokens)[:1] == [tok0]      # partial progress kept
    for i in safe:
        s = eng.poll(rids[i])
        assert s.status == FINISHED and list(s.tokens) == base[i]
    _no_leaks(eng)
    # the poisoned slot was scrubbed (blank KV rows re-placed): reusing the
    # engine stays bit-identical for safe traffic
    again = eng.generate([reqs[i] for i in safe])
    assert again == [base[i] for i in safe]
    _no_leaks(eng)


def test_finiteness_guard_keeps_compile_budget(poisoned):
    cfg, model, pp, poison, reqs, _ = poisoned
    eng = _engine((cfg, model, pp))
    eng.prewarm()
    assert eng.prefill_compiles == eng.max_prefill_variants
    assert eng.decode_compiles == eng.max_decode_variants
    bad = Request(np.asarray([poison], np.int32), max_new=3)
    eng.generate(reqs + [bad])
    # the NaN check rides inside the existing executables: serving poisoned
    # traffic must not add a single compile
    assert eng.prefill_compiles == eng.max_prefill_variants
    assert eng.decode_compiles == eng.max_decode_variants


# ---------------------------------------------------------------------------
# Deadlines, cancellation, shedding
# ---------------------------------------------------------------------------


def test_deadline_expires_at_step_boundary(lm, base6):
    reqs = _mix(0, 6)
    clk = ManualClock()
    eng = _engine(lm, clock=clk)
    # request 0 gets a 5 ms TTL; each engine step takes a simulated 10 ms
    doomed = Request(reqs[0].prompt, max_new=reqs[0].max_new,
                     deadline_ms=5.0)
    rids = [eng.submit(r) for r in [doomed] + reqs[1:]]
    _drive(eng, clk=clk, dt=0.010)
    s0 = eng.poll(rids[0])
    assert s0.status == EXPIRED and "deadline_ms=5.0" in s0.error
    for rid, b in zip(rids[1:], base6[1:]):
        s = eng.poll(rid)
        assert s.status == FINISHED and list(s.tokens) == b
    assert eng.stats.expired == 1
    _no_leaks(eng)


def test_cancel_running_and_queued(lm):
    reqs = _mix(0, 6)
    eng = _engine(lm)
    rids = [eng.submit(r) for r in reqs]
    eng.step()                       # admit the first chunk
    running = next(r for r in rids if eng.poll(r).status == "RUNNING")
    queued = next(r for r in rids if eng.poll(r).status == "QUEUED")
    assert eng.cancel(running) and eng.cancel(queued)
    for rid in (running, queued):
        s = eng.poll(rid)
        assert s.status == CANCELLED and "cancelled by caller" in s.error
    assert eng.cancel(running) is False      # already terminal
    with pytest.raises(KeyError):
        eng.cancel(10_000)                   # unknown rid
    _drive(eng)                              # stale queue entry is skipped
    assert all(eng.poll(r).status in TERMINAL_STATES for r in rids)
    assert eng.stats.cancelled == 2
    _no_leaks(eng)


def test_reject_shedding_and_backpressure(lm, base6):
    reqs = _mix(0, 6)
    eng = _engine(lm, max_queue=2)
    for r in reqs[:2]:
        eng.submit(r)
    with pytest.raises(QueueFullError) as ei:
        eng.submit(reqs[2])
    assert ei.value.max_queue == 2 and ei.value.depth == 2
    assert eng.stats.rejected == 1
    _drive(eng)
    _no_leaks(eng)
    # generate() absorbs the backpressure internally: rejected submits step
    # the engine and retry, so outputs are complete and identical
    assert eng.generate(reqs) == base6


def test_drop_oldest_shedding(lm):
    reqs = _mix(0, 6)
    eng = _engine(lm, max_queue=2, shed_policy="drop-oldest")
    rids = [eng.submit(r) for r in reqs[:3]]      # third submit sheds first
    s0 = eng.poll(rids[0])
    assert s0.status == CANCELLED and "load shed (drop-oldest)" in s0.error
    assert eng.stats.rejected == 1
    _drive(eng)
    assert all(eng.poll(r).status == FINISHED for r in rids[1:])
    _no_leaks(eng)


# ---------------------------------------------------------------------------
# Snapshot / restore
# ---------------------------------------------------------------------------


def test_snapshot_restore_resumes_mid_stream(lm, tmp_path):
    cfg, model, params = lm
    reqs = _mix(0, 5)
    # include a sampled request so the snapshot must carry per-request RNG
    # state exactly, not just greedy determinism
    reqs.append(Request(np.asarray([1, 2, 3], np.int32), max_new=6,
                        sampling=SamplingParams(temperature=1.0, seed=7)))
    eng = _engine(lm, snapshot_dir=str(tmp_path))
    rids = [eng.submit(r) for r in reqs]
    for _ in range(3):
        eng.step()                   # decode a few tokens mid-stream
    eng.snapshot()
    assert eng.stats.snapshots == 1
    _drive(eng)
    want = {rid: eng.poll(rid) for rid in rids}

    twin = _engine(lm, snapshot_dir=str(tmp_path))
    twin.restore()
    assert twin.stats.recoveries == 1
    _drive(twin)
    for rid in rids:
        got = twin.poll(rid)
        assert got.status == want[rid].status
        assert got.tokens == want[rid].tokens, (
            "restored engine diverged mid-stream")
    _no_leaks(twin)


def test_restore_refuses_config_mismatch(lm, tmp_path):
    cfg, model, params = lm
    eng = _engine(lm, snapshot_dir=str(tmp_path))
    eng.submit(_mix(0, 1)[0])
    eng.snapshot()
    other = ServeEngine(model, cfg, params, batch=BATCH,
                        cache_len=CACHE * 2, snapshot_dir=str(tmp_path))
    with pytest.raises(ValueError, match="fingerprint"):
        other.restore()


def test_fatal_fault_recovers_via_snapshot(lm, base6, tmp_path):
    reqs = _mix(0, 6)
    inj = ServeFaultInjector(fatal_decode_at={3})
    eng = _engine(lm, fault_injector=inj, snapshot_dir=str(tmp_path),
                  snapshot_every=1)
    rids = [eng.submit(r) for r in reqs]
    with pytest.raises(EngineFatalError):
        _drive(eng)
    with pytest.raises(EngineFatalError):
        eng.snapshot()               # dead engines may not snapshot

    twin = _engine(lm, snapshot_dir=str(tmp_path))
    twin.restore()
    _drive(twin)
    for rid, b in zip(rids, base6):
        s = twin.poll(rid)
        assert s.status == FINISHED and list(s.tokens) == b, (
            "post-recovery outputs must be bit-identical to the "
            "fault-free run")
    assert twin.stats.recoveries == 1
    _no_leaks(twin)


def test_restore_needs_fresh_engine(lm, tmp_path):
    eng = _engine(lm, snapshot_dir=str(tmp_path))
    eng.submit(_mix(0, 1)[0])
    eng.snapshot()
    with pytest.raises(RuntimeError, match="fresh"):
        eng.restore()                # engine already has in-flight state


# ---------------------------------------------------------------------------
# Misc lifecycle contract
# ---------------------------------------------------------------------------


def test_wave_engine_rejects_deadlines(lm):
    cfg, model, params = lm
    wave = WaveEngine(model, cfg, params, batch=BATCH, cache_len=CACHE)
    with pytest.raises(ValueError, match="lifecycle"):
        wave.generate([Request(np.asarray([1, 2], np.int32), max_new=2,
                               deadline_ms=100.0)])


def test_bad_deadline_rejected(lm):
    eng = _engine(lm)
    with pytest.raises(ValueError):
        eng.submit(Request(np.asarray([1], np.int32), max_new=2,
                           deadline_ms=0.0))
    with pytest.raises(ValueError):
        eng.submit(Request(np.asarray([1], np.int32), max_new=2,
                           deadline_ms=-5.0))
