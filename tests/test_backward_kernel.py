"""Kernel-backed training adjoint: the transposed-geometry Pallas dw kernel
vs the pure-XLA einsum oracle, plus structural train-step regressions.

The weight adjoint ``dL/dw[i,j] = Σ_b x_j ⋆ g_i`` is the forward's per-bin
complex GEMM with the train batch promoted to the contraction axis
(``kernel.bc_dw_pallas``). These tests pin it against
``ops._dw_freq_cotangents`` — the einsum formulation it replaced, kept as
the oracle — over the conformance (p, q, k, B) grid (odd k, k=1,
non-divisible Linear dims, B=1), through BOTH VJP paths (`_bwd` for
time-domain tables, `_freq_bwd` for frozen frequency params), and assert
the cached train-step jaxpr contains no dense (P, Q)-block-grid
``dot_general`` outside a ``pallas_call`` — the acceptance criterion that
the O(n log n) training claim holds structurally, not just numerically.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import (LaunchBudget, NoDenseDotGeneral, NoFFT,
                            StructuralContractError, iter_eqns)
from repro.kernels.block_circulant import (block_circulant_matmul,
                                           build_plan)
from repro.kernels.block_circulant.ops import (_dw_freq_cotangents,
                                               count_pallas_launches,
                                               outer_dot_shapes)
from repro.kernels.block_circulant.plan import (clear_plan_cache,
                                                dw_geometry,
                                                dw_geometry_cache_info)
from repro.kernels.block_circulant.ref import (block_circulant_matmul_ref,
                                               blocks_to_dense)
from repro.core.circulant import dft_bases
from repro.train.loop import make_grad_step

jax.config.update("jax_platform_name", "cpu")


def _rand(shape, seed=0, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(seed), shape,
                             jnp.float32) * scale


def _dw_oracle_time(x2d, gz, p, q, k):
    """Einsum-oracle weight adjoint folded back to the time domain —
    exactly what ops._bwd computed before the kernel-backed path."""
    dwr, dwi = _dw_freq_cotangents(x2d, gz, p, q, k)
    C, S, _, _ = dft_bases(k, jnp.float32)
    return dwr @ C.T + dwi @ S.T


# same grid as tests/test_conformance.py
K_GRID = (1, 2, 5, 8, 12)
PQ_GRID = ((1, 1), (2, 3), (5, 2))
B_GRID = (1, 4)


# ---------------------------------------------------------------------------
# dw kernel vs einsum oracle (time-domain `_bwd` path)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("k", K_GRID)
@pytest.mark.parametrize("p,q", PQ_GRID)
@pytest.mark.parametrize("B", B_GRID)
def test_dw_kernel_matches_einsum_oracle(B, p, q, k):
    w = _rand((p, q, k), seed=1, scale=(q * k) ** -0.5)
    x = _rand((B, q * k), seed=2)
    t = _rand((B, p * k), seed=3)          # fixed upstream cotangent
    gw = jax.grad(lambda w: (block_circulant_matmul(x, w) * t).sum())(w)
    gw_oracle = _dw_oracle_time(x, t, p, q, k)
    np.testing.assert_allclose(np.asarray(gw), np.asarray(gw_oracle),
                               rtol=2e-5, atol=2e-5)
    # and against autodiff of the dense expansion (independent derivation)
    gw_dense = jax.grad(
        lambda w: (block_circulant_matmul_ref(x, w) * t).sum())(w)
    np.testing.assert_allclose(np.asarray(gw), np.asarray(gw_dense),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("k", (1, 5, 8))
def test_dw_kernel_freq_path_matches_oracle(k):
    """`_freq_bwd`: grads w.r.t. the plan's frozen (wr, wi) — the raw
    frequency cotangents, padded to the plan's tile grid."""
    p, q, B = 3, 2, 4
    w = _rand((p, q, k), seed=1, scale=(q * k) ** -0.5)
    x = _rand((B, q * k), seed=2)
    plan = build_plan(w)
    g = jax.grad(lambda pl: (pl.apply(x) ** 2).sum())(plan)
    z = plan.apply(x)
    dwr_o, dwi_o = _dw_freq_cotangents(
        x, 2.0 * z, plan.wr.shape[0], plan.wr.shape[1], k)
    np.testing.assert_allclose(np.asarray(g.wr), np.asarray(dwr_o),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(g.wi), np.asarray(dwi_o),
                               rtol=1e-5, atol=1e-5)


def test_dw_kernel_with_bias_activation_epilogue():
    """Full fused-epilogue backward (act' chained before the dw kernel)."""
    B, p, q, k = 5, 2, 3, 8
    w = _rand((p, q, k), seed=1, scale=(q * k) ** -0.5)
    x = _rand((B, q * k), seed=2)
    b = _rand((p * k,), seed=3)
    f = lambda x, w, b: (
        block_circulant_matmul(x, w, bias=b, activation="tanh") ** 2).sum()

    def ref(x, w, b):
        y = jnp.tanh(block_circulant_matmul_ref(x, w) + b)
        return (y ** 2).sum()

    for a, e in zip(jax.grad(f, (0, 1, 2))(x, w, b),
                    jax.grad(ref, (0, 1, 2))(x, w, b)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(e),
                                   rtol=1e-3, atol=1e-4)


@pytest.mark.parametrize("in_dim,out_dim,requested,expect_k", [
    (20, 12, 8, 4),     # gcd fallback: 8 -> 4
    (9, 6, 8, 3),       # odd fallback: 8 -> 3
])
def test_dw_kernel_non_divisible_linear_dims(in_dim, out_dim, requested,
                                             expect_k):
    """Mirror of the conformance Linear grid, on the gradient path."""
    from repro.configs.base import SWMConfig
    from repro.nn.linear import Linear
    from repro.nn.module import init_params

    lin = Linear(in_dim=in_dim, out_dim=out_dim, family="ffn",
                 swm=SWMConfig(block_size=requested, impl="pallas"),
                 dtype="float32")
    assert lin.block_size == expect_k
    params = init_params(lin.specs(), 0)
    x = _rand((4, in_dim), seed=2)
    t = _rand((4, out_dim), seed=3)
    gw = jax.grad(lambda w: (lin({"w": w}, x) * t).sum())(params["w"])
    gw_dense = jax.grad(
        lambda w: ((x @ blocks_to_dense(w).T) * t).sum())(params["w"])
    np.testing.assert_allclose(np.asarray(gw), np.asarray(gw_dense),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# rfft dedup: the backward must reuse the forward's (wr, wi) residuals
# ---------------------------------------------------------------------------


def test_bwd_reuses_forward_freq_weights():
    """One rfft(w) per train step: the forward's; `_bwd` carries (wr, wi)
    in the residuals instead of re-transforming the full weight table."""
    p, q, k = 2, 3, 8
    w = _rand((p, q, k), seed=1)
    x = _rand((4, q * k), seed=2)
    jaxpr = jax.make_jaxpr(
        jax.grad(lambda w: (block_circulant_matmul(x, w) ** 2).sum()))(w)
    ffts = [e for e in iter_eqns(jaxpr) if e.primitive.name == "fft"]
    assert len(ffts) == 1, [str(e) for e in ffts]


# ---------------------------------------------------------------------------
# Structural: cached train-step jaxpr has no dense (P, Q)-grid dot_general
# ---------------------------------------------------------------------------


def test_train_step_linear_jaxpr_kernel_backed():
    """SGD train step over a circulant Linear: every contraction runs as a
    Pallas launch (forward z + dx + dw = 3); no dot_general at all outside
    kernels, in particular none spanning the (p=3, q=7) block grid."""
    from repro.configs.base import SWMConfig
    from repro.nn.linear import Linear
    from repro.nn.module import init_params

    p, q, k = 3, 7, 8
    lin = Linear(in_dim=q * k, out_dim=p * k, family="ffn",
                 swm=SWMConfig(block_size=k, impl="pallas"), dtype="float32")
    params = init_params(lin.specs(), 0)
    batch = {"x": _rand((4, q * k), seed=2), "y": _rand((4, p * k), seed=3)}
    loss = lambda params, b: ((lin(params, b["x"]) - b["y"]) ** 2).mean()
    step = make_grad_step(loss)
    new_params, l0 = step(params, batch)        # the cached executable runs
    assert np.isfinite(float(l0))
    jp = jax.make_jaxpr(jax.value_and_grad(loss))(params, batch)
    dots = outer_dot_shapes(jp)
    assert dots == [], dots
    assert count_pallas_launches(jp) == 3       # forward z + dx + dw
    # a few steps actually descend
    for i in range(5):
        params, l = step(params, batch)
    assert float(l) < float(l0)


def test_train_step_lstm_jaxpr_kernel_backed():
    """Train step over an SWM-LSTM cell (fused-gate circulant launches):
    no dense contraction outside kernels anywhere in the scan body."""
    from repro.configs.base import SWMConfig
    from repro.core.lstm import SWMLSTM
    from repro.nn.module import init_params

    cell = SWMLSTM(d_in=16, d_cell=24, d_proj=16,
                   swm=SWMConfig(block_size=8, impl="pallas",
                                 targets=("attn", "ffn", "lstm")))
    params = init_params(cell.specs(), 0)
    batch = _rand((4, 5, 16), seed=2)
    loss = lambda params, xs: (cell(params, xs)[0] ** 2).mean()
    jp = jax.make_jaxpr(jax.value_and_grad(loss))(params, batch)
    dots = outer_dot_shapes(jp)
    assert dots == [], dots
    assert count_pallas_launches(jp) > 0
    step = make_grad_step(loss)
    _, l = step(params, batch)
    assert np.isfinite(float(l))


def test_train_step_frozen_plan_jaxpr_no_fft_no_dense():
    """Frequency-domain training (frozen plan params): the whole step —
    forward AND both adjoints — contains no fft primitive and no dense
    (P, Q) contraction; the weight adjoint is the dw kernel launch."""
    p, q, k = 3, 7, 8
    w = _rand((p, q, k), seed=1, scale=(q * k) ** -0.5)
    plan = build_plan(w)
    batch = {"x": _rand((4, q * k), seed=2), "y": _rand((4, p * k), seed=3)}
    loss = lambda pl, b: ((pl.apply(b["x"]) - b["y"]) ** 2).mean()
    jp = jax.make_jaxpr(jax.value_and_grad(loss))(plan, batch)
    assert NoFFT().check(jp) == []
    assert NoDenseDotGeneral().check(jp) == []
    assert LaunchBudget(exact=3).check(jp) == []
    # the construction-time gate agrees: audit_args runs the same rules
    # (NoFFT + NoDenseDotGeneral) before anything compiles
    make_grad_step(loss, audit_args=(plan, batch))
    # and a loss that re-transforms per step is rejected at construction,
    # with the offending primitive and call site in the message
    bad = lambda pl, b: ((block_circulant_matmul(
        b["x"], jnp.fft.irfft(pl.wr + 1j * pl.wi, n=k, axis=-1))
        - b["y"]) ** 2).mean()
    with pytest.raises(StructuralContractError, match=r"NoFFT.*\.py:\d+"):
        make_grad_step(bad, audit_args=(plan, batch))


# ---------------------------------------------------------------------------
# Backward geometry cache
# ---------------------------------------------------------------------------


def test_dw_geometry_cached_across_plans_and_steps():
    clear_plan_cache()
    w1 = _rand((3, 5, 8), seed=0)
    w2 = _rand((3, 5, 8), seed=9)
    x = _rand((4, 40), seed=1)
    for w in (w1, w2):
        jax.grad(lambda w: (block_circulant_matmul(x, w) ** 2).sum())(w)
    info = dw_geometry_cache_info()
    assert info.misses >= 1
    assert info.hits >= 1          # second train step reused the geometry
    p1, p2 = build_plan(w1), build_plan(w2)
    assert p1.dw_tiles() == p2.dw_tiles()
    geo = dw_geometry(p1.wr.shape[0], p1.wr.shape[1], 8)
    assert (geo.pt, geo.qt) == p1.dw_tiles()
    assert geo.p_pad % geo.pt == 0 and geo.q_pad % geo.qt == 0
