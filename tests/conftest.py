"""Test bootstrap: a minimal deterministic `hypothesis` shim.

The container does not ship `hypothesis`; the property tests only use
``given`` / ``settings`` / ``strategies.{integers,sampled_from}``. When the
real library is absent we install a tiny deterministic stand-in that draws
``max_examples`` samples from a seeded PRNG — the property tests still
exercise many shapes, just without shrinking/replay.
"""

import random
import sys
import types


def _install_hypothesis_stub():
    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def sample(self, rng):
            return self._draw(rng)

    def integers(min_value, max_value):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    def sampled_from(elements):
        elements = list(elements)
        return _Strategy(lambda rng: rng.choice(elements))

    def floats(min_value=0.0, max_value=1.0, **_):
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    def booleans():
        return _Strategy(lambda rng: rng.random() < 0.5)

    def lists(elem, min_size=0, max_size=8, **_):
        return _Strategy(
            lambda rng: [elem.sample(rng)
                         for _ in range(rng.randint(min_size, max_size))]
        )

    def settings(**kwargs):
        def deco(fn):
            setattr(fn, "_stub_settings", kwargs)
            return fn

        return deco

    def given(*strategies, **kw_strategies):
        def deco(fn):
            def wrapper(*args, **kwargs):
                conf = (getattr(wrapper, "_stub_settings", None)
                        or getattr(fn, "_stub_settings", {}))
                n = int(conf.get("max_examples", 10))
                rng = random.Random(0)
                for _ in range(n):
                    drawn = [s.sample(rng) for s in strategies]
                    drawn_kw = {k: s.sample(rng)
                                for k, s in kw_strategies.items()}
                    fn(*args, *drawn, **kwargs, **drawn_kw)

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return wrapper

        return deco

    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    st = types.ModuleType("hypothesis.strategies")
    st.integers = integers
    st.sampled_from = sampled_from
    st.floats = floats
    st.booleans = booleans
    st.lists = lists
    mod.strategies = st
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st


try:  # pragma: no cover - depends on environment
    import hypothesis  # noqa: F401
except ImportError:  # pragma: no cover
    _install_hypothesis_stub()


def pytest_configure(config):
    # pytest-timeout is installed in CI (hard hang caps on the serve
    # jobs) but not in the base container; register the marker so local
    # runs don't warn about it
    config.addinivalue_line(
        "markers",
        "timeout(seconds): hard wall-clock cap, enforced when "
        "pytest-timeout is installed")
