"""Frequency-domain execution plans: fused epilogue, plan cache, multi-proj.

Everything runs the Pallas kernel in interpret mode (CPU container) against
the dense oracle ``ref.block_circulant_matmul_ref`` composed with the same
bias/activation epilogue.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.test_util import check_grads

from repro.analysis import LaunchBudget, NoFFT, NoWeightConcat, iter_eqns
from repro.kernels.block_circulant import (BCPlan, block_circulant_matmul,
                                           block_circulant_matmul_multi,
                                           build_multi_plan, build_plan,
                                           freq_weights)
from repro.kernels.block_circulant.kernel import (apply_activation,
                                                  choose_blocks,
                                                  vmem_estimate)
from repro.kernels.block_circulant.plan import plan_geometry
from repro.kernels.block_circulant.ref import block_circulant_matmul_ref

jax.config.update("jax_platform_name", "cpu")


def _rand(shape, seed=0):
    return jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32)


def _ref(x, w, b=None, act="none"):
    y = block_circulant_matmul_ref(x, w)
    if b is not None:
        y = y + b
    return apply_activation(y, act)


# k=12: non-power-of-two; (10, 10, 128): requires (p, q) tile padding
SHAPES = [(4, 3, 5, 8), (7, 2, 3, 12), (4, 10, 10, 128)]


# ---------------------------------------------------------------------------
# Fused epilogue
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B,p,q,k", SHAPES)
@pytest.mark.parametrize("act", ["none", "relu", "tanh", "sigmoid", "gelu"])
def test_fused_epilogue_matches_reference(B, p, q, k, act):
    # variance-preserving weight scale (as Linear uses) so pre-activations
    # are O(1) — the regime the 1e-5 rel-error bound is stated for
    w = _rand((p, q, k)) * (q * k) ** -0.5
    x = _rand((B, q * k), seed=1)
    b = _rand((p * k,), seed=2)
    y = block_circulant_matmul(x, w, bias=b, activation=act)
    y_ref = _ref(x, w, b, act)
    rel = float(jnp.max(jnp.abs(y - y_ref)) /
                jnp.maximum(jnp.max(jnp.abs(y_ref)), 1e-6))
    assert rel <= 1e-5, rel


@pytest.mark.parametrize("B,p,q,k", SHAPES[:2])
def test_fused_epilogue_gradcheck(B, p, q, k):
    """check_grads + grads vs dense-oracle autodiff, bias + tanh fused."""
    w = _rand((p, q, k))
    x = _rand((B, q * k), seed=1)
    b = _rand((p * k,), seed=2)

    f = lambda x, w, b: (
        block_circulant_matmul(x, w, bias=b, activation="tanh") ** 2
    ).sum()
    r = lambda x, w, b: (_ref(x, w, b, "tanh") ** 2).sum()
    check_grads(f, (x, w, b), order=1, modes=["rev"], atol=1e-2, rtol=1e-2)
    gk = jax.grad(f, (0, 1, 2))(x, w, b)
    gr = jax.grad(r, (0, 1, 2))(x, w, b)
    for a, e in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(e),
                                   rtol=1e-3, atol=1e-4)


def test_backward_dx_uses_kernel_not_fft():
    """dx comes from the kernel with conj/index-reversed freq weights: the
    frozen-path VJP jaxpr must not contain any fft primitive."""
    p, q, k = 2, 3, 16
    w = _rand((p, q, k))
    x = _rand((4, q * k), seed=1)
    plan = build_plan(w)
    jaxpr = jax.make_jaxpr(jax.grad(lambda x: plan.apply(x).sum()))(x)
    assert NoFFT().check(jaxpr) == []


# ---------------------------------------------------------------------------
# Plans
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B,p,q,k", SHAPES)
def test_plan_bitwise_identical_to_uncached(B, p, q, k):
    w = _rand((p, q, k))
    x = _rand((B, q * k), seed=1)
    b = _rand((p * k,), seed=2)
    plan = build_plan(w, bias=b, activation="sigmoid")
    y_plan = plan.apply(x)
    y_call = block_circulant_matmul(x, w, bias=b, activation="sigmoid")
    assert y_plan.shape == y_call.shape
    assert bool(jnp.all(y_plan == y_call)), "plan output must be bitwise equal"
    # reuse across calls: still identical
    assert bool(jnp.all(plan.apply(x) == y_plan))


def test_plan_jaxpr_has_no_fft():
    """The acceptance check: no fft primitive in the plan-cached forward."""
    w = _rand((3, 5, 8))
    plan = build_plan(w, bias=_rand((24,), seed=2), activation="gelu")
    x = _rand((4, 40), seed=1)
    assert NoFFT().check(jax.make_jaxpr(plan.apply)(x)) == []
    # the per-call path (which must rfft the weights) does contain one —
    # and the auditor's violation names the rfft call site
    vs = NoFFT().check(jax.make_jaxpr(
        lambda x, w: block_circulant_matmul(x, w))(x, w))
    assert vs and vs[0].primitive == "fft" and "ops.py" in vs[0].where


def test_plan_gradcheck_wrt_x():
    """Plan-backed forward (frozen weights) differentiates w.r.t. x."""
    p, q, k = 2, 3, 12
    w = _rand((p, q, k))
    x = _rand((5, q * k), seed=1)
    b = _rand((p * k,), seed=2)
    plan = build_plan(w, bias=b, activation="tanh")
    f = lambda x: (plan.apply(x) ** 2).sum()
    r = lambda x: (_ref(x, w, b, "tanh") ** 2).sum()
    check_grads(f, (x,), order=1, modes=["rev"], atol=1e-2, rtol=1e-2)
    np.testing.assert_allclose(np.asarray(jax.grad(f)(x)),
                               np.asarray(jax.grad(r)(x)),
                               rtol=1e-3, atol=1e-4)


def test_plan_geometry_cache_shared():
    plan_geometry.cache_clear()
    w1 = _rand((3, 5, 8), seed=0)
    w2 = _rand((3, 5, 8), seed=9)
    p1 = build_plan(w1)
    p2 = build_plan(w2)
    info = plan_geometry.cache_info()
    assert info.hits >= 1          # second plan reused the cached geometry
    assert (p1.pt, p1.qt) == (p2.pt, p2.qt)
    x = _rand((4, 40), seed=1)
    np.testing.assert_allclose(
        np.asarray(p1.apply(x)),
        np.asarray(block_circulant_matmul(x, w1)), rtol=1e-6, atol=1e-6)


def test_plan_is_pytree():
    """Plans jit/flatten cleanly (weights are leaves, geometry is static)."""
    plan = build_plan(_rand((2, 2, 16)))
    leaves = jax.tree.leaves(plan)
    assert any(l.shape == plan.wr.shape for l in leaves)
    x = _rand((4, 32), seed=1)
    y0 = plan.apply(x)
    y1 = jax.jit(lambda pl, x: pl.apply(x))(plan, x)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                               rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# Stacked-p multi-projection (gate / QKV fusion)
# ---------------------------------------------------------------------------


def test_multi_projection_matches_per_gate():
    """4 LSTM-style gates, one launch == 4 separate matmul→bias→sigmoid."""
    q, k = 4, 8
    ps = [3, 3, 3, 3]
    ws = [_rand((p, q, k), seed=i) for i, p in enumerate(ps)]
    bs = [_rand((p * k,), seed=10 + i) for i, p in enumerate(ps)]
    x = _rand((6, q * k), seed=20)
    fused = block_circulant_matmul_multi(x, ws, biases=bs,
                                         activation="sigmoid")
    assert len(fused) == 4
    for y, w, b in zip(fused, ws, bs):
        y_ref = _ref(x, w, b, "sigmoid")
        rel = float(jnp.max(jnp.abs(y - y_ref)) /
                    jnp.max(jnp.abs(y_ref)))
        assert rel <= 1e-5, rel


def test_multi_projection_mixed_widths_and_grads():
    """QKV-style: different p_i per projection; grads match per-proj refs."""
    q, k = 3, 12
    ps = [4, 2, 2]
    ws = [_rand((p, q, k), seed=i) for i, p in enumerate(ps)]
    x = _rand((5, q * k), seed=20)
    fused = block_circulant_matmul_multi(x, ws)
    for y, w in zip(fused, ws):
        np.testing.assert_allclose(
            np.asarray(y), np.asarray(block_circulant_matmul_ref(x, w)),
            rtol=2e-5, atol=2e-5)

    loss = lambda ws: sum((o ** 2).sum()
                          for o in block_circulant_matmul_multi(x, ws))
    ref = lambda ws: sum((block_circulant_matmul_ref(x, w) ** 2).sum()
                         for w in ws)
    g = jax.grad(loss)(ws)
    gr = jax.grad(ref)(ws)
    for a, e in zip(g, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(e),
                                   rtol=1e-3, atol=1e-4)


def test_multi_plan_single_launch_outputs():
    q, k = 4, 8
    ps = [2, 3]
    ws = [_rand((p, q, k), seed=i) for i, p in enumerate(ps)]
    bs = [_rand((p * k,), seed=5 + i) for i, p in enumerate(ps)]
    mp = build_multi_plan(ws, biases=bs, activation="relu")
    assert mp.splits == (2, 3)
    x = _rand((4, q * k), seed=9)
    outs = mp.apply_multi(x)
    for y, w, b in zip(outs, ws, bs):
        np.testing.assert_allclose(
            np.asarray(y), np.asarray(_ref(x, w, b, "relu")),
            rtol=2e-5, atol=2e-5)
    jp = jax.make_jaxpr(mp.apply_multi)(x)
    assert NoFFT().check(jp) == []
    assert LaunchBudget(exact=1).check(jp) == []   # one fused launch


def test_multi_plan_rejects_mismatched_tables():
    with pytest.raises(ValueError):
        build_multi_plan([_rand((2, 3, 8)), _rand((2, 4, 8))])


# ---------------------------------------------------------------------------
# Frozen freq weights through Linear / freeze_params
# ---------------------------------------------------------------------------


def test_freeze_params_roundtrip_linear():
    from repro.configs.base import SWMConfig
    from repro.kernels.block_circulant.plan import freeze_params
    from repro.nn.linear import Linear
    from repro.nn.module import init_params

    lin = Linear(in_dim=24, out_dim=16, family="ffn",
                 swm=SWMConfig(block_size=8, impl="pallas"), dtype="float32")
    params = init_params(lin.specs(), 0)
    frozen = freeze_params(lin.specs(), params)
    # the time-domain table is DROPPED (serve memory: w would sit unused)
    assert set(frozen) == {"wr", "wi"}
    wr, wi = freq_weights(params["w"])
    np.testing.assert_array_equal(np.asarray(frozen["wr"]), np.asarray(wr))
    # idempotent
    assert freeze_params(lin.specs(), frozen)["wr"] is frozen["wr"]
    x = _rand((4, 24), seed=1)
    np.testing.assert_allclose(
        np.asarray(lin(frozen, x)), np.asarray(lin(params, x)),
        rtol=1e-6, atol=1e-6)
    assert NoFFT().check(
        jax.make_jaxpr(lambda p, x: lin(p, x))(frozen, x)) == []


# ---------------------------------------------------------------------------
# Pre-concatenated fused frozen groups (attention QKV, LSTM gates)
# ---------------------------------------------------------------------------


def _attn(impl="dft"):
    from repro.configs.base import ModelConfig, SWMConfig
    from repro.nn.attention import Attention

    cfg = ModelConfig(name="fuse", n_layers=2, d_model=32, n_heads=2,
                      n_kv_heads=1, head_dim=16, d_ff=64, vocab=48,
                      remat="none", param_dtype="float32",
                      compute_dtype="float32",
                      swm=SWMConfig(block_size=8, impl=impl))
    return Attention(cfg)


@pytest.mark.parametrize("impl", ["dft", "pallas"])
def test_freeze_params_fuses_attention_qkv(impl):
    """freeze_params pre-concatenates the Q/K/V frozen tables into one
    stacked table (FUSED_KEY): outputs are bit-identical to the
    per-projection frozen path and the fused launch's jaxpr contains no
    concatenate — the weight stack is resident, not rebuilt per trace."""
    from repro.kernels.block_circulant.plan import FUSED_KEY, freeze_params
    from repro.nn.module import init_params

    att = _attn(impl)
    params = init_params(att.specs(), 0)
    frozen = freeze_params(att.specs(), params)
    assert FUSED_KEY in frozen
    fused = frozen[FUSED_KEY]
    # stacked along p: q (4 blocks) + k (2) + v (2) of (q=4, K=5) tables
    assert fused["wr"].shape == (8, 4, 5) and fused["wi"].shape == (8, 4, 5)
    x = _rand((2, 3, 32), seed=1)
    pos = jnp.broadcast_to(jnp.arange(3, dtype=jnp.int32), (2, 3))
    y_raw, _ = att(params, x, pos)
    y_fused, _ = att(frozen, x, pos)
    np.testing.assert_allclose(np.asarray(y_raw), np.asarray(y_fused),
                               rtol=2e-5, atol=2e-5)
    # bit-identical to the old frozen path (concat-in-trace of wr_i/wi_i)
    nofuse = {k: v for k, v in frozen.items() if k != FUSED_KEY}
    y_perproj, _ = att(nofuse, x, pos)
    assert bool(jnp.all(y_fused == y_perproj))
    jp = jax.make_jaxpr(lambda p, xx: att._fused_qkv(p, xx))(frozen, x)
    assert NoWeightConcat().check(jp) == []        # strict: no concat at all
    if impl == "pallas":
        # the kernel path has no fft primitive at all; the dft/freq path
        # still transforms ACTIVATIONS (the paper's streaming x̂) — only
        # the weight-side rfft is frozen out
        assert NoFFT().check(jp) == []
    # idempotent: re-freezing a fused tree is the identity
    assert freeze_params(att.specs(), frozen) is frozen


def test_freeze_params_fuses_lstm_gates():
    """The 8 gate tables fuse along q (x ++ recurrent) then p (4 gates),
    gate biases pre-concatenate alongside; the frozen step's only
    concatenate is the [x_t ; y_prev] activation concat."""
    from repro.configs.base import SWMConfig
    from repro.core.lstm import SWMLSTM
    from repro.kernels.block_circulant.plan import FUSED_KEY, freeze_params
    from repro.nn.module import init_params

    lstm = SWMLSTM(d_in=16, d_cell=32, d_proj=16,
                   swm=SWMConfig(block_size=8, impl="dft",
                                 targets=("attn", "ffn", "lstm")))
    assert lstm._fused_gate_k == 8
    params = init_params(lstm.specs(), 0)
    frozen = freeze_params(lstm.specs(), params)
    assert FUSED_KEY in frozen
    fused = frozen[FUSED_KEY]
    # 4 gates x (dc/k = 4) output blocks; (di + dp)/k = 4 input blocks
    assert fused["wr"].shape == (16, 4, 5)
    assert fused["bias"].shape == (4 * 32,)
    xs = _rand((2, 4, 16), seed=2)
    y_raw, _ = lstm(params, xs)
    y_fused, _ = lstm(frozen, xs)
    np.testing.assert_allclose(np.asarray(y_raw), np.asarray(y_fused),
                               rtol=2e-5, atol=2e-5)
    nofuse = {k: v for k, v in frozen.items() if k != FUSED_KEY}
    y_perproj, _ = lstm(nofuse, xs)
    assert bool(jnp.all(y_fused == y_perproj))
    jp = jax.make_jaxpr(lambda p, a, b, c: lstm.step(p, a, b, c))(
        frozen, xs[:, 0], jnp.zeros((2, 16)), jnp.zeros((2, 32)))
    concats = [e for e in iter_eqns(jp)
               if e.primitive.name == "concatenate"]
    assert len(concats) == 1                       # [x_t ; y_prev] only
    # and the weight-concat rule agrees: the survivor is activation-side
    n_params = len(jax.tree.leaves(frozen))
    assert NoWeightConcat(
        table_shapes=[tuple(fused["wr"].shape)],
        n_param_invars=n_params).check(jp) == []


def test_count_frozen_tables_skips_fused_entries():
    """The fused entry is an eager concat of already-frozen tables — it
    must not inflate the rfft(w) accounting the freeze-once regression
    compares against."""
    from repro.kernels.block_circulant.plan import (FUSED_KEY,
                                                    count_frozen_tables,
                                                    freeze_params)
    from repro.nn.module import init_params

    att = _attn()
    frozen = freeze_params(att.specs(), init_params(att.specs(), 0))
    assert FUSED_KEY in frozen
    assert count_frozen_tables(frozen) == 4        # q, k, v, o — not _fused


# ---------------------------------------------------------------------------
# VMEM estimate is the single source of truth
# ---------------------------------------------------------------------------


def test_vmem_estimate_consistent_with_choose_blocks():
    for (B, p, q, k) in [(128, 8, 8, 128), (256, 24, 8, 128), (64, 32, 32, 16)]:
        bB, pt, qt = choose_blocks(B, p, q, k)
        assert vmem_estimate(bB, pt, qt, k) <= 8 * 1024 * 1024
    # monotone in every tile dim
    assert vmem_estimate(64, 8, 8, 128) < vmem_estimate(128, 8, 8, 128)
    assert vmem_estimate(64, 8, 8, 128) < vmem_estimate(64, 16, 8, 128)
