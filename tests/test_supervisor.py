"""Self-healing supervision and the persistent prefix store.

The acceptance chaos test drives a 3-tenant bursty workload through the
supervised fair engine, kills the engine mid-stream with an injected
fatal, and asserts the full contract: the supervisor restores the latest
snapshot onto a fresh engine, re-queues post-snapshot in-flight work,
every request's incrementally-collected token stream is bit-identical to
the fault-free run (zero duplicated or lost tokens), no tenant is
starved at a DRR round boundary, TTFT histograms ride through
snapshot/restore, and the compile budget is unchanged.
"""

import tempfile

import numpy as np
import pytest

import jax

from repro.configs.base import ModelConfig, SWMConfig
from repro.ft.checkpoint import available_steps, save_checkpoint
from repro.models.decoder import HybridDecoderLM
from repro.nn.module import init_params
from repro.serve.engine import Request, ServeEngine
from repro.serve.guard import (TERMINAL_STATES, ManualClock,
                               ServeFaultInjector)
from repro.serve.prefix_store import PrefixStore
from repro.serve.supervisor import Supervisor, SupervisorGaveUp

jax.config.update("jax_platform_name", "cpu")

BATCH, CACHE = 2, 32


def _cfg(**kw):
    base = dict(name="supervisor", n_layers=2, d_model=32, n_heads=2,
                n_kv_heads=1, head_dim=16, d_ff=64, vocab=48, remat="none",
                param_dtype="float32", compute_dtype="float32",
                swm=SWMConfig(block_size=8, impl="dft"))
    base.update(kw)
    return ModelConfig(**base)


@pytest.fixture(scope="module")
def lm():
    cfg = _cfg()
    model = HybridDecoderLM(cfg)
    params = init_params(model.specs(), 0)
    return cfg, model, params


def _engine(lm, **kw):
    cfg, model, params = lm
    kw.setdefault("batch", BATCH)
    kw.setdefault("cache_len", CACHE)
    return ServeEngine(model, cfg, params, **kw)


WEIGHTS = {"a": 2, "b": 1, "c": 1}


def _tenant_reqs(seed, n_per, max_new=4):
    rng = np.random.default_rng(seed)
    return [Request(rng.integers(0, 48, size=5).astype(np.int32),
                    max_new=max_new, tenant=t)
            for t in sorted(WEIGHTS) for _ in range(n_per)]


def _drive_supervised(sup, clk, srids, max_steps=600):
    """Step to idle, collecting each request's at-most-once stream."""
    streams = {r: [] for r in srids}
    fair_at = None
    sum_w = sum(WEIGHTS.values())
    n_per = len(srids) // len(WEIGHTS)
    steps = 0
    while True:
        alive = sup.step()
        steps += 1
        clk.advance(0.002)
        for r in srids:
            new, _ = sup.take_new_tokens(r)
            streams[r].extend(new)
        admitted = {t: ts.admitted for t, ts in sup.stats.tenants.items()}
        total = sum(admitted.values())
        if fair_at is None and \
                2 * sum_w <= total <= len(WEIGHTS) * n_per - 2:
            fair_at = dict(admitted)
        if not alive:
            break
        assert steps < max_steps, "supervised engine hang"
    return streams, fair_at, steps


class TestSelfHealChaos:
    @pytest.mark.timeout(300)
    def test_midstream_fatal_full_contract(self, lm):
        """The acceptance-criteria chaos test (see module docstring)."""
        reqs = _tenant_reqs(0, 6)
        base_eng = _engine(lm, policy="fair", tenant_weights=WEIGHTS)
        base = base_eng.generate(reqs)

        clk = ManualClock()
        # the fairness window freezes at the first DRR boundary past two
        # full rounds (~10 admissions); decode launch 20 is comfortably
        # after that but mid-stream, so the heal cannot inflate the
        # frozen per-tenant counts
        inj = ServeFaultInjector(fatal_decode_at={20})
        with tempfile.TemporaryDirectory() as snap_dir:
            def factory():
                return _engine(lm, policy="fair", tenant_weights=WEIGHTS,
                               snapshot_dir=snap_dir, snapshot_every=2,
                               clock=clk, fault_injector=inj)

            sup = Supervisor(factory)
            budget_p = sup.engine.max_prefill_variants
            budget_d = sup.engine.max_decode_variants
            srids = [sup.submit(r) for r in reqs]
            streams, fair_at, _ = _drive_supervised(sup, clk, srids)

            assert sup.restarts == 1
            assert sup.stats.recoveries == 1
            # zero duplicated or lost tokens: every stream bit-identical
            # to the fault-free run
            for i, r in enumerate(srids):
                assert tuple(streams[r]) == tuple(base[i]), \
                    f"request {i} stream diverged across the heal"
            # no tenant starved at the DRR round boundary
            assert fair_at is not None
            total = sum(fair_at.values())
            for t, w in WEIGHTS.items():
                share = total * w / sum(WEIGHTS.values())
                assert abs(fair_at.get(t, 0) - share) <= w + 1, \
                    f"tenant {t} starved: {fair_at} at boundary {total}"
            # TTFT instrumentation rode through snapshot/restore
            assert sup.stats.ttft_ms.count == len(reqs)
            assert sup.stats.ttft_ms.p99 is not None
            # compile budget unchanged on the replacement engine
            assert sup.engine.prefill_compiles <= budget_p
            assert sup.engine.decode_compiles <= budget_d
            # terminal claims by supervisor rid
            out = sup.drain(srids)
            assert [out[r] for r in srids] == [list(b) for b in base]

    def test_fatal_during_prefill_requeues_unadmitted(self, lm):
        reqs = _tenant_reqs(1, 2)
        base = _engine(lm, policy="fair",
                       tenant_weights=WEIGHTS).generate(reqs)
        clk = ManualClock()
        inj = ServeFaultInjector(fatal_prefill_at={1})
        with tempfile.TemporaryDirectory() as snap_dir:
            def factory():
                return _engine(lm, policy="fair", tenant_weights=WEIGHTS,
                               snapshot_dir=snap_dir, snapshot_every=1,
                               clock=clk, fault_injector=inj)

            sup = Supervisor(factory)
            srids = [sup.submit(r) for r in reqs]
            streams, _, _ = _drive_supervised(sup, clk, srids)
            assert sup.restarts == 1
            for i, r in enumerate(srids):
                assert tuple(streams[r]) == tuple(base[i])

    def test_gives_up_after_max_restarts(self, lm):
        clk = ManualClock()
        inj = ServeFaultInjector(fatal_decode_at={1, 3})
        with tempfile.TemporaryDirectory() as snap_dir:
            def factory():
                return _engine(lm, snapshot_dir=snap_dir, snapshot_every=1,
                               clock=clk, fault_injector=inj)

            sup = Supervisor(factory, max_restarts=1)
            srids = [sup.submit(r) for r in _tenant_reqs(2, 2, max_new=6)]
            with pytest.raises(SupervisorGaveUp, match="max_restarts"):
                for _ in range(200):
                    sup.step()
                    clk.advance(0.002)
            assert sup.restarts == 2
            # already-delivered tokens stay delivered: poll works on the
            # dead engine and the at-most-once ledger is intact
            delivered = []
            for r in srids:
                new, _ = sup.take_new_tokens(r)
                delivered.extend(new)
            assert delivered, "no tokens survived the give-up"

    def test_requires_snapshot_dir_by_default(self, lm):
        with pytest.raises(ValueError, match="snapshot_dir"):
            Supervisor(lambda: _engine(lm))

    def test_replay_from_scratch_mode(self, lm):
        reqs = _tenant_reqs(3, 2)
        base = _engine(lm, policy="fair",
                       tenant_weights=WEIGHTS).generate(reqs)
        clk = ManualClock()
        inj = ServeFaultInjector(fatal_decode_at={5})

        def factory():
            return _engine(lm, policy="fair", tenant_weights=WEIGHTS,
                           clock=clk, fault_injector=inj)

        sup = Supervisor(factory, require_snapshots=False)
        srids = [sup.submit(r) for r in reqs]
        streams, _, _ = _drive_supervised(sup, clk, srids)
        # no snapshot: the heal replays everything; at-most-once emission
        # still yields each token exactly once
        assert sup.restarts == 1
        for i, r in enumerate(srids):
            assert tuple(streams[r]) == tuple(base[i])

    def test_heal_walks_past_corrupt_latest_snapshot(self, lm):
        reqs = _tenant_reqs(4, 2)
        base = _engine(lm, policy="fair",
                       tenant_weights=WEIGHTS).generate(reqs)
        clk = ManualClock()
        inj = ServeFaultInjector(fatal_decode_at={6})
        with tempfile.TemporaryDirectory() as snap_dir:
            def factory():
                eng = _engine(lm, policy="fair", tenant_weights=WEIGHTS,
                              snapshot_dir=snap_dir, snapshot_every=2,
                              clock=clk, fault_injector=inj)
                return eng

            sup = Supervisor(factory)
            srids = [sup.submit(r) for r in reqs]
            # run a few steps so real snapshots exist, then plant a
            # corrupt snapshot as the newest step
            for _ in range(4):
                sup.step()
                clk.advance(0.002)
            good = available_steps(snap_dir)
            assert good, "no snapshot written in 4 steps"
            save_checkpoint(snap_dir, max(good) + 100,
                            {"meta": np.zeros(3, np.uint8)})
            streams, _, _ = _drive_supervised(sup, clk, srids)
            assert sup.restarts == 1
            for i, r in enumerate(srids):
                assert tuple(streams[r]) == tuple(base[i]), \
                    "heal did not fall back past the corrupt snapshot"


class TestPrefixStore:
    def _rows(self, val, n=64):
        return {"s00000": np.full((n,), val, np.float32)}

    def test_put_get_hottest_order(self):
        st = PrefixStore(capacity_bytes=1 << 20)
        p1 = np.asarray([1, 2, 3], np.int32)
        p2 = np.asarray([4, 5], np.int32)
        st.put(p1, self._rows(1.0), "fp")
        st.put(p2, self._rows(2.0), "fp")
        hot = [tuple(p.tolist()) for p, _ in st.hottest()]
        assert hot == [(4, 5), (1, 2, 3)]     # MRU first
        st.touch(p1)
        hot = [tuple(p.tolist()) for p, _ in st.hottest()]
        assert hot == [(1, 2, 3), (4, 5)]

    def test_capacity_evicts_coldest(self):
        entry = self._rows(0.0)
        nb = int(np.asarray([0, 0], np.int32).nbytes
                 + entry["s00000"].nbytes)
        st = PrefixStore(capacity_bytes=2 * nb)
        for i in range(3):
            st.put(np.asarray([i, i], np.int32), self._rows(float(i)), "fp")
        assert len(st) == 2 and st.evictions == 1
        keys = [tuple(p.tolist()) for p, _ in st.hottest()]
        assert (0, 0) not in keys             # coldest evicted

    def test_oversize_entry_refused(self):
        st = PrefixStore(capacity_bytes=16)
        ok = st.put(np.asarray([1], np.int32), self._rows(0.0), "fp")
        assert not ok and len(st) == 0

    def test_fingerprint_mismatch_raises(self):
        st = PrefixStore(capacity_bytes=1 << 20)
        st.put(np.asarray([1], np.int32), self._rows(0.0), "geom-A")
        with pytest.raises(ValueError, match="geometry"):
            st.put(np.asarray([2], np.int32), self._rows(0.0), "geom-B")

    def test_persistence_round_trip_preserves_lru(self):
        with tempfile.TemporaryDirectory() as d:
            st = PrefixStore(capacity_bytes=1 << 20, persist_dir=d)
            for i in range(3):
                st.put(np.asarray([i, i + 1], np.int32),
                       self._rows(float(i)), "fp")
            st.touch(np.asarray([0, 1], np.int32))   # make entry 0 hottest
            st.save()
            st2 = PrefixStore.load(d)
            assert len(st2) == 3
            assert st2.fingerprint == "fp"
            hot = [tuple(p.tolist()) for p, _ in st2.hottest()]
            assert hot[0] == (0, 1)                  # LRU order survives
            (_, rows) = next(st2.hottest())
            assert rows["s00000"][0] == 0.0

    def test_load_empty_dir_gives_empty_store(self):
        with tempfile.TemporaryDirectory() as d:
            st = PrefixStore.load(d)
            assert len(st) == 0 and st.persist_dir == d

    def test_load_with_smaller_capacity_evicts(self):
        entry = self._rows(0.0)
        nb = int(np.asarray([0, 0], np.int32).nbytes
                 + entry["s00000"].nbytes)
        with tempfile.TemporaryDirectory() as d:
            st = PrefixStore(capacity_bytes=4 * nb, persist_dir=d)
            for i in range(3):
                st.put(np.asarray([i, i], np.int32),
                       self._rows(float(i)), "fp")
            st.save()
            st2 = PrefixStore.load(d, capacity_bytes=2 * nb)
            assert len(st2) == 2
            keys = [tuple(p.tolist()) for p, _ in st2.hottest()]
            assert (0, 0) not in keys


class TestPrefixSpillAdopt:
    def test_cold_engine_warm_starts_from_store(self, lm):
        store = PrefixStore(capacity_bytes=8 << 20)
        rng = np.random.default_rng(0)
        shared = rng.integers(0, 48, size=16).astype(np.int32)
        reqs = [Request(np.concatenate(
            [shared, rng.integers(0, 48, size=3).astype(np.int32)]),
            max_new=4) for _ in range(3)]
        hot = _engine(lm, prefix_cache=True, prefix_store=store)
        out1 = hot.generate(reqs)
        assert store.spills >= 1, "no donor rows spilled to the store"

        cold = _engine(lm, prefix_cache=True, prefix_store=store)
        adopted = cold.adopt_prefixes()
        assert adopted >= 1
        assert cold.stats.prefix_adoptions == adopted
        out2 = cold.generate([Request(r.prompt, max_new=r.max_new)
                              for r in reqs])
        assert out2 == out1, "adopted prefix rows changed greedy outputs"
        assert cold.stats.prefix_hits >= 1
        assert cold.stats.prefill_tokens_saved > 0, \
            "warm start saved no prefill work"

    def test_store_requires_prefix_cache(self, lm):
        with pytest.raises(ValueError, match="prefix"):
            _engine(lm, prefix_store=PrefixStore())

    def test_adopt_geometry_mismatch_raises(self, lm):
        store = PrefixStore(capacity_bytes=8 << 20)
        rng = np.random.default_rng(1)
        shared = rng.integers(0, 48, size=16).astype(np.int32)
        reqs = [Request(np.concatenate(
            [shared, rng.integers(0, 48, size=2).astype(np.int32)]),
            max_new=3) for _ in range(3)]
        _engine(lm, prefix_cache=True, prefix_store=store).generate(reqs)
        assert len(store) >= 1
        other = _engine(lm, cache_len=CACHE * 2, prefix_cache=True,
                        prefix_store=store)
        with pytest.raises(ValueError, match="geometry"):
            other.adopt_prefixes()


class TestAvailableSteps:
    def test_lists_complete_steps_only(self):
        with tempfile.TemporaryDirectory() as d:
            assert available_steps(d) == []
            save_checkpoint(d, 3, {"x": np.zeros(2, np.float32)})
            save_checkpoint(d, 7, {"x": np.zeros(2, np.float32)})
            import os
            os.makedirs(os.path.join(d, "step_00000009.tmp"))
            os.makedirs(os.path.join(d, "step_junk"), exist_ok=True)
            assert available_steps(d) == [3, 7]
