"""Continuous-batching serve engine: scheduling/bucketing correctness vs the
one-request-at-a-time reference loop, wave-engine equivalence, the
compile-budget + freeze-once regression, and cache-overflow errors."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig, SWMConfig
from repro.models.decoder import HybridDecoderLM
from repro.nn.module import init_params
from repro.serve.engine import (Request, SamplingParams, Scheduler,
                                ServeEngine, WaveEngine, _sample_token,
                                batch_split, make_decode_step,
                                make_prefill_step, pick_bucket, pow2_buckets)

jax.config.update("jax_platform_name", "cpu")


def _cfg(impl="dft", **kw):
    base = dict(name="eng", n_layers=2, d_model=32, n_heads=2, n_kv_heads=1,
                head_dim=16, d_ff=64, vocab=48, remat="none",
                param_dtype="float32", compute_dtype="float32",
                swm=SWMConfig(block_size=8, impl=impl))
    base.update(kw)
    return ModelConfig(**base)


@pytest.fixture(scope="module")
def lm():
    cfg = _cfg()
    model = HybridDecoderLM(cfg)
    params = init_params(model.specs(), 0)
    return cfg, model, params


@pytest.fixture(scope="module")
def engine(lm):
    cfg, model, params = lm
    return ServeEngine(model, cfg, params, batch=2, cache_len=32)


def _mix(seed, n, vocab=48, plen_hi=11, new_hi=7):
    rng = np.random.default_rng(seed)
    return [
        Request(rng.integers(0, vocab,
                             size=int(rng.integers(1, plen_hi))
                             ).astype(np.int32),
                max_new=int(rng.integers(1, new_hi)))
        for _ in range(n)
    ]


def _reference_loop(model, cfg, params, requests, cache_len):
    """The gold loop: one request at a time, B=1, no padding, no buckets.
    Uses the same (frozen) params as the engine so any divergence is the
    engine's scheduling/bucketing — not numerics."""
    prefill = jax.jit(make_prefill_step(model, cfg))
    decode = jax.jit(make_decode_step(model, cfg))
    outs = []
    for r in requests:
        p = np.asarray(r.prompt, np.int32).reshape(-1)
        cache = model.init_cache(1, cache_len)
        logits, cache = prefill(params, jnp.asarray(p)[None], cache)
        lg = np.asarray(logits)[0]
        rng = r.sampling.make_rng()
        out, pos = [], len(p)
        while True:
            tok = _sample_token(lg, r.sampling, rng)
            if r.stop_tokens and tok in r.stop_tokens:
                break
            out.append(tok)
            if len(out) >= r.max_new:
                break
            logits, cache = decode(params, jnp.asarray([[tok]], np.int32),
                                   cache, jnp.asarray([pos], np.int32))
            lg = np.asarray(logits)[0]
            pos += 1
        outs.append(out)
    return outs


# ---------------------------------------------------------------------------
# Correctness vs the reference loop
# ---------------------------------------------------------------------------


def test_queued_requests_exceed_slots_mixed_lengths(lm, engine):
    """7 requests through 2 slots, mixed prompt lengths AND budgets: outputs
    must equal the unbatched reference, in request order."""
    cfg, model, _ = lm
    reqs = _mix(0, 7)
    outs = engine.generate(reqs)
    assert [len(o) for o in outs] == [r.max_new for r in reqs]
    assert outs == _reference_loop(model, cfg, engine.params, reqs, 32)


def test_stop_tokens_match_reference(lm, engine):
    cfg, model, _ = lm
    base = _mix(1, 4, new_hi=8)
    plain = engine.generate(base)
    # stop on a token each request actually produces mid-stream
    reqs = [
        Request(r.prompt, max_new=r.max_new,
                stop_tokens=(o[len(o) // 2],) if len(o) > 1 else (-1,))
        for r, o in zip(base, plain)
    ]
    outs = engine.generate(reqs)
    ref = _reference_loop(model, cfg, engine.params, reqs, 32)
    assert outs == ref
    for o, p in zip(outs, plain):
        assert len(o) <= len(p)


def test_sampling_reproducible_and_matches_reference(lm, engine):
    cfg, model, _ = lm
    rng = np.random.default_rng(3)
    reqs = [
        Request(rng.integers(0, 48, size=4).astype(np.int32), max_new=5,
                sampling=SamplingParams(temperature=0.8, top_k=8, seed=i))
        for i in range(4)
    ]
    a = engine.generate(reqs)
    b = engine.generate(reqs)
    assert a == b                       # per-request seeded rng
    assert a == _reference_loop(model, cfg, engine.params, reqs, 32)


def test_policies_produce_identical_outputs(lm, engine):
    """Slots are independent: sjf vs fifo only reorders admission, never
    changes any request's tokens."""
    cfg, model, params = lm
    reqs = _mix(4, 6)
    sjf = ServeEngine(model, cfg, params, batch=2, cache_len=32,
                      policy="sjf")
    assert engine.generate(reqs) == sjf.generate(reqs)


def test_wave_and_continuous_identical_greedy(lm):
    """Acceptance: seeded request mix, wave == continuous, bit-identical."""
    cfg, model, params = lm
    reqs = _mix(5, 9, plen_hi=13, new_hi=9)
    cont = ServeEngine(model, cfg, params, batch=3, cache_len=32)
    wave = WaveEngine(model, cfg, params, batch=3, cache_len=32)
    assert cont.generate(reqs) == wave.generate(reqs)


# ---------------------------------------------------------------------------
# Compile budget + freeze-once regression (the plan-cache invariants)
# ---------------------------------------------------------------------------


def test_compile_budget_and_zero_rfft_after_freeze():
    from repro.kernels.block_circulant import ops
    from repro.kernels.block_circulant.plan import count_frozen_tables

    cfg = _cfg(impl="pallas")
    model = HybridDecoderLM(cfg)
    params = init_params(model.specs(), 0)

    n0 = ops.freq_weights_trace_count()
    eng = ServeEngine(model, cfg, params, batch=2, cache_len=16,
                      prompt_buckets=(4, 8))
    n_frozen = count_frozen_tables(eng.params)
    assert n_frozen > 0
    # construction freezes each circulant table exactly once
    assert ops.freq_weights_trace_count() - n0 == n_frozen

    reqs = _mix(6, 5, plen_hi=7, new_hi=4)
    eng.generate(reqs)
    eng.generate(_mix(7, 3, plen_hi=4, new_hi=3))
    # zero rfft(w) across the entire serving lifetime after freeze
    assert ops.freq_weights_trace_count() - n0 == n_frozen

    # at most len(buckets) executables, decode exactly one
    assert eng.prefill_compiles <= eng.max_prefill_variants
    assert eng.prefill_compiles == len(eng.stats.prefill_shapes)
    assert eng.decode_compiles == 1

    # jaxpr check: no fft primitive in either traced step
    toks = jnp.zeros((1, 4), jnp.int32)
    pos = jnp.zeros((1, 4), jnp.int32)
    slots = jnp.zeros((1,), jnp.int32)
    jp = jax.make_jaxpr(eng._prefill_fn)(
        eng.params, toks, pos, eng.cache, slots)
    assert "fft" not in str(jp)
    jd = jax.make_jaxpr(eng._decode_fn)(
        eng.params, jnp.zeros((2, 1), jnp.int32), eng.cache,
        jnp.zeros((2,), jnp.int32))
    assert "fft" not in str(jd)


def test_prewarm_compiles_every_bucket_then_serves_compile_free(lm):
    cfg, model, params = lm
    eng = ServeEngine(model, cfg, params, batch=2, cache_len=32,
                      prompt_buckets=(8, 16))
    eng.prewarm()
    assert eng.prefill_compiles == eng.max_prefill_variants
    assert eng.decode_compiles == 1
    eng.generate(_mix(8, 5))
    assert eng.prefill_compiles == eng.max_prefill_variants
    assert eng.decode_compiles == 1


# ---------------------------------------------------------------------------
# Cache-overflow validation (no silent truncation)
# ---------------------------------------------------------------------------


def test_prompt_exceeding_cache_len_raises(lm, engine):
    with pytest.raises(ValueError, match="exceeds cache_len"):
        engine.generate([Request(np.arange(40, dtype=np.int32), max_new=1)])


def test_prompt_plus_max_new_exceeding_cache_len_raises(lm, engine):
    with pytest.raises(ValueError, match="ring cache would silently"):
        engine.generate([Request(np.arange(20, dtype=np.int32), max_new=20)])
    # boundary: the final token is returned but never written back, so
    # L + max_new - 1 == cache_len is servable
    outs = engine.generate([Request(np.arange(20, dtype=np.int32),
                                    max_new=13)])
    assert len(outs[0]) == 13


def test_wave_engine_also_validates(lm):
    cfg, model, params = lm
    wave = WaveEngine(model, cfg, params, batch=2, cache_len=32)
    with pytest.raises(ValueError, match="exceeds"):
        wave.generate([Request(np.arange(40, dtype=np.int32), max_new=1)])


def test_degenerate_requests_raise(lm, engine):
    with pytest.raises(ValueError, match="empty prompt"):
        engine.generate([Request(np.zeros((0,), np.int32))])
    with pytest.raises(ValueError, match="max_new"):
        engine.generate([Request(np.arange(3, dtype=np.int32), max_new=0)])
    # WaveEngine shares the same admission contract
    cfg, model, params = lm
    wave = WaveEngine(model, cfg, params, batch=2, cache_len=32)
    with pytest.raises(ValueError, match="max_new"):
        wave.generate([Request(np.arange(3, dtype=np.int32), max_new=0)])


def test_wave_engine_is_greedy_only(lm):
    cfg, model, params = lm
    wave = WaveEngine(model, cfg, params, batch=2, cache_len=32)
    with pytest.raises(ValueError, match="greedy-only"):
        wave.generate([Request(np.arange(3, dtype=np.int32), max_new=2,
                               sampling=SamplingParams(temperature=0.5))])
    with pytest.raises(ValueError, match="greedy-only"):
        wave.generate([Request(np.arange(3, dtype=np.int32), max_new=2,
                               stop_tokens=(1,))])


def test_recurrent_mixers_rejected():
    """Pad tokens pollute recurrent state — serving must refuse, not emit
    silently padding-dependent tokens."""
    from repro.configs.base import LayerGroup, LayerSpec

    cfg = _cfg(n_layers=1, rwkv_head_dim=16, rwkv_decay_lora=8,
               rwkv_mix_lora=8,
               groups=(LayerGroup(
                   layers=(LayerSpec(mixer="rwkv", ffn="dense"),),
                   repeat=1),))
    model = HybridDecoderLM(cfg)
    params = init_params(model.specs(), 0)
    with pytest.raises(ValueError, match="recurrent state"):
        ServeEngine(model, cfg, params, batch=2, cache_len=32)
    with pytest.raises(ValueError, match="recurrent state"):
        WaveEngine(model, cfg, params, batch=2, cache_len=32)
    # a wave of one never pads: still allowed
    WaveEngine(model, cfg, params, batch=1, cache_len=32)


# ---------------------------------------------------------------------------
# Scheduler / bucket unit behavior
# ---------------------------------------------------------------------------


def test_scheduler_orders():
    fifo = Scheduler("fifo")
    sjf = Scheduler("sjf")
    for name, plen in (("a", 5), ("b", 1), ("c", 3)):
        fifo.submit(name, plen)
        sjf.submit(name, plen)
    assert fifo.take(3) == ["a", "b", "c"]
    assert sjf.take(3) == ["b", "c", "a"]
    with pytest.raises(ValueError):
        Scheduler("lifo")


def test_bucket_helpers():
    assert pow2_buckets(8, 64) == (8, 16, 32, 64)
    assert pow2_buckets(8, 48) == (8, 16, 32, 48)
    assert pow2_buckets(1, 1) == (1,)
    assert pick_bucket(9, (8, 16, 32)) == 16
    assert pick_bucket(8, (8, 16, 32)) == 8
    with pytest.raises(ValueError):
        pick_bucket(33, (8, 16, 32))
    assert batch_split(7, (1, 2, 4)) == [4, 2, 1]
    assert batch_split(4, (1, 2, 4)) == [4]
    # any m <= slot count decomposes exactly
    for m in range(1, 17):
        assert sum(batch_split(m, (1, 2, 4, 8))) == m


def test_stats_accounting(lm):
    cfg, model, params = lm
    eng = ServeEngine(model, cfg, params, batch=2, cache_len=32)
    reqs = _mix(9, 4)
    outs = eng.generate(reqs)
    s = eng.stats
    assert s.tokens_generated == sum(len(o) for o in outs)
    assert s.requests_completed == len(reqs)
    assert s.prefill_calls >= 1 and s.decode_steps >= 1
    assert 0.0 < s.tokens_per_decode_step <= eng.batch
    d = s.as_dict()
    assert d["prefill_shapes"] == sorted(s.prefill_shapes)
