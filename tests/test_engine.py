"""Continuous-batching serve engine: scheduling/bucketing correctness vs the
one-request-at-a-time reference loop, wave-engine equivalence, the
compile-budget + freeze-once regression, and cache-overflow errors."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig, SWMConfig
from repro.models.decoder import HybridDecoderLM
from repro.nn.module import init_params
from repro.serve.engine import (Request, RequestState, SamplingParams,
                                Scheduler, ServeEngine, WaveEngine,
                                _sample_token, batch_split, make_decode_step,
                                make_prefill_step, pick_bucket, pow2_buckets,
                                validate_buckets)

jax.config.update("jax_platform_name", "cpu")


def _cfg(impl="dft", **kw):
    base = dict(name="eng", n_layers=2, d_model=32, n_heads=2, n_kv_heads=1,
                head_dim=16, d_ff=64, vocab=48, remat="none",
                param_dtype="float32", compute_dtype="float32",
                swm=SWMConfig(block_size=8, impl=impl))
    base.update(kw)
    return ModelConfig(**base)


@pytest.fixture(scope="module")
def lm():
    cfg = _cfg()
    model = HybridDecoderLM(cfg)
    params = init_params(model.specs(), 0)
    return cfg, model, params


@pytest.fixture(scope="module")
def engine(lm):
    cfg, model, params = lm
    return ServeEngine(model, cfg, params, batch=2, cache_len=32)


def _mix(seed, n, vocab=48, plen_hi=11, new_hi=7):
    rng = np.random.default_rng(seed)
    return [
        Request(rng.integers(0, vocab,
                             size=int(rng.integers(1, plen_hi))
                             ).astype(np.int32),
                max_new=int(rng.integers(1, new_hi)))
        for _ in range(n)
    ]


def _reference_loop(model, cfg, params, requests, cache_len):
    """The gold loop: one request at a time, B=1, no padding, no buckets.
    Uses the same (frozen) params as the engine so any divergence is the
    engine's scheduling/bucketing — not numerics."""
    prefill = jax.jit(make_prefill_step(model, cfg))
    decode = jax.jit(make_decode_step(model, cfg))
    outs = []
    for r in requests:
        p = np.asarray(r.prompt, np.int32).reshape(-1)
        cache = model.init_cache(1, cache_len)
        logits, cache = prefill(params, jnp.asarray(p)[None], cache)
        lg = np.asarray(logits)[0]
        rng = r.sampling.make_rng()
        out, pos = [], len(p)
        while True:
            tok = _sample_token(lg, r.sampling, rng)
            if r.stop_tokens and tok in r.stop_tokens:
                break
            out.append(tok)
            if len(out) >= r.max_new:
                break
            logits, cache = decode(params, jnp.asarray([[tok]], np.int32),
                                   cache, jnp.asarray([pos], np.int32))
            lg = np.asarray(logits)[0]
            pos += 1
        outs.append(out)
    return outs


# ---------------------------------------------------------------------------
# Correctness vs the reference loop
# ---------------------------------------------------------------------------


def test_queued_requests_exceed_slots_mixed_lengths(lm, engine):
    """7 requests through 2 slots, mixed prompt lengths AND budgets: outputs
    must equal the unbatched reference, in request order."""
    cfg, model, _ = lm
    reqs = _mix(0, 7)
    outs = engine.generate(reqs)
    assert [len(o) for o in outs] == [r.max_new for r in reqs]
    assert outs == _reference_loop(model, cfg, engine.params, reqs, 32)


def test_stop_tokens_match_reference(lm, engine):
    cfg, model, _ = lm
    base = _mix(1, 4, new_hi=8)
    plain = engine.generate(base)
    # stop on a token each request actually produces mid-stream
    reqs = [
        Request(r.prompt, max_new=r.max_new,
                stop_tokens=(o[len(o) // 2],) if len(o) > 1 else (-1,))
        for r, o in zip(base, plain)
    ]
    outs = engine.generate(reqs)
    ref = _reference_loop(model, cfg, engine.params, reqs, 32)
    assert outs == ref
    for o, p in zip(outs, plain):
        assert len(o) <= len(p)


def test_sampling_reproducible_and_matches_reference(lm, engine):
    cfg, model, _ = lm
    rng = np.random.default_rng(3)
    reqs = [
        Request(rng.integers(0, 48, size=4).astype(np.int32), max_new=5,
                sampling=SamplingParams(temperature=0.8, top_k=8, seed=i))
        for i in range(4)
    ]
    a = engine.generate(reqs)
    b = engine.generate(reqs)
    assert a == b                       # per-request seeded rng
    assert a == _reference_loop(model, cfg, engine.params, reqs, 32)


def test_policies_produce_identical_outputs(lm, engine):
    """Slots are independent: sjf vs fifo only reorders admission, never
    changes any request's tokens."""
    cfg, model, params = lm
    reqs = _mix(4, 6)
    sjf = ServeEngine(model, cfg, params, batch=2, cache_len=32,
                      policy="sjf")
    assert engine.generate(reqs) == sjf.generate(reqs)


def test_wave_and_continuous_identical_greedy(lm):
    """Acceptance: seeded request mix, wave == continuous, bit-identical."""
    cfg, model, params = lm
    reqs = _mix(5, 9, plen_hi=13, new_hi=9)
    cont = ServeEngine(model, cfg, params, batch=3, cache_len=32)
    wave = WaveEngine(model, cfg, params, batch=3, cache_len=32)
    assert cont.generate(reqs) == wave.generate(reqs)


# ---------------------------------------------------------------------------
# Decode-side bucketing: equivalence, row-work accounting, compile budget
# ---------------------------------------------------------------------------


def test_decode_bucket_equivalence_and_row_work(lm):
    """Slot compaction is a pure permutation: greedy outputs bit-identical
    across decode_buckets settings (full-slot = PR-2 behavior, pow2 default,
    all-singleton), while bucketed row-work strictly drops on a tail-heavy
    mix (one long request outlives the rest)."""
    cfg, model, params = lm
    reqs = _mix(10, 6, plen_hi=9, new_hi=4)
    reqs.append(Request(np.arange(5, dtype=np.int32), max_new=14))  # tail
    full = ServeEngine(model, cfg, params, batch=4, cache_len=32,
                       decode_buckets=(4,))
    bkt = ServeEngine(model, cfg, params, batch=4, cache_len=32)
    ones = ServeEngine(model, cfg, params, batch=4, cache_len=32,
                       decode_buckets=(1, 2, 3, 4))
    outs = full.generate(reqs)
    assert bkt.generate(reqs) == outs
    assert ones.generate(reqs) == outs
    assert outs == _reference_loop(model, cfg, full.params, reqs, 32)
    # same tokens, strictly less decode row-work once the batch tails off
    assert full.stats.tokens_generated == bkt.stats.tokens_generated
    assert bkt.stats.decode_rows < full.stats.decode_rows
    assert (bkt.stats.decode_rows_per_token
            < full.stats.decode_rows_per_token)
    assert set(full.stats.decode_shapes) == {4}
    assert min(bkt.stats.decode_shapes) < 4


def test_decode_compile_budget_bounded_by_buckets(lm):
    cfg, model, params = lm
    eng = ServeEngine(model, cfg, params, batch=4, cache_len=32,
                      prompt_buckets=(8, 16))
    eng.prewarm()
    assert eng.decode_compiles == len(eng.decode_buckets)
    assert eng.decode_compiles <= len(eng.batch_buckets)
    eng.generate(_mix(11, 9))
    eng.generate(_mix(12, 3))
    assert eng.decode_compiles == len(eng.decode_buckets)


# ---------------------------------------------------------------------------
# Shared-prefix KV reuse + donated decode buffers
# ---------------------------------------------------------------------------


def _shared_head_mix(seed, n, head_len=12, vocab=48, n_heads=2):
    """Requests drawn from a few long shared prompt heads + private tails —
    the workload shape the prefix cache exists for."""
    rng = np.random.default_rng(seed)
    heads = [rng.integers(0, vocab, size=head_len).astype(np.int32)
             for _ in range(n_heads)]
    reqs = []
    for i in range(n):
        tail = rng.integers(0, vocab,
                            size=int(rng.integers(1, 5))).astype(np.int32)
        reqs.append(Request(np.concatenate([heads[i % n_heads], tail]),
                            max_new=int(rng.integers(2, 6))))
    return reqs


def _check_prefix_invariants(eng):
    """No dangling pins, and every index entry points at a slot that still
    holds the indexed prefix (eviction removed stale entries)."""
    assert (eng._slot_refs == 0).all()
    for (m, bts), slot in eng._prefix_index.items():
        p = eng._slot_prompt[slot]
        assert p is not None and p.shape[0] >= m
        assert p[:m].tobytes() == bts


def test_prefix_cache_bit_identical_shared_heads(lm):
    """Acceptance: shared-head traffic hits the prefix cache (tokens saved)
    while greedy outputs stay bit-identical to cache-off and to the
    unbatched reference loop."""
    cfg, model, params = lm
    reqs = _shared_head_mix(20, 9)
    off = ServeEngine(model, cfg, params, batch=3, cache_len=32)
    on = ServeEngine(model, cfg, params, batch=3, cache_len=32,
                     prefix_cache=True)
    outs = off.generate(reqs)
    assert on.generate(reqs) == outs
    assert outs == _reference_loop(model, cfg, off.params, reqs, 32)
    assert on.stats.prefix_hits > 0
    assert on.stats.prefill_tokens_saved > 0
    assert 0.0 < on.stats.prefix_hit_rate <= 1.0
    # cache-off engine never probes or saves anything
    assert off.stats.prefix_lookups == 0
    assert off.stats.prefill_tokens_saved == 0
    _check_prefix_invariants(on)


def test_prefix_cache_disjoint_workload_all_misses(lm):
    """Disjoint prompts: the index never matches, outputs are unchanged,
    and the saved-token counter stays zero (no false hits)."""
    cfg, model, params = lm
    reqs = _mix(21, 7)
    off = ServeEngine(model, cfg, params, batch=2, cache_len=32)
    on = ServeEngine(model, cfg, params, batch=2, cache_len=32,
                     prefix_cache=True, prefix_block=16)
    assert on.generate(reqs) == off.generate(reqs)
    assert on.stats.prefix_hits == 0
    assert on.stats.prefill_tokens_saved == 0
    _check_prefix_invariants(on)


def test_prefix_refcount_defers_instead_of_clobbering(lm):
    """Every queued request matches the SAME donor rows while placement is
    starved (2 slots, all free slots are donors): the refcount must keep
    the pinned donor out of placement/pad-lane reuse, deferral must keep
    the engine making progress, and outputs stay bit-identical."""
    cfg, model, params = lm
    head = np.arange(8, dtype=np.int32) + 3
    reqs = [Request(np.concatenate([head, np.asarray([40 + i], np.int32)]),
                    max_new=3) for i in range(6)]
    off = ServeEngine(model, cfg, params, batch=2, cache_len=32)
    on = ServeEngine(model, cfg, params, batch=2, cache_len=32,
                     prefix_cache=True)
    outs = off.generate(reqs)
    assert on.generate(reqs) == outs
    # round 1 (both slots empty) can't hit; everything admitted against a
    # resident donor afterwards must
    assert on.stats.prefix_hits >= 3
    assert on.stats.prefill_tokens_saved == 8 * on.stats.prefix_hits
    _check_prefix_invariants(on)


def test_prefix_capacity_bounds_index(lm):
    cfg, model, params = lm
    reqs = _shared_head_mix(22, 8, n_heads=3)
    off = ServeEngine(model, cfg, params, batch=2, cache_len=32)
    on = ServeEngine(model, cfg, params, batch=2, cache_len=32,
                     prefix_cache=True, prefix_capacity=2)
    assert on.generate(reqs) == off.generate(reqs)
    assert len(on._prefix_index) <= 2
    _check_prefix_invariants(on)
    with pytest.raises(ValueError, match="prefix_capacity"):
        ServeEngine(model, cfg, params, batch=2, cache_len=32,
                    prefix_cache=True, prefix_capacity=0)
    with pytest.raises(ValueError, match="prefix_block"):
        ServeEngine(model, cfg, params, batch=2, cache_len=32,
                    prefix_cache=True, prefix_block=0)


def test_prefix_cache_rejects_short_ring_caches():
    """A local-attention ring shorter than cache_len overwrites donor rows
    past the window — prefix reuse must refuse, not serve wrong tokens."""
    from repro.configs.base import LayerGroup, LayerSpec

    cfg = _cfg(sliding_window=8,
               groups=(LayerGroup(
                   layers=(LayerSpec(mixer="attn_local", ffn="dense"),),
                   repeat=2),))
    model = HybridDecoderLM(cfg)
    params = init_params(model.specs(), 0)
    with pytest.raises(ValueError, match="full-length KV caches"):
        ServeEngine(model, cfg, params, batch=2, cache_len=32,
                    prefix_cache=True)
    # without prefix reuse the config still serves
    ServeEngine(model, cfg, params, batch=2, cache_len=32)


def test_donation_on_off_equivalence(lm):
    """donate_argnums is pure plumbing: outputs bit-identical with the
    cache donated or copied, with and without the prefix cache (the
    REPRO_INTERPRET CI matrix runs this file under interpret mode too)."""
    cfg, model, params = lm
    reqs = _mix(23, 6)
    d_on = ServeEngine(model, cfg, params, batch=2, cache_len=32)
    d_off = ServeEngine(model, cfg, params, batch=2, cache_len=32,
                        donate=False)
    assert d_on.donate and not d_off.donate
    assert d_on.generate(reqs) == d_off.generate(reqs)
    shared = _shared_head_mix(24, 6)
    p_on = ServeEngine(model, cfg, params, batch=2, cache_len=32,
                       prefix_cache=True)
    p_off = ServeEngine(model, cfg, params, batch=2, cache_len=32,
                        prefix_cache=True, donate=False)
    assert p_on.generate(shared) == p_off.generate(shared)
    assert p_on.stats.prefill_tokens_saved \
        == p_off.stats.prefill_tokens_saved > 0


def test_prewarm_commits_donated_caches_and_requires_idle(lm):
    """prewarm must COMMIT its warmed cache handles (a donated input buffer
    is dead after the call — the old discard behavior would kill the live
    cache), serve compile-free afterwards with outputs unchanged, and
    refuse to run over active slots."""
    cfg, model, params = lm
    eng = ServeEngine(model, cfg, params, batch=2, cache_len=32,
                      prompt_buckets=(8, 16), prefix_cache=True)
    n = eng.prewarm()
    assert n == eng.max_prefill_variants + eng.max_decode_variants
    reqs = _shared_head_mix(25, 5)
    want = ServeEngine(model, cfg, params, batch=2, cache_len=32,
                       prompt_buckets=(8, 16)).generate(reqs)
    assert eng.generate(reqs) == want
    assert eng.prefill_compiles == eng.max_prefill_variants
    assert eng.decode_compiles == eng.max_decode_variants
    # idle again: prewarm may rerun (no-op compiles, masked writes only)
    eng.prewarm()
    assert eng.generate(reqs) == want
    # active slots: refuse
    eng2 = ServeEngine(model, cfg, params, batch=2, cache_len=32)
    eng2.submit(Request(np.arange(4, dtype=np.int32), max_new=6))
    eng2.step()
    with pytest.raises(RuntimeError, match="idle"):
        eng2.prewarm()
    eng2.drain()


def test_prefix_cache_compile_budget(lm):
    """Acceptance: with the prefix cache enabled the executable counts stay
    within max_prefill_variants + len(decode_buckets) — seeding rides in
    the same per-bucket executables, it never adds shapes."""
    cfg, model, params = lm
    eng = ServeEngine(model, cfg, params, batch=4, cache_len=32,
                      prompt_buckets=(8, 16), prefix_cache=True)
    eng.prewarm()
    eng.generate(_shared_head_mix(26, 10))
    eng.generate(_mix(27, 5))
    assert eng.prefill_compiles <= eng.max_prefill_variants
    assert eng.decode_compiles <= eng.max_decode_variants
    assert eng.max_decode_variants == len(eng.decode_buckets)


# ---------------------------------------------------------------------------
# Streaming submit / step / poll / drain
# ---------------------------------------------------------------------------


def test_streaming_submit_poll_matches_generate(lm):
    """The streaming loop and the closed generate() call produce identical
    tokens — generate IS the streaming loop (submit all, drain, reorder)."""
    cfg, model, params = lm
    reqs = _mix(13, 6, new_hi=8)
    want = ServeEngine(model, cfg, params, batch=2,
                       cache_len=32).generate(reqs)
    eng = ServeEngine(model, cfg, params, batch=2, cache_len=32)
    rids = [eng.submit(r) for r in reqs]
    while eng.step():
        pass
    views = [eng.poll(rid) for rid in rids]
    assert all(v.done for v in views)
    assert [list(v.tokens) for v in views] == want
    done = eng.drain(rids)
    assert [done[rid] for rid in rids] == want


def test_streaming_incremental_poll_and_claim(lm):
    cfg, model, params = lm
    eng = ServeEngine(model, cfg, params, batch=2, cache_len=32)
    rid = eng.submit(Request(np.arange(4, dtype=np.int32), max_new=6))
    v0 = eng.poll(rid)
    assert isinstance(v0, RequestState)
    assert v0 == RequestState(rid, False, ())          # queued, no tokens yet
    seen = [len(v0.tokens)]
    while eng.step():
        seen.append(len(eng.poll(rid).tokens))
    assert eng.poll(rid).done
    assert seen == sorted(seen) and len(eng.poll(rid).tokens) == 6
    # late submits keep the stream open and ids monotone
    rid2 = eng.submit(Request(np.arange(3, dtype=np.int32), max_new=2))
    assert rid2 > rid
    out = eng.drain()
    assert set(out) == {rid, rid2}
    assert len(out[rid]) == 6 and len(out[rid2]) == 2
    with pytest.raises(KeyError, match="already-claimed"):
        eng.poll(rid)
    with pytest.raises(KeyError, match="not a finished"):
        eng.drain([rid])


def test_drain_with_bad_id_claims_nothing(lm):
    """drain must validate every requested id before popping any: a bad id
    mid-list cannot silently discard other requests' outputs."""
    cfg, model, params = lm
    eng = ServeEngine(model, cfg, params, batch=2, cache_len=32)
    rid = eng.submit(Request(np.arange(3, dtype=np.int32), max_new=2))
    while eng.step():
        pass
    with pytest.raises(KeyError, match="not a finished"):
        eng.drain([rid, 999])
    with pytest.raises(KeyError, match="duplicate"):
        eng.drain([rid, rid])
    # rid's output survived both failed drains and is still claimable
    assert len(eng.drain([rid])[rid]) == 2


def test_generate_with_invalid_request_enqueues_nothing(lm):
    """generate validates the whole batch before submitting any of it: a
    bad request must not leave its predecessors as ghost work that burns
    slots in the caller's next call."""
    cfg, model, params = lm
    eng = ServeEngine(model, cfg, params, batch=2, cache_len=32)
    good = Request(np.arange(3, dtype=np.int32), max_new=2)
    bad = Request(np.arange(40, dtype=np.int32), max_new=2)
    with pytest.raises(ValueError, match="exceeds cache_len"):
        eng.generate([good, bad])
    assert not eng.step()                   # nothing queued, nothing active
    assert eng.stats.tokens_generated == 0


def test_generate_claims_only_its_own_requests(lm):
    """generate() drains the whole engine but only claims its own ids —
    an earlier streaming submit stays pollable afterwards."""
    cfg, model, params = lm
    eng = ServeEngine(model, cfg, params, batch=2, cache_len=32)
    early = eng.submit(Request(np.arange(4, dtype=np.int32), max_new=3))
    outs = eng.generate(_mix(14, 3))
    assert len(outs) == 3
    v = eng.poll(early)
    assert v.done and len(v.tokens) == 3
    assert eng.drain([early]) == {early: list(v.tokens)}


# ---------------------------------------------------------------------------
# Compile budget + freeze-once regression (the plan-cache invariants)
# ---------------------------------------------------------------------------


def test_compile_budget_and_zero_rfft_after_freeze():
    from repro.kernels.block_circulant import ops
    from repro.kernels.block_circulant.plan import count_frozen_tables

    cfg = _cfg(impl="pallas")
    model = HybridDecoderLM(cfg)
    params = init_params(model.specs(), 0)

    n0 = ops.freq_weights_trace_count()
    eng = ServeEngine(model, cfg, params, batch=2, cache_len=16,
                      prompt_buckets=(4, 8))
    n_frozen = count_frozen_tables(eng.params)
    assert n_frozen > 0
    # construction freezes each circulant table exactly once
    assert ops.freq_weights_trace_count() - n0 == n_frozen

    reqs = _mix(6, 5, plen_hi=7, new_hi=4)
    eng.generate(reqs)
    eng.generate(_mix(7, 3, plen_hi=4, new_hi=3))
    # zero rfft(w) across the entire serving lifetime after freeze
    assert ops.freq_weights_trace_count() - n0 == n_frozen

    # at most len(buckets) executables for prefill AND decode
    assert eng.prefill_compiles <= eng.max_prefill_variants
    assert eng.prefill_compiles == len(eng.stats.prefill_shapes)
    assert eng.decode_compiles <= eng.max_decode_variants
    assert eng.decode_compiles == len(eng.stats.decode_shapes)

    # structural check: the full per-surface contract set — NoFFT (pallas
    # impl promises zero fft, weights AND activations), no dense-fallback
    # contraction, no per-trace weight concat, frozen dtypes, donation
    # aliasing — over EVERY bucketed executable, via the auditor
    assert eng.audit(raise_on_violation=True) == []


def test_prewarm_compiles_every_bucket_then_serves_compile_free(lm):
    cfg, model, params = lm
    eng = ServeEngine(model, cfg, params, batch=2, cache_len=32,
                      prompt_buckets=(8, 16))
    eng.prewarm()
    assert eng.prefill_compiles == eng.max_prefill_variants
    assert eng.decode_compiles == eng.max_decode_variants
    assert eng.max_decode_variants <= len(eng.batch_buckets)
    eng.generate(_mix(8, 5))
    assert eng.prefill_compiles == eng.max_prefill_variants
    assert eng.decode_compiles == eng.max_decode_variants


# ---------------------------------------------------------------------------
# Cache-overflow validation (no silent truncation)
# ---------------------------------------------------------------------------


def test_prompt_exceeding_cache_len_raises(lm, engine):
    with pytest.raises(ValueError, match="exceeds cache_len"):
        engine.generate([Request(np.arange(40, dtype=np.int32), max_new=1)])


def test_prompt_plus_max_new_exceeding_cache_len_raises(lm, engine):
    with pytest.raises(ValueError, match="ring cache would silently"):
        engine.generate([Request(np.arange(20, dtype=np.int32), max_new=20)])
    # boundary: the final token is returned but never written back, so
    # L + max_new - 1 == cache_len is servable
    outs = engine.generate([Request(np.arange(20, dtype=np.int32),
                                    max_new=13)])
    assert len(outs[0]) == 13


def test_wave_engine_also_validates(lm):
    cfg, model, params = lm
    wave = WaveEngine(model, cfg, params, batch=2, cache_len=32)
    with pytest.raises(ValueError, match="exceeds"):
        wave.generate([Request(np.arange(40, dtype=np.int32), max_new=1)])


def test_degenerate_requests_raise(lm, engine):
    with pytest.raises(ValueError, match="empty prompt"):
        engine.generate([Request(np.zeros((0,), np.int32))])
    with pytest.raises(ValueError, match="max_new"):
        engine.generate([Request(np.arange(3, dtype=np.int32), max_new=0)])
    # WaveEngine shares the same admission contract
    cfg, model, params = lm
    wave = WaveEngine(model, cfg, params, batch=2, cache_len=32)
    with pytest.raises(ValueError, match="max_new"):
        wave.generate([Request(np.arange(3, dtype=np.int32), max_new=0)])


def test_wave_engine_is_greedy_only(lm):
    cfg, model, params = lm
    wave = WaveEngine(model, cfg, params, batch=2, cache_len=32)
    with pytest.raises(ValueError, match="greedy-only"):
        wave.generate([Request(np.arange(3, dtype=np.int32), max_new=2,
                               sampling=SamplingParams(temperature=0.5))])
    with pytest.raises(ValueError, match="greedy-only"):
        wave.generate([Request(np.arange(3, dtype=np.int32), max_new=2,
                               stop_tokens=(1,))])


def test_recurrent_mixer_capabilities():
    """Recurrent families serve through ServeEngine's RecurrentRunner
    (pad-aware masking makes bucketed prefill safe), but their state has
    no per-position rows: the prefix cache must refuse with an actionable
    message, and the padding wave baseline still rejects batched waves."""
    from repro.configs.base import LayerGroup, LayerSpec
    from repro.serve.runner import RecurrentRunner

    cfg = _cfg(n_layers=1, rwkv_head_dim=16, rwkv_decay_lora=8,
               rwkv_mix_lora=8,
               groups=(LayerGroup(
                   layers=(LayerSpec(mixer="rwkv", ffn="dense"),),
                   repeat=1),))
    model = HybridDecoderLM(cfg)
    params = init_params(model.specs(), 0)
    eng = ServeEngine(model, cfg, params, batch=2, cache_len=32)
    assert isinstance(eng.runner, RecurrentRunner)
    assert not eng.runner.supports_prefix_cache
    outs = eng.generate([Request(np.arange(1, 5, dtype=np.int32), max_new=3),
                         Request(np.arange(2, 9, dtype=np.int32), max_new=3)])
    assert all(len(o) == 3 for o in outs)
    # recurrent state has no per-position rows -> prefix reuse impossible
    with pytest.raises(ValueError, match="recurrent state"):
        ServeEngine(model, cfg, params, batch=2, cache_len=32,
                    prefix_cache=True)
    # the wave baseline has no validity masking: batched waves still refuse
    with pytest.raises(ValueError, match="recurrent state"):
        WaveEngine(model, cfg, params, batch=2, cache_len=32)
    # a wave of one never pads: still allowed
    WaveEngine(model, cfg, params, batch=1, cache_len=32)


# ---------------------------------------------------------------------------
# Scheduler / bucket unit behavior
# ---------------------------------------------------------------------------


def test_scheduler_orders():
    fifo = Scheduler("fifo")
    sjf = Scheduler("sjf")
    for name, plen in (("a", 5), ("b", 1), ("c", 3)):
        fifo.submit(name, plen)
        sjf.submit(name, plen)
    assert fifo.take(3) == ["a", "b", "c"]
    assert sjf.take(3) == ["b", "c", "a"]
    with pytest.raises(ValueError):
        Scheduler("lifo")


def test_bucket_helpers():
    assert pow2_buckets(8, 64) == (8, 16, 32, 64)
    assert pow2_buckets(8, 48) == (8, 16, 32, 48)
    assert pow2_buckets(1, 1) == (1,)
    assert pick_bucket(9, (8, 16, 32)) == 16
    assert pick_bucket(8, (8, 16, 32)) == 8
    with pytest.raises(ValueError):
        pick_bucket(33, (8, 16, 32))
    assert batch_split(7, (1, 2, 4)) == [4, 2, 1]
    assert batch_split(4, (1, 2, 4)) == [4]
    # any m <= slot count decomposes exactly
    for m in range(1, 17):
        assert sum(batch_split(m, (1, 2, 4, 8))) == m


def test_batch_split_without_unit_bucket_raises():
    """A bucket list that cannot cover the remainder must raise a ValueError
    naming the buckets — not leak a bare StopIteration from next()."""
    with pytest.raises(ValueError, match=r"\[2, 4\].*include 1"):
        batch_split(3, (2, 4))
    with pytest.raises(ValueError, match="cannot decompose 5"):
        batch_split(5, (4,))


def test_validate_buckets_and_engine_construction(lm):
    assert validate_buckets("b", (4, 1, 2, 2), 4) == (1, 2, 4)
    assert validate_buckets("b", (2,), 4) == (2, 4)      # hi appended
    with pytest.raises(ValueError, match="decode_buckets"):
        validate_buckets("decode_buckets", (0, 2), 4)
    with pytest.raises(ValueError, match="decode_buckets"):
        validate_buckets("decode_buckets", (8,), 4)
    with pytest.raises(ValueError, match="decode_buckets"):
        validate_buckets("decode_buckets", (), 4)
    # engine construction validates user-supplied buckets the same way
    cfg, model, params = lm
    with pytest.raises(ValueError, match="decode_buckets"):
        ServeEngine(model, cfg, params, batch=2, cache_len=32,
                    decode_buckets=(3,))
    with pytest.raises(ValueError, match="prompt_buckets"):
        ServeEngine(model, cfg, params, batch=2, cache_len=32,
                    prompt_buckets=(0, 8))
    eng = ServeEngine(model, cfg, params, batch=2, cache_len=32,
                      decode_buckets=(1,))
    assert eng.decode_buckets == (1, 2)                  # batch appended


def test_top_k_ties_keep_exactly_k():
    """Regression: `z >= kth` kept every candidate tied at the k-th value.
    Ties now break deterministically toward the lower token id, so exactly
    top_k survive."""
    logits = np.zeros(8, np.float32)
    logits[[2, 4, 6]] = 1.0                # three-way tie at the top
    sp = SamplingParams(temperature=1.0, top_k=2, seed=0)
    draws = {_sample_token(logits, sp, np.random.default_rng(s))
             for s in range(200)}
    # survivors are the two LOWEST tied ids; 6 (and everything cold) is out
    assert draws == {2, 4}
    # k-th value tied with below-threshold entries: still exactly k
    tied = np.array([3.0, 2.0, 2.0, 2.0, 0.0], np.float32)
    sp1 = SamplingParams(temperature=1.0, top_k=2)
    draws = {_sample_token(tied, sp1, np.random.default_rng(s))
             for s in range(200)}
    assert draws == {0, 1}


def test_top_k_at_least_vocab_means_full_vocab():
    """top_k >= vocab is explicitly full-vocab sampling: identical draws to
    top_k=0 under the same rng stream."""
    rng = np.random.default_rng(5)
    logits = rng.normal(size=16).astype(np.float32)
    for k in (16, 17, 1000):
        sp_k = SamplingParams(temperature=0.9, top_k=k)
        sp_0 = SamplingParams(temperature=0.9, top_k=0)
        a = [_sample_token(logits, sp_k, np.random.default_rng(s))
             for s in range(50)]
        b = [_sample_token(logits, sp_0, np.random.default_rng(s))
             for s in range(50)]
        assert a == b


def test_request_defaults_and_stop_token_normalization():
    """Each Request gets its own SamplingParams (default_factory, no shared
    mutable-ish default), and stop_tokens normalizes to a tuple."""
    a, b = Request(np.arange(3, dtype=np.int32)), \
        Request(np.arange(3, dtype=np.int32))
    assert a.sampling == SamplingParams() and a.sampling is not b.sampling
    r = Request(np.arange(3, dtype=np.int32), stop_tokens=[7, 9])
    assert r.stop_tokens == (7, 9) and isinstance(r.stop_tokens, tuple)
    # list- and array-valued stop_tokens hash/compare like the tuple form
    assert Request(np.arange(2, dtype=np.int32),
                   stop_tokens=np.array([1, 2])).stop_tokens == (1, 2)
    assert all(isinstance(t, int) for t in r.stop_tokens)


def test_list_stop_tokens_served_like_tuple(lm, engine):
    cfg, model, _ = lm
    base = Request(np.arange(4, dtype=np.int32), max_new=6)
    plain = engine.generate([base])[0]
    assert len(plain) > 1
    stop = plain[len(plain) // 2]
    with_list = Request(np.arange(4, dtype=np.int32), max_new=6,
                        stop_tokens=[stop])
    with_tuple = Request(np.arange(4, dtype=np.int32), max_new=6,
                         stop_tokens=(stop,))
    assert (engine.generate([with_list])
            == engine.generate([with_tuple]))


def test_stats_accounting(lm):
    cfg, model, params = lm
    eng = ServeEngine(model, cfg, params, batch=2, cache_len=32)
    reqs = _mix(9, 4)
    outs = eng.generate(reqs)
    s = eng.stats
    assert s.tokens_generated == sum(len(o) for o in outs)
    assert s.requests_completed == len(reqs)
    assert s.prefill_calls >= 1 and s.decode_steps >= 1
    assert 0.0 < s.tokens_per_decode_step <= eng.batch
    d = s.as_dict()
    assert d["prefill_shapes"] == sorted(s.prefill_shapes)
