"""Multi-tenant serving layer: fair scheduling, SLO instrumentation,
backpressure hints, and the asyncio front-end.

Scheduler-level tests run without jax (pure data structures); the
engine-level tests share one tiny module-scoped model. Front-end tests
drive the asyncio layer against a stub engine with an injected sleep, so
backoff behavior is asserted deterministically without wall-clock waits.
"""

import asyncio
import dataclasses
import json
import tempfile

import numpy as np
import pytest

import jax

from repro.configs.base import ModelConfig, SWMConfig
from repro.models.decoder import HybridDecoderLM
from repro.nn.module import init_params
from repro.serve.engine import (LatencyHistogram, Request, Scheduler,
                                ServeEngine)
from repro.serve.frontend import (SLO_CLASSES, AsyncFrontend, TenantConfig,
                                  TenantRejectedError, TokenBucket)
from repro.serve.guard import ManualClock, QueueFullError

jax.config.update("jax_platform_name", "cpu")

BATCH, CACHE = 2, 32


def _cfg(**kw):
    base = dict(name="tenants", n_layers=2, d_model=32, n_heads=2,
                n_kv_heads=1, head_dim=16, d_ff=64, vocab=48, remat="none",
                param_dtype="float32", compute_dtype="float32",
                swm=SWMConfig(block_size=8, impl="dft"))
    base.update(kw)
    return ModelConfig(**base)


@pytest.fixture(scope="module")
def lm():
    cfg = _cfg()
    model = HybridDecoderLM(cfg)
    params = init_params(model.specs(), 0)
    return cfg, model, params


def _engine(lm, **kw):
    cfg, model, params = lm
    kw.setdefault("batch", BATCH)
    kw.setdefault("cache_len", CACHE)
    return ServeEngine(model, cfg, params, **kw)


def _reqs(seed, n, tenant="default", plen=5, max_new=4):
    rng = np.random.default_rng(seed)
    return [Request(rng.integers(0, 48, size=plen).astype(np.int32),
                    max_new=max_new, tenant=tenant) for _ in range(n)]


# ---------------------------------------------------------------------------
# Scheduler: fair policy (weighted DRR)
# ---------------------------------------------------------------------------


class TestFairScheduler:
    def test_weighted_round_robin_order(self):
        s = Scheduler("fair", tenant_weights={"a": 2, "b": 1})
        for i in range(6):
            s.submit(f"a{i}", 4, tenant="a")
        for i in range(3):
            s.submit(f"b{i}", 4, tenant="b")
        got = [s.take(1)[0] for _ in range(9)]
        # single-item takes advance the rotation each call and bank the
        # unused deficit; the 2:1 weight ratio is honored in aggregate
        assert got == ["a0", "b0", "a1", "b1", "a2", "b2", "a3", "a4", "a5"]
        assert got.count("b0") + got.count("b1") + got.count("b2") == 3
        # aggregate service over any full-rotation window follows weights
        assert [g[0] for g in got[:6]].count("a") == 3

    def test_starvation_free_under_heavy_tenant(self):
        s = Scheduler("fair", tenant_weights={"big": 4, "small": 1})
        for i in range(100):
            s.submit(f"big{i}", 4, tenant="big")
        s.submit("small0", 4, tenant="small")
        # the small tenant is served within one DRR round, not after the
        # heavy tenant's whole backlog
        first_10 = [s.take(1)[0] for _ in range(10)]
        assert "small0" in first_10

    def test_unknown_tenants_default_weight_one(self):
        s = Scheduler("fair")       # no weights: every tenant weight 1
        s.submit("x0", 4, tenant="x")
        s.submit("y0", 4, tenant="y")
        s.submit("x1", 4, tenant="x")
        assert [s.take(1)[0] for _ in range(3)] == ["x0", "y0", "x1"]

    def test_take_batch_spans_rounds(self):
        s = Scheduler("fair", tenant_weights={"a": 2, "b": 1})
        for i in range(4):
            s.submit(f"a{i}", 4, tenant="a")
        for i in range(2):
            s.submit(f"b{i}", 4, tenant="b")
        assert s.take(6) == ["a0", "a1", "b0", "a2", "a3", "b1"]

    def test_weights_require_fair_policy(self):
        with pytest.raises(ValueError, match="fair"):
            Scheduler("fifo", tenant_weights={"a": 2})

    def test_weights_must_be_positive(self):
        with pytest.raises(ValueError, match="weight"):
            Scheduler("fair", tenant_weights={"a": 0})

    def test_put_front_beats_rotation(self):
        s = Scheduler("fair", tenant_weights={"a": 1, "b": 1})
        s.submit("a0", 4, tenant="a")
        s.submit("b0", 4, tenant="b")
        s.put_front("a-deferred", 9, tenant="a")
        got = [s.take(1)[0] for _ in range(3)]
        assert got[0] == "a-deferred"

    def test_state_dict_round_trip_preserves_order(self):
        s = Scheduler("fair", tenant_weights={"a": 2, "b": 1})
        for i in range(5):
            s.submit(f"a{i}", 4, tenant="a")
        for i in range(3):
            s.submit(f"b{i}", 4, tenant="b")
        consumed = [s.take(1)[0] for _ in range(3)]
        blob = json.loads(json.dumps(s.state_dict()))  # snapshot wire format
        s2 = Scheduler("fair", tenant_weights={"a": 2, "b": 1})
        s2.load_state(blob)
        rest = [s2.take(1)[0] for _ in range(len(s2))]
        # the restored scheduler continues the EXACT rotation the
        # original would have taken
        assert consumed == ["a0", "b0", "a1"]
        assert rest == [s.take(1)[0] for _ in range(len(s))]

    def test_fifo_sjf_order_unchanged_by_tenant_field(self):
        # FIFO/SJF must ignore tenants entirely (bit-identical ordering)
        f = Scheduler("fifo")
        for i, t in enumerate(["a", "b", "a", "c"]):
            f.submit(i, 4 + i, tenant=t)
        assert f.take(4) == [0, 1, 2, 3]
        s = Scheduler("sjf")
        s.submit("long", 20, tenant="a")
        s.submit("short", 2, tenant="b")
        s.submit("mid", 10, tenant="a")
        assert s.take(3) == ["short", "mid", "long"]


# ---------------------------------------------------------------------------
# Satellite: drop-oldest under burst (O(log n) shed path)
# ---------------------------------------------------------------------------


class TestDropOldestBurst:
    @pytest.mark.timeout(60)
    def test_sustained_burst_keeps_newest_in_order(self):
        # regression: drop_oldest used to rescan + heapify the whole queue
        # per shed (O(n) each, quadratic under sustained overload). 20k
        # submissions against a 64-deep queue must both stay correct and
        # finish fast (the hard timeout catches a quadratic regression).
        s = Scheduler("fifo", max_queue=64, shed_policy="drop-oldest")
        for i in range(20_000):
            s.submit(i, 4)
        assert len(s) == 64
        assert s.take(64) == list(range(20_000 - 64, 20_000))

    @pytest.mark.timeout(60)
    def test_burst_under_sjf_drops_oldest_not_longest(self):
        s = Scheduler("sjf", max_queue=4, shed_policy="drop-oldest")
        for i, plen in enumerate([9, 1, 8, 2, 7]):
            s.submit(f"r{i}", plen)
        # r0 (oldest) was dropped regardless of its sjf key; the rest
        # drain in prompt-length order
        assert s.take(4) == ["r1", "r3", "r4", "r2"]

    def test_drop_oldest_interleaved_with_takes(self):
        s = Scheduler("fifo", max_queue=3, shed_policy="drop-oldest")
        s.submit("a", 4)
        s.submit("b", 4)
        assert s.take(1) == ["a"]         # lazy heap entry for "a" is dead
        s.submit("c", 4)
        s.submit("d", 4)
        s.submit("e", 4)                  # sheds "b" — not the dead "a"
        assert s.take(3) == ["c", "d", "e"]


# ---------------------------------------------------------------------------
# Satellite: put_front under sjf with interleaved purges
# ---------------------------------------------------------------------------


class TestPutFrontSJF:
    def test_reenters_ahead_of_same_key_entries(self):
        s = Scheduler("sjf")
        for i in range(3):
            s.submit(f"q{i}", 10)        # all the same sjf key
        s.submit("short", 2)
        deferred = s.take(1)             # sjf serves the short prompt first
        assert deferred == ["short"]
        # a deferred long-prompt request re-enters ahead of ALL same-key
        # queued entries, not behind them
        s.put_front("deferred-long", 10)
        assert s.take(1) == ["deferred-long"]
        assert s.take(3) == ["q0", "q1", "q2"]

    def test_survives_interleaved_purge(self):
        s = Scheduler("sjf")
        keep = []
        for i in range(4):
            s.submit(i, 10)
            keep.append(i)
        s.put_front(100, 10)
        # purge everything except the front item and two same-key entries
        s.purge(lambda item: item in {100, 1, 3})
        assert s.take(1) == [100], \
            "purge() must not demote a put_front entry behind same-key items"
        assert s.take(2) == [1, 3]

    def test_multiple_put_fronts_lifo_among_themselves(self):
        s = Scheduler("sjf")
        s.submit("q", 10)
        s.put_front("first", 10)
        s.purge(lambda item: True)        # no-op purge of live entries
        s.put_front("second", 10)
        assert s.take(3) == ["second", "first", "q"]


# ---------------------------------------------------------------------------
# Latency histograms (SLO instrumentation)
# ---------------------------------------------------------------------------


class TestLatencyHistogram:
    def test_quantiles_upper_bound_semantics(self):
        h = LatencyHistogram()
        for ms in (0.5, 1.5, 3.0, 40.0, 900.0):
            h.observe(ms)
        assert h.count == 5
        assert h.p50 >= 3.0            # the covering bucket's upper bound
        assert h.p99 >= 900.0
        assert h.quantile(0.2) >= 0.5

    def test_empty_histogram_has_no_quantiles(self):
        h = LatencyHistogram()
        assert h.p50 is None and h.p99 is None and h.count == 0

    def test_overflow_bucket_is_inf(self):
        h = LatencyHistogram()
        h.observe(1e9)
        assert h.p99 == float("inf")

    def test_counts_round_trip_exactly(self):
        h = LatencyHistogram()
        for ms in (0.01, 2.0, 2.0, 77.0, 1e4):
            h.observe(ms)
        h2 = LatencyHistogram(json.loads(json.dumps(list(h.counts))))
        assert list(h2.counts) == list(h.counts)
        assert h2.p50 == h.p50 and h2.p99 == h.p99

    def test_bad_counts_rejected_with_actionable_error(self):
        with pytest.raises(ValueError, match="bucket"):
            LatencyHistogram([1, 2, 3])


# ---------------------------------------------------------------------------
# Engine-level: tenant stats, TTFT through snapshot, retry hints,
# autosnapshot origin fix
# ---------------------------------------------------------------------------


class TestEngineTenancy:
    def test_per_tenant_stats_and_fair_service(self, lm):
        clk = ManualClock()
        eng = _engine(lm, policy="fair",
                      tenant_weights={"a": 2, "b": 1}, clock=clk)
        reqs = _reqs(0, 4, tenant="a") + _reqs(1, 2, tenant="b")
        rids = [eng.submit(r) for r in reqs]
        while eng.step():
            clk.advance(0.002)
        s = eng.stats
        assert s.tenants["a"].submitted == 4
        assert s.tenants["a"].completed == 4
        assert s.tenants["b"].completed == 2
        assert s.tenants["a"].tokens == 16 and s.tenants["b"].tokens == 8
        assert s.ttft_ms.count == 6
        assert s.tok_ms.count == 6 * 4 - 6   # every non-first token
        for rid in rids:
            assert eng.poll(rid).status == "FINISHED"

    def test_invalid_tenant_rejected_at_request(self):
        with pytest.raises(ValueError, match="tenant"):
            Request(np.asarray([1, 2], np.int32), tenant="")

    def test_ttft_histograms_survive_snapshot_restore(self, lm):
        clk = ManualClock()
        with tempfile.TemporaryDirectory() as d:
            eng = _engine(lm, snapshot_dir=d, clock=clk,
                          tenant_weights=None)
            reqs = _reqs(2, 4, tenant="t0", max_new=6)
            rids = [eng.submit(r) for r in reqs]
            for _ in range(4):
                eng.step()
                clk.advance(0.002)
            assert eng.stats.ttft_ms.count > 0
            eng.snapshot()
            saved = list(eng.stats.ttft_ms.counts)
            saved_t = eng.stats.tenants["t0"].as_dict()

            eng2 = _engine(lm, snapshot_dir=d, clock=clk)
            eng2.restore()
            assert list(eng2.stats.ttft_ms.counts) == saved
            assert eng2.stats.tenants["t0"].as_dict() == saved_t
            while eng2.step():
                clk.advance(0.002)
            # the restored engine keeps observing into the same histograms
            assert eng2.stats.ttft_ms.count == 4

    def test_retry_after_hint_flows_from_drain_rate(self, lm):
        clk = ManualClock()
        eng = _engine(lm, max_queue=2, clock=clk)
        assert eng.retry_after_hint() is None   # nothing drained yet
        for r in _reqs(3, 4, max_new=2):
            try:
                eng.submit(r)
            except QueueFullError as e:
                assert e.retry_after_hint is None
        while eng.step():
            clk.advance(0.01)
        clk.advance(0.01)
        eng.step()      # one idle step: the last burst's drain registers
        assert eng.retry_after_hint() is not None       # rate observed
        for r in _reqs(4, 8, max_new=2):
            try:
                eng.submit(r)
            except QueueFullError as e:
                assert e.retry_after_hint is not None
                assert 1e-3 <= e.retry_after_hint <= 60.0
                break
        else:
            pytest.fail("queue bound never hit")
        eng.drain()

    def test_autosnapshot_skips_empty_engine(self, lm):
        with tempfile.TemporaryDirectory() as d:
            eng = _engine(lm, snapshot_dir=d, snapshot_every=1)
            for _ in range(3):
                eng.step()              # idle steps: nothing to snapshot
            assert eng.stats.snapshots == 0
            from repro.ft.checkpoint import latest_step
            assert latest_step(d) is None
            rids = [eng.submit(r) for r in _reqs(5, 2)]
            eng.step()
            assert eng.stats.snapshots > 0  # work present: snapshots resume
            eng.drain(rids)

    def test_restore_from_empty_snapshot_refused(self, lm):
        with tempfile.TemporaryDirectory() as d:
            eng = _engine(lm, snapshot_dir=d)
            eng.snapshot()              # explicit empty snapshot
            eng2 = _engine(lm, snapshot_dir=d)
            with pytest.raises(ValueError, match="EMPTY"):
                eng2.restore()


# ---------------------------------------------------------------------------
# Async front-end (stub engine, injected sleep: no wall-clock waits)
# ---------------------------------------------------------------------------


class _StubEngine:
    def __init__(self, reject_first=0, hint=None):
        self.reject_first = reject_first
        self.hint = hint
        self.submitted = []
        self._rid = 0

    def submit(self, request):
        if self.reject_first > 0:
            self.reject_first -= 1
            raise QueueFullError(5, 5, retry_after_hint=self.hint)
        self._rid += 1
        self.submitted.append(request)
        return self._rid

    def step(self):
        return False


def _fe(engine, clk=None, **kw):
    sleeps = []

    async def fake_sleep(s):
        sleeps.append(s)
        if clk is not None and s > 0:
            clk.advance(s)

    kw.setdefault("tenants", {
        "vip": TenantConfig("vip", slo="interactive", rate=10.0, burst=2),
        "bulk": TenantConfig("bulk", slo="batch", rate=100.0, burst=50),
    })
    fe = AsyncFrontend(engine, sleep=fake_sleep,
                       clock=(clk if clk is not None else (lambda: 0.0)),
                       **kw)
    return fe, sleeps


class TestAsyncFrontend:
    def test_slo_deadline_default_applied(self):
        eng = _StubEngine()
        fe, _ = _fe(eng)
        req = Request(np.asarray([1, 2, 3], np.int32))
        asyncio.run(fe.submit("vip", req))
        sub = eng.submitted[0]
        assert sub.tenant == "vip"
        assert sub.deadline_ms == SLO_CLASSES["interactive"].deadline_ms

    def test_explicit_deadline_not_overridden(self):
        eng = _StubEngine()
        fe, _ = _fe(eng)
        req = Request(np.asarray([1], np.int32), deadline_ms=123.0)
        asyncio.run(fe.submit("vip", req))
        assert eng.submitted[0].deadline_ms == 123.0

    def test_batch_class_keeps_no_deadline(self):
        eng = _StubEngine()
        fe, _ = _fe(eng)
        asyncio.run(fe.submit("bulk", Request(np.asarray([1], np.int32))))
        assert eng.submitted[0].deadline_ms is None

    def test_unregistered_tenant_rejected(self):
        fe, _ = _fe(_StubEngine())
        with pytest.raises(KeyError, match="unregistered"):
            asyncio.run(fe.submit("ghost",
                                  Request(np.asarray([1], np.int32))))

    def test_backoff_uses_retry_after_hint_proportionally(self):
        eng = _StubEngine(reject_first=3, hint=0.5)
        fe, sleeps = _fe(eng, max_retries=4, jitter=0.0)
        rid = asyncio.run(fe.submit("bulk",
                                    Request(np.asarray([1], np.int32))))
        assert rid == 1
        backoffs = [s for s in sleeps if s > 0]
        # hint * (attempt + 1): proportional, not constant spinning
        assert backoffs == [0.5, 1.0, 1.5]

    def test_exhausted_retries_raise_tenant_scoped(self):
        eng = _StubEngine(reject_first=99, hint=0.01)
        fe, _ = _fe(eng, max_retries=2, jitter=0.0)
        with pytest.raises(TenantRejectedError) as ei:
            asyncio.run(fe.submit("bulk",
                                  Request(np.asarray([1], np.int32))))
        assert ei.value.tenant == "bulk"
        assert ei.value.attempts == 3
        assert fe.rejections["bulk"] == 1

    def test_token_bucket_throttles_burst(self):
        clk = ManualClock()
        eng = _StubEngine()
        fe, sleeps = _fe(eng, clk=clk)
        # vip: rate 10/s, burst 2 — the 3rd submit must wait ~0.1 s
        async def burst():
            for _ in range(3):
                await fe.submit("vip", Request(np.asarray([1], np.int32)))
        asyncio.run(burst())
        waits = [s for s in sleeps if s > 0]
        assert waits and abs(waits[0] - 0.1) < 1e-6
        assert len(eng.submitted) == 3

    def test_tenant_weights_follow_slo_classes(self):
        fe, _ = _fe(_StubEngine())
        assert fe.tenant_weights() == {"vip": 4, "bulk": 1}

    def test_token_bucket_refills_on_clock(self):
        clk = ManualClock()
        b = TokenBucket(rate=2.0, burst=2, clock=clk)
        assert b.try_take() and b.try_take() and not b.try_take()
        assert abs(b.wait_time() - 0.5) < 1e-9
        clk.advance(0.5)
        assert b.try_take()

    def test_run_drives_engine_submissions_to_terminal(self, lm):
        eng = _engine(lm, policy="fair", tenant_weights={"vip": 1,
                                                         "bulk": 1})
        # both tenants on the batch class: no deadline defaults, so slow
        # CI interpret runs can never EXPIRE these requests
        fe = AsyncFrontend(eng, {
            "vip": TenantConfig("vip", slo="batch", rate=1e4, burst=100),
            "bulk": TenantConfig("bulk", slo="batch", rate=1e4, burst=100),
        })

        async def main():
            rids = []
            for i, r in enumerate(_reqs(6, 4, max_new=3)):
                rids.append(await fe.submit("vip" if i % 2 else "bulk", r))
            await fe.run(idle_rounds=2)
            return [await fe.result(rid) for rid in rids]

        states = asyncio.run(main())
        assert all(st.status == "FINISHED" for st in states)
        assert all(len(st.tokens) == 3 for st in states)


class TestLauncherTenantParsing:
    def _parse(self, text, default_slo="standard"):
        import argparse

        from repro.launch.serve import _parse_tenants
        ap = argparse.ArgumentParser()
        return _parse_tenants(ap, text, default_slo)

    def test_names_and_slos(self):
        out = self._parse("app:interactive,jobs:batch,web")
        assert sorted(out) == ["app", "jobs", "web"]
        assert out["app"].slo == "interactive"
        assert out["jobs"].slo == "batch"
        assert out["web"].slo == "standard"      # default fills in

    def test_empty_text_means_no_tenants(self):
        assert self._parse("") == {}

    def test_unknown_slo_class_errors(self):
        with pytest.raises(SystemExit):
            self._parse("app:gold")

    def test_duplicate_tenant_errors(self):
        with pytest.raises(SystemExit):
            self._parse("app,app:batch")

    def test_empty_entry_errors(self):
        with pytest.raises(SystemExit):
            self._parse("app,,jobs")
