"""ModelRunner conformance grid: every family served by the engine must be
bucket-shape invariant (bucketed prefill bit-identical to the unbucketed
B=1 loop through the same runner), the decoder family must be bit-identical
to the pre-refactor reference path (``make_prefill_step``/
``make_decode_step``), snapshot/restore must round-trip per runner, and the
capability flags must gate the prefix cache and the wave baseline."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import LayerGroup, LayerSpec, ModelConfig, SWMConfig
from repro.launch.specs import build_model
from repro.nn.module import init_params
from repro.serve.engine import (Request, ServeEngine, WaveEngine,
                                make_decode_step, make_prefill_step)
from repro.serve.guard import flatten_state_tree, unflatten_state_tree
from repro.serve.runner import (DecoderRunner, EncDecRunner, RecurrentRunner,
                                make_runner, recurrent_mixer_names)

jax.config.update("jax_platform_name", "cpu")

_BASE = dict(name="rt", d_model=32, n_heads=2, n_kv_heads=1, head_dim=16,
             d_ff=64, vocab=48, remat="none", param_dtype="float32",
             compute_dtype="float32")


def _swm():
    return SWMConfig(block_size=8, impl="dft")


def _cfg_attn():
    return ModelConfig(**_BASE, n_layers=2, swm=_swm())


def _cfg_rwkv():
    return ModelConfig(**_BASE, n_layers=2, rwkv_head_dim=16,
                       rwkv_decay_lora=8, rwkv_mix_lora=8, swm=_swm(),
                       groups=(LayerGroup(layers=(
                           LayerSpec(mixer="rwkv", ffn="dense"),),
                           repeat=2),))


def _cfg_mamba():
    return ModelConfig(**_BASE, n_layers=2, swm=_swm(),
                       groups=(LayerGroup(layers=(
                           LayerSpec(mixer="mamba", ffn="dense"),),
                           repeat=2),))


def _cfg_jamba():
    return ModelConfig(**_BASE, n_layers=4, n_experts=4,
                       n_experts_per_token=2, d_ff_expert=64, swm=_swm(),
                       groups=(LayerGroup(layers=(
                           LayerSpec(mixer="mamba", ffn="dense"),
                           LayerSpec(mixer="attn", ffn="moe"),
                           LayerSpec(mixer="mamba", ffn="dense"),
                           LayerSpec(mixer="attn", ffn="moe"),),
                           repeat=1),))


def _cfg_moe():
    return ModelConfig(**_BASE, n_layers=2, n_experts=8,
                       n_experts_per_token=4, d_ff_expert=64, swm=_swm(),
                       groups=(LayerGroup(layers=(
                           LayerSpec(mixer="attn", ffn="moe"),
                           LayerSpec(mixer="attn", ffn="moe"),),
                           repeat=1),))


def _cfg_encdec():
    return ModelConfig(**{**_BASE, "n_kv_heads": 2}, family="encdec",
                       n_layers=2, n_enc_layers=2, enc_seq=8, swm=_swm())


FAMILY_CFGS = {
    "attn": _cfg_attn,
    "rwkv": _cfg_rwkv,
    "mamba": _cfg_mamba,
    "jamba": _cfg_jamba,
    "moe": _cfg_moe,
    "encdec": _cfg_encdec,
}

EXPECTED_RUNNER = {
    "attn": DecoderRunner,
    "rwkv": RecurrentRunner,
    "mamba": RecurrentRunner,
    "jamba": RecurrentRunner,
    "moe": DecoderRunner,
    "encdec": EncDecRunner,
}


def _built(family):
    cfg = FAMILY_CFGS[family]()
    model = build_model(cfg)
    params = init_params(model.specs(), 0)
    return cfg, model, params


def _reqs(cfg, seed=7, lens=(3, 9, 5, 12, 2, 7), max_new=3):
    """Mixed prompt lengths so bucketed admission actually pads."""
    rng = np.random.default_rng(seed)
    out = []
    for L in lens:
        extra = None
        if cfg.family == "encdec":
            extra = rng.standard_normal(
                (cfg.enc_seq, cfg.d_model)).astype(np.float32)
        out.append(Request(
            prompt=rng.integers(1, cfg.vocab, size=L).astype(np.int32),
            max_new=max_new, extra=extra))
    return out


def _b1_oracle(runner, params, reqs, cache_len):
    """Greedy B=1 loop THROUGH the runner: exact prompt length (never a
    bucket), fresh per-request state — the unbucketed ground truth every
    bucketed engine run must match bit for bit."""
    outs = []
    for r in reqs:
        p = np.asarray(r.prompt, np.int32).reshape(-1)
        L = p.shape[0]
        state = runner.init_state(1)
        kw = {}
        if r.extra is not None:
            kw["extra"] = jnp.asarray(r.extra)[None]
        lg, ok, state = runner.prefill(
            params, jnp.asarray(p)[None],
            jnp.asarray(np.arange(L, dtype=np.int32))[None],
            state, jnp.asarray([0], jnp.int32), **kw)
        assert bool(np.asarray(ok)[0])
        cur = int(np.argmax(np.asarray(lg)[0]))
        out, pos = [cur], L
        while len(out) < r.max_new:
            lg, ok, state = runner.decode(
                params, jnp.asarray([[cur]], jnp.int32), state,
                jnp.asarray([pos], jnp.int32), jnp.asarray([0], jnp.int32))
            cur = int(np.argmax(np.asarray(lg)[0]))
            out.append(cur)
            pos += 1
        outs.append(out)
    return outs


# ---------------------------------------------------------------------------
# Conformance: bucketed engine == unbucketed B=1 runner loop
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("family", ["rwkv", "mamba", "jamba", "moe",
                                    "encdec"])
def test_bucketed_matches_b1(family):
    cfg, model, params = _built(family)
    reqs = _reqs(cfg)
    eng = ServeEngine(model, cfg, params, batch=4, cache_len=32)
    assert isinstance(eng.runner, EXPECTED_RUNNER[family])
    outs = eng.generate(reqs)
    ref = _b1_oracle(make_runner(model, cfg, 32), eng.params, reqs, 32)
    assert outs == ref
    # bucketing must also stay inside the compile budget
    assert eng.prefill_compiles <= eng.max_prefill_variants
    assert eng.decode_compiles <= eng.max_decode_variants


def test_decoder_family_matches_prerefactor_reference():
    """The attention-decoder path must be bit-identical to the untouched
    pre-refactor builders (``make_prefill_step``/``make_decode_step``) —
    the refactor's correctness oracle."""
    cfg, model, params = _built("attn")
    reqs = _reqs(cfg)
    eng = ServeEngine(model, cfg, params, batch=4, cache_len=32)
    assert isinstance(eng.runner, DecoderRunner)
    outs = eng.generate(reqs)

    prefill = jax.jit(make_prefill_step(model, cfg))
    decode = jax.jit(make_decode_step(model, cfg))
    ref = []
    for r in reqs:
        p = np.asarray(r.prompt, np.int32).reshape(-1)
        cache = model.init_cache(1, 32)
        logits, cache = prefill(eng.params, jnp.asarray(p)[None], cache)
        cur = int(np.argmax(np.asarray(logits)[0]))
        out, pos = [cur], len(p)
        while len(out) < r.max_new:
            logits, cache = decode(eng.params,
                                   jnp.asarray([[cur]], np.int32), cache,
                                   jnp.asarray([pos], np.int32))
            cur = int(np.argmax(np.asarray(logits)[0]))
            out.append(cur)
            pos += 1
        ref.append(out)
    assert outs == ref


# ---------------------------------------------------------------------------
# Snapshot / restore round-trips per runner
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("family", ["attn", "rwkv", "encdec"])
def test_snapshot_restore_roundtrip(family, tmp_path):
    cfg, model, params = _built(family)
    reqs = _reqs(cfg, lens=(4, 7, 3))
    d = str(tmp_path)
    eng = ServeEngine(model, cfg, params, batch=2, cache_len=32,
                      snapshot_dir=d)
    for r in reqs:
        eng.submit(r)
    eng.step()
    eng.step()
    eng.snapshot()
    fresh = ServeEngine(model, cfg, params, batch=2, cache_len=32,
                        snapshot_dir=d)
    fresh.restore()
    assert eng.drain() == fresh.drain()


def test_snapshot_rejects_other_family(tmp_path):
    """A snapshot taken by one family must not restore into another: the
    fingerprint names the runner, and the opaque state tree leaf count is
    checked against the restoring runner's template."""
    cfg_a, model_a, params_a = _built("attn")
    eng = ServeEngine(model_a, cfg_a, params_a, batch=2, cache_len=32,
                      snapshot_dir=str(tmp_path))
    eng.snapshot()
    cfg_r, model_r, params_r = _built("rwkv")
    other = ServeEngine(model_r, cfg_r, params_r, batch=2, cache_len=32,
                        snapshot_dir=str(tmp_path))
    with pytest.raises(ValueError, match="fingerprint"):
        other.restore()


def test_state_tree_flatten_roundtrip():
    """The generic serialization helpers must round-trip every family's
    state tree bit for bit (canonical leaf order, dtype cast through the
    template)."""
    for family in ("attn", "rwkv", "jamba", "encdec"):
        cfg, model, params = _built(family)
        runner = make_runner(model, cfg, 16)
        state = runner.init_state(2)
        flat = flatten_state_tree(state)
        rebuilt = unflatten_state_tree(runner.init_state(2), flat)
        a = jax.tree_util.tree_leaves(state)
        b = jax.tree_util.tree_leaves(rebuilt)
        assert len(a) == len(b)
        for x, y in zip(a, b):
            assert x.dtype == y.dtype
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    with pytest.raises(ValueError, match="leaves"):
        unflatten_state_tree(runner.init_state(2), {"s00000": np.zeros(3)})


# ---------------------------------------------------------------------------
# Capability flags: prefix cache, extra conditioning, wave guards
# ---------------------------------------------------------------------------


def test_prefix_cache_gated_on_capability():
    cfg, model, params = _built("rwkv")
    with pytest.raises(ValueError, match="recurrent state"):
        ServeEngine(model, cfg, params, batch=2, cache_len=32,
                    prefix_cache=True)
    cfg_e, model_e, params_e = _built("encdec")
    with pytest.raises(ValueError, match="prefix_cache"):
        ServeEngine(model_e, cfg_e, params_e, batch=2, cache_len=32,
                    prefix_cache=True)


def test_prefix_index_inert_without_capability():
    """Regression: the index/matcher must be no-ops for runners whose
    state has no per-position rows, even if called directly — a recurrent
    donor entry would promise a row copy the runner cannot make."""
    cfg, model, params = _built("rwkv")
    eng = ServeEngine(model, cfg, params, batch=2, cache_len=32)
    prompt = np.arange(1, 17, dtype=np.int32)
    eng._index_insert(0, prompt)
    assert len(eng._prefix_index) == 0
    assert eng._slot_prompt[0] is None
    assert eng._match_prefix(prompt) == (None, 0)


def test_decoder_extra_rejected():
    cfg, model, params = _built("attn")
    eng = ServeEngine(model, cfg, params, batch=2, cache_len=32)
    bad = Request(prompt=np.arange(1, 5, dtype=np.int32), max_new=2,
                  extra=np.zeros((4, 4), np.float32))
    with pytest.raises(ValueError, match="extra"):
        eng.generate([bad])


def test_encdec_request_validation():
    cfg, model, params = _built("encdec")
    eng = ServeEngine(model, cfg, params, batch=2, cache_len=32)
    with pytest.raises(ValueError, match="encoder frames"):
        eng.generate([Request(prompt=np.arange(1, 5, dtype=np.int32),
                              max_new=2)])
    with pytest.raises(ValueError, match="shape"):
        eng.generate([Request(prompt=np.arange(1, 5, dtype=np.int32),
                              max_new=2,
                              extra=np.zeros((3, 3), np.float32))])


def test_wave_engine_guards():
    cfg, model, params = _built("encdec")
    with pytest.raises(ValueError, match="decoder-LM baseline"):
        WaveEngine(model, cfg, params, batch=1, cache_len=32)
    cfg_m, model_m, params_m = _built("mamba")
    with pytest.raises(ValueError, match="recurrent state"):
        WaveEngine(model_m, cfg_m, params_m, batch=2, cache_len=32)
    WaveEngine(model_m, cfg_m, params_m, batch=1, cache_len=32)


def test_recurrent_mixer_names():
    assert recurrent_mixer_names(_cfg_attn()) == ()
    assert recurrent_mixer_names(_cfg_rwkv()) == ("rwkv",)
    assert recurrent_mixer_names(_cfg_jamba()) == ("mamba",)
    assert recurrent_mixer_names(_cfg_encdec()) == ()
