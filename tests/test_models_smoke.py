"""Per-architecture smoke tests (assignment requirement): reduced configs,
one forward/train step on CPU, asserting output shapes + no NaNs; plus
prefill+decode ≡ full-forward consistency for representative mixers."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import TrainConfig
from repro.configs.registry import ARCHS, get_smoke
from repro.launch.specs import build_model, count_params
from repro.nn.module import init_params
from repro.train.loop import init_train_state, make_train_step

jax.config.update("jax_platform_name", "cpu")

TCFG = TrainConfig(z_loss=0.0, learning_rate=1e-3)


def _batch(cfg, B=2, S=16, seed=0):
    toks = jax.random.randint(jax.random.PRNGKey(seed), (B, S + 1), 0,
                              cfg.vocab)
    batch = {"tokens": toks}
    if cfg.family == "vlm":
        batch["img"] = jax.random.normal(
            jax.random.PRNGKey(1), (B, cfg.n_img_tokens, cfg.d_model),
            jnp.float32)
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            jax.random.PRNGKey(1), (B, cfg.enc_seq, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_arch_forward_shapes_and_finite(arch):
    cfg = get_smoke(arch)
    model = build_model(cfg)
    params = init_params(model.specs(), 0)
    batch = _batch(cfg)
    if cfg.family == "encdec":
        logits, _, aux = model.forward(params, batch["frames"],
                                       batch["tokens"][:, :-1])
        assert logits.shape == (2, 16, cfg.vocab)
    elif cfg.family == "vlm":
        logits, _, aux = model.forward(params, batch["tokens"][:, :-1],
                                       img_embeds=batch["img"])
        assert logits.shape == (2, 16 + cfg.n_img_tokens, cfg.vocab)
    else:
        logits, _, aux = model.forward(params, batch["tokens"][:, :-1])
        assert logits.shape == (2, 16, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_arch_train_step(arch):
    cfg = get_smoke(arch)
    model = build_model(cfg)
    state = init_train_state(init_params(model.specs(), 0), TCFG)
    step = jax.jit(make_train_step(model, cfg, TCFG))
    state, metrics = step(state, _batch(cfg))
    assert np.isfinite(float(metrics["loss"]))
    assert int(state["step"]) == 1
    # a second step must also be finite (optimizer state update path)
    state, metrics = step(state, _batch(cfg, seed=7))
    assert np.isfinite(float(metrics["loss"]))


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "gemma3-27b", "rwkv6-7b",
                                  "jamba-v0.1-52b", "paligemma-3b"])
def test_arch_decode_consistency(arch):
    """prefill + step-by-step decode must equal the full forward pass."""
    cfg = get_smoke(arch)
    if cfg.n_experts:  # lossless capacity for exactness
        cfg = dataclasses.replace(cfg, capacity_factor=float(cfg.n_experts))
    model = build_model(cfg)
    params = init_params(model.specs(), 0)
    B, S, cache_len = 2, 12, 16
    toks = jax.random.randint(jax.random.PRNGKey(0), (B, S), 0, cfg.vocab)
    img = None
    if cfg.family == "vlm":
        img = jax.random.normal(jax.random.PRNGKey(1),
                                (B, cfg.n_img_tokens, cfg.d_model),
                                jnp.float32)
    full, _, _ = model.forward(params, toks, img_embeds=img)
    Sp = S - 4
    cache = model.init_cache(B, cache_len + (cfg.n_img_tokens or 0))
    lastp, cache = model.prefill(params, toks[:, :Sp], cache, img_embeds=img)
    off = cfg.n_img_tokens or 0
    np.testing.assert_allclose(np.asarray(lastp),
                               np.asarray(full[:, off + Sp - 1]),
                               rtol=1e-4, atol=1e-4)
    for t in range(Sp, S):
        pos = jnp.full((B,), off + t, jnp.int32)
        lg, cache = model.decode_step(params, toks[:, t:t + 1], cache, pos)
        np.testing.assert_allclose(np.asarray(lg),
                                   np.asarray(full[:, off + t]),
                                   rtol=1e-4, atol=1e-4)


def test_encdec_decode_consistency():
    cfg = get_smoke("seamless-m4t-medium")
    model = build_model(cfg)
    params = init_params(model.specs(), 0)
    B, S = 2, 10
    frames = jax.random.normal(jax.random.PRNGKey(1), (B, cfg.enc_seq,
                                                       cfg.d_model))
    toks = jax.random.randint(jax.random.PRNGKey(0), (B, S), 0, cfg.vocab)
    full, _, _ = model.forward(params, frames, toks)
    Sp = S - 3
    cache = model.init_cache(B, 16)
    logits, _, cache_aux = None, None, None
    out, cache, _ = model.forward(params, frames, toks[:, :Sp], cache=cache)
    np.testing.assert_allclose(np.asarray(out[:, -1]),
                               np.asarray(full[:, Sp - 1]), rtol=1e-4,
                               atol=1e-4)
    for t in range(Sp, S):
        pos = jnp.full((B,), t, jnp.int32)
        lg, cache = model.decode_step(params, toks[:, t:t + 1], cache, pos)
        np.testing.assert_allclose(np.asarray(lg), np.asarray(full[:, t]),
                                   rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_swm_compression_accounting(arch):
    """Full configs: SWM must compress ≥ 10× of the compressible weights."""
    counts = count_params(get_smoke(arch))
    assert counts["compression"] > 1.5, counts
