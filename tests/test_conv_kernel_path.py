"""CirculantConv2D on the shared block-circulant kernel path.

The conv layer's im2col GEMM is block-circulant over (taps × input-channel
blocks), so it reshapes to ONE (p, r²·q, k) table and runs through the same
``block_circulant_matmul`` as Linear — Pallas forward, kernel-backed dx/dw
adjoints, frozen frequency weights, tile/VMEM machinery. These tests pin
the new path against the pre-change implementation (raw ``jnp.fft.rfft`` +
einsum contraction, reproduced verbatim below as the reference): the
strided-gather im2col is bit-identical to the old loop-of-slices, the k=1
dense path is bit-identical end to end, and the k>1 kernel path matches the
fft-einsum reference to f32 round-off on both the forward and every
gradient (the fft→DFT-matmul transform swap reorders float ops, so exact
bit-equality is only defined for the paths that share the arithmetic).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.conv import CirculantConv2D, extract_patches
from repro.kernels.block_circulant.ops import (count_pallas_launches,
                                               outer_dot_shapes)
from repro.nn.module import init_params

jax.config.update("jax_platform_name", "cpu")


def _old_conv_reference(conv, params, x):
    """The pre-change CirculantConv2D.__call__: Python r² loop-of-slices
    im2col + raw rfft/einsum/irfft contraction. Kept as the oracle."""
    r, C, P, k = conv.ksize, conv.in_ch, conv.out_ch, conv.k
    B, H, W, _ = x.shape
    Ho, Wo = H - r + 1, W - r + 1
    patches = jnp.stack(
        [x[:, i: i + Ho, j: j + Wo, :] for i in range(r) for j in range(r)],
        axis=3,
    )
    w = params["w"]
    if k == 1:
        y = jnp.einsum("bhwtc,tcp->bhwp", patches, w.astype(x.dtype))
    else:
        q = C // k
        xb = patches.reshape(B, Ho, Wo, r * r, q, k)
        xh = jnp.fft.rfft(xb.astype(jnp.float32), axis=-1)
        wh = jnp.fft.rfft(w.astype(jnp.float32), axis=-1)
        yh = jnp.einsum("bhwtqf,tpqf->bhwpf", xh, wh)
        y = jnp.fft.irfft(yh, n=k, axis=-1).reshape(B, Ho, Wo, P)
        y = y.astype(x.dtype)
    return y + params["b"].astype(y.dtype)


def _conv(block_size, in_ch=8, out_ch=8, ksize=3):
    return CirculantConv2D(in_ch=in_ch, out_ch=out_ch, ksize=ksize,
                           block_size=block_size)


def test_patch_extraction_bitwise_matches_loop_im2col():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 9, 11, 5))
    for r in (1, 2, 3):
        Ho, Wo = 9 - r + 1, 11 - r + 1
        loop = jnp.stack(
            [x[:, i: i + Ho, j: j + Wo, :]
             for i in range(r) for j in range(r)], axis=3)
        np.testing.assert_array_equal(np.asarray(extract_patches(x, r)),
                                      np.asarray(loop))


@pytest.mark.parametrize("block_size,ksize", [(4, 3), (8, 5), (2, 2)])
def test_conv_forward_matches_fft_einsum_reference(block_size, ksize):
    conv = _conv(block_size, ksize=ksize)
    params = init_params(conv.specs(), 0)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 10, 10, 8))
    y = conv(params, x)
    y_ref = _old_conv_reference(conv, params, x)
    assert y.shape == y_ref.shape
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-5, atol=2e-5)


def test_conv_k1_dense_path_bitwise_unchanged():
    """The k=1 path shares every op with the pre-change code: bit-for-bit."""
    conv = _conv(1)
    params = init_params(conv.specs(), 0)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 7, 7, 8))
    assert bool(jnp.all(conv(params, x) == _old_conv_reference(
        conv, params, x)))


def test_conv_backward_matches_fft_einsum_reference():
    conv = _conv(4)
    params = init_params(conv.specs(), 0)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 8, 8))
    loss_new = lambda p, x: (conv(p, x) ** 2).sum()
    loss_ref = lambda p, x: (_old_conv_reference(conv, p, x) ** 2).sum()
    (gp, gx) = jax.grad(loss_new, (0, 1))(params, x)
    (gp_r, gx_r) = jax.grad(loss_ref, (0, 1))(params, x)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(gx_r),
                               rtol=2e-4, atol=2e-4)
    for key in gp:
        np.testing.assert_allclose(np.asarray(gp[key]),
                                   np.asarray(gp_r[key]),
                                   rtol=2e-4, atol=2e-4, err_msg=key)


def test_conv_small_input_raises_clear_error():
    conv = _conv(4, ksize=3)
    params = init_params(conv.specs(), 0)
    with pytest.raises(ValueError, match="smaller than ksize"):
        conv(params, jnp.zeros((1, 2, 8, 8)))
    with pytest.raises(ValueError, match="smaller than ksize"):
        conv(params, jnp.zeros((1, 8, 2, 8)))


def test_conv_frozen_freq_path_matches_and_has_no_fft():
    """freeze_params swaps the tagged tap table for (wr, wi); the frozen
    forward is bit-identical to the unfrozen kernel path (same kernel,
    same frequency tables) and traces with no fft primitive."""
    from repro.kernels.block_circulant.plan import freeze_params

    conv = _conv(4)
    params = init_params(conv.specs(), 0)
    frozen = freeze_params(conv.specs(), params)
    assert set(frozen) == {"wr", "wi", "b"}     # time-domain table dropped
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 8, 8))
    assert bool(jnp.all(conv(frozen, x) == conv(params, x)))
    jp = str(jax.make_jaxpr(lambda p, x: conv(p, x))(frozen, x))
    assert "fft" not in jp
    # idempotent
    assert freeze_params(conv.specs(), frozen) is frozen


def test_conv_train_step_jaxpr_kernel_backed():
    """Conv train step: forward z + dx + dw all run as Pallas launches; no
    dot_general outside a kernel anywhere in the step."""
    from repro.train.loop import make_grad_step

    conv = _conv(4)
    params = init_params(conv.specs(), 0)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 8, 8))
    t = jax.random.normal(jax.random.PRNGKey(2), (2, 6, 6, 8))
    loss = lambda p, x: ((conv(p, x) - t) ** 2).mean()
    jp = jax.make_jaxpr(jax.value_and_grad(loss))(params, x)
    dots = outer_dot_shapes(jp)
    assert dots == [], dots
    assert count_pallas_launches(jp) == 3
    step = make_grad_step(loss)
    p1, l0 = step(params, x)
    for _ in range(5):
        p1, l = step(p1, x)
    assert float(l) < float(l0)
