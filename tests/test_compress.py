"""Gradient compression: int8 round-trip bounds + error-feedback property."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.dist.compress import (apply_error_feedback, int8_compress,
                                 int8_decompress)

jax.config.update("jax_platform_name", "cpu")


def test_int8_roundtrip_error_bound():
    g = jax.random.normal(jax.random.PRNGKey(0), (3, 700))
    q, s = int8_compress(g)
    deq = int8_decompress(q, s, g.shape, g.size)
    err = np.abs(np.asarray(deq - g))
    bound = np.asarray(s).max() * 0.5 + 1e-6
    assert err.max() <= bound


@given(st.integers(0, 5))
@settings(max_examples=5, deadline=None)
def test_error_feedback_telescopes(seed):
    """Σ transmitted_t == Σ g_t − residual_T: no gradient is ever lost."""
    key = jax.random.PRNGKey(seed)
    residual = jnp.zeros((257,))
    total_g = jnp.zeros((257,))
    total_tx = jnp.zeros((257,))
    for t in range(6):
        key, k = jax.random.split(key)
        g = jax.random.normal(k, (257,)) * (10.0 ** (t % 3))
        tx, residual = apply_error_feedback(g, residual)
        total_g += g
        total_tx += tx
    np.testing.assert_allclose(np.asarray(total_tx + residual),
                               np.asarray(total_g), rtol=1e-4, atol=1e-4)


def test_compressed_psum_single_shard_identity():
    """On a 1-shard mesh the compressed all-reduce must equal plain quantize."""
    from jax.sharding import Mesh, PartitionSpec as P
    from jax.experimental.shard_map import shard_map
    from repro.dist.compress import compressed_psum_grads

    mesh = jax.make_mesh((1,), ("data",))
    g = {"w": jax.random.normal(jax.random.PRNGKey(0), (130,))}
    r = {"w": jnp.zeros((130,))}

    def f(g, r):
        return compressed_psum_grads(g, r, mesh, axes=("data",))

    red, new_r = shard_map(f, mesh=mesh,
                           in_specs=(P(), P()), out_specs=(P(), P()))(g, r)
    np.testing.assert_allclose(np.asarray(red["w"] + new_r["w"]),
                               np.asarray(g["w"]), rtol=1e-4, atol=1e-4)
