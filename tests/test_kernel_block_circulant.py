"""Pallas kernel sweeps (interpret mode) vs the pure-jnp dense oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.block_circulant import block_circulant_matmul
from repro.kernels.block_circulant.ref import block_circulant_matmul_ref

jax.config.update("jax_platform_name", "cpu")


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
           dict(rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,p,q,k", [
    (4, 3, 5, 8), (16, 2, 2, 128), (7, 1, 3, 64), (32, 4, 4, 16),
    (3, 2, 2, 2), (1, 1, 1, 256), (130, 2, 3, 32),   # odd batch > block
])
def test_kernel_shape_dtype_sweep(B, p, q, k, dtype):
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (p, q, k), jnp.float32).astype(dtype)
    x = jax.random.normal(jax.random.PRNGKey(1), (B, q * k),
                          jnp.float32).astype(dtype)
    y = block_circulant_matmul(x, w)
    y_ref = block_circulant_matmul_ref(
        x.astype(jnp.float32), w.astype(jnp.float32))
    assert y.dtype == dtype
    np.testing.assert_allclose(
        np.asarray(y, np.float32), np.asarray(y_ref, np.float32), **_tol(dtype)
    )


def test_kernel_3d_batch():
    w = jax.random.normal(jax.random.PRNGKey(0), (2, 3, 16))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 5, 48))
    y = block_circulant_matmul(x, w)
    assert y.shape == (2, 5, 32)
    y_ref = block_circulant_matmul_ref(x.reshape(10, 48), w).reshape(2, 5, 32)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=2e-4,
                               atol=2e-4)


def test_kernel_custom_vjp_matches_autodiff_of_ref():
    B, p, q, k = 4, 2, 3, 8
    w = jax.random.normal(jax.random.PRNGKey(0), (p, q, k))
    x = jax.random.normal(jax.random.PRNGKey(1), (B, q * k))
    t = jax.random.normal(jax.random.PRNGKey(2), (B, p * k))
    f_k = lambda x, w: (block_circulant_matmul(x, w) * t).sum()
    f_r = lambda x, w: (block_circulant_matmul_ref(x, w) * t).sum()
    gx_k, gw_k = jax.grad(f_k, (0, 1))(x, w)
    gx_r, gw_r = jax.grad(f_r, (0, 1))(x, w)
    np.testing.assert_allclose(np.asarray(gx_k), np.asarray(gx_r),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(gw_k), np.asarray(gw_r),
                               rtol=1e-4, atol=1e-4)


def test_kernel_inside_jit_and_grad_pipeline():
    """Kernel must compose with jit + optimizer-style updates."""
    w = jax.random.normal(jax.random.PRNGKey(0), (2, 2, 16))
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 32))
    y = jax.random.normal(jax.random.PRNGKey(2), (8, 32))

    @jax.jit
    def loss(w):
        return ((block_circulant_matmul(x, w) - y) ** 2).mean()

    l0 = loss(w)
    for _ in range(20):
        w = w - 0.1 * jax.grad(loss)(w)
    assert float(loss(w)) < float(l0)
