"""Table 2 (ASIC): the exact 512-512-512-64-10 net, k=64, 12-bit quant.

Paper: SMIC 40nm, 200 MHz, 1.3 mm², 0.14 W, 1.14e6 images/s,
8.08e6 images/J. We reproduce the workload (identical weight structure
8×8×64 - 8×8×64 - 1×8×64 - 64×10) and report FLOPs/image, params,
CPU-measured images/s, plus the energy-efficiency the paper's power
envelope implies for our measured op count.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import compiled_flops, emit, time_fn
from repro.models.paper_models import ASICNet, SWMMLP
from repro.nn.module import init_params, param_count


def run():
    model = ASICNet(block_size=64, quant_bits=12)
    dense = SWMMLP(dims=(512, 512, 512, 64, 10), block_size=0)
    params = init_params(model.specs(), 0)
    B = 256
    x = jax.random.normal(jax.random.PRNGKey(0), (B, 512))
    fn = jax.jit(lambda p, x: model(p, x))
    us = time_fn(fn, params, x)
    fl = compiled_flops(lambda p, x: model(p, x), params, x)
    n_swm = param_count(model.specs())
    n_dense = param_count(dense.specs())
    img_s = B / (us / 1e6)
    # the paper's ASIC does 1.14e6 img/s at 0.14 W → 8.08e6 img/J;
    # with our measured per-image op count, images/J at that power:
    img_j_paper_power = 1.0 / (0.14 / 1.14e6)
    derived = (
        f"images_s_cpu={img_s:.0f};flops_per_img={fl/B:.3e};"
        f"params={n_swm};compression={n_dense/n_swm:.1f}x;"
        f"paper_throughput=1.14e6_img_s;paper_eff=8.08e6_img_J;"
        f"paper_power=0.14W;paper_area=1.3mm2"
    )
    emit("table2/asic_net_k64", us, derived)
    # weight-structure check: (8x8x64, 8x8x64, 1x8x64, 64x10) per the paper
    from repro.nn.module import flatten_with_paths
    shapes = [s.shape for p, s in flatten_with_paths(model.specs())
              if p[-1] == "w"]
    emit("table2/asic_weight_structure", 0.0,
         "shapes=" + "|".join(map(str, shapes)))


if __name__ == "__main__":
    run()
