"""Pallas block-circulant kernel: correctness-at-shape sweep + VMEM budget.

Wall-times here run the kernel in INTERPRET mode (no TPU in this
container) and are labeled as such — the meaningful outputs are the
rel-error vs the dense oracle, the chosen tile sizes, and the VMEM
working-set estimate per tile (must be < 16 MB v5e VMEM).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.kernels.block_circulant import block_circulant_matmul
from repro.kernels.block_circulant.kernel import choose_blocks
from repro.kernels.block_circulant.ref import block_circulant_matmul_ref


def run():
    for (B, p, q, k) in [(128, 8, 8, 128), (256, 24, 8, 128),
                         (64, 32, 32, 16), (512, 4, 4, 64)]:
        x = jax.random.normal(jax.random.PRNGKey(0), (B, q * k), jnp.float32)
        w = jax.random.normal(jax.random.PRNGKey(1), (p, q, k), jnp.float32)
        y = block_circulant_matmul(x, w)
        y_ref = block_circulant_matmul_ref(x, w)
        rel = float(jnp.max(jnp.abs(y - y_ref)) /
                    jnp.max(jnp.abs(y_ref)))
        bB, pt, qt = choose_blocks(B, p, q, k)
        K = k // 2 + 1
        vmem = (2 * (bB * qt * k * 4 + 2 * pt * qt * K * 4)
                + 2 * bB * pt * K * 4 + bB * pt * k * 4
                + 2 * k * K * 4 + 2 * K * k * 4)
        us = time_fn(lambda x, w: block_circulant_matmul(x, w), x, w,
                     iters=3, warmup=1)
        emit(f"kernel/bc_B{B}_p{p}_q{q}_k{k}", us,
             f"relerr={rel:.2e};tiles=({bB},{pt},{qt});"
             f"vmem_bytes={vmem};vmem_ok={vmem < 16*2**20};interpret=True")


if __name__ == "__main__":
    run()
