"""Pallas block-circulant kernel: correctness-at-shape sweep + VMEM budget,
plan-cached vs per-call forward, and fused vs unfused multi-projection.

Wall-times here run the kernel in INTERPRET mode (no TPU in this
container) and are labeled as such — the meaningful outputs are the
rel-error vs the dense oracle, the chosen tile sizes, the VMEM
working-set estimate per tile (must be < 16 MB v5e VMEM), and the
*structural* wins (no fft primitive on the plan path; 1 launch instead
of 4 for fused gates), which carry to hardware.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import compiled_flops, emit, time_fn
from repro.kernels.block_circulant import (block_circulant_matmul,
                                           block_circulant_matmul_multi,
                                           build_multi_plan, build_plan)
from repro.kernels.block_circulant.kernel import (apply_activation,
                                                  choose_blocks,
                                                  vmem_estimate)
from repro.kernels.block_circulant.ref import block_circulant_matmul_ref


def correctness_and_vmem():
    for (B, p, q, k) in [(128, 8, 8, 128), (256, 24, 8, 128),
                         (64, 32, 32, 16), (512, 4, 4, 64)]:
        x = jax.random.normal(jax.random.PRNGKey(0), (B, q * k), jnp.float32)
        w = jax.random.normal(jax.random.PRNGKey(1), (p, q, k), jnp.float32)
        y = block_circulant_matmul(x, w)
        y_ref = block_circulant_matmul_ref(x, w)
        rel = float(jnp.max(jnp.abs(y - y_ref)) /
                    jnp.max(jnp.abs(y_ref)))
        bB, pt, qt = choose_blocks(B, p, q, k)
        vmem = vmem_estimate(bB, pt, qt, k)
        us = time_fn(lambda x, w: block_circulant_matmul(x, w), x, w,
                     iters=3, warmup=1)
        emit(f"kernel/bc_B{B}_p{p}_q{q}_k{k}", us,
             f"relerr={rel:.2e};tiles=({bB},{pt},{qt});"
             f"vmem_bytes={vmem};vmem_ok={vmem < 16*2**20};interpret=True")


def plan_vs_per_call():
    """Plan-cached forward (frozen FFT(w), no per-call rfft/dft_bases/pad)
    vs the per-call path that re-derives everything from w each step."""
    for (B, p, q, k) in [(64, 8, 8, 64), (32, 16, 16, 32)]:
        x = jax.random.normal(jax.random.PRNGKey(0), (B, q * k), jnp.float32)
        w = jax.random.normal(jax.random.PRNGKey(1), (p, q, k),
                              jnp.float32) * (q * k) ** -0.5
        b = jax.random.normal(jax.random.PRNGKey(2), (p * k,), jnp.float32)

        plan = build_plan(w, bias=b, activation="relu")
        cached = jax.jit(plan.apply)
        per_call = jax.jit(lambda x, w, b: block_circulant_matmul(
            x, w, bias=b, activation="relu"))

        us_cached = time_fn(cached, x, iters=15, warmup=3)
        us_call = time_fn(per_call, x, w, b, iters=15, warmup=3)
        # deterministic cost signals (interpret-mode wall time is noisy):
        # per-step HLO FLOPs and traced-op count — the cached path drops
        # the rfft(w), dft-basis rebuild, and weight padding every call.
        fl_cached = compiled_flops(plan.apply, x)
        fl_call = compiled_flops(
            lambda x, w, b: block_circulant_matmul(
                x, w, bias=b, activation="relu"), x, w, b)
        eq_cached = len(jax.make_jaxpr(plan.apply)(x).jaxpr.eqns)
        eq_call = len(jax.make_jaxpr(
            lambda x: block_circulant_matmul(
                x, w, bias=b, activation="relu"))(x).jaxpr.eqns)
        no_fft = "fft" not in str(jax.make_jaxpr(plan.apply)(x))
        emit(f"kernel/plan_cached_B{B}_p{p}_q{q}_k{k}", us_cached,
             f"no_fft_in_jaxpr={no_fft};flops={fl_cached:.3g};"
             f"jaxpr_eqns={eq_cached};interpret=True")
        emit(f"kernel/plan_percall_B{B}_p{p}_q{q}_k{k}", us_call,
             f"speedup_cached={us_call / max(us_cached, 1e-9):.2f}x;"
             f"flops={fl_call:.3g};jaxpr_eqns={eq_call};"
             f"flops_saved={fl_call - fl_cached:.3g};interpret=True")


def fused_vs_unfused_gates():
    """4 LSTM-gate projections sharing one input: ONE stacked-p launch vs
    4 separate kernel launches + XLA bias/sigmoid epilogues."""
    B, p, q, k = 32, 4, 4, 64
    x = jax.random.normal(jax.random.PRNGKey(0), (B, q * k), jnp.float32)
    ws = [jax.random.normal(jax.random.PRNGKey(i), (p, q, k), jnp.float32)
          * (q * k) ** -0.5 for i in range(1, 5)]
    bs = [jax.random.normal(jax.random.PRNGKey(10 + i), (p * k,), jnp.float32)
          for i in range(4)]

    fused = jax.jit(lambda x, ws, bs: block_circulant_matmul_multi(
        x, ws, biases=bs, activation="sigmoid"))

    def unfused_fn(x, ws, bs):
        return [apply_activation(block_circulant_matmul(x, w) + b, "sigmoid")
                for w, b in zip(ws, bs)]

    unfused = jax.jit(unfused_fn)

    y_f = fused(x, ws, bs)
    y_u = unfused(x, ws, bs)
    rel = max(float(jnp.max(jnp.abs(a - b)) / jnp.max(jnp.abs(b)))
              for a, b in zip(y_f, y_u))
    us_f = time_fn(fused, x, ws, bs, iters=5, warmup=2)
    us_u = time_fn(unfused, x, ws, bs, iters=5, warmup=2)
    emit(f"kernel/gates4_fused_B{B}_p{p}_q{q}_k{k}", us_f,
         f"launches=1;relerr_vs_unfused={rel:.2e};interpret=True")
    emit(f"kernel/gates4_unfused_B{B}_p{p}_q{q}_k{k}", us_u,
         f"launches=4;speedup_fused={us_u / max(us_f, 1e-9):.2f}x;"
         f"interpret=True")

    # plan form of the same fusion (frozen weights, one launch, no fft)
    mp = build_multi_plan(ws, biases=bs, activation="sigmoid")
    us_mp = time_fn(jax.jit(mp.apply_multi), x, iters=5, warmup=2)
    emit(f"kernel/gates4_multiplan_B{B}_p{p}_q{q}_k{k}", us_mp,
         f"launches=1;frozen=True;"
         f"no_fft={'fft' not in str(jax.make_jaxpr(mp.apply_multi)(x))};"
         f"interpret=True")


def run():
    correctness_and_vmem()
    plan_vs_per_call()
    fused_vs_unfused_gates()


if __name__ == "__main__":
    run()
