"""Pallas block-circulant kernel: correctness-at-shape sweep + VMEM budget,
plan-cached vs per-call forward, fused vs unfused multi-projection, and
forward+backward TRAIN-STEP timings (kernel-backed weight adjoint).

Wall-times here run the kernel in INTERPRET mode (no TPU in this
container) and are labeled as such — the meaningful outputs are the
rel-error vs the dense oracle, the chosen tile sizes, the VMEM
working-set estimate per tile (must be < 16 MB v5e VMEM), and the
*structural* wins (no fft primitive on the plan path; 1 launch instead
of 4 for fused gates; 3 Pallas launches and zero dense (P, Q)-grid
dot_generals in the cached train step), which carry to hardware.

    PYTHONPATH=src python -m benchmarks.kernel_bench \
        --json kernel_bench_backward.json
"""

from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import compiled_flops, emit, time_fn
from repro.kernels.block_circulant import (block_circulant_matmul,
                                           block_circulant_matmul_multi,
                                           build_multi_plan, build_plan)
from repro.kernels.block_circulant.kernel import (apply_activation,
                                                  choose_blocks,
                                                  choose_blocks_dw,
                                                  vmem_estimate,
                                                  vmem_estimate_dw)
from repro.kernels.block_circulant.ops import (count_pallas_launches,
                                               outer_dot_shapes)
from repro.kernels.block_circulant.ref import block_circulant_matmul_ref
from repro.train.loop import make_grad_step


def correctness_and_vmem():
    for (B, p, q, k) in [(128, 8, 8, 128), (256, 24, 8, 128),
                         (64, 32, 32, 16), (512, 4, 4, 64)]:
        x = jax.random.normal(jax.random.PRNGKey(0), (B, q * k), jnp.float32)
        w = jax.random.normal(jax.random.PRNGKey(1), (p, q, k), jnp.float32)
        y = block_circulant_matmul(x, w)
        y_ref = block_circulant_matmul_ref(x, w)
        rel = float(jnp.max(jnp.abs(y - y_ref)) /
                    jnp.max(jnp.abs(y_ref)))
        bB, pt, qt = choose_blocks(B, p, q, k)
        vmem = vmem_estimate(bB, pt, qt, k)
        us = time_fn(lambda x, w: block_circulant_matmul(x, w), x, w,
                     iters=3, warmup=1)
        emit(f"kernel/bc_B{B}_p{p}_q{q}_k{k}", us,
             f"relerr={rel:.2e};tiles=({bB},{pt},{qt});"
             f"vmem_bytes={vmem};vmem_ok={vmem < 16*2**20};interpret=True")


def plan_vs_per_call():
    """Plan-cached forward (frozen FFT(w), no per-call rfft/dft_bases/pad)
    vs the per-call path that re-derives everything from w each step."""
    for (B, p, q, k) in [(64, 8, 8, 64), (32, 16, 16, 32)]:
        x = jax.random.normal(jax.random.PRNGKey(0), (B, q * k), jnp.float32)
        w = jax.random.normal(jax.random.PRNGKey(1), (p, q, k),
                              jnp.float32) * (q * k) ** -0.5
        b = jax.random.normal(jax.random.PRNGKey(2), (p * k,), jnp.float32)

        plan = build_plan(w, bias=b, activation="relu")
        cached = jax.jit(plan.apply)
        per_call = jax.jit(lambda x, w, b: block_circulant_matmul(
            x, w, bias=b, activation="relu"))

        us_cached = time_fn(cached, x, iters=15, warmup=3)
        us_call = time_fn(per_call, x, w, b, iters=15, warmup=3)
        # deterministic cost signals (interpret-mode wall time is noisy):
        # per-step HLO FLOPs and traced-op count — the cached path drops
        # the rfft(w), dft-basis rebuild, and weight padding every call.
        fl_cached = compiled_flops(plan.apply, x)
        fl_call = compiled_flops(
            lambda x, w, b: block_circulant_matmul(
                x, w, bias=b, activation="relu"), x, w, b)
        eq_cached = len(jax.make_jaxpr(plan.apply)(x).jaxpr.eqns)
        eq_call = len(jax.make_jaxpr(
            lambda x: block_circulant_matmul(
                x, w, bias=b, activation="relu"))(x).jaxpr.eqns)
        no_fft = "fft" not in str(jax.make_jaxpr(plan.apply)(x))
        emit(f"kernel/plan_cached_B{B}_p{p}_q{q}_k{k}", us_cached,
             f"no_fft_in_jaxpr={no_fft};flops={fl_cached:.3g};"
             f"jaxpr_eqns={eq_cached};interpret=True")
        emit(f"kernel/plan_percall_B{B}_p{p}_q{q}_k{k}", us_call,
             f"speedup_cached={us_call / max(us_cached, 1e-9):.2f}x;"
             f"flops={fl_call:.3g};jaxpr_eqns={eq_call};"
             f"flops_saved={fl_call - fl_cached:.3g};interpret=True")


def fused_vs_unfused_gates():
    """4 LSTM-gate projections sharing one input: ONE stacked-p launch vs
    4 separate kernel launches + XLA bias/sigmoid epilogues."""
    B, p, q, k = 32, 4, 4, 64
    x = jax.random.normal(jax.random.PRNGKey(0), (B, q * k), jnp.float32)
    ws = [jax.random.normal(jax.random.PRNGKey(i), (p, q, k), jnp.float32)
          * (q * k) ** -0.5 for i in range(1, 5)]
    bs = [jax.random.normal(jax.random.PRNGKey(10 + i), (p * k,), jnp.float32)
          for i in range(4)]

    fused = jax.jit(lambda x, ws, bs: block_circulant_matmul_multi(
        x, ws, biases=bs, activation="sigmoid"))

    def unfused_fn(x, ws, bs):
        return [apply_activation(block_circulant_matmul(x, w) + b, "sigmoid")
                for w, b in zip(ws, bs)]

    unfused = jax.jit(unfused_fn)

    y_f = fused(x, ws, bs)
    y_u = unfused(x, ws, bs)
    rel = max(float(jnp.max(jnp.abs(a - b)) / jnp.max(jnp.abs(b)))
              for a, b in zip(y_f, y_u))
    us_f = time_fn(fused, x, ws, bs, iters=5, warmup=2)
    us_u = time_fn(unfused, x, ws, bs, iters=5, warmup=2)
    emit(f"kernel/gates4_fused_B{B}_p{p}_q{q}_k{k}", us_f,
         f"launches=1;relerr_vs_unfused={rel:.2e};interpret=True")
    emit(f"kernel/gates4_unfused_B{B}_p{p}_q{q}_k{k}", us_u,
         f"launches=4;speedup_fused={us_u / max(us_f, 1e-9):.2f}x;"
         f"interpret=True")

    # plan form of the same fusion (frozen weights, one launch, no fft)
    mp = build_multi_plan(ws, biases=bs, activation="sigmoid")
    us_mp = time_fn(jax.jit(mp.apply_multi), x, iters=5, warmup=2)
    emit(f"kernel/gates4_multiplan_B{B}_p{p}_q{q}_k{k}", us_mp,
         f"launches=1;frozen=True;"
         f"no_fft={'fft' not in str(jax.make_jaxpr(mp.apply_multi)(x))};"
         f"interpret=True")


def backward_timings(json_path: str = ""):
    """Train-step mode: forward vs forward+backward for the per-call path
    (trainable time-domain tables) and the plan path (frozen frequency
    params — QAT-style training directly in the frequency domain).

    The trajectory artifact for the training path: per shape, the step
    wall time, the Pallas launch count of the cached train step (forward z
    + dx + dw = 3 — every adjoint is a kernel), the dw-kernel tile choice
    with its VMEM working set, and the structural asserts (no dense
    (P, Q)-grid dot_general outside kernels; no fft primitive in the
    plan-path step).
    """
    report = {"mode": "train-step", "interpret": True, "shapes": []}
    for (B, p, q, k) in [(64, 8, 8, 64), (32, 16, 16, 32)]:
        x = jax.random.normal(jax.random.PRNGKey(0), (B, q * k), jnp.float32)
        w = jax.random.normal(jax.random.PRNGKey(1), (p, q, k),
                              jnp.float32) * (q * k) ** -0.5
        yt = jax.random.normal(jax.random.PRNGKey(2), (B, p * k), jnp.float32)
        batch = {"x": x, "y": yt}

        loss = lambda params, b: (
            (block_circulant_matmul(b["x"], params["w"]) - b["y"]) ** 2
        ).mean()
        step = make_grad_step(loss)
        fwd = jax.jit(loss)
        us_fwd = time_fn(fwd, {"w": w}, batch, iters=5, warmup=2)
        us_step = time_fn(step, {"w": w}, batch, iters=5, warmup=2)
        jp = jax.make_jaxpr(loss_and_grad_of(loss))({"w": w}, batch)
        launches = count_pallas_launches(jp)
        # every contraction must be a kernel launch: NO dot_general at all
        # outside pallas_call (stronger than matching (p, q) dims, which
        # a dense fallback over the expanded (p·k, q·k) shape would evade)
        outer_dots = outer_dot_shapes(jp)
        bB, pt, qt = choose_blocks_dw(B, p, q, k)
        vm = vmem_estimate_dw(bB, pt, qt, k)
        emit(f"kernel/train_step_B{B}_p{p}_q{q}_k{k}", us_step,
             f"fwd_us={us_fwd:.2f};pallas_launches={launches};"
             f"outer_dots={len(outer_dots)};dw_tiles=({bB},{pt},{qt});"
             f"dw_vmem_bytes={vm};dw_vmem_ok={vm < 16 * 2**20};"
             f"interpret=True")
        assert launches == 3, launches          # forward z + dx + dw
        assert outer_dots == [], outer_dots

        plan = build_plan(w)
        ploss = lambda pl, b: ((pl.apply(b["x"]) - b["y"]) ** 2).mean()
        pstep = make_grad_step(ploss)
        us_pstep = time_fn(pstep, plan, batch, iters=5, warmup=2)
        pjp = jax.make_jaxpr(loss_and_grad_of(ploss))(plan, batch)
        no_fft = "fft" not in str(pjp)
        emit(f"kernel/train_step_plan_B{B}_p{p}_q{q}_k{k}", us_pstep,
             f"no_fft_in_jaxpr={no_fft};"
             f"pallas_launches={count_pallas_launches(pjp)};interpret=True")
        assert no_fft

        report["shapes"].append({
            "B": B, "p": p, "q": q, "k": k,
            "fwd_us": us_fwd, "train_step_us": us_step,
            "train_step_plan_us": us_pstep,
            "pallas_launches": launches, "outer_dots": len(outer_dots),
            "dw_tiles": [bB, pt, qt], "dw_vmem_bytes": vm,
            "plan_no_fft": no_fft,
        })
    if json_path:
        with open(json_path, "w") as f:
            json.dump(report, f, indent=2)
        print(f"wrote {json_path}")


def loss_and_grad_of(loss):
    """value_and_grad WITHOUT jit — tracable by make_jaxpr for structural
    inspection of exactly what the cached train step executes."""
    return jax.value_and_grad(loss)


def quantized_tables(json_path: str = ""):
    """int8 frozen frequency tables with in-kernel dequant vs the fp32 plan.

    Structural wins asserted per shape: resident table bytes at most 0.55x
    fp32 (int8 re/im + one f32 scale per block), IDENTICAL Pallas launch
    count (dequant happens on the VMEM tile inside the existing kernel, no
    extra launch), still no fft primitive, and bitwise-equal outputs vs the
    same plan geometry run on the host-dequantized fp32 tables (int8 ->
    f32 * scale is exact, so in-kernel dequant is not an approximation of
    the fake-quantized weights — it IS them).
    """
    import dataclasses as dc

    from repro.core.quant import dequantize_symmetric

    report = {"mode": "quantized-tables", "interpret": True, "shapes": []}
    for (B, p, q, k) in [(64, 8, 8, 64), (32, 16, 16, 32)]:
        x = jax.random.normal(jax.random.PRNGKey(0), (B, q * k), jnp.float32)
        w = jax.random.normal(jax.random.PRNGKey(1), (p, q, k),
                              jnp.float32) * (q * k) ** -0.5
        b = jax.random.normal(jax.random.PRNGKey(2), (p * k,), jnp.float32)

        plan_f = build_plan(w, bias=b, activation="relu")
        plan_q = build_plan(w, bias=b, activation="relu", quantize="int8")
        bytes_f, bytes_q = plan_f.table_bytes(), plan_q.table_bytes()
        ratio = bytes_q / bytes_f

        # oracle: host-dequantize the stored int8 tables and run the SAME
        # plan geometry in fp32 — the in-kernel dequant must match bitwise
        plan_o = dc.replace(
            plan_q,
            wr=dequantize_symmetric(plan_q.wr, plan_q.scale),
            wi=dequantize_symmetric(plan_q.wi, plan_q.scale),
            scale=None,
        )
        y_q = jax.jit(plan_q.apply)(x)
        y_o = jax.jit(plan_o.apply)(x)
        bit_equal = bool(jnp.array_equal(y_q, y_o))

        jp_q = jax.make_jaxpr(plan_q.apply)(x)
        launches_q = count_pallas_launches(jp_q)
        launches_f = count_pallas_launches(jax.make_jaxpr(plan_f.apply)(x))
        no_fft = "fft" not in str(jp_q)
        us_q = time_fn(jax.jit(plan_q.apply), x, iters=5, warmup=2)
        emit(f"kernel/quant_int8_B{B}_p{p}_q{q}_k{k}", us_q,
             f"bytes_ratio={ratio:.3f};bit_equal_vs_dequant={bit_equal};"
             f"launches={launches_q};launches_fp32={launches_f};"
             f"no_fft_in_jaxpr={no_fft};interpret=True")
        assert bit_equal
        assert launches_q == launches_f, (launches_q, launches_f)
        assert ratio <= 0.55, ratio
        assert no_fft

        report["shapes"].append({
            "B": B, "p": p, "q": q, "k": k,
            "table_bytes_fp32": bytes_f, "table_bytes_int8": bytes_q,
            "bytes_ratio": ratio, "bit_equal_vs_dequant": bit_equal,
            "pallas_launches_int8": launches_q,
            "pallas_launches_fp32": launches_f,
            "no_fft": no_fft, "quant_us": us_q,
        })
    if json_path:
        with open(json_path, "w") as f:
            json.dump(report, f, indent=2)
        print(f"wrote {json_path}")


def run(json_path: str = ""):
    correctness_and_vmem()
    plan_vs_per_call()
    fused_vs_unfused_gates()
    backward_timings(json_path)
    quantized_tables()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="",
                    help="write the train-step (backward) report as JSON "
                         "(or the quantized-tables report with --quantize)")
    ap.add_argument("--train-step-only", action="store_true",
                    help="skip the forward-only sections")
    ap.add_argument("--quantize", choices=("off", "int8"), default="off",
                    help="int8: run ONLY the quantized-tables section "
                         "(bytes ratio, launch parity, bitwise dequant "
                         "equality) and write its JSON report")
    args = ap.parse_args()
    if args.quantize == "int8":
        quantized_tables(args.json)
    elif args.train_step_only:
        backward_timings(args.json)
    else:
        run(args.json)


if __name__ == "__main__":
    main()
