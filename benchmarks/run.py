"""Benchmark harness — one module per paper table (DESIGN.md §6).

Prints ``name,us_per_call,derived`` CSV rows. CPU-measured wall-times are
labeled; roofline-derived numbers for the production cells live in
EXPERIMENTS.md (fed by launch/dryrun.py + launch/roofline.py).
"""

from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (complexity_scaling, compression_accuracy,
                            kernel_bench, serve_bench, table1_dcnn,
                            table1_lstm, table2_asic)

    print("name,us_per_call,derived")
    mods = [
        ("table1_dcnn", table1_dcnn),
        ("table1_lstm", table1_lstm),
        ("table2_asic", table2_asic),
        ("compression_accuracy", compression_accuracy),
        ("complexity_scaling", complexity_scaling),
        ("kernel_bench", kernel_bench),
        ("serve_bench", serve_bench),
    ]
    failures = []
    for name, mod in mods:
        try:
            mod.run()
        except Exception as e:                      # keep the harness going
            failures.append((name, e))
            traceback.print_exc()
    if failures:
        print(f"FAILED: {[n for n, _ in failures]}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
