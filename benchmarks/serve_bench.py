"""Serving throughput: continuous-batching engine vs the wave baseline,
plus decode-side slot compaction vs full-slot decode.

Runs the same seeded request workload through ``ServeEngine`` (per-slot
admission, bucketed prefill shapes, compacted decode) in two decode
configurations — bucketed (default pow2 ``decode_buckets``) and full-slot
(``decode_buckets=(batch,)``, the pre-compaction behavior) — and through
``WaveEngine`` (fixed waves, stall-on-slowest), and reports:

  * tokens/sec (CPU wall time in this container — labeled as such),
  * tokens per decode step — the batching-efficiency signal that carries to
    hardware: the wave engine idles slots until the wave's largest max_new
    finishes, the continuous engine refills them;
  * decode rows per generated token — the decode-side work amplification:
    full-slot decode pays ``batch`` FFT -> o -> IFFT rows per step whatever
    the occupancy, compaction pays the bucket that holds the active set;
  * recompile counts — wave prefill recompiles per distinct wave length
    (unbounded in the workload), the continuous engine is bounded by its
    bucket grids on both the prefill and decode paths.

Three workloads: ``mixed`` (mixed prompt lengths and budgets — where wave
batching stalls), ``tail`` (tail-heavy: a few long-budget requests
outlive many short ones, so the batch drains to 1-2 live slots — where
full-slot decode burns dead rows), and ``prefix`` (many requests sharing
long prompt heads — the multi-turn / few-shot shape — where shared-prefix
KV reuse stops re-running prefill over heads other requests already
computed: the bench compares the continuous engine with the prefix cache
off vs on and reports ``prefill_tokens_saved`` / ``prefix_hit_rate`` /
prefill tokens per request / tokens-per-sec, asserting the saved-token
count is strictly positive and greedy outputs are bit-identical).
Greedy outputs of every engine are asserted identical before timing is
reported (same frozen-FFT(w) math, different orchestration); on the tail
workload the bucketed engine must show strictly lower decode row-work per
token than full-slot decode.

A fourth workload, ``chaos``, replays the mixed traffic under seeded
injected faults (transient launch failures, NaN-poisoned requests,
deadlines under a step stall, drop-oldest shedding, and an engine-fatal
fault recovered via snapshot/restore) and asserts the fault-tolerance
contract instead of timing: no hang, every request terminal, no slot or
refcount leak, unaffected outputs bit-identical, compile budget
unchanged.

A fifth workload, ``quantize``, serves the mixed traffic through fp32 vs
int8 frozen frequency tables vs a dequantized-table oracle engine and
asserts the quantized-serving contract: int8 greedy outputs bit-identical
to the oracle, resident frozen-table bytes at most 0.55x fp32, compile
budget unchanged.

A sixth workload, ``families``, serves the mixed traffic through three
model families behind their :class:`~repro.serve.runner.ModelRunner`
implementations — an attention decoder (``DecoderRunner``), an RWKV
recurrent stack (``RecurrentRunner``) and a capacity-bucketed MoE decoder
— and reports tokens/sec per family while asserting the cross-family
serving contract: every family stays inside its engine's compile budget,
and the recurrent family's bucketed greedy outputs are bit-identical to
the unbucketed B=1 loop through the same runner (the pad-invariance
guarantee that makes left-padded bucketed prefill legal for stateful
mixers).

    PYTHONPATH=src python benchmarks/serve_bench.py --quick --json out.json
    PYTHONPATH=src python benchmarks/serve_bench.py --quick --workload tail \
        --json out_tail.json
    PYTHONPATH=src python benchmarks/serve_bench.py --quick \
        --workload prefix --json out_prefix.json
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import time

import numpy as np

from benchmarks.common import emit
from repro.configs.base import ModelConfig, SWMConfig
from repro.models.decoder import HybridDecoderLM
from repro.nn.module import init_params
from repro.serve.engine import Request, ServeEngine, WaveEngine


def _cfg() -> ModelConfig:
    return ModelConfig(
        name="serve-bench", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        head_dim=16, d_ff=128, vocab=128, remat="none",
        param_dtype="float32", compute_dtype="float32",
        swm=SWMConfig(block_size=8, impl="dft"),
    )


def _workload_mixed(n_requests: int, cache_len: int, seed: int):
    """Mixed prompt lengths AND mixed generation budgets — the shape of
    traffic where wave batching stalls (every wave runs to its max max_new
    at its max prompt length)."""
    rng = np.random.default_rng(seed)
    reqs = []
    for _ in range(n_requests):
        plen = int(rng.integers(2, 25))
        max_new = int(rng.integers(2, min(25, cache_len - plen)))
        reqs.append(Request(
            rng.integers(0, 128, size=plen).astype(np.int32),
            max_new=max_new,
        ))
    return reqs


def _workload_tail(n_requests: int, cache_len: int, seed: int):
    """Tail-heavy: most requests have tiny budgets, every 4th runs long —
    once the short ones finish and the queue empties, 1-2 live slots remain
    and full-slot decode pays ``batch`` rows for each of their tokens."""
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n_requests):
        plen = int(rng.integers(2, 13))
        if i % 4 == 0:
            # long budget, clamped so plen + max_new - 1 <= cache_len stays
            # servable even at small --cache-len values
            cap = cache_len - plen + 1
            lo = max(2, min(cache_len // 2, cap - 1))
            max_new = int(rng.integers(lo, max(lo + 1, cap)))
        else:
            max_new = int(rng.integers(2, 5))
        reqs.append(Request(
            rng.integers(0, 128, size=plen).astype(np.int32),
            max_new=max_new,
        ))
    return reqs


def _workload_prefix(n_requests: int, cache_len: int, seed: int):
    """Shared-head traffic: every request is one of 3 long common heads
    (half the cache) plus a short private tail — the multi-turn / few-shot
    serving shape where the same prompt head is prefilled over and over
    unless resident rows are reused."""
    rng = np.random.default_rng(seed)
    head_len = cache_len // 2
    heads = [rng.integers(0, 128, size=head_len).astype(np.int32)
             for _ in range(3)]
    reqs = []
    for i in range(n_requests):
        tail = rng.integers(0, 128,
                            size=int(rng.integers(1, 4))).astype(np.int32)
        prompt = np.concatenate([heads[i % len(heads)], tail])
        cap = cache_len - prompt.shape[0] + 1
        max_new = int(rng.integers(2, max(3, min(7, cap))))
        reqs.append(Request(prompt, max_new=max_new))
    return reqs


WORKLOADS = {"mixed": _workload_mixed, "tail": _workload_tail,
             "prefix": _workload_prefix, "chaos": _workload_mixed,
             "quantize": _workload_mixed, "families": _workload_mixed,
             "tenants": _workload_mixed}


def _run_tenants(n_requests, batch, cache_len, seed, json_path):
    """Tenants workload: a bursty 3-tenant mix (SLO classes interactive/
    standard/batch -> DRR weights 4/2/1) served through the supervised
    engine with a mid-stream engine-fatal fault, asserting the
    multi-tenant robustness contract end to end:

      * fairness — at a DRR round boundary (every tenant still
        backlogged), each tenant's admitted share is within its weight
        +-1 request of its proportional share (starvation-free);
      * self-heal — the supervisor restores the latest snapshot onto a
        fresh engine and re-queues post-snapshot work; every request's
        incrementally-collected token stream is bit-identical to the
        fault-free run with zero duplicated or lost tokens
        (at-most-once emission);
      * SLO visibility — streaming TTFT histograms cover every request,
        survive snapshot/restore, and order by priority (the interactive
        tenant's p99 TTFT <= the batch tenant's under burst);
      * compile budget unchanged across the heal.

    All on a ManualClock (2 ms per engine step) so latency numbers are
    deterministic. Writes the tenants JSON report for CI (the BENCH
    trajectory artifact)."""
    from repro.serve.guard import ManualClock, ServeFaultInjector
    from repro.serve.supervisor import Supervisor
    import tempfile

    cfg = dataclasses.replace(_cfg(), name="serve-tenants")
    model = HybridDecoderLM(cfg)
    params = init_params(model.specs(), 0)
    weights = {"alpha": 4, "beta": 2, "gamma": 1}
    slo = {"alpha": "interactive", "beta": "standard", "gamma": "batch"}
    sum_w = sum(weights.values())
    n_per = max(8, n_requests // 3)
    rng = np.random.default_rng(seed)
    # uniform shapes: fairness accounting is request-count-based and the
    # per-stream greedy outputs must be comparable across runs
    reqs = [Request(rng.integers(0, 128, size=6).astype(np.int32),
                    max_new=5, tenant=t)
            for t in sorted(weights) for _ in range(n_per)]

    # fault-free baseline streams
    base_eng = ServeEngine(model, cfg, params, batch=batch,
                           cache_len=cache_len, policy="fair",
                           tenant_weights=weights)
    base_eng.prewarm()
    base = base_eng.generate(reqs)

    clk = ManualClock()
    inj = ServeFaultInjector(fatal_decode_at={20})
    with tempfile.TemporaryDirectory() as snap_dir:
        def factory():
            eng = ServeEngine(model, cfg, params, batch=batch,
                              cache_len=cache_len, policy="fair",
                              tenant_weights=weights, snapshot_dir=snap_dir,
                              snapshot_every=2, clock=clk,
                              fault_injector=inj)
            eng.prewarm()
            return eng

        sup = Supervisor(factory)
        budget_prefill = sup.engine.max_prefill_variants
        budget_decode = sup.engine.max_decode_variants
        srids = [sup.submit(r) for r in reqs]
        streams = {r: [] for r in srids}
        fair_at = None
        steps = 0
        while True:
            alive = sup.step()
            steps += 1
            clk.advance(0.002)
            for r in srids:
                new, _ = sup.take_new_tokens(r)
                streams[r].extend(new)
            admitted = {t: ts.admitted
                        for t, ts in sup.stats.tenants.items()}
            total = sum(admitted.values())
            # freeze the fairness window at the first DRR-round boundary
            # past two full rounds, while every tenant is still backlogged
            if fair_at is None and 2 * sum_w <= total <= 3 * n_per - 2:
                fair_at = dict(admitted)
            if not alive:
                break
            assert steps < 4000, "tenants workload hang"

        s = sup.stats
        # -- the multi-tenant contract -----------------------------------
        assert sup.restarts == 1, f"expected 1 self-heal, got {sup.restarts}"
        assert s.recoveries == 1, "snapshot restore did not run"
        assert fair_at is not None, "fairness window never observed"
        fair_total = sum(fair_at.values())
        starved = {}
        for t, w in weights.items():
            share = fair_total * w / sum_w
            if abs(fair_at.get(t, 0) - share) > w + 1:
                starved[t] = (fair_at.get(t, 0), share)
        assert not starved, (
            f"DRR fairness violated at admission boundary {fair_total}: "
            f"{starved} (admitted, proportional share)")
        dup_or_lost = [i for i, r in enumerate(srids)
                       if tuple(streams[r]) != tuple(base[i])]
        assert not dup_or_lost, (
            f"{len(dup_or_lost)} streams diverged from the fault-free "
            f"run across the heal (duplicated or lost tokens): "
            f"requests {dup_or_lost[:5]}")
        assert s.ttft_ms.count == len(reqs), (
            f"TTFT histogram covers {s.ttft_ms.count}/{len(reqs)} "
            f"requests (lost through snapshot/restore?)")
        p99_alpha = s.tenants["alpha"].ttft_ms.p99
        p99_gamma = s.tenants["gamma"].ttft_ms.p99
        assert p99_alpha <= p99_gamma, (
            f"SLO inversion under burst: interactive p99 TTFT "
            f"{p99_alpha}ms > batch {p99_gamma}ms")
        eng = sup.engine
        assert eng.prefill_compiles <= budget_prefill, "compile budget blown"
        assert eng.decode_compiles <= budget_decode, "compile budget blown"

        report = {
            "workload": {"name": "tenants", "n_per_tenant": n_per,
                         "batch": batch, "cache_len": cache_len,
                         "seed": seed, "weights": weights, "slo": slo,
                         "host": "cpu-interpret"},
            "injected": {"fatal_decode_at": [20]},
            "steps": steps,
            "restarts": sup.restarts,
            "fairness_at_boundary": {"admitted": fair_at,
                                     "total": fair_total},
            "ttft_ms": {"p50": s.ttft_ms.p50, "p99": s.ttft_ms.p99},
            "tok_ms": {"p50": s.tok_ms.p50, "p99": s.tok_ms.p99},
            "tenants": {t: ts.as_dict() for t, ts in s.tenants.items()},
            "contract": {
                "streams_bit_identical": True,
                "zero_duplicated_or_lost_tokens": True,
                "no_starvation": True,
                "ttft_serialized_through_snapshot": True,
                "compile_budget_unchanged": True,
            },
        }
    emit(f"serve/tenants_B{batch}_N{3 * n_per}", 0.0,
         f"steps={steps};restarts={sup.restarts};"
         f"fair_admitted={sorted(fair_at.items())};"
         f"ttft_p50={s.ttft_ms.p50}ms;ttft_p99={s.ttft_ms.p99}ms;"
         f"alpha_p99={p99_alpha}ms;gamma_p99={p99_gamma}ms;"
         f"streams_bit_identical=True;host=cpu")
    if json_path:
        with open(json_path, "w") as f:
            json.dump(report, f, indent=2)
        print(f"wrote {json_path}")
    return report


def _run_families(n_requests, batch, cache_len, seed, json_path):
    """Families workload: the same seeded mixed traffic served through
    three model families behind their ModelRunner implementations —
    an attention decoder (DecoderRunner), an RWKV recurrent stack
    (RecurrentRunner) and a capacity-bucketed no-drop MoE decoder.
    Reports tokens/sec per family and asserts the cross-family serving
    contract: each family's engine stays inside its compile budget, and
    the recurrent family's bucketed greedy outputs are bit-identical to
    the unbucketed B=1 loop through the same runner."""
    import jax
    import jax.numpy as jnp

    from repro.configs.base import LayerGroup, LayerSpec
    from repro.launch.specs import build_model
    from repro.serve.runner import make_runner

    base = dict(d_model=64, n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
                vocab=128, remat="none", param_dtype="float32",
                compute_dtype="float32",
                swm=SWMConfig(block_size=8, impl="dft"))
    fams = {
        "decoder": ModelConfig(name="fam-decoder", n_layers=2, **base),
        "rwkv": ModelConfig(
            name="fam-rwkv", n_layers=2, rwkv_head_dim=16,
            rwkv_decay_lora=8, rwkv_mix_lora=8,
            groups=(LayerGroup(layers=(
                LayerSpec(mixer="rwkv", ffn="dense"),), repeat=2),),
            **base),
        "moe": ModelConfig(
            name="fam-moe", n_layers=2, n_experts=4, n_experts_per_token=2,
            d_ff_expert=128,
            groups=(LayerGroup(layers=(
                LayerSpec(mixer="attn", ffn="moe"),), repeat=2),),
            **base),
    }
    reqs = _workload_mixed(n_requests, cache_len, seed)
    warmup = _workload_mixed(max(4, n_requests // 4), cache_len, seed + 1)
    rows = {}
    rwkv_ctx = None
    for fam, cfg in fams.items():
        model = build_model(cfg)
        params = init_params(model.specs(), 0)
        eng = ServeEngine(model, cfg, params, batch=batch,
                          cache_len=cache_len)
        eng.prewarm()
        outs, row = _run(eng, warmup, reqs)
        assert eng.prefill_compiles <= eng.max_prefill_variants, (
            f"{fam}: prefill compile budget blown "
            f"({eng.prefill_compiles} > {eng.max_prefill_variants})")
        assert eng.decode_compiles <= eng.max_decode_variants, (
            f"{fam}: decode compile budget blown "
            f"({eng.decode_compiles} > {eng.max_decode_variants})")
        row["runner"] = type(eng.runner).__name__
        row["max_prefill_variants"] = eng.max_prefill_variants
        row["max_decode_variants"] = eng.max_decode_variants
        rows[fam] = row
        if fam == "rwkv":
            rwkv_ctx = (outs, eng.params, model, cfg)

    # pad-invariance: the recurrent family's bucketed engine outputs must
    # match the unbucketed B=1 loop through the same runner bit for bit
    outs_r, params_r, model_r, cfg_r = rwkv_ctx
    runner = make_runner(model_r, cfg_r, cache_len)
    check = reqs[:min(6, len(reqs))]
    prefill = jax.jit(runner.prefill)
    decode = jax.jit(runner.decode)
    ref = []
    for r in check:
        p = np.asarray(r.prompt, np.int32).reshape(-1)
        L = p.shape[0]
        state = runner.init_state(1)
        lg, _, state = prefill(
            params_r, jnp.asarray(p)[None],
            jnp.asarray(np.arange(L, dtype=np.int32))[None],
            state, jnp.asarray([0], np.int32))
        cur = int(np.argmax(np.asarray(lg)[0]))
        out, pos = [cur], L
        while len(out) < r.max_new:
            lg, _, state = decode(
                params_r, jnp.asarray([[cur]], np.int32), state,
                jnp.asarray([pos], np.int32), jnp.asarray([0], np.int32))
            cur = int(np.argmax(np.asarray(lg)[0]))
            out.append(cur)
            pos += 1
        ref.append(out)
    assert outs_r[:len(check)] == ref, (
        "rwkv bucketed serving diverged from the unbucketed B=1 runner "
        "loop: recurrent pad-invariance broken")

    report = {
        "workload": {"name": "families", "n_requests": n_requests,
                     "batch": batch, "cache_len": cache_len, "seed": seed,
                     "host": "cpu-interpret"},
        "families": rows,
        "recurrent_bucketed_equals_b1": True,
        "compile_budget_ok": True,
    }
    for fam, row in rows.items():
        emit(f"serve/family_{fam}_B{batch}_N{n_requests}",
             row["seconds"] * 1e6,
             f"runner={row['runner']};tok_s={row['tokens_per_sec']:.1f};"
             f"tok_per_decode_step={row['tokens_per_decode_step']:.2f};"
             f"prefill_compiles={row['prefill_compiles']}"
             f"<={row['max_prefill_variants']};"
             f"decode_compiles={row['decode_compiles']}"
             f"<={row['max_decode_variants']};host=cpu")
    emit("serve/families", 0.0,
         "recurrent_bucketed_equals_b1=True;compile_budget_ok=True;"
         + ";".join(f"{f}_tok_s={r['tokens_per_sec']:.1f}"
                    for f, r in rows.items()))
    if json_path:
        with open(json_path, "w") as f:
            json.dump(report, f, indent=2)
        print(f"wrote {json_path}")
    return report


def _run_chaos(n_requests, batch, cache_len, seed, json_path):
    """Chaos workload: the mixed workload served under seeded injected
    faults — a transient prefill launch failure, a transient decode launch
    failure (retried), NaN-poisoned prompts, per-request deadlines under an
    artificial step stall, drop-oldest load shedding, and an injected
    engine-fatal fault recovered via snapshot/restore into a replacement
    engine. Asserts the fault-tolerance contract end to end: the engine
    never hangs (hard step budget), every request reaches a terminal
    state, no slot or prefix-refcount leak, unaffected requests' greedy
    outputs are bit-identical to the fault-free run, and the compile
    budget is unchanged (the finiteness guard rides in the existing
    executables). Writes the chaos-run JSON report for CI."""
    import jax
    import jax.numpy as jnp

    from repro.serve.guard import (EngineFatalError, ManualClock,
                                   ServeFaultInjector, TERMINAL_STATES)
    import tempfile

    cfg = dataclasses.replace(_cfg(), name="serve-chaos",
                              tie_embeddings=False)
    model = HybridDecoderLM(cfg)
    params = init_params(model.specs(), 0)
    rng = np.random.default_rng(seed)
    # prompts drawn strictly below 100 so a poison token >= 100 can only
    # enter the model through the requests we poison on purpose
    reqs = []
    for _ in range(n_requests):
        plen = int(rng.integers(2, 25))
        max_new = int(rng.integers(2, min(25, cache_len - plen)))
        reqs.append(Request(
            rng.integers(0, 100, size=plen).astype(np.int32),
            max_new=max_new))

    def build(par, **kw):
        return ServeEngine(model, cfg, par, batch=batch,
                           cache_len=cache_len, **kw)

    # fault-free baseline (clean params, no injector)
    base_eng = build(params)
    base_eng.prewarm()
    base = base_eng.generate(reqs)
    used = {int(t) for o in base for t in o}
    poison_tok = next(t for t in range(cfg.vocab - 1, 99, -1)
                      if t not in used)
    params_poison = jax.tree.map(lambda x: x, params)
    params_poison["embed"]["table"] = (
        params_poison["embed"]["table"].at[poison_tok].set(jnp.nan))
    # clean requests behave bit-identically under the poisoned params:
    # the NaN embedding row is gather-only, and no clean prompt or
    # baseline output ever feeds it

    n_poison = max(1, n_requests // 6)
    poison_reqs = [Request(np.asarray([3, poison_tok, 7], np.int32),
                           max_new=4) for _ in range(n_poison)]
    # extra requests with a tight TTL, submitted AFTER the clean traffic so
    # drop-oldest shedding (which evicts the earliest submissions) cannot
    # reach them — the injected 1 s stall at step 7 blows their deadline
    # long before their 20-token budget completes
    n_deadline = 2
    deadline_reqs = [Request(np.asarray([5, 6, 7], np.int32), max_new=20,
                             deadline_ms=30.0) for _ in range(n_deadline)]
    max_queue = n_requests + n_deadline   # poison submits shed 2 clean reqs
    clk = ManualClock()
    inj = ServeFaultInjector(
        fail_prefill_at={1},            # one transient prefill fault
        fail_decode_at={2},             # one transient decode fault (retried)
        fatal_decode_at={8},            # engine-fatal -> snapshot/restore
        delay_at={7}, delay_s=1.0,      # step stall, past watchdog warmup
        clock=clk)
    eng_kw = dict(snapshot_every=2, max_queue=max_queue,
                  shed_policy="drop-oldest", clock=clk)
    with tempfile.TemporaryDirectory() as snap_dir:
        eng = build(params_poison, fault_injector=inj,
                    snapshot_dir=snap_dir, **eng_kw)
        eng.prewarm()
        budget_prefill = eng.max_prefill_variants
        budget_decode = eng.max_decode_variants
        rids = []
        for r in reqs + deadline_reqs + poison_reqs:
            rids.append(eng.submit(r))
        max_steps = 50 * (n_requests + n_deadline + n_poison) + 200
        steps = recoveries = slow_steps_seen = 0
        while True:
            if steps >= max_steps:
                raise AssertionError(
                    f"engine did not go idle within {max_steps} steps — "
                    f"hang detected")
            try:
                more = eng.step()
            except EngineFatalError:
                assert recoveries == 0, "second engine-fatal fault"
                recoveries += 1
                slow_steps_seen = max(slow_steps_seen, eng.stats.slow_steps)
                eng = build(params_poison, snapshot_dir=snap_dir, **eng_kw)
                eng.restore()
                continue
            steps += 1
            clk.advance(0.002)
            if not more:
                break
        slow_steps_seen = max(slow_steps_seen, eng.stats.slow_steps)

        statuses = {rid: eng.poll(rid) for rid in rids}
        hist: dict = {}
        for st in statuses.values():
            hist[st.status] = hist.get(st.status, 0) + 1
        # -- the chaos contract ------------------------------------------
        assert all(st.status in TERMINAL_STATES
                   for st in statuses.values()), "non-terminal request"
        assert not eng._active.any() and len(eng._sched) == 0, "not idle"
        assert (eng._slot_refs == 0).all(), "prefix refcount leak"
        assert not eng._req and not eng._out, "request-table leak"
        for (m, _), slot in eng._prefix_index.items():
            assert eng._slot_prompt[slot] is not None, "prefix index leak"
        mismatched = sum(
            1 for i, rid in enumerate(rids[:n_requests])
            if statuses[rid].status == "FINISHED"
            and list(statuses[rid].tokens) != base[i])
        assert mismatched == 0, (
            f"{mismatched} unaffected requests diverged from the "
            f"fault-free run")
        finished_clean = sum(
            1 for i, rid in enumerate(rids[:n_requests])
            if statuses[rid].status == "FINISHED")
        assert finished_clean > 0, "no clean request finished"
        for rid in rids[n_requests:n_requests + n_deadline]:
            assert statuses[rid].status == "EXPIRED", "deadline not enforced"
        for rid in rids[n_requests + n_deadline:]:
            assert statuses[rid].status == "FAILED", "poison not isolated"
            assert "non-finite" in (statuses[rid].error or "")
        assert eng.prefill_compiles <= budget_prefill, "compile budget blown"
        assert eng.decode_compiles <= budget_decode, "compile budget blown"
        assert eng.stats.recoveries == 1 and recoveries == 1
        assert eng.stats.aborted >= n_poison
        assert eng.stats.expired == n_deadline
        assert eng.stats.rejected >= 1, "drop-oldest shedding never fired"
        assert slow_steps_seen >= 1, "watchdog never flagged the stall"
        s = eng.stats
        report = {
            "workload": {"name": "chaos", "n_requests": n_requests,
                         "n_poison": n_poison, "n_deadline": n_deadline,
                         "batch": batch, "cache_len": cache_len,
                         "seed": seed, "poison_token": poison_tok,
                         "host": "cpu-interpret"},
            "injected": {"fail_prefill_at": [1], "fail_decode_at": [2],
                         "fatal_decode_at": [8], "delay_at": [7]},
            "steps": steps,
            "statuses": hist,
            "stats": s.as_dict(),
            "contract": {
                "all_terminal": True,
                "no_hang": True,
                "no_slot_or_refcount_leak": True,
                "unaffected_bit_identical": True,
                "poison_isolated": True,
                "compile_budget_unchanged": True,
                "recoveries": s.recoveries,
            },
        }
    emit(f"serve/chaos_B{batch}_N{n_requests}", 0.0,
         f"steps={steps};statuses={sorted(hist.items())};"
         f"aborted={s.aborted};expired={s.expired};rejected={s.rejected};"
         f"retries={s.launch_retries};recoveries={s.recoveries};"
         f"snapshots={s.snapshots};slow_steps={slow_steps_seen};"
         f"prefill_compiles={eng.prefill_compiles}"
         f"<=budget={budget_prefill};host=cpu")
    if json_path:
        with open(json_path, "w") as f:
            json.dump(report, f, indent=2)
        print(f"wrote {json_path}")
    return report


def _run(engine, warmup, reqs):
    """Warm the jit caches on a separate seeded mix, then time the measured
    workload (steady-state serving throughput). Compile counts are reported
    as the *delta during measurement*: the wave engine keeps compiling for
    every unseen wave length, the bucketed engine has a hard bound."""
    engine.generate(warmup)
    c0, s0 = engine.prefill_compiles, engine.stats.decode_steps
    a0, p0 = engine.stats.slot_steps_active, engine.stats.prefill_calls
    r0, t0 = engine.stats.decode_rows, engine.stats.tokens_generated
    h0, v0 = engine.stats.prefix_hits, engine.stats.prefill_tokens_saved
    l0 = engine.stats.prefix_lookups
    t_start = time.perf_counter()
    outs = engine.generate(reqs)
    dt = time.perf_counter() - t_start
    tokens = sum(len(o) for o in outs)
    decode_steps = engine.stats.decode_steps - s0
    active = engine.stats.slot_steps_active - a0
    decode_rows = engine.stats.decode_rows - r0
    gen_tokens = engine.stats.tokens_generated - t0
    lookups = engine.stats.prefix_lookups - l0
    return outs, {
        "tokens": tokens,
        "seconds": dt,
        "tokens_per_sec": tokens / max(dt, 1e-9),
        "decode_steps": decode_steps,
        "prefill_calls": engine.stats.prefill_calls - p0,
        "tokens_per_decode_step": active / max(decode_steps, 1),
        "decode_rows": decode_rows,
        "decode_rows_per_token": decode_rows / max(gen_tokens, 1),
        "decode_shapes": sorted(engine.stats.decode_shapes),
        "prefill_compiles_measured": engine.prefill_compiles - c0,
        "prefill_compiles": engine.prefill_compiles,
        "decode_compiles": engine.decode_compiles,
        "prefill_shapes": sorted(engine.stats.prefill_shapes),
        "prefix_hits": engine.stats.prefix_hits - h0,
        "prefix_lookups": lookups,
        "prefix_hit_rate": (engine.stats.prefix_hits - h0)
        / max(lookups, 1),
        "prefill_tokens_saved": engine.stats.prefill_tokens_saved - v0,
    }


def _run_prefix(model, cfg, params, reqs, warmup, n_requests, batch,
                cache_len, seed, json_path):
    """Prefix workload: continuous engine with the prefix cache OFF vs ON.
    Outputs must stay bit-identical; the cache-on engine must prefill
    strictly fewer prompt tokens per request (prefill_tokens_saved > 0)."""
    off = ServeEngine(model, cfg, params, batch=batch, cache_len=cache_len)
    off.prewarm()
    outs_off, row_off = _run(off, warmup, reqs)
    on = ServeEngine(model, cfg, params, batch=batch, cache_len=cache_len,
                     prefix_cache=True)
    on.prewarm()
    outs_on, row_on = _run(on, warmup, reqs)

    assert outs_on == outs_off, (
        "greedy outputs diverged with the prefix cache on: shared-head "
        "reuse must be bit-identical to full prefill"
    )
    assert row_on["prefill_tokens_saved"] > 0, (
        "prefix workload produced zero reused prefix tokens"
    )
    prompt_tokens = sum(r.prompt_len for r in reqs)
    for row in (row_off, row_on):
        row["prompt_tokens"] = prompt_tokens
        row["prefill_tokens"] = prompt_tokens - row["prefill_tokens_saved"]
        row["prefill_tokens_per_request"] = (
            row["prefill_tokens"] / n_requests)
    assert (row_on["prefill_tokens_per_request"]
            < row_off["prefill_tokens_per_request"]), (
        "prefill tokens/request must drop strictly with the prefix cache on"
    )

    report = {
        "workload": {"name": "prefix", "n_requests": n_requests,
                     "batch": batch, "cache_len": cache_len, "seed": seed,
                     "total_tokens": row_on["tokens"],
                     "prompt_tokens": prompt_tokens,
                     "host": "cpu-interpret"},
        "prefix_off": row_off,
        "prefix_on": row_on,
        "equal_greedy_outputs": True,
        "prefill_tokens_saved": row_on["prefill_tokens_saved"],
        "prefix_hit_rate": row_on["prefix_hit_rate"],
        "speedup_tokens_per_sec":
            row_on["tokens_per_sec"] / max(row_off["tokens_per_sec"], 1e-9),
        "prefill_token_drop":
            row_off["prefill_tokens_per_request"]
            / max(row_on["prefill_tokens_per_request"], 1e-9),
    }
    for name, row in (("prefix_off", row_off), ("prefix_on", row_on)):
        emit(f"serve/{name}_B{batch}_N{n_requests}_prefix",
             row["seconds"] * 1e6,
             f"tok_s={row['tokens_per_sec']:.1f};"
             f"prefill_tokens_per_request="
             f"{row['prefill_tokens_per_request']:.1f};"
             f"prefix_hits={row['prefix_hits']};"
             f"prefix_hit_rate={row['prefix_hit_rate']:.2f};"
             f"prefill_tokens_saved={row['prefill_tokens_saved']};"
             f"prefill_compiles={row['prefill_compiles']};"
             f"decode_compiles={row['decode_compiles']};host=cpu")
    emit("serve/speedup_prefix", 0.0,
         f"tokens_per_sec={report['speedup_tokens_per_sec']:.2f}x;"
         f"prefill_token_drop={report['prefill_token_drop']:.2f}x;"
         f"prefix_hit_rate={report['prefix_hit_rate']:.2f};"
         f"prefill_tokens_saved={report['prefill_tokens_saved']};"
         f"equal_outputs=True")
    if json_path:
        with open(json_path, "w") as f:
            json.dump(report, f, indent=2)
        print(f"wrote {json_path}")
    return report


def _run_quantize(model, cfg, params, reqs, warmup, n_requests, batch,
                  cache_len, seed, json_path):
    """Quantize workload: fp32 frozen tables vs int8 frozen tables vs the
    dequantized oracle (the int8 engine's tables dequantized back to fp32
    and served through a quantize-off engine).

    The contract asserted: int8 and oracle greedy outputs are BIT-identical
    (int8 -> f32 * scale is exact, so serving the quantized tables is
    serving the fake-quantized weights, not an approximation of them);
    resident frozen-table bytes drop to <= 0.55x fp32; and the compile
    budget is unchanged — quantization swaps array contents, never launch
    shapes or executable counts."""
    from repro.kernels.block_circulant.plan import dequantize_frozen

    fp = ServeEngine(model, cfg, params, batch=batch, cache_len=cache_len)
    fp.prewarm()
    outs_f, row_f = _run(fp, warmup, reqs)
    q = ServeEngine(model, cfg, params, batch=batch, cache_len=cache_len,
                    quantize="int8")
    q.prewarm()
    outs_q, row_q = _run(q, warmup, reqs)
    # oracle: the int8 engine's own tables, host-dequantized to fp32, served
    # through a quantize-off engine (freeze_params passes frozen trees
    # through untouched, so the oracle runs exactly these table values)
    oracle = ServeEngine(model, cfg, dequantize_frozen(q.params),
                         batch=batch, cache_len=cache_len)
    oracle.prewarm()
    outs_o, row_o = _run(oracle, warmup, reqs)

    assert outs_q == outs_o, (
        "int8 serving diverged from its dequantized-table oracle: "
        "in-engine dequant must be bit-identical"
    )
    bytes_f, bytes_q = fp.frozen_table_bytes(), q.frozen_table_bytes()
    ratio = bytes_q / max(bytes_f, 1)
    assert ratio <= 0.55, (
        f"int8 frozen tables are {ratio:.3f}x fp32 bytes (must be <= 0.55x)"
    )
    assert (row_q["prefill_compiles"] == row_f["prefill_compiles"]
            and row_q["decode_compiles"] == row_f["decode_compiles"]), (
        "quantization changed the compile budget: int8 tables must reuse "
        "the fp32 engine's executable counts"
    )
    for row, eng in ((row_f, fp), (row_q, q), (row_o, oracle)):
        row["frozen_table_bytes"] = eng.frozen_table_bytes()

    report = {
        "workload": {"name": "quantize", "n_requests": n_requests,
                     "batch": batch, "cache_len": cache_len, "seed": seed,
                     "total_tokens": row_q["tokens"],
                     "host": "cpu-interpret"},
        "fp32": row_f,
        "int8": row_q,
        "dequant_oracle": row_o,
        "int8_equals_oracle": True,
        "frozen_table_bytes_fp32": bytes_f,
        "frozen_table_bytes_int8": bytes_q,
        "frozen_table_bytes_ratio": ratio,
        "compile_budget_unchanged": True,
    }
    for name, row in (("fp32", row_f), ("int8", row_q),
                      ("dequant_oracle", row_o)):
        emit(f"serve/{name}_B{batch}_N{n_requests}_quantize",
             row["seconds"] * 1e6,
             f"tok_s={row['tokens_per_sec']:.1f};"
             f"frozen_table_bytes={row['frozen_table_bytes']};"
             f"prefill_compiles={row['prefill_compiles']};"
             f"decode_compiles={row['decode_compiles']};host=cpu")
    emit("serve/quantize_int8", 0.0,
         f"bytes_ratio={ratio:.3f};int8_equals_oracle=True;"
         f"compile_budget_unchanged=True;"
         f"tokens_per_sec_vs_fp32="
         f"{row_q['tokens_per_sec'] / max(row_f['tokens_per_sec'], 1e-9):.2f}x")
    if json_path:
        with open(json_path, "w") as f:
            json.dump(report, f, indent=2)
        print(f"wrote {json_path}")
    return report


def run(n_requests: int = 32, batch: int = 4, cache_len: int = 64,
        seed: int = 0, workload: str = "mixed", json_path: str = ""):
    if workload == "chaos":
        return _run_chaos(n_requests, batch, cache_len, seed, json_path)
    if workload == "families":
        return _run_families(n_requests, batch, cache_len, seed, json_path)
    if workload == "tenants":
        return _run_tenants(n_requests, batch, cache_len, seed, json_path)
    cfg = _cfg()
    model = HybridDecoderLM(cfg)
    params = init_params(model.specs(), 0)
    make = WORKLOADS[workload]
    reqs = make(n_requests, cache_len, seed)
    warmup = make(max(4, n_requests // 4), cache_len, seed + 1)
    if workload == "prefix":
        return _run_prefix(model, cfg, params, reqs, warmup, n_requests,
                           batch, cache_len, seed, json_path)
    if workload == "quantize":
        return _run_quantize(model, cfg, params, reqs, warmup, n_requests,
                             batch, cache_len, seed, json_path)

    wave = WaveEngine(model, cfg, params, batch=batch, cache_len=cache_len)
    outs_w, row_w = _run(wave, warmup, reqs)
    # full-slot decode: the PR-2 engine (decode always at the slot count)
    full = ServeEngine(model, cfg, params, batch=batch, cache_len=cache_len,
                       decode_buckets=(batch,))
    full.prewarm()
    outs_f, row_f = _run(full, warmup, reqs)
    # compacted decode: active slots gather into the smallest pow2 bucket
    cont = ServeEngine(model, cfg, params, batch=batch, cache_len=cache_len)
    cont.prewarm()        # finite bucket grids -> compile everything up front
    outs_c, row_c = _run(cont, warmup, reqs)

    assert outs_c == outs_w, "continuous and wave greedy outputs diverged"
    assert outs_c == outs_f, "bucketed and full-slot decode outputs diverged"
    for eng, row in ((full, row_f), (cont, row_c)):
        row["max_prefill_variants"] = eng.max_prefill_variants
        row["max_decode_variants"] = eng.max_decode_variants
        row["batch_buckets"] = list(eng.batch_buckets)
        row["prompt_buckets"] = list(eng.prompt_buckets)
        row["decode_buckets"] = list(eng.decode_buckets)

    row_work_drop = (row_f["decode_rows_per_token"]
                     / max(row_c["decode_rows_per_token"], 1e-9))
    if workload == "tail":
        assert (row_c["decode_rows_per_token"]
                < row_f["decode_rows_per_token"]), (
            "decode compaction must strictly drop row-work per token on the "
            "tail-heavy workload"
        )

    report = {
        "workload": {"name": workload, "n_requests": n_requests,
                     "batch": batch, "cache_len": cache_len, "seed": seed,
                     "total_tokens": row_c["tokens"],
                     "host": "cpu-interpret"},
        "wave": row_w,
        "continuous_full_slot": row_f,
        "continuous": row_c,
        "equal_greedy_outputs": True,
        "speedup_tokens_per_sec":
            row_c["tokens_per_sec"] / max(row_w["tokens_per_sec"], 1e-9),
        "speedup_tokens_per_decode_step":
            row_c["tokens_per_decode_step"]
            / max(row_w["tokens_per_decode_step"], 1e-9),
        "decode_row_work_drop_vs_full_slot": row_work_drop,
    }
    for name, row in (("wave", row_w), ("full_slot", row_f),
                      ("continuous", row_c)):
        emit(f"serve/{name}_B{batch}_N{n_requests}_{workload}",
             row["seconds"] * 1e6,
             f"tok_s={row['tokens_per_sec']:.1f};"
             f"tok_per_decode_step={row['tokens_per_decode_step']:.2f};"
             f"decode_rows_per_token={row['decode_rows_per_token']:.2f};"
             f"decode_steps={row['decode_steps']};"
             f"prefill_compiles_measured={row['prefill_compiles_measured']};"
             f"prefill_compiles={row['prefill_compiles']};"
             f"decode_compiles={row['decode_compiles']};host=cpu")
    emit(f"serve/speedup_{workload}", 0.0,
         f"tokens_per_sec={report['speedup_tokens_per_sec']:.2f}x;"
         f"tokens_per_decode_step="
         f"{report['speedup_tokens_per_decode_step']:.2f}x;"
         f"decode_row_work_drop={row_work_drop:.2f}x;"
         f"recompile_bound={row_c['max_prefill_variants']}"
         f"+{row_c['max_decode_variants']};"
         f"equal_outputs=True")
    if json_path:
        with open(json_path, "w") as f:
            json.dump(report, f, indent=2)
        print(f"wrote {json_path}")
    return report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small workload (CI artifact)")
    ap.add_argument("--json", default="", help="write the report as JSON")
    ap.add_argument("--workload", choices=sorted(WORKLOADS),
                    default="mixed",
                    help="mixed: wave-stalling traffic; tail: tail-heavy "
                         "traffic where decode compaction pays off; "
                         "prefix: shared-prompt-head traffic where the "
                         "prefix cache skips repeated head prefill; "
                         "chaos: mixed traffic under seeded injected "
                         "faults, asserting the fault-tolerance contract; "
                         "quantize: mixed traffic through fp32 vs int8 "
                         "frozen tables vs the dequantized oracle "
                         "(bit-equality, bytes, compile budget); "
                         "families: the same traffic through decoder vs "
                         "rwkv vs moe runners (tokens/sec per family, "
                         "compile-budget + recurrent pad-invariance "
                         "asserts); "
                         "tenants: bursty 3-tenant mix through the "
                         "supervised fair engine with a mid-stream fatal "
                         "(DRR fairness, at-most-once streams, TTFT "
                         "histograms through snapshot/restore)")
    ap.add_argument("--n-requests", type=int, default=0)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    n = args.n_requests or (12 if args.quick else 32)
    run(n_requests=n, batch=args.batch, cache_len=args.cache_len,
        seed=args.seed, workload=args.workload, json_path=args.json)


if __name__ == "__main__":
    main()
