"""Serving throughput: continuous-batching engine vs the wave baseline.

Runs the same seeded mixed-length / mixed-budget request workload through
``ServeEngine`` (per-slot admission, bucketed prefill shapes) and
``WaveEngine`` (fixed waves, stall-on-slowest), and reports:

  * tokens/sec (CPU wall time in this container — labeled as such),
  * tokens per decode step — the batching-efficiency signal that carries to
    hardware: the wave engine idles slots until the wave's largest max_new
    finishes, the continuous engine refills them;
  * recompile counts — wave prefill recompiles per distinct wave length
    (unbounded in the workload), the continuous engine is bounded by its
    bucket grid (``max_prefill_variants``).

Greedy outputs of the two engines are asserted identical before timing is
reported (same frozen-FFT(w) math, different orchestration).

    PYTHONPATH=src python benchmarks/serve_bench.py --quick --json out.json
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np

from benchmarks.common import emit
from repro.configs.base import ModelConfig, SWMConfig
from repro.models.decoder import HybridDecoderLM
from repro.nn.module import init_params
from repro.serve.engine import Request, ServeEngine, WaveEngine


def _cfg() -> ModelConfig:
    return ModelConfig(
        name="serve-bench", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        head_dim=16, d_ff=128, vocab=128, remat="none",
        param_dtype="float32", compute_dtype="float32",
        swm=SWMConfig(block_size=8, impl="dft"),
    )


def _workload(n_requests: int, cache_len: int, seed: int):
    """Mixed prompt lengths AND mixed generation budgets — the shape of
    traffic where wave batching stalls (every wave runs to its max max_new
    at its max prompt length)."""
    rng = np.random.default_rng(seed)
    reqs = []
    for _ in range(n_requests):
        plen = int(rng.integers(2, 25))
        max_new = int(rng.integers(2, min(25, cache_len - plen)))
        reqs.append(Request(
            rng.integers(0, 128, size=plen).astype(np.int32),
            max_new=max_new,
        ))
    return reqs


def _run(engine, warmup, reqs):
    """Warm the jit caches on a separate seeded mix, then time the measured
    workload (steady-state serving throughput). Compile counts are reported
    as the *delta during measurement*: the wave engine keeps compiling for
    every unseen wave length, the bucketed engine has a hard bound."""
    engine.generate(warmup)
    c0, s0 = engine.prefill_compiles, engine.stats.decode_steps
    a0, p0 = engine.stats.slot_steps_active, engine.stats.prefill_calls
    t_start = time.perf_counter()
    outs = engine.generate(reqs)
    dt = time.perf_counter() - t_start
    tokens = sum(len(o) for o in outs)
    decode_steps = engine.stats.decode_steps - s0
    active = engine.stats.slot_steps_active - a0
    return outs, {
        "tokens": tokens,
        "seconds": dt,
        "tokens_per_sec": tokens / max(dt, 1e-9),
        "decode_steps": decode_steps,
        "prefill_calls": engine.stats.prefill_calls - p0,
        "tokens_per_decode_step": active / max(decode_steps, 1),
        "prefill_compiles_measured": engine.prefill_compiles - c0,
        "prefill_compiles": engine.prefill_compiles,
        "decode_compiles": engine.decode_compiles,
        "prefill_shapes": sorted(engine.stats.prefill_shapes),
    }


def run(n_requests: int = 32, batch: int = 4, cache_len: int = 64,
        seed: int = 0, json_path: str = ""):
    cfg = _cfg()
    model = HybridDecoderLM(cfg)
    params = init_params(model.specs(), 0)
    reqs = _workload(n_requests, cache_len, seed)
    warmup = _workload(max(4, n_requests // 4), cache_len, seed + 1)

    wave = WaveEngine(model, cfg, params, batch=batch, cache_len=cache_len)
    outs_w, row_w = _run(wave, warmup, reqs)
    cont = ServeEngine(model, cfg, params, batch=batch, cache_len=cache_len)
    cont.prewarm()        # finite bucket grid -> compile everything up front
    outs_c, row_c = _run(cont, warmup, reqs)

    assert outs_c == outs_w, "continuous and wave greedy outputs diverged"
    row_c["max_prefill_variants"] = cont.max_prefill_variants
    row_c["batch_buckets"] = list(cont.batch_buckets)
    row_c["prompt_buckets"] = list(cont.prompt_buckets)

    report = {
        "workload": {"n_requests": n_requests, "batch": batch,
                     "cache_len": cache_len, "seed": seed,
                     "total_tokens": row_c["tokens"],
                     "host": "cpu-interpret"},
        "wave": row_w,
        "continuous": row_c,
        "equal_greedy_outputs": True,
        "speedup_tokens_per_sec":
            row_c["tokens_per_sec"] / max(row_w["tokens_per_sec"], 1e-9),
        "speedup_tokens_per_decode_step":
            row_c["tokens_per_decode_step"]
            / max(row_w["tokens_per_decode_step"], 1e-9),
    }
    for name, row in (("wave", row_w), ("continuous", row_c)):
        emit(f"serve/{name}_B{batch}_N{n_requests}",
             row["seconds"] * 1e6,
             f"tok_s={row['tokens_per_sec']:.1f};"
             f"tok_per_decode_step={row['tokens_per_decode_step']:.2f};"
             f"decode_steps={row['decode_steps']};"
             f"prefill_compiles_measured={row['prefill_compiles_measured']};"
             f"prefill_compiles={row['prefill_compiles']};"
             f"decode_compiles={row['decode_compiles']};host=cpu")
    emit("serve/speedup", 0.0,
         f"tokens_per_sec={report['speedup_tokens_per_sec']:.2f}x;"
         f"tokens_per_decode_step="
         f"{report['speedup_tokens_per_decode_step']:.2f}x;"
         f"recompile_bound={row_c['max_prefill_variants']};"
         f"equal_outputs=True")
    if json_path:
        with open(json_path, "w") as f:
            json.dump(report, f, indent=2)
        print(f"wrote {json_path}")
    return report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small workload (CI artifact)")
    ap.add_argument("--json", default="", help="write the report as JSON")
    ap.add_argument("--n-requests", type=int, default=0)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    n = args.n_requests or (12 if args.quick else 32)
    run(n_requests=n, batch=args.batch, cache_len=args.cache_len,
        seed=args.seed, json_path=args.json)


if __name__ == "__main__":
    main()
