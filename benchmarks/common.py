"""Shared benchmark utilities: timing, FLOP counting, CSV emission."""

from __future__ import annotations

import time
from typing import Callable

import jax
import numpy as np

# TPU v5e hardware constants (targets; this host is CPU so wall-times are
# CPU-measured and labeled as such — roofline projections use these).
PEAK_FLOPS_BF16 = 197e12          # per chip
HBM_BW = 819e9                    # B/s per chip
ICI_BW = 50e9                     # B/s per link

ROWS = []


def time_fn(fn: Callable, *args, iters: int = 20, warmup: int = 3) -> float:
    """Median wall-clock μs per call (jit'd fn; blocks on result)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


def compiled_flops(fn: Callable, *args) -> float:
    """HLO FLOPs of fn(*args) from XLA cost analysis."""
    ca = jax.jit(fn).lower(*args).compile().cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return float(ca.get("flops", -1.0))


def emit(name: str, us_per_call: float, derived: str = ""):
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.2f},{derived}")
