"""§4.2 reproduction: compression ratio (block size) vs model accuracy.

The paper sweeps block size and reports model-size reduction at negligible
accuracy loss (<2% DCNN; 0.32%/1.23% PER LSTM). We train the paper's MLP
on deterministic synthetic image data for each k ∈ {1, 2, 4, 8, 16} and
report test accuracy + size reduction.

The quantization arm sweeps bit width and reports BOTH deployment modes
per width:

* **PTQ** (post-training quantization): train in fp32, then evaluate with
  the fixed-point forward — the trained fp32 params are reused unchanged.
* **QAT** (quantization-aware training): train with the fake-quantized
  forward (clipped-STE ``fixed_point``), so the weights adapt to the
  rails during training.

The old version of this benchmark trained the "quantized" arm with the
fixed-point forward and labeled the result as plain quantization — i.e.
it measured QAT but implied PTQ, hiding the PTQ-vs-QAT gap the paper's
fixed-point results rest on. Both numbers are now reported explicitly.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.data.pipeline import synthetic_images
from repro.models.paper_models import SWMMLP
from repro.nn.module import init_params, param_count
from repro.optim.optimizers import adamw_init, adamw_update
from repro.configs.base import TrainConfig

DIMS = (784, 256, 256, 10)
QUANT_BITS = (8, 12, 16)


def _train(model, steps=150, lr=3e-3, seed=0):
    """Train ``model`` on the synthetic stream; returns the trained params.

    When ``model.quant_bits`` is set, the forward is fake-quantized, so
    this IS quantization-aware training (the optimizer still updates the
    full-precision master copy).
    """
    params = init_params(model.specs(), seed)
    tcfg = TrainConfig(learning_rate=lr, warmup_steps=10, total_steps=steps,
                       weight_decay=0.0)
    opt = adamw_init(params, tcfg)

    @jax.jit
    def step(params, opt, i, x, y):
        def loss(p):
            logits = model(p, x)
            return -jnp.take_along_axis(
                jax.nn.log_softmax(logits), y[:, None], 1).mean()
        l, g = jax.value_and_grad(loss)(params)
        params, opt = adamw_update(params, g, opt, i, tcfg)
        return params, opt, l

    for i in range(steps):
        xi, yi = synthetic_images(128, i)
        params, opt, l = step(params, opt, jnp.asarray(i),
                              jnp.asarray(xi.reshape(128, -1)),
                              jnp.asarray(yi))
    return params


def _eval(model, params):
    """Held-out accuracy of ``model`` (its own forward — quantized when
    ``model.quant_bits`` is set) over the fixed eval steps."""
    correct = total = 0
    for i in range(1000, 1008):
        xi, yi = synthetic_images(128, i)
        pred = np.asarray(jnp.argmax(model(params, jnp.asarray(
            xi.reshape(128, -1))), -1))
        correct += (pred == yi).sum()
        total += len(yi)
    return correct / total


def _train_eval(model, steps=150, lr=3e-3, seed=0):
    return _eval(model, _train(model, steps=steps, lr=lr, seed=seed))


def run():
    dense_params = param_count(SWMMLP(dims=DIMS, block_size=0).specs())
    acc_dense = None
    params_k8 = None
    for k in (0, 2, 4, 8, 16):
        model = SWMMLP(dims=DIMS, block_size=k)
        params = _train(model)
        acc = _eval(model, params)
        n = param_count(model.specs())
        if k == 0:
            acc_dense = acc
        if k == 8:
            params_k8 = params          # fp32 master copy for the PTQ arm
        emit(f"compression_accuracy/k{k or 'dense'}", 0.0,
             f"acc={acc:.4f};size_reduction={dense_params/n:.1f}x;"
             f"acc_delta_vs_dense={(acc_dense-acc)*100:+.2f}pp")
    # quantization arm (paper uses 12-bit fixed point): for each width,
    # PTQ evaluates the k=8 fp32 params through the fixed-point forward;
    # QAT retrains with the fake-quantized forward from scratch.
    for bits in QUANT_BITS:
        qmodel = SWMMLP(dims=DIMS, block_size=8, quant_bits=bits)
        acc_ptq = _eval(qmodel, params_k8)
        acc_qat = _train_eval(qmodel)
        emit(f"compression_accuracy/k8_b{bits}",
             0.0,
             f"acc_ptq={acc_ptq:.4f};acc_qat={acc_qat:.4f};"
             f"qat_gain={(acc_qat-acc_ptq)*100:+.2f}pp;"
             f"acc_delta_vs_dense_qat={(acc_dense-acc_qat)*100:+.2f}pp")


if __name__ == "__main__":
    run()
