"""§4.2 reproduction: compression ratio (block size) vs model accuracy.

The paper sweeps block size and reports model-size reduction at negligible
accuracy loss (<2% DCNN; 0.32%/1.23% PER LSTM). We train the paper's MLP
on deterministic synthetic image data for each k ∈ {1, 2, 4, 8, 16} (and
12-bit quantization on/off) and report test accuracy + size reduction.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.data.pipeline import synthetic_images
from repro.models.paper_models import SWMMLP
from repro.nn.module import init_params, param_count
from repro.optim.optimizers import adamw_init, adamw_update
from repro.configs.base import TrainConfig


def _train_eval(model, steps=150, lr=3e-3, seed=0):
    params = init_params(model.specs(), seed)
    tcfg = TrainConfig(learning_rate=lr, warmup_steps=10, total_steps=steps,
                       weight_decay=0.0)
    opt = adamw_init(params, tcfg)

    @jax.jit
    def step(params, opt, i, x, y):
        def loss(p):
            logits = model(p, x)
            return -jnp.take_along_axis(
                jax.nn.log_softmax(logits), y[:, None], 1).mean()
        l, g = jax.value_and_grad(loss)(params)
        params, opt = adamw_update(params, g, opt, i, tcfg)
        return params, opt, l

    for i in range(steps):
        xi, yi = synthetic_images(128, i)
        params, opt, l = step(params, opt, jnp.asarray(i),
                              jnp.asarray(xi.reshape(128, -1)),
                              jnp.asarray(yi))
    # eval on held-out steps
    correct = total = 0
    for i in range(1000, 1008):
        xi, yi = synthetic_images(128, i)
        pred = np.asarray(jnp.argmax(model(params, jnp.asarray(
            xi.reshape(128, -1))), -1))
        correct += (pred == yi).sum()
        total += len(yi)
    return correct / total


def run():
    dense_params = param_count(SWMMLP(dims=(784, 256, 256, 10),
                                      block_size=0).specs())
    acc_dense = None
    for k in (0, 2, 4, 8, 16):
        model = SWMMLP(dims=(784, 256, 256, 10), block_size=k)
        acc = _train_eval(model)
        n = param_count(model.specs())
        if k == 0:
            acc_dense = acc
        emit(f"compression_accuracy/k{k or 'dense'}", 0.0,
             f"acc={acc:.4f};size_reduction={dense_params/n:.1f}x;"
             f"acc_delta_vs_dense={(acc_dense-acc)*100:+.2f}pp")
    # quantized variant (paper uses 12-bit fixed point)
    model = SWMMLP(dims=(784, 256, 256, 10), block_size=8, quant_bits=12)
    acc = _train_eval(model)
    emit("compression_accuracy/k8_quant12", 0.0,
         f"acc={acc:.4f};acc_delta_vs_dense={(acc_dense-acc)*100:+.2f}pp")


if __name__ == "__main__":
    run()
