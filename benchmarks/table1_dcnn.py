"""Table 1 (DCNN rows): SWM vs dense throughput/energy on the paper's nets.

The paper reports kFPS and kFPS/W on a CyClone V FPGA vs IBM TrueNorth for
MNIST MLPs, a LeNet-like CNN, SVHN and CIFAR-10 nets. We reproduce the
*system-level quantities we can measure here*: images/s (CPU-measured,
labeled), FLOPs/image (compiled), parameter compression, and a TPU-v5e
roofline projection (FLOPs / peak). Paper numbers are quoted inline for
reference.

Paper reference rows (Table 1):
  Proposed MNIST 1  (MLP)     92.9%   8.6e4 kFPS   1.57e5 kFPS/W
  Proposed MNIST 2  (MLP)     95.6%   2.9e4 kFPS   5.2e4  kFPS/W
  Proposed MNIST 3  (LeNet)   99.0%   363  kFPS    659.5  kFPS/W
  Proposed SVHN               96.2%   384.9 kFPS   699.7  kFPS/W
  Proposed CIFAR-10 1         80.3%   1383 kFPS    2514   kFPS/W
  TrueNorth MNIST             95%     1.0  kFPS    250    kFPS/W
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import PEAK_FLOPS_BF16, compiled_flops, emit, time_fn
from repro.models.paper_models import SWMCNN, SWMMLP
from repro.nn.module import init_params, param_count


def _bench_net(name, model, x, dense_model=None):
    params = init_params(model.specs(), 0)
    fn = jax.jit(lambda p, x: model(p, x))
    us = time_fn(fn, params, x)
    B = x.shape[0]
    fl = compiled_flops(lambda p, x: model(p, x), params, x)
    n_params = param_count(model.specs())
    img_s = B / (us / 1e6)
    # TPU v5e projection: FLOP-bound images/s at 50% peak utilization
    tpu_img_s = 0.5 * PEAK_FLOPS_BF16 / max(fl / B, 1)
    derived = (f"images_s_cpu={img_s:.0f};flops_per_img={fl/B:.3e};"
               f"params={n_params};tpu_v5e_proj_kfps={tpu_img_s/1e3:.0f}")
    if dense_model is not None:
        dp = init_params(dense_model.specs(), 0)
        dus = time_fn(jax.jit(lambda p, x: dense_model(p, x)), dp, x)
        dn = param_count(dense_model.specs())
        derived += (f";speedup_vs_dense={dus/us:.2f}x"
                    f";compression={dn/n_params:.1f}x")
    emit(name, us, derived)


def run():
    B = 64
    x_mlp = jax.random.normal(jax.random.PRNGKey(0), (B, 784))
    # MNIST 1/2: MLPs (paper's 92.9% / 95.6% rows), k=64 vs dense
    _bench_net(
        "table1/mnist_mlp_swm_k64",
        SWMMLP(dims=(784, 512, 512, 10), block_size=64, quant_bits=12),
        x_mlp,
        dense_model=SWMMLP(dims=(784, 512, 512, 10), block_size=0),
    )
    # MNIST 3: LeNet-like CNN (99.0% row)
    x_img = jax.random.normal(jax.random.PRNGKey(1), (8, 28, 28, 1))
    _bench_net(
        "table1/mnist_cnn_swm",
        SWMCNN(),
        x_img,
        dense_model=SWMCNN(conv_block=1, fc_block=0),
    )
    # SVHN / CIFAR-10-1: wider MLP-ish stand-ins at the paper's scale
    x32 = jax.random.normal(jax.random.PRNGKey(2), (B, 3072))
    _bench_net(
        "table1/cifar10_swm_k64",
        SWMMLP(dims=(3072, 1024, 1024, 10), block_size=64, quant_bits=12),
        x32,
        dense_model=SWMMLP(dims=(3072, 1024, 1024, 10), block_size=0),
    )


if __name__ == "__main__":
    run()
