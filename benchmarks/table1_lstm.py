"""Table 1 (LSTM rows): SWM-LSTM (FFT8/FFT16) vs dense Google-LSTM vs ESE.

Paper claims: block size 16 → 14.6× model-size reduction, ~3.7× compute
reduction, 1.23% PER degradation; block size 8 → 7.6× / 2.6× / 0.32%.
vs ESE: up to 21× performance, 33.5× energy efficiency.

We measure: μs/frame (CPU), FLOPs/frame (compiled), parameter reduction —
and check the paper's compute/storage reduction ratios directly.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import compiled_flops, emit, time_fn
from repro.models.paper_models import SWMLSTMASR
from repro.nn.module import init_params, param_count


def run():
    B, T = 4, 32
    x = jax.random.normal(jax.random.PRNGKey(0), (B, T, 153))
    dense = SWMLSTMASR(block_size=0)
    nd = param_count(dense.specs())
    fd = None
    base_us = None
    for k, name in [(0, "dense"), (8, "fft8_lstm2"), (16, "fft16_lstm1")]:
        model = SWMLSTMASR(block_size=k)
        params = init_params(model.specs(), 0)
        fn = jax.jit(lambda p, x, m=model: m(p, x))
        us = time_fn(fn, params, x, iters=5, warmup=2)
        fl = compiled_flops(lambda p, x, m=model: m(p, x), params, x)
        np_ = param_count(model.specs())
        if k == 0:
            fd, base_us = fl, us
            derived = f"flops_per_frame={fl/(B*T):.3e};params={np_}"
        else:
            derived = (f"flops_per_frame={fl/(B*T):.3e};params={np_};"
                       f"size_reduction={nd/np_:.1f}x;"
                       f"flop_reduction={fd/fl:.2f}x;"
                       f"paper_claim_size={'7.6x' if k==8 else '14.6x'};"
                       f"paper_claim_flops={'2.6x' if k==8 else '3.7x'}")
        emit(f"table1/lstm_{name}", us, derived)


if __name__ == "__main__":
    run()
