"""§3 claim: compute O(n²)→O(n log n), storage O(n²)→O(n).

Measures compiled FLOPs and wall-μs for one n×n layer, dense vs SWM
(freq impl with k=n/8 fixed block count, and k=64 fixed block size),
as n grows. The FLOPs ratio should track ~k/4; storage ratio exactly k.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import compiled_flops, emit, time_fn
from repro.core.circulant import (block_circulant_apply, dense_flops,
                                  swm_flops)


def run():
    B = 32
    for n in (512, 1024, 2048, 4096):
        k = 64
        p = q = n // k
        x = jax.random.normal(jax.random.PRNGKey(0), (B, n))
        w_swm = jax.random.normal(jax.random.PRNGKey(1), (p, q, k))
        w_dense = jax.random.normal(jax.random.PRNGKey(2), (n, n))

        f_dense = jax.jit(lambda x, w: x @ w.T)
        f_swm = jax.jit(lambda x, w: block_circulant_apply(x, w, impl="freq"))
        f_dft = jax.jit(lambda x, w: block_circulant_apply(x, w, impl="dft"))

        us_d = time_fn(f_dense, x, w_dense)
        us_s = time_fn(f_swm, x, w_swm)
        us_m = time_fn(f_dft, x, w_swm)
        fl_d = compiled_flops(lambda x, w: x @ w.T, x, w_dense)
        fl_s = compiled_flops(
            lambda x, w: block_circulant_apply(x, w, impl="dft"), x, w_swm)
        emit(f"complexity/n{n}_dense", us_d, f"flops={fl_d:.3e};params={n*n}")
        emit(f"complexity/n{n}_swm_k64_freq", us_s,
             f"analytic_flops={swm_flops(B,n,n,k):.3e};params={n*n//k};"
             f"storage_reduction={k}x;speedup={us_d/us_s:.2f}x")
        emit(f"complexity/n{n}_swm_k64_dft", us_m,
             f"flops={fl_s:.3e};flop_reduction={fl_d/max(fl_s,1):.1f}x;"
             f"speedup={us_d/us_m:.2f}x")


if __name__ == "__main__":
    run()
