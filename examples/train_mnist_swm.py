"""Paper §4.2/§6.1 end-to-end: train the paper's MNIST MLP with SWM
compression at several block sizes and compare accuracy vs model size —
the accuracy/compression trade-off curve that motivates the whole paper.

    PYTHONPATH=src python examples/train_mnist_swm.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import TrainConfig
from repro.data.pipeline import synthetic_images
from repro.models.paper_models import SWMMLP
from repro.nn.module import init_params, param_count
from repro.optim.optimizers import adamw_init, adamw_update


def train_one(k: int, steps: int = 200) -> tuple:
    model = SWMMLP(dims=(784, 256, 256, 10), block_size=k,
                   quant_bits=12 if k else 0)
    tcfg = TrainConfig(learning_rate=3e-3, warmup_steps=10,
                       total_steps=steps, weight_decay=0.0)
    params = init_params(model.specs(), 0)
    opt = adamw_init(params, tcfg)

    @jax.jit
    def step(params, opt, i, x, y):
        def loss(p):
            lp = jax.nn.log_softmax(model(p, x))
            return -jnp.take_along_axis(lp, y[:, None], 1).mean()
        l, g = jax.value_and_grad(loss)(params)
        params, opt = adamw_update(params, g, opt, i, tcfg)
        return params, opt, l

    for i in range(steps):
        x, y = synthetic_images(128, i)
        params, opt, l = step(params, opt, jnp.asarray(i),
                              jnp.asarray(x.reshape(128, -1)), jnp.asarray(y))
    correct = total = 0
    for i in range(1000, 1010):
        x, y = synthetic_images(128, i)
        pred = np.asarray(jnp.argmax(
            model(params, jnp.asarray(x.reshape(128, -1))), -1))
        correct += (pred == y).sum()
        total += len(y)
    return correct / total, param_count(model.specs())


def main():
    print(f"{'block size':>12} {'accuracy':>9} {'params':>9} {'reduction':>10}")
    base = None
    for k in (0, 2, 4, 8, 16):
        acc, n = train_one(k)
        base = base or n
        print(f"{k or 'dense':>12} {acc:9.4f} {n:9,} {base/n:9.1f}x")
    print("\n(the paper reports <2% accuracy loss at 400×+ FC-layer "
          "compression on real MNIST; synthetic data shown here)")


if __name__ == "__main__":
    main()
