"""Quickstart: train a small SWM (block-circulant) language model.

Shows the three-line story of the paper's technique inside this framework:
set ``swm.block_size=k`` on any config and every projection becomes a
circulant block table — k× less storage, ~k/4× less compute — trained with
the ordinary AdamW loop.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax

from repro.configs.base import ModelConfig, SWMConfig, TrainConfig
from repro.data.pipeline import SyntheticLM
from repro.launch.specs import count_params
from repro.models.decoder import HybridDecoderLM
from repro.nn.module import init_params, param_count
from repro.train.loop import init_train_state, make_train_step


def main():
    cfg = ModelConfig(
        name="quickstart-swm-lm",
        n_layers=4, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
        d_ff=512, vocab=512,
        swm=SWMConfig(block_size=16, impl="dft"),   # <-- the paper, one line
        remat="none", param_dtype="float32", compute_dtype="float32",
    )
    tcfg = TrainConfig(learning_rate=3e-3, warmup_steps=20, total_steps=200,
                       z_loss=0.0)
    model = HybridDecoderLM(cfg)
    counts = count_params(cfg)
    print(f"params: {counts['stored']:,} stored "
          f"({counts['dense']:,} dense-equivalent → "
          f"{counts['compression']:.1f}x compression)")

    params = init_params(model.specs(), seed=0)
    state = init_train_state(params, tcfg)
    step = jax.jit(make_train_step(model, cfg, tcfg), donate_argnums=0)
    data = SyntheticLM(vocab=cfg.vocab, seq_len=64, batch=16)

    for s in range(200):
        state, metrics = step(state, data.batch_jax(s))
        if s % 25 == 0 or s == 199:
            print(f"step {s:4d}  loss {float(metrics['loss']):8.4f}  "
                  f"grad_norm {float(metrics['grad_norm']):7.3f}")
    print("done — loss should have dropped by >2 nats.")


if __name__ == "__main__":
    main()
