"""Serving demo: train a tiny SWM LM briefly, then serve a mixed-length,
mixed-budget request batch through the continuous-batching engine —
per-slot admission, bucketed prefill shapes, compacted decode buckets,
per-request sampling and stop tokens (prefill -> decode, frozen FFT(w)),
donated in-place cache buffers — then the streaming
submit()/step()/poll()/drain() API serving an open-ended trickle, and
finally shared-prefix KV reuse: requests sharing a long prompt head copy
the resident rows from a donor slot instead of re-running prefill over
the head (prefill_tokens_saved / prefix_hit_rate).

A multi-tenant section puts the asyncio front-end and the supervisor on
top: three tenants with different SLO classes burst-submit through
``AsyncFrontend`` (token-bucket admission, SLO deadline stamping) into a
supervised ``fair``-policy engine whose DRR weights come from the same
SLO classes; an injected mid-stream engine fatal self-heals from the
latest snapshot, re-queues the forgotten work, and the per-tenant
admitted shares + TTFT histograms (and ``restarts=1``) tell the story.

A quantized-serving section re-serves the same trained weights with the
frozen frequency tables stored as int8 (``quantize="int8"``): one
symmetric f32 scale per circulant block, dequantized inside the serving
math, so greedy outputs are BIT-identical to serving the dequantized
tables in fp32 while the resident table bytes drop to ~0.35x.

A recurrent-family section serves an RWKV config through the same
engine: ``ServeEngine`` picks the runner from the config
(``RecurrentRunner`` here), whose pad-invariant prefill makes left-padded
bucketed admission legal for stateful mixers — the bucketed outputs are
checked bit-identical against an unbucketed B=1 loop through the runner.

The last section demonstrates the failure semantics: a seeded
``ServeFaultInjector`` drives a transient decode launch failure (retried
transparently), bounded admission with reject-new shedding
(``QueueFullError`` backpressure), a per-request ``deadline_ms`` expiring
on a ``ManualClock``, and ``cancel()`` — every request lands in exactly
one terminal state (FINISHED/FAILED/EXPIRED/CANCELLED) with an ``error``
reason on the unsuccessful ones.

    PYTHONPATH=src python examples/serve_demo.py
"""

import numpy as np
import jax

from repro.configs.base import ModelConfig, SWMConfig, TrainConfig
from repro.data.pipeline import SyntheticLM
from repro.models.decoder import HybridDecoderLM
from repro.nn.module import init_params
from repro.serve.engine import Request, SamplingParams, ServeEngine
from repro.serve.guard import (ManualClock, QueueFullError,
                               ServeFaultInjector)
from repro.train.loop import init_train_state, make_train_step


def main():
    cfg = ModelConfig(
        name="serve-demo", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        head_dim=16, d_ff=128, vocab=128,
        swm=SWMConfig(block_size=8, impl="dft"),
        remat="none", param_dtype="float32", compute_dtype="float32",
    )
    tcfg = TrainConfig(learning_rate=5e-3, warmup_steps=10, total_steps=120,
                       z_loss=0.0)
    model = HybridDecoderLM(cfg)
    state = init_train_state(init_params(model.specs(), 0), tcfg)
    step = jax.jit(make_train_step(model, cfg, tcfg), donate_argnums=0)
    data = SyntheticLM(vocab=cfg.vocab, seq_len=48, batch=16)
    for s in range(120):
        state, metrics = step(state, data.batch_jax(s))
    print(f"trained 120 steps, final loss {float(metrics['loss']):.3f}")

    # 4 slots, prompt buckets 8/16, decode buckets 1/2/4 — the engine admits
    # a request the moment a slot frees up, so the short-budget requests
    # below don't stall the long ones (and vice versa), and once the batch
    # tails off, decode gathers the survivors into a smaller bucket instead
    # of stepping all 4 slot rows. prefix_cache lets later requests that
    # share a prompt head copy the resident donor rows (demo below); the
    # cache buffers are donated (default), so decode scatters update the
    # slot cache in place.
    engine = ServeEngine(model, cfg, state["params"], batch=4, cache_len=64,
                         prompt_buckets=(8, 16), decode_buckets=(1, 2, 4),
                         policy="sjf", prefix_cache=True)
    # prompts drawn from the training distribution: the model should
    # continue the +1..+6 drift pattern it learned
    prompts = [np.array([5, 9, 14, 18, 21], np.int32),
               np.array([100, 104, 107], np.int32),
               np.array([50, 53], np.int32),
               np.array([7, 11, 16, 19, 25, 28], np.int32),
               np.array([64, 70, 75], np.int32),
               np.array([30, 33, 37, 40], np.int32)]
    reqs = [
        Request(prompts[0], max_new=8),                       # greedy
        Request(prompts[1], max_new=3),                       # short budget
        Request(prompts[2], max_new=12),                      # long budget
        Request(prompts[3], max_new=8,
                stop_tokens=tuple(range(120, 128))),          # stop band
        Request(prompts[4], max_new=8,
                sampling=SamplingParams(temperature=0.7, top_k=8, seed=7)),
        Request(prompts[5], max_new=6),
    ]
    outs = engine.generate(reqs)
    for r, o in zip(reqs, outs):
        tag = ("sampled" if r.sampling.temperature > 0 else
               "stop" if r.stop_tokens else "greedy")
        print(f"prompt {np.asarray(r.prompt).tolist()} [{tag:7s} "
              f"max_new={r.max_new:2d}] -> {o}")
    s = engine.stats
    print(f"prefill shapes {sorted(s.prefill_shapes)} "
          f"({engine.prefill_compiles} compiles, bound "
          f"{engine.max_prefill_variants}); decode shapes "
          f"{sorted(s.decode_shapes)} ({engine.decode_compiles} compiles, "
          f"bound {engine.max_decode_variants}); tokens/decode-step "
          f"{s.tokens_per_decode_step:.2f}; decode-rows/token "
          f"{s.decode_rows_per_token:.2f}")

    # --- streaming: an open-ended trickle instead of a closed batch -------
    # submit() hands back a request id immediately; step() advances the
    # engine one admission+decode round; poll() snapshots partial tokens;
    # drain() finishes the stragglers and claims their outputs.
    print("\nstreaming trickle:")
    rids = []
    for i, p in enumerate(prompts[:4]):
        rid = engine.submit(Request(p, max_new=4 + 2 * i))
        rids.append(rid)
        engine.step()                       # requests decode while we submit
        v = engine.poll(rid)
        print(f"  submitted req {rid}; poll -> done={v.done} "
              f"tokens={list(v.tokens)}")
    done = engine.drain(rids)
    for rid in rids:
        print(f"  req {rid} finished: {done[rid]}")

    # --- shared-prefix KV reuse -------------------------------------------
    # many requests share one long prompt head (the multi-turn / few-shot
    # serving shape): after the first request prefills the head, later ones
    # copy the resident rows from its slot and prefill only their tails.
    print("\nshared-prefix reuse:")
    head = np.array([3, 9, 14, 20, 25, 31, 36, 42, 47, 53, 58, 64,
                     69, 75, 80, 86], np.int32)          # 16-token head
    tails = [np.array(t, np.int32) for t in
             ([90, 94], [101, 105, 110], [7, 12], [115, 120, 125],
              [50, 55], [33, 38, 44])]
    h0, s0 = engine.stats.prefix_hits, engine.stats.prefill_tokens_saved
    outs = engine.generate(
        [Request(np.concatenate([head, t]), max_new=4) for t in tails])
    for t, o in zip(tails, outs):
        print(f"  head+{t.tolist()} -> {o}")
    s = engine.stats
    print(f"  prefix hits {s.prefix_hits - h0}/{len(tails)}; prefill "
          f"tokens saved {s.prefill_tokens_saved - s0} "
          f"(lifetime hit rate {s.prefix_hit_rate:.2f})")

    # --- quantized serving: int8 frozen tables ----------------------------
    # the same trained weights, but freeze_params stores the frequency
    # tables as int8 with one f32 scale per circulant block. Dequant
    # happens inside the serving math (on the VMEM tile on the kernel
    # path), so outputs are bit-identical to serving the dequantized
    # tables in fp32 — at ~0.35x the resident table bytes and the same
    # compile budget.
    print("\nquantized serving (int8 frozen tables):")
    from repro.kernels.block_circulant.plan import dequantize_frozen

    q_engine = ServeEngine(model, cfg, state["params"], batch=4,
                           cache_len=64, prompt_buckets=(8, 16),
                           decode_buckets=(1, 2, 4), quantize="int8")
    oracle = ServeEngine(model, cfg, dequantize_frozen(q_engine.params),
                         batch=4, cache_len=64, prompt_buckets=(8, 16),
                         decode_buckets=(1, 2, 4))
    greedy = [Request(p, max_new=6) for p in prompts[:4]]
    outs_q = q_engine.generate(greedy)
    outs_o = oracle.generate([Request(p, max_new=6) for p in prompts[:4]])
    for r, o in zip(greedy, outs_q):
        print(f"  prompt {np.asarray(r.prompt).tolist()} -> {o}")
    bytes_q = q_engine.frozen_table_bytes()
    bytes_f = oracle.frozen_table_bytes()
    print(f"  int8 == dequantized-oracle outputs: {outs_q == outs_o}; "
          f"frozen table bytes {bytes_q} vs fp32 {bytes_f} "
          f"({bytes_q / bytes_f:.2f}x)")

    # --- recurrent family: RWKV behind the same engine --------------------
    # the engine is model-agnostic: it serves whatever family the config
    # names through a ModelRunner. For stateful mixers (rwkv/mamba) the
    # RecurrentRunner's pad-invariance contract makes left-padded bucketed
    # prefill legal — a padded bucket row computes the same post-prompt
    # state as running the prompt alone at its exact length.
    print("\nrecurrent family (rwkv):")
    from repro.configs.base import LayerGroup, LayerSpec
    from repro.serve.runner import make_runner

    import jax.numpy as jnp

    rcfg = ModelConfig(
        name="serve-demo-rwkv", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, head_dim=16, d_ff=128, vocab=128,
        rwkv_head_dim=16, rwkv_decay_lora=8, rwkv_mix_lora=8,
        groups=(LayerGroup(layers=(
            LayerSpec(mixer="rwkv", ffn="dense"),), repeat=2),),
        swm=SWMConfig(block_size=8, impl="dft"),
        remat="none", param_dtype="float32", compute_dtype="float32",
    )
    from repro.launch.specs import build_model

    rmodel = build_model(rcfg)
    rparams = init_params(rmodel.specs(), 0)
    rengine = ServeEngine(rmodel, rcfg, rparams, batch=4, cache_len=64,
                          prompt_buckets=(8, 16), decode_buckets=(1, 2, 4))
    print(f"  runner: {type(rengine.runner).__name__} "
          f"(prefix cache supported: {rengine.runner.supports_prefix_cache})")
    r_reqs = [Request(p, max_new=5) for p in prompts[:4]]
    r_outs = rengine.generate(r_reqs)
    # unbucketed B=1 oracle through the same runner: exact prompt lengths,
    # fresh state per request — the bucketed engine must match bit for bit
    runner = make_runner(rmodel, rcfg, 64)
    prefill = jax.jit(runner.prefill)
    decode = jax.jit(runner.decode)
    ref = []
    for r in r_reqs:
        p = np.asarray(r.prompt, np.int32)
        st = runner.init_state(1)
        lg, _, st = prefill(rengine.params, jnp.asarray(p)[None],
                            jnp.asarray(np.arange(len(p),
                                                  dtype=np.int32))[None],
                            st, jnp.asarray([0], np.int32))
        cur, out, pos = int(np.argmax(np.asarray(lg)[0])), [], len(p)
        out.append(cur)
        while len(out) < r.max_new:
            lg, _, st = decode(rengine.params, jnp.asarray([[cur]], np.int32),
                               st, jnp.asarray([pos], np.int32),
                               jnp.asarray([0], np.int32))
            cur = int(np.argmax(np.asarray(lg)[0]))
            out.append(cur)
            pos += 1
        ref.append(out)
    for r, o in zip(r_reqs, r_outs):
        print(f"  prompt {np.asarray(r.prompt).tolist()} -> {o}")
    print(f"  bucketed == unbucketed B=1: {r_outs == ref}")

    # --- failure semantics under injected faults --------------------------
    # a second engine serving the same weights through a seeded fault
    # schedule: a transient decode launch failure (retried, outputs
    # unchanged), a bounded admission queue with reject-new shedding, a
    # per-request deadline on a manual clock, and cancellation — every
    # request ends in exactly one terminal state.
    print("\nfault injection:")
    clk = ManualClock()
    inj = ServeFaultInjector(fail_decode_at={1}, clock=clk)
    ft_engine = ServeEngine(model, cfg, state["params"], batch=2,
                            cache_len=64, prompt_buckets=(8, 16),
                            max_queue=3, fault_injector=inj, clock=clk)
    rids = [ft_engine.submit(Request(prompts[0], max_new=6)),
            ft_engine.submit(Request(prompts[1], max_new=6,
                                     deadline_ms=25.0)),
            ft_engine.submit(Request(prompts[2], max_new=8))]
    try:                               # queue is full: reject-new sheds
        ft_engine.submit(Request(prompts[3], max_new=4))
    except QueueFullError as e:
        print(f"  shed: {e}")
    ft_engine.cancel(rids[2])
    while ft_engine.step():            # each step "takes" 10 ms
        clk.advance(0.010)
    for rid in rids:
        v = ft_engine.poll(rid)
        err = f" ({v.error})" if v.error else ""
        print(f"  req {rid}: {v.status}{err} tokens={list(v.tokens)}")
    fs = ft_engine.stats
    print(f"  stats: rejected={fs.rejected} expired={fs.expired} "
          f"cancelled={fs.cancelled} retries={fs.launch_retries} "
          f"aborted={fs.aborted}")

    # --- multi-tenant burst: fairness, SLOs, self-healing -----------------
    # three tenants burst through the asyncio front-end into a supervised
    # fair-policy engine. The DRR weights come from each tenant's SLO
    # class (interactive 4x / standard 2x / batch 1x), the front-end
    # stamps class deadlines, and a mid-stream engine fatal self-heals
    # from the latest snapshot — the burst finishes as if nothing died.
    print("\nmulti-tenant burst (fair DRR + SLOs + self-heal):")
    import asyncio
    import tempfile

    from repro.serve.frontend import AsyncFrontend, TenantConfig
    from repro.serve.supervisor import Supervisor

    tenants = {
        "chat-app": TenantConfig("chat-app", slo="interactive"),
        "dashboard": TenantConfig("dashboard", slo="standard"),
        "nightly-jobs": TenantConfig("nightly-jobs", slo="batch"),
    }
    weights = {n: c.slo_class.weight for n, c in tenants.items()}
    inj2 = ServeFaultInjector(fatal_decode_at={8})
    # a manual clock ticked 10 ms per engine round (via the front-end's
    # injectable sleep) keeps the SLO deadlines meaningful even when
    # interpret-mode launches take wall-clock seconds
    clk3 = ManualClock()

    async def tick(s):
        clk3.advance(max(float(s), 0.010))
        await asyncio.sleep(0)

    with tempfile.TemporaryDirectory() as snap_dir:
        def factory():
            return ServeEngine(model, cfg, state["params"], batch=4,
                               cache_len=64, prompt_buckets=(8, 16),
                               decode_buckets=(1, 2, 4), policy="fair",
                               tenant_weights=weights,
                               snapshot_dir=snap_dir, snapshot_every=2,
                               clock=clk3, fault_injector=inj2)

        sup = Supervisor(factory)
        fe = AsyncFrontend(sup, tenants, clock=clk3, sleep=tick)

        async def feed(name):
            rids = []
            for p in prompts[:4]:
                rids.append(await fe.submit(name, Request(p, max_new=5)))
            return rids

        async def burst():
            feeds = [asyncio.ensure_future(feed(n)) for n in sorted(tenants)]
            runner = asyncio.ensure_future(fe.run(idle_rounds=2))
            await asyncio.gather(*feeds)
            await runner

        asyncio.run(burst())
        while sup.step():                   # finish any straggler rounds
            pass
        st = sup.stats
        for name in sorted(tenants):
            ts = st.tenants[name]
            print(f"  {name:12s} [{tenants[name].slo:11s} "
                  f"w={tenants[name].slo_class.weight}] "
                  f"submitted={ts.submitted} admitted={ts.admitted} "
                  f"completed={ts.completed} "
                  f"ttft p50={ts.ttft_ms.p50} ms")
        print(f"  engine restarts={sup.restarts} "
              f"recoveries={st.recoveries}; fleet ttft "
              f"p50/p99 = {st.ttft_ms.p50}/{st.ttft_ms.p99} ms")


if __name__ == "__main__":
    main()
