"""Serving demo: train a tiny SWM LM briefly, then serve batched requests
through the continuous-batching engine (prefill → greedy decode).

    PYTHONPATH=src python examples/serve_demo.py
"""

import numpy as np
import jax

from repro.configs.base import ModelConfig, SWMConfig, TrainConfig
from repro.data.pipeline import SyntheticLM
from repro.models.decoder import HybridDecoderLM
from repro.nn.module import init_params
from repro.serve.engine import Request, ServeEngine
from repro.train.loop import init_train_state, make_train_step


def main():
    cfg = ModelConfig(
        name="serve-demo", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        head_dim=16, d_ff=128, vocab=128,
        swm=SWMConfig(block_size=8, impl="dft"),
        remat="none", param_dtype="float32", compute_dtype="float32",
    )
    tcfg = TrainConfig(learning_rate=5e-3, warmup_steps=10, total_steps=120,
                       z_loss=0.0)
    model = HybridDecoderLM(cfg)
    state = init_train_state(init_params(model.specs(), 0), tcfg)
    step = jax.jit(make_train_step(model, cfg, tcfg), donate_argnums=0)
    data = SyntheticLM(vocab=cfg.vocab, seq_len=48, batch=16)
    for s in range(120):
        state, metrics = step(state, data.batch_jax(s))
    print(f"trained 120 steps, final loss {float(metrics['loss']):.3f}")

    engine = ServeEngine(model, cfg, state["params"], batch=4, cache_len=64)
    # prompts drawn from the training distribution: the model should
    # continue the +1..+6 drift pattern it learned
    prompts = [np.array([5, 9, 14, 18, 21], np.int32),
               np.array([100, 104, 107], np.int32),
               np.array([50, 53], np.int32),
               np.array([7, 11, 16, 19, 25, 28], np.int32),
               np.array([64, 70, 75], np.int32)]
    outs = engine.generate([Request(p, max_new=8) for p in prompts])
    for p, o in zip(prompts, outs):
        print(f"prompt {list(p)} -> {o}")


if __name__ == "__main__":
    main()
