"""Paper §6.1 LSTM end-to-end: train the SWM-LSTM (Google-LSTM geometry,
TIMIT-like synthetic frames) at FFT8/FFT16 block sizes; report per-frame
accuracy (proxy for 1-PER) and model-size reduction vs the dense LSTM.

    PYTHONPATH=src python examples/lstm_asr.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import TrainConfig
from repro.data.pipeline import synthetic_speech
from repro.models.paper_models import SWMLSTMASR
from repro.nn.module import init_params, param_count
from repro.optim.optimizers import adamw_init, adamw_update


def train_one(block_size: int, steps: int = 250):
    model = SWMLSTMASR(d_cell=256, d_proj=128, block_size=block_size)
    tcfg = TrainConfig(learning_rate=8e-3, warmup_steps=10, total_steps=steps,
                       weight_decay=0.0)
    params = init_params(model.specs(), 0)
    opt = adamw_init(params, tcfg)

    @jax.jit
    def step(params, opt, i, x, y):
        def loss(p):
            lp = jax.nn.log_softmax(model(p, x))
            return -jnp.take_along_axis(lp, y[..., None], -1).mean()
        l, g = jax.value_and_grad(loss)(params)
        params, opt = adamw_update(params, g, opt, i, tcfg)
        return params, opt, l

    B, T = 16, 24
    for i in range(steps):
        x, y = synthetic_speech(B, T, 153, i)
        params, opt, l = step(params, opt, jnp.asarray(i), jnp.asarray(x),
                              jnp.asarray(y))
    hits = tot = 0
    for i in range(500, 504):
        x, y = synthetic_speech(B, T, 153, i)
        pred = np.asarray(jnp.argmax(model(params, jnp.asarray(x)), -1))
        hits += (pred == y).sum(); tot += y.size
    return hits / tot, param_count(model.specs())


def main():
    print(f"{'variant':>14} {'frame_acc':>10} {'params':>10} {'reduction':>10}")
    base = None
    for k, name in ((0, "dense"), (8, "FFT8/LSTM2"), (16, "FFT16/LSTM1")):
        acc, n = train_one(k)
        base = base or n
        print(f"{name:>14} {acc:10.4f} {n:10,} {base/n:9.1f}x")
    print("\n(paper: FFT8 → 7.6x size cut at 0.32% PER loss; "
          "FFT16 → 14.6x at 1.23%)")


if __name__ == "__main__":
    main()
